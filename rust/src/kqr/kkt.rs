//! Exact KKT certificate for the original (non-smooth) KQR problem (2).
//!
//! Stationarity of problem (2) reads 0 ∈ −(1/n) Σᵢ ∂ρ_τ(rᵢ)Kᵢ + λKα and
//! 0 ∈ −(1/n) Σᵢ ∂ρ_τ(rᵢ). Writing gᵢ = nλαᵢ, the first condition is
//! K(λα − g/n) = 0, i.e. (modulo the null space of K, which we project
//! away) **gᵢ must be a valid subgradient of ρ_τ at rᵢ**, and the second
//! is Σᵢ gᵢ = 0. This is the certificate the finite smoothing algorithm
//! terminates on: it holds only when the smoothed solution coincides with
//! the exact minimizer (Theorem 3).

use crate::smooth::rho_subgradient;
use crate::spectral::SpectralBasis;

/// Result of a KKT certificate evaluation.
#[derive(Clone, Debug)]
pub struct KktReport {
    /// max over i of dist(nλαᵢ, ∂ρ_τ(rᵢ)).
    pub max_stationarity: f64,
    /// |Σᵢ nλαᵢ| / n (intercept optimality).
    pub intercept: f64,
    /// Residual band below which a point is treated as on the singular set.
    pub band: f64,
    pub pass: bool,
}

impl KktReport {
    /// Scalar certificate quality: the worse of the two stationarity
    /// measures. Both solver backends (APGD's γ ladder and pALM-SSN's
    /// outer loop) keep the iterate with the smallest score, so
    /// "best-so-far" means the same thing everywhere.
    pub fn score(&self) -> f64 {
        self.max_stationarity.max(self.intercept)
    }

    /// Artifact/diagnostics serialization (see [`crate::api`]).
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        Json::obj(vec![
            ("pass", Json::Bool(self.pass)),
            ("max_stationarity", Json::num(self.max_stationarity)),
            ("intercept", Json::num(self.intercept)),
            ("band", Json::num(self.band)),
        ])
    }

    /// Inverse of [`KktReport::to_json`].
    pub fn from_json(v: &crate::util::Json) -> anyhow::Result<KktReport> {
        use anyhow::anyhow;
        Ok(KktReport {
            max_stationarity: v
                .get_f64("max_stationarity")
                .ok_or_else(|| anyhow!("kkt: missing max_stationarity"))?,
            intercept: v.get_f64("intercept").ok_or_else(|| anyhow!("kkt: missing intercept"))?,
            band: v.get_f64("band").ok_or_else(|| anyhow!("kkt: missing band"))?,
            pass: v.get_bool("pass").ok_or_else(|| anyhow!("kkt: missing pass"))?,
        })
    }
}

/// Evaluate the certificate at (b, β). `tol` is the unitless subgradient
/// tolerance; `band` the |rᵢ| ≈ 0 width (residual units).
#[allow(clippy::too_many_arguments)]
pub fn kkt_check(
    basis: &SpectralBasis,
    y: &[f64],
    tau: f64,
    lam: f64,
    b: f64,
    beta: &[f64],
    tol: f64,
    band: f64,
) -> KktReport {
    let n = basis.n;
    let nf = n as f64;
    // Note: do NOT project out small-eigenvalue components here. At the
    // smoothed optimum β_j = (Uᵀz)_j/(nλ) for every j with λ_j > 0 — the
    // tiny-eigenvalue directions barely move fitted values but carry the
    // subgradient identity nλα = z that this certificate verifies.
    let alpha = basis.alpha_from_beta(beta);
    let mut scratch = vec![0.0; basis.dim()];
    let mut f = vec![0.0; n];
    basis.fitted(b, beta, &mut scratch, &mut f);

    // Rank-deficient bases (exact zero eigenvalues, or a thin low-rank
    // factor from kernel::nystrom whose span is a strict subspace of ℝⁿ)
    // cannot satisfy nλαᵢ = zᵢ elementwise — stationarity only holds on
    // range(K̃). In that case we certify with an explicit subgradient
    // candidate ĝ = clamp(nλα, ∂ρ): range-projected stationarity
    // ‖Uᵀ_r(nλα − ĝ)‖∞ and b-stationarity |Σᵢ ĝᵢ|/n. For strictly
    // positive full-rank spectra the elementwise box check (tighter) is
    // used.
    let rank_deficient = basis.rank_deficient();
    let mut max_stat = 0.0f64;
    let mut sum_g = 0.0f64;
    let mut excess = vec![0.0f64; n];
    for i in 0..n {
        let r = y[i] - f[i];
        let g = nf * lam * alpha[i];
        let (lo, hi) = rho_subgradient(r, tau, band);
        let g_hat = g.clamp(lo, hi);
        excess[i] = g - g_hat;
        sum_g += if rank_deficient { g_hat } else { g };
        let viol = (lo - g).max(g - hi).max(0.0);
        if viol > max_stat {
            max_stat = viol;
        }
    }
    if rank_deficient {
        // project the excess onto the retained eigendirections
        let mut e = vec![0.0; basis.dim()];
        crate::linalg::gemv_t(&basis.u, &excess, &mut e);
        max_stat = 0.0;
        for (j, &l) in basis.lambda.iter().enumerate() {
            if l > 0.0 {
                max_stat = max_stat.max(e[j].abs());
            }
        }
    }
    let intercept = (sum_g / nf).abs();
    KktReport {
        max_stationarity: max_stat,
        intercept,
        band,
        pass: max_stat <= tol && intercept <= tol,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::kernel::Kernel;
    use crate::linalg::Matrix;

    /// On a constructed "solution" that violates the subgradient box the
    /// certificate must fail; on the true optimum of a tiny analytic
    /// problem it must pass.
    #[test]
    fn rejects_garbage_coefficients() {
        let mut rng = Rng::new(8);
        let x = Matrix::from_fn(12, 1, |_, _| rng.uniform());
        let k = Kernel::Rbf { sigma: 0.7 }.gram(&x);
        let basis = SpectralBasis::new(&k).unwrap();
        let y: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        // alpha = large constant → g_i = nλα_i way outside [τ−1, τ]
        let alpha = vec![5.0; 12];
        let beta = basis.beta_from_alpha(&alpha);
        let rep = kkt_check(&basis, &y, 0.5, 1.0, 0.0, &beta, 1e-4, 1e-8);
        assert!(!rep.pass);
        assert!(rep.max_stationarity > 1.0);
    }

    #[test]
    fn passes_on_analytic_median_solution() {
        // Single point, K = [[1]]: minimize ρ_τ(y − b − α) + (λ/2)α².
        // For λ large enough the optimum keeps |r| > 0 with subgradient
        // g = nλα = τ (r>0 side). Take y=1, τ=0.5, λ=0.25, n=1:
        //   λα = subgrad/n: α = τ/(nλ) = 2·0.5·... solve: α = τ/(nλ) = 2? No:
        //   g = nλα must equal τ → α = τ/(nλ) = 0.5/0.25 = 2 — but then
        //   stationarity wrt b requires Σg = 0 which fails with one point
        //   unless r = 0. With an intercept the single-point optimum has
        //   r = 0 (interpolation) and α = 0, g = 0 ∈ [τ−1, τ]. Verify that.
        let k = Matrix::from_vec(1, 1, vec![1.0]);
        let basis = SpectralBasis::new(&k).unwrap();
        let beta = basis.beta_from_alpha(&[0.0]);
        let rep = kkt_check(&basis, &[1.0], 0.5, 0.25, 1.0, &beta, 1e-6, 1e-8);
        assert!(rep.pass, "{rep:?}");
    }

    #[test]
    fn band_controls_singular_set_membership() {
        // r_i slightly off zero: with a wide band, interior subgradients
        // are acceptable; with a zero band they are not.
        let k = Matrix::from_vec(1, 1, vec![1.0]);
        let basis = SpectralBasis::new(&k).unwrap();
        let tau = 0.5;
        // y=1, fit b=0.999, α=0 → r = 0.001 > 0 needs g = τ = 0.5, but g=0.
        let beta = vec![0.0];
        let narrow = kkt_check(&basis, &[1.0], tau, 0.1, 0.999, &beta, 1e-6, 1e-6);
        assert!(!narrow.pass);
        let wide = kkt_check(&basis, &[1.0], tau, 0.1, 0.999, &beta, 1e-6, 1e-2);
        assert!(wide.pass);
    }
}
