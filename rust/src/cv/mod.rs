//! k-fold cross validation and λ-grid search, warm-started per fold.
//!
//! The paper's timing protocol (Tables 1–6) fits a 50-value λ path with
//! 5-fold CV and reports the whole wall time plus the objective at the
//! CV-selected λ. This module implements exactly that loop on top of
//! `KqrSolver::fit_path` — each fold builds its own Gram matrix and
//! eigenbasis, fits the full warm-started path, and scores held-out
//! pinball loss.

use crate::data::{Dataset, Rng};
use crate::kernel::Kernel;
use crate::kqr::{KqrSolver, SolveOptions};
use crate::smooth::pinball_loss;
use anyhow::Result;

/// Outcome of a cross-validated path fit.
#[derive(Clone, Debug)]
pub struct CvResult {
    /// λ grid (descending, as fitted).
    pub lambdas: Vec<f64>,
    /// Mean held-out pinball loss per λ.
    pub cv_loss: Vec<f64>,
    /// Index of the winning λ.
    pub best_index: usize,
    pub best_lambda: f64,
}

/// Assign each of `n` indices to one of `k` folds (balanced, shuffled).
pub fn fold_assignment(n: usize, k: usize, rng: &mut Rng) -> Vec<usize> {
    assert!(k >= 2 && k <= n);
    let perm = rng.permutation(n);
    let mut folds = vec![0usize; n];
    for (pos, &idx) in perm.iter().enumerate() {
        folds[idx] = pos % k;
    }
    folds
}

/// k-fold CV over a descending λ grid at quantile level τ.
pub fn cross_validate(
    data: &Dataset,
    kernel: &Kernel,
    tau: f64,
    lambdas: &[f64],
    k: usize,
    opts: &SolveOptions,
    rng: &mut Rng,
) -> Result<CvResult> {
    let n = data.n();
    let folds = fold_assignment(n, k, rng);
    let mut loss_sum = vec![0.0f64; lambdas.len()];
    for fold in 0..k {
        let train_idx: Vec<usize> = (0..n).filter(|i| folds[*i] != fold).collect();
        let test_idx: Vec<usize> = (0..n).filter(|i| folds[*i] == fold).collect();
        let train = data.subset(&train_idx);
        let test = data.subset(&test_idx);
        let solver = KqrSolver::new(&train.x, &train.y, kernel.clone())
            .with_options(opts.clone());
        let path = solver.fit_path(tau, lambdas)?;
        for (li, fit) in path.iter().enumerate() {
            let preds = fit.predict(&test.x);
            loss_sum[li] += pinball_loss(&test.y, &preds, tau);
        }
    }
    let cv_loss: Vec<f64> = loss_sum.iter().map(|s| s / k as f64).collect();
    let best_index = cv_loss
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    Ok(CvResult {
        lambdas: lambdas.to_vec(),
        cv_loss,
        best_index,
        best_lambda: lambdas[best_index],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn folds_are_balanced_partition() {
        let mut rng = Rng::new(1);
        let folds = fold_assignment(23, 5, &mut rng);
        assert_eq!(folds.len(), 23);
        let mut counts = vec![0usize; 5];
        for &f in &folds {
            assert!(f < 5);
            counts[f] += 1;
        }
        assert!(counts.iter().all(|&c| c == 4 || c == 5));
    }

    #[test]
    fn cv_selects_interior_lambda_on_smooth_signal() {
        let mut rng = Rng::new(2);
        let data = synth::sine_hetero(90, &mut rng);
        let sigma = crate::kernel::median_heuristic_sigma(&data.x);
        let kernel = Kernel::Rbf { sigma };
        let solver = KqrSolver::new(&data.x, &data.y, kernel.clone());
        let lams = solver.lambda_grid(8, 10.0, 1e-6);
        let res =
            cross_validate(&data, &kernel, 0.5, &lams, 4, &SolveOptions::default(), &mut rng)
                .unwrap();
        assert_eq!(res.cv_loss.len(), 8);
        assert!(res.cv_loss.iter().all(|v| v.is_finite()));
        // neither the most extreme over- nor under-smoothed end should win
        assert!(res.best_index > 0, "picked λ_max");
        assert_eq!(res.best_lambda, lams[res.best_index]);
    }
}
