//! Fit-job specifications and outcomes.

use crate::cv::CvResult;
use crate::data::Dataset;
use crate::kernel::Kernel;
use crate::kqr::KqrFit;
use crate::nckqr::NckqrFit;

/// What a job should compute.
#[derive(Clone, Debug)]
pub enum JobSpec {
    /// Single (τ, λ) KQR fit.
    Kqr { tau: f64, lambda: f64 },
    /// Warm-started descending-λ path at one τ.
    KqrPath { tau: f64, lambdas: Vec<f64> },
    /// Simultaneous non-crossing fit.
    Nckqr { taus: Vec<f64>, lam1: f64, lam2: f64 },
    /// k-fold CV over a λ grid.
    Cv { tau: f64, lambdas: Vec<f64>, folds: usize, seed: u64 },
}

impl JobSpec {
    /// Largest λ of the job (used for warm-start-aware ordering).
    pub fn lambda_head(&self) -> f64 {
        match self {
            JobSpec::Kqr { lambda, .. } => *lambda,
            JobSpec::KqrPath { lambdas, .. } => lambdas.first().copied().unwrap_or(0.0),
            JobSpec::Nckqr { lam2, .. } => *lam2,
            JobSpec::Cv { lambdas, .. } => lambdas.first().copied().unwrap_or(0.0),
        }
    }

    pub fn tau_head(&self) -> f64 {
        match self {
            JobSpec::Kqr { tau, .. } | JobSpec::KqrPath { tau, .. } | JobSpec::Cv { tau, .. } => {
                *tau
            }
            JobSpec::Nckqr { taus, .. } => taus.first().copied().unwrap_or(0.5),
        }
    }
}

/// A schedulable unit of work.
#[derive(Clone, Debug)]
pub struct FitJob {
    pub id: u64,
    pub dataset: Dataset,
    pub kernel: Kernel,
    pub spec: JobSpec,
}

impl FitJob {
    /// Fingerprint used to group jobs that share solver state (same data
    /// object ⇒ same Gram matrix / eigenbasis).
    pub fn dataset_key(&self) -> (usize, usize, String) {
        (self.dataset.n(), self.dataset.p(), self.dataset.name.clone())
    }
}

/// Result payload of a finished job.
#[derive(Clone, Debug)]
pub enum JobOutcome {
    Kqr(Vec<KqrFit>),
    Nckqr(NckqrFit),
    Cv(CvResult),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lambda_head_per_spec() {
        assert_eq!(JobSpec::Kqr { tau: 0.5, lambda: 0.3 }.lambda_head(), 0.3);
        assert_eq!(
            JobSpec::KqrPath { tau: 0.5, lambdas: vec![1.0, 0.1] }.lambda_head(),
            1.0
        );
        assert_eq!(
            JobSpec::Nckqr { taus: vec![0.5], lam1: 2.0, lam2: 0.7 }.lambda_head(),
            0.7
        );
    }
}
