//! Versioned JSON model artifacts.
//!
//! An artifact is everything `predict` needs — resolved kernel, training
//! inputs, per-level coefficients — plus the fit provenance (objective,
//! KKT report, iteration counts), in one self-describing document:
//!
//! ```json
//! { "format": "fastkqr.model", "format_version": 1,
//!   "created_by": "fastkqr 0.1.0", "kind": "kqr|set|nckqr",
//!   "kernel": {"type":"rbf","sigma":…}, "x_train": [[…]…], … }
//! ```
//!
//! Numbers are written with Rust's shortest-round-trip float formatting,
//! so every f64 — coefficients, intercepts, training inputs — reloads to
//! the identical bit pattern and a reloaded model's predictions equal the
//! original's bitwise. Readers accept any `format_version` ≤ theirs and
//! reject newer documents loudly instead of misreading them.
//!
//! **Compressed low-rank documents (format_version 2).** A fit produced
//! on a Nyström basis persists `"repr":"lowrank"` with the m landmark
//! inputs `z`, their training-row indices, `n_train`, and per-fit
//! m-dimensional kernel weights `w` — **no** `x_train` and no
//! n-dimensional α, so the artifact is O(m·p) instead of O(n·p + n) per
//! fit. Prediction from a reloaded document goes through the identical
//! landmark path the in-memory model uses, so it stays bitwise. Dense
//! models keep writing format_version 1 (older readers stay compatible);
//! version-1 readers reject low-rank documents loudly instead of
//! misreading them.
//!
//! **Random-feature documents (format_version 3).** A fit produced on a
//! random Fourier feature basis persists `"repr":"rff"` with the D×p
//! frequency matrix, the D phases, the drawing seed and `n_train`, plus
//! one D-dimensional feature weight vector `w` per fit — the artifact is
//! O(D·p) **independent of n**, smaller than any landmark document once
//! n outgrows D. The √(2/D) normalizer is recomputed from D on load
//! (bit-identical), so a reloaded model's predictions equal the
//! original's bitwise. Each version is the lowest that can represent the
//! model; older readers reject newer documents loudly.

use super::model::{shape_from_json, shape_to_json, CvSummary, ModelSet, QuantileModel};
use super::{kernel_from_json, kernel_to_json, matrix_from_json, matrix_to_json};
use crate::kernel::rff::RffMap;
use crate::kernel::Kernel;
use crate::kqr::kkt::KktReport;
use crate::kqr::KqrFit;
use crate::linalg::Matrix;
use crate::nckqr::{LevelCoef, NcLowRank, NcRff, NckqrFit};
use crate::spectral::{LowRankCoef, RffCoef};
use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// Highest artifact document version this build reads. [`to_json`]
/// writes the lowest version that can represent the model: 1 (dense),
/// 2 (compressed low-rank) or 3 (random features).
pub const ARTIFACT_VERSION: u64 = 3;
/// Magic `format` tag distinguishing model artifacts from other JSON.
pub const ARTIFACT_FORMAT: &str = "fastkqr.model";

fn kqr_fit_to_json(f: &KqrFit) -> Json {
    let mut pairs = vec![
        ("tau", Json::num(f.tau)),
        ("lambda", Json::num(f.lam)),
        ("b", Json::num(f.b)),
    ];
    // Compressed fits persist the small weight vector instead of the
    // n-dim α — that single choice is what makes the artifact O(m)
    // (landmark weights) or O(D) (feature weights).
    match (&f.rff, &f.lowrank) {
        (Some(rf), _) => pairs.push(("w", Json::arr_f64(&rf.w))),
        (None, Some(lr)) => pairs.push(("w", Json::arr_f64(&lr.w))),
        (None, None) => pairs.push(("alpha", Json::arr_f64(&f.alpha))),
    }
    pairs.extend(vec![
        ("objective", Json::num(f.objective)),
        ("gamma_final", Json::num(f.gamma_final)),
        ("apgd_iters", Json::num(f.apgd_iters as f64)),
        ("expansions", Json::num(f.expansions as f64)),
        ("singular_set", Json::arr_usize(&f.singular_set)),
        ("kkt", f.kkt.to_json()),
    ]);
    Json::obj(pairs)
}

fn kqr_fit_from_json(v: &Json, x_train: &Arc<Matrix>, kernel: &Kernel) -> Result<KqrFit> {
    let need = |key: &str| v.get_f64(key).ok_or_else(|| anyhow!("fit: missing {key:?}"));
    let alpha = v
        .get_f64_arr_strict("alpha")
        .ok_or_else(|| anyhow!("fit: missing 'alpha'"))?;
    if alpha.len() != x_train.rows() {
        bail!("fit: len(alpha)={} != n_train={}", alpha.len(), x_train.rows());
    }
    let kkt = KktReport::from_json(v.get("kkt").ok_or_else(|| anyhow!("fit: missing 'kkt'"))?)?;
    Ok(KqrFit::assemble(
        need("tau")?,
        need("lambda")?,
        need("b")?,
        alpha,
        need("objective")?,
        kkt,
        need("gamma_final")?,
        v.get_usize("apgd_iters").unwrap_or(0),
        v.get_usize("expansions").unwrap_or(0),
        v.get_usize_arr("singular_set").unwrap_or_default(),
        None,
        None,
        x_train.clone(),
        kernel.clone(),
    ))
}

/// Parse one compressed low-rank fit object (`"w"` instead of `"alpha"`).
fn kqr_fit_from_json_lowrank(
    v: &Json,
    z: &Arc<Matrix>,
    landmarks: &[usize],
    n_train: usize,
    kernel: &Kernel,
) -> Result<KqrFit> {
    let need = |key: &str| v.get_f64(key).ok_or_else(|| anyhow!("fit: missing {key:?}"));
    let w = v.get_f64_arr_strict("w").ok_or_else(|| anyhow!("lowrank fit: missing 'w'"))?;
    if w.len() != z.rows() {
        bail!("lowrank fit: len(w)={} != landmarks m={}", w.len(), z.rows());
    }
    let kkt = KktReport::from_json(v.get("kkt").ok_or_else(|| anyhow!("fit: missing 'kkt'"))?)?;
    Ok(KqrFit::assemble_compressed(
        need("tau")?,
        need("lambda")?,
        need("b")?,
        need("objective")?,
        kkt,
        need("gamma_final")?,
        v.get_usize("apgd_iters").unwrap_or(0),
        v.get_usize("expansions").unwrap_or(0),
        v.get_usize_arr("singular_set").unwrap_or_default(),
        n_train,
        LowRankCoef { z: z.clone(), landmarks: landmarks.to_vec(), w },
        kernel.clone(),
    ))
}

/// Parse one random-feature fit object (`"w"` holds the D-dimensional
/// feature weights).
fn kqr_fit_from_json_rff(
    v: &Json,
    map: &Arc<RffMap>,
    n_train: usize,
    kernel: &Kernel,
) -> Result<KqrFit> {
    let need = |key: &str| v.get_f64(key).ok_or_else(|| anyhow!("fit: missing {key:?}"));
    let w = v.get_f64_arr_strict("w").ok_or_else(|| anyhow!("rff fit: missing 'w'"))?;
    if w.len() != map.d() {
        bail!("rff fit: len(w)={} != d={}", w.len(), map.d());
    }
    let kkt = KktReport::from_json(v.get("kkt").ok_or_else(|| anyhow!("fit: missing 'kkt'"))?)?;
    Ok(KqrFit::assemble_compressed_rff(
        need("tau")?,
        need("lambda")?,
        need("b")?,
        need("objective")?,
        kkt,
        need("gamma_final")?,
        v.get_usize("apgd_iters").unwrap_or(0),
        v.get_usize("expansions").unwrap_or(0),
        v.get_usize_arr("singular_set").unwrap_or_default(),
        n_train,
        RffCoef { map: map.clone(), w },
        kernel.clone(),
    ))
}

/// Shared header of a compressed low-rank document (every kind writes
/// the same four keys): landmark indices, landmark inputs Z, original
/// training size.
fn push_lowrank_header<'a>(
    pairs: &mut Vec<(&'a str, Json)>,
    z: &Matrix,
    landmarks: &[usize],
    n_train: usize,
) {
    pairs.push(("repr", Json::str("lowrank")));
    pairs.push(("landmarks", Json::arr_usize(landmarks)));
    pairs.push(("z", matrix_to_json(z)));
    pairs.push(("n_train", Json::num(n_train as f64)));
}

/// Shared header of a random-feature document: the seed-pinned map
/// (frequencies + phases + seed) and the original training size. The
/// √(2/D) normalizer is a function of D and is recomputed on load.
fn push_rff_header<'a>(pairs: &mut Vec<(&'a str, Json)>, map: &RffMap, n_train: usize) {
    pairs.push(("repr", Json::str("rff")));
    pairs.push(("freqs", matrix_to_json(&map.freqs)));
    pairs.push(("phases", Json::arr_f64(&map.phases)));
    pairs.push(("rff_seed", Json::num(map.seed as f64)));
    pairs.push(("n_train", Json::num(n_train as f64)));
}

/// Serialize a model to the artifact document. Errors on an empty fit
/// set (which [`from_json`] would reject anyway) or a set mixing gram
/// representations (impossible from one solver).
pub fn to_json(model: &QuantileModel) -> Result<Json> {
    // Lowest version that represents the document (see ARTIFACT_VERSION).
    let fit_version = |lowrank: bool, rff: bool| if rff { 3u64 } else if lowrank { 2 } else { 1 };
    let version: u64 = match model {
        QuantileModel::Kqr(f) => fit_version(f.lowrank.is_some(), f.rff.is_some()),
        QuantileModel::Set(s) => s
            .fits
            .first()
            .map(|f| fit_version(f.lowrank.is_some(), f.rff.is_some()))
            .unwrap_or(1),
        QuantileModel::Nckqr(f) => fit_version(f.lowrank.is_some(), f.rff.is_some()),
    };
    let mut pairs = vec![
        ("format", Json::str(ARTIFACT_FORMAT)),
        ("format_version", Json::num(version as f64)),
        ("created_by", Json::str(format!("fastkqr {}", crate::version()))),
        ("kind", Json::str(model.kind())),
    ];
    match model {
        QuantileModel::Kqr(f) => {
            pairs.push(("kernel", kernel_to_json(f.kernel())));
            match (&f.rff, &f.lowrank) {
                (Some(rf), _) => push_rff_header(&mut pairs, &rf.map, f.n_train()),
                (None, Some(lr)) => {
                    push_lowrank_header(&mut pairs, &lr.z, &lr.landmarks, f.n_train())
                }
                (None, None) => pairs.push(("x_train", matrix_to_json(f.x_train()))),
            }
            pairs.push(("fit", kqr_fit_to_json(f)));
        }
        QuantileModel::Set(s) => {
            // All fits of a set share one solver, hence one kernel and
            // one Arc'd design matrix / landmark set — serialize once.
            let head = s
                .fits
                .first()
                .ok_or_else(|| anyhow!("cannot serialize an empty model set"))?;
            if s.fits.iter().any(|f| {
                f.lowrank.is_some() != head.lowrank.is_some()
                    || f.rff.is_some() != head.rff.is_some()
            }) {
                bail!("cannot serialize a set mixing gram representations");
            }
            pairs.push(("kernel", kernel_to_json(head.kernel())));
            match (&head.rff, &head.lowrank) {
                (Some(rf), _) => push_rff_header(&mut pairs, &rf.map, head.n_train()),
                (None, Some(lr)) => {
                    push_lowrank_header(&mut pairs, &lr.z, &lr.landmarks, head.n_train())
                }
                (None, None) => pairs.push(("x_train", matrix_to_json(head.x_train()))),
            }
            pairs.push(("fits", Json::Arr(s.fits.iter().map(kqr_fit_to_json).collect())));
            pairs.push(("shape", shape_to_json(&s.shape)));
            if !s.cv.is_empty() {
                pairs.push(("cv", Json::Arr(s.cv.iter().map(CvSummary::to_json).collect())));
            }
        }
        QuantileModel::Nckqr(f) => {
            pairs.push(("kernel", kernel_to_json(f.kernel())));
            match (&f.rff, &f.lowrank) {
                (Some(rf), _) => {
                    push_rff_header(&mut pairs, &rf.map, f.n_train());
                    pairs.push((
                        "levels",
                        Json::Arr(
                            f.levels
                                .iter()
                                .zip(&rf.w)
                                .map(|(lv, w)| {
                                    Json::obj(vec![
                                        ("tau", Json::num(lv.tau)),
                                        ("b", Json::num(lv.b)),
                                        ("w", Json::arr_f64(w)),
                                    ])
                                })
                                .collect(),
                        ),
                    ));
                }
                (None, Some(lr)) => {
                    push_lowrank_header(&mut pairs, &lr.z, &lr.landmarks, f.n_train());
                    pairs.push((
                        "levels",
                        Json::Arr(
                            f.levels
                                .iter()
                                .zip(&lr.w)
                                .map(|(lv, w)| {
                                    Json::obj(vec![
                                        ("tau", Json::num(lv.tau)),
                                        ("b", Json::num(lv.b)),
                                        ("w", Json::arr_f64(w)),
                                    ])
                                })
                                .collect(),
                        ),
                    ));
                }
                (None, None) => {
                    pairs.push(("x_train", matrix_to_json(f.x_train())));
                    pairs.push((
                        "levels",
                        Json::Arr(
                            f.levels
                                .iter()
                                .map(|lv| {
                                    Json::obj(vec![
                                        ("tau", Json::num(lv.tau)),
                                        ("b", Json::num(lv.b)),
                                        ("alpha", Json::arr_f64(&lv.alpha)),
                                    ])
                                })
                                .collect(),
                        ),
                    ));
                }
            }
            pairs.push(("taus", Json::arr_f64(&f.taus)));
            pairs.push(("lam1", Json::num(f.lam1)));
            pairs.push(("lam2", Json::num(f.lam2)));
            pairs.push(("objective", Json::num(f.objective)));
            pairs.push(("mm_iters", Json::num(f.mm_iters as f64)));
            pairs.push(("gamma_final", Json::num(f.gamma_final)));
            pairs.push(("train_crossings", Json::num(f.train_crossings as f64)));
            pairs.push(("kkt", f.kkt.to_json()));
        }
    }
    Ok(Json::obj(pairs))
}

/// Deserialize an artifact document.
pub fn from_json(v: &Json) -> Result<QuantileModel> {
    match v.get_str("format") {
        Some(ARTIFACT_FORMAT) => {}
        Some(other) => bail!("not a fastkqr model artifact (format {other:?})"),
        None => bail!("not a fastkqr model artifact (missing 'format')"),
    }
    let version = v.get_usize("format_version").unwrap_or(0) as u64;
    if version == 0 || version > ARTIFACT_VERSION {
        bail!(
            "artifact format_version {version} unsupported (this build reads 1..={ARTIFACT_VERSION})"
        );
    }
    let kernel =
        kernel_from_json(v.get("kernel").ok_or_else(|| anyhow!("artifact: missing 'kernel'"))?)?;
    // Compressed documents carry their representation instead of
    // x_train: low-rank brings (z, landmarks, n_train), random features
    // bring (freqs, phases, n_train). Dense documents parse as before.
    let (lowrank_doc, rff_doc_tag) = match v.get_str("repr") {
        None => (false, false),
        Some("lowrank") => (true, false),
        Some("rff") => (false, true),
        Some(other) => bail!("artifact: unknown repr {other:?}"),
    };
    let compressed = if lowrank_doc {
        let z = Arc::new(matrix_from_json(
            v.get("z").ok_or_else(|| anyhow!("lowrank artifact: missing 'z'"))?,
        )?);
        let landmarks = v
            .get_usize_arr("landmarks")
            .ok_or_else(|| anyhow!("lowrank artifact: missing 'landmarks'"))?;
        if landmarks.len() != z.rows() {
            bail!("lowrank artifact: {} landmarks for {} z rows", landmarks.len(), z.rows());
        }
        let n_train = v
            .get_usize("n_train")
            .ok_or_else(|| anyhow!("lowrank artifact: missing 'n_train'"))?;
        Some((z, landmarks, n_train))
    } else {
        None
    };
    let rff_doc = if rff_doc_tag {
        let freqs = matrix_from_json(
            v.get("freqs").ok_or_else(|| anyhow!("rff artifact: missing 'freqs'"))?,
        )?;
        let phases = v
            .get_f64_arr_strict("phases")
            .ok_or_else(|| anyhow!("rff artifact: missing 'phases'"))?;
        if freqs.rows() == 0 {
            bail!("rff artifact: empty frequency matrix");
        }
        if phases.len() != freqs.rows() {
            bail!("rff artifact: {} phases for {} frequencies", phases.len(), freqs.rows());
        }
        let n_train = v
            .get_usize("n_train")
            .ok_or_else(|| anyhow!("rff artifact: missing 'n_train'"))?;
        let seed = v.get_usize("rff_seed").unwrap_or(0) as u64;
        // √(2/D) is a pure function of D — recomputed bit-identically.
        let scale = (2.0 / freqs.rows() as f64).sqrt();
        Some((Arc::new(RffMap { freqs, phases, scale, seed }), n_train))
    } else {
        None
    };
    let dense_x_train = || -> Result<Arc<Matrix>> {
        Ok(Arc::new(matrix_from_json(
            v.get("x_train").ok_or_else(|| anyhow!("artifact: missing 'x_train'"))?,
        )?))
    };
    match v.get_str("kind") {
        Some("kqr") => {
            let fit = v.get("fit").ok_or_else(|| anyhow!("artifact: missing 'fit'"))?;
            match (&rff_doc, &compressed) {
                (Some((map, n_train)), _) => Ok(QuantileModel::Kqr(kqr_fit_from_json_rff(
                    fit, map, *n_train, &kernel,
                )?)),
                (None, Some((z, landmarks, n_train))) => Ok(QuantileModel::Kqr(
                    kqr_fit_from_json_lowrank(fit, z, landmarks, *n_train, &kernel)?,
                )),
                (None, None) => {
                    let x_train = dense_x_train()?;
                    Ok(QuantileModel::Kqr(kqr_fit_from_json(fit, &x_train, &kernel)?))
                }
            }
        }
        Some("set") => {
            let fits_json = v
                .get("fits")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact: missing 'fits'"))?;
            if fits_json.is_empty() {
                bail!("artifact: empty fit set");
            }
            let fits: Vec<KqrFit> = match (&rff_doc, &compressed) {
                (Some((map, n_train)), _) => fits_json
                    .iter()
                    .map(|f| kqr_fit_from_json_rff(f, map, *n_train, &kernel))
                    .collect::<Result<_>>()?,
                (None, Some((z, landmarks, n_train))) => fits_json
                    .iter()
                    .map(|f| kqr_fit_from_json_lowrank(f, z, landmarks, *n_train, &kernel))
                    .collect::<Result<_>>()?,
                (None, None) => {
                    let x_train = dense_x_train()?;
                    fits_json
                        .iter()
                        .map(|f| kqr_fit_from_json(f, &x_train, &kernel))
                        .collect::<Result<_>>()?
                }
            };
            let shape = shape_from_json(
                v.get("shape").ok_or_else(|| anyhow!("artifact: missing 'shape'"))?,
            )?;
            let cv = match v.get("cv").and_then(Json::as_arr) {
                None => Vec::new(),
                Some(arr) => arr.iter().map(CvSummary::from_json).collect::<Result<_>>()?,
            };
            Ok(QuantileModel::Set(ModelSet { fits, shape, cv, lockstep: None, solver: None, ssn: None }))
        }
        Some("nckqr") => {
            let taus = v
                .get_f64_arr_strict("taus")
                .ok_or_else(|| anyhow!("artifact: missing 'taus'"))?;
            let levels_json = v
                .get("levels")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact: missing 'levels'"))?;
            if levels_json.len() != taus.len() {
                bail!("artifact: {} levels for {} taus", levels_json.len(), taus.len());
            }
            let kkt = KktReport::from_json(
                v.get("kkt").ok_or_else(|| anyhow!("artifact: missing 'kkt'"))?,
            )?;
            let lam1 =
                v.get_f64("lam1").ok_or_else(|| anyhow!("artifact: missing 'lam1'"))?;
            let lam2 =
                v.get_f64("lam2").ok_or_else(|| anyhow!("artifact: missing 'lam2'"))?;
            let objective = v
                .get_f64("objective")
                .ok_or_else(|| anyhow!("artifact: missing 'objective'"))?;
            let mm_iters = v.get_usize("mm_iters").unwrap_or(0);
            let gamma_final = v.get_f64("gamma_final").unwrap_or(0.0);
            let train_crossings = v.get_usize("train_crossings").unwrap_or(0);
            match (rff_doc, compressed) {
                (Some((map, n_train)), _) => {
                    let mut levels = Vec::with_capacity(levels_json.len());
                    let mut ws = Vec::with_capacity(levels_json.len());
                    for lv in levels_json {
                        let w = lv
                            .get_f64_arr_strict("w")
                            .ok_or_else(|| anyhow!("rff level: missing 'w'"))?;
                        if w.len() != map.d() {
                            bail!("rff level: len(w)={} != d={}", w.len(), map.d());
                        }
                        levels.push(LevelCoef {
                            tau: lv
                                .get_f64("tau")
                                .ok_or_else(|| anyhow!("level: missing 'tau'"))?,
                            b: lv.get_f64("b").ok_or_else(|| anyhow!("level: missing 'b'"))?,
                            alpha: Vec::new(),
                        });
                        ws.push(w);
                    }
                    Ok(QuantileModel::Nckqr(NckqrFit::assemble_compressed_rff(
                        taus,
                        lam1,
                        lam2,
                        levels,
                        objective,
                        kkt,
                        mm_iters,
                        gamma_final,
                        train_crossings,
                        n_train,
                        NcRff { map, w: ws },
                        kernel,
                    )))
                }
                (None, Some((z, landmarks, n_train))) => {
                    let mut levels = Vec::with_capacity(levels_json.len());
                    let mut ws = Vec::with_capacity(levels_json.len());
                    for lv in levels_json {
                        let w = lv
                            .get_f64_arr_strict("w")
                            .ok_or_else(|| anyhow!("lowrank level: missing 'w'"))?;
                        if w.len() != z.rows() {
                            bail!("lowrank level: len(w)={} != m={}", w.len(), z.rows());
                        }
                        levels.push(LevelCoef {
                            tau: lv
                                .get_f64("tau")
                                .ok_or_else(|| anyhow!("level: missing 'tau'"))?,
                            b: lv.get_f64("b").ok_or_else(|| anyhow!("level: missing 'b'"))?,
                            alpha: Vec::new(),
                        });
                        ws.push(w);
                    }
                    Ok(QuantileModel::Nckqr(NckqrFit::assemble_compressed(
                        taus,
                        lam1,
                        lam2,
                        levels,
                        objective,
                        kkt,
                        mm_iters,
                        gamma_final,
                        train_crossings,
                        n_train,
                        NcLowRank { z, landmarks, w: ws },
                        kernel,
                    )))
                }
                (None, None) => {
                    let x_train = dense_x_train()?;
                    let mut levels = Vec::with_capacity(levels_json.len());
                    for lv in levels_json {
                        let alpha = lv
                            .get_f64_arr_strict("alpha")
                            .ok_or_else(|| anyhow!("level: missing 'alpha'"))?;
                        if alpha.len() != x_train.rows() {
                            bail!(
                                "level: len(alpha)={} != n_train={}",
                                alpha.len(),
                                x_train.rows()
                            );
                        }
                        levels.push(LevelCoef {
                            tau: lv
                                .get_f64("tau")
                                .ok_or_else(|| anyhow!("level: missing 'tau'"))?,
                            b: lv.get_f64("b").ok_or_else(|| anyhow!("level: missing 'b'"))?,
                            alpha,
                        });
                    }
                    Ok(QuantileModel::Nckqr(NckqrFit::assemble(
                        taus,
                        lam1,
                        lam2,
                        levels,
                        objective,
                        kkt,
                        mm_iters,
                        gamma_final,
                        train_crossings,
                        x_train,
                        kernel,
                    )))
                }
            }
        }
        other => bail!("artifact: unknown kind {other:?}"),
    }
}

/// Write `model` to `path` as one compact JSON document.
///
/// The write is atomic (temp file in the same directory + rename): a
/// crash or full disk mid-write never leaves a truncated artifact behind
/// — important for registry persistence directories, which are reloaded
/// wholesale at server startup.
pub fn save(model: &QuantileModel, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("create {}", parent.display()))?;
        }
    }
    let mut doc = to_json(model)?.to_string();
    doc.push('\n');
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, doc).with_context(|| format!("write {}", tmp.display()))?;
    std::fs::rename(&tmp, path).with_context(|| {
        let _ = std::fs::remove_file(&tmp);
        format!("rename {} -> {}", tmp.display(), path.display())
    })?;
    Ok(())
}

/// Read a model artifact from `path`.
pub fn load(path: &Path) -> Result<QuantileModel> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
    let v = Json::parse(text.trim())
        .map_err(|e| anyhow!("{}: not valid JSON: {e}", path.display()))?;
    from_json(&v).with_context(|| format!("load model artifact {}", path.display()))
}

/// [`load`] plus the compiled serving plan: the consumers that load in
/// order to *predict* (the CLI's `predict` subcommand, registry reloads,
/// benches) get the [`PredictPlan`](crate::engine::PredictPlan) compiled
/// exactly once at artifact-load time instead of re-deriving the
/// coefficient layout per request. An artifact parses into one shared
/// `x_train`/landmark `Arc` for all its fits, so the plan always
/// compiles to a single group.
pub fn load_compiled(
    path: &Path,
) -> Result<(QuantileModel, std::sync::Arc<crate::engine::PredictPlan>)> {
    let model = load(path)?;
    let plan = std::sync::Arc::new(model.compile_plan());
    Ok((model, plan))
}

// ---------------------------------------------------------------------
// Generation manifest: cheap change detection for shared artifact dirs.
// ---------------------------------------------------------------------

/// File name of the generation manifest inside a persistence directory.
/// Registry scans must skip it — it describes artifacts, it isn't one.
pub const MANIFEST_FILE: &str = "manifest.json";
/// Magic `format` tag of manifest documents.
pub const MANIFEST_FORMAT: &str = "fastkqr.manifest";
/// Manifest document version this build reads and writes.
pub const MANIFEST_VERSION: u64 = 1;
/// A `manifest.lock` older than this is presumed abandoned (crashed
/// writer) and removed.
const LOCK_STALE: std::time::Duration = std::time::Duration::from_secs(5);
/// How long [`update_manifest`] waits for the lock before giving up.
const LOCK_DEADLINE: std::time::Duration = std::time::Duration::from_secs(5);

/// The generation manifest of a shared persistence directory:
///
/// ```json
/// { "format": "fastkqr.manifest", "format_version": 1,
///   "generation": 7, "models": {"r0m0": 3, "r1m0": 7} }
/// ```
///
/// `generation` is bumped on **every** artifact write or removal, and
/// each model records the generation of its last write. Replicas sharing
/// the directory poll the one small file — not N artifacts — and
/// hot-swap exactly the models whose recorded generation moved (see
/// `ModelRegistry::refresh`). The write itself is atomic (temp + rename,
/// like artifacts) and read-modify-write cycles are serialized through a
/// `manifest.lock` file, so concurrent replicas never lose an update.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Manifest {
    pub generation: u64,
    /// Model id → generation of its last artifact write.
    pub models: std::collections::BTreeMap<String, u64>,
}

impl Manifest {
    pub fn to_json(&self) -> Json {
        let models = Json::Obj(
            self.models.iter().map(|(k, &g)| (k.clone(), Json::num(g as f64))).collect(),
        );
        Json::obj(vec![
            ("format", Json::str(MANIFEST_FORMAT)),
            ("format_version", Json::num(MANIFEST_VERSION as f64)),
            ("generation", Json::num(self.generation as f64)),
            ("models", models),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Manifest> {
        match v.get_str("format") {
            Some(MANIFEST_FORMAT) => {}
            other => bail!("not a fastkqr manifest (format {other:?})"),
        }
        let version = v.get_usize("format_version").unwrap_or(0) as u64;
        if version == 0 || version > MANIFEST_VERSION {
            bail!("manifest format_version {version} unsupported (this build reads 1..={MANIFEST_VERSION})");
        }
        let generation = v
            .get_usize("generation")
            .ok_or_else(|| anyhow!("manifest: missing 'generation'"))? as u64;
        let mut models = std::collections::BTreeMap::new();
        match v.get("models") {
            Some(Json::Obj(m)) => {
                for (id, gv) in m {
                    let g = gv
                        .as_f64()
                        .filter(|g| *g >= 0.0 && *g == g.trunc())
                        .ok_or_else(|| anyhow!("manifest: bad generation for {id:?}"))?;
                    models.insert(id.clone(), g as u64);
                }
            }
            Some(_) => bail!("manifest: 'models' is not an object"),
            None => bail!("manifest: missing 'models'"),
        }
        Ok(Manifest { generation, models })
    }
}

/// Path of the manifest inside `dir`.
pub fn manifest_path(dir: &Path) -> std::path::PathBuf {
    dir.join(MANIFEST_FILE)
}

/// Read the manifest of `dir`. `Ok(None)` when the directory has none
/// yet (a pre-manifest directory or a fresh one) — that is not an error;
/// a corrupt or foreign `manifest.json` is.
pub fn read_manifest(dir: &Path) -> Result<Option<Manifest>> {
    let path = manifest_path(dir);
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e).with_context(|| format!("read {}", path.display())),
    };
    let v = Json::parse(text.trim())
        .map_err(|e| anyhow!("{}: not valid JSON: {e}", path.display()))?;
    Manifest::from_json(&v)
        .with_context(|| format!("load manifest {}", path.display()))
        .map(Some)
}

/// Removes the lock file when the guard drops (including on early
/// returns and panics inside the critical section).
struct LockGuard(std::path::PathBuf);

impl Drop for LockGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

fn acquire_manifest_lock(dir: &Path) -> Result<LockGuard> {
    let lock = dir.join("manifest.lock");
    let deadline = std::time::Instant::now() + LOCK_DEADLINE;
    loop {
        match std::fs::OpenOptions::new().write(true).create_new(true).open(&lock) {
            Ok(mut f) => {
                use std::io::Write as _;
                let _ = writeln!(f, "{}", std::process::id());
                return Ok(LockGuard(lock));
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                // a writer crashed mid-update: break abandoned locks
                if let Ok(meta) = std::fs::metadata(&lock) {
                    let stale = meta
                        .modified()
                        .ok()
                        .and_then(|t| t.elapsed().ok())
                        .is_some_and(|age| age > LOCK_STALE);
                    if stale {
                        let _ = std::fs::remove_file(&lock);
                        continue;
                    }
                }
                if std::time::Instant::now() >= deadline {
                    bail!("timed out waiting for {}", lock.display());
                }
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            Err(e) => {
                return Err(e).with_context(|| format!("create {}", lock.display()));
            }
        }
    }
}

/// Bump the manifest of `dir`: the global generation increments once,
/// every id in `touched` is stamped with the new generation, every id in
/// `removed` is dropped. Returns the updated manifest. The
/// read-modify-write runs under `manifest.lock`, and the file itself is
/// replaced atomically — concurrent replica writers serialize, pollers
/// never see a torn document.
pub fn update_manifest(dir: &Path, touched: &[&str], removed: &[&str]) -> Result<Manifest> {
    let _lock = acquire_manifest_lock(dir)?;
    let mut manifest = read_manifest(dir)?.unwrap_or_default();
    manifest.generation += 1;
    for id in touched {
        manifest.models.insert((*id).to_string(), manifest.generation);
    }
    for id in removed {
        manifest.models.remove(*id);
    }
    let path = manifest_path(dir);
    let mut doc = manifest.to_json().to_string();
    doc.push('\n');
    let tmp = dir.join("manifest.json.tmp");
    std::fs::write(&tmp, doc).with_context(|| format!("write {}", tmp.display()))?;
    std::fs::rename(&tmp, &path).with_context(|| {
        let _ = std::fs::remove_file(&tmp);
        format!("rename {} -> {}", tmp.display(), path.display())
    })?;
    Ok(manifest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, Rng};

    fn toy_kqr_model() -> QuantileModel {
        let mut rng = Rng::new(21);
        let d = synth::sine_hetero(18, &mut rng);
        let fit = crate::kqr::KqrSolver::new(&d.x, &d.y, Kernel::Rbf { sigma: 0.4 })
            .unwrap()
            .fit(0.5, 0.05)
            .unwrap();
        QuantileModel::Kqr(fit)
    }

    #[test]
    fn rff_artifact_roundtrips_and_is_version_3() {
        use crate::spectral::GramRepr;
        let mut rng = Rng::new(33);
        let d = synth::sine_hetero(24, &mut rng);
        let kernel = Kernel::Rbf { sigma: 0.5 };
        let factor = crate::kernel::rff::rff(&d.x, &kernel, 16, 7).unwrap();
        let solver = crate::kqr::KqrSolver::with_repr(
            &d.x,
            &d.y,
            kernel,
            GramRepr::RandomFeatures(std::sync::Arc::new(factor)),
        );
        let fit = solver.fit(0.5, 0.05).unwrap();
        let model = QuantileModel::Kqr(fit);
        let doc = to_json(&model).unwrap();
        assert_eq!(doc.get_usize("format_version"), Some(3));
        assert_eq!(doc.get_str("repr"), Some("rff"));
        assert!(doc.get("x_train").is_none(), "rff artifacts are n-free");
        let back = from_json(&doc).unwrap();
        assert_eq!(to_json(&back).unwrap().to_string(), doc.to_string());
        // reloaded predictions are bitwise equal
        let mut rng2 = Rng::new(34);
        let xt = Matrix::from_fn(9, d.x.cols(), |_, _| rng2.normal());
        assert_eq!(model.predict(&xt), back.predict(&xt));
    }

    #[test]
    fn kqr_artifact_roundtrips_in_memory() {
        let model = toy_kqr_model();
        let doc = to_json(&model).unwrap();
        assert_eq!(doc.get_str("format"), Some(ARTIFACT_FORMAT));
        let back = from_json(&doc).unwrap();
        // the serialized form of the reloaded model is identical
        assert_eq!(to_json(&back).unwrap().to_string(), doc.to_string());
    }

    #[test]
    fn rejects_foreign_and_future_documents() {
        assert!(from_json(&Json::parse(r#"{"hello":1}"#).unwrap()).is_err());
        assert!(from_json(
            &Json::parse(r#"{"format":"fastkqr.model","format_version":999,"kind":"kqr"}"#)
                .unwrap()
        )
        .is_err());
        let mut doc = to_json(&toy_kqr_model()).unwrap();
        if let Json::Obj(m) = &mut doc {
            m.insert("kind".into(), Json::str("mystery"));
        }
        assert!(from_json(&doc).is_err());
    }

    #[test]
    fn manifest_updates_bump_generations_per_id() {
        let dir = std::env::temp_dir().join(format!(
            "fastkqr-manifest-test-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(read_manifest(&dir).unwrap().is_none(), "fresh dir has no manifest");
        let m1 = update_manifest(&dir, &["m0"], &[]).unwrap();
        assert_eq!(m1.generation, 1);
        assert_eq!(m1.models.get("m0"), Some(&1));
        let m2 = update_manifest(&dir, &["m1"], &[]).unwrap();
        assert_eq!(m2.generation, 2);
        assert_eq!(m2.models.get("m0"), Some(&1), "untouched ids keep their generation");
        assert_eq!(m2.models.get("m1"), Some(&2));
        // a re-write of m0 moves only m0's generation
        let m3 = update_manifest(&dir, &["m0"], &[]).unwrap();
        assert_eq!(m3.models.get("m0"), Some(&3));
        assert_eq!(m3.models.get("m1"), Some(&2));
        // removal drops the id but still bumps the global counter
        let m4 = update_manifest(&dir, &[], &["m1"]).unwrap();
        assert_eq!(m4.generation, 4);
        assert!(!m4.models.contains_key("m1"));
        // what's on disk is exactly what update returned
        assert_eq!(read_manifest(&dir).unwrap().unwrap(), m4);
        // the lock is released
        assert!(!dir.join("manifest.lock").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_rejects_foreign_documents() {
        assert!(Manifest::from_json(&Json::parse(r#"{"zzz":1}"#).unwrap()).is_err());
        assert!(Manifest::from_json(
            &Json::parse(r#"{"format":"fastkqr.manifest","format_version":99,"generation":1,"models":{}}"#)
                .unwrap()
        )
        .is_err());
        let ok = Manifest::from_json(
            &Json::parse(
                r#"{"format":"fastkqr.manifest","format_version":1,"generation":3,"models":{"r0m0":3}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(ok.generation, 3);
        assert_eq!(ok.models.get("r0m0"), Some(&3));
    }

    #[test]
    fn empty_set_serialization_is_an_error_not_a_panic() {
        use crate::api::{ModelSet, SetShape};
        let empty = QuantileModel::Set(ModelSet {
            fits: Vec::new(),
            shape: SetShape::Path { tau: 0.5 },
            cv: Vec::new(),
            lockstep: None,
            solver: None,
            ssn: None,
        });
        assert!(to_json(&empty).is_err());
    }
}
