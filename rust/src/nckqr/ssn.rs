//! Semismooth-Newton backend for the non-crossing task.
//!
//! Lifts the pALM construction of [`crate::solver::ssn`] to problem (12):
//! every level keeps its own Moreau-envelope check loss (split residual
//! u_t, multiplier w_t, shared penalty σ), the λ₂ ridge acts per level,
//! and the η_exact-smoothed crossing penalty λ₁ Σ V(f_t − f_{t+1}) stays
//! on the fitted values directly — it is C¹, so it contributes its exact
//! gradient and its a.e. second derivative joins the generalized
//! Jacobian as **crossing rows**: for every adjacent pair (t, i) with
//! |f_t(xᵢ) − f_{t+1}(xᵢ)| ≤ η_exact, the rank-1 term
//! μ·E E^T with E = [1; Wᵢ] at block t minus [1; Wᵢ] at block t+1 and
//! μ = λ₁/(2η_exact) (V″ inside the band).
//!
//! The Newton system couples all T levels through those rows: one
//! T(dim+1) Cholesky factor per refresh, maintained across Newton steps
//! by rank-1 up/downdates over the symmetric difference of the envelope
//! active sets **and** the crossing band, and carried across outer
//! rounds by a σ-shift over the factor's own active sets (the crossing
//! rows are σ-independent and carry for free). Certification and the
//! reported objective go through the same exact-problem
//! [`NckqrSolver::kkt_check`] / [`NckqrSolver::exact_objective`] as the
//! MM path, so `--solver ssn` fits are certified against the identical
//! criterion.

use super::{count_crossings_in, LevelCoef, LevelState, NcLowRank, NcRff, NckqrFit, NckqrSolver, ETA_EXACT};
use crate::kqr::apgd::ApgdWorkspace;
use crate::kqr::kkt::KktReport;
use crate::linalg::{amax, gemv, gemv_t, Cholesky, Matrix};
use crate::smooth::{rho_tau, smooth_relu, smooth_relu_prime};
use crate::solver::ssn::{
    prox_rho, swing_cap, INNER_TOL_FLOOR, MAX_NEWTON, MAX_OUTER, MAX_STALL, SIGMA_GROWTH,
    SIGMA_INIT, SIGMA_MAX, TAU_P,
};
use crate::solver::SsnGridStats;
use anyhow::{bail, Result};

/// Generalized-Jacobian weight of one banded crossing row (V″ = 1/(2η)
/// inside |δ| ≤ η).
#[inline]
fn crossing_weight(lam1: f64) -> f64 {
    lam1 / (2.0 * ETA_EXACT)
}

/// Scratch buffers for the lifted solve; all per-level slots are indexed
/// by level (T × n) and the stacked slots by the block layout
/// z = (b_0, η_0, …, b_{T−1}, η_{T−1}) of length m = T(dim+1).
struct NcWs {
    /// fitted values per level
    f: Vec<Vec<f64>>,
    /// shifted residuals v_t = y − f_t − w_t/σ
    v: Vec<Vec<f64>>,
    /// envelope gradients s_t = v_t − prox(v_t)
    s: Vec<Vec<f64>>,
    /// envelope active sets (prox(v) == 0) per level
    active: Vec<Vec<bool>>,
    /// crossing-band membership per adjacent pair ((T−1) × n)
    band: Vec<Vec<bool>>,
    /// V′(f_t − f_{t+1}) per adjacent pair
    q: Vec<Vec<f64>>,
    /// stacked gradient / Newton direction (length m)
    grad: Vec<f64>,
    dir: Vec<f64>,
    /// per-level direction images Δ_t = d_{b_t} + W d_{η_t}
    delta: Vec<Vec<f64>>,
    /// n-scratch for the crossing gradient rows q_t − q_{t−1}
    r: Vec<f64>,
    /// dim-scratches (Uᵀs and spectral products)
    uts: Vec<f64>,
    scratch: Vec<f64>,
}

impl NcWs {
    fn new(t_lv: usize, n: usize, dim: usize) -> NcWs {
        let m = t_lv * (dim + 1);
        NcWs {
            f: vec![vec![0.0; n]; t_lv],
            v: vec![vec![0.0; n]; t_lv],
            s: vec![vec![0.0; n]; t_lv],
            active: vec![vec![false; n]; t_lv],
            band: vec![vec![false; n]; t_lv.saturating_sub(1)],
            q: vec![vec![0.0; n]; t_lv.saturating_sub(1)],
            grad: vec![0.0; m],
            dir: vec![0.0; m],
            delta: vec![vec![0.0; n]; t_lv],
            r: vec![0.0; n],
            uts: vec![0.0; dim],
            scratch: vec![0.0; dim],
        }
    }
}

/// A kept T(dim+1) factor with the sets it embeds (the lifted analogue
/// of [`crate::solver::ssn::FactorCarry`]).
struct NcFactor {
    chol: Cholesky,
    active: Vec<Vec<bool>>,
    band: Vec<Vec<bool>>,
    sigma: f64,
}

#[derive(Default)]
struct InnerNc {
    steps: usize,
    refactors: usize,
    updates: usize,
    seeded: bool,
}

/// Refresh f, v, s, the envelope active sets, and the crossing-band
/// state for the current iterate.
#[allow(clippy::too_many_arguments)]
fn refresh(
    solver: &NckqrSolver,
    sqrt_lam: &[f64],
    lam1: f64,
    b: &[f64],
    eta: &[Vec<f64>],
    w: &[Vec<f64>],
    sigma: f64,
    ws: &mut NcWs,
) {
    let n = solver.n();
    let t_lv = solver.t_levels();
    let c = 1.0 / (n as f64 * sigma);
    {
        let (scratch, f) = (&mut ws.scratch, &mut ws.f);
        for lv in 0..t_lv {
            for (sc, (sl, e)) in scratch.iter_mut().zip(sqrt_lam.iter().zip(&eta[lv])) {
                *sc = sl * e;
            }
            gemv(&solver.basis.u, scratch, &mut f[lv]);
        }
    }
    for lv in 0..t_lv {
        let (lo, hi) = (c * (1.0 - solver.taus[lv]), c * solver.taus[lv]);
        for i in 0..n {
            let fi = b[lv] + ws.f[lv][i];
            ws.f[lv][i] = fi;
            let vi = solver.y[i] - fi - w[lv][i] / sigma;
            ws.v[lv][i] = vi;
            let p = prox_rho(vi, lo, hi);
            ws.s[lv][i] = vi - p;
            ws.active[lv][i] = p == 0.0;
        }
    }
    for lv in 0..t_lv.saturating_sub(1) {
        for i in 0..n {
            let d = ws.f[lv][i] - ws.f[lv + 1][i];
            ws.q[lv][i] = if lam1 > 0.0 { smooth_relu_prime(d, ETA_EXACT) } else { 0.0 };
            ws.band[lv][i] = lam1 > 0.0 && d.abs() <= ETA_EXACT;
        }
    }
}

/// Assemble ∇ψ into `ws.grad`, returning ‖∇ψ‖_∞.
#[allow(clippy::too_many_arguments)]
fn gradient(
    solver: &NckqrSolver,
    sqrt_lam: &[f64],
    lam1: f64,
    lam2: f64,
    sigma: f64,
    center: (&[f64], &[Vec<f64>]),
    b: &[f64],
    eta: &[Vec<f64>],
    ws: &mut NcWs,
) -> f64 {
    let n = solver.n();
    let t_lv = solver.t_levels();
    let dim = sqrt_lam.len();
    let crossing = lam1 > 0.0 && t_lv > 1;
    let mut gmax = 0.0f64;
    for lv in 0..t_lv {
        let o = lv * (dim + 1);
        let sum_s: f64 = ws.s[lv].iter().sum();
        gemv_t(&solver.basis.u, &ws.s[lv], &mut ws.uts);
        let mut sum_r = 0.0;
        if crossing {
            for i in 0..n {
                let fwd = if lv + 1 < t_lv { ws.q[lv][i] } else { 0.0 };
                let bwd = if lv > 0 { ws.q[lv - 1][i] } else { 0.0 };
                ws.r[i] = fwd - bwd;
                sum_r += ws.r[i];
            }
            gemv_t(&solver.basis.u, &ws.r, &mut ws.scratch);
        }
        ws.grad[o] = -sigma * sum_s + lam1 * sum_r + TAU_P * (b[lv] - center.0[lv]);
        gmax = gmax.max(ws.grad[o].abs());
        for j in 0..dim {
            let mut g = lam2 * eta[lv][j] - sigma * sqrt_lam[j] * ws.uts[j]
                + TAU_P * (eta[lv][j] - center.1[lv][j]);
            if crossing {
                g += lam1 * sqrt_lam[j] * ws.scratch[j];
            }
            ws.grad[o + 1 + j] = g;
            gmax = gmax.max(g.abs());
        }
    }
    gmax
}

/// ψ at the trial point z + t·dir, via the per-level direction images
/// (v_t,trial = v_t − tΔ_t, δ_trial = δ + t(Δ_t − Δ_{t+1})).
#[allow(clippy::too_many_arguments)]
fn trial_objective(
    solver: &NckqrSolver,
    lam1: f64,
    lam2: f64,
    sigma: f64,
    center: (&[f64], &[Vec<f64>]),
    b: &[f64],
    eta: &[Vec<f64>],
    t: f64,
    ws: &NcWs,
) -> f64 {
    let n = solver.n();
    let nf = n as f64;
    let t_lv = solver.t_levels();
    let dim = eta[0].len();
    let c = 1.0 / (nf * sigma);
    let mut total = 0.0;
    for lv in 0..t_lv {
        let tau = solver.taus[lv];
        let (lo, hi) = (c * (1.0 - tau), c * tau);
        for i in 0..n {
            let v = ws.v[lv][i] - t * ws.delta[lv][i];
            let u = prox_rho(v, lo, hi);
            total += rho_tau(u, tau) / nf + 0.5 * sigma * (u - v) * (u - v);
        }
        let o = lv * (dim + 1);
        let bt = b[lv] + t * ws.dir[o];
        let db = bt - center.0[lv];
        total += 0.5 * TAU_P * db * db;
        for j in 0..dim {
            let ej = eta[lv][j] + t * ws.dir[o + 1 + j];
            let dj = ej - center.1[lv][j];
            total += 0.5 * lam2 * ej * ej + 0.5 * TAU_P * dj * dj;
        }
    }
    if lam1 > 0.0 {
        for lv in 0..t_lv.saturating_sub(1) {
            for i in 0..n {
                let d = (ws.f[lv][i] + t * ws.delta[lv][i])
                    - (ws.f[lv + 1][i] + t * ws.delta[lv + 1][i]);
                total += lam1 * smooth_relu(d, ETA_EXACT);
            }
        }
    }
    total
}

/// Stacked rank-1 vector of one envelope row: √w·[1; Wᵢ] at block `lv`,
/// zeros elsewhere (the leading zeros make the up/downdate start at the
/// block offset — see [`Cholesky::update`]).
fn env_vec(solver: &NckqrSolver, sqrt_lam: &[f64], weight: f64, lv: usize, i: usize) -> Vec<f64> {
    let dim = sqrt_lam.len();
    let m = solver.t_levels() * (dim + 1);
    let o = lv * (dim + 1);
    let sw = weight.sqrt();
    let row = solver.basis.u.row(i);
    let mut x = vec![0.0; m];
    x[o] = sw;
    for a in 0..dim {
        x[o + 1 + a] = sw * sqrt_lam[a] * row[a];
    }
    x
}

/// Stacked rank-1 vector of one crossing row: √μ·[1; Wᵢ] at block `lv`
/// and −√μ·[1; Wᵢ] at block `lv+1`.
fn band_vec(solver: &NckqrSolver, sqrt_lam: &[f64], mu: f64, lv: usize, i: usize) -> Vec<f64> {
    let dim = sqrt_lam.len();
    let m = solver.t_levels() * (dim + 1);
    let o1 = lv * (dim + 1);
    let o2 = o1 + dim + 1;
    let sm = mu.sqrt();
    let row = solver.basis.u.row(i);
    let mut x = vec![0.0; m];
    x[o1] = sm;
    x[o2] = -sm;
    for a in 0..dim {
        let ja = sm * sqrt_lam[a] * row[a];
        x[o1 + 1 + a] = ja;
        x[o2 + 1 + a] = -ja;
    }
    x
}

/// Build the T(dim+1) generalized-Hessian factor from scratch:
/// block-diagonal diag(τ_p, (λ₂+τ_p)I) per level, plus σ·jjᵀ per active
/// envelope row, plus μ·EEᵀ per banded crossing row (which couples
/// adjacent blocks).
fn refactor(
    solver: &NckqrSolver,
    sqrt_lam: &[f64],
    lam1: f64,
    lam2: f64,
    sigma: f64,
    active: &[Vec<bool>],
    band: &[Vec<bool>],
) -> Result<Cholesky> {
    let n = solver.n();
    let t_lv = solver.t_levels();
    let dim = sqrt_lam.len();
    let m = t_lv * (dim + 1);
    let mut h = Matrix::zeros(m, m);
    for lv in 0..t_lv {
        let o = lv * (dim + 1);
        h[(o, o)] = TAU_P;
        for j in 0..dim {
            h[(o + 1 + j, o + 1 + j)] = lam2 + TAU_P;
        }
    }
    for lv in 0..t_lv {
        let o = lv * (dim + 1);
        for i in 0..n {
            if !active[lv][i] {
                continue;
            }
            let row = solver.basis.u.row(i);
            h[(o, o)] += sigma;
            for a in 0..dim {
                let ja = sqrt_lam[a] * row[a];
                h[(o + 1 + a, o)] += sigma * ja;
                for bc in 0..=a {
                    h[(o + 1 + a, o + 1 + bc)] += sigma * ja * (sqrt_lam[bc] * row[bc]);
                }
            }
        }
    }
    let mu = crossing_weight(lam1);
    if mu > 0.0 {
        for lv in 0..t_lv.saturating_sub(1) {
            let o1 = lv * (dim + 1);
            let o2 = o1 + dim + 1;
            for i in 0..n {
                if !band[lv][i] {
                    continue;
                }
                let row = solver.basis.u.row(i);
                h[(o1, o1)] += mu;
                h[(o2, o2)] += mu;
                h[(o2, o1)] -= mu;
                for a in 0..dim {
                    let ja = sqrt_lam[a] * row[a];
                    h[(o1 + 1 + a, o1)] += mu * ja;
                    h[(o2 + 1 + a, o2)] += mu * ja;
                    h[(o2 + 1 + a, o1)] -= mu * ja;
                    h[(o2, o1 + 1 + a)] -= mu * ja;
                    for bc in 0..=a {
                        let jb = sqrt_lam[bc] * row[bc];
                        h[(o1 + 1 + a, o1 + 1 + bc)] += mu * ja * jb;
                        h[(o2 + 1 + a, o2 + 1 + bc)] += mu * ja * jb;
                    }
                    for bc in 0..dim {
                        h[(o2 + 1 + a, o1 + 1 + bc)] -= mu * ja * (sqrt_lam[bc] * row[bc]);
                    }
                }
            }
        }
    }
    Ok(Cholesky::new(&h)?)
}

/// Try to seed a factor for the current sets from a carried one: σ-shift
/// over the carried envelope rows (the crossing rows are σ-independent),
/// then reconcile both symmetric differences by rank-1 up/downdates.
/// `None` when the work would not beat a refactorization or a downdate
/// loses definiteness; completed ops are counted into `updates` either
/// way.
#[allow(clippy::too_many_arguments)]
fn seed_factor(
    solver: &NckqrSolver,
    sqrt_lam: &[f64],
    mu: f64,
    sigma: f64,
    fc: NcFactor,
    active: &[Vec<bool>],
    band: &[Vec<bool>],
    updates: &mut usize,
) -> Option<Cholesky> {
    let dim = sqrt_lam.len();
    let m = solver.t_levels() * (dim + 1);
    if fc.active.len() != active.len() || fc.band.len() != band.len() {
        return None;
    }
    let carried: usize = fc.active.iter().map(|a| a.iter().filter(|x| **x).count()).sum();
    let env_diff: usize = fc
        .active
        .iter()
        .zip(active)
        .map(|(p, c)| p.iter().zip(c).filter(|(a, b)| a != b).count())
        .sum();
    let band_diff: usize = fc
        .band
        .iter()
        .zip(band)
        .map(|(p, c)| p.iter().zip(c).filter(|(a, b)| a != b).count())
        .sum();
    let sshift = fc.sigma != sigma;
    let ops = env_diff + band_diff + if sshift { carried } else { 0 };
    if ops > m / 3 {
        return None;
    }
    let mut chol = fc.chol;
    if sshift {
        let ds = sigma - fc.sigma;
        for (lv, rowset) in fc.active.iter().enumerate() {
            for i in 0..rowset.len() {
                if !rowset[i] {
                    continue;
                }
                let mut x = env_vec(solver, sqrt_lam, ds.abs(), lv, i);
                if ds > 0.0 {
                    chol.update(&mut x);
                } else if chol.downdate(&mut x).is_err() {
                    return None;
                }
                *updates += 1;
            }
        }
    }
    for (lv, (prev, cur)) in fc.active.iter().zip(active).enumerate() {
        for i in 0..prev.len() {
            if prev[i] == cur[i] {
                continue;
            }
            let mut x = env_vec(solver, sqrt_lam, sigma, lv, i);
            if cur[i] {
                chol.update(&mut x);
            } else if chol.downdate(&mut x).is_err() {
                return None;
            }
            *updates += 1;
        }
    }
    if mu > 0.0 {
        for (lv, (prev, cur)) in fc.band.iter().zip(band).enumerate() {
            for i in 0..prev.len() {
                if prev[i] == cur[i] {
                    continue;
                }
                let mut x = band_vec(solver, sqrt_lam, mu, lv, i);
                if cur[i] {
                    chol.update(&mut x);
                } else if chol.downdate(&mut x).is_err() {
                    return None;
                }
                *updates += 1;
            }
        }
    }
    Some(chol)
}

/// Minimize the lifted ψ over z = (b_t, η_t) to gradient tolerance `tol`
/// by semismooth Newton; the factor carries across Newton steps (rank-1
/// maintenance over envelope + band swings) and across outer rounds via
/// the `carry` slot (σ-shift seeding).
#[allow(clippy::too_many_arguments)]
fn inner_solve(
    solver: &NckqrSolver,
    sqrt_lam: &[f64],
    lam1: f64,
    lam2: f64,
    sigma: f64,
    tol: f64,
    b: &mut [f64],
    eta: &mut [Vec<f64>],
    w: &[Vec<f64>],
    carry: &mut Option<NcFactor>,
    ws: &mut NcWs,
) -> Result<InnerNc> {
    let t_lv = solver.t_levels();
    let dim = sqrt_lam.len();
    let m = t_lv * (dim + 1);
    let cap = swing_cap(m);
    let mu = crossing_weight(lam1);
    let center_b = b.to_vec();
    let center_eta = eta.to_vec();
    let mut chol: Option<Cholesky> = None;
    let mut prev_active: Vec<Vec<bool>> = Vec::new();
    let mut prev_band: Vec<Vec<bool>> = Vec::new();
    let mut res = InnerNc::default();

    refresh(solver, sqrt_lam, lam1, b, eta, w, sigma, ws);
    for _ in 0..MAX_NEWTON {
        let gmax = gradient(
            solver,
            sqrt_lam,
            lam1,
            lam2,
            sigma,
            (&center_b, &center_eta),
            b,
            eta,
            ws,
        );
        if gmax <= tol {
            break;
        }

        let mut factored = false;
        if chol.is_none() {
            if let Some(fc) = carry.take() {
                if let Some(c) =
                    seed_factor(solver, sqrt_lam, mu, sigma, fc, &ws.active, &ws.band, &mut res.updates)
                {
                    prev_active = ws.active.clone();
                    prev_band = ws.band.clone();
                    chol = Some(c);
                    res.seeded = true;
                    factored = true;
                }
            }
        }
        if !factored {
            if let Some(f) = chol.as_mut() {
                let changed_env: Vec<(usize, usize, bool)> = prev_active
                    .iter()
                    .zip(ws.active.iter())
                    .enumerate()
                    .flat_map(|(lv, (p, c))| {
                        p.iter()
                            .zip(c.iter())
                            .enumerate()
                            .filter(|(_, (a, b))| a != b)
                            .map(move |(i, (_, b))| (lv, i, *b))
                            .collect::<Vec<_>>()
                    })
                    .collect();
                let changed_band: Vec<(usize, usize, bool)> = prev_band
                    .iter()
                    .zip(ws.band.iter())
                    .enumerate()
                    .flat_map(|(lv, (p, c))| {
                        p.iter()
                            .zip(c.iter())
                            .enumerate()
                            .filter(|(_, (a, b))| a != b)
                            .map(move |(i, (_, b))| (lv, i, *b))
                            .collect::<Vec<_>>()
                    })
                    .collect();
                if changed_env.len() + changed_band.len() <= cap {
                    let mut ok = true;
                    for &(lv, i, entered) in &changed_env {
                        let mut x = env_vec(solver, sqrt_lam, sigma, lv, i);
                        if entered {
                            f.update(&mut x);
                        } else if f.downdate(&mut x).is_err() {
                            ok = false;
                            break;
                        }
                        res.updates += 1;
                    }
                    if ok {
                        for &(lv, i, entered) in &changed_band {
                            let mut x = band_vec(solver, sqrt_lam, mu, lv, i);
                            if entered {
                                f.update(&mut x);
                            } else if f.downdate(&mut x).is_err() {
                                ok = false;
                                break;
                            }
                            res.updates += 1;
                        }
                    }
                    factored = ok;
                }
            }
        }
        if !factored {
            chol = Some(refactor(solver, sqrt_lam, lam1, lam2, sigma, &ws.active, &ws.band)?);
            res.refactors += 1;
        }
        prev_active = ws.active.clone();
        prev_band = ws.band.clone();

        // Newton direction H d = −g, then per-level direction images
        let neg: Vec<f64> = ws.grad.iter().map(|g| -g).collect();
        let d = chol.as_ref().expect("factor present").solve(&neg);
        ws.dir.copy_from_slice(&d);
        let gd: f64 = ws.grad.iter().zip(&ws.dir).map(|(g, di)| g * di).sum();
        {
            let NcWs { dir, delta, scratch, .. } = &mut *ws;
            for lv in 0..t_lv {
                let o = lv * (dim + 1);
                for (sc, (sl, dj)) in
                    scratch.iter_mut().zip(sqrt_lam.iter().zip(&dir[o + 1..o + 1 + dim]))
                {
                    *sc = sl * dj;
                }
                gemv(&solver.basis.u, scratch, &mut delta[lv]);
                for di in delta[lv].iter_mut() {
                    *di += dir[o];
                }
            }
        }

        // Armijo backtracking on ψ
        let f0 =
            trial_objective(solver, lam1, lam2, sigma, (&center_b, &center_eta), b, eta, 0.0, ws);
        let mut t = 1.0f64;
        let step = loop {
            if t <= 1e-12 {
                break None;
            }
            let ft = trial_objective(
                solver,
                lam1,
                lam2,
                sigma,
                (&center_b, &center_eta),
                b,
                eta,
                t,
                ws,
            );
            if ft <= f0 + 1e-4 * t * gd {
                break Some(t);
            }
            t *= 0.5;
        };
        let t = match step {
            Some(t) => t,
            // numerically flat — treat as converged
            None => break,
        };
        for lv in 0..t_lv {
            let o = lv * (dim + 1);
            b[lv] += t * ws.dir[o];
            for j in 0..dim {
                eta[lv][j] += t * ws.dir[o + 1 + j];
            }
        }
        res.steps += 1;
        refresh(solver, sqrt_lam, lam1, b, eta, w, sigma, ws);
        let step_inf = amax(&ws.dir);
        let it_inf = eta.iter().flatten().fold(
            b.iter().fold(0.0f64, |a, v| a.max(v.abs())),
            |a, v| a.max(v.abs()),
        );
        if t * step_inf <= 1e-15 * (1.0 + it_inf) {
            break;
        }
    }
    if let Some(c) = chol {
        *carry = Some(NcFactor { chol: c, active: prev_active, band: prev_band, sigma });
    }
    Ok(res)
}

impl NckqrSolver {
    /// Fit at a single (λ₁, λ₂) with the pALM semismooth-Newton backend.
    ///
    /// Solves the identical exact problem (12) as [`NckqrSolver::fit`]
    /// and certifies against the same exact KKT report; `mm_iters` on
    /// the returned fit counts Newton steps and [`NckqrFit::ssn`]
    /// carries the factor-reuse counters.
    pub fn fit_ssn(&self, lam1: f64, lam2: f64) -> Result<NckqrFit> {
        if lam1 < 0.0 {
            bail!("lambda1 must be >= 0, got {lam1}");
        }
        if lam2 <= 0.0 {
            bail!("lambda2 must be positive, got {lam2}");
        }
        let n = self.n();
        let t_lv = self.t_levels();
        let dim = self.basis.dim();
        let sqrt_lam: Vec<f64> = self.basis.lambda.iter().map(|l| l.max(0.0).sqrt()).collect();
        let band = self.opts.kkt_band * amax(&self.y).max(1.0);
        let mut apgd_ws = ApgdWorkspace::for_basis(&self.basis);
        let mut ws = NcWs::new(t_lv, n, dim);

        let mut b = vec![0.0; t_lv];
        let mut eta = vec![vec![0.0; dim]; t_lv];
        let mut w = vec![vec![0.0; n]; t_lv];
        let mut sigma = SIGMA_INIT;
        let mut factor: Option<NcFactor> = None;
        let mut stats = SsnGridStats { cells: 1, ..Default::default() };
        let mut best: Option<(f64, Vec<f64>, Vec<Vec<f64>>, KktReport, f64)> = None;
        let mut prev_obj = f64::INFINITY;
        let mut stall = 0usize;

        for outer in 0..MAX_OUTER {
            let tol = (1e-2 * 0.1f64.powi(outer as i32)).max(INNER_TOL_FLOOR);
            let inner = inner_solve(
                self,
                &sqrt_lam,
                lam1,
                lam2,
                sigma,
                tol,
                &mut b,
                &mut eta,
                &w,
                &mut factor,
                &mut ws,
            )?;
            stats.newton_steps += inner.steps;
            stats.refactorizations += inner.refactors;
            stats.rank1_updates += inner.updates;
            if inner.seeded {
                stats.carried_seeds += 1;
            }
            stats.outer_rounds = outer + 1;

            // multiplier update at the final inner point: w⁺ = σ(prox(v) − v)
            for (wl, sl) in w.iter_mut().zip(&ws.s) {
                for (wi, si) in wl.iter_mut().zip(sl) {
                    *wi = -sigma * si;
                }
            }

            // certify with the exact non-smooth criterion of problem (12)
            let states = states_from(&sqrt_lam, &b, &eta);
            let rep = self.kkt_check(lam1, lam2, &states, band);
            let fs = self.fitted_levels(&states, &mut apgd_ws);
            let obj = self.exact_objective(lam1, lam2, &states, &fs);
            let score = rep.max_stationarity.max(rep.intercept);
            let improved = best.as_ref().map(|(s, ..)| score < *s).unwrap_or(true);
            if improved {
                best = Some((score, b.clone(), eta.clone(), rep.clone(), obj));
            }
            let plateau = (prev_obj - obj).abs() <= 1e-11 * (1.0 + obj.abs());
            prev_obj = obj;
            if rep.pass {
                if tol <= INNER_TOL_FLOOR && plateau {
                    break;
                }
                stall = if improved { 0 } else { stall + 1 };
                if stall >= MAX_STALL {
                    break;
                }
            }
            sigma = (sigma * SIGMA_GROWTH).min(SIGMA_MAX);
        }

        let (_, best_b, best_eta, kkt, objective) =
            best.expect("nc-ssn: at least one outer round ran");
        let best_states = states_from(&sqrt_lam, &best_b, &best_eta);
        let levels: Vec<LevelCoef> = (0..t_lv)
            .map(|t| LevelCoef {
                tau: self.taus[t],
                b: best_states[t].b,
                alpha: self.basis.alpha_from_beta(&best_states[t].beta),
            })
            .collect();
        let fs = self.fitted_levels(&best_states, &mut apgd_ws);
        let train_crossings = count_crossings_in(&fs, 1e-9);
        let lowrank = self.repr.low_rank().map(|f| NcLowRank {
            z: f.z.clone(),
            landmarks: f.landmarks.clone(),
            w: (0..t_lv).map(|t| f.coef(&best_states[t].beta).w).collect(),
        });
        let rff = self.repr.rff().map(|f| NcRff {
            map: f.map.clone(),
            w: (0..t_lv).map(|t| f.coef(&best_states[t].beta).w).collect(),
        });
        Ok(NckqrFit {
            taus: self.taus.clone(),
            lam1,
            lam2,
            levels,
            objective,
            kkt,
            mm_iters: stats.newton_steps,
            gamma_final: 0.0,
            train_crossings,
            lowrank,
            rff,
            ssn: Some(stats),
            x_train: self.x.clone(),
            n_train: self.x.rows(),
            kernel: self.kernel.clone(),
        })
    }
}

/// Convert the stacked (b, η) iterate into per-level [`LevelState`]s
/// (β = η/√λ on the non-degenerate spectrum) for the parent's exact
/// certificate and objective.
fn states_from(sqrt_lam: &[f64], b: &[f64], eta: &[Vec<f64>]) -> Vec<LevelState> {
    b.iter()
        .zip(eta)
        .map(|(bt, et)| {
            let beta: Vec<f64> = sqrt_lam
                .iter()
                .zip(et)
                .map(|(sl, e)| if *sl > 0.0 { e / sl } else { 0.0 })
                .collect();
            LevelState { b: *bt, beta: beta.clone(), b_prev: *bt, beta_prev: beta }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use crate::data::{synth, Rng};
    use crate::kernel::{median_heuristic_sigma, Kernel};
    use crate::kqr::KqrSolver;
    use crate::linalg::Matrix;
    use crate::nckqr::NckqrSolver;

    fn fixture(n: usize, seed: u64) -> (Matrix, Vec<f64>, Kernel) {
        let mut rng = Rng::new(seed);
        let d = synth::sine_hetero(n, &mut rng);
        let sigma = median_heuristic_sigma(&d.x);
        (d.x, d.y, Kernel::Rbf { sigma })
    }

    #[test]
    fn ssn_matches_mm_on_multilevel_fit() {
        let (x, y, kernel) = fixture(40, 1);
        let nc = NckqrSolver::new(&x, &y, kernel, &[0.25, 0.5, 0.75]).unwrap();
        let mm = nc.fit(1.0, 0.05).unwrap();
        let ssn = nc.fit_ssn(1.0, 0.05).unwrap();
        assert!(ssn.kkt.pass, "{:?}", ssn.kkt);
        assert!(
            (ssn.objective - mm.objective).abs() < 2e-3 * (1.0 + mm.objective),
            "ssn={} mm={}",
            ssn.objective,
            mm.objective
        );
        let stats = ssn.ssn.expect("ssn counters attached");
        assert!(stats.newton_steps > 0 && stats.outer_rounds > 0);
        assert!(stats.refactorizations >= 1, "at least one full factorization");
        assert!(mm.ssn.is_none(), "the MM path must not claim ssn counters");
    }

    #[test]
    fn ssn_lam1_zero_matches_independent_fits() {
        let (x, y, kernel) = fixture(40, 2);
        let taus = [0.25, 0.75];
        let nc = NckqrSolver::new(&x, &y, kernel.clone(), &taus).unwrap();
        let fit = nc.fit_ssn(0.0, 0.05).unwrap();
        let kqr = KqrSolver::new(&x, &y, kernel).unwrap();
        let sum_obj: f64 = taus.iter().map(|&t| kqr.fit(t, 0.05).unwrap().objective).sum();
        assert!(
            (fit.objective - sum_obj).abs() < 1e-3 * (1.0 + sum_obj),
            "ssn={} sum_kqr={sum_obj}",
            fit.objective
        );
    }

    #[test]
    fn ssn_strong_penalty_removes_crossings() {
        let (x, y, kernel) = fixture(50, 4);
        let nc = NckqrSolver::new(&x, &y, kernel, &[0.1, 0.5, 0.9]).unwrap();
        let tight = nc.fit_ssn(50.0, 1e-3).unwrap();
        let grid = Matrix::from_fn(100, 1, |i, _| i as f64 / 99.0);
        assert_eq!(tight.count_crossings(&grid, 1e-6), 0);
    }

    #[test]
    fn ssn_input_validation() {
        let (x, y, kernel) = fixture(10, 7);
        let nc = NckqrSolver::new(&x, &y, kernel, &[0.5]).unwrap();
        assert!(nc.fit_ssn(-1.0, 0.1).is_err());
        assert!(nc.fit_ssn(1.0, 0.0).is_err());
    }
}
