#!/usr/bin/env python3
"""Print per-metric deltas between the two most recent bench snapshots.

Snapshots are directories under benchmarks/ (sorted by name — use
ISO dates so lexicographic == chronological), each holding the
machine-readable bench outputs: BENCH_grid.json, BENCH_serve.json,
BENCH_lowrank.json. Record one with tools/bench_snapshot.sh.

With a single snapshot, values are printed as "added" so the first
recording is still inspectable; metrics or whole bench files present in
only one of the two snapshots are reported as added/removed rather than
erroring. Null / non-numeric fields (e.g. the schema-only placeholder
committed from a toolchain-less build container) are skipped gracefully.
"""

import json
import sys
from pathlib import Path

BENCH_FILES = ["BENCH_grid.json", "BENCH_serve.json", "BENCH_lowrank.json"]

# List elements are keyed by their identifying field(s), not their
# position: inserting a row (say the rff column growing a new D) must not
# shift every later row onto a different comparison partner.
ID_FIELDS = ("m", "d", "n", "tau", "name", "io", "replicas")


def _list_key(item, index):
    """Stable key for one list element: `[m=64]`-style when the element
    is a dict carrying identifying fields, positional otherwise. All
    matching id fields combine into one key — the serve bench's
    replica_scaling rows are identified by (io, replicas) jointly, and
    either alone would collide."""
    if isinstance(item, dict):
        parts = []
        for f in ID_FIELDS:
            v = item.get(f)
            if isinstance(v, bool) or not isinstance(v, (int, float, str)):
                continue
            if isinstance(v, float) and v.is_integer():
                v = int(v)
            parts.append(f"{f}={v}")
        if parts:
            return f"[{','.join(parts)}]"
    return str(index)


def flatten(doc, prefix=""):
    """Yield (dotted.key, value) for every numeric leaf in a JSON doc."""
    if isinstance(doc, dict):
        for k, v in doc.items():
            yield from flatten(v, f"{prefix}{k}.")
    elif isinstance(doc, list):
        for i, v in enumerate(doc):
            yield from flatten(v, f"{prefix}{_list_key(v, i)}.")
    elif isinstance(doc, bool):
        return  # bools are ints in python; not a perf metric
    elif isinstance(doc, (int, float)):
        yield prefix.rstrip("."), float(doc)


def load_metrics(snap_dir):
    """Map bench-file stem -> {metric: value} for one snapshot dir."""
    out = {}
    for name in BENCH_FILES:
        path = snap_dir / name
        if not path.is_file():
            continue
        try:
            doc = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as exc:
            print(f"  ! skipping {path}: {exc}", file=sys.stderr)
            continue
        out[name] = dict(flatten(doc))
    return out


def fmt(v):
    return f"{v:.6g}"


def main():
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(__file__).resolve().parent.parent / "benchmarks"
    snaps = sorted(d for d in root.iterdir() if d.is_dir()) if root.is_dir() else []
    if not snaps:
        print(f"no snapshot directories under {root}; run tools/bench_snapshot.sh first")
        return 1

    new_dir = snaps[-1]
    old_dir = snaps[-2] if len(snaps) > 1 else None
    new = load_metrics(new_dir)
    old = load_metrics(old_dir) if old_dir else {}
    print(f"comparing {old_dir.name if old_dir else '(none)'} -> {new_dir.name}\n")

    for name in BENCH_FILES:
        if name not in new and name not in old:
            continue
        if old_dir and name not in old:
            print(f"== {name} (added in {new_dir.name}) ==")
        elif name not in new:
            print(f"== {name} (removed in {new_dir.name}) ==")
        else:
            print(f"== {name} ==")
        new_m = new.get(name, {})
        old_m = old.get(name, {})
        keys = sorted(set(new_m) | set(old_m))
        if not keys:
            print("  (no numeric metrics — placeholder snapshot?)")
        width = max((len(k) for k in keys), default=0)
        for key in keys:
            a, b = old_m.get(key), new_m.get(key)
            if b is None:
                print(f"  {key:<{width}}  {fmt(a)} -> (removed)")
            elif a is None:
                print(f"  {key:<{width}}  {fmt(b)}  (added)")
            else:
                delta = b - a
                pct = f"{100.0 * delta / a:+.1f}%" if a != 0 else "n/a"
                print(f"  {key:<{width}}  {fmt(a)} -> {fmt(b)}  ({delta:+.6g}, {pct})")
        print()
    return 0


if __name__ == "__main__":
    sys.exit(main())
