//! Structure-blind first-order NCKQR solver — the `cvxr` comparator.
//!
//! R's `CVXR` hands the NCKQR program to a generic conic solver: correct,
//! but with none of fastkqr's structure reuse, and orders of magnitude
//! slower (Tables 2 and 6). We reproduce the class with an accelerated
//! proximal-gradient method on the smoothed objective Q^γ (γ = η = 10⁻⁵)
//! whose step size comes from a *global* Lipschitz bound estimated by
//! power iteration on K — i.e. everything fastkqr's majorization and
//! spectral tricks avoid: tiny steps, a fresh O(Tn²) gradient per
//! iteration, no warm-start intelligence.

use crate::linalg::{dot, gemv, nrm2, Matrix};
use crate::smooth::{h_gamma, h_gamma_prime, smooth_relu, smooth_relu_prime};
use anyhow::Result;

/// Solution of the generic NCKQR solver.
#[derive(Clone, Debug)]
pub struct ProximalFit {
    /// per level: (b, alpha)
    pub levels: Vec<(f64, Vec<f64>)>,
    /// Exact objective of problem (12) (check loss + η_exact penalty).
    pub objective: f64,
    pub iters: usize,
}

/// Largest eigenvalue of K by power iteration (the global step-size bound
/// a generic solver would use).
fn power_iteration_max_eig(gram: &Matrix, iters: usize) -> f64 {
    let n = gram.rows();
    let mut v = vec![1.0 / (n as f64).sqrt(); n];
    let mut kv = vec![0.0; n];
    let mut lam = 1.0;
    for _ in 0..iters {
        gemv(gram, &v, &mut kv);
        lam = nrm2(&kv).max(1e-300);
        for i in 0..n {
            v[i] = kv[i] / lam;
        }
    }
    lam
}

/// Solve NCKQR at (λ₁, λ₂) by accelerated proximal gradient descent.
pub fn solve_nckqr_proximal(
    gram: &Matrix,
    y: &[f64],
    taus: &[f64],
    lam1: f64,
    lam2: f64,
    max_iters: usize,
    grad_tol: f64,
) -> Result<ProximalFit> {
    let n = y.len();
    let nf = n as f64;
    let t_lv = taus.len();
    let gamma = crate::nckqr::ETA_EXACT; // smooth at the exact-problem scale
    let eta = crate::nckqr::ETA_EXACT;
    // Global Lipschitz bound of ∇Q^γ in (b, α):
    //   loss: (1/(2γn))·λmax([1,K]ᵀ[1,K]) ≤ (1/(2γn))(n + λmax(K)²·n...)
    // A generic solver just uses a crude product bound:
    let kmax = power_iteration_max_eig(gram, 50);
    let a_norm2 = nf + kmax * kmax; // ‖[1,K]‖² upper bound
    let l_loss = a_norm2 / (2.0 * gamma * nf);
    let l_pen = 2.0 * lam1 * a_norm2 / eta; // V'' ≤ 1/(2η), T−1 pairs ≤ 2 per level
    let l_ridge = lam2 * kmax;
    let step = 1.0 / (l_loss + l_pen + l_ridge);

    // state: per level (b, alpha); FISTA extrapolation
    let mut bs = vec![0.0f64; t_lv];
    let mut als = vec![vec![0.0f64; n]; t_lv];
    let mut bs_prev = bs.clone();
    let mut als_prev = als.clone();
    let mut ck = 1.0f64;
    let mut fs = vec![vec![0.0; n]; t_lv];
    let mut iters = 0usize;
    for it in 0..max_iters {
        iters = it + 1;
        let ck_next = 0.5 * (1.0 + (1.0 + 4.0 * ck * ck).sqrt());
        let mom = (ck - 1.0) / ck_next;
        // extrapolated point
        let bse: Vec<f64> = (0..t_lv).map(|t| bs[t] + mom * (bs[t] - bs_prev[t])).collect();
        let alse: Vec<Vec<f64>> = (0..t_lv)
            .map(|t| {
                (0..n).map(|i| als[t][i] + mom * (als[t][i] - als_prev[t][i])).collect()
            })
            .collect();
        for t in 0..t_lv {
            gemv(gram, &alse[t], &mut fs[t]);
            for i in 0..n {
                fs[t][i] += bse[t];
            }
        }
        // gradient per level
        let mut max_g = 0.0f64;
        let mut new_bs = vec![0.0; t_lv];
        let mut new_als = vec![vec![0.0; n]; t_lv];
        for t in 0..t_lv {
            // carrier: −z/n + λ₁(q_t − q_{t−1}) in value space
            let mut carrier = vec![0.0; n];
            for i in 0..n {
                let z = h_gamma_prime(y[i] - fs[t][i], taus[t], gamma);
                let fwd = if t < t_lv - 1 {
                    smooth_relu_prime(fs[t][i] - fs[t + 1][i], eta)
                } else {
                    0.0
                };
                let bwd = if t > 0 {
                    smooth_relu_prime(fs[t - 1][i] - fs[t][i], eta)
                } else {
                    0.0
                };
                carrier[i] = -z / nf + lam1 * (fwd - bwd);
            }
            let gb: f64 = carrier.iter().sum();
            // ∂/∂α = K(carrier + λ₂α)
            let mut w = carrier.clone();
            for i in 0..n {
                w[i] += lam2 * alse[t][i];
            }
            let mut ga = vec![0.0; n];
            gemv(gram, &w, &mut ga);
            max_g = max_g.max(gb.abs());
            for i in 0..n {
                max_g = max_g.max(ga[i].abs());
            }
            new_bs[t] = bse[t] - step * gb;
            for i in 0..n {
                new_als[t][i] = alse[t][i] - step * ga[i];
            }
        }
        bs_prev = bs;
        als_prev = als;
        bs = new_bs;
        als = new_als;
        ck = ck_next;
        if max_g < grad_tol {
            break;
        }
    }
    // exact objective
    let mut objective = 0.0;
    for t in 0..t_lv {
        gemv(gram, &als[t], &mut fs[t]);
        let pen = 0.5 * lam2 * dot(&als[t], &fs[t]);
        for i in 0..n {
            fs[t][i] += bs[t];
        }
        let loss: f64 =
            (0..n).map(|i| crate::smooth::rho_tau(y[i] - fs[t][i], taus[t])).sum::<f64>() / nf;
        objective += loss + pen;
    }
    for t in 0..t_lv.saturating_sub(1) {
        for i in 0..n {
            objective += lam1 * smooth_relu(fs[t][i] - fs[t + 1][i], crate::nckqr::ETA_EXACT);
        }
    }
    let levels = (0..t_lv).map(|t| (bs[t], als[t].clone())).collect();
    Ok(ProximalFit { levels, objective, iters })
}

/// Smoothed objective (diagnostics / tests).
#[allow(dead_code)]
pub(crate) fn smoothed_q(
    gram: &Matrix,
    y: &[f64],
    taus: &[f64],
    lam1: f64,
    lam2: f64,
    gamma: f64,
    eta: f64,
    bs: &[f64],
    als: &[Vec<f64>],
) -> f64 {
    let n = y.len();
    let nf = n as f64;
    let t_lv = taus.len();
    let mut fs = vec![vec![0.0; n]; t_lv];
    let mut q = 0.0;
    for t in 0..t_lv {
        gemv(gram, &als[t], &mut fs[t]);
        q += 0.5 * lam2 * dot(&als[t], &fs[t]);
        for i in 0..n {
            fs[t][i] += bs[t];
            q += h_gamma(y[i] - fs[t][i], taus[t], gamma) / nf;
        }
    }
    for t in 0..t_lv.saturating_sub(1) {
        for i in 0..n {
            q += lam1 * smooth_relu(fs[t][i] - fs[t + 1][i], eta);
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, Rng};
    use crate::kernel::Kernel;
    use crate::nckqr::NckqrSolver;

    #[test]
    fn power_iteration_matches_eigensolver() {
        let mut rng = Rng::new(1);
        let x = Matrix::from_fn(15, 2, |_, _| rng.normal());
        let gram = Kernel::Rbf { sigma: 1.0 }.gram(&x);
        let pi = power_iteration_max_eig(&gram, 200);
        let eig = crate::linalg::SymEigen::new(&gram);
        assert!((pi - eig.max_eigenvalue()).abs() < 1e-6 * eig.max_eigenvalue());
    }

    #[test]
    fn proximal_approaches_fastkqr_objective_slowly() {
        let mut rng = Rng::new(2);
        let d = synth::sine_hetero(25, &mut rng);
        let kernel = Kernel::Rbf { sigma: 0.5 };
        let taus = [0.25, 0.75];
        let nc = NckqrSolver::new(&d.x, &d.y, kernel, &taus).unwrap();
        let exact = nc.fit(1.0, 0.1).unwrap();
        let prox =
            solve_nckqr_proximal(nc.gram(), &d.y, &taus, 1.0, 0.1, 200_000, 1e-7).unwrap();
        // generic solver never beats the exact objective, lands near it
        assert!(prox.objective >= exact.objective - 1e-6);
        assert!(
            prox.objective - exact.objective < 0.05 * (1.0 + exact.objective),
            "exact {} vs prox {}",
            exact.objective,
            prox.objective
        );
    }
}
