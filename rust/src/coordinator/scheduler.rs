//! Warm-start-aware fit-job scheduler on the shared fit engine.
//!
//! Workers pull jobs from a shared queue. `submit_batch` orders a batch
//! so that jobs sharing a dataset are adjacent, grouped by τ, with λ
//! descending — the order in which warm starts pay off. Solver setup
//! (Gram matrix + eigenbasis) goes through the scheduler's
//! [`FitEngine`]: **concurrent** jobs on the same dataset share one
//! cached basis (the cache coalesces in-flight computations, so two
//! workers decomposing the same dataset at the same time still cost one
//! eigendecomposition), replacing the old per-worker "consecutive jobs
//! on one worker" heuristic. Warm APGD state stays per-worker, keyed by
//! (dataset fingerprint, τ).

use super::job::{FitJob, JobOutcome, JobSpec};
use super::metrics::Metrics;
use crate::backend::NativeBackend;
use crate::cv::cross_validate_on;
use crate::data::Rng;
use crate::engine::{fingerprint, ApproxSpec, Fingerprint, FitEngine};
use crate::kqr::apgd::ApgdState;
use crate::kqr::SolveOptions;
use crate::linalg::par;
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// A finished job: (job id, result).
pub type JobResult = (u64, anyhow::Result<JobOutcome>);

struct Queue {
    jobs: Mutex<VecDeque<(FitJob, Sender<JobResult>)>>,
    ready: Condvar,
    shutdown: Mutex<bool>,
}

/// Thread-pool scheduler.
pub struct Scheduler {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    pub opts: SolveOptions,
    /// The engine all workers share: one (Gram, basis) per dataset
    /// fingerprint across the whole pool.
    pub engine: Arc<FitEngine>,
}

impl Scheduler {
    pub fn new(n_workers: usize) -> Scheduler {
        Scheduler::with_options(n_workers, SolveOptions::default())
    }

    pub fn with_options(n_workers: usize, opts: SolveOptions) -> Scheduler {
        Scheduler::with_engine(n_workers, opts, FitEngine::global().clone())
    }

    /// Run on an explicit engine (tests use a fresh one to assert cache
    /// accounting; embedders can share an engine with a server).
    pub fn with_engine(
        n_workers: usize,
        opts: SolveOptions,
        engine: Arc<FitEngine>,
    ) -> Scheduler {
        Scheduler::with_engine_and_metrics(n_workers, opts, engine, Arc::new(Metrics::new()))
    }

    /// [`Scheduler::with_engine`] on a shared [`Metrics`] instance — hand
    /// in a co-located TCP server's metrics so the wire `metrics` command
    /// surfaces the scheduler-side counters (`jobs_*`, `fits_total`,
    /// `warm_evictions`) instead of reporting a disjoint instance's zeros.
    pub fn with_engine_and_metrics(
        n_workers: usize,
        opts: SolveOptions,
        engine: Arc<FitEngine>,
        metrics: Arc<Metrics>,
    ) -> Scheduler {
        assert!(n_workers >= 1);
        let queue = Arc::new(Queue {
            jobs: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        let mut workers = Vec::new();
        // With several workers the pool itself is the parallel dimension:
        // each worker runs its solves with intra-op (GEMV) parallelism
        // disabled so W workers never fan out into W × threads.
        let multi_worker = n_workers > 1;
        for wid in 0..n_workers {
            let q = queue.clone();
            let m = metrics.clone();
            let o = opts.clone();
            let e = engine.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("fastkqr-worker-{wid}"))
                    .spawn(move || worker_loop(q, m, o, e, multi_worker))
                    .expect("spawn worker"),
            );
        }
        Scheduler { queue, workers, metrics, opts, engine }
    }

    /// Submit one job; the receiver yields its result.
    pub fn submit(&self, job: FitJob) -> Receiver<JobResult> {
        Metrics::incr(&self.metrics.jobs_submitted);
        let (tx, rx) = channel();
        self.queue.jobs.lock().unwrap().push_back((job, tx));
        self.queue.ready.notify_one();
        rx
    }

    /// Submit a batch in warm-start-friendly order; one receiver yields
    /// all results (job ids disambiguate).
    pub fn submit_batch(&self, mut jobs: Vec<FitJob>) -> Receiver<JobResult> {
        jobs.sort_by(|a, b| {
            a.dataset_key()
                .cmp(&b.dataset_key())
                .then(
                    a.spec
                        .tau_head()
                        .partial_cmp(&b.spec.tau_head())
                        .unwrap_or(std::cmp::Ordering::Equal),
                )
                // λ descending: warm starts flow from heavy to light
                .then(
                    b.spec
                        .lambda_head()
                        .partial_cmp(&a.spec.lambda_head())
                        .unwrap_or(std::cmp::Ordering::Equal),
                )
        });
        let (tx, rx) = channel();
        {
            let mut q = self.queue.jobs.lock().unwrap();
            for job in jobs {
                Metrics::incr(&self.metrics.jobs_submitted);
                q.push_back((job, tx.clone()));
            }
        }
        self.queue.ready.notify_all();
        rx
    }

    /// Stop accepting work and join the workers.
    pub fn shutdown(self) {
        *self.queue.shutdown.lock().unwrap() = true;
        self.queue.ready.notify_all();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Per-worker warm-start state: APGD iterate keyed by (dataset
/// fingerprint, τ). Its lifetime is bounded by the engine's GramCache:
/// after every job the worker checks whether the fingerprint is still
/// cached and drops the state when it is not (see `worker_loop`) —
/// otherwise the O(n) iterate vectors of a dataset whose jobs finished
/// long ago would sit in the worker forever, and a revived dataset
/// would pay the eigendecomposition again anyway.
struct WarmState {
    key: Fingerprint,
    tau: f64,
    state: ApgdState,
}

fn worker_loop(
    queue: Arc<Queue>,
    metrics: Arc<Metrics>,
    opts: SolveOptions,
    engine: Arc<FitEngine>,
    multi_worker: bool,
) {
    let mut warm: Option<WarmState> = None;
    loop {
        let item = {
            let mut jobs = queue.jobs.lock().unwrap();
            loop {
                if let Some(item) = jobs.pop_front() {
                    break Some(item);
                }
                if *queue.shutdown.lock().unwrap() {
                    break None;
                }
                jobs = queue.ready.wait(jobs).unwrap();
            }
        };
        let Some((job, tx)) = item else { return };
        let t0 = Instant::now();
        let result = if multi_worker {
            par::serial_scope(|| run_job(&job, &opts, &engine, &mut warm, &metrics))
        } else {
            run_job(&job, &opts, &engine, &mut warm, &metrics)
        };
        Metrics::add(&metrics.solver_micros, t0.elapsed().as_micros() as u64);
        match &result {
            Ok(_) => Metrics::incr(&metrics.jobs_completed),
            Err(_) => Metrics::incr(&metrics.jobs_failed),
        }
        // Evict warm-start state whose dataset the GramCache has dropped:
        // the iterate can never warm-start a cheaper solve than a cold
        // one once the factorization must be recomputed anyway.
        if let Some(w) = &warm {
            if !engine.cache.contains(&w.key) {
                warm = None;
                Metrics::incr(&metrics.warm_evictions);
            }
        }
        // receiver may have been dropped; that's fine
        let _ = tx.send((job.id, result));
    }
}

fn run_job(
    job: &FitJob,
    opts: &SolveOptions,
    engine: &FitEngine,
    warm: &mut Option<WarmState>,
    metrics: &Metrics,
) -> anyhow::Result<JobOutcome> {
    match &job.spec {
        JobSpec::Kqr { tau, lambda } => {
            let key = fingerprint(&job.dataset.x, &job.dataset.y, &job.kernel);
            let solver = engine.solver_with_options(
                &job.dataset.x,
                &job.dataset.y,
                &job.kernel,
                opts.clone(),
            )?;
            let mut backend = NativeBackend::new();
            let mut state = match warm.take() {
                Some(w) if w.key == key && w.tau == *tau => w.state,
                _ => ApgdState::zeros(solver.state_dim()),
            };
            let fit = solver.fit_warm(*tau, *lambda, &mut state, &mut backend)?;
            *warm = Some(WarmState { key, tau: *tau, state });
            Metrics::incr(&metrics.fits_total);
            Metrics::add(&metrics.apgd_iters_total, fit.apgd_iters as u64);
            Ok(JobOutcome::Kqr(vec![fit]))
        }
        JobSpec::KqrPath { tau, lambdas } => {
            let solver = engine.solver_with_options(
                &job.dataset.x,
                &job.dataset.y,
                &job.kernel,
                opts.clone(),
            )?;
            let fits = solver.fit_path(*tau, lambdas)?;
            Metrics::add(&metrics.fits_total, fits.len() as u64);
            Metrics::add(
                &metrics.apgd_iters_total,
                fits.iter().map(|f| f.apgd_iters as u64).sum(),
            );
            Ok(JobOutcome::Kqr(fits))
        }
        JobSpec::Nckqr { taus, lam1, lam2 } => {
            // Engine-backed: an NCKQR job on the same dataset as any other
            // job (or a previous run) reuses the cached Gram/eigenbasis.
            let solver = engine.nc_solver(&job.dataset.x, &job.dataset.y, &job.kernel, taus)?;
            let fit = solver.fit(*lam1, *lam2)?;
            Metrics::incr(&metrics.fits_total);
            Ok(JobOutcome::Nckqr(fit))
        }
        JobSpec::Cv { tau, lambdas, folds, seed } => {
            let mut rng = Rng::new(*seed);
            let res = cross_validate_on(
                engine,
                &job.dataset,
                &job.kernel,
                *tau,
                lambdas,
                *folds,
                opts,
                ApproxSpec::Exact,
                &mut rng,
            )?;
            // fold path fits + the final full-data refit path (λ_max..λ*)
            let refit_fits = res.best_index + 1;
            Metrics::add(
                &metrics.fits_total,
                (lambdas.len() * folds + refit_fits) as u64,
            );
            Ok(JobOutcome::Cv(res))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kernel::Kernel;

    fn make_job(id: u64, n: usize, seed: u64, spec: JobSpec) -> FitJob {
        let mut rng = Rng::new(seed);
        let dataset = synth::sine_hetero(n, &mut rng);
        FitJob { id, dataset, kernel: Kernel::Rbf { sigma: 0.4 }, spec }
    }

    #[test]
    fn single_job_roundtrip() {
        let sched = Scheduler::new(1);
        let rx = sched.submit(make_job(7, 25, 1, JobSpec::Kqr { tau: 0.5, lambda: 0.05 }));
        let (id, res) = rx.recv().unwrap();
        assert_eq!(id, 7);
        match res.unwrap() {
            JobOutcome::Kqr(fits) => {
                assert_eq!(fits.len(), 1);
                assert!(fits[0].kkt.pass);
            }
            _ => panic!("wrong outcome"),
        }
        assert_eq!(Metrics::get(&sched.metrics.jobs_completed), 1);
        sched.shutdown();
    }

    #[test]
    fn batch_is_ordered_lambda_descending() {
        let sched = Scheduler::new(1);
        // same dataset (same seed/name/shape) → grouped; λ ascending input
        let jobs = vec![
            make_job(1, 20, 3, JobSpec::Kqr { tau: 0.5, lambda: 0.01 }),
            make_job(2, 20, 3, JobSpec::Kqr { tau: 0.5, lambda: 1.0 }),
            make_job(3, 20, 3, JobSpec::Kqr { tau: 0.5, lambda: 0.1 }),
        ];
        let rx = sched.submit_batch(jobs);
        let mut order = Vec::new();
        for _ in 0..3 {
            let (id, res) = rx.recv().unwrap();
            res.unwrap();
            order.push(id);
        }
        // execution order follows descending λ: ids 2, 3, 1
        assert_eq!(order, vec![2, 3, 1]);
        sched.shutdown();
    }

    #[test]
    fn multi_spec_batch_completes() {
        let sched = Scheduler::new(2);
        let jobs = vec![
            make_job(1, 24, 5, JobSpec::KqrPath { tau: 0.3, lambdas: vec![0.5, 0.05] }),
            make_job(2, 24, 5, JobSpec::Nckqr { taus: vec![0.3, 0.7], lam1: 1.0, lam2: 0.05 }),
            make_job(
                3,
                24,
                5,
                JobSpec::Cv { tau: 0.5, lambdas: vec![0.5, 0.05], folds: 3, seed: 1 },
            ),
        ];
        let rx = sched.submit_batch(jobs);
        let mut got = 0;
        for _ in 0..3 {
            let (_, res) = rx.recv().unwrap();
            res.unwrap();
            got += 1;
        }
        assert_eq!(got, 3);
        assert_eq!(Metrics::get(&sched.metrics.jobs_failed), 0);
        sched.shutdown();
    }

    #[test]
    fn failed_job_reports_error() {
        let sched = Scheduler::new(1);
        let rx = sched.submit(make_job(9, 10, 6, JobSpec::Kqr { tau: 0.5, lambda: -1.0 }));
        let (_, res) = rx.recv().unwrap();
        assert!(res.is_err());
        assert_eq!(Metrics::get(&sched.metrics.jobs_failed), 1);
        sched.shutdown();
    }

    #[test]
    fn warm_state_is_evicted_with_the_gram_cache_entry() {
        use crate::engine::EngineConfig;
        // capacity-1 cache: fitting dataset B evicts dataset A's entry,
        // and the worker must then drop A's warm-start state too.
        let engine = std::sync::Arc::new(FitEngine::with_config(EngineConfig {
            cache_capacity: 1,
            ..EngineConfig::default()
        }));
        // externally-shared metrics (what a co-located server would pass)
        let shared = std::sync::Arc::new(Metrics::new());
        let sched = Scheduler::with_engine_and_metrics(
            1,
            SolveOptions::default(),
            engine,
            shared.clone(),
        );
        let rx = sched.submit(make_job(1, 20, 11, JobSpec::Kqr { tau: 0.5, lambda: 0.1 }));
        rx.recv().unwrap().1.unwrap();
        assert_eq!(
            Metrics::get(&sched.metrics.warm_evictions),
            0,
            "dataset A still cached; its warm state survives"
        );
        // different seed => different dataset => cache eviction of A
        let rx = sched.submit(make_job(
            2,
            20,
            12,
            JobSpec::KqrPath { tau: 0.5, lambdas: vec![0.1] },
        ));
        rx.recv().unwrap().1.unwrap();
        assert_eq!(
            Metrics::get(&sched.metrics.warm_evictions),
            1,
            "A's fingerprint left the GramCache; warm state must go with it"
        );
        assert_eq!(
            Metrics::get(&shared.warm_evictions),
            1,
            "the externally-shared metrics handle sees the same counter"
        );
        // the worker keeps serving jobs afterwards
        let rx = sched.submit(make_job(3, 20, 11, JobSpec::Kqr { tau: 0.5, lambda: 0.1 }));
        assert!(rx.recv().unwrap().1.is_ok());
        sched.shutdown();
    }

    #[test]
    fn bad_cv_fold_count_errors_instead_of_panicking() {
        // `folds: 1` is reachable from server-supplied job specs; it must
        // surface as a job error, not kill the worker thread.
        let sched = Scheduler::new(1);
        let rx = sched.submit(make_job(
            4,
            15,
            8,
            JobSpec::Cv { tau: 0.5, lambdas: vec![0.1], folds: 1, seed: 1 },
        ));
        let (_, res) = rx.recv().unwrap();
        assert!(res.is_err());
        assert_eq!(Metrics::get(&sched.metrics.jobs_failed), 1);
        // the worker is still alive and serves the next job
        let rx = sched.submit(make_job(5, 15, 8, JobSpec::Kqr { tau: 0.5, lambda: 0.1 }));
        assert!(rx.recv().unwrap().1.is_ok());
        sched.shutdown();
    }
}
