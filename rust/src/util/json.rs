//! Minimal JSON parser + writer (substrate).
//!
//! The offline environment has no serde facade crate, so the coordinator
//! protocol and the artifact manifest use this small, strict JSON
//! implementation. Supports the full JSON grammar except `\u` surrogate
//! pairs beyond the BMP (sufficient for our machine-generated payloads).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Convenience: `obj.get(key).and_then(as_f64)`.
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }

    /// Extract a numeric array.
    pub fn get_f64_arr(&self, key: &str) -> Option<Vec<f64>> {
        self.get(key)?.as_arr().map(|a| a.iter().filter_map(Json::as_f64).collect())
    }

    /// Strict numeric array: `None` if the key is missing, not an array,
    /// or any element is not a number (unlike [`Json::get_f64_arr`], which
    /// silently drops non-numeric entries).
    pub fn get_f64_arr_strict(&self, key: &str) -> Option<Vec<f64>> {
        let arr = self.get(key)?.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()?);
        }
        Some(out)
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Json::as_bool)
    }

    /// Non-negative integer field (rejects negatives and non-integers).
    pub fn get_usize(&self, key: &str) -> Option<usize> {
        let v = self.get_f64(key)?;
        if v >= 0.0 && v == v.trunc() && v < 9e15 {
            Some(v as usize)
        } else {
            None
        }
    }

    /// Extract a usize array of indices (all entries must be non-negative
    /// integers, else `None`).
    pub fn get_usize_arr(&self, key: &str) -> Option<Vec<usize>> {
        let arr = self.get(key)?.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            let f = v.as_f64()?;
            if f < 0.0 || f != f.trunc() || f >= 9e15 {
                return None;
            }
            out.push(f as usize);
        }
        Some(out)
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => {
                if v.is_finite() {
                    if *v == v.trunc() && v.abs() < 1e15 {
                        let _ = write!(out, "{}", *v as i64);
                    } else {
                        let _ = write!(out, "{v:e}");
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad hex in \\u"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "s": "x\"y\n"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get_f64_arr("a").unwrap(), vec![1.0, 2.5, -300.0]);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_bool(), Some(true));
        assert_eq!(v.get_str("s"), Some("x\"y\n"));
        // serialize → parse → identical
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn numbers_various() {
        for (s, e) in [("0", 0.0), ("-1.25", -1.25), ("1e3", 1000.0), ("2.5E-2", 0.025)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(e), "{s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "tru", "\"abc", "{\"a\" 1}", "1 2"] {
            assert!(Json::parse(s).is_err(), "{s:?} should fail");
        }
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn float_precision_roundtrip() {
        let v = Json::Num(0.1234567890123456);
        let r = Json::parse(&v.to_string()).unwrap();
        assert!((r.as_f64().unwrap() - 0.1234567890123456).abs() < 1e-16);
    }

    #[test]
    fn builder_helpers() {
        let o = Json::obj(vec![("x", Json::num(2.0)), ("name", Json::str("hi"))]);
        assert_eq!(o.get_f64("x"), Some(2.0));
        assert_eq!(o.to_string(), r#"{"name":"hi","x":2}"#);
    }
}
