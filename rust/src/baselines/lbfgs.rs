//! L-BFGS on the smoothed objective — the `nlm` comparator.
//!
//! R's `nlm` is a generic Newton-type optimizer; applied to KQR it
//! operates on the raw (n+1)-dimensional parameter vector with no reuse
//! of kernel structure. We reproduce the class with a standard two-loop
//! L-BFGS (m=10) + Armijo backtracking on G^γ with a small fixed γ —
//! accurate but slow, matching the paper's "near-par objective, ~100×
//! slower" profile (Tables 1/3/4/5).

use crate::linalg::{dot, gemv, Matrix};
use crate::smooth::{h_gamma, h_gamma_prime};
use anyhow::Result;

/// Generic L-BFGS minimizer over x ∈ R^d.
///
/// `fg` evaluates the objective and writes the gradient into its second
/// argument. Returns (x, objective, iterations).
pub fn lbfgs_minimize(
    mut x: Vec<f64>,
    mut fg: impl FnMut(&[f64], &mut [f64]) -> f64,
    max_iters: usize,
    grad_tol: f64,
) -> (Vec<f64>, f64, usize) {
    let d = x.len();
    let m = 10usize;
    let mut g = vec![0.0; d];
    let mut fx = fg(&x, &mut g);
    let mut s_hist: Vec<Vec<f64>> = Vec::new();
    let mut y_hist: Vec<Vec<f64>> = Vec::new();
    let mut rho_hist: Vec<f64> = Vec::new();
    let mut iters = 0usize;
    for it in 0..max_iters {
        iters = it + 1;
        let gnorm = g.iter().fold(0.0f64, |a, v| a.max(v.abs()));
        if gnorm < grad_tol {
            break;
        }
        // two-loop recursion
        let mut q = g.clone();
        let k = s_hist.len();
        let mut alphas = vec![0.0; k];
        for i in (0..k).rev() {
            alphas[i] = rho_hist[i] * dot(&s_hist[i], &q);
            for (qj, yj) in q.iter_mut().zip(&y_hist[i]) {
                *qj -= alphas[i] * yj;
            }
        }
        // initial Hessian scaling
        if k > 0 {
            let ys = dot(&y_hist[k - 1], &s_hist[k - 1]);
            let yy = dot(&y_hist[k - 1], &y_hist[k - 1]);
            let scale = (ys / yy.max(1e-300)).max(1e-12);
            for qj in q.iter_mut() {
                *qj *= scale;
            }
        }
        for i in 0..k {
            let beta = rho_hist[i] * dot(&y_hist[i], &q);
            for (qj, sj) in q.iter_mut().zip(&s_hist[i]) {
                *qj += (alphas[i] - beta) * sj;
            }
        }
        // direction = −q; Armijo backtracking
        let dir_dot_g = -dot(&q, &g);
        if dir_dot_g >= 0.0 {
            // not a descent direction (numerical breakdown): reset memory
            s_hist.clear();
            y_hist.clear();
            rho_hist.clear();
            continue;
        }
        let mut step = 1.0f64;
        let mut x_new = vec![0.0; d];
        let mut g_new = vec![0.0; d];
        let mut f_new;
        let mut ls_ok = false;
        for _ in 0..40 {
            for i in 0..d {
                x_new[i] = x[i] - step * q[i];
            }
            f_new = fg(&x_new, &mut g_new);
            if f_new <= fx + 1e-4 * step * dir_dot_g {
                // accept
                let s: Vec<f64> = (0..d).map(|i| x_new[i] - x[i]).collect();
                let yv: Vec<f64> = (0..d).map(|i| g_new[i] - g[i]).collect();
                let ys = dot(&yv, &s);
                if ys > 1e-12 {
                    if s_hist.len() == m {
                        s_hist.remove(0);
                        y_hist.remove(0);
                        rho_hist.remove(0);
                    }
                    rho_hist.push(1.0 / ys);
                    s_hist.push(s);
                    y_hist.push(yv);
                }
                x.copy_from_slice(&x_new);
                g.copy_from_slice(&g_new);
                fx = f_new;
                ls_ok = true;
                break;
            }
            step *= 0.5;
        }
        if !ls_ok {
            break; // line search failed: practical convergence
        }
    }
    (x, fx, iters)
}

/// Fit of the generic-optimizer baselines.
#[derive(Clone, Debug)]
pub struct GenericFit {
    pub b: f64,
    pub alpha: Vec<f64>,
    /// Exact (check-loss) objective of problem (2).
    pub objective: f64,
    pub iters: usize,
}

/// Evaluate G^γ and its gradient in (b, α) coordinates (dense; O(n²) per
/// call — deliberately structure-blind like `nlm`).
pub(crate) fn smoothed_fg(
    gram: &Matrix,
    y: &[f64],
    tau: f64,
    lam: f64,
    gamma: f64,
    x: &[f64],
    grad: &mut [f64],
) -> f64 {
    let n = y.len();
    let nf = n as f64;
    let b = x[0];
    let alpha = &x[1..];
    let mut ka = vec![0.0; n];
    gemv(gram, alpha, &mut ka);
    let mut obj = 0.0;
    let mut z = vec![0.0; n];
    for i in 0..n {
        let r = y[i] - b - ka[i];
        obj += h_gamma(r, tau, gamma) / nf;
        z[i] = h_gamma_prime(r, tau, gamma);
    }
    obj += 0.5 * lam * dot(alpha, &ka);
    // ∂/∂b = −(1/n)Σz ; ∂/∂α = K(−z/n + λα)
    grad[0] = -z.iter().sum::<f64>() / nf;
    let mut w = vec![0.0; n];
    for i in 0..n {
        w[i] = -z[i] / nf + lam * alpha[i];
    }
    gemv(gram, &w, &mut grad[1..]);
    obj
}

/// `nlm` proxy: L-BFGS on G^γ with small fixed γ.
pub fn solve_kqr_lbfgs(
    gram: &Matrix,
    y: &[f64],
    tau: f64,
    lam: f64,
    max_iters: usize,
) -> Result<GenericFit> {
    let n = y.len();
    let gamma = 1e-4;
    let x0 = vec![0.0; n + 1];
    let (x, _, iters) = lbfgs_minimize(
        x0,
        |x, g| smoothed_fg(gram, y, tau, lam, gamma, x, g),
        max_iters,
        1e-7,
    );
    let b = x[0];
    let alpha = x[1..].to_vec();
    let objective = exact_objective(gram, y, tau, lam, b, &alpha);
    Ok(GenericFit { b, alpha, objective, iters })
}

/// Exact check-loss objective at (b, α) via the Gram matrix.
pub(crate) fn exact_objective(
    gram: &Matrix,
    y: &[f64],
    tau: f64,
    lam: f64,
    b: f64,
    alpha: &[f64],
) -> f64 {
    let n = y.len();
    let nf = n as f64;
    let mut ka = vec![0.0; n];
    gemv(gram, alpha, &mut ka);
    let loss: f64 =
        (0..n).map(|i| crate::smooth::rho_tau(y[i] - b - ka[i], tau)).sum::<f64>() / nf;
    loss + 0.5 * lam * dot(alpha, &ka)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, Rng};
    use crate::kernel::{median_heuristic_sigma, Kernel};
    use crate::kqr::KqrSolver;

    #[test]
    fn lbfgs_minimizes_quadratic() {
        // f(x) = ½‖x − c‖²
        let c = [3.0, -1.0, 2.0];
        let (x, f, _) = lbfgs_minimize(
            vec![0.0; 3],
            |x, g| {
                let mut v = 0.0;
                for i in 0..3 {
                    g[i] = x[i] - c[i];
                    v += 0.5 * (x[i] - c[i]).powi(2);
                }
                v
            },
            200,
            1e-10,
        );
        assert!(f < 1e-15);
        for i in 0..3 {
            assert!((x[i] - c[i]).abs() < 1e-7);
        }
    }

    #[test]
    fn lbfgs_rosenbrock() {
        let (x, f, _) = lbfgs_minimize(
            vec![-1.2, 1.0],
            |x, g| {
                let (a, b) = (x[0], x[1]);
                g[0] = -400.0 * a * (b - a * a) - 2.0 * (1.0 - a);
                g[1] = 200.0 * (b - a * a);
                100.0 * (b - a * a).powi(2) + (1.0 - a).powi(2)
            },
            2000,
            1e-9,
        );
        assert!(f < 1e-10, "f={f}");
        assert!((x[0] - 1.0).abs() < 1e-4 && (x[1] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn kqr_lbfgs_close_to_fastkqr_but_generic() {
        let mut rng = Rng::new(5);
        let d = synth::sine_hetero(40, &mut rng);
        let sigma = median_heuristic_sigma(&d.x);
        let kernel = Kernel::Rbf { sigma };
        let solver = KqrSolver::new(&d.x, &d.y, kernel).unwrap();
        let fast = solver.fit(0.5, 0.05).unwrap();
        let slow = solve_kqr_lbfgs(solver.gram(), &d.y, 0.5, 0.05, 3000).unwrap();
        // nlm-class solvers land close but (slightly) above the exact optimum
        assert!(slow.objective >= fast.objective - 1e-6);
        assert!(
            slow.objective - fast.objective < 0.02 * (1.0 + fast.objective),
            "fast {} vs lbfgs {}",
            fast.objective,
            slow.objective
        );
    }
}
