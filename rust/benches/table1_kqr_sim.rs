//! Table 1: KQR on the Friedman simulation (paper: p=5000).
//! `cargo bench --bench table1_kqr_sim [-- --paper|--ns ...|--p ...]`
use fastkqr::experiments::{kqr_tables, print_table, speedups, TableConfig};
use fastkqr::util::Args;

fn main() {
    let args = Args::from_env();
    let mut cfg = TableConfig::from_args(&args);
    if args.flag("paper") && args.get("p").is_none() {
        cfg.p = 5000;
    }
    let cells = kqr_tables::table1(&cfg).expect("table1");
    print_table(&format!("Table 1 — Friedman p={}", cfg.p), &cells, &cfg.solvers);
    for (label, n, solver, factor) in speedups(&cells) {
        println!("speedup {label} n={n}: {factor:.1}x vs {solver}");
    }
}
