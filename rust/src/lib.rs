//! # fastkqr
//!
//! A production-grade reproduction of *fastkqr: A Fast Algorithm for
//! Kernel Quantile Regression* (Tang, Gu & Wang, 2024) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)**: the exact finite-smoothing solvers for KQR and
//!   non-crossing KQR, the spectral O(n²) update machinery, baselines,
//!   CV, the fit-job coordinator and a TCP fit/predict server.
//! - **L2/L1 (python/, build-time only)**: the APGD iteration chunk as a
//!   JAX program calling Pallas kernels, AOT-lowered to HLO text and
//!   executed from Rust through PJRT (`runtime`, behind the `xla`
//!   feature).
//!
//! Cross-cutting the solvers sits the **fit engine** ([`engine`]):
//!
//! - [`linalg::simd`] — a runtime-resolved SIMD dispatch table
//!   (`FASTKQR_SIMD`: AVX2 on x86_64, NEON on aarch64, scalar elsewhere
//!   or on `off`) feeding every level-1 kernel. The SIMD lanes mirror
//!   the scalar accumulator structure, so results are bitwise-identical
//!   to the scalar oracle at every tier; the opt-in `FASTKQR_FMA=1`
//!   fused tier trades that for ≤1e-12 tolerance parity.
//! - [`linalg::par`] — a scoped-thread parallel substrate (row-blocked
//!   GEMV/GEMVᵀ/GEMM, parallel Gram construction) that the `linalg::blas`
//!   kernels dispatch into above a size cutoff, with a serial fallback
//!   that keeps small-n results bitwise unchanged. Configure with
//!   `FASTKQR_THREADS` / `FASTKQR_PAR_MIN_DIM`.
//! - [`linalg::gemm`] — the BLAS-3 layer: multi-RHS GEMM entry points
//!   whose columns/rows are bitwise equal to the serial GEMV kernels
//!   (the lockstep substrate) plus a packed Mc/Kc/Nc-tiled microkernel
//!   (`FASTKQR_GEMM_MC`/`_KC`/`_NC`). The O(n³) `tred2` phases of the
//!   one-time eigendecomposition also run on the parallel substrate.
//! - [`engine::GramCache`] — content-fingerprinted, `Arc`-shared
//!   memoization of (dataset, kernel) → (Gram, eigenbasis); the O(n³)
//!   eigendecomposition runs exactly once per fingerprint per process,
//!   even under concurrent requests. Non-PSD kernel matrices are
//!   rejected with an error (and the rejection is cached too).
//! - [`engine::FitEngine`] — hands out cache-backed solvers, batches
//!   full τ × λ grids on one basis with warm starts in both directions
//!   ([`engine::FitEngine::fit_grid`]), and bounds the concurrency that
//!   [`cv::cross_validate`] (parallel folds + final refit) and the
//!   [`coordinator`] scheduler/server draw on. `FASTKQR_LOCKSTEP=1`
//!   (or `EngineConfig::lockstep`) switches `fit_grid` to the
//!   [`engine::lockstep`] driver: all ready grid cells advance together,
//!   two GEMMs per bundle iteration instead of two GEMVs per cell, with
//!   the sequential path kept as the bitwise parity oracle.
//!
//! The **scale axis** is the first-class Gram representation
//! ([`spectral::GramRepr`]): every layer — solvers, KKT certificates,
//! the eq.-(8)/(19) projection solves, the engine cache, the lockstep
//! grid driver, CV, artifacts — operates on either the exact dense n×n
//! matrix (the default and the bitwise oracle) or a rank-m **Nyström
//! thin factor** ([`kernel::nystrom`]): O(n·m) memory, O(n·m²+m³)
//! setup, no n×n materialization and no zero-padding anywhere, which
//! lifts the n ≫ 10⁴ cap. [`engine::ApproxSpec`] keys the GramCache so
//! exact and approximate bases for one dataset coexist; fitted models
//! carry a compressed O(m) landmark predictor that persists as an O(m)
//! artifact and predicts in O(m·p) per point.
//!
//! The engine fits through one of two **solver backends**
//! ([`solver::SolverBackend`]): the paper's finite-smoothing APGD
//! ([`kqr`], the default) or a pALM semismooth-Newton method
//! ([`solver::ssn`]) whose active-set Newton systems are (rank+1)² —
//! the backend of choice on thin Nyström/RFF bases. Both certify
//! against the same exact KKT report; `Auto` picks per problem from a
//! deterministic cost model ([`solver::auto_select`]).
//!
//! On top of the engine sits the declarative **fit API** ([`api`]): a
//! serializable [`api::FitSpec`] (kernel — optionally with a Nyström
//! `approx` block — + task + option overrides + a master `seed` that
//! pins landmark sampling and CV fold shuffling) executed by
//! [`engine::FitEngine::run`] into a unified [`api::QuantileModel`]
//! with one `predict`/`taus`/`diagnostics` surface and versioned
//! save/load artifacts. The CLI subcommands, the TCP protocol and the
//! CV driver are all thin shells over this one entry point.
//!
//! The **serving path** mirrors the fit engine: every model compiles
//! once into an [`engine::PredictPlan`] (resolved kernel + `Arc`'d
//! train-row/landmark block + all coefficients packed into one matrix,
//! so a request is one cross-Gram + one multi-RHS GEMM), the model
//! registry stores the plan beside the model, and the coordinator's
//! [`coordinator::batcher`] coalesces concurrent predict requests for
//! one model into a single plan execution with bitwise-identical rows
//! (`FASTKQR_BATCH_WINDOW_US` / `FASTKQR_BATCH_MAX_ROWS`; large
//! responses stream in bounded chunks via the protocol's
//! `"stream": true`).
//!
//! Quick start (native backend):
//!
//! ```no_run
//! use fastkqr::prelude::*;
//!
//! let mut rng = Rng::new(7);
//! let data = fastkqr::data::synth::sine_hetero(200, &mut rng);
//! let spec = FitSpec::single(data.x, data.y, KernelSpec::Auto, 0.5, 1e-2);
//! let model = FitEngine::global().run(&spec).expect("fit");
//! assert!(model.kkt_pass(), "exactness certificate");
//! model.save("model.json").expect("persist");
//! let back = QuantileModel::load("model.json").expect("reload");
//! assert_eq!(back.taus(), vec![0.5]);
//! ```

pub mod api;
pub mod backend;
pub mod baselines;
pub mod coordinator;
pub mod cv;
pub mod data;
pub mod engine;
pub mod experiments;
pub mod kernel;
pub mod kqr;
pub mod linalg;
pub mod nckqr;
pub mod runtime;
pub mod smooth;
pub mod solver;
pub mod spectral;
pub mod util;

/// Convenience re-exports for the common fitting workflow.
pub mod prelude {
    pub use crate::api::{FitSpec, KernelSpec, QuantileModel, Task};
    pub use crate::backend::Backend;
    pub use crate::cv::{cross_validate, CvResult};
    pub use crate::data::{Dataset, Rng};
    pub use crate::engine::{
        ApproxSpec, EngineConfig, FitEngine, GridFit, LockstepStats, PredictPlan,
    };
    pub use crate::kernel::{median_heuristic_sigma, Kernel};
    pub use crate::kqr::{KqrFit, KqrSolver, SolveOptions};
    pub use crate::nckqr::{NcOptions, NckqrFit, NckqrSolver};
    pub use crate::smooth::pinball_loss;
    pub use crate::solver::SolverBackend;
    pub use crate::spectral::{GramRepr, LowRankCoef, LowRankFactor};
}

/// Crate version string (reported by the CLI and the server banner).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
