//! pALM-SSN: preconditioned augmented Lagrangian with semismooth-Newton
//! inner solves for the exact (non-smooth) KQR problem.
//!
//! Following Deng–Li–Zhang ("Scalable Kernel Quantile Regression: A
//! Preconditioned Augmented Lagrangian Method"), the check-loss residual
//! is split out as a constrained variable and eliminated through its
//! Moreau envelope, leaving a C¹ subproblem whose generalized Hessian is
//! diagonal-plus-low-rank on the **active set** (points inside the
//! residual band). Each Newton system is solved by a Cholesky factor of
//! an (r+1)×(r+1) matrix — r the spectral rank — maintained across
//! Newton steps with rank-1 up/down-dates ([`Cholesky::update`] /
//! [`Cholesky::downdate`]) as points enter and leave the active set.
//!
//! **Coordinates.** We work in η = Λ^{1/2}β (β the spectral coordinates
//! of [`crate::spectral::SpectralBasis`]), with W = U·diag(√λ_j), so the
//! fitted values are f = b·1 + Wη and the RKHS penalty is (λ/2)‖η‖².
//! This makes the Newton system unconditionally positive definite for
//! every Gram representation — dense, Nyström and random-feature bases
//! all pass through unchanged, and rank-deficient spectra cost nothing.
//!
//! **Augmented Lagrangian.** With u = y − b·1 − Wη (the residual) as the
//! split variable, multipliers w and penalty σ, minimizing over u in
//! closed form gives the reduced objective over z = (b, η)
//!
//!   ψ(z) = (λ/2)‖η‖² + Σ_i φ_i(v_i) + (τ_p/2)‖z − z̄‖²,
//!     v_i = y_i − b − (Wη)_i − w_i/σ,
//!     φ_i = Moreau envelope of c·ρ_τ at scale c = 1/(nσ),
//!
//! with prox(v) = v − cτ (v > cτ), v + c(1−τ) (v < −c(1−τ)), else 0 and
//! ∇φ_i = σ·s_i, s = v − prox(v). The proximal term τ_p keeps the
//! b-block positive definite even when the active set is empty. After
//! each inner solve the multipliers update as w⁺ = σ(prox(v) − v) ∈
//! −(1/n)∂ρ_τ, i.e. w stays in the box [−τ/n, (1−τ)/n].
//!
//! Convergence is certified by the *same* exact check-loss objective and
//! KKT report as APGD ([`apgd::exact_objective`], [`kkt_check`]), so the
//! two backends are interchangeable behind the engine.
//!
//! **Factor carry.** The grid drivers run through
//! [`fit_warm_from_stats_carried`], which persists the converged active
//! set and its Cholesky factor in [`SsnState::factor`] across inner
//! solves *and* grid cells. The next solve seeds its Newton system from
//! the carried factor by rank-1 up/downdates over the symmetric
//! difference of active sets (plus sparse axis updates for Δλ and
//! scaled-jacobian updates for Δσ) instead of refactorizing — see
//! [`FactorCarry`]. The per-cell path ([`fit_warm_from_stats`]) never
//! reads or writes the carry and is preserved decision-for-decision as
//! the parity oracle.

use crate::kqr::apgd::{self, ApgdWorkspace};
use crate::kqr::kkt::{kkt_check, KktReport};
use crate::kqr::{KqrFit, KqrSolver};
use crate::linalg::{gemv, gemv_t, Cholesky, Matrix};
use crate::smooth::rho_tau;
use anyhow::{bail, Result};

/// Initial augmented-Lagrangian penalty for a cold start.
pub(crate) const SIGMA_INIT: f64 = 1.0;
/// Multiplicative σ escalation per outer iteration.
pub(crate) const SIGMA_GROWTH: f64 = 10.0;
/// σ ceiling (the prox band 1/(nσ) is far below f64 noise here).
pub(crate) const SIGMA_MAX: f64 = 1e10;
/// Proximal (pALM) regularization: keeps the Newton system PD when the
/// active set is empty; the prox center moves every outer iteration, so
/// it does not bias the fixed point.
pub(crate) const TAU_P: f64 = 1e-8;
/// Inner gradient tolerance floor, in subgradient units (the same units
/// as `SolveOptions::kkt_tol`; the default KKT gate is 1e-3).
pub(crate) const INNER_TOL_FLOOR: f64 = 1e-10;
/// Hard caps: outer (multiplier) rounds and Newton steps per inner solve.
pub(crate) const MAX_OUTER: usize = 40;
pub(crate) const MAX_NEWTON: usize = 100;
/// Stop after this many consecutive outer rounds without certificate
/// improvement once the certificate already passes.
pub(crate) const MAX_STALL: usize = 3;

/// Active-set swings beyond this trigger a refactorization instead of
/// |ΔA| rank-1 passes (each costs O(dim²)); also the bundle driver's
/// Hamming-distance bound for adopting a leader's factor.
pub(crate) fn swing_cap(dim: usize) -> usize {
    8usize.max(dim / 4)
}

/// Warm-startable pALM state: primal (b, η), multipliers w, penalty σ.
///
/// The grid drivers carry this cell-to-cell exactly like the APGD path
/// carries [`crate::kqr::apgd::ApgdState`]: within a τ column the full
/// state (including multipliers and a damped σ) flows down the λ path;
/// across columns the head state seeds the neighbor after
/// [`SsnState::retarget`] clamps the multipliers into the new τ's box.
#[derive(Clone, Debug)]
pub struct SsnState {
    pub b: f64,
    /// η = Λ^{1/2}β, length = basis dim.
    pub eta: Vec<f64>,
    /// Multipliers, length n, in [−τ/n, (1−τ)/n].
    pub w: Vec<f64>,
    /// Augmented-Lagrangian penalty; ≤ 0 means "cold" (reset on entry).
    pub sigma: f64,
    /// Newton factor carried across inner solves and grid cells by the
    /// carry-enabled path ([`fit_warm_from_stats_carried`]); `None` on
    /// cold starts and always `None` after the per-cell oracle path.
    pub factor: Option<FactorCarry>,
}

/// A Newton-system Cholesky factor annotated with exactly what it
/// embeds: the active set A and the (λ, σ) pair of
///
///   H = diag(τ_p, (λ+τ_p)I) + σ Σ_{i∈A} j_i j_iᵀ,  j_i = [1; W_i].
///
/// Carrying this between solves lets [`seed_factor`] reconcile it to a
/// new (λ, σ, A) by rank-1 up/downdates — sparse axis vectors for the
/// λ-shift, jacobian columns over the symmetric set difference, and
/// √|Δσ|-scaled jacobian columns over the new active set — with every
/// intermediate matrix positive definite, so a numerical failure at any
/// step simply falls back to refactorization.
#[derive(Clone, Debug)]
pub struct FactorCarry {
    pub(crate) chol: Cholesky,
    pub(crate) active: Vec<bool>,
    pub(crate) lam: f64,
    pub(crate) sigma: f64,
}

impl SsnState {
    /// Cold state for a problem with `n` observations and spectral
    /// dimension `dim`.
    pub fn zeros(n: usize, dim: usize) -> SsnState {
        SsnState { b: 0.0, eta: vec![0.0; dim], w: vec![0.0; n], sigma: 0.0, factor: None }
    }

    /// Prepare a state fitted at one τ to seed an adjacent τ column:
    /// clamp the multipliers into the new box [−τ/n, (1−τ)/n] and damp σ
    /// so the new subproblem can reshape its active set cheaply.
    pub fn retarget(&mut self, tau: f64) {
        let n = self.w.len().max(1) as f64;
        let (lo, hi) = (-tau / n, (1.0 - tau) / n);
        for wi in &mut self.w {
            *wi = wi.clamp(lo, hi);
        }
        if self.sigma > 0.0 {
            self.sigma = (self.sigma / 100.0).clamp(SIGMA_INIT, 1e4);
        }
    }
}

/// prox of c·ρ_τ at v, with `hi = cτ`, `lo = c(1−τ)` precomputed.
/// (`pub(crate)`: the NCKQR lift reuses it per level.)
#[inline]
pub(crate) fn prox_rho(v: f64, lo: f64, hi: f64) -> f64 {
    if v > hi {
        v - hi
    } else if v < -lo {
        v + lo
    } else {
        0.0
    }
}

/// Scratch buffers reused across Newton steps and outer rounds.
/// `pub(crate)` so the bundled grid driver (`engine::ssn_grid`) can fill
/// the GEMV-shaped slots (`f`, `uts`, `delta`) from batched GEMMs.
pub(crate) struct Workspace {
    /// fitted values b + Wη (length n)
    pub(crate) f: Vec<f64>,
    /// shifted residuals v = y − f − w/σ (length n)
    pub(crate) v: Vec<f64>,
    /// envelope gradients s = v − prox(v) (length n)
    pub(crate) s: Vec<f64>,
    /// active-set membership (prox(v_i) == 0)
    pub(crate) active: Vec<bool>,
    /// Uᵀs (length dim)
    pub(crate) uts: Vec<f64>,
    /// gradient over (b, η) (length dim+1)
    pub(crate) grad: Vec<f64>,
    /// Newton direction (length dim+1)
    pub(crate) dir: Vec<f64>,
    /// line-search direction image d_b + W d_η (length n)
    pub(crate) delta: Vec<f64>,
    /// spectral scratch (length dim)
    pub(crate) scratch: Vec<f64>,
}

impl Workspace {
    pub(crate) fn new(n: usize, dim: usize) -> Workspace {
        Workspace {
            f: vec![0.0; n],
            v: vec![0.0; n],
            s: vec![0.0; n],
            active: vec![false; n],
            uts: vec![0.0; dim],
            grad: vec![0.0; dim + 1],
            dir: vec![0.0; dim + 1],
            delta: vec![0.0; n],
            scratch: vec![0.0; dim],
        }
    }
}

/// The W row image of a spectral vector: out = W q = U(√λ ∘ q).
pub(crate) fn w_apply(
    solver: &KqrSolver,
    sqrt_lam: &[f64],
    q: &[f64],
    scratch: &mut [f64],
    out: &mut [f64],
) {
    for (sc, (sl, qi)) in scratch.iter_mut().zip(sqrt_lam.iter().zip(q)) {
        *sc = sl * qi;
    }
    gemv(&solver.basis.u, scratch, out);
}

/// Refresh f, v, s, active for the current (b, η, w, σ). Returns the
/// number of active points.
#[allow(clippy::too_many_arguments)]
pub(crate) fn refresh(
    solver: &KqrSolver,
    sqrt_lam: &[f64],
    b: f64,
    eta: &[f64],
    w: &[f64],
    sigma: f64,
    tau: f64,
    ws: &mut Workspace,
) -> usize {
    // Split the borrow: w_apply writes ws.f from ws.scratch.
    let (scratch, f) = (&mut ws.scratch, &mut ws.f);
    w_apply(solver, sqrt_lam, eta, scratch, f);
    refresh_from_f(solver, b, w, sigma, tau, ws)
}

/// Scalar tail of [`refresh`]: assumes `ws.f` already holds the Wη rows
/// (the bundled grid driver fills them from one grid-wide GEMM) and
/// finishes f, v, s and the active set in place.
pub(crate) fn refresh_from_f(
    solver: &KqrSolver,
    b: f64,
    w: &[f64],
    sigma: f64,
    tau: f64,
    ws: &mut Workspace,
) -> usize {
    let y = &solver.y;
    let c = 1.0 / (y.len() as f64 * sigma);
    let (lo, hi) = (c * (1.0 - tau), c * tau);
    let mut n_active = 0;
    for i in 0..y.len() {
        let fi = b + ws.f[i];
        ws.f[i] = fi;
        let vi = y[i] - fi - w[i] / sigma;
        ws.v[i] = vi;
        let p = prox_rho(vi, lo, hi);
        ws.s[i] = vi - p;
        ws.active[i] = p == 0.0;
        if ws.active[i] {
            n_active += 1;
        }
    }
    n_active
}

/// The reduced AL objective ψ at trial point (b+t·d_b, η+t·d_η), using
/// the precomputed direction image Δ = d_b + W d_η (v_trial = v − tΔ).
#[allow(clippy::too_many_arguments)]
pub(crate) fn trial_objective(
    solver: &KqrSolver,
    lam: f64,
    tau: f64,
    sigma: f64,
    tau_p: f64,
    center: (f64, &[f64]),
    b: f64,
    eta: &[f64],
    t: f64,
    ws: &Workspace,
) -> f64 {
    let n = solver.y.len();
    let nf = n as f64;
    let c = 1.0 / (nf * sigma);
    let (lo, hi) = (c * (1.0 - tau), c * tau);
    let mut env = 0.0;
    for i in 0..n {
        let v = ws.v[i] - t * ws.delta[i];
        let u = prox_rho(v, lo, hi);
        env += rho_tau(u, tau) / nf + 0.5 * sigma * (u - v) * (u - v);
    }
    let (cb, ceta) = center;
    let bt = b + t * ws.dir[0];
    let mut pen = 0.0;
    let mut prox_term = (bt - cb) * (bt - cb);
    for j in 0..eta.len() {
        let ej = eta[j] + t * ws.dir[j + 1];
        pen += ej * ej;
        let dj = ej - ceta[j];
        prox_term += dj * dj;
    }
    env + 0.5 * lam * pen + 0.5 * tau_p * prox_term
}

/// Build the generalized-Hessian Cholesky factor from scratch:
/// H = diag(τ_p, (λ+τ_p)I) + σ Σ_{i∈A} j_i j_iᵀ, j_i = [1; W_i].
pub(crate) fn refactor(
    solver: &KqrSolver,
    sqrt_lam: &[f64],
    lam: f64,
    sigma: f64,
    tau_p: f64,
    active: &[bool],
) -> Result<Cholesky> {
    let dim = sqrt_lam.len();
    let m = dim + 1;
    let mut h = Matrix::zeros(m, m);
    h[(0, 0)] = tau_p;
    for j in 0..dim {
        h[(j + 1, j + 1)] = lam + tau_p;
    }
    for (i, &on) in active.iter().enumerate() {
        if !on {
            continue;
        }
        let row = solver.basis.u.row(i);
        // lower triangle only (Cholesky::new reads nothing else)
        h[(0, 0)] += sigma;
        for a in 0..dim {
            let ja = sqrt_lam[a] * row[a];
            h[(a + 1, 0)] += sigma * ja;
            for bcol in 0..=a {
                h[(a + 1, bcol + 1)] += sigma * ja * (sqrt_lam[bcol] * row[bcol]);
            }
        }
    }
    Cholesky::new(&h).map_err(|e| anyhow::anyhow!("ssn: Newton system factorization: {e}"))
}

/// The ±√σ·j_i vector of one observation (for rank-1 factor maintenance).
pub(crate) fn jacobian_column(
    solver: &KqrSolver,
    sqrt_lam: &[f64],
    sigma: f64,
    i: usize,
) -> Vec<f64> {
    let row = solver.basis.u.row(i);
    let rs = sigma.sqrt();
    let mut x = Vec::with_capacity(sqrt_lam.len() + 1);
    x.push(rs);
    for (sl, r) in sqrt_lam.iter().zip(row) {
        x.push(rs * sl * r);
    }
    x
}

/// Reconcile a carried factor to the current (λ, σ, active) by rank-1
/// up/downdates, or decline (`None`) when the rank-1 budget would exceed
/// the refactorization estimate or a downdate loses definiteness.
///
/// Three passes, each of which leaves a valid positive-definite H:
///
/// 1. **λ-shift**: the η diagonal moves by Δλ — `dim` axis updates of
///    √|Δλ|·e_{j+1} (sparse; [`Cholesky::update`] skips leading zeros);
/// 2. **active-set difference** at the carried σ: jacobian columns for
///    points that entered (update) or left (downdate), in index order;
/// 3. **σ-shift** over the new active set: √|Δσ|-scaled jacobian
///    columns (escalation ⇒ updates, cross-cell damping ⇒ downdates).
///
/// Successful rank-1 operations are counted into `updates` (they remain
/// counted on a failed seed — the partial work was done). The carry is
/// consumed either way; on `None` the caller refactorizes.
pub(crate) fn seed_factor(
    solver: &KqrSolver,
    sqrt_lam: &[f64],
    lam: f64,
    sigma: f64,
    fc: FactorCarry,
    active: &[bool],
    updates: &mut usize,
) -> Option<Cholesky> {
    let dim = sqrt_lam.len();
    let FactorCarry { mut chol, active: old_active, lam: lam0, sigma: sigma0 } = fc;
    if old_active.len() != active.len() || chol.factor().rows() != dim + 1 {
        return None;
    }
    let lam_changed = lam != lam0;
    let sigma_changed = sigma != sigma0;
    let n_diff = old_active.iter().zip(active).filter(|(p, c)| p != c).count();
    let a_new = active.iter().filter(|&&on| on).count();
    // Rank-1 ops this seed would cost vs a rough refactorization budget
    // (build |A|·dim²/2 + factor dim³/3): decline when seeding is the
    // more expensive road.
    let budget = n_diff
        + if lam_changed { dim } else { 0 }
        + if sigma_changed { a_new } else { 0 };
    if budget > dim + a_new {
        return None;
    }
    if lam_changed {
        let dl = lam - lam0;
        let r = dl.abs().sqrt();
        for j in 0..dim {
            let mut x = vec![0.0; dim + 1];
            x[j + 1] = r;
            if dl > 0.0 {
                chol.update(&mut x);
            } else if chol.downdate(&mut x).is_err() {
                return None;
            }
            *updates += 1;
        }
    }
    for (i, (&was, &is)) in old_active.iter().zip(active).enumerate() {
        if was == is {
            continue;
        }
        let mut x = jacobian_column(solver, sqrt_lam, sigma0, i);
        if is {
            chol.update(&mut x);
        } else if chol.downdate(&mut x).is_err() {
            return None;
        }
        *updates += 1;
    }
    if sigma_changed {
        let ds = sigma - sigma0;
        for (i, &on) in active.iter().enumerate() {
            if !on {
                continue;
            }
            let mut x = jacobian_column(solver, sqrt_lam, ds.abs(), i);
            if ds > 0.0 {
                chol.update(&mut x);
            } else if chol.downdate(&mut x).is_err() {
                return None;
            }
            *updates += 1;
        }
    }
    Some(chol)
}

/// Assemble ∇ψ into `ws.grad` from the refreshed `ws.s` / `ws.uts`,
/// returning ‖∇ψ‖_∞. (`ws.uts` must already hold Uᵀs — the per-cell
/// path computes it with a GEMV, the bundled driver with one GEMM.)
pub(crate) fn assemble_gradient(
    sqrt_lam: &[f64],
    lam: f64,
    sigma: f64,
    center: (f64, &[f64]),
    b: f64,
    eta: &[f64],
    ws: &mut Workspace,
) -> f64 {
    let mut sum_s = 0.0;
    for &si in &ws.s {
        sum_s += si;
    }
    ws.grad[0] = -sigma * sum_s + TAU_P * (b - center.0);
    let mut gmax = ws.grad[0].abs();
    for j in 0..sqrt_lam.len() {
        let g = lam * eta[j] - sigma * sqrt_lam[j] * ws.uts[j] + TAU_P * (eta[j] - center.1[j]);
        ws.grad[j + 1] = g;
        gmax = gmax.max(g.abs());
    }
    gmax
}

/// Armijo backtracking on ψ along `ws.dir` (its residual image already
/// in `ws.delta`): the accepted step, or `None` when the search bottoms
/// out — numerically flat, which callers treat as inner convergence.
#[allow(clippy::too_many_arguments)]
pub(crate) fn line_search(
    solver: &KqrSolver,
    lam: f64,
    tau: f64,
    sigma: f64,
    center: (f64, &[f64]),
    b: f64,
    eta: &[f64],
    gd: f64,
    ws: &Workspace,
) -> Option<f64> {
    let f0 = trial_objective(solver, lam, tau, sigma, TAU_P, center, b, eta, 0.0, ws);
    let mut t = 1.0;
    while t > 1e-12 {
        let ft = trial_objective(solver, lam, tau, sigma, TAU_P, center, b, eta, t, ws);
        if ft <= f0 + 1e-4 * t * gd {
            return Some(t);
        }
        t *= 0.5;
    }
    None
}

/// Result of one inner semismooth-Newton solve.
struct InnerResult {
    newton_steps: usize,
    refactors: usize,
    updates: usize,
    /// 1 when the first factorization was seeded from a carried factor.
    seeded: usize,
}

/// Minimize ψ over (b, η) to gradient tolerance `tol` by semismooth
/// Newton with active-set Cholesky maintenance and Armijo backtracking.
///
/// `carry` is the cross-solve factor slot: when it holds a
/// [`FactorCarry`] on entry, the first Newton step seeds its factor
/// from it via [`seed_factor`] instead of refactorizing; on exit the
/// final factor (with the active set it embeds) is written back. The
/// oracle path passes a slot that starts `None` and is dropped, which
/// reproduces the per-cell behavior decision-for-decision.
#[allow(clippy::too_many_arguments)]
fn inner_solve(
    solver: &KqrSolver,
    sqrt_lam: &[f64],
    tau: f64,
    lam: f64,
    sigma: f64,
    tol: f64,
    b: &mut f64,
    eta: &mut [f64],
    w: &[f64],
    carry: &mut Option<FactorCarry>,
    ws: &mut Workspace,
) -> Result<InnerResult> {
    let dim = sqrt_lam.len();
    let center = (*b, eta.to_vec());
    let cap = swing_cap(dim);
    let mut chol: Option<Cholesky> = None;
    let mut prev_active: Vec<bool> = Vec::new();
    let mut res = InnerResult { newton_steps: 0, refactors: 0, updates: 0, seeded: 0 };

    refresh(solver, sqrt_lam, *b, eta, w, sigma, tau, ws);
    for _ in 0..MAX_NEWTON {
        // gradient of ψ at (b, η)
        gemv_t(&solver.basis.u, &ws.s, &mut ws.uts);
        let gmax = assemble_gradient(sqrt_lam, lam, sigma, (center.0, &center.1), *b, eta, ws);
        if gmax <= tol {
            break;
        }

        // factor maintenance: seed from the carried factor on first
        // need, then rank-1 up/down-dates on small active-set swings,
        // refactorization on large ones (or downdate failure)
        let mut factored = false;
        if chol.is_none() {
            if let Some(fc) = carry.take() {
                if let Some(c) =
                    seed_factor(solver, sqrt_lam, lam, sigma, fc, &ws.active, &mut res.updates)
                {
                    prev_active.clear();
                    prev_active.extend_from_slice(&ws.active);
                    chol = Some(c);
                    res.seeded = 1;
                    factored = true;
                }
            }
        }
        if !factored {
            if let Some(f) = chol.as_mut() {
                let changed: Vec<(usize, bool)> = prev_active
                    .iter()
                    .zip(ws.active.iter())
                    .enumerate()
                    .filter(|(_, (p, c))| p != c)
                    .map(|(i, (_, c))| (i, *c))
                    .collect();
                if changed.len() <= cap {
                    let mut ok = true;
                    for &(i, entered) in &changed {
                        let mut x = jacobian_column(solver, sqrt_lam, sigma, i);
                        if entered {
                            f.update(&mut x);
                        } else if f.downdate(&mut x).is_err() {
                            ok = false;
                            break;
                        }
                        res.updates += 1;
                    }
                    factored = ok;
                }
            }
        }
        if !factored {
            chol = Some(refactor(solver, sqrt_lam, lam, sigma, TAU_P, &ws.active)?);
            res.refactors += 1;
        }
        prev_active.clear();
        prev_active.extend_from_slice(&ws.active);

        // Newton direction H d = −g
        let neg: Vec<f64> = ws.grad.iter().map(|g| -g).collect();
        let d = chol.as_ref().expect("factor present").solve(&neg);
        ws.dir.copy_from_slice(&d);
        let gd: f64 = ws.grad.iter().zip(&ws.dir).map(|(g, di)| g * di).sum();

        // Armijo backtracking on ψ, trial points via Δ = d_b + W d_η
        {
            let (scratch, delta) = (&mut ws.scratch, &mut ws.delta);
            w_apply(solver, sqrt_lam, &d[1..], scratch, delta);
            for di in delta.iter_mut() {
                *di += d[0];
            }
        }
        let t = match line_search(
            solver, lam, tau, sigma, (center.0, &center.1), *b, eta, gd, ws,
        ) {
            Some(t) => t,
            // numerically flat — treat as converged
            None => break,
        };
        *b += t * ws.dir[0];
        for j in 0..dim {
            eta[j] += t * ws.dir[j + 1];
        }
        res.newton_steps += 1;
        refresh(solver, sqrt_lam, *b, eta, w, sigma, tau, ws);
        // a full step that barely moved anything cannot improve further
        let step_inf = ws.dir.iter().fold(0.0f64, |a, d| a.max(d.abs()));
        if t * step_inf <= 1e-15 * (1.0 + eta.iter().fold(b.abs(), |a, e| a.max(e.abs()))) {
            break;
        }
    }
    if let Some(c) = chol {
        *carry = Some(FactorCarry { chol: c, active: prev_active, lam, sigma });
    }
    Ok(res)
}

/// Per-fit pALM-SSN diagnostics (folded into [`KqrFit`] counters and
/// surfaced by the race bench).
#[derive(Clone, Copy, Debug, Default)]
pub struct SsnStats {
    /// Total Newton steps across all outer rounds.
    pub newton_steps: usize,
    /// Outer (multiplier-update) rounds.
    pub outer_rounds: usize,
    /// Full Newton-system refactorizations.
    pub refactors: usize,
    /// Rank-1 factor up/down-dates (maintenance + carry seeding).
    pub updates: usize,
    /// Inner solves whose first factor was seeded from a carried factor
    /// instead of refactorizing (always 0 on the oracle path).
    pub carried: usize,
}

/// Solve one (τ, λ) cell with pALM-SSN, warm-starting from (and leaving
/// the final state in) `state`. The returned [`KqrFit`] carries the same
/// exact objective and KKT certificate as the APGD path; its
/// `apgd_iters` field counts Newton steps and `expansions` counts outer
/// rounds.
pub fn fit_warm_from(
    solver: &KqrSolver,
    tau: f64,
    lam: f64,
    state: &mut SsnState,
) -> Result<KqrFit> {
    let (fit, _) = fit_warm_from_stats(solver, tau, lam, state)?;
    Ok(fit)
}

/// [`fit_warm_from`] returning the pALM-SSN work counters alongside.
/// This is the per-cell **oracle** path: the factor slot starts empty
/// every inner solve and is dropped afterwards, reproducing the
/// original per-cell behavior decision-for-decision.
pub fn fit_warm_from_stats(
    solver: &KqrSolver,
    tau: f64,
    lam: f64,
    state: &mut SsnState,
) -> Result<(KqrFit, SsnStats)> {
    fit_impl(solver, tau, lam, state, false)
}

/// [`fit_warm_from_stats`] with cross-solve **factor carry**: the
/// converged active set and its Cholesky factor persist in
/// [`SsnState::factor`] across outer rounds and across grid cells (the
/// state flows down λ columns and across τ column heads), so each inner
/// solve seeds its Newton system by rank-1 up/downdates over the active
/// set's symmetric difference — plus λ/σ shifts — instead of
/// refactorizing. Iterates may differ from the oracle path in the last
/// bits (the seeded factor is the same matrix up to rounding); both
/// paths certify against the same exact KKT report, and the grid tests
/// pin their objectives together at ≤1e-8.
pub fn fit_warm_from_stats_carried(
    solver: &KqrSolver,
    tau: f64,
    lam: f64,
    state: &mut SsnState,
) -> Result<(KqrFit, SsnStats)> {
    fit_impl(solver, tau, lam, state, true)
}

fn fit_impl(
    solver: &KqrSolver,
    tau: f64,
    lam: f64,
    state: &mut SsnState,
    carry: bool,
) -> Result<(KqrFit, SsnStats)> {
    if !(0.0 < tau && tau < 1.0) {
        bail!("tau must be in (0,1), got {tau}");
    }
    if lam <= 0.0 {
        bail!("lambda must be positive, got {lam}");
    }
    let n = solver.n();
    let dim = solver.basis.dim();
    if state.eta.len() != dim || state.w.len() != n {
        bail!(
            "ssn: state dims (eta {}, w {}) do not match problem (dim {dim}, n {n})",
            state.eta.len(),
            state.w.len()
        );
    }
    let basis = &solver.basis;
    let y = &solver.y;
    let opts = &solver.opts;
    let yscale = crate::linalg::amax(y).max(1.0);
    let band = opts.kkt_band * yscale;
    let sqrt_lam: Vec<f64> = basis.lambda.iter().map(|l| l.max(0.0).sqrt()).collect();

    // a warm σ is kept but damped; multipliers are clamped into the τ box
    if state.sigma <= 0.0 {
        state.sigma = SIGMA_INIT;
    }
    state.retarget(tau);
    if state.sigma <= 0.0 {
        state.sigma = SIGMA_INIT;
    }

    let mut ws = Workspace::new(n, dim);
    let mut apgd_ws = ApgdWorkspace::for_basis(basis);
    let mut stats = SsnStats::default();
    let mut beta = vec![0.0; dim];
    let mut best: Option<(f64, f64, Vec<f64>, KktReport, f64)> = None; // (score, b, eta, kkt, obj)
    let mut prev_obj = f64::INFINITY;
    let mut stall = 0usize;

    // The oracle path runs every inner solve with a fresh, discarded
    // factor slot (per-cell PR behavior); the carry path threads
    // `state.factor` through, so factors survive outer rounds and cells.
    let mut discard: Option<FactorCarry> = None;
    for outer in 0..MAX_OUTER {
        let tol = (1e-2 * 0.1f64.powi(outer as i32)).max(INNER_TOL_FLOOR);
        let slot = if carry { &mut state.factor } else { &mut discard };
        let inner = inner_solve(
            solver,
            &sqrt_lam,
            tau,
            lam,
            state.sigma,
            tol,
            &mut state.b,
            &mut state.eta,
            &state.w,
            slot,
            &mut ws,
        )?;
        if !carry {
            discard = None;
        }
        stats.newton_steps += inner.newton_steps;
        stats.refactors += inner.refactors;
        stats.updates += inner.updates;
        stats.carried += inner.seeded;
        stats.outer_rounds = outer + 1;

        // multiplier update at the final inner point: w⁺ = σ(prox(v) − v)
        for (wi, si) in state.w.iter_mut().zip(&ws.s) {
            *wi = -state.sigma * si;
        }

        // certify with the exact (non-smooth) certificate
        for j in 0..dim {
            beta[j] = if sqrt_lam[j] > 0.0 { state.eta[j] / sqrt_lam[j] } else { 0.0 };
        }
        let report = kkt_check(basis, y, tau, lam, state.b, &beta, opts.kkt_tol, band);
        let obj = apgd::exact_objective(basis, lam, y, tau, state.b, &beta, &mut apgd_ws);
        let score = report.score();
        let improved = best.as_ref().map(|(s, ..)| score < *s).unwrap_or(true);
        if improved {
            best = Some((score, state.b, state.eta.clone(), report.clone(), obj));
        }
        let plateau = (prev_obj - obj).abs() <= 1e-11 * (1.0 + obj.abs());
        prev_obj = obj;
        if report.pass {
            if tol <= INNER_TOL_FLOOR && plateau {
                break;
            }
            stall = if improved { 0 } else { stall + 1 };
            if stall >= MAX_STALL {
                break;
            }
        }
        state.sigma = (state.sigma * SIGMA_GROWTH).min(SIGMA_MAX);
    }

    let (_, best_b, best_eta, kkt, objective) =
        best.expect("ssn: at least one outer round ran");
    for j in 0..dim {
        beta[j] = if sqrt_lam[j] > 0.0 { best_eta[j] / sqrt_lam[j] } else { 0.0 };
    }
    // singular set at the best iterate: points inside the residual band
    let mut fitted = vec![0.0; n];
    basis.fitted(best_b, &beta, &mut ws.scratch, &mut fitted);
    let singular_set: Vec<usize> =
        (0..n).filter(|&i| (y[i] - fitted[i]).abs() <= band).collect();
    let alpha = basis.alpha_from_beta(&beta);
    let lowrank = solver.repr.low_rank().map(|f| f.coef(&beta));
    let rff = solver.repr.rff().map(|f| f.coef(&beta));
    let fit = KqrFit::assemble(
        tau,
        lam,
        best_b,
        alpha,
        objective,
        kkt,
        0.0,
        stats.newton_steps,
        stats.outer_rounds,
        singular_set,
        lowrank,
        rff,
        solver.x.clone(),
        solver.kernel.clone(),
    );
    Ok((fit, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, Rng};
    use crate::kernel::{median_heuristic_sigma, Kernel};

    fn toy_solver(n: usize, seed: u64) -> KqrSolver {
        let mut rng = Rng::new(seed);
        let d = synth::sine_hetero(n, &mut rng);
        let sigma = median_heuristic_sigma(&d.x);
        KqrSolver::new(&d.x, &d.y, Kernel::Rbf { sigma }).unwrap()
    }

    #[test]
    fn ssn_fit_passes_exact_kkt() {
        let solver = toy_solver(24, 3);
        let mut state = SsnState::zeros(solver.n(), solver.basis.dim());
        let fit = fit_warm_from(&solver, 0.5, 0.05, &mut state).unwrap();
        assert!(fit.kkt.pass, "{:?}", fit.kkt);
        assert!(fit.apgd_iters > 0, "Newton steps recorded");
        assert!(fit.expansions > 0, "outer rounds recorded");
    }

    #[test]
    fn ssn_matches_apgd_objective() {
        let solver = toy_solver(30, 7);
        for &(tau, lam) in &[(0.25, 0.1), (0.5, 0.02), (0.9, 0.05)] {
            let apgd_fit = solver.fit(tau, lam).unwrap();
            let mut state = SsnState::zeros(solver.n(), solver.basis.dim());
            let ssn_fit = fit_warm_from(&solver, tau, lam, &mut state).unwrap();
            let gap = (apgd_fit.objective - ssn_fit.objective).abs();
            assert!(
                gap <= 1e-6 * (1.0 + apgd_fit.objective.abs()),
                "tau={tau} lam={lam}: apgd {} vs ssn {} (gap {gap:.3e})",
                apgd_fit.objective,
                ssn_fit.objective
            );
        }
    }

    #[test]
    fn ssn_rejects_bad_inputs() {
        let solver = toy_solver(10, 1);
        let mut state = SsnState::zeros(solver.n(), solver.basis.dim());
        assert!(fit_warm_from(&solver, 0.0, 0.1, &mut state).is_err());
        assert!(fit_warm_from(&solver, 0.5, 0.0, &mut state).is_err());
        let mut short = SsnState::zeros(3, 2);
        assert!(fit_warm_from(&solver, 0.5, 0.1, &mut short).is_err());
    }

    #[test]
    fn carried_fits_match_oracle_with_fewer_refactors() {
        let solver = toy_solver(28, 9);
        let lambdas = [0.1, 0.05, 0.02, 0.01];
        let mut oracle_state = SsnState::zeros(solver.n(), solver.basis.dim());
        let mut carry_state = SsnState::zeros(solver.n(), solver.basis.dim());
        let (mut oracle_refactors, mut carry_refactors) = (0usize, 0usize);
        let mut carry_updates = 0usize;
        for &lam in &lambdas {
            let (fo, so) = fit_warm_from_stats(&solver, 0.5, lam, &mut oracle_state).unwrap();
            let (fc, sc) =
                fit_warm_from_stats_carried(&solver, 0.5, lam, &mut carry_state).unwrap();
            assert!(fc.kkt.pass, "lam={lam}: {:?}", fc.kkt);
            let gap = (fo.objective - fc.objective).abs();
            assert!(
                gap <= 1e-8 * (1.0 + fo.objective.abs()),
                "lam={lam}: oracle {} vs carried {} (gap {gap:.3e})",
                fo.objective,
                fc.objective
            );
            oracle_refactors += so.refactors;
            carry_refactors += sc.refactors;
            carry_updates += sc.updates;
            assert_eq!(so.carried, 0, "oracle path must never seed from a carry");
        }
        assert!(
            carry_refactors < oracle_refactors,
            "carry refactors {carry_refactors} not below oracle {oracle_refactors}"
        );
        assert!(carry_updates > 0, "carry path performed no rank-1 work");
        assert!(carry_state.factor.is_some(), "carry state parks its factor");
        assert!(oracle_state.factor.is_none(), "oracle state must stay carry-free");
    }

    #[test]
    fn warm_state_stays_in_multiplier_box() {
        let solver = toy_solver(20, 5);
        let mut state = SsnState::zeros(solver.n(), solver.basis.dim());
        let tau = 0.3;
        fit_warm_from(&solver, tau, 0.05, &mut state).unwrap();
        let n = solver.n() as f64;
        for &wi in &state.w {
            assert!(
                wi >= -tau / n - 1e-12 && wi <= (1.0 - tau) / n + 1e-12,
                "multiplier {wi} escapes the box"
            );
        }
    }
}
