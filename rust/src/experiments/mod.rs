//! Experiment harnesses: one per table/figure of the paper (DESIGN.md §5).
//!
//! Each harness is callable from both the CLI (`fastkqr table1 …`) and
//! the `cargo bench` targets, prints paper-formatted rows, and returns
//! structured results so integration tests can assert the *shape* of the
//! reproduction (who wins, by what factor) without parsing stdout.
//!
//! Default scales are sized for this single-core container; `--paper`
//! switches to the paper's full (n, p, reps, grid) settings.

pub mod ablations;
pub mod figure1;
pub mod kqr_tables;
pub mod nckqr_tables;
pub mod perf;

/// One (solver, τ/dataset, n) cell of a results table.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub solver: String,
    pub label: String,
    pub n: usize,
    pub obj_mean: f64,
    pub obj_sd: f64,
    pub time_s: f64,
}

impl CellResult {
    pub fn paper_cell(&self) -> String {
        format!("{:.3}({:.3})", self.obj_mean, self.obj_sd)
    }
}

/// Scale configuration shared by the table harnesses.
#[derive(Clone, Debug)]
pub struct TableConfig {
    /// Sample sizes (paper: 200/500/1000).
    pub ns: Vec<usize>,
    /// Dimension (Table 1: 5000, Table 3: 100, Table 4: 2).
    pub p: usize,
    pub taus: Vec<f64>,
    /// λ-path length (paper: 50).
    pub nlam: usize,
    /// CV folds (paper: 5).
    pub folds: usize,
    /// Independent repetitions (paper: 20).
    pub reps: usize,
    /// Solvers to run (subset of fastkqr/ipm/lbfgs/neldermead — the
    /// generic ones are orders of magnitude slower, exactly as in the
    /// paper, so harnesses can drop them at large n like the paper's
    /// ">24h" cells).
    pub solvers: Vec<String>,
    pub seed: u64,
}

impl TableConfig {
    /// Container-scale defaults.
    pub fn quick() -> TableConfig {
        TableConfig {
            ns: vec![100, 200],
            p: 10,
            taus: vec![0.1, 0.5, 0.9],
            nlam: 10,
            folds: 3,
            reps: 3,
            solvers: vec!["fastkqr".into(), "ipm".into(), "lbfgs".into(), "neldermead".into()],
            seed: 2024,
        }
    }

    /// The paper's settings (long-running).
    pub fn paper() -> TableConfig {
        TableConfig {
            ns: vec![200, 500, 1000],
            p: 5000,
            taus: vec![0.1, 0.5, 0.9],
            nlam: 50,
            folds: 5,
            reps: 20,
            ..TableConfig::quick()
        }
    }

    pub fn from_args(args: &crate::util::Args) -> TableConfig {
        let mut cfg = if args.flag("paper") { TableConfig::paper() } else { TableConfig::quick() };
        cfg.ns = args.get_usize_list("ns", &cfg.ns);
        cfg.p = args.get_usize("p", cfg.p);
        cfg.taus = args.get_f64_list("taus", &cfg.taus);
        cfg.nlam = args.get_usize("nlam", cfg.nlam);
        cfg.folds = args.get_usize("folds", cfg.folds);
        cfg.reps = args.get_usize("reps", cfg.reps);
        cfg.seed = args.get_usize("seed", cfg.seed as usize) as u64;
        if let Some(s) = args.get("solvers") {
            cfg.solvers = s.split(',').map(|v| v.trim().to_string()).collect();
        }
        cfg
    }
}

/// Print a block of cells in the paper's (τ, n) × solver layout.
pub fn print_table(title: &str, cells: &[CellResult], solvers: &[String]) {
    println!("\n=== {title} ===");
    let mut widths = vec![8usize, 6, 6];
    for _ in solvers {
        widths.push(22);
    }
    let mut headers = vec!["label", "n", "what"];
    let solver_names: Vec<&str> = solvers.iter().map(String::as_str).collect();
    headers.extend(solver_names.iter());
    let tp = crate::util::bench::TablePrinter::new(&headers, widths);
    // group rows by (label, n)
    let mut keys: Vec<(String, usize)> = Vec::new();
    for c in cells {
        let k = (c.label.clone(), c.n);
        if !keys.contains(&k) {
            keys.push(k);
        }
    }
    for (label, n) in keys {
        let row_cells: Vec<&CellResult> = cells
            .iter()
            .filter(|c| c.label == label && c.n == n)
            .collect();
        let find = |s: &str| row_cells.iter().find(|c| c.solver == s);
        let mut obj_row = vec![label.clone(), n.to_string(), "obj".to_string()];
        let mut time_row = vec![String::new(), String::new(), "time".to_string()];
        for s in solvers {
            match find(s) {
                Some(c) => {
                    obj_row.push(c.paper_cell());
                    time_row.push(format!("{:.2}s", c.time_s));
                }
                None => {
                    obj_row.push("*".to_string());
                    time_row.push("*".to_string());
                }
            }
        }
        tp.row(&obj_row.iter().map(String::as_str).collect::<Vec<_>>());
        tp.row(&time_row.iter().map(String::as_str).collect::<Vec<_>>());
    }
}

/// Speedup of fastkqr over each other solver, per (label, n) group —
/// the headline numbers the integration tests assert on.
pub fn speedups(cells: &[CellResult]) -> Vec<(String, usize, String, f64)> {
    let mut out = Vec::new();
    for c in cells {
        if c.solver == "fastkqr" {
            continue;
        }
        if let Some(fast) = cells
            .iter()
            .find(|f| f.solver == "fastkqr" && f.label == c.label && f.n == c.n)
        {
            if fast.time_s > 0.0 {
                out.push((c.label.clone(), c.n, c.solver.clone(), c.time_s / fast.time_s));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_from_args_overrides() {
        let args = crate::util::Args::parse(
            ["--ns", "50", "--reps", "2", "--solvers", "fastkqr,ipm"]
                .iter()
                .map(|s| s.to_string()),
        );
        let cfg = TableConfig::from_args(&args);
        assert_eq!(cfg.ns, vec![50]);
        assert_eq!(cfg.reps, 2);
        assert_eq!(cfg.solvers, vec!["fastkqr", "ipm"]);
    }

    #[test]
    fn speedup_computation() {
        let cells = vec![
            CellResult {
                solver: "fastkqr".into(),
                label: "t".into(),
                n: 10,
                obj_mean: 1.0,
                obj_sd: 0.0,
                time_s: 2.0,
            },
            CellResult {
                solver: "ipm".into(),
                label: "t".into(),
                n: 10,
                obj_mean: 1.0,
                obj_sd: 0.0,
                time_s: 20.0,
            },
        ];
        let s = speedups(&cells);
        assert_eq!(s.len(), 1);
        assert!((s[0].3 - 10.0).abs() < 1e-12);
    }
}
