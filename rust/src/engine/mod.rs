//! The fit engine: a shared, cached, parallel solve layer.
//!
//! Everything above the raw solvers goes through this subsystem:
//!
//! - [`GramCache`] (in [`cache`]): content-fingerprinted, `Arc`-shared
//!   memoization of (dataset, kernel) → (Gram, [`SpectralBasis`]) with
//!   concurrency coalescing — the O(n³) eigendecomposition runs exactly
//!   once per fingerprint per process, no matter how many CV folds,
//!   τ-grid columns or concurrent coordinator jobs ask for it.
//! - [`FitEngine`]: hands out [`KqrSolver`]s backed by the cache, owns
//!   the [`Parallelism`] budget that bounds total concurrency, and
//!   provides [`FitEngine::fit_grid`] — a batched τ × λ grid on one
//!   basis with warm starts in both directions (λ descending within a
//!   column, τ-adjacent columns seeding each other).
//! - [`lockstep`]: the BLAS-3 grid driver behind `FASTKQR_LOCKSTEP` /
//!   [`EngineConfig::lockstep`] — all ready cells of the warm-start
//!   wavefront advance together as a cell-major bundle (two GEMMs per
//!   iteration for the whole bundle; converged cells retire via
//!   swap-remove repacking), with the sequential path kept as the
//!   bitwise parity oracle.
//! - [`ssn_grid`]: the SSN mirror of the lockstep idea — in-flight cells
//!   batch their n×dim products through grid-wide GEMMs and pool their
//!   Newton factorizations (one leader factor per (λ, σ) group,
//!   per-cell RHS, rank-1 reconciliation for near-identical active
//!   sets). The sequential SSN path carries the active-set Cholesky
//!   factor cell-to-cell instead
//!   ([`crate::solver::fit_tau_columns_ssn_carry`]), and the per-cell
//!   PR 8 path survives as the ≤1e-8 parity oracle.
//! - [`predict`]: the serving-side counterpart — [`PredictPlan`]s compile
//!   a fitted model once (resolved kernel, `Arc`'d train-row/landmark
//!   block or random-feature map, coefficients packed into one matrix) so
//!   every predict request is one design build + one multi-RHS GEMM, and
//!   `predict_many` stacks
//!   concurrent requests for the coordinator's micro-batcher with
//!   bitwise-identical per-request rows.
//!
//! Consumers: `cv::cross_validate` runs folds on the engine,
//! `coordinator::scheduler` workers share one engine (concurrent jobs on
//! the same dataset share one cached basis), and the TCP server fits
//! through the engine so identical payloads from different connections
//! never re-decompose.
//!
//! [`SpectralBasis`]: crate::spectral::SpectralBasis

pub mod cache;
pub mod lockstep;
pub mod predict;
pub mod ssn_grid;

pub use cache::{
    fingerprint, fingerprint_approx, ApproxSpec, BasisEntry, CacheMetrics, Fingerprint, GramCache,
};
pub use lockstep::LockstepStats;
pub use predict::{PlanGroup, PredictPlan};

use crate::backend::NativeBackend;
use crate::data::Dataset;
use crate::kernel::Kernel;
use crate::kqr::apgd::ApgdState;
use crate::kqr::{KqrFit, KqrSolver, SolveOptions};
use crate::linalg::par::{self, Parallelism};
use crate::linalg::Matrix;
use crate::nckqr::{NcOptions, NckqrSolver};
use crate::solver::{self, SolverBackend};
use crate::util::panic_message;
use anyhow::{anyhow, ensure, Result};
use std::sync::{Arc, OnceLock};

/// Engine construction knobs.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Concurrency budget: bounds fold/grid fan-out and (via the global
    /// linalg configuration) intra-op GEMV parallelism.
    pub par: Parallelism,
    /// Max cached factorizations (each O(n²) memory).
    pub cache_capacity: usize,
    /// Default solver options for engine-issued solvers.
    pub opts: SolveOptions,
    /// Grid solve strategy: `Some(true)` forces the BLAS-3 lockstep
    /// driver, `Some(false)` the sequential per-cell path, `None` defers
    /// to the `FASTKQR_LOCKSTEP` environment switch (default: off).
    pub lockstep: Option<bool>,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig {
            par: par::global(),
            cache_capacity: 16,
            opts: SolveOptions::default(),
            lockstep: None,
        }
    }
}

/// The `FASTKQR_LOCKSTEP` switch, read once per process: "1"/"true"/"on"
/// enable the lockstep grid driver for engines that don't override it.
fn env_lockstep() -> bool {
    static ENV: OnceLock<bool> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("FASTKQR_LOCKSTEP")
            .map(|v| matches!(v.trim().to_ascii_lowercase().as_str(), "1" | "true" | "on"))
            .unwrap_or(false)
    })
}

/// Shared, cached, parallel solve layer (see module docs).
pub struct FitEngine {
    pub cache: GramCache,
    pub config: EngineConfig,
}

impl Default for FitEngine {
    fn default() -> Self {
        FitEngine::new()
    }
}

impl FitEngine {
    pub fn new() -> FitEngine {
        FitEngine::with_config(EngineConfig::default())
    }

    pub fn with_config(config: EngineConfig) -> FitEngine {
        FitEngine { cache: GramCache::new(config.cache_capacity), config }
    }

    /// The process-wide shared engine: every consumer that does not
    /// construct its own engine (CV convenience wrapper, server, CLI)
    /// funnels through this one, which is what makes "one
    /// eigendecomposition per (dataset, kernel) per process" hold across
    /// subsystems.
    pub fn global() -> &'static Arc<FitEngine> {
        static GLOBAL: OnceLock<Arc<FitEngine>> = OnceLock::new();
        GLOBAL.get_or_init(|| Arc::new(FitEngine::new()))
    }

    /// A solver for this exact (dataset, kernel), backed by the cached
    /// Gram matrix + eigenbasis (computed on first use), with the
    /// engine's default options. Errors when the kernel matrix is not
    /// PSD (see [`crate::spectral::SpectralBasis::new`]).
    pub fn solver(&self, x: &Matrix, y: &[f64], kernel: &Kernel) -> Result<KqrSolver> {
        self.solver_with_options(x, y, kernel, self.config.opts.clone())
    }

    /// [`FitEngine::solver`] with explicit solve options.
    pub fn solver_with_options(
        &self,
        x: &Matrix,
        y: &[f64],
        kernel: &Kernel,
        opts: SolveOptions,
    ) -> Result<KqrSolver> {
        self.solver_approx(x, y, kernel, ApproxSpec::Exact, opts)
    }

    /// A solver on an explicit Gram representation: `ApproxSpec::Exact`
    /// is the dense cached path (bitwise-identical to
    /// [`FitEngine::solver`]); `ApproxSpec::Nystrom` serves the rank-m
    /// thin factor and `ApproxSpec::RandomFeatures` the D-dimensional
    /// random Fourier basis from the same cache — exact and approximate
    /// entries for one dataset coexist under distinct fingerprints.
    pub fn solver_approx(
        &self,
        x: &Matrix,
        y: &[f64],
        kernel: &Kernel,
        approx: ApproxSpec,
        opts: SolveOptions,
    ) -> Result<KqrSolver> {
        let entry = self.cache.get_or_compute_approx(x, y, kernel, approx)?;
        Ok(KqrSolver::with_repr_arc(entry.x.clone(), y, kernel.clone(), entry.repr.clone())
            .with_options(opts))
    }

    /// Convenience overload for [`Dataset`] holders.
    pub fn solver_for(&self, data: &Dataset, kernel: &Kernel) -> Result<KqrSolver> {
        self.solver(&data.x, &data.y, kernel)
    }

    /// A non-crossing solver for this exact (dataset, kernel), backed by
    /// the same cached Gram/eigenbasis the KQR solvers share — an NCKQR
    /// fit after (or concurrent with) any other fit on the same data
    /// costs zero additional eigendecompositions.
    pub fn nc_solver(
        &self,
        x: &Matrix,
        y: &[f64],
        kernel: &Kernel,
        taus: &[f64],
    ) -> Result<NckqrSolver> {
        self.nc_solver_approx(x, y, kernel, taus, ApproxSpec::Exact)
    }

    /// [`FitEngine::nc_solver`] on an explicit Gram representation.
    pub fn nc_solver_approx(
        &self,
        x: &Matrix,
        y: &[f64],
        kernel: &Kernel,
        taus: &[f64],
        approx: ApproxSpec,
    ) -> Result<NckqrSolver> {
        // Validate the τ grid before paying for (or caching) a Gram
        // matrix the request can never use.
        crate::nckqr::normalize_taus(taus)?;
        let entry = self.cache.get_or_compute_approx(x, y, kernel, approx)?;
        NckqrSolver::with_repr_arc(entry.x.clone(), y, kernel.clone(), taus, entry.repr.clone())
    }

    /// [`FitEngine::nc_solver`] with explicit NCKQR options.
    pub fn nc_solver_with_options(
        &self,
        x: &Matrix,
        y: &[f64],
        kernel: &Kernel,
        taus: &[f64],
        opts: NcOptions,
    ) -> Result<NckqrSolver> {
        Ok(self.nc_solver(x, y, kernel, taus)?.with_options(opts))
    }

    /// [`FitEngine::nc_solver_approx`] with explicit NCKQR options.
    pub fn nc_solver_approx_with_options(
        &self,
        x: &Matrix,
        y: &[f64],
        kernel: &Kernel,
        taus: &[f64],
        approx: ApproxSpec,
        opts: NcOptions,
    ) -> Result<NckqrSolver> {
        Ok(self.nc_solver_approx(x, y, kernel, taus, approx)?.with_options(opts))
    }

    /// Is the lockstep grid driver enabled for this engine?
    pub fn lockstep_enabled(&self) -> bool {
        self.config.lockstep.unwrap_or_else(env_lockstep)
    }

    /// Fit the full τ × λ grid on **one** cached eigenbasis.
    ///
    /// Two strategies, selected by [`EngineConfig::lockstep`] /
    /// `FASTKQR_LOCKSTEP`:
    ///
    /// - **Sequential (default, the parity oracle).** Within each τ
    ///   column the λ path is warm-started downward exactly like
    ///   `KqrSolver::fit_path` (iterate + γ-ladder position carry over,
    ///   §2.4). Across columns, each τ seeds its first (largest-λ) fit
    ///   from the previous τ's largest-λ solution. When the engine has
    ///   >1 thread and several columns, the τ columns are chunked onto
    ///   scoped threads (cross-column seeding then applies within each
    ///   chunk) and each worker runs its solves with intra-op parallelism
    ///   disabled to avoid oversubscription.
    /// - **Lockstep (BLAS-3).** [`lockstep`] advances every ready cell of
    ///   the same warm-start wavefront together, so one bundle iteration
    ///   costs two GEMMs against U instead of two GEMVs per cell. With
    ///   serial GEMV kernels on the oracle side (always the case for a
    ///   multi-column grid on a threaded engine, and for any grid inside
    ///   a serial scope) the per-cell fits are bitwise identical to the
    ///   single-worker sequential path.
    ///
    /// Returns fits indexed `[tau][lambda]`, matching the input orders.
    pub fn fit_grid(
        &self,
        x: &Matrix,
        y: &[f64],
        kernel: &Kernel,
        taus: &[f64],
        lambdas: &[f64],
    ) -> Result<GridFit> {
        self.fit_grid_with_strategy(x, y, kernel, taus, lambdas, ApproxSpec::Exact, None, None)
    }

    /// [`FitEngine::fit_grid`] with per-call overrides: `approx` selects
    /// the Gram representation (`Exact`, a rank-m Nyström thin factor, or
    /// a D-dimensional random-feature basis — the sequential and lockstep
    /// drivers run unchanged on any of them),
    /// `lockstep` `Some(true)`/`Some(false)` forces the lockstep /
    /// sequential driver for this grid only (`None` defers to the engine
    /// configuration, which in turn defers to `FASTKQR_LOCKSTEP`), and
    /// `opts` replaces the engine's default solve options. This is the
    /// hook the [`crate::api::FitSpec`] hints ride on.
    #[allow(clippy::too_many_arguments)]
    pub fn fit_grid_with_strategy(
        &self,
        x: &Matrix,
        y: &[f64],
        kernel: &Kernel,
        taus: &[f64],
        lambdas: &[f64],
        approx: ApproxSpec,
        lockstep: Option<bool>,
        opts: Option<SolveOptions>,
    ) -> Result<GridFit> {
        self.fit_grid_with_solver(
            x,
            y,
            kernel,
            taus,
            lambdas,
            approx,
            lockstep,
            opts,
            SolverBackend::Apgd,
        )
    }

    /// [`FitEngine::fit_grid_with_strategy`] with an explicit solver
    /// backend. `Auto` resolves here via [`solver::auto_select`] from
    /// (n, basis rank, grid size) — a pure function of the problem, so
    /// the same spec picks the same backend on any machine.
    ///
    /// Both backends honor the `lockstep` hint: APGD dispatches to the
    /// bitwise-parity [`lockstep`] wavefront, SSN to the bundled
    /// [`ssn_grid`] driver (shared factorizations, batched GEMMs, ≤1e-8
    /// parity). With the hint off, APGD runs the sequential columns and
    /// SSN the sequential **factor-carry** columns
    /// ([`solver::fit_tau_columns_ssn_carry`]); either way an SSN grid
    /// reports its factor-reuse accounting in [`GridFit::ssn`] and
    /// `GridFit::lockstep` stays `None` (that field is APGD bundle
    /// accounting).
    #[allow(clippy::too_many_arguments)]
    pub fn fit_grid_with_solver(
        &self,
        x: &Matrix,
        y: &[f64],
        kernel: &Kernel,
        taus: &[f64],
        lambdas: &[f64],
        approx: ApproxSpec,
        lockstep: Option<bool>,
        opts: Option<SolveOptions>,
        backend: SolverBackend,
    ) -> Result<GridFit> {
        ensure!(!taus.is_empty(), "fit_grid: empty tau grid");
        ensure!(!lambdas.is_empty(), "fit_grid: empty lambda grid");
        let opts = opts.unwrap_or_else(|| self.config.opts.clone());
        let solver = self.solver_approx(x, y, kernel, approx, opts)?;
        let backend = match backend {
            SolverBackend::Auto => {
                solver::auto_select(y.len(), solver.state_dim(), taus.len() * lambdas.len())
            }
            concrete => concrete,
        };
        let bundle = lockstep.unwrap_or_else(|| self.lockstep_enabled());
        if backend == SolverBackend::Apgd && bundle {
            let (fits, stats) = lockstep::fit_grid_lockstep(self, &solver, taus, lambdas)?;
            return Ok(GridFit {
                taus: taus.to_vec(),
                lambdas: lambdas.to_vec(),
                fits,
                lockstep: Some(stats),
                ssn: None,
                solver: SolverBackend::Apgd,
            });
        }
        if backend == SolverBackend::Ssn && bundle {
            let (fits, stats) = ssn_grid::fit_grid_ssn_bundled(self, &solver, taus, lambdas)?;
            return Ok(GridFit {
                taus: taus.to_vec(),
                lambdas: lambdas.to_vec(),
                fits,
                lockstep: None,
                ssn: Some(stats),
                solver: SolverBackend::Ssn,
            });
        }
        // Inside an outer serial scope (e.g. a scheduler worker) the grid
        // must not fan out — the outer level owns the parallelism.
        let workers = if par::in_serial_scope() {
            1
        } else {
            self.config.par.threads.min(taus.len()).max(1)
        };
        if backend == SolverBackend::Ssn {
            let (fits, stats) = ssn_carry_tau_columns(&solver, taus, lambdas, workers)?;
            return Ok(GridFit {
                taus: taus.to_vec(),
                lambdas: lambdas.to_vec(),
                fits,
                lockstep: None,
                ssn: Some(stats),
                solver: SolverBackend::Ssn,
            });
        }
        let fits = chunked_tau_columns(&solver, taus, lambdas, workers, fit_tau_columns)?;
        Ok(GridFit {
            taus: taus.to_vec(),
            lambdas: lambdas.to_vec(),
            fits,
            lockstep: None,
            ssn: None,
            solver: backend,
        })
    }
}

/// A sequential multi-column grid driver (the APGD column shape; the
/// SSN carry columns thread factor-reuse stats and go through
/// [`ssn_carry_tau_columns`] instead).
type ColumnDriver = fn(&KqrSolver, &[f64], &[f64]) -> Result<Vec<Vec<KqrFit>>>;

/// The SSN mirror of [`chunked_tau_columns`]: τ columns chunked onto
/// scoped threads, each chunk running the sequential **factor-carry**
/// columns ([`solver::fit_tau_columns_ssn_carry`]) in a serial scope,
/// with per-chunk [`solver::SsnGridStats`] merged into one grid total.
fn ssn_carry_tau_columns(
    solver: &KqrSolver,
    taus: &[f64],
    lambdas: &[f64],
    workers: usize,
) -> Result<(Vec<Vec<KqrFit>>, solver::SsnGridStats)> {
    if workers <= 1 || taus.len() <= 1 {
        return solver::fit_tau_columns_ssn_carry(solver, taus, lambdas);
    }
    let chunk = (taus.len() + workers - 1) / workers;
    let chunk_results: Vec<Result<(Vec<Vec<KqrFit>>, solver::SsnGridStats)>> =
        std::thread::scope(|s| {
            let handles: Vec<_> = taus
                .chunks(chunk)
                .map(|tau_chunk| {
                    s.spawn(move || {
                        par::serial_scope(|| {
                            solver::fit_tau_columns_ssn_carry(solver, tau_chunk, lambdas)
                        })
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|p| {
                        Err(anyhow!("fit_grid worker panicked: {}", panic_message(&p)))
                    })
                })
                .collect()
        });
    let mut all = Vec::with_capacity(taus.len());
    let mut stats = solver::SsnGridStats::default();
    for r in chunk_results {
        let (fits, s) = r?;
        stats.merge(&s);
        all.extend(fits);
    }
    Ok((all, stats))
}

/// Run `fit_cols` over the τ axis, chunked onto scoped threads when the
/// engine has spare workers (cross-column warm-start seeding then
/// applies within each chunk); each worker runs with intra-op
/// parallelism disabled to avoid oversubscription.
fn chunked_tau_columns(
    solver: &KqrSolver,
    taus: &[f64],
    lambdas: &[f64],
    workers: usize,
    fit_cols: ColumnDriver,
) -> Result<Vec<Vec<KqrFit>>> {
    if workers <= 1 || taus.len() <= 1 {
        return fit_cols(solver, taus, lambdas);
    }
    let chunk = (taus.len() + workers - 1) / workers;
    let chunk_results: Vec<Result<Vec<Vec<KqrFit>>>> = std::thread::scope(|s| {
        let handles: Vec<_> = taus
            .chunks(chunk)
            .map(|tau_chunk| {
                s.spawn(move || par::serial_scope(|| fit_cols(solver, tau_chunk, lambdas)))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                // A poisoned worker must not abort a process that
                // is serving other jobs: surface the panic as an
                // error on this grid only.
                h.join().unwrap_or_else(|p| {
                    Err(anyhow!("fit_grid worker panicked: {}", panic_message(&p)))
                })
            })
            .collect()
    });
    let mut all = Vec::with_capacity(taus.len());
    for r in chunk_results {
        all.extend(r?);
    }
    Ok(all)
}

/// Fit a run of τ columns serially, seeding each column's largest-λ fit
/// from its predecessor's.
fn fit_tau_columns(
    solver: &KqrSolver,
    taus: &[f64],
    lambdas: &[f64],
) -> Result<Vec<Vec<KqrFit>>> {
    let mut cols = Vec::with_capacity(taus.len());
    let mut seed: Option<ApgdState> = None;
    for &tau in taus {
        let col = fit_tau_column(solver, tau, lambdas, seed.take())?;
        let head = &col[0];
        seed = Some(ApgdState::from_solution(
            head.b,
            &solver.basis.beta_from_alpha(&head.alpha),
        ));
        cols.push(col);
    }
    Ok(cols)
}

/// One warm-started descending-λ column, optionally seeded from an
/// adjacent τ's iterate.
fn fit_tau_column(
    solver: &KqrSolver,
    tau: f64,
    lambdas: &[f64],
    seed: Option<ApgdState>,
) -> Result<Vec<KqrFit>> {
    let mut backend = NativeBackend::new();
    let mut state = seed.unwrap_or_else(|| ApgdState::zeros(solver.state_dim()));
    let mut gamma_start = solver.opts.gamma_init;
    let mut fits = Vec::with_capacity(lambdas.len());
    for &lam in lambdas {
        let fit = solver.fit_warm_from(tau, lam, &mut state, &mut backend, gamma_start)?;
        gamma_start = (fit.gamma_final / solver.opts.gamma_shrink)
            .min(solver.opts.gamma_init)
            .max(solver.opts.gamma_min);
        fits.push(fit);
    }
    Ok(fits)
}

/// Result of [`FitEngine::fit_grid`]: fits indexed `[tau][lambda]`.
#[derive(Clone, Debug)]
pub struct GridFit {
    pub taus: Vec<f64>,
    pub lambdas: Vec<f64>,
    pub fits: Vec<Vec<KqrFit>>,
    /// Bundle accounting when the APGD lockstep driver produced this
    /// grid (`None` for the sequential path and for SSN grids).
    pub lockstep: Option<LockstepStats>,
    /// Factor-reuse accounting when the SSN backend produced this grid
    /// (carry columns or the bundled driver); `None` for APGD.
    pub ssn: Option<solver::SsnGridStats>,
    /// Which backend actually fitted the cells — always concrete
    /// (`Auto` resolves before fitting starts).
    pub solver: SolverBackend,
}

impl GridFit {
    /// The fit at (τ index, λ index).
    pub fn at(&self, ti: usize, li: usize) -> &KqrFit {
        &self.fits[ti][li]
    }

    /// Total APGD iterations across the grid (warm-start accounting).
    pub fn total_iters(&self) -> usize {
        self.fits.iter().flatten().map(|f| f.apgd_iters).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::data::Rng;
    use crate::kernel::median_heuristic_sigma;

    fn fixture(n: usize, seed: u64) -> (Dataset, Kernel) {
        let mut rng = Rng::new(seed);
        let data = synth::sine_hetero(n, &mut rng);
        let sigma = median_heuristic_sigma(&data.x);
        (data, Kernel::Rbf { sigma })
    }

    #[test]
    fn solver_reuses_cached_basis() {
        let engine = FitEngine::new();
        let (data, kernel) = fixture(30, 1);
        let s1 = engine.solver_for(&data, &kernel).unwrap();
        let s2 = engine.solver_for(&data, &kernel).unwrap();
        assert!(Arc::ptr_eq(&s1.basis, &s2.basis));
        assert_eq!(CacheMetrics::get(&engine.cache.metrics.decompositions), 1);
        // the cached solver fits exactly like a fresh one
        let fresh = KqrSolver::new(&data.x, &data.y, kernel.clone()).unwrap();
        let a = s1.fit(0.5, 0.01).unwrap();
        let b = fresh.fit(0.5, 0.01).unwrap();
        assert!((a.objective - b.objective).abs() < 1e-12);
    }

    #[test]
    fn fit_grid_matches_cold_fits_on_one_basis() {
        let engine = FitEngine::with_config(EngineConfig {
            par: Parallelism::with_threads(2),
            ..EngineConfig::default()
        });
        let (data, kernel) = fixture(40, 2);
        let taus = [0.25, 0.5, 0.75];
        let lambdas = [0.1, 0.01];
        let grid = engine.fit_grid(&data.x, &data.y, &kernel, &taus, &lambdas).unwrap();
        assert_eq!(grid.fits.len(), 3);
        assert_eq!(grid.fits[0].len(), 2);
        assert_eq!(
            CacheMetrics::get(&engine.cache.metrics.decompositions),
            1,
            "a grid is one basis"
        );
        let cold = KqrSolver::new(&data.x, &data.y, kernel.clone()).unwrap();
        for (ti, &tau) in taus.iter().enumerate() {
            for (li, &lam) in lambdas.iter().enumerate() {
                let warm = grid.at(ti, li);
                assert_eq!(warm.tau, tau);
                assert_eq!(warm.lam, lam);
                let reference = cold.fit(tau, lam).unwrap();
                assert!(
                    (warm.objective - reference.objective).abs()
                        < 1e-5 * (1.0 + reference.objective.abs()),
                    "tau={tau} lam={lam}: warm {} vs cold {}",
                    warm.objective,
                    reference.objective
                );
            }
        }
    }

    #[test]
    fn fit_grid_serial_engine_also_works() {
        let engine = FitEngine::with_config(EngineConfig {
            par: Parallelism::serial(),
            ..EngineConfig::default()
        });
        let (data, kernel) = fixture(25, 3);
        let grid = engine
            .fit_grid(&data.x, &data.y, &kernel, &[0.3, 0.7], &[0.05])
            .unwrap();
        assert!(grid.fits.iter().flatten().all(|f| f.kkt.pass));
        assert!(grid.total_iters() > 0);
    }

    #[test]
    fn fit_grid_rejects_empty_axes() {
        let engine = FitEngine::new();
        let (data, kernel) = fixture(10, 4);
        assert!(engine.fit_grid(&data.x, &data.y, &kernel, &[], &[0.1]).is_err());
        assert!(engine.fit_grid(&data.x, &data.y, &kernel, &[0.5], &[]).is_err());
    }

    #[test]
    fn lockstep_switch_dispatches_and_agrees() {
        let (data, kernel) = fixture(30, 5);
        let taus = [0.3, 0.7];
        let lambdas = [0.1, 0.01];
        let seq_engine = FitEngine::with_config(EngineConfig {
            par: Parallelism::serial(),
            lockstep: Some(false),
            ..EngineConfig::default()
        });
        let seq = seq_engine.fit_grid(&data.x, &data.y, &kernel, &taus, &lambdas).unwrap();
        assert!(seq.lockstep.is_none());
        let lock_engine = FitEngine::with_config(EngineConfig {
            par: Parallelism::serial(),
            lockstep: Some(true),
            ..EngineConfig::default()
        });
        let lock = lock_engine.fit_grid(&data.x, &data.y, &kernel, &taus, &lambdas).unwrap();
        let stats = lock.lockstep.expect("lockstep stats present");
        assert_eq!(stats.cells, 4);
        assert_eq!(stats.retired, 4);
        assert!(stats.max_active >= 1 && stats.chunks > 0);
        // deep parity is pinned down in tests/lockstep.rs; smoke it here
        for ti in 0..taus.len() {
            for li in 0..lambdas.len() {
                assert_eq!(lock.at(ti, li).b, seq.at(ti, li).b, "({ti},{li})");
            }
        }
    }

    #[test]
    fn ssn_grid_backend_matches_apgd_and_records_itself() {
        let engine = FitEngine::with_config(EngineConfig {
            par: Parallelism::with_threads(2),
            ..EngineConfig::default()
        });
        let (data, kernel) = fixture(30, 7);
        let taus = [0.3, 0.7];
        let lambdas = [0.1, 0.01];
        let apgd = engine
            .fit_grid_with_solver(
                &data.x,
                &data.y,
                &kernel,
                &taus,
                &lambdas,
                ApproxSpec::Exact,
                Some(false),
                None,
                crate::solver::SolverBackend::Apgd,
            )
            .unwrap();
        assert_eq!(apgd.solver, crate::solver::SolverBackend::Apgd);
        // lockstep hint on → the bundled shared-factorization driver
        let ssn = engine
            .fit_grid_with_solver(
                &data.x,
                &data.y,
                &kernel,
                &taus,
                &lambdas,
                ApproxSpec::Exact,
                Some(true),
                None,
                crate::solver::SolverBackend::Ssn,
            )
            .unwrap();
        assert_eq!(ssn.solver, crate::solver::SolverBackend::Ssn);
        assert!(ssn.lockstep.is_none(), "lockstep field is APGD accounting");
        let bstats = ssn.ssn.expect("bundled SSN grid reports factor stats");
        assert_eq!(bstats.cells, taus.len() * lambdas.len());
        assert!(bstats.rank1_updates > 0, "bundle did no rank-1 factor work");
        // hint off → the sequential factor-carry columns, same stats shape
        let carry = engine
            .fit_grid_with_solver(
                &data.x,
                &data.y,
                &kernel,
                &taus,
                &lambdas,
                ApproxSpec::Exact,
                Some(false),
                None,
                crate::solver::SolverBackend::Ssn,
            )
            .unwrap();
        let cstats = carry.ssn.expect("carry SSN grid reports factor stats");
        assert_eq!(cstats.cells, taus.len() * lambdas.len());
        assert_eq!(cstats.bundles, 0, "carry columns form no bundles");
        assert!(apgd.ssn.is_none(), "APGD grids carry no SSN stats");
        for ti in 0..taus.len() {
            for li in 0..lambdas.len() {
                let (a, s, c) = (apgd.at(ti, li), ssn.at(ti, li), carry.at(ti, li));
                assert!(s.kkt.pass, "({ti},{li}): {:?}", s.kkt);
                assert!(
                    (a.objective - s.objective).abs() < 1e-6 * (1.0 + a.objective.abs()),
                    "({ti},{li}): apgd {} vs ssn {}",
                    a.objective,
                    s.objective
                );
                assert!(
                    (c.objective - s.objective).abs() < 1e-8 * (1.0 + c.objective.abs()),
                    "({ti},{li}): carry {} vs bundled {}",
                    c.objective,
                    s.objective
                );
            }
        }
    }

    #[test]
    fn nc_solver_shares_cached_basis_with_kqr() {
        let engine = FitEngine::new();
        let (data, kernel) = fixture(25, 6);
        let s = engine.solver_for(&data, &kernel).unwrap();
        let nc = engine.nc_solver(&data.x, &data.y, &kernel, &[0.25, 0.75]).unwrap();
        assert!(Arc::ptr_eq(&s.basis, &nc.basis), "KQR and NCKQR share one basis");
        assert_eq!(CacheMetrics::get(&engine.cache.metrics.decompositions), 1);
        // repeated NC solver construction is pure cache hits
        let _ = engine.nc_solver(&data.x, &data.y, &kernel, &[0.1, 0.9]).unwrap();
        assert_eq!(CacheMetrics::get(&engine.cache.metrics.decompositions), 1);
    }

    #[test]
    fn non_psd_kernel_surfaces_as_error_not_panic() {
        // A linear kernel with a negative offset produces an indefinite
        // "Gram" matrix; the engine must refuse it loudly.
        let engine = FitEngine::new();
        let x = Matrix::from_fn(6, 1, |i, _| i as f64);
        let y = vec![0.0; 6];
        let bad = Kernel::Linear { c: -100.0 };
        let err = engine.solver(&x, &y, &bad).map(|_| ()).unwrap_err();
        assert!(err.to_string().contains("not PSD"), "got: {err}");
        // and the cached error does not re-decompose
        let before = CacheMetrics::get(&engine.cache.metrics.decompositions);
        assert!(engine.solver(&x, &y, &bad).is_err());
        assert_eq!(CacheMetrics::get(&engine.cache.metrics.decompositions), before);
    }
}
