//! Consistent-hash front for multi-replica serving.
//!
//! A [`Router`] listens on one client-facing port and fans requests out
//! to N replica servers, picking the replica by **consistent-hashing the
//! model id** ([`HashRing`]). Model-addressed commands (`predict`,
//! `save`, `export`, `drop` via `"model"`, `load` via `"name"`) always
//! land on the same replica for a given id, so each replica's
//! [`PredictBatcher`](super::batcher::PredictBatcher) sees *all* of one
//! model's traffic — the micro-batching win multiplies per replica
//! instead of diluting. Commands with no model key (`fit`, `ping`,
//! `metrics`, `models`) round-robin.
//!
//! Replicas share one persistence directory. A fit lands on one replica
//! and is written through; the registry bumps `manifest.json`'s
//! generation counter, and every other replica's manifest poller
//! hot-swaps the new artifact in (see
//! [`ModelRegistry::refresh`](super::registry::ModelRegistry::refresh)).
//! The router itself is stateless — it never parses model payloads, only
//! peeks at the routing key and passes response lines through verbatim
//! (bitwise, which keeps the parity oracle meaningful end to end).
//!
//! The ring hashes `"{label}#{vnode}"` for [`DEFAULT_VNODES`] virtual
//! nodes per replica, FNV-1a finalized with the splitmix64 mixer (plain
//! FNV clusters badly on strings sharing long prefixes — vnode labels —
//! which skews ownership; the mixer restores uniformity). Adding or
//! removing a replica moves only ~1/N of the key space.

use super::metrics::Metrics;
use crate::util::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Virtual nodes per replica on the ring. 64 keeps the ownership split
/// within a few percent of even for small N while the ring stays tiny
/// (N×64 points, binary-searched).
pub const DEFAULT_VNODES: usize = 64;

/// FNV-1a 64-bit, finalized with the splitmix64 mixer. FNV alone is fast
/// but clusters inputs that differ only near the end (exactly our
/// `"addr#k"` vnode labels and `"m0"`/`"m1"` model ids); the mixer's
/// avalanche spreads them uniformly over the ring.
pub fn hash64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    // splitmix64 finalizer
    let mut z = h;
    z ^= z >> 30;
    z = z.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z ^= z >> 27;
    z = z.wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    z
}

/// A consistent-hash ring over replica labels. Deterministic: the
/// mapping from key to label depends only on the *set* of labels (and
/// vnode count), never on insertion order or process state.
pub struct HashRing {
    /// `(point, label index)`, sorted by point.
    points: Vec<(u64, usize)>,
    labels: Vec<String>,
}

impl HashRing {
    pub fn new(labels: &[String], vnodes: usize) -> HashRing {
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(labels.len() * vnodes);
        for (i, label) in labels.iter().enumerate() {
            for v in 0..vnodes {
                points.push((hash64(&format!("{label}#{v}")), i));
            }
        }
        points.sort_unstable();
        HashRing { points, labels: labels.to_vec() }
    }

    /// Index of the replica owning `key`: the first ring point at or
    /// after `hash64(key)`, wrapping at the top.
    pub fn route(&self, key: &str) -> usize {
        debug_assert!(!self.points.is_empty());
        let h = hash64(key);
        let idx = self.points.partition_point(|&(p, _)| p < h);
        let (_, owner) = self.points[idx % self.points.len()];
        owner
    }

    pub fn label(&self, idx: usize) -> &str {
        &self.labels[idx]
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
}

#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Client-facing listen address.
    pub addr: String,
    /// Replica addresses (the ring's labels — keep them stable across
    /// restarts or keys will move).
    pub replicas: Vec<String>,
    /// Virtual nodes per replica (0 → [`DEFAULT_VNODES`]).
    pub vnodes: usize,
}

/// A running router handle.
pub struct Router {
    pub local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    pub ring: Arc<HashRing>,
    pub metrics: Arc<Metrics>,
}

struct RouterShared {
    ring: Arc<HashRing>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    /// Round-robin cursor for requests with no model key.
    next_rr: AtomicU64,
}

impl Router {
    /// Bind the client port and start proxying. The replicas are not
    /// contacted until the first request that routes to them, so a
    /// router can come up before (or outlive) any individual replica.
    pub fn spawn(config: RouterConfig) -> Result<Router> {
        anyhow::ensure!(!config.replicas.is_empty(), "router needs at least one replica");
        let listener =
            TcpListener::bind(&config.addr).with_context(|| format!("bind {}", config.addr))?;
        let local_addr = listener.local_addr()?;
        let vnodes = if config.vnodes == 0 { DEFAULT_VNODES } else { config.vnodes };
        let ring = Arc::new(HashRing::new(&config.replicas, vnodes));
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let shared = Arc::new(RouterShared {
            ring: ring.clone(),
            metrics: metrics.clone(),
            stop: stop.clone(),
            next_rr: AtomicU64::new(0),
        });
        let stop2 = stop.clone();
        let accept_thread = std::thread::Builder::new()
            .name("fastkqr-route".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let sh = shared.clone();
                            sh.metrics.conn_opened();
                            let sh2 = shared.clone();
                            if std::thread::Builder::new()
                                .name("fastkqr-route-conn".into())
                                .spawn(move || {
                                    proxy_connection(stream, &sh);
                                    sh.metrics.conn_closed();
                                })
                                .is_err()
                            {
                                sh2.metrics.conn_closed();
                                Metrics::incr(&sh2.metrics.accept_spawn_errors);
                            }
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Router { local_addr, stop, accept_thread: Some(accept_thread), ring, metrics })
    }

    /// Stop accepting, join the accept loop, and drain open client
    /// connections (bounded wait — proxy threads observe the stop flag
    /// within their read-timeout tick).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.local_addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        let deadline = std::time::Instant::now() + Duration::from_secs(3);
        while Metrics::get(&self.metrics.active_connections) > 0
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(5));
        }
    }
}

/// One lazily-opened upstream replica connection.
struct Upstream {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Upstream {
    fn connect(addr: &str) -> std::io::Result<Upstream> {
        let stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Duration::from_millis(200)))?;
        let writer = stream.try_clone()?;
        Ok(Upstream { reader: BufReader::new(stream), writer })
    }
}

/// Extract the routing key from a request line: `"model"` (predict /
/// save / export / drop) or `"name"` (load). Unparseable lines return
/// `None` and round-robin — the replica's protocol layer owns error
/// reporting, and a clean error must come from *somewhere*.
fn routing_key(line: &str) -> Option<String> {
    let req = Json::parse(line.trim()).ok()?;
    for field in ["model", "name"] {
        if let Some(v) = req.get(field).and_then(Json::as_str) {
            return Some(v.to_string());
        }
    }
    None
}

fn proxy_connection(stream: TcpStream, shared: &RouterShared) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    // one upstream slot per replica, opened on first use
    let mut upstreams: Vec<Option<Upstream>> = (0..shared.ring.len()).map(|_| None).collect();
    let mut buf: Vec<u8> = Vec::new();
    'conn: loop {
        // Read one request line, ticking on the timeout so the stop flag
        // is observed promptly; partial bytes persist across ticks.
        let line = match read_line_tick(&mut reader, &mut buf, &shared.stop) {
            LineRead::Line(l) => l,
            LineRead::Eof | LineRead::Stopped | LineRead::Dead => break 'conn,
        };
        if line.trim().is_empty() {
            continue;
        }
        if line.trim() == "quit" {
            break 'conn;
        }
        Metrics::incr(&shared.metrics.requests_total);
        let idx = match routing_key(&line) {
            Some(key) => shared.ring.route(&key),
            None => {
                (shared.next_rr.fetch_add(1, Ordering::Relaxed) as usize) % shared.ring.len()
            }
        };
        match forward(&line, idx, &mut upstreams, shared, &mut writer) {
            ForwardOutcome::Ok => {}
            ForwardOutcome::ClientGone => break 'conn,
            ForwardOutcome::UpstreamFailed(e) => {
                // the upstream slot is dropped; next request redials
                Metrics::incr(&shared.metrics.protocol_errors);
                let resp = Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    (
                        "error",
                        Json::str(format!(
                            "replica {} unavailable: {e}",
                            shared.ring.label(idx)
                        )),
                    ),
                ]);
                let mut out = resp.to_string();
                out.push('\n');
                if writer.write_all(out.as_bytes()).is_err() {
                    break 'conn;
                }
            }
        }
    }
}

enum ForwardOutcome {
    Ok,
    ClientGone,
    UpstreamFailed(String),
}

/// Forward one request line to replica `idx` and relay its response
/// lines back verbatim. Multi-line streamed responses are detected the
/// same way [`Client::request_stream`](super::server::Client) does: a
/// first line with `"stream":true` keeps relaying until `"done":true`.
fn forward(
    line: &str,
    idx: usize,
    upstreams: &mut [Option<Upstream>],
    shared: &RouterShared,
    writer: &mut TcpStream,
) -> ForwardOutcome {
    if upstreams[idx].is_none() {
        match Upstream::connect(shared.ring.label(idx)) {
            Ok(u) => upstreams[idx] = Some(u),
            Err(e) => return ForwardOutcome::UpstreamFailed(e.to_string()),
        }
    }
    let up = upstreams[idx].as_mut().expect("just connected");
    let mut out = line.trim().to_string();
    out.push('\n');
    if let Err(e) = up.writer.write_all(out.as_bytes()) {
        upstreams[idx] = None;
        return ForwardOutcome::UpstreamFailed(e.to_string());
    }
    let mut first = true;
    let mut streaming = false;
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let resp = match read_line_tick(&mut up.reader, &mut buf, &shared.stop) {
            LineRead::Line(l) => l,
            LineRead::Stopped => {
                upstreams[idx] = None;
                return ForwardOutcome::UpstreamFailed("router shutting down".into());
            }
            LineRead::Eof | LineRead::Dead => {
                upstreams[idx] = None;
                return ForwardOutcome::UpstreamFailed(
                    "connection closed mid-response".into(),
                );
            }
        };
        // relay the raw line — responses stay bitwise-identical
        let mut relay = resp.clone();
        relay.push('\n');
        if writer.write_all(relay.as_bytes()).is_err() {
            return ForwardOutcome::ClientGone;
        }
        let parsed = Json::parse(resp.trim()).ok();
        let done = parsed
            .as_ref()
            .and_then(|v| v.get("done"))
            .and_then(Json::as_bool)
            == Some(true);
        if first {
            streaming = parsed
                .as_ref()
                .and_then(|v| v.get("stream"))
                .and_then(Json::as_bool)
                == Some(true);
            first = false;
            if !streaming {
                return ForwardOutcome::Ok;
            }
        }
        if done {
            return ForwardOutcome::Ok;
        }
    }
}

pub(crate) enum LineRead {
    Line(String),
    Eof,
    Stopped,
    Dead,
}

/// Read one `\n`-terminated line, ticking on the read timeout so `stop`
/// is observed within ~100–200 ms. Partial bytes accumulate in `buf`
/// across ticks; EOF with residual bytes yields them as a final line
/// (matching `BufRead::lines`). Shared with the server's
/// thread-per-connection model, whose shutdown drain needs the same
/// prompt stop observation.
pub(crate) fn read_line_tick(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
    stop: &AtomicBool,
) -> LineRead {
    loop {
        if stop.load(Ordering::SeqCst) {
            return LineRead::Stopped;
        }
        match reader.read_until(b'\n', buf) {
            Ok(0) => {
                if buf.is_empty() {
                    return LineRead::Eof;
                }
                // EOF with a residual unterminated line
                let bytes = std::mem::take(buf);
                return match String::from_utf8(bytes) {
                    Ok(s) => LineRead::Line(s),
                    Err(_) => LineRead::Dead,
                };
            }
            Ok(_) => {
                if buf.last() == Some(&b'\n') {
                    buf.pop();
                    if buf.last() == Some(&b'\r') {
                        buf.pop();
                    }
                    let bytes = std::mem::take(buf);
                    return match String::from_utf8(bytes) {
                        Ok(s) => LineRead::Line(s),
                        Err(_) => LineRead::Dead,
                    };
                }
                // short read without a newline yet: keep accumulating
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
            {
                // timeout tick: loop back to re-check stop; any bytes
                // read before the timeout are already in `buf`
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return LineRead::Dead,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("127.0.0.1:{}", 7801 + i)).collect()
    }

    #[test]
    fn ring_is_deterministic_and_order_independent() {
        let a = HashRing::new(&labels(3), DEFAULT_VNODES);
        let mut shuffled = labels(3);
        shuffled.reverse();
        let b = HashRing::new(&shuffled, DEFAULT_VNODES);
        for k in 0..200 {
            let key = format!("m{k}");
            // same *label* owns the key regardless of construction order
            assert_eq!(a.label(a.route(&key)), b.label(b.route(&key)), "key {key}");
            // and routing twice is stable
            assert_eq!(a.route(&key), a.route(&key));
        }
    }

    #[test]
    fn ring_spreads_keys_across_replicas() {
        let ring = HashRing::new(&labels(4), DEFAULT_VNODES);
        let mut counts = [0usize; 4];
        for k in 0..1000 {
            counts[ring.route(&format!("m{k}"))] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            // perfectly even would be 250; demand at least half of that
            assert!(c > 125, "replica {i} owns only {c}/1000 keys: {counts:?}");
        }
    }

    #[test]
    fn adding_a_replica_moves_about_one_over_n() {
        let before = HashRing::new(&labels(3), DEFAULT_VNODES);
        let after = HashRing::new(&labels(4), DEFAULT_VNODES);
        let n = 1000;
        let mut moved = 0;
        for k in 0..n {
            let key = format!("m{k}");
            let (b, a) = (before.route(&key), after.route(&key));
            if before.label(b) != after.label(a) {
                moved += 1;
                // every moved key must land on the NEW replica — keys
                // never shuffle between surviving replicas
                assert_eq!(after.label(a), "127.0.0.1:7804", "key {key} moved sideways");
            }
        }
        // ideal is 1/4 = 250; accept a generous band around it
        let frac = moved as f64 / n as f64;
        assert!(
            (0.10..=0.45).contains(&frac),
            "moved fraction {frac} outside [0.10, 0.45] ({moved}/{n})"
        );
    }

    #[test]
    fn routing_key_prefers_model_then_name() {
        assert_eq!(routing_key(r#"{"cmd":"predict","model":"m3","x":[[0.1]]}"#).as_deref(), Some("m3"));
        assert_eq!(routing_key(r#"{"cmd":"load","name":"prod"}"#).as_deref(), Some("prod"));
        assert_eq!(routing_key(r#"{"cmd":"ping"}"#), None);
        assert_eq!(routing_key("not json"), None);
    }

    #[test]
    fn hash64_avalanches_neighboring_ids() {
        // ids differing in one trailing character must not be adjacent
        // on the ring (the failure mode of unfinalized FNV)
        let h0 = hash64("m0");
        let h1 = hash64("m1");
        assert!(h0.abs_diff(h1) > u64::MAX / 1000, "h(m0)={h0:x} h(m1)={h1:x} too close");
    }
}
