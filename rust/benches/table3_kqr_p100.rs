//! Table 3 (supplement): KQR on the Friedman simulation with p=100.
use fastkqr::experiments::{kqr_tables, print_table, speedups, TableConfig};
use fastkqr::util::Args;

fn main() {
    let args = Args::from_env();
    let mut cfg = TableConfig::from_args(&args);
    cfg.p = args.get_usize("p", 100);
    let cells = kqr_tables::table3(&cfg).expect("table3");
    print_table("Table 3 — Friedman p=100", &cells, &cfg.solvers);
    for (label, n, solver, factor) in speedups(&cells) {
        println!("speedup {label} n={n}: {factor:.1}x vs {solver}");
    }
}
