//! Tables 1, 3, 4, 5: single-level KQR — fastkqr vs kernlab(IPM) vs
//! nlm(L-BFGS) vs optim(Nelder–Mead).
//!
//! Protocol (paper §4.1): per repetition, generate training data, run
//! each solver over the full λ path **including** `folds`-fold CV to pick
//! λ, record total wall time and the objective of problem (2) at the
//! selected λ. fastkqr amortizes one eigendecomposition + warm starts
//! across the whole grid; the baselines re-solve from scratch per
//! (fold, λ) — exactly the structural gap the paper measures.

use super::{CellResult, TableConfig};
use crate::baselines::{solve_kqr_ipm, solve_kqr_lbfgs, solve_kqr_nelder_mead, IpmOptions};
use crate::cv::fold_assignment;
use crate::data::{benchmarks, synth, Dataset, Rng};
use crate::kernel::{median_heuristic_sigma, Kernel};
use crate::kqr::{KqrSolver, SolveOptions};
use crate::linalg::Matrix;
use crate::smooth::pinball_loss;
use crate::util::bench::mean_sd;
use crate::util::Timer;
use anyhow::Result;

/// Which solver to run on a (data, τ, λ-grid, folds) workload.
fn run_solver_cv(
    solver: &str,
    data: &Dataset,
    kernel: &Kernel,
    tau: f64,
    lambdas: &[f64],
    folds: usize,
    rng: &mut Rng,
) -> Result<f64> {
    let n = data.n();
    let assignment = fold_assignment(n, folds, rng)?;
    let mut cv_loss = vec![0.0f64; lambdas.len()];
    // held-out scoring per fold
    for fold in 0..folds {
        let tr_idx: Vec<usize> = (0..n).filter(|i| assignment[*i] != fold).collect();
        let te_idx: Vec<usize> = (0..n).filter(|i| assignment[*i] == fold).collect();
        let tr = data.subset(&tr_idx);
        let te = data.subset(&te_idx);
        match solver {
            "fastkqr" => {
                // fold fits use the loose CV preset (hold-out scoring needs
                // a stable predictor, not a certificate); the final refit
                // below runs at full rigor
                let s = KqrSolver::new(&tr.x, &tr.y, kernel.clone())?
                    .with_options(SolveOptions::cv_preset());
                let fits = s.fit_path(tau, lambdas)?;
                for (li, fit) in fits.iter().enumerate() {
                    cv_loss[li] += pinball_loss(&te.y, &fit.predict(&te.x), tau);
                }
            }
            "ipm" => {
                let gram = kernel.gram(&tr.x);
                for (li, &lam) in lambdas.iter().enumerate() {
                    let fit = solve_kqr_ipm(&gram, &tr.y, tau, lam, &IpmOptions::default())?;
                    let cg = kernel.cross_gram(&te.x, &tr.x);
                    let mut pred = vec![0.0; te.n()];
                    crate::linalg::gemv(&cg, &fit.alpha, &mut pred);
                    for p in pred.iter_mut() {
                        *p += fit.b;
                    }
                    cv_loss[li] += pinball_loss(&te.y, &pred, tau);
                }
            }
            "lbfgs" | "neldermead" => {
                let gram = kernel.gram(&tr.x);
                for (li, &lam) in lambdas.iter().enumerate() {
                    let fit = if solver == "lbfgs" {
                        solve_kqr_lbfgs(&gram, &tr.y, tau, lam, 500)?
                    } else {
                        solve_kqr_nelder_mead(&gram, &tr.y, tau, lam, 4000)?
                    };
                    let cg = kernel.cross_gram(&te.x, &tr.x);
                    let mut pred = vec![0.0; te.n()];
                    crate::linalg::gemv(&cg, &fit.alpha, &mut pred);
                    for p in pred.iter_mut() {
                        *p += fit.b;
                    }
                    cv_loss[li] += pinball_loss(&te.y, &pred, tau);
                }
            }
            other => anyhow::bail!("unknown solver {other:?}"),
        }
    }
    // select λ*, refit on the full data, report the objective there
    let best = cv_loss
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    let lam_star = lambdas[best];
    let obj = match solver {
        "fastkqr" => {
            let s = KqrSolver::new(&data.x, &data.y, kernel.clone())?;
            // warm-started down the path to λ*
            let path: Vec<f64> = lambdas[..=best].to_vec();
            let fits = s.fit_path(tau, &path)?;
            fits.last().unwrap().objective
        }
        "ipm" => {
            let gram = kernel.gram(&data.x);
            solve_kqr_ipm(&gram, &data.y, tau, lam_star, &IpmOptions::default())?.objective
        }
        "lbfgs" => {
            let gram = kernel.gram(&data.x);
            solve_kqr_lbfgs(&gram, &data.y, tau, lam_star, 500)?.objective
        }
        "neldermead" => {
            let gram = kernel.gram(&data.x);
            solve_kqr_nelder_mead(&gram, &data.y, tau, lam_star, 4000)?.objective
        }
        _ => unreachable!(),
    };
    Ok(obj)
}

/// Generic KQR table engine over a data generator.
pub fn kqr_table(
    cfg: &TableConfig,
    mut generate: impl FnMut(usize, &mut Rng) -> Dataset,
) -> Result<Vec<CellResult>> {
    let mut cells = Vec::new();
    for &tau in &cfg.taus {
        for &n in &cfg.ns {
            for solver in &cfg.solvers {
                let mut objs = Vec::new();
                let mut total_time = 0.0;
                for rep in 0..cfg.reps {
                    let mut rng = Rng::new(cfg.seed + 1000 * rep as u64 + n as u64);
                    let data = generate(n, &mut rng);
                    let sigma = median_heuristic_sigma(&data.x);
                    let kernel = Kernel::Rbf { sigma };
                    let lambdas =
                        lambda_grid(cfg.nlam, 1.0, 1e-4);
                    let timer = Timer::start(solver);
                    let obj = run_solver_cv(
                        solver, &data, &kernel, tau, &lambdas, cfg.folds, &mut rng,
                    )?;
                    total_time += timer.total();
                    objs.push(obj);
                }
                let (m, sd) = mean_sd(&objs);
                cells.push(CellResult {
                    solver: solver.clone(),
                    label: format!("tau={tau}"),
                    n,
                    obj_mean: m,
                    obj_sd: sd,
                    time_s: total_time,
                });
            }
        }
    }
    Ok(cells)
}

fn lambda_grid(count: usize, max: f64, min_ratio: f64) -> Vec<f64> {
    let log_max = max.ln();
    let log_min = (max * min_ratio).ln();
    (0..count)
        .map(|i| {
            (log_max + (log_min - log_max) * i as f64 / (count.max(2) - 1) as f64).exp()
        })
        .collect()
}

/// Table 1: Friedman et al. simulation, p = 5000 (quick default p from cfg).
pub fn table1(cfg: &TableConfig) -> Result<Vec<CellResult>> {
    let p = cfg.p;
    kqr_table(cfg, move |n, rng| synth::friedman(n, p, 3.0, rng))
}

/// Table 3 (supplement): Friedman, p = 100.
pub fn table3(cfg: &TableConfig) -> Result<Vec<CellResult>> {
    let p = cfg.p.min(100);
    kqr_table(cfg, move |n, rng| synth::friedman(n, p, 3.0, rng))
}

/// Table 4 (supplement): Yuan (2006) 2-D model.
pub fn table4(cfg: &TableConfig) -> Result<Vec<CellResult>> {
    kqr_table(cfg, |n, rng| synth::yuan(n, rng))
}

/// Table 5 (supplement): benchmark-data lookalikes (crabs/GAG/mcycle/BH).
/// `subsample` caps each dataset's n for the quick configuration.
pub fn table5(cfg: &TableConfig, subsample: Option<usize>) -> Result<Vec<CellResult>> {
    let mut cells = Vec::new();
    for &tau in &cfg.taus {
        for ds_id in 0..4usize {
            for solver in &cfg.solvers {
                let mut objs = Vec::new();
                let mut total_time = 0.0;
                let mut used_n = 0usize;
                let mut label = String::new();
                for rep in 0..cfg.reps {
                    let seed = cfg.seed + rep as u64;
                    let mut data = match ds_id {
                        0 => benchmarks::crabs(seed),
                        1 => benchmarks::gagurine(seed),
                        2 => benchmarks::mcycle(seed),
                        _ => benchmarks::boston_housing(seed),
                    };
                    let mut rng = Rng::new(seed ^ 0xbeef);
                    if let Some(cap) = subsample {
                        if data.n() > cap {
                            let idx = rng.permutation(data.n());
                            data = data.subset(&idx[..cap]);
                        }
                    }
                    data.standardize();
                    used_n = data.n();
                    label = data.name.split('(').next().unwrap_or("data").to_string();
                    let sigma = median_heuristic_sigma(&data.x);
                    let kernel = Kernel::Rbf { sigma };
                    let lambdas = lambda_grid(cfg.nlam, 1.0, 1e-4);
                    let timer = Timer::start(solver);
                    let obj = run_solver_cv(
                        solver, &data, &kernel, tau, &lambdas, cfg.folds, &mut rng,
                    )?;
                    total_time += timer.total();
                    objs.push(obj);
                }
                let (m, sd) = mean_sd(&objs);
                cells.push(CellResult {
                    solver: solver.clone(),
                    label: format!("{label} tau={tau}"),
                    n: used_n,
                    obj_mean: m,
                    obj_sd: sd,
                    time_s: total_time,
                });
            }
        }
    }
    Ok(cells)
}

/// Options shared with the CLI for stand-alone fits.
pub fn default_solve_options() -> SolveOptions {
    SolveOptions::default()
}

/// Convenience used by tests: a tiny Friedman table run.
pub fn smoke_cells() -> Result<Vec<CellResult>> {
    let cfg = TableConfig {
        ns: vec![40],
        p: 5,
        taus: vec![0.5],
        nlam: 3,
        folds: 2,
        reps: 1,
        solvers: vec!["fastkqr".into(), "ipm".into()],
        seed: 7,
    };
    table1(&cfg)
}

#[allow(dead_code)]
fn _unused(_: &Matrix) {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_table_shapes_and_parity() {
        let cells = smoke_cells().unwrap();
        assert_eq!(cells.len(), 2);
        let fast = cells.iter().find(|c| c.solver == "fastkqr").unwrap();
        let ipm = cells.iter().find(|c| c.solver == "ipm").unwrap();
        // same protocol ⇒ nearly identical objective (both exact-class)
        assert!(
            (fast.obj_mean - ipm.obj_mean).abs() < 0.05 * (1.0 + ipm.obj_mean.abs()),
            "fast {} vs ipm {}",
            fast.obj_mean,
            ipm.obj_mean
        );
        assert!(fast.time_s > 0.0 && ipm.time_s > 0.0);
    }
}
