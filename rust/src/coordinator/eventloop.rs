//! Event-driven connection layer: a readiness poller + bounded worker
//! pool, replacing thread-per-connection on the serving hot path.
//!
//! The thread model (still available, see [`IoModel`]) burns one OS
//! thread per open connection — fine for tens of clients, a hard cap far
//! below the "millions of users" target. This module drives nonblocking
//! `std::net` sockets off **raw `epoll`** (Linux) / **`kqueue`** (macOS)
//! through thin `extern "C"` declarations against the always-linked
//! libc — no new crate dependencies — and hands complete request lines
//! to a **bounded** worker pool (`FASTKQR_WORKERS`, default = cores)
//! through an MPMC queue with backpressure: when the queue is full the
//! client gets a clean protocol error (counted in
//! `Metrics::queue_full_rejects`), never a hang.
//!
//! Responses — including multi-line streamed predicts — go through
//! per-connection outbound buffers drained on writability, so a slow
//! reader can no longer pin a worker for the duration of its download.
//!
//! Requests on one connection are dispatched **one at a time** (later
//! pipelined lines queue on the connection until the in-flight request's
//! last response line is buffered), which makes the event loop's byte
//! stream per connection identical to the thread model's — the thread
//! model is kept as the bitwise-parity oracle and as the portable
//! fallback on targets without a poller (`IoModel::Auto` resolves to
//! threads there).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Connection-layer selection: `FASTKQR_IO=epoll|threads|auto` or
/// `ServerConfig::io_model`. `epoll` names the event-driven model on
/// both Linux (epoll proper) and macOS (kqueue-backed); `auto` picks the
/// event model where a poller exists and threads everywhere else.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoModel {
    Auto,
    Threads,
    Epoll,
}

impl IoModel {
    /// Parse `epoll` / `threads` / `auto` (the accepted spellings of
    /// `FASTKQR_IO` and `serve --io`).
    pub fn parse(s: &str) -> anyhow::Result<IoModel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(IoModel::Auto),
            "threads" | "thread" => Ok(IoModel::Threads),
            "epoll" | "event" | "kqueue" => Ok(IoModel::Epoll),
            other => anyhow::bail!("unknown io model {other:?} (epoll|threads|auto)"),
        }
    }

    /// Read `FASTKQR_IO`; unset or invalid values fall back to `Auto`
    /// (invalid loudly, on stderr — never a silent behavior change).
    pub fn from_env() -> IoModel {
        match std::env::var("FASTKQR_IO") {
            Ok(v) if !v.trim().is_empty() => IoModel::parse(&v).unwrap_or_else(|e| {
                eprintln!("fastkqr: ignoring FASTKQR_IO: {e}");
                IoModel::Auto
            }),
            _ => IoModel::Auto,
        }
    }

    /// Whether this build has an event poller at all.
    pub fn event_supported() -> bool {
        cfg!(any(target_os = "linux", target_os = "macos"))
    }

    /// Resolve `Auto` to a concrete model for this target. An explicit
    /// `Epoll` request on a target without a poller is an error (the
    /// operator asked for something this build cannot do); `Auto`
    /// quietly falls back to threads there.
    pub fn resolve(self) -> anyhow::Result<IoModel> {
        match self {
            IoModel::Auto => {
                if Self::event_supported() {
                    Ok(IoModel::Epoll)
                } else {
                    Ok(IoModel::Threads)
                }
            }
            IoModel::Threads => Ok(IoModel::Threads),
            IoModel::Epoll => {
                if Self::event_supported() {
                    Ok(IoModel::Epoll)
                } else {
                    anyhow::bail!(
                        "io model 'epoll' is not supported on this target \
                         (no epoll/kqueue); use 'threads' or 'auto'"
                    )
                }
            }
        }
    }

    /// The label reported in `metrics` (`io_model` field).
    pub fn label(self) -> &'static str {
        match self {
            IoModel::Auto => "auto",
            IoModel::Threads => "threads",
            IoModel::Epoll => "epoll",
        }
    }
}

/// `FASTKQR_WORKERS` (default = available cores, min 1): size of the
/// event loop's bounded worker pool. `configured` (from
/// `ServerConfig::workers`) wins when non-zero.
pub fn resolve_workers(configured: usize) -> usize {
    if configured > 0 {
        return configured;
    }
    std::env::var("FASTKQR_WORKERS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&w| w > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

/// `FASTKQR_QUEUE_CAP` (default 1024): backpressure cap of the worker
/// queue *and* of each connection's pipelined-request queue.
pub fn resolve_queue_cap(configured: usize) -> usize {
    if configured > 0 {
        return configured;
    }
    std::env::var("FASTKQR_QUEUE_CAP")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&c| c > 0)
        .unwrap_or(1024)
}

/// A unit of work for the pool.
pub(crate) type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolQueue {
    jobs: VecDeque<Job>,
    stopped: bool,
}

struct PoolShared {
    queue: Mutex<PoolQueue>,
    available: Condvar,
}

/// Fixed-size worker pool over a bounded MPMC queue. Submission never
/// blocks: a full queue returns the job to the caller (backpressure is
/// the *caller's* protocol decision, not an invisible stall).
pub(crate) struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
    cap: usize,
}

impl WorkerPool {
    pub fn spawn(workers: usize, cap: usize, name: &str) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(PoolQueue { jobs: VecDeque::new(), stopped: false }),
            available: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let sh = shared.clone();
            let handle = std::thread::Builder::new()
                .name(format!("{name}-{i}"))
                .spawn(move || loop {
                    let job = {
                        let mut q = sh.queue.lock().unwrap();
                        loop {
                            if let Some(j) = q.jobs.pop_front() {
                                break Some(j);
                            }
                            if q.stopped {
                                break None;
                            }
                            q = sh.available.wait(q).unwrap();
                        }
                    };
                    match job {
                        // A panicking request must not shrink the pool.
                        Some(j) => {
                            let _ = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(j),
                            );
                        }
                        None => break,
                    }
                })
                .expect("spawn worker thread");
            handles.push(handle);
        }
        WorkerPool { shared, handles, cap: cap.max(1) }
    }

    /// Enqueue `job`, or hand it back when the queue is at capacity (or
    /// the pool is stopping).
    pub fn try_submit(&self, job: Job) -> Result<(), Job> {
        let mut q = self.shared.queue.lock().unwrap();
        if q.stopped || q.jobs.len() >= self.cap {
            return Err(job);
        }
        q.jobs.push_back(job);
        drop(q);
        self.shared.available.notify_one();
        Ok(())
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Stop accepting work, let queued jobs finish, join every worker.
    pub fn shutdown(self) {
        self.shared.queue.lock().unwrap().stopped = true;
        self.shared.available.notify_all();
        for h in self.handles {
            let _ = h.join();
        }
    }
}

#[cfg(any(target_os = "linux", target_os = "macos"))]
pub(crate) use imp::{spawn_event_loop, LoopShared};

#[cfg(not(any(target_os = "linux", target_os = "macos")))]
pub(crate) use stub::{spawn_event_loop, LoopShared};

/// The real event loop: only compiled where a poller exists.
#[cfg(any(target_os = "linux", target_os = "macos"))]
mod imp {
    use super::super::metrics::Metrics;
    use super::super::protocol::{err_json, handle_request, ProtocolState};
    use super::{Job, WorkerPool};
    use std::collections::VecDeque;
    use std::io::{ErrorKind, Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Mutex};
    use std::thread::JoinHandle;
    use std::time::{Duration, Instant};

    const TOKEN_LISTENER: u64 = 0;
    const TOKEN_WAKE: u64 = 1;
    const TOKEN_BASE: u64 = 2;
    /// Bounded wait so the stop flag is observed even without a wake.
    const WAIT_MS: i32 = 250;
    /// Orderly-shutdown drain budget for in-flight requests + buffers.
    const DRAIN: Duration = Duration::from_secs(3);

    /// One readiness event, normalized across epoll/kqueue. Error and
    /// hangup conditions surface as readable+writable so the read/write
    /// paths observe the failure (`read` → 0/error, `write` → error)
    /// instead of the connection idling forever.
    #[derive(Clone, Copy)]
    pub(crate) struct PollEvent {
        pub token: u64,
        pub readable: bool,
        pub writable: bool,
    }

    #[cfg(target_os = "linux")]
    mod sys {
        use super::PollEvent;
        use std::io;
        use std::os::raw::c_int;
        use std::os::unix::io::RawFd;

        // x86_64 is the one Linux ABI where epoll_event is packed.
        #[repr(C)]
        #[cfg_attr(target_arch = "x86_64", repr(packed))]
        #[derive(Clone, Copy)]
        struct EpollEvent {
            events: u32,
            data: u64,
        }

        extern "C" {
            fn epoll_create1(flags: c_int) -> c_int;
            fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
            fn epoll_wait(
                epfd: c_int,
                events: *mut EpollEvent,
                maxevents: c_int,
                timeout: c_int,
            ) -> c_int;
            fn close(fd: c_int) -> c_int;
        }

        const EPOLL_CLOEXEC: c_int = 0o2000000;
        const EPOLL_CTL_ADD: c_int = 1;
        const EPOLL_CTL_DEL: c_int = 2;
        const EPOLL_CTL_MOD: c_int = 3;
        const EPOLLIN: u32 = 0x001;
        const EPOLLOUT: u32 = 0x004;
        const EPOLLERR: u32 = 0x008;
        const EPOLLHUP: u32 = 0x010;
        const MAX_EVENTS: usize = 128;

        /// Level-triggered epoll instance (level-triggering keeps the
        /// loop logic simple: un-drained readiness just fires again).
        pub(crate) struct Poller {
            fd: RawFd,
        }

        impl Poller {
            pub fn new() -> io::Result<Poller> {
                // SAFETY: plain syscall, no pointers; the returned fd is
                // owned by Poller and closed exactly once in Drop.
                let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
                if fd < 0 {
                    return Err(io::Error::last_os_error());
                }
                Ok(Poller { fd })
            }

            fn interest(read: bool, write: bool) -> u32 {
                let mut ev = 0;
                if read {
                    ev |= EPOLLIN;
                }
                if write {
                    ev |= EPOLLOUT;
                }
                ev
            }

            fn ctl(&self, op: c_int, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
                let mut ev = EpollEvent { events: Self::interest(read, write), data: token };
                // SAFETY: `ev` is a live, properly initialized
                // repr(C) epoll_event for the duration of the call; fd
                // and self.fd are valid open descriptors.
                let rc = unsafe { epoll_ctl(self.fd, op, fd, &mut ev) };
                if rc < 0 {
                    return Err(io::Error::last_os_error());
                }
                Ok(())
            }

            pub fn register(&self, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
                self.ctl(EPOLL_CTL_ADD, fd, token, read, write)
            }

            pub fn reregister(&self, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
                self.ctl(EPOLL_CTL_MOD, fd, token, read, write)
            }

            pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
                // The event argument is ignored for DEL but must be
                // non-null on pre-2.6.9 kernels; pass a zeroed one.
                self.ctl(EPOLL_CTL_DEL, fd, 0, false, false)
            }

            pub fn wait(&self, out: &mut Vec<PollEvent>, timeout_ms: i32) -> io::Result<()> {
                out.clear();
                let mut buf = [EpollEvent { events: 0, data: 0 }; MAX_EVENTS];
                // SAFETY: buf is a properly initialized array of
                // MAX_EVENTS repr(C) epoll_events; the kernel writes at
                // most MAX_EVENTS entries.
                let n = unsafe {
                    epoll_wait(self.fd, buf.as_mut_ptr(), MAX_EVENTS as c_int, timeout_ms)
                };
                if n < 0 {
                    let e = io::Error::last_os_error();
                    if e.kind() == io::ErrorKind::Interrupted {
                        return Ok(());
                    }
                    return Err(e);
                }
                for ev in buf.iter().take(n as usize) {
                    // copy out of the (possibly packed) struct by value
                    let ev = *ev;
                    let bits = ev.events;
                    out.push(PollEvent {
                        token: ev.data,
                        readable: bits & (EPOLLIN | EPOLLHUP | EPOLLERR) != 0,
                        writable: bits & (EPOLLOUT | EPOLLHUP | EPOLLERR) != 0,
                    });
                }
                Ok(())
            }
        }

        impl Drop for Poller {
            fn drop(&mut self) {
                // SAFETY: self.fd is the epoll fd created in new() and
                // closed nowhere else.
                unsafe {
                    close(self.fd);
                }
            }
        }
    }

    #[cfg(target_os = "macos")]
    mod sys {
        use super::PollEvent;
        use std::ffi::c_void;
        use std::io;
        use std::os::raw::c_int;
        use std::os::unix::io::RawFd;

        #[repr(C)]
        #[derive(Clone, Copy)]
        struct Kevent {
            ident: usize,
            filter: i16,
            flags: u16,
            fflags: u32,
            data: isize,
            udata: *mut c_void,
        }

        #[repr(C)]
        struct Timespec {
            tv_sec: i64,
            tv_nsec: i64,
        }

        extern "C" {
            fn kqueue() -> c_int;
            fn kevent(
                kq: c_int,
                changelist: *const Kevent,
                nchanges: c_int,
                eventlist: *mut Kevent,
                nevents: c_int,
                timeout: *const Timespec,
            ) -> c_int;
            fn close(fd: c_int) -> c_int;
        }

        const EVFILT_READ: i16 = -1;
        const EVFILT_WRITE: i16 = -2;
        const EV_ADD: u16 = 0x1;
        const EV_DELETE: u16 = 0x2;
        const MAX_EVENTS: usize = 128;

        fn kev(fd: RawFd, filter: i16, flags: u16, token: u64) -> Kevent {
            Kevent {
                ident: fd as usize,
                filter,
                flags,
                fflags: 0,
                data: 0,
                udata: token as usize as *mut c_void,
            }
        }

        /// kqueue-backed poller presenting the same level-triggered
        /// register/reregister/wait surface as the Linux one.
        pub(crate) struct Poller {
            fd: RawFd,
        }

        impl Poller {
            pub fn new() -> io::Result<Poller> {
                // SAFETY: plain syscall, no pointers; the fd is owned by
                // Poller and closed exactly once in Drop.
                let fd = unsafe { kqueue() };
                if fd < 0 {
                    return Err(io::Error::last_os_error());
                }
                Ok(Poller { fd })
            }

            fn change(&self, ev: &Kevent) -> io::Result<()> {
                // SAFETY: `ev` points at one live repr(C) kevent; the
                // eventlist is null with nevents 0, so the kernel writes
                // nothing back.
                let rc = unsafe { kevent(self.fd, ev, 1, std::ptr::null_mut(), 0, std::ptr::null()) };
                if rc < 0 {
                    return Err(io::Error::last_os_error());
                }
                Ok(())
            }

            fn apply(&self, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
                let rf = if read { EV_ADD } else { EV_DELETE };
                let wf = if write { EV_ADD } else { EV_DELETE };
                let r = self.change(&kev(fd, EVFILT_READ, rf, token));
                if read {
                    r?;
                }
                let w = self.change(&kev(fd, EVFILT_WRITE, wf, token));
                if write {
                    w?;
                }
                // deletions of an absent filter return ENOENT: ignored
                Ok(())
            }

            pub fn register(&self, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
                self.apply(fd, token, read, write)
            }

            pub fn reregister(&self, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
                self.apply(fd, token, read, write)
            }

            pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
                self.apply(fd, 0, false, false)
            }

            pub fn wait(&self, out: &mut Vec<PollEvent>, timeout_ms: i32) -> io::Result<()> {
                out.clear();
                let ts = Timespec {
                    tv_sec: (timeout_ms / 1000) as i64,
                    tv_nsec: ((timeout_ms % 1000) as i64) * 1_000_000,
                };
                let mut buf = [kev(0, 0, 0, 0); MAX_EVENTS];
                // SAFETY: buf is a properly initialized array of
                // MAX_EVENTS repr(C) kevents; the kernel fills at most
                // MAX_EVENTS entries; the timespec outlives the call.
                let n = unsafe {
                    kevent(self.fd, std::ptr::null(), 0, buf.as_mut_ptr(), MAX_EVENTS as c_int, &ts)
                };
                if n < 0 {
                    let e = io::Error::last_os_error();
                    if e.kind() == io::ErrorKind::Interrupted {
                        return Ok(());
                    }
                    return Err(e);
                }
                for ev in buf.iter().take(n as usize) {
                    out.push(PollEvent {
                        token: ev.udata as usize as u64,
                        readable: ev.filter == EVFILT_READ,
                        writable: ev.filter == EVFILT_WRITE,
                    });
                }
                Ok(())
            }
        }

        impl Drop for Poller {
            fn drop(&mut self) {
                // SAFETY: self.fd is the kqueue fd created in new() and
                // closed nowhere else.
                unsafe {
                    close(self.fd);
                }
            }
        }
    }

    use sys::Poller;

    /// State a worker shares with the loop for one connection.
    pub(crate) struct ConnShared {
        stream: TcpStream,
        token: u64,
        /// Bytes awaiting the socket (drained opportunistically by the
        /// writer, and on writability by the loop).
        out: Mutex<VecDeque<u8>>,
        /// Pipelined request lines + the in-flight flag.
        pending: Mutex<ConnPending>,
        dead: AtomicBool,
    }

    struct ConnPending {
        lines: VecDeque<String>,
        running: bool,
        quit: bool,
    }

    /// Loop-thread-only per-connection read state.
    struct ConnSlot {
        conn: Arc<ConnShared>,
        read_buf: Vec<u8>,
        eof: bool,
        read_off: bool,
        write_armed: bool,
    }

    /// Shared between the loop, the workers, and the server handle: the
    /// dirty list ("re-examine this connection") and the wake channel.
    pub(crate) struct LoopShared {
        dirty: Mutex<Vec<u64>>,
        wake_tx: UnixStream,
    }

    impl LoopShared {
        pub(crate) fn wake(&self) {
            // &UnixStream implements Write; a full pipe just means a
            // wake is already pending.
            let _ = (&self.wake_tx).write(&[1u8]);
        }

        fn mark_dirty(&self, token: u64) {
            self.dirty.lock().unwrap().push(token);
            self.wake();
        }
    }

    /// Flush as much of `conn`'s outbound buffer as the socket accepts.
    /// On `WouldBlock` the connection is marked dirty so the loop arms
    /// write interest; on error the connection is marked dead.
    fn drain_output(conn: &ConnShared, shared: &LoopShared) {
        let mut out = conn.out.lock().unwrap();
        loop {
            let (a, b) = out.as_slices();
            let chunk = if a.is_empty() { b } else { a };
            if chunk.is_empty() {
                break;
            }
            match (&conn.stream).write(chunk) {
                Ok(0) => {
                    conn.dead.store(true, Ordering::Relaxed);
                    out.clear();
                    shared.mark_dirty(conn.token);
                    break;
                }
                Ok(n) => {
                    out.drain(..n);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    shared.mark_dirty(conn.token);
                    break;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    conn.dead.store(true, Ordering::Relaxed);
                    out.clear();
                    shared.mark_dirty(conn.token);
                    break;
                }
            }
        }
    }

    /// Worker-side request execution: run the first line, then drain any
    /// lines that piled up on the connection while it ran (dispatching
    /// them inline preserves per-connection response order — the parity
    /// contract with the thread model).
    fn worker_job(
        conn: Arc<ConnShared>,
        first_line: String,
        state: Arc<ProtocolState>,
        metrics: Arc<Metrics>,
        shared: Arc<LoopShared>,
    ) {
        let now = metrics.workers_busy.fetch_add(1, Ordering::Relaxed) + 1;
        metrics.workers_busy_peak.fetch_max(now, Ordering::Relaxed);
        let mut line = first_line;
        loop {
            let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                handle_request(&state, &line, &mut |resp| {
                    let mut text = resp.to_string();
                    text.push('\n');
                    conn.out.lock().unwrap().extend(text.as_bytes());
                    drain_output(&conn, &shared);
                    !conn.dead.load(Ordering::Relaxed)
                });
            }))
            .is_err();
            if panicked {
                // the thread model would kill its connection thread here;
                // match that by failing the connection, not the worker
                conn.dead.store(true, Ordering::Relaxed);
            }
            let next = {
                let mut p = conn.pending.lock().unwrap();
                if conn.dead.load(Ordering::Relaxed) {
                    p.lines.clear();
                }
                match p.lines.pop_front() {
                    Some(l) => Some(l),
                    None => {
                        p.running = false;
                        None
                    }
                }
            };
            match next {
                Some(l) => line = l,
                None => break,
            }
        }
        Metrics::dec(&metrics.workers_busy);
        shared.mark_dirty(conn.token);
    }

    struct EventLoop {
        poller: Poller,
        listener: TcpListener,
        wake_rx: UnixStream,
        conns: Vec<Option<ConnSlot>>,
        free: Vec<usize>,
        pool: WorkerPool,
        state: Arc<ProtocolState>,
        metrics: Arc<Metrics>,
        shared: Arc<LoopShared>,
        stop: Arc<AtomicBool>,
        queue_cap: usize,
    }

    impl EventLoop {
        fn run(mut self) {
            let mut events: Vec<PollEvent> = Vec::with_capacity(128);
            loop {
                if self.stop.load(Ordering::SeqCst) {
                    break;
                }
                if self.poller.wait(&mut events, WAIT_MS).is_err() {
                    break;
                }
                for i in 0..events.len() {
                    let PollEvent { token, readable, writable } = events[i];
                    match token {
                        TOKEN_LISTENER => self.accept_ready(),
                        TOKEN_WAKE => self.drain_wake(),
                        t => {
                            if writable {
                                self.conn_writable(t);
                            }
                            if readable {
                                self.conn_readable(t);
                            }
                        }
                    }
                }
                self.sweep_dirty();
            }
            self.drain_and_close(&mut events);
            // partial move out of self — EventLoop has no Drop impl
            self.pool.shutdown();
        }

        fn slot_idx(token: u64) -> usize {
            (token - TOKEN_BASE) as usize
        }

        fn accept_ready(&mut self) {
            loop {
                match self.listener.accept() {
                    Ok((stream, _)) => {
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        let idx = self.free.pop().unwrap_or_else(|| {
                            self.conns.push(None);
                            self.conns.len() - 1
                        });
                        let token = TOKEN_BASE + idx as u64;
                        let conn = Arc::new(ConnShared {
                            stream,
                            token,
                            out: Mutex::new(VecDeque::new()),
                            pending: Mutex::new(ConnPending {
                                lines: VecDeque::new(),
                                running: false,
                                quit: false,
                            }),
                            dead: AtomicBool::new(false),
                        });
                        if self
                            .poller
                            .register(conn.stream.as_raw_fd(), token, true, false)
                            .is_err()
                        {
                            self.free.push(idx);
                            continue;
                        }
                        self.metrics.conn_opened();
                        self.conns[idx] = Some(ConnSlot {
                            conn,
                            read_buf: Vec::new(),
                            eof: false,
                            read_off: false,
                            write_armed: false,
                        });
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => break,
                }
            }
        }

        fn drain_wake(&mut self) {
            let mut buf = [0u8; 64];
            while matches!((&self.wake_rx).read(&mut buf), Ok(n) if n > 0) {}
        }

        fn conn_writable(&mut self, token: u64) {
            let idx = Self::slot_idx(token);
            let conn = match self.conns.get(idx).and_then(|s| s.as_ref()) {
                Some(slot) => slot.conn.clone(),
                None => return,
            };
            drain_output(&conn, &self.shared);
            self.sweep_one(token);
        }

        fn conn_readable(&mut self, token: u64) {
            let idx = Self::slot_idx(token);
            match self.conns.get(idx).and_then(|s| s.as_ref()) {
                None => return,
                Some(s) => {
                    if s.eof || s.conn.dead.load(Ordering::Relaxed) {
                        self.sweep_one(token);
                        return;
                    }
                }
            }
            let (lines, conn) = {
                let slot = match self.conns.get_mut(idx).and_then(|s| s.as_mut()) {
                    Some(s) => s,
                    None => return,
                };
                let mut tmp = [0u8; 16384];
                loop {
                    match (&slot.conn.stream).read(&mut tmp) {
                        Ok(0) => {
                            slot.eof = true;
                            break;
                        }
                        Ok(n) => {
                            slot.read_buf.extend_from_slice(&tmp[..n]);
                            if n < tmp.len() {
                                break;
                            }
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == ErrorKind::Interrupted => {}
                        Err(_) => {
                            slot.conn.dead.store(true, Ordering::Relaxed);
                            slot.eof = true;
                            break;
                        }
                    }
                }
                // Split the buffer into complete lines; at EOF a final
                // unterminated line is processed too (BufRead::lines —
                // the thread model's reader — yields it as well).
                let mut lines: Vec<Vec<u8>> = Vec::new();
                while let Some(pos) = slot.read_buf.iter().position(|&b| b == b'\n') {
                    let mut bytes: Vec<u8> = slot.read_buf.drain(..=pos).collect();
                    bytes.pop();
                    if bytes.last() == Some(&b'\r') {
                        bytes.pop();
                    }
                    lines.push(bytes);
                }
                if slot.eof && !slot.read_buf.is_empty() {
                    lines.push(std::mem::take(&mut slot.read_buf));
                }
                (lines, slot.conn.clone())
            };
            for bytes in lines {
                self.dispatch_line(&conn, bytes);
            }
            // quit stops further reads, like the thread model's `break`
            if conn.pending.lock().unwrap().quit {
                if let Some(Some(slot)) = self.conns.get_mut(idx) {
                    slot.eof = true;
                }
            }
            self.sweep_one(token);
        }

        fn dispatch_line(&mut self, conn: &Arc<ConnShared>, bytes: Vec<u8>) {
            let line = match String::from_utf8(bytes) {
                Ok(s) => s,
                Err(_) => {
                    // the thread model's `lines()` iterator errors and
                    // drops the connection on invalid UTF-8
                    conn.dead.store(true, Ordering::Relaxed);
                    return;
                }
            };
            if line.trim().is_empty() {
                return;
            }
            {
                let mut p = conn.pending.lock().unwrap();
                if p.quit {
                    return;
                }
                // quit stops reading but queued lines still get their
                // responses — the thread oracle reaches quit only after
                // answering everything before it
                if line.trim() == "quit" {
                    p.quit = true;
                    return;
                }
                if p.running {
                    if p.lines.len() >= self.queue_cap {
                        drop(p);
                        self.reject(conn);
                    } else {
                        p.lines.push_back(line);
                    }
                    return;
                }
                p.running = true;
            }
            let job: Job = {
                let conn = conn.clone();
                let state = self.state.clone();
                let metrics = self.metrics.clone();
                let shared = self.shared.clone();
                Box::new(move || worker_job(conn, line, state, metrics, shared))
            };
            if self.pool.try_submit(job).is_err() {
                conn.pending.lock().unwrap().running = false;
                self.reject(conn);
            }
        }

        /// Queue-full backpressure: a clean protocol error line instead
        /// of an unbounded queue or a hang.
        fn reject(&self, conn: &ConnShared) {
            Metrics::incr(&self.metrics.requests_total);
            Metrics::incr(&self.metrics.queue_full_rejects);
            let resp = err_json(format!(
                "server busy: worker queue full (cap {}); retry shortly",
                self.pool.cap()
            ));
            let mut text = resp.to_string();
            text.push('\n');
            conn.out.lock().unwrap().extend(text.as_bytes());
            drain_output(conn, &self.shared);
        }

        fn sweep_dirty(&mut self) {
            let tokens = std::mem::take(&mut *self.shared.dirty.lock().unwrap());
            for t in tokens {
                self.sweep_one(t);
            }
        }

        /// Re-examine one connection: arm/disarm write interest to match
        /// the outbound buffer, retire finished reads, close when done.
        fn sweep_one(&mut self, token: u64) {
            let idx = Self::slot_idx(token);
            let poller = &self.poller;
            let must_close = {
                let slot = match self.conns.get_mut(idx).and_then(|s| s.as_mut()) {
                    Some(s) => s,
                    None => return,
                };
                'decide: {
                    if slot.conn.dead.load(Ordering::Relaxed) {
                        break 'decide true;
                    }
                    let want_write = !slot.conn.out.lock().unwrap().is_empty();
                    let want_read = !slot.eof;
                    if want_write != slot.write_armed || (slot.eof && !slot.read_off) {
                        let ok = poller
                            .reregister(slot.conn.stream.as_raw_fd(), token, want_read, want_write)
                            .is_ok();
                        if !ok {
                            slot.conn.dead.store(true, Ordering::Relaxed);
                            break 'decide true;
                        }
                        slot.write_armed = want_write;
                        slot.read_off = !want_read;
                    }
                    if slot.eof && !want_write {
                        let p = slot.conn.pending.lock().unwrap();
                        break 'decide !p.running && p.lines.is_empty();
                    }
                    false
                }
            };
            if must_close {
                self.close(idx);
            }
        }

        fn close(&mut self, idx: usize) {
            if let Some(slot) = self.conns.get_mut(idx).and_then(|s| s.take()) {
                let _ = self.poller.deregister(slot.conn.stream.as_raw_fd());
                // a worker may still hold the Arc briefly; shutting the
                // socket down now makes its writes fail fast
                let _ = slot.conn.stream.shutdown(std::net::Shutdown::Both);
                self.metrics.conn_closed();
                self.free.push(idx);
            }
        }

        /// Orderly shutdown: stop reading, give in-flight requests and
        /// outbound buffers a bounded window to flush, then close
        /// everything and join the pool.
        fn drain_and_close(&mut self, events: &mut Vec<PollEvent>) {
            let deadline = Instant::now() + DRAIN;
            loop {
                let busy = self.conns.iter().flatten().any(|s| {
                    if s.conn.dead.load(Ordering::Relaxed) {
                        return false;
                    }
                    let p = s.conn.pending.lock().unwrap();
                    let inflight = p.running || !p.lines.is_empty();
                    drop(p);
                    inflight || !s.conn.out.lock().unwrap().is_empty()
                });
                if !busy || Instant::now() >= deadline {
                    break;
                }
                if self.poller.wait(events, 20).is_err() {
                    break;
                }
                for i in 0..events.len() {
                    let PollEvent { token, writable, .. } = events[i];
                    match token {
                        TOKEN_LISTENER => {}
                        TOKEN_WAKE => self.drain_wake(),
                        t if writable => self.conn_writable(t),
                        _ => {}
                    }
                }
                self.sweep_dirty();
            }
            for idx in 0..self.conns.len() {
                self.close(idx);
            }
        }
    }

    /// Start the event loop on `listener`: one `fastkqr-io` thread plus
    /// `workers` `fastkqr-worker-*` threads. Returns the loop's join
    /// handle and the shared wake handle (for `Server::shutdown`).
    pub(crate) fn spawn_event_loop(
        listener: TcpListener,
        state: Arc<ProtocolState>,
        metrics: Arc<Metrics>,
        stop: Arc<AtomicBool>,
        workers: usize,
        queue_cap: usize,
    ) -> anyhow::Result<(JoinHandle<()>, Arc<LoopShared>)> {
        use anyhow::Context;
        listener.set_nonblocking(true).context("set listener nonblocking")?;
        let (wake_rx, wake_tx) = UnixStream::pair().context("wake channel")?;
        wake_rx.set_nonblocking(true).context("wake rx nonblocking")?;
        wake_tx.set_nonblocking(true).context("wake tx nonblocking")?;
        let poller = Poller::new().context("create poller")?;
        poller
            .register(listener.as_raw_fd(), TOKEN_LISTENER, true, false)
            .context("register listener")?;
        poller
            .register(wake_rx.as_raw_fd(), TOKEN_WAKE, true, false)
            .context("register wake channel")?;
        let shared = Arc::new(LoopShared { dirty: Mutex::new(Vec::new()), wake_tx });
        metrics.worker_threads.store(workers as u64, Ordering::Relaxed);
        let el = EventLoop {
            poller,
            listener,
            wake_rx,
            conns: Vec::new(),
            free: Vec::new(),
            pool: WorkerPool::spawn(workers, queue_cap, "fastkqr-worker"),
            state,
            metrics,
            shared: shared.clone(),
            stop,
            queue_cap,
        };
        let handle = std::thread::Builder::new()
            .name("fastkqr-io".into())
            .spawn(move || el.run())
            .context("spawn io thread")?;
        Ok((handle, shared))
    }
}

/// Targets without epoll/kqueue: [`IoModel::resolve`] never yields
/// `Epoll` here, so this stub only satisfies the type/signature.
#[cfg(not(any(target_os = "linux", target_os = "macos")))]
mod stub {
    use super::super::metrics::Metrics;
    use super::super::protocol::ProtocolState;
    use std::net::TcpListener;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;
    use std::thread::JoinHandle;

    pub(crate) struct LoopShared;

    impl LoopShared {
        pub(crate) fn wake(&self) {}
    }

    pub(crate) fn spawn_event_loop(
        _listener: TcpListener,
        _state: Arc<ProtocolState>,
        _metrics: Arc<Metrics>,
        _stop: Arc<AtomicBool>,
        _workers: usize,
        _queue_cap: usize,
    ) -> anyhow::Result<(JoinHandle<()>, Arc<LoopShared>)> {
        anyhow::bail!("event-driven io is not supported on this target")
    }
}

#[cfg(test)]
mod tests {
    use super::super::metrics::Metrics;
    use super::*;
    use std::sync::atomic::Ordering;
    use std::sync::mpsc;

    #[test]
    fn io_model_parses_and_resolves() {
        assert_eq!(IoModel::parse("epoll").unwrap(), IoModel::Epoll);
        assert_eq!(IoModel::parse("KQUEUE").unwrap(), IoModel::Epoll);
        assert_eq!(IoModel::parse("threads").unwrap(), IoModel::Threads);
        assert_eq!(IoModel::parse("auto").unwrap(), IoModel::Auto);
        assert!(IoModel::parse("tokio").is_err());
        // Threads always resolves; Auto resolves to a concrete model
        assert_eq!(IoModel::Threads.resolve().unwrap(), IoModel::Threads);
        let auto = IoModel::Auto.resolve().unwrap();
        assert!(auto == IoModel::Epoll || auto == IoModel::Threads);
        assert_eq!(auto == IoModel::Epoll, IoModel::event_supported());
    }

    #[test]
    fn worker_pool_runs_jobs_and_bounds_the_queue() {
        let pool = WorkerPool::spawn(1, 1, "test-pool");
        let (started_tx, started_rx) = mpsc::channel::<()>();
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        // job 1: occupies the single worker until released
        pool.try_submit(Box::new(move || {
            started_tx.send(()).unwrap();
            gate_rx.recv().unwrap();
        }))
        .unwrap_or_else(|_| panic!("first submit must fit"));
        started_rx.recv().unwrap(); // worker has dequeued job 1
        let (done_tx, done_rx) = mpsc::channel::<u32>();
        let done_tx2 = done_tx.clone();
        // job 2: fills the queue (cap 1)
        pool.try_submit(Box::new(move || done_tx2.send(2).unwrap()))
            .unwrap_or_else(|_| panic!("second submit fills the queue"));
        // job 3: rejected — backpressure, not blocking
        assert!(pool.try_submit(Box::new(move || done_tx.send(3).unwrap())).is_err());
        gate_tx.send(()).unwrap();
        assert_eq!(done_rx.recv().unwrap(), 2);
        pool.shutdown(); // joins cleanly with an empty queue
    }

    #[test]
    fn worker_pool_survives_panicking_jobs() {
        let pool = WorkerPool::spawn(1, 4, "test-panic");
        pool.try_submit(Box::new(|| panic!("request exploded")))
            .unwrap_or_else(|_| panic!("submit"));
        let (tx, rx) = mpsc::channel::<()>();
        pool.try_submit(Box::new(move || tx.send(()).unwrap()))
            .unwrap_or_else(|_| panic!("submit after panic"));
        // the worker outlived the panic and ran the next job
        rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        pool.shutdown();
    }

    #[test]
    fn env_knob_resolvers_prefer_explicit_config() {
        assert_eq!(resolve_workers(3), 3);
        assert!(resolve_workers(0) >= 1);
        assert_eq!(resolve_queue_cap(7), 7);
        assert!(resolve_queue_cap(0) >= 1);
    }

    #[test]
    fn metrics_worker_gauges_exist() {
        let m = Metrics::new();
        m.worker_threads.store(4, Ordering::Relaxed);
        let now = m.workers_busy.fetch_add(1, Ordering::Relaxed) + 1;
        m.workers_busy_peak.fetch_max(now, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get_f64("worker_threads"), Some(4.0));
        assert_eq!(j.get_f64("workers_busy_peak"), Some(1.0));
    }
}
