//! Level-1/2/3 dense kernels (hand-rolled BLAS substrate).
//!
//! The fastkqr hot path is two GEMVs per APGD iteration against the
//! eigenbasis U (see `spectral`). The level-1 primitives (`dot`/`axpy`/
//! `scal`) delegate to the runtime-resolved SIMD dispatch table
//! (`linalg::simd`): AVX2/NEON microkernels where the CPU supports them,
//! otherwise the scalar reference kernels with 4-way unrolled
//! accumulators. Both tiers produce bitwise-identical results (the SIMD
//! lanes mirror the scalar accumulator structure), so everything built
//! on top — GEMV, GEMVᵀ, the cache-blocked GEMM — inherits exact parity
//! with the pre-SIMD code path.

use super::matrix::Matrix;
use super::simd::{self, SimdDispatch};

/// Dot product with 4 accumulators reduced as `(s0+s1)+(s2+s3)`.
/// Dispatched: one 4-lane vector on AVX2/NEON, 4 scalar accumulators
/// otherwise — bitwise-identical either way.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    (simd::global().dot)(a, b)
}

/// y <- alpha*x + y (elementwise, dispatched; lane width cannot change
/// rounding).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    (simd::global().axpy)(alpha, x, y)
}

/// x <- alpha*x (elementwise, dispatched).
#[inline]
pub fn scal(alpha: f64, x: &mut [f64]) {
    (simd::global().scal)(alpha, x)
}

/// Sum of entries.
#[inline]
pub fn asum_signed(x: &[f64]) -> f64 {
    x.iter().sum()
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// max_i |x_i|
#[inline]
pub fn amax(x: &[f64]) -> f64 {
    x.iter().fold(0.0f64, |m, v| m.max(v.abs()))
}

/// out = A x  (A row-major). Row-wise dot products: each row is a
/// contiguous streaming read, the access pattern the perf pass targets.
///
/// Dispatches to the row-blocked parallel kernel (`linalg::par`) for
/// matrices above the configured serial cutoff; both paths compute each
/// output row in the identical order, so results are bitwise equal.
pub fn gemv(a: &Matrix, x: &[f64], out: &mut [f64]) {
    assert_eq!(a.cols(), x.len(), "gemv: dim mismatch");
    assert_eq!(a.rows(), out.len(), "gemv: out dim mismatch");
    let workers = super::par::global().workers_for(a.rows().min(a.cols()));
    if workers > 1 {
        super::par::par_gemv(a, x, out, workers);
    } else {
        gemv_serial(a, x, out);
    }
}

/// Serial GEMV kernel (the parallel path runs this per row block).
pub fn gemv_serial(a: &Matrix, x: &[f64], out: &mut [f64]) {
    gemv_serial_with(simd::global(), a, x, out)
}

/// Serial GEMV through an explicit dispatch table — benches and parity
/// tests pass `simd::scalar()` here to pin the oracle path.
pub fn gemv_serial_with(t: &SimdDispatch, a: &Matrix, x: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.cols(), x.len());
    debug_assert_eq!(a.rows(), out.len());
    for (i, o) in out.iter_mut().enumerate() {
        *o = (t.dot)(a.row(i), x);
    }
}

/// out = A^T x without materializing A^T: accumulate rows scaled by x_i.
/// Streams A once; `out` stays hot in cache.
///
/// Dispatches to the row-blocked parallel kernel above the serial cutoff
/// (per-thread partials; agrees with serial to rounding, ~1e-12).
pub fn gemv_t(a: &Matrix, x: &[f64], out: &mut [f64]) {
    assert_eq!(a.rows(), x.len(), "gemv_t: dim mismatch");
    assert_eq!(a.cols(), out.len(), "gemv_t: out dim mismatch");
    let workers = super::par::global().workers_for(a.rows().min(a.cols()));
    if workers > 1 {
        super::par::par_gemv_t(a, x, out, workers);
    } else {
        gemv_t_serial(a, x, out);
    }
}

/// Serial GEMVᵀ kernel.
pub fn gemv_t_serial(a: &Matrix, x: &[f64], out: &mut [f64]) {
    gemv_t_serial_with(simd::global(), a, x, out)
}

/// Serial GEMVᵀ through an explicit dispatch table. The `xi != 0.0`
/// zero-skip stays out here (not in the kernel), so both tiers skip the
/// same rows and parity is preserved.
pub fn gemv_t_serial_with(t: &SimdDispatch, a: &Matrix, x: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.rows(), x.len());
    debug_assert_eq!(a.cols(), out.len());
    out.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        if xi != 0.0 {
            (t.axpy)(xi, a.row(i), out);
        }
    }
}

/// C = A * B, cache-blocked (i-k-j loop order keeps B rows streaming).
///
/// Dispatches to the row-blocked parallel kernel above the serial cutoff;
/// C rows are computed in the identical accumulation order either way.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "gemm: inner dim mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let workers = super::par::global().workers_for(m.min(n).min(k));
    if workers > 1 {
        return super::par::par_gemm(a, b, workers);
    }
    gemm_serial(a, b)
}

/// Serial cache-blocked GEMM kernel.
pub fn gemm_serial(a: &Matrix, b: &Matrix) -> Matrix {
    debug_assert_eq!(a.cols(), b.rows());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    const BK: usize = 64;
    for kb in (0..k).step_by(BK) {
        let kend = (kb + BK).min(k);
        for i in 0..m {
            let arow = a.row(i);
            let crow = c.row_mut(i);
            for kk in kb..kend {
                let aik = arow[kk];
                if aik != 0.0 {
                    axpy(aik, b.row(kk), crow);
                }
            }
        }
    }
    c
}

/// Symmetric rank-n product A^T A.
pub fn syrk_t(a: &Matrix) -> Matrix {
    let t = a.transpose();
    gemm(&t, a)
}

/// Quadratic form x^T A y.
pub fn quad_form(a: &Matrix, x: &[f64], y: &[f64]) -> f64 {
    let mut tmp = vec![0.0; a.rows()];
    gemv(a, y, &mut tmp);
    dot(x, &tmp)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_gemv(a: &Matrix, x: &[f64]) -> Vec<f64> {
        (0..a.rows())
            .map(|i| (0..a.cols()).map(|j| a[(i, j)] * x[j]).sum())
            .collect()
    }

    #[test]
    fn dot_handles_remainders() {
        for n in [0usize, 1, 3, 4, 5, 17] {
            let a: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let b: Vec<f64> = (0..n).map(|i| (2 * i) as f64).collect();
            let expect: f64 = (0..n).map(|i| (i * 2 * i) as f64).sum();
            assert_eq!(dot(&a, &b), expect, "n={n}");
        }
    }

    #[test]
    fn gemv_matches_naive() {
        let a = Matrix::from_fn(5, 7, |i, j| ((i + 1) * (j + 2)) as f64 * 0.1);
        let x: Vec<f64> = (0..7).map(|i| i as f64 - 3.0).collect();
        let mut out = vec![0.0; 5];
        gemv(&a, &x, &mut out);
        let expect = naive_gemv(&a, &x);
        for (o, e) in out.iter().zip(&expect) {
            assert!((o - e).abs() < 1e-12);
        }
    }

    #[test]
    fn gemv_t_matches_transpose_gemv() {
        let a = Matrix::from_fn(6, 4, |i, j| (i as f64 - j as f64) * 0.3);
        let x: Vec<f64> = (0..6).map(|i| (i as f64).sin()).collect();
        let mut out = vec![0.0; 4];
        gemv_t(&a, &x, &mut out);
        let at = a.transpose();
        let mut expect = vec![0.0; 4];
        gemv(&at, &x, &mut expect);
        for (o, e) in out.iter().zip(&expect) {
            assert!((o - e).abs() < 1e-12);
        }
    }

    #[test]
    fn gemm_matches_naive() {
        let a = Matrix::from_fn(3, 5, |i, j| (i + j) as f64);
        let b = Matrix::from_fn(5, 2, |i, j| (i as f64) - (j as f64) * 2.0);
        let c = gemm(&a, &b);
        for i in 0..3 {
            for j in 0..2 {
                let e: f64 = (0..5).map(|k| a[(i, k)] * b[(k, j)]).sum();
                assert!((c[(i, j)] - e).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gemm_identity_is_noop() {
        let a = Matrix::from_fn(4, 4, |i, j| ((i * 4 + j) as f64).cos());
        let c = gemm(&a, &Matrix::eye(4));
        assert!(a.max_abs_diff(&c) < 1e-15);
    }

    #[test]
    fn quad_form_matches_hand() {
        let a = Matrix::eye(3);
        let x = [1.0, 2.0, 3.0];
        assert!((quad_form(&a, &x, &x) - 14.0).abs() < 1e-14);
    }

    #[test]
    fn axpy_scal_nrm2() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
        scal(0.5, &mut y);
        assert_eq!(y, vec![3.5, 4.5]);
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        assert_eq!(amax(&[-7.0, 2.0]), 7.0);
    }
}
