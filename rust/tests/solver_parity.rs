//! Cross-solver exactness: fastkqr must match the independent IPM solver
//! (the kernlab-class comparator) on the exact objective across a grid of
//! (τ, λ, dataset) combinations, and NCKQR must never lose to the generic
//! solvers — the paper's accuracy claim (Tables 1–6, "obj" columns).

use fastkqr::baselines::{solve_kqr_ipm, solve_kqr_lbfgs, IpmOptions};
use fastkqr::data::{benchmarks, synth, Rng};
use fastkqr::kernel::{median_heuristic_sigma, Kernel};
use fastkqr::kqr::KqrSolver;
use fastkqr::nckqr::NckqrSolver;

#[test]
fn fastkqr_matches_ipm_across_grid() {
    // 3 datasets × 3 τ × 3 λ
    for (seed, n) in [(1u64, 45usize), (2, 60), (3, 35)] {
        let mut rng = Rng::new(seed);
        let d = synth::sine_hetero(n, &mut rng);
        let sigma = median_heuristic_sigma(&d.x);
        let solver = KqrSolver::new(&d.x, &d.y, Kernel::Rbf { sigma }).unwrap();
        for tau in [0.1, 0.5, 0.9] {
            for lam in [0.2, 0.02, 0.002] {
                let fast = solver.fit(tau, lam).expect("fastkqr");
                let ipm = solve_kqr_ipm(solver.gram(), &d.y, tau, lam, &IpmOptions::default())
                    .expect("ipm");
                let rel = (fast.objective - ipm.objective).abs() / (1.0 + ipm.objective);
                assert!(
                    rel < 1e-3,
                    "seed={seed} tau={tau} lam={lam}: fast {} vs ipm {} (rel {rel:.2e})",
                    fast.objective,
                    ipm.objective
                );
                assert!(fast.kkt.pass, "certificate failed at tau={tau} lam={lam}");
            }
        }
    }
}

#[test]
fn fastkqr_matches_ipm_on_benchmark_lookalikes() {
    for (mut data, lam) in [(benchmarks::mcycle(5), 1e-2), (benchmarks::geyser(5), 1e-2)] {
        data.standardize();
        // subsample for test speed (y keeps its physical scale, which
        // stresses the scale-aware tolerances)
        let mut rng = Rng::new(9);
        let idx = rng.permutation(data.n());
        let data = data.subset(&idx[..80]);
        let sigma = median_heuristic_sigma(&data.x);
        let solver = KqrSolver::new(&data.x, &data.y, Kernel::Rbf { sigma }).unwrap();
        let fast = solver.fit(0.5, lam).expect("fastkqr");
        let ipm =
            solve_kqr_ipm(solver.gram(), &data.y, 0.5, lam, &IpmOptions::default()).expect("ipm");
        let rel = (fast.objective - ipm.objective).abs() / (1.0 + ipm.objective.abs());
        assert!(
            rel < 2e-3,
            "{}: fast {} vs ipm {}",
            data.name,
            fast.objective,
            ipm.objective
        );
    }
}

#[test]
fn generic_solvers_never_beat_fastkqr() {
    let mut rng = Rng::new(4);
    let d = synth::yuan(60, &mut rng);
    let sigma = median_heuristic_sigma(&d.x);
    let solver = KqrSolver::new(&d.x, &d.y, Kernel::Rbf { sigma }).unwrap();
    for tau in [0.25, 0.75] {
        let fast = solver.fit(tau, 0.05).unwrap();
        let lb = solve_kqr_lbfgs(solver.gram(), &d.y, tau, 0.05, 2000).unwrap();
        assert!(
            lb.objective >= fast.objective - 1e-7,
            "tau={tau}: lbfgs {} beat exact {}",
            lb.objective,
            fast.objective
        );
    }
}

#[test]
fn nckqr_exactness_and_monotone_crossing_penalty() {
    let mut rng = Rng::new(6);
    let d = synth::sine_hetero(50, &mut rng);
    let sigma = median_heuristic_sigma(&d.x);
    let kernel = Kernel::Rbf { sigma };
    let taus = [0.1, 0.5, 0.9];
    let nc = NckqrSolver::new(&d.x, &d.y, kernel, &taus).unwrap();
    // crossing count decreases with λ₁
    let grid = fastkqr::linalg::Matrix::from_fn(100, 1, |i, _| i as f64 / 99.0);
    let mut last_cross = usize::MAX;
    for lam1 in [0.0, 1.0, 50.0] {
        let fit = nc.fit(lam1, 1e-3).unwrap();
        let c = fit.count_crossings(&grid, 1e-7);
        assert!(c <= last_cross, "crossings increased with lam1={lam1}: {c} > {last_cross}");
        last_cross = c;
    }
    assert_eq!(last_cross, 0, "strong penalty must remove crossings");
}

#[test]
fn cv_pipeline_end_to_end_small() {
    let mut rng = Rng::new(8);
    let data = synth::yuan(60, &mut rng);
    let kernel = Kernel::Rbf { sigma: median_heuristic_sigma(&data.x) };
    let solver = KqrSolver::new(&data.x, &data.y, kernel.clone()).unwrap();
    let lams = solver.lambda_grid(6, 1.0, 1e-4);
    let res =
        fastkqr::cv::cross_validate(&data, &kernel, 0.5, &lams, 3, &solver.opts, &mut rng)
            .unwrap();
    assert!(res.cv_loss.iter().all(|v| v.is_finite()));
    let fit = solver.fit(0.5, res.best_lambda).unwrap();
    assert!(fit.kkt.pass);
}
