//! fastkqr CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   fit        fit one KQR model on a named workload
//!   path       warm-started λ path at one τ
//!   cv         k-fold cross-validated path
//!   nckqr      simultaneous non-crossing fit
//!   serve      start the TCP fit/predict server
//!   client     send one JSON request line to a running server
//!   table1..6  regenerate the paper's tables (quick scale; --paper full)
//!   figure1    regenerate the crossing figure (writes CSV)
//!   ablations  design-choice ablations
//!   perf       hot-path microbenchmarks
//!
//! Common options: --data yuan|friedman|sine|gagurine|mcycle|crabs|boston
//! --n --p --tau --lambda --backend native|xla --seed; see DESIGN.md §5.

use anyhow::{bail, Result};
use fastkqr::backend::{Backend, NativeBackend};
use fastkqr::coordinator::{Server, ServerConfig};
use fastkqr::data::{benchmarks, synth, Dataset, Rng};
use fastkqr::experiments::{self, print_table, speedups, TableConfig};
use fastkqr::kernel::{median_heuristic_sigma, Kernel};
use fastkqr::kqr::apgd::ApgdState;
use fastkqr::kqr::KqrSolver;
use fastkqr::nckqr::NckqrSolver;
use fastkqr::runtime::XlaBackend;
use fastkqr::util::{Args, Json, Timer};

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "fit" => cmd_fit(args),
        "path" => cmd_path(args),
        "grid" => cmd_grid(args),
        "cv" => cmd_cv(args),
        "nckqr" => cmd_nckqr(args),
        "serve" => cmd_serve(args),
        "client" => cmd_client(args),
        "table1" => cmd_table(args, 1),
        "table2" => cmd_table(args, 2),
        "table3" => cmd_table(args, 3),
        "table4" => cmd_table(args, 4),
        "table5" => cmd_table(args, 5),
        "table6" => cmd_table(args, 6),
        "figure1" => cmd_figure1(args),
        "ablations" => cmd_ablations(args),
        "perf" => cmd_perf(args),
        "help" | "--help" => {
            println!("fastkqr {} — exact kernel quantile regression", fastkqr::version());
            println!("subcommands: fit path grid cv nckqr serve client table1..6 figure1 ablations perf");
            println!("see README.md for options");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `fastkqr help`)"),
    }
}

/// Build the dataset selected by --data/--n/--p/--seed.
fn dataset_from_args(args: &Args) -> Result<Dataset> {
    let n = args.get_usize("n", 200);
    let p = args.get_usize("p", 10);
    let seed = args.get_usize("seed", 2024) as u64;
    let mut rng = Rng::new(seed);
    Ok(match args.get_str("data", "yuan") {
        "yuan" => synth::yuan(n, &mut rng),
        "friedman" => synth::friedman(n, p, 3.0, &mut rng),
        "sine" => synth::sine_hetero(n, &mut rng),
        "gagurine" => benchmarks::gagurine(seed),
        "mcycle" => benchmarks::mcycle(seed),
        "crabs" => benchmarks::crabs(seed),
        "boston" => benchmarks::boston_housing(seed),
        "geyser" => benchmarks::geyser(seed),
        other => bail!("unknown --data {other:?}"),
    })
}

fn kernel_from_args(args: &Args, data: &Dataset) -> Kernel {
    match args.get("sigma") {
        Some(s) => Kernel::Rbf { sigma: s.parse().unwrap_or(1.0) },
        None => Kernel::Rbf { sigma: median_heuristic_sigma(&data.x) },
    }
}

fn backend_from_args(args: &Args) -> Result<Box<dyn Backend>> {
    match args.get_str("backend", "native") {
        "native" => Ok(Box::new(NativeBackend::new())),
        "xla" => Ok(Box::new(XlaBackend::from_default_dir()?)),
        other => bail!("unknown --backend {other:?} (native|xla)"),
    }
}

fn cmd_fit(args: &Args) -> Result<()> {
    let data = dataset_from_args(args)?;
    let kernel = kernel_from_args(args, &data);
    let tau = args.get_f64("tau", 0.5);
    let lambda = args.get_f64("lambda", 1e-2);
    let mut backend = backend_from_args(args)?;
    let mut timer = Timer::start("fit");
    let solver = KqrSolver::new(&data.x, &data.y, kernel)?;
    let setup = timer.lap();
    let mut state = ApgdState::zeros(solver.n());
    let fit = solver.fit_warm(tau, lambda, &mut state, backend.as_mut())?;
    let solve = timer.lap();
    println!("dataset        {}", data.name);
    println!("backend        {}", backend.name());
    println!("tau/lambda     {tau} / {lambda}");
    println!("objective      {:.6}", fit.objective);
    println!(
        "kkt            pass={} stat={:.2e} intercept={:.2e}",
        fit.kkt.pass, fit.kkt.max_stationarity, fit.kkt.intercept
    );
    println!(
        "gamma_final    {:.2e}   |singular set| {}",
        fit.gamma_final,
        fit.singular_set.len()
    );
    println!("apgd iters     {}", fit.apgd_iters);
    println!("setup/solve    {setup:.3}s / {solve:.3}s");
    Ok(())
}

fn cmd_path(args: &Args) -> Result<()> {
    let data = dataset_from_args(args)?;
    let kernel = kernel_from_args(args, &data);
    let tau = args.get_f64("tau", 0.5);
    let nlam = args.get_usize("nlam", 50);
    let mut backend = backend_from_args(args)?;
    let solver = KqrSolver::new(&data.x, &data.y, kernel)?;
    let lams = solver.lambda_grid(nlam, args.get_f64("lambda-max", 1.0), 1e-4);
    let timer = Timer::start("path");
    let fits = solver.fit_path_with_backend(tau, &lams, backend.as_mut())?;
    let total = timer.total();
    println!("{:<12} {:<14} {:<10} {:<8} {:<6}", "lambda", "objective", "iters", "|S|", "kkt");
    for f in &fits {
        println!(
            "{:<12.4e} {:<14.6} {:<10} {:<8} {:<6}",
            f.lam,
            f.objective,
            f.apgd_iters,
            f.singular_set.len(),
            f.kkt.pass
        );
    }
    println!("total {total:.3}s for {} fits ({} backend)", fits.len(), backend.name());
    Ok(())
}

/// Fit a whole τ×λ grid on one cached eigenbasis through the engine.
/// `FASTKQR_LOCKSTEP=1` (or --lockstep / --no-lockstep overriding it)
/// selects the BLAS-3 lockstep driver; default is the sequential path.
fn cmd_grid(args: &Args) -> Result<()> {
    let data = dataset_from_args(args)?;
    let kernel = kernel_from_args(args, &data);
    let taus = args.get_f64_list("taus", &[0.1, 0.25, 0.5, 0.75, 0.9]);
    let nlam = args.get_usize("nlam", 8);
    let lockstep = if args.flag("lockstep") {
        Some(true)
    } else if args.flag("no-lockstep") {
        Some(false)
    } else {
        None // defer to FASTKQR_LOCKSTEP
    };
    let engine = fastkqr::engine::FitEngine::with_config(fastkqr::engine::EngineConfig {
        lockstep,
        ..Default::default()
    });
    let solver = engine.solver_for(&data, &kernel)?;
    let lams = solver.lambda_grid(nlam, args.get_f64("lambda-max", 1.0), 1e-4);
    let timer = Timer::start("grid");
    let grid = engine.fit_grid(&data.x, &data.y, &kernel, &taus, &lams)?;
    let total = timer.total();
    println!("{:<8} {:<12} {:<14} {:<10} {:<6}", "tau", "lambda", "objective", "iters", "kkt");
    for (ti, &tau) in grid.taus.iter().enumerate() {
        for (li, &lam) in grid.lambdas.iter().enumerate() {
            let f = grid.at(ti, li);
            println!(
                "{tau:<8} {lam:<12.4e} {:<14.6} {:<10} {:<6}",
                f.objective, f.apgd_iters, f.kkt.pass
            );
        }
    }
    let pass = grid.fits.iter().flatten().filter(|f| f.kkt.pass).count();
    println!(
        "grid {}x{}: {pass}/{} kkt pass, {} total iters, {total:.3}s",
        grid.taus.len(),
        grid.lambdas.len(),
        grid.taus.len() * grid.lambdas.len(),
        grid.total_iters()
    );
    if let Some(stats) = grid.lockstep {
        println!(
            "lockstep: bundle peak {} cells, {} chunks, {} retired",
            stats.max_active, stats.chunks, stats.retired
        );
    }
    Ok(())
}

fn cmd_cv(args: &Args) -> Result<()> {
    let data = dataset_from_args(args)?;
    let kernel = kernel_from_args(args, &data);
    let tau = args.get_f64("tau", 0.5);
    let nlam = args.get_usize("nlam", 20);
    let folds = args.get_usize("folds", 5);
    let mut rng = Rng::new(args.get_usize("seed", 2024) as u64 ^ 0xc5);
    // Engine-backed solver: the basis computed here lands in the global
    // cache, so the CV refit on the full data reuses it for free.
    let solver = fastkqr::engine::FitEngine::global().solver_for(&data, &kernel)?;
    let lams = solver.lambda_grid(nlam, 1.0, 1e-4);
    let timer = Timer::start("cv");
    let res =
        fastkqr::cv::cross_validate(&data, &kernel, tau, &lams, folds, &solver.opts, &mut rng)?;
    println!("{:<12} {}", "lambda", "cv pinball");
    for (l, v) in res.lambdas.iter().zip(&res.cv_loss) {
        let mark = if *l == res.best_lambda { "  <- best" } else { "" };
        println!("{l:<12.4e} {v:.6}{mark}");
    }
    println!("best lambda {:.4e} in {:.3}s", res.best_lambda, timer.total());
    if let Some(refit) = &res.refit {
        println!(
            "refit at best lambda: objective {:.6}  kkt pass={}",
            refit.objective, refit.kkt.pass
        );
    }
    Ok(())
}

fn cmd_nckqr(args: &Args) -> Result<()> {
    let data = dataset_from_args(args)?;
    let kernel = kernel_from_args(args, &data);
    let taus = args.get_f64_list("taus", &[0.1, 0.3, 0.5, 0.7, 0.9]);
    let lam1 = args.get_f64("lam1", 10.0);
    let lam2 = args.get_f64("lam2", 1e-2);
    let solver = NckqrSolver::new(&data.x, &data.y, kernel, &taus)?;
    let timer = Timer::start("nckqr");
    let fit = solver.fit(lam1, lam2)?;
    let crossings = fit.count_crossings(&data.x, 1e-9);
    println!("dataset     {}", data.name);
    println!("taus        {taus:?}  lam1={lam1}  lam2={lam2}");
    println!("objective   {:.6}", fit.objective);
    println!("kkt         pass={} stat={:.2e}", fit.kkt.pass, fit.kkt.max_stationarity);
    println!("crossings   {crossings} (training points)");
    println!("mm iters    {}   time {:.3}s", fit.mm_iters, timer.total());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7787").to_string();
    let server = Server::spawn(ServerConfig { addr: addr.clone(), opts: Default::default() })?;
    println!("fastkqr {} serving on {}", fastkqr::version(), server.local_addr);
    println!("protocol: one JSON request per line; try: {{\"cmd\":\"ping\"}}");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7787");
    let req = args
        .get("json")
        .map(String::from)
        .unwrap_or_else(|| r#"{"cmd":"ping"}"#.to_string());
    let mut client = fastkqr::coordinator::server::Client::connect(addr)?;
    let resp = client.request(&Json::parse(&req).map_err(|e| anyhow::anyhow!("{e}"))?)?;
    println!("{}", resp.to_string());
    Ok(())
}

fn cmd_table(args: &Args, which: usize) -> Result<()> {
    let mut cfg = TableConfig::from_args(args);
    let cells = match which {
        1 => {
            if args.flag("paper") && args.get("p").is_none() {
                cfg.p = 5000;
            }
            experiments::kqr_tables::table1(&cfg)?
        }
        2 => {
            if args.get("solvers").is_none() {
                cfg.solvers = vec!["fastkqr".into(), "proximal".into(), "lbfgs".into()];
            }
            experiments::nckqr_tables::table2(&cfg, args.get_f64("lam1", 1.0))?
        }
        3 => {
            cfg.p = args.get_usize("p", 100);
            experiments::kqr_tables::table3(&cfg)?
        }
        4 => experiments::kqr_tables::table4(&cfg)?,
        5 => {
            let cap = if args.flag("paper") { None } else { Some(args.get_usize("cap", 120)) };
            experiments::kqr_tables::table5(&cfg, cap)?
        }
        6 => {
            if args.get("solvers").is_none() {
                cfg.solvers = vec!["fastkqr".into(), "proximal".into()];
            }
            let cap = if args.flag("paper") { None } else { Some(args.get_usize("cap", 100)) };
            experiments::nckqr_tables::table6(&cfg, args.get_f64("lam1", 1.0), cap)?
        }
        _ => unreachable!(),
    };
    print_table(&format!("Table {which}"), &cells, &cfg.solvers);
    println!("\nspeedups of fastkqr:");
    for (label, n, solver, factor) in speedups(&cells) {
        println!("  {label} n={n}: {factor:.1}x vs {solver}");
    }
    Ok(())
}

fn cmd_figure1(args: &Args) -> Result<()> {
    let seed = args.get_usize("seed", 2025) as u64;
    let lam = args.get_f64("lambda", 2e-5);
    let lam1 = args.get_f64("lam1", 5.0);
    let out = args.get_str("out", "out/figure1");
    let res = experiments::figure1::run(seed, lam, lam1, args.get_usize("grid", 200))?;
    experiments::figure1::write_csv(&res, out)?;
    println!("Figure 1 (GAGurine lookalike, 5 quantile levels)");
    println!("  individual fits: {} crossing violations on the grid", res.crossings_individual);
    println!("  NCKQR joint fit: {} crossing violations", res.crossings_joint);
    println!("  curves written to {out}/figure1_*.csv");
    Ok(())
}

fn cmd_ablations(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 100);
    let seed = args.get_usize("seed", 2024) as u64;
    let mut rows = Vec::new();
    rows.extend(experiments::ablations::spectral_vs_dense(n, args.get_usize("plans", 8), seed)?);
    rows.extend(experiments::ablations::warm_vs_cold(n, args.get_usize("nlam", 20), seed)?);
    rows.extend(experiments::ablations::solver_switches(n.min(80), seed)?);
    rows.extend(experiments::ablations::nckqr_ridge(n.min(60), seed)?);
    experiments::ablations::print_rows(&rows);
    Ok(())
}

fn cmd_perf(args: &Args) -> Result<()> {
    let reps = args.get_usize("reps", 20);
    for n in args.get_usize_list("ns", &[128, 256, 512, 1024]) {
        let (stats, gbps) = experiments::perf::gemv_throughput(n, reps);
        println!("{}  ({gbps:.2} GB/s effective)", stats.report_line());
    }
    for n in args.get_usize_list("chunk-ns", &[64, 256]) {
        for s in experiments::perf::chunk_cost(n, reps.min(10))? {
            println!("{}", s.report_line());
        }
    }
    for n in args.get_usize_list("eig-ns", &[128, 512]) {
        println!("{}", experiments::perf::eigen_cost(n, 3).report_line());
    }
    println!(
        "{}",
        experiments::perf::fit_latency(args.get_usize("fit-n", 200), 3).report_line()
    );
    Ok(())
}
