//! Low-rank (Nyström) scaling trajectory: wall time and in-sample check
//! loss vs the landmark count m at a fixed n, against the exact dense
//! baseline at the same n. Writes the machine-readable baseline to
//! `BENCH_lowrank.json` (override with `--out`) so the scale trajectory
//! of future PRs has a recorded starting point.
//!
//! Expectation (ISSUE 4): setup drops from O(n³) to O(n·m² + m³) and
//! per-iteration cost from O(n²) to O(n·m), so wall time falls steeply
//! with m while the check loss approaches the dense baseline as m grows.

use fastkqr::data::{synth, Rng};
use fastkqr::engine::{ApproxSpec, EngineConfig, FitEngine};
use fastkqr::kernel::{median_heuristic_sigma, Kernel};
use fastkqr::smooth::pinball_loss;
use fastkqr::util::{Args, Json};
use std::time::Instant;

fn fit_once(
    engine: &FitEngine,
    data: &fastkqr::data::Dataset,
    kernel: &Kernel,
    approx: ApproxSpec,
    tau: f64,
    lam: f64,
) -> (f64, f64, usize) {
    let t0 = Instant::now();
    let solver = engine
        .solver_approx(&data.x, &data.y, kernel, approx, engine.config.opts.clone())
        .expect("solver");
    let fit = solver.fit(tau, lam).expect("fit");
    let secs = t0.elapsed().as_secs_f64();
    let loss = pinball_loss(&data.y, &fit.predict(&data.x), tau);
    (secs, loss, fit.apgd_iters)
}

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 768);
    let tau = args.get_f64("tau", 0.5);
    let lam = args.get_f64("lambda", 1e-2);
    let ms: Vec<usize> = {
        let def = [32usize, 64, 128, 256];
        args.get_usize_list("ms", &def).into_iter().filter(|&m| m <= n).collect()
    };
    let seed = args.get_usize("seed", 2024) as u64;
    let out = args.get_str("out", "BENCH_lowrank.json").to_string();

    let mut rng = Rng::new(seed);
    let data = synth::sine_hetero(n, &mut rng);
    let kernel = Kernel::Rbf { sigma: median_heuristic_sigma(&data.x) };
    println!("-- nystrom scaling: n={n}, tau={tau}, lambda={lam:.1e} --");

    // Dense baseline at the same n (fresh engine: cold factorization).
    let dense_engine = FitEngine::with_config(EngineConfig::default());
    let (dense_secs, dense_loss, dense_iters) =
        fit_once(&dense_engine, &data, &kernel, ApproxSpec::Exact, tau, lam);
    println!(
        "   exact     n={n:<5}           {dense_secs:8.3}s   check-loss {dense_loss:.6}  \
         ({dense_iters} iters)"
    );

    let mut rows = Vec::new();
    for &m in &ms {
        let engine = FitEngine::with_config(EngineConfig::default());
        let (secs, loss, iters) =
            fit_once(&engine, &data, &kernel, ApproxSpec::Nystrom { m, seed }, tau, lam);
        let speedup = dense_secs / secs.max(1e-12);
        let loss_gap = loss - dense_loss;
        println!(
            "   nystrom   m={m:<5} ({speedup:5.2}x) {secs:8.3}s   check-loss {loss:.6}  \
             (gap {loss_gap:+.2e}, {iters} iters)"
        );
        rows.push(Json::obj(vec![
            ("m", Json::num(m as f64)),
            ("secs", Json::num(secs)),
            ("check_loss", Json::num(loss)),
            ("loss_gap_vs_dense", Json::num(loss_gap)),
            ("speedup_vs_dense", Json::num(speedup)),
            ("apgd_iters", Json::num(iters as f64)),
        ]));
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("nystrom_scaling")),
        ("n", Json::num(n as f64)),
        ("tau", Json::num(tau)),
        ("lambda", Json::num(lam)),
        ("seed", Json::num(seed as f64)),
        (
            "dense",
            Json::obj(vec![
                ("secs", Json::num(dense_secs)),
                ("check_loss", Json::num(dense_loss)),
                ("apgd_iters", Json::num(dense_iters as f64)),
            ]),
        ),
        ("lowrank", Json::Arr(rows)),
    ]);
    std::fs::write(&out, doc.to_string()).expect("write BENCH_lowrank.json");
    println!("wrote {out}");
}
