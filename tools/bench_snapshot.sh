#!/usr/bin/env bash
# Record a dated bench snapshot under benchmarks/<name>/ and diff it
# against the previous one. Usage: tools/bench_snapshot.sh [name]
# (name defaults to today's ISO date; pass e.g. "2026-08-08-avx2" to
# keep several machines apart).
set -euo pipefail

repo="$(cd "$(dirname "$0")/.." && pwd)"
name="${1:-$(date +%F)}"
dest="$repo/benchmarks/$name"
mkdir -p "$dest"

cd "$repo/rust"
cargo bench --bench grid_lockstep -- --out "$dest/BENCH_grid.json"
cargo bench --bench serve_throughput -- --out "$dest/BENCH_serve.json"
cargo bench --bench nystrom_scaling -- --out "$dest/BENCH_lowrank.json"

echo
python3 "$repo/tools/bench_diff.py"
