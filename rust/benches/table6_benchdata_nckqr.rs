//! Table 6 (supplement): NCKQR on the benchmark-data lookalikes (5 taus).
use fastkqr::experiments::{nckqr_tables, print_table, speedups, TableConfig};
use fastkqr::util::Args;

fn main() {
    let args = Args::from_env();
    let mut cfg = TableConfig::from_args(&args);
    if args.get("solvers").is_none() {
        cfg.solvers = vec!["fastkqr".into(), "proximal".into()];
    }
    if args.get("nlam").is_none() && !args.flag("paper") {
        cfg.nlam = 3;
    }
    if args.get("reps").is_none() && !args.flag("paper") {
        cfg.reps = 2;
    }
    let cap = if args.flag("paper") { None } else { Some(args.get_usize("cap", 100)) };
    let cells = nckqr_tables::table6(&cfg, args.get_f64("lam1", 1.0), cap).expect("table6");
    print_table("Table 6 — benchmark data (NCKQR)", &cells, &cfg.solvers);
    for (label, n, solver, factor) in speedups(&cells) {
        println!("speedup {label} n={n}: {factor:.1}x vs {solver}");
    }
}
