//! TCP fit/predict server (line-JSON protocol; see
//! [`protocol`](super::protocol)).
//!
//! Two connection layers share one protocol implementation:
//!
//! - **threads** — the original thread-per-connection model (std::net +
//!   blocking reads). Simple, portable, and kept as the bitwise-parity
//!   oracle for the event loop; the default on targets without a
//!   readiness poller.
//! - **epoll** — the event-driven model ([`super::eventloop`]): one
//!   nonblocking I/O thread multiplexing every connection over raw
//!   epoll/kqueue, dispatching complete request lines to a bounded
//!   worker pool. Thousands of idle connections cost file descriptors,
//!   not threads. The default on Linux/macOS.
//!
//! Selected by [`ServerConfig::io_model`] / `FASTKQR_IO=epoll|threads|
//! auto`. Both layers produce byte-identical response streams for the
//! same request sequence (including multi-line streamed predicts) — the
//! tests in `tests/eventloop.rs` hold them to that.
//!
//! With a persistence directory configured the server can also poll the
//! directory's generation manifest (`FASTKQR_MANIFEST_POLL_MS`), hot-
//! swapping models written by *other* replicas sharing the directory —
//! see [`ModelRegistry::refresh`] and [`super::router`].

use super::batcher::BatchConfig;
use super::eventloop::{self, IoModel};
use super::metrics::Metrics;
use super::protocol::{err_json, handle_request, ProtocolState};
use super::registry::ModelRegistry;
use super::router::{read_line_tick, LineRead};
use crate::kqr::SolveOptions;
use anyhow::{Context, Result};
use std::io::{BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    pub opts: SolveOptions,
    /// Artifact directory for the model registry: fitted models are
    /// written through as versioned JSON artifacts and reloaded on the
    /// next spawn, so the server survives restarts (`None` = in-memory
    /// only).
    pub persist_dir: Option<String>,
    /// Predict micro-batching knobs; the default reads
    /// `FASTKQR_BATCH_WINDOW_US` / `FASTKQR_BATCH_MAX_ROWS` from the
    /// environment at config construction.
    pub batch: BatchConfig,
    /// Connection layer (the default reads `FASTKQR_IO` at config
    /// construction; `Auto` resolves to the event loop where supported).
    pub io_model: IoModel,
    /// Worker threads behind the event loop (0 = `FASTKQR_WORKERS`,
    /// default number of cores). Ignored by the thread model.
    pub workers: usize,
    /// Worker-queue backpressure cap (0 = `FASTKQR_QUEUE_CAP`, default
    /// 1024). Ignored by the thread model.
    pub queue_cap: usize,
    /// Registry id scope for replicas sharing one persistence dir:
    /// generated ids become `"{scope}m{seq}"` (see
    /// [`ModelRegistry::with_persistence_scoped`]). `None` = unscoped.
    pub scope: Option<String>,
    /// Manifest poll interval for hot-swapping peer writes. `None` reads
    /// `FASTKQR_MANIFEST_POLL_MS` (default 200); `Some(0)` disables
    /// polling. Only meaningful with `persist_dir` set.
    pub manifest_poll_ms: Option<u64>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7787".to_string(),
            opts: SolveOptions::default(),
            persist_dir: None,
            batch: BatchConfig::from_env(),
            io_model: IoModel::from_env(),
            workers: 0,
            queue_cap: 0,
            scope: None,
            manifest_poll_ms: None,
        }
    }
}

fn resolve_manifest_poll_ms(config: &ServerConfig) -> u64 {
    match config.manifest_poll_ms {
        Some(ms) => ms,
        None => std::env::var("FASTKQR_MANIFEST_POLL_MS")
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok())
            .unwrap_or(200),
    }
}

/// A running server handle.
pub struct Server {
    pub local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    poll_thread: Option<JoinHandle<()>>,
    /// Wake handle of the event loop (None under the thread model).
    loop_shared: Option<Arc<eventloop::LoopShared>>,
    pub registry: Arc<ModelRegistry>,
    pub metrics: Arc<Metrics>,
}

impl Server {
    /// Bind and start accepting connections on a background thread.
    pub fn spawn(config: ServerConfig) -> Result<Server> {
        let io = config.io_model.resolve()?;
        let listener =
            TcpListener::bind(&config.addr).with_context(|| format!("bind {}", config.addr))?;
        let local_addr = listener.local_addr()?;
        let scope = config.scope.as_deref().unwrap_or("");
        let registry = Arc::new(match &config.persist_dir {
            Some(dir) => ModelRegistry::with_persistence_scoped(dir, scope)
                .with_context(|| format!("open model persistence dir {dir}"))?,
            None => ModelRegistry::new(),
        });
        let metrics = Arc::new(Metrics::new());
        let _ = metrics.io_model.set(io.label());
        let stop = Arc::new(AtomicBool::new(false));
        let state = Arc::new(ProtocolState::new(
            registry.clone(),
            metrics.clone(),
            config.opts,
            // the process-global engine: concurrent connections (and any
            // co-located scheduler) share one Gram/basis per dataset
            crate::engine::FitEngine::global().clone(),
            config.batch.clone(),
        ));
        let (accept_thread, loop_shared) = match io {
            IoModel::Epoll => {
                let workers = eventloop::resolve_workers(config.workers);
                let queue_cap = eventloop::resolve_queue_cap(config.queue_cap);
                let (handle, shared) = eventloop::spawn_event_loop(
                    listener,
                    state,
                    metrics.clone(),
                    stop.clone(),
                    workers,
                    queue_cap,
                )?;
                (handle, Some(shared))
            }
            IoModel::Threads | IoModel::Auto => {
                (spawn_accept_loop(listener, state, metrics.clone(), stop.clone())?, None)
            }
        };
        // Manifest poller: hot-swap models written by peer replicas
        // sharing the persistence dir (see ModelRegistry::refresh).
        let poll_ms = resolve_manifest_poll_ms(&config);
        let poll_thread = if config.persist_dir.is_some() && poll_ms > 0 {
            let reg = registry.clone();
            let stop2 = stop.clone();
            Some(
                std::thread::Builder::new()
                    .name("fastkqr-manifest".into())
                    .spawn(move || {
                        let mut elapsed = 0u64;
                        while !stop2.load(Ordering::SeqCst) {
                            // short sleeps so shutdown is prompt even
                            // under long poll intervals
                            std::thread::sleep(Duration::from_millis(poll_ms.min(50)));
                            elapsed += poll_ms.min(50);
                            if elapsed < poll_ms {
                                continue;
                            }
                            elapsed = 0;
                            if let Err(e) = reg.refresh() {
                                crate::util::timer::vlog(&format!(
                                    "manifest refresh failed: {e:#}"
                                ));
                            }
                        }
                    })
                    .context("spawn manifest poll thread")?,
            )
        } else {
            None
        };
        Ok(Server {
            local_addr,
            stop,
            accept_thread: Some(accept_thread),
            poll_thread,
            loop_shared,
            registry,
            metrics,
        })
    }

    /// Stop accepting, join the I/O threads, and drain live connections
    /// (bounded wait): after return `active_connections` is zero unless
    /// a connection refused to finish within the drain window.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        match &self.loop_shared {
            // event loop: poke the wake channel so the poller returns
            Some(shared) => shared.wake(),
            // thread model: a throwaway connection unblocks accept()
            None => {
                let _ = TcpStream::connect(self.local_addr);
            }
        }
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.poll_thread.take() {
            let _ = t.join();
        }
        // Connection threads (thread model) observe the stop flag within
        // their read-timeout tick; the event loop closes its connections
        // before its thread exits. Wait for the gauge to drain.
        let deadline = std::time::Instant::now() + Duration::from_secs(3);
        while Metrics::get(&self.metrics.active_connections) > 0
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(5));
        }
    }
}

/// The thread-per-connection accept loop (portable fallback + parity
/// oracle for the event loop).
fn spawn_accept_loop(
    listener: TcpListener,
    state: Arc<ProtocolState>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) -> Result<JoinHandle<()>> {
    let handle = std::thread::Builder::new()
        .name("fastkqr-accept".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        metrics.conn_opened();
                        let st = state.clone();
                        let m2 = metrics.clone();
                        let stop2 = stop.clone();
                        // Builder::spawn drops the closure (and the
                        // stream inside it) on error — clone a writer
                        // first so the client gets an error line instead
                        // of a silent close.
                        let err_stream = stream.try_clone().ok();
                        let spawned = std::thread::Builder::new()
                            .name("fastkqr-conn".into())
                            .spawn(move || {
                                handle_connection(stream, &st, &stop2);
                                m2.conn_closed();
                            });
                        if let Err(e) = spawned {
                            metrics.conn_closed();
                            reject_connection(err_stream, &metrics, &e);
                        }
                    }
                    Err(_) => break,
                }
            }
        })?;
    Ok(handle)
}

/// Thread/fd exhaustion at accept time: answer with a protocol error
/// line and count it, instead of the silent close the client used to
/// see (`accept_spawn_errors` in `metrics`).
fn reject_connection(stream: Option<TcpStream>, metrics: &Metrics, err: &std::io::Error) {
    Metrics::incr(&metrics.accept_spawn_errors);
    Metrics::incr(&metrics.requests_total);
    if let Some(mut s) = stream {
        let mut line =
            err_json(format!("server overloaded: connection thread spawn failed: {err}"))
                .to_string();
        line.push('\n');
        let _ = s.write_all(line.as_bytes());
        let _ = s.shutdown(std::net::Shutdown::Both);
    }
}

fn handle_connection(stream: TcpStream, state: &ProtocolState, stop: &AtomicBool) {
    let peer = stream.peer_addr().ok();
    // A read timeout turns the blocking read into a tick loop: the
    // thread observes a server shutdown within ~100 ms instead of
    // blocking forever on an idle keep-alive connection.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let line = match read_line_tick(&mut reader, &mut buf, stop) {
            LineRead::Line(l) => l,
            LineRead::Eof | LineRead::Stopped | LineRead::Dead => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        if line.trim() == "quit" {
            break;
        }
        // One request, one *or more* response lines (streamed predicts
        // emit header + chunk records + terminator); each line is
        // serialized and written as it renders, so memory per connection
        // is bounded by the chunk size, not the prediction matrix.
        let mut write_ok = true;
        handle_request(state, &line, &mut |resp| {
            let mut out = resp.to_string();
            out.push('\n');
            write_ok = writer.write_all(out.as_bytes()).is_ok();
            write_ok
        });
        if !write_ok {
            break;
        }
    }
    crate::util::timer::vlog(&format!("connection closed: {peer:?}"));
}

/// Minimal blocking client (used by tests, examples and the CLI).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connect")?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one JSON request line, read one JSON response line.
    pub fn request(&mut self, req: &crate::util::Json) -> Result<crate::util::Json> {
        use std::io::BufRead;
        let mut line = req.to_string();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        let mut resp = String::new();
        if self.reader.read_line(&mut resp)? == 0 {
            // EOF used to fall through to the parser and surface as a
            // confusing `bad response ("")` — name the actual condition
            anyhow::bail!("server closed the connection before responding");
        }
        crate::util::Json::parse(resp.trim())
            .map_err(|e| anyhow::anyhow!("bad response: {e} ({resp:?})"))
    }

    /// Send one request and collect **all** of its response lines: one
    /// for ordinary commands, header + chunk records + terminator for a
    /// streamed predict (`"stream": true`). Reading stops at the
    /// terminator (`"done": true`), at a single non-stream response, or
    /// at a leading error.
    pub fn request_stream(&mut self, req: &crate::util::Json) -> Result<Vec<crate::util::Json>> {
        use crate::util::Json;
        use std::io::BufRead;
        let mut line = req.to_string();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        let mut lines = Vec::new();
        loop {
            let mut resp = String::new();
            if self.reader.read_line(&mut resp)? == 0 {
                anyhow::bail!("connection closed mid-stream after {} line(s)", lines.len());
            }
            let v = Json::parse(resp.trim())
                .map_err(|e| anyhow::anyhow!("bad response: {e} ({resp:?})"))?;
            let first = lines.is_empty();
            let streaming_header =
                first && v.get("stream").and_then(Json::as_bool) == Some(true);
            let done = v.get("done").and_then(Json::as_bool) == Some(true);
            lines.push(v);
            if (first && !streaming_header) || done {
                return Ok(lines);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Json;

    fn net_available() -> bool {
        std::net::TcpListener::bind("127.0.0.1:0").is_ok()
    }

    fn threads_config() -> ServerConfig {
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            io_model: IoModel::Threads,
            ..ServerConfig::default()
        }
    }

    #[test]
    fn spawn_ping_shutdown() {
        if !net_available() {
            eprintln!("skipping: no loopback TCP available in this environment");
            return;
        }
        let server = Server::spawn(threads_config()).unwrap();
        let mut client = Client::connect(server.local_addr).unwrap();
        let resp = client.request(&Json::obj(vec![("cmd", Json::str("ping"))])).unwrap();
        assert_eq!(resp.get("pong").and_then(Json::as_bool), Some(true));
        let m = client.request(&Json::obj(vec![("cmd", Json::str("metrics"))])).unwrap();
        // the metrics request itself is counted before rendering
        assert_eq!(m.get_f64("requests_total"), Some(2.0));
        assert_eq!(m.get_str("io_model"), Some("threads"));
        server.shutdown();
    }

    #[test]
    fn shutdown_drains_open_connections() {
        if !net_available() {
            eprintln!("skipping: no loopback TCP available in this environment");
            return;
        }
        let server = Server::spawn(threads_config()).unwrap();
        let metrics = server.metrics.clone();
        let mut client = Client::connect(server.local_addr).unwrap();
        let resp = client.request(&Json::obj(vec![("cmd", Json::str("ping"))])).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(Metrics::get(&metrics.active_connections), 1);
        // shutdown with the client still open: the connection thread
        // observes the stop flag within its read-timeout tick and the
        // gauge drains before shutdown returns
        let t0 = std::time::Instant::now();
        server.shutdown();
        assert!(t0.elapsed() < Duration::from_secs(3), "drain must be bounded");
        assert_eq!(Metrics::get(&metrics.active_connections), 0);
        assert_eq!(Metrics::get(&metrics.connections_peak), 1);
    }

    #[test]
    fn client_reports_closed_connection_not_bad_response() {
        if !net_available() {
            eprintln!("skipping: no loopback TCP available in this environment");
            return;
        }
        // a listener that accepts and immediately drops the socket
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let _ = listener.accept().map(drop);
        });
        let mut client = Client::connect(addr).unwrap();
        let err = client
            .request(&Json::obj(vec![("cmd", Json::str("ping"))]))
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("closed the connection"),
            "EOF must be reported as a closed connection, got: {err}"
        );
        t.join().unwrap();
    }

    #[test]
    fn reject_connection_answers_before_closing() {
        if !net_available() {
            eprintln!("skipping: no loopback TCP available in this environment");
            return;
        }
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            use std::io::Read;
            let mut s = TcpStream::connect(addr).unwrap();
            let mut text = String::new();
            s.read_to_string(&mut text).unwrap();
            text
        });
        let (server_side, _) = listener.accept().unwrap();
        let metrics = Metrics::new();
        let err = std::io::Error::new(std::io::ErrorKind::WouldBlock, "no threads left");
        reject_connection(Some(server_side), &metrics, &err);
        assert_eq!(Metrics::get(&metrics.accept_spawn_errors), 1);
        let text = client.join().unwrap();
        let resp = Json::parse(text.trim()).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        assert!(
            resp.get_str("error").unwrap_or("").contains("spawn failed"),
            "client must learn why: {text:?}"
        );
    }
}
