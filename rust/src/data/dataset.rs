//! Dataset container, train/test splitting, standardization.

use super::rng::Rng;
use crate::linalg::Matrix;

/// A supervised regression dataset: `x` is n×p, `y` length n.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Matrix,
    pub y: Vec<f64>,
    /// Human-readable provenance tag shown by the harnesses.
    pub name: String,
}

impl Dataset {
    pub fn new(name: impl Into<String>, x: Matrix, y: Vec<f64>) -> Dataset {
        assert_eq!(x.rows(), y.len(), "Dataset: x rows != y len");
        Dataset { x, y, name: name.into() }
    }

    pub fn n(&self) -> usize {
        self.y.len()
    }

    pub fn p(&self) -> usize {
        self.x.cols()
    }

    /// Select rows by index (used by CV folds and subsampling).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut x = Matrix::zeros(idx.len(), self.p());
        let mut y = Vec::with_capacity(idx.len());
        for (r, &i) in idx.iter().enumerate() {
            x.row_mut(r).copy_from_slice(self.x.row(i));
            y.push(self.y[i]);
        }
        Dataset { x, y, name: self.name.clone() }
    }

    /// Random train/test split; `train_frac` in (0,1).
    pub fn split(&self, train_frac: f64, rng: &mut Rng) -> (Dataset, Dataset) {
        assert!(train_frac > 0.0 && train_frac < 1.0);
        let n = self.n();
        let perm = rng.permutation(n);
        let ntr = ((n as f64) * train_frac).round() as usize;
        let ntr = ntr.clamp(1, n - 1);
        (self.subset(&perm[..ntr]), self.subset(&perm[ntr..]))
    }

    /// Standardize columns to zero mean / unit sd (in place), returning the
    /// per-column (mean, sd) so test data can reuse the transform.
    pub fn standardize(&mut self) -> Vec<(f64, f64)> {
        let (n, p) = (self.n(), self.p());
        let mut stats = Vec::with_capacity(p);
        for j in 0..p {
            let mean = (0..n).map(|i| self.x[(i, j)]).sum::<f64>() / n as f64;
            let var = (0..n).map(|i| (self.x[(i, j)] - mean).powi(2)).sum::<f64>() / n as f64;
            let sd = var.sqrt().max(1e-12);
            for i in 0..n {
                self.x[(i, j)] = (self.x[(i, j)] - mean) / sd;
            }
            stats.push((mean, sd));
        }
        stats
    }

    /// Apply a previously computed standardization.
    pub fn apply_standardization(&mut self, stats: &[(f64, f64)]) {
        assert_eq!(stats.len(), self.p());
        for j in 0..self.p() {
            let (mean, sd) = stats[j];
            for i in 0..self.n() {
                self.x[(i, j)] = (self.x[(i, j)] - mean) / sd;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let x = Matrix::from_fn(6, 2, |i, j| (i * 2 + j) as f64);
        let y = (0..6).map(|i| i as f64).collect();
        Dataset::new("toy", x, y)
    }

    #[test]
    fn subset_picks_rows() {
        let d = toy();
        let s = d.subset(&[4, 0]);
        assert_eq!(s.n(), 2);
        assert_eq!(s.y, vec![4.0, 0.0]);
        assert_eq!(s.x.row(0), &[8.0, 9.0]);
    }

    #[test]
    fn split_partitions() {
        let d = toy();
        let mut rng = Rng::new(1);
        let (tr, te) = d.split(0.5, &mut rng);
        assert_eq!(tr.n() + te.n(), 6);
        let mut all: Vec<f64> = tr.y.iter().chain(te.y.iter()).cloned().collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(all, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn standardize_gives_zero_mean_unit_sd() {
        let mut d = toy();
        let stats = d.standardize();
        for j in 0..d.p() {
            let mean: f64 = (0..d.n()).map(|i| d.x[(i, j)]).sum::<f64>() / d.n() as f64;
            let var: f64 =
                (0..d.n()).map(|i| d.x[(i, j)].powi(2)).sum::<f64>() / d.n() as f64 - mean * mean;
            assert!(mean.abs() < 1e-12);
            assert!((var - 1.0).abs() < 1e-9);
        }
        // round trip on an identical copy
        let mut d2 = toy();
        d2.apply_standardization(&stats);
        assert!(d.x.max_abs_diff(&d2.x) < 1e-12);
    }
}
