//! END-TO-END DRIVER: the full system on a real small workload.
//!
//!     cargo run --release --example e2e_solver_race [-- --n 500 --paperish]
//!
//! Reproduces the paper's headline experiment shape on the Yuan (2006)
//! benchmark (§4.1 / Table 4): a 50-value λ path with 5-fold CV for
//! every solver, total wall time + objective at the CV-selected λ. It
//! exercises every layer of the stack:
//!
//!   data generator → kernel/Gram → one eigendecomposition → warm-started
//!   spectral APGD (native AND AOT/PJRT backend) → finite smoothing →
//!   exact KKT certificates → CV → comparison against the kernlab-class
//!   IPM and the generic optimizers.
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use fastkqr::backend::{Backend, NativeBackend};
use fastkqr::data::{synth, Rng};
use fastkqr::experiments::kqr_tables;
use fastkqr::experiments::{print_table, speedups, TableConfig};
use fastkqr::kernel::{median_heuristic_sigma, Kernel};
use fastkqr::kqr::KqrSolver;
use fastkqr::runtime::XlaBackend;
use fastkqr::util::{Args, Timer};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", if args.flag("paperish") { 500 } else { 200 });
    let nlam = args.get_usize("nlam", if args.flag("paperish") { 50 } else { 20 });
    let folds = args.get_usize("folds", 5);
    let reps = args.get_usize("reps", if args.flag("paperish") { 3 } else { 1 });

    // ---- part 1: backend parity + path timing through the AOT artifact ----
    println!("== part 1: three-layer composition check (native vs AOT/PJRT) ==");
    let mut rng = Rng::new(11);
    let data = synth::yuan(n.min(256), &mut rng);
    let kernel = Kernel::Rbf { sigma: median_heuristic_sigma(&data.x) };
    let solver = KqrSolver::new(&data.x, &data.y, kernel)?;
    let lams = solver.lambda_grid(8, 1.0, 1e-3);
    let mut native = NativeBackend::new();
    let t = Timer::start("native");
    let fits_native = solver.fit_path_with_backend(0.5, &lams, &mut native)?;
    let native_s = t.total();
    println!("  native backend: {:>8.3}s for {} fits", native_s, fits_native.len());
    match XlaBackend::from_default_dir() {
        Ok(mut xla) => {
            let t = Timer::start("xla");
            let fits_xla = solver.fit_path_with_backend(0.5, &lams, &mut xla)?;
            let xla_s = t.total();
            println!(
                "  xla backend:    {:>8.3}s for {} fits ({} artifact executions)",
                xla_s,
                fits_xla.len(),
                xla.executions
            );
            let max_diff = fits_native
                .iter()
                .zip(&fits_xla)
                .map(|(a, b)| (a.objective - b.objective).abs())
                .fold(0.0f64, f64::max);
            println!("  max |objective difference| = {max_diff:.2e}");
            assert!(max_diff < 1e-7, "backends must agree");
            assert!(xla.name() == "xla");
        }
        Err(e) => println!("  (xla backend unavailable: {e}; run `make artifacts`)"),
    }

    // ---- part 2: the paper's protocol — solver race with CV ----
    println!("\n== part 2: solver race on Yuan (2006), n={n}, {nlam}-lambda path, {folds}-fold CV ==");
    let cfg = TableConfig {
        ns: vec![n],
        p: 2,
        taus: vec![0.1, 0.5, 0.9],
        nlam,
        folds,
        reps,
        solvers: vec!["fastkqr".into(), "ipm".into(), "lbfgs".into(), "neldermead".into()],
        seed: args.get_usize("seed", 2024) as u64,
    };
    let cells = kqr_tables::table4(&cfg)?;
    print_table("E2E solver race (Yuan 2006)", &cells, &cfg.solvers);
    println!("\nheadline speedups (fastkqr vs):");
    let mut min_ipm_speedup = f64::INFINITY;
    for (label, n, solver, factor) in speedups(&cells) {
        println!("  {label} n={n}: {factor:.1}x vs {solver}");
        if solver == "ipm" {
            min_ipm_speedup = min_ipm_speedup.min(factor);
        }
    }
    // the paper's claim: same accuracy, order(s)-of-magnitude faster
    for tau_label in ["tau=0.1", "tau=0.5", "tau=0.9"] {
        let fast = cells.iter().find(|c| c.solver == "fastkqr" && c.label == tau_label);
        let ipm = cells.iter().find(|c| c.solver == "ipm" && c.label == tau_label);
        if let (Some(f), Some(i)) = (fast, ipm) {
            let rel = (f.obj_mean - i.obj_mean).abs() / (1.0 + i.obj_mean.abs());
            assert!(rel < 0.05, "{tau_label}: objectives diverge ({} vs {})", f.obj_mean, i.obj_mean);
        }
    }
    println!("\nminimum speedup vs IPM across taus: {min_ipm_speedup:.1}x");
    println!("e2e_solver_race OK");
    Ok(())
}
