//! Dense row-major matrix type used throughout the library.
//!
//! This is a deliberate substrate: the offline environment has no BLAS /
//! ndarray crates, and the fastkqr algorithm only needs a small, fast set
//! of dense operations (GEMV against the eigenbasis, a one-time
//! eigendecomposition, Cholesky solves for the IPM baseline). Everything
//! is `f64`: the paper's exactness machinery (KKT certificates at 1e-8
//! tolerances) is not reliable in `f32`.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major `rows x cols` matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero-initialized matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of size `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector. Panics if sizes disagree.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "Matrix::from_vec: size mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow the `i`th row as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow the `i`th row.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Underlying row-major storage.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the row-major storage vector.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Column `j` as an owned vector.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Max |a_ij - b_ij| between two equally-shaped matrices.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Is this matrix symmetric to within `tol`?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Stack matrices vertically (row-wise concatenation). All parts must
    /// have the same column count; the result's row r holds the same bits
    /// as the corresponding part row (pure memcpy of the row-major
    /// storage), which is what lets the predict micro-batcher stack query
    /// matrices without perturbing any downstream arithmetic.
    pub fn vstack(parts: &[&Matrix]) -> Matrix {
        assert!(!parts.is_empty(), "vstack of zero matrices");
        let cols = parts[0].cols;
        let rows: usize = parts
            .iter()
            .map(|m| {
                assert_eq!(m.cols, cols, "vstack: mismatched column counts");
                m.rows
            })
            .sum();
        let mut out = Matrix::zeros(rows, cols);
        let mut off = 0usize;
        for m in parts {
            out.data[off..off + m.data.len()].copy_from_slice(&m.data);
            off += m.data.len();
        }
        out
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show = self.rows.min(6);
        for i in 0..show {
            let cols = self.cols.min(8);
            let row: Vec<String> = (0..cols).map(|j| format!("{:>10.4}", self[(i, j)])).collect();
            writeln!(f, "  {}{}", row.join(" "), if self.cols > 8 { " ..." } else { "" })?;
        }
        if self.rows > show {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_eye_and_index() {
        let z = Matrix::zeros(3, 4);
        assert_eq!(z.rows(), 3);
        assert_eq!(z.cols(), 4);
        assert!(z.as_slice().iter().all(|&v| v == 0.0));
        let i3 = Matrix::eye(3);
        assert_eq!(i3[(1, 1)], 1.0);
        assert_eq!(i3[(0, 1)], 0.0);
    }

    #[test]
    fn from_fn_and_transpose() {
        let m = Matrix::from_fn(2, 3, |i, j| (i * 10 + j) as f64);
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        assert_eq!(t[(2, 1)], m[(1, 2)]);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn row_access_and_col() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.col(0), vec![1.0, 3.0]);
    }

    #[test]
    fn vstack_concatenates_rows_bitwise() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(1, 2, vec![5.0, 6.0]);
        let s = Matrix::vstack(&[&a, &b]);
        assert_eq!(s.rows(), 3);
        assert_eq!(s.cols(), 2);
        assert_eq!(s.row(0), a.row(0));
        assert_eq!(s.row(1), a.row(1));
        assert_eq!(s.row(2), b.row(0));
    }

    #[test]
    #[should_panic]
    fn vstack_rejects_mismatched_cols() {
        let a = Matrix::zeros(1, 2);
        let b = Matrix::zeros(1, 3);
        let _ = Matrix::vstack(&[&a, &b]);
    }

    #[test]
    fn symmetry_check() {
        let mut m = Matrix::eye(3);
        assert!(m.is_symmetric(0.0));
        m[(0, 1)] = 0.5;
        assert!(!m.is_symmetric(1e-12));
        m[(1, 0)] = 0.5;
        assert!(m.is_symmetric(1e-12));
    }

    #[test]
    fn fro_norm_matches_hand_value() {
        let m = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-15);
    }

    #[test]
    #[should_panic]
    fn from_vec_size_mismatch_panics() {
        let _ = Matrix::from_vec(2, 2, vec![1.0]);
    }
}
