//! Kernel functions, Gram matrix construction and bandwidth heuristics.
//!
//! KQR lives in the RKHS induced by a kernel K; the paper uses the radial
//! basis kernel K(x,x') = exp(−‖x−x'‖²/(2σ²)) throughout. We also ship
//! linear / polynomial / Laplacian kernels so the library is usable beyond
//! the paper's experiments.

use crate::linalg::Matrix;

pub mod nystrom;
pub mod rff;

/// Kernel function selector.
#[derive(Clone, Debug, PartialEq)]
pub enum Kernel {
    /// exp(−‖x−x'‖² / (2σ²))
    Rbf { sigma: f64 },
    /// x·x' + c
    Linear { c: f64 },
    /// (γ x·x' + c)^degree
    Polynomial { gamma: f64, c: f64, degree: u32 },
    /// exp(−‖x−x'‖₁ / σ)
    Laplacian { sigma: f64 },
}

impl Kernel {
    /// Evaluate k(a, b).
    pub fn eval(&self, a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        match self {
            Kernel::Rbf { sigma } => {
                // Dispatched squared distance (linalg::simd): AVX2/NEON
                // lanes mirroring the scalar 4-accumulator reduction, so
                // Gram entries are ISA-invariant bitwise.
                let d2 = (crate::linalg::simd::global().sqdist)(a, b);
                (-d2 / (2.0 * sigma * sigma)).exp()
            }
            Kernel::Linear { c } => a.iter().zip(b).map(|(x, y)| x * y).sum::<f64>() + c,
            Kernel::Polynomial { gamma, c, degree } => {
                let ip: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
                (gamma * ip + c).powi(*degree as i32)
            }
            Kernel::Laplacian { sigma } => {
                let d1: f64 = a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum();
                (-d1 / sigma).exp()
            }
        }
    }

    /// n×n Gram matrix of the training inputs (rows of `x`).
    ///
    /// Each pair is evaluated once (upper triangle) and mirrored; for the
    /// RBF/Laplacian kernels the diagonal is exactly 1. Above the global
    /// parallel cutoff the triangle is filled by scoped threads owning
    /// contiguous row bands sized to equal triangle *area* (row i holds
    /// n − i evaluations, so equal row counts would be badly unbalanced);
    /// `eval` is deterministic, so the parallel result is bitwise equal
    /// to the serial one.
    pub fn gram(&self, x: &Matrix) -> Matrix {
        let n = x.rows();
        let workers = crate::linalg::par::global().workers_for(n);
        self.gram_blocked(x, workers)
    }

    /// Gram construction with an explicit worker count (1 = the serial
    /// pair-mirrored loop). Exposed so benches and tests can compare the
    /// two paths without touching process-global configuration.
    pub fn gram_blocked(&self, x: &Matrix, workers: usize) -> Matrix {
        let n = x.rows();
        let mut k = Matrix::zeros(n, n);
        if workers > 1 && n > 1 {
            // Parallel upper-triangle fill: workers own contiguous row
            // bands balanced by triangle area, each writing only j ≥ i.
            let bounds = triangle_bounds(n, workers);
            std::thread::scope(|s| {
                let mut rows_iter = k.as_mut_slice().chunks_mut(n);
                for w in bounds.windows(2) {
                    let lo = w[0];
                    let band: Vec<&mut [f64]> =
                        rows_iter.by_ref().take(w[1] - w[0]).collect();
                    s.spawn(move || {
                        for (r, row) in band.into_iter().enumerate() {
                            let i = lo + r;
                            for (j, slot) in row.iter_mut().enumerate().skip(i) {
                                *slot = self.eval(x.row(i), x.row(j));
                            }
                        }
                    });
                }
            });
            // Serial mirror of the strict lower triangle (memory copies —
            // cheap next to the kernel evaluations above).
            for i in 1..n {
                for j in 0..i {
                    let v = k[(j, i)];
                    k[(i, j)] = v;
                }
            }
        } else {
            for i in 0..n {
                k[(i, i)] = self.eval(x.row(i), x.row(i));
                for j in (i + 1)..n {
                    let v = self.eval(x.row(i), x.row(j));
                    k[(i, j)] = v;
                    k[(j, i)] = v;
                }
            }
        }
        k
    }

    /// m×n cross-Gram matrix between test rows `xt` and training rows `x`
    /// (for prediction: f(x*) = Σ_i α_i K(x_i, x*)).
    pub fn cross_gram(&self, xt: &Matrix, x: &Matrix) -> Matrix {
        assert_eq!(xt.cols(), x.cols());
        Matrix::from_fn(xt.rows(), x.rows(), |i, j| self.eval(xt.row(i), x.row(j)))
    }
}

/// Contiguous row-band boundaries `0 = b₀ < b₁ < … = n` splitting the
/// upper triangle (row i owns n − i cells) into runs of roughly equal
/// area — at most `workers + 1` bands.
fn triangle_bounds(n: usize, workers: usize) -> Vec<usize> {
    let total = n * (n + 1) / 2;
    let per = (total + workers - 1) / workers.max(1);
    let mut bounds = vec![0usize];
    let mut acc = 0usize;
    for i in 0..n {
        acc += n - i;
        if acc >= per && *bounds.last().unwrap() < i + 1 && i + 1 < n {
            bounds.push(i + 1);
            acc = 0;
        }
    }
    bounds.push(n);
    bounds
}

/// Median heuristic for the RBF bandwidth: σ = median of pairwise
/// Euclidean distances (on a subsample of at most `max_pairs` pairs for
/// large n). The standard default when the paper tunes only λ.
pub fn median_heuristic_sigma(x: &Matrix) -> f64 {
    let n = x.rows();
    if n < 2 {
        return 1.0;
    }
    let mut dists = Vec::new();
    let max_pairs = 200_000usize;
    let total_pairs = n * (n - 1) / 2;
    let stride = (total_pairs / max_pairs).max(1);
    let mut c = 0usize;
    for i in 0..n {
        for j in (i + 1)..n {
            if c % stride == 0 {
                let d2: f64 = x
                    .row(i)
                    .iter()
                    .zip(x.row(j))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                dists.push(d2.sqrt());
            }
            c += 1;
        }
    }
    dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = dists[dists.len() / 2];
    if med > 0.0 {
        med
    } else {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::linalg::SymEigen;

    #[test]
    fn rbf_identity_and_symmetry() {
        let k = Kernel::Rbf { sigma: 1.5 };
        let a = [1.0, 2.0];
        let b = [0.5, -1.0];
        assert!((k.eval(&a, &a) - 1.0).abs() < 1e-15);
        assert!((k.eval(&a, &b) - k.eval(&b, &a)).abs() < 1e-15);
        assert!(k.eval(&a, &b) > 0.0 && k.eval(&a, &b) < 1.0);
    }

    #[test]
    fn rbf_matches_formula() {
        let k = Kernel::Rbf { sigma: 2.0 };
        let v = k.eval(&[0.0], &[2.0]);
        assert!((v - (-4.0f64 / 8.0).exp()).abs() < 1e-15);
    }

    #[test]
    fn gram_is_symmetric_psd() {
        let mut rng = Rng::new(1);
        let x = Matrix::from_fn(20, 3, |_, _| rng.normal());
        let k = Kernel::Rbf { sigma: 1.0 }.gram(&x);
        assert!(k.is_symmetric(1e-15));
        let eig = SymEigen::new(&k);
        assert!(eig.values[0] > -1e-9, "min eig {}", eig.values[0]);
    }

    #[test]
    fn linear_poly_laplacian_basics() {
        let lin = Kernel::Linear { c: 1.0 };
        assert!((lin.eval(&[1.0, 2.0], &[3.0, 4.0]) - 12.0).abs() < 1e-15);
        let poly = Kernel::Polynomial { gamma: 1.0, c: 0.0, degree: 2 };
        assert!((poly.eval(&[1.0, 1.0], &[2.0, 3.0]) - 25.0).abs() < 1e-15);
        let lap = Kernel::Laplacian { sigma: 1.0 };
        assert!((lap.eval(&[0.0], &[1.0]) - (-1.0f64).exp()).abs() < 1e-15);
    }

    #[test]
    fn cross_gram_shape_and_consistency() {
        let mut rng = Rng::new(2);
        let x = Matrix::from_fn(5, 2, |_, _| rng.normal());
        let k = Kernel::Rbf { sigma: 1.0 };
        let g = k.gram(&x);
        let cg = k.cross_gram(&x, &x);
        assert!(g.max_abs_diff(&cg) < 1e-15);
    }

    #[test]
    fn median_heuristic_positive_and_scales() {
        let mut rng = Rng::new(3);
        let x = Matrix::from_fn(50, 2, |_, _| rng.normal());
        let s1 = median_heuristic_sigma(&x);
        assert!(s1 > 0.1 && s1 < 10.0);
        let x10 = Matrix::from_fn(50, 2, |i, j| 10.0 * x[(i, j)]);
        let s10 = median_heuristic_sigma(&x10);
        assert!((s10 / s1 - 10.0).abs() < 1e-9);
    }
}
