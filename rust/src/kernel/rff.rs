//! Random Fourier features — the paper's other §5 integration target,
//! implemented as the **linear-in-n** compute path.
//!
//! Rahimi & Recht (2007): for the shift-invariant RBF kernel
//! K(x,x') = exp(−‖x−x'‖²/(2σ²)), draw D frequencies wⱼ ~ N(0, σ⁻²I)
//! and phases bⱼ ~ U[0, 2π); the feature map
//!
//!   φ(x)ⱼ = √(2/D) · cos(wⱼ·x + bⱼ)
//!
//! satisfies E[φ(x)·φ(x')] = K(x,x'), so K̃ = ΦΦᵀ with Φ the explicit
//! n×D feature matrix. Wang–Feng (arXiv 2408.13591) show this
//! approximation attains optimal learning rates for kernel quantile
//! regression — the theory behind ROADMAP item 1's "fit 10⁶ rows".
//!
//! The factorization mirrors `kernel::nystrom` so every consumer of
//! [`crate::spectral::GramRepr`] picks it up unchanged:
//!
//!   C = ΦᵀΦ = V S Vᵀ (D×D), U = Φ V S^{-1/2} (n×r, orthonormal),
//!   K̃ = ΦΦᵀ = U S Uᵀ
//!
//! with negligible directions of C dropped by the same relative
//! threshold as Nyström. The fit then runs in the r ≤ min(n, D)
//! dimensional primal. Crucially Φ is **streamed in row blocks** through
//! the SIMD-dispatched `gemm_nt_into` — the full n×D matrix is never
//! materialized during construction, peak extra memory is
//! O(block·D + D²), and the only n-sized output is the thin basis U
//! (n×r). No n×n object exists anywhere on this path.
//!
//! The factor carries the compressed-predictor coefficient map
//! M = V S^{1/2} (D×r): for any spectral iterate β, w = M β satisfies
//! Φ·w = UΛβ **exactly** (Φ V S^{1/2} β = U S β), so a fitted model
//! predicts with one D-dimensional feature build per point and persists
//! in O(D) — independent of n, unlike Nyström's landmark artifacts which
//! still store m training rows.
//!
//! Determinism: the map is reproducible bit-for-bit from `{d, seed}`
//! alone — one [`Rng`] (SplitMix64-seeded xoshiro256++) drawn strictly
//! sequentially (all D×p frequencies row-major, then all D phases), and
//! the block GEMM computes every element with the dispatched serial dot
//! kernel at any worker count, so Φ is invariant across thread counts
//! and `FASTKQR_SIMD` on/off.

use super::Kernel;
use crate::data::rng::Rng;
use crate::linalg::{gemm_into, gemm_nt_into, gemv_t, Matrix, SymEigen};
use crate::spectral::{RffFactor, SpectralBasis};
use anyhow::{bail, Result};
use std::sync::Arc;

/// Rows of Φ materialized at a time during streaming builds. 1024×D
/// doubles stay L2-resident for the D values that make sense (≤ 8192)
/// while amortizing the GEMM call overhead.
const ROW_BLOCK: usize = 1024;

/// A seed-pinned random Fourier feature map for the RBF kernel: D
/// frequencies (D×p, rows wⱼ ~ N(0, σ⁻²I)), D phases (U[0, 2π)), and
/// the √(2/D) normalizer. Fully determined by `{d, seed}` given the
/// kernel bandwidth and input dimension.
#[derive(Clone, Debug)]
pub struct RffMap {
    /// Frequency matrix (D×p), row j = wⱼ.
    pub freqs: Matrix,
    /// Phase offsets bⱼ (length D).
    pub phases: Vec<f64>,
    /// Feature normalizer √(2/D).
    pub scale: f64,
    /// The seed the map was drawn from (artifact provenance).
    pub seed: u64,
}

impl RffMap {
    /// Draw the map for `kernel` on `p`-dimensional inputs. Errors on
    /// `d = 0` or a non-RBF kernel (random Fourier features require a
    /// shift-invariant kernel; only RBF is wired up).
    pub fn new(kernel: &Kernel, p: usize, d: usize, seed: u64) -> Result<RffMap> {
        if d == 0 {
            bail!("rff: need d > 0 random features");
        }
        let sigma = match kernel {
            Kernel::Rbf { sigma } => *sigma,
            other => bail!("rff: random Fourier features require the RBF kernel, got {other:?}"),
        };
        if !(sigma > 0.0) {
            bail!("rff: RBF bandwidth must be positive, got {sigma}");
        }
        // Strictly sequential draw order — the reproducibility contract:
        // all D×p frequency components row-major, then all D phases.
        let mut rng = Rng::new(seed);
        let inv_sigma = 1.0 / sigma;
        let freqs = Matrix::from_fn(d, p, |_, _| rng.normal() * inv_sigma);
        let phases: Vec<f64> =
            (0..d).map(|_| rng.uniform_range(0.0, 2.0 * std::f64::consts::PI)).collect();
        let scale = (2.0 / d as f64).sqrt();
        Ok(RffMap { freqs, phases, scale, seed })
    }

    /// Number of random features D.
    pub fn d(&self) -> usize {
        self.freqs.rows()
    }

    /// Input dimension p.
    pub fn p(&self) -> usize {
        self.freqs.cols()
    }

    /// f64s held by the map itself: D·p frequencies + D phases.
    pub fn memory_floats(&self) -> usize {
        self.freqs.rows() * self.freqs.cols() + self.phases.len()
    }

    /// Fill `phi` (t×D) with features of the `t` rows of `x_block`:
    /// Φᵢⱼ = √(2/D)·cos(wⱼ·xᵢ + bⱼ). The inner product block runs
    /// through `gemm_nt_into` (bitwise-invariant across `workers`), the
    /// cos/scale pass is elementwise — so the result is identical at any
    /// thread count and SIMD tier.
    pub fn features_into(&self, x_block: &Matrix, phi: &mut Matrix, workers: usize) {
        assert_eq!(x_block.cols(), self.freqs.cols(), "rff: input dimension mismatch");
        assert_eq!(phi.rows(), x_block.rows(), "rff: phi rows mismatch");
        assert_eq!(phi.cols(), self.freqs.rows(), "rff: phi cols mismatch");
        gemm_nt_into(x_block, &self.freqs, phi, workers);
        let d = self.d();
        for i in 0..phi.rows() {
            let row = phi.row_mut(i);
            for j in 0..d {
                row[j] = (row[j] + self.phases[j]).cos() * self.scale;
            }
        }
    }

    /// Feature matrix of all rows of `x` (t×D), worker count from the
    /// global parallelism config. Used by predict paths where t is a
    /// request batch, not the training set.
    pub fn features(&self, x: &Matrix) -> Matrix {
        let workers = crate::linalg::par::global().workers_for(x.rows().max(self.d()));
        let mut phi = Matrix::zeros(x.rows(), self.d());
        self.features_into(x, &mut phi, workers);
        phi
    }
}

/// Build the rank-≤D random-feature approximation of `kernel` on the
/// rows of `x`, streaming Φ in [`ROW_BLOCK`]-row blocks. Returns the
/// thin factor; neither the dense n×n K̃ nor the full n×D Φ is ever
/// formed.
pub fn rff(x: &Matrix, kernel: &Kernel, d: usize, seed: u64) -> Result<RffFactor> {
    let n = x.rows();
    if n == 0 {
        bail!("rff: empty input");
    }
    let map = RffMap::new(kernel, x.cols(), d, seed)?;

    // ---- pass 1: C = ΦᵀΦ (D×D), accumulated block-wise ----
    let workers = crate::linalg::par::global().workers_for(n.max(d));
    let mut c = Matrix::zeros(d, d);
    let mut ctmp = Matrix::zeros(d, d);
    let mut phi = Matrix::zeros(ROW_BLOCK.min(n), d);
    let mut lo = 0usize;
    while lo < n {
        let t = ROW_BLOCK.min(n - lo);
        let xb = Matrix::from_fn(t, x.cols(), |i, j| x[(lo + i, j)]);
        if phi.rows() != t {
            phi = Matrix::zeros(t, d);
        }
        map.features_into(&xb, &mut phi, workers);
        // Φᵦᵀ·Φᵦ via the NT kernel on the transposed block (each element
        // one serial dot — deterministic at any worker count).
        let phit = phi.transpose();
        gemm_nt_into(&phit, &phit, &mut ctmp, workers);
        for (acc, inc) in c.as_mut_slice().iter_mut().zip(ctmp.as_slice()) {
            *acc += inc;
        }
        lo += t;
    }

    // ---- eigendecomposition of the D×D covariance; drop null space ----
    let eig = SymEigen::new(&c);
    let smax = eig.values.last().copied().unwrap_or(0.0).max(1e-300);
    let keep: Vec<usize> = (0..d).filter(|&j| eig.values[j] > 1e-12 * smax).collect();
    let rank = keep.len();
    if rank == 0 {
        bail!("rff: approximate kernel matrix is numerically zero");
    }

    // Kept components, ASCENDING eigenvalue order to match the SymEigen /
    // SpectralBasis convention (keep is ascending over eig.values).
    //   U        = Φ · (V S^{-1/2})   (n × r, orthonormal columns)
    //   coef_map = V S^{1/2}          (D × r; w = coef_map·β ⇒ Φw = UΛβ)
    let mut v_shalf = Matrix::zeros(d, rank);
    let mut coef_map = Matrix::zeros(d, rank);
    let mut lambda = vec![0.0; rank];
    for (slot, &j) in keep.iter().enumerate() {
        let s = eig.values[j];
        let sq = s.sqrt();
        lambda[slot] = s;
        for k in 0..d {
            v_shalf[(k, slot)] = eig.vectors[(k, j)] / sq;
            coef_map[(k, slot)] = eig.vectors[(k, j)] * sq;
        }
    }

    // ---- pass 2: U = Φ · v_shalf, streamed in the same blocks ----
    let mut u = Matrix::zeros(n, rank);
    let mut ub = Matrix::zeros(ROW_BLOCK.min(n), rank);
    let mut lo = 0usize;
    while lo < n {
        let t = ROW_BLOCK.min(n - lo);
        let xb = Matrix::from_fn(t, x.cols(), |i, j| x[(lo + i, j)]);
        if phi.rows() != t {
            phi = Matrix::zeros(t, d);
        }
        if ub.rows() != t {
            ub = Matrix::zeros(t, rank);
        }
        map.features_into(&xb, &mut phi, workers);
        gemm_into(&phi, &v_shalf, &mut ub);
        for i in 0..t {
            u.row_mut(lo + i).copy_from_slice(ub.row(i));
        }
        lo += t;
    }

    let ones = vec![1.0; n];
    let mut u1 = vec![0.0; rank];
    gemv_t(&u, &ones, &mut u1);
    let basis = SpectralBasis { n, u, lambda, u1 };
    Ok(RffFactor { basis: Arc::new(basis), map: Arc::new(map), coef_map })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kernel::median_heuristic_sigma;
    use crate::kqr::KqrSolver;
    use crate::spectral::GramRepr;

    fn fixture(n: usize, seed: u64) -> (Matrix, Vec<f64>, Kernel) {
        let mut rng = Rng::new(seed);
        let d = synth::sine_hetero(n, &mut rng);
        let sigma = median_heuristic_sigma(&d.x);
        (d.x, d.y, Kernel::Rbf { sigma })
    }

    #[test]
    fn large_d_approximates_gram() {
        // Monte-Carlo error of each entry is O(1/√D); at D = 4096 the
        // worst entry over a 30×30 Gram sits well inside 0.1.
        let (x, _, kernel) = fixture(30, 1);
        let f = rff(&x, &kernel, 4096, 2).unwrap();
        let repr = GramRepr::RandomFeatures(Arc::new(f));
        let exact = kernel.gram(&x);
        let mut max_diff = 0.0f64;
        for i in 0..30 {
            for j in 0..30 {
                max_diff = max_diff.max((repr.entry(i, j) - exact[(i, j)]).abs());
            }
        }
        assert!(max_diff < 0.1, "D=4096 RFF Gram error too large: {max_diff}");
    }

    #[test]
    fn factor_is_thin_with_positive_spectrum() {
        let (x, _, kernel) = fixture(40, 3);
        let f = rff(&x, &kernel, 15, 4).unwrap();
        let r = f.basis.dim();
        assert!(r <= 15 && r > 0);
        assert_eq!(f.basis.u.rows(), 40);
        assert_eq!(f.basis.u.cols(), r, "no zero-padding: U is thin");
        assert_eq!(f.map.d(), 15);
        assert_eq!(f.coef_map.rows(), 15);
        assert_eq!(f.coef_map.cols(), r);
        assert!(f.basis.lambda.iter().all(|&l| l > 0.0));
        assert!(f.basis.lambda.windows(2).all(|w| w[0] <= w[1]), "ascending");
    }

    #[test]
    fn orthonormal_retained_columns() {
        let (x, _, kernel) = fixture(25, 5);
        let f = rff(&x, &kernel, 10, 6).unwrap();
        let n = 25;
        let r = f.basis.dim();
        for a in 0..r {
            for b in 0..r {
                let mut s = 0.0;
                for i in 0..n {
                    s += f.basis.u[(i, a)] * f.basis.u[(i, b)];
                }
                let expect = if a == b { 1.0 } else { 0.0 };
                assert!((s - expect).abs() < 1e-9, "UᵀU[{a},{b}]={s}");
            }
        }
    }

    /// The compressed-predictor identity: Φ·(coef_map·β) = UΛβ for any
    /// spectral coordinates β — the contract the O(D) artifacts rest on.
    #[test]
    fn coefficient_map_reproduces_fitted_values() {
        let (x, _, kernel) = fixture(35, 7);
        let f = rff(&x, &kernel, 12, 8).unwrap();
        let r = f.basis.dim();
        let mut rng = Rng::new(9);
        let beta: Vec<f64> = (0..r).map(|_| rng.normal()).collect();
        let coef = f.coef(&beta);
        assert_eq!(coef.w.len(), 12);
        // f_rf = Φ w
        let phi = f.map.features(&x);
        let mut f_rf = vec![0.0; 35];
        crate::linalg::gemv(&phi, &coef.w, &mut f_rf);
        // f_spec = UΛβ
        let mut scratch = vec![0.0; r];
        let mut f_spec = vec![0.0; 35];
        f.basis.fitted(0.0, &beta, &mut scratch, &mut f_spec);
        for i in 0..35 {
            assert!(
                (f_rf[i] - f_spec[i]).abs() < 1e-8,
                "i={i}: rff {} vs spectral {}",
                f_rf[i],
                f_spec[i]
            );
        }
    }

    #[test]
    fn kqr_on_rff_basis_close_to_exact() {
        // End-to-end: solve KQR on K̃ = ΦΦᵀ with the unchanged finite
        // smoothing machinery; the objective approaches the exact-kernel
        // one as D grows.
        let (x, y, kernel) = fixture(60, 7);
        let exact = KqrSolver::new(&x, &y, kernel.clone()).unwrap().fit(0.5, 1e-2).unwrap();
        let f = rff(&x, &kernel, 1024, 11).unwrap();
        let solver =
            KqrSolver::with_repr(&x, &y, kernel.clone(), GramRepr::RandomFeatures(Arc::new(f)));
        let fit = solver.fit(0.5, 1e-2).unwrap();
        let gap = (fit.objective - exact.objective).abs();
        assert!(gap < 0.05 * (1.0 + exact.objective), "D=1024 objective gap {gap}");
        assert!(fit.rff.is_some(), "RFF fit carries the compressed predictor");
        assert!(fit.lowrank.is_none());
    }

    #[test]
    fn map_is_bitwise_reproducible_from_seed() {
        let kernel = Kernel::Rbf { sigma: 0.7 };
        let a = RffMap::new(&kernel, 3, 17, 42).unwrap();
        let b = RffMap::new(&kernel, 3, 17, 42).unwrap();
        assert_eq!(a.freqs.as_slice(), b.freqs.as_slice());
        assert_eq!(a.phases, b.phases);
        let c = RffMap::new(&kernel, 3, 17, 43).unwrap();
        assert_ne!(a.freqs.as_slice(), c.freqs.as_slice(), "seed must matter");
        // features are worker-count invariant, bit for bit
        let mut rng = Rng::new(5);
        let x = Matrix::from_fn(33, 3, |_, _| rng.normal());
        let mut phi1 = Matrix::zeros(33, 17);
        let mut phi4 = Matrix::zeros(33, 17);
        a.features_into(&x, &mut phi1, 1);
        a.features_into(&x, &mut phi4, 4);
        assert_eq!(phi1.as_slice(), phi4.as_slice(), "workers must not change bits");
    }

    #[test]
    fn streamed_factor_matches_single_block_build() {
        // n > ROW_BLOCK exercises the multi-block accumulation; the
        // factor must not depend on how Φ was chunked. Compare U S Uᵀ
        // entries against a direct whole-Φ computation.
        let (x, _, kernel) = fixture(40, 12);
        let f = rff(&x, &kernel, 8, 13).unwrap();
        let phi = f.map.features(&x);
        let repr = GramRepr::RandomFeatures(Arc::new(f));
        for i in [0usize, 7, 39] {
            for j in [0usize, 11, 39] {
                let direct: f64 = phi.row(i).iter().zip(phi.row(j)).map(|(a, b)| a * b).sum();
                assert!(
                    (repr.entry(i, j) - direct).abs() < 1e-9,
                    "K̃[{i},{j}]: {} vs {direct}",
                    repr.entry(i, j)
                );
            }
        }
    }

    #[test]
    fn rejects_bad_inputs() {
        let (x, _, kernel) = fixture(10, 9);
        assert!(rff(&x, &kernel, 0, 1).is_err(), "d = 0");
        assert!(rff(&x, &Kernel::Linear { c: 0.0 }, 8, 1).is_err(), "non-RBF");
        assert!(Matrix::zeros(0, 2).rows() == 0 && rff(&Matrix::zeros(0, 2), &kernel, 8, 1).is_err());
    }
}
