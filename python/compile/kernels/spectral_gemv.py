"""L1 Pallas kernels: the O(n²) GEMV hot spot of the spectral update.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper is a CPU
algorithm; its core insight — touch the kernel matrix only through
matrix–vector products against a fixed eigenbasis — maps onto TPU as a
row-tiled GEMV whose HBM↔VMEM schedule is expressed with a BlockSpec
grid. Each grid step streams a (TILE_ROWS × n) slab of U into VMEM and
produces TILE_ROWS outputs; the x vector stays resident. VMEM footprint
per step is (TILE_ROWS·n + n + TILE_ROWS)·8 bytes — ≤ 2.1 MB for
n = 4096 at TILE_ROWS = 64, comfortably inside a TensorCore's ~16 MB.

The kernels MUST run with interpret=True on this image: real TPU
lowering emits Mosaic custom-calls the CPU PJRT client cannot execute.
Interpret mode still exercises the same BlockSpec index maps, which is
what the tests validate.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

# Row-tile height. 8 keeps the interpret-mode grid exercised even for the
# small n used in tests; on hardware this would be 64–256.
TILE_ROWS = 8


def _matvec_kernel(a_ref, x_ref, o_ref):
    """One grid step: o[tile] = A[tile, :] @ x."""
    o_ref[...] = a_ref[...] @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("tile_rows",))
def pallas_gemv(a, x, tile_rows: int = TILE_ROWS):
    """o = A @ x with a row-tiled Pallas kernel (A: (m, n), x: (n,)).

    m must be divisible by `tile_rows` (the AOT path pads problem sizes
    to multiples of 8; tests cover the exact-multiple contract).
    """
    m, n = a.shape
    assert m % tile_rows == 0, f"rows {m} not a multiple of tile {tile_rows}"
    grid = (m // tile_rows,)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), a.dtype),
        interpret=True,
    )(a, x)


def _matvec_t_kernel(a_ref, x_ref, acc_ref):
    """One grid step of o = Aᵀx: accumulate x[tile] · A[tile, :].

    The row tiles of A are reduced into the single output block; step 0
    initializes the accumulator.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += x_ref[...] @ a_ref[...]


@functools.partial(jax.jit, static_argnames=("tile_rows",))
def pallas_gemv_t(a, x, tile_rows: int = TILE_ROWS):
    """o = Aᵀ @ x streaming A once by row tiles (A: (m, n), x: (m,))."""
    m, n = a.shape
    assert m % tile_rows == 0, f"rows {m} not a multiple of tile {tile_rows}"
    grid = (m // tile_rows,)
    return pl.pallas_call(
        _matvec_t_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((tile_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a, x)


def vmem_footprint_bytes(n: int, tile_rows: int = TILE_ROWS, dtype_bytes: int = 8):
    """Estimated VMEM bytes per grid step (slab + x + out tile).

    Reported by DESIGN.md §Perf for the TPU roofline estimate.
    """
    return dtype_bytes * (tile_rows * n + n + max(n, tile_rows))
