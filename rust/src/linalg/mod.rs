//! Dense linear algebra substrate (no external BLAS/LAPACK available).
//!
//! - [`matrix::Matrix`]: row-major dense matrix
//! - [`blas`]: dot/axpy/GEMV/GEMM kernels (the O(n²) hot path), each
//!   dispatching to the parallel substrate above a size cutoff
//! - [`par`]: scoped-thread row-blocked parallel kernels + the
//!   [`par::Parallelism`] configuration (env-overridable)
//! - [`eigen::SymEigen`]: one-time K = UΛUᵀ decomposition
//! - [`chol::Cholesky`]: SPD solves for the interior-point baseline

pub mod blas;
pub mod chol;
pub mod eigen;
pub mod matrix;
pub mod par;

pub use blas::{amax, axpy, dot, gemm, gemv, gemv_t, nrm2, quad_form, scal};
pub use chol::{CholError, Cholesky};
pub use eigen::SymEigen;
pub use matrix::Matrix;
pub use par::Parallelism;
