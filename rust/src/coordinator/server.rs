//! Threaded TCP fit/predict server (line-JSON protocol; see
//! [`protocol`](super::protocol)).
//!
//! std::net + thread-per-connection: the offline image has no tokio, and
//! for a compute-bound service (fits run for seconds) blocking threads
//! are the simpler and equally scalable design at this fan-in.

use super::batcher::BatchConfig;
use super::metrics::Metrics;
use super::protocol::{handle_request, ProtocolState};
use super::registry::ModelRegistry;
use crate::kqr::SolveOptions;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    pub opts: SolveOptions,
    /// Artifact directory for the model registry: fitted models are
    /// written through as versioned JSON artifacts and reloaded on the
    /// next spawn, so the server survives restarts (`None` = in-memory
    /// only).
    pub persist_dir: Option<String>,
    /// Predict micro-batching knobs; the default reads
    /// `FASTKQR_BATCH_WINDOW_US` / `FASTKQR_BATCH_MAX_ROWS` from the
    /// environment at config construction.
    pub batch: BatchConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7787".to_string(),
            opts: SolveOptions::default(),
            persist_dir: None,
            batch: BatchConfig::from_env(),
        }
    }
}

/// A running server handle.
pub struct Server {
    pub local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    pub registry: Arc<ModelRegistry>,
    pub metrics: Arc<Metrics>,
}

impl Server {
    /// Bind and start accepting connections on a background thread.
    pub fn spawn(config: ServerConfig) -> Result<Server> {
        let listener =
            TcpListener::bind(&config.addr).with_context(|| format!("bind {}", config.addr))?;
        let local_addr = listener.local_addr()?;
        let registry = Arc::new(match &config.persist_dir {
            Some(dir) => ModelRegistry::with_persistence(dir)
                .with_context(|| format!("open model persistence dir {dir}"))?,
            None => ModelRegistry::new(),
        });
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let state = Arc::new(ProtocolState::new(
            registry.clone(),
            metrics.clone(),
            config.opts,
            // the process-global engine: concurrent connections (and any
            // co-located scheduler) share one Gram/basis per dataset
            crate::engine::FitEngine::global().clone(),
            config.batch,
        ));
        let stop2 = stop.clone();
        let accept_thread = std::thread::Builder::new()
            .name("fastkqr-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let st = state.clone();
                            let _ = std::thread::Builder::new()
                                .name("fastkqr-conn".into())
                                .spawn(move || handle_connection(stream, &st));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server {
            local_addr,
            stop,
            accept_thread: Some(accept_thread),
            registry,
            metrics,
        })
    }

    /// Stop accepting and join the accept loop (in-flight connections
    /// finish their current request).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // wake the accept loop
        let _ = TcpStream::connect(self.local_addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_connection(stream: TcpStream, state: &ProtocolState) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        if line.trim() == "quit" {
            break;
        }
        // One request, one *or more* response lines (streamed predicts
        // emit header + chunk records + terminator); each line is
        // serialized and written as it renders, so memory per connection
        // is bounded by the chunk size, not the prediction matrix.
        let mut write_ok = true;
        handle_request(state, &line, &mut |resp| {
            let mut out = resp.to_string();
            out.push('\n');
            write_ok = writer.write_all(out.as_bytes()).is_ok();
            write_ok
        });
        if !write_ok {
            break;
        }
    }
    crate::util::timer::vlog(&format!("connection closed: {peer:?}"));
}

/// Minimal blocking client (used by tests, examples and the CLI).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connect")?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one JSON request line, read one JSON response line.
    pub fn request(&mut self, req: &crate::util::Json) -> Result<crate::util::Json> {
        let mut line = req.to_string();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        crate::util::Json::parse(resp.trim())
            .map_err(|e| anyhow::anyhow!("bad response: {e} ({resp:?})"))
    }

    /// Send one request and collect **all** of its response lines: one
    /// for ordinary commands, header + chunk records + terminator for a
    /// streamed predict (`"stream": true`). Reading stops at the
    /// terminator (`"done": true`), at a single non-stream response, or
    /// at a leading error.
    pub fn request_stream(&mut self, req: &crate::util::Json) -> Result<Vec<crate::util::Json>> {
        use crate::util::Json;
        let mut line = req.to_string();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        let mut lines = Vec::new();
        loop {
            let mut resp = String::new();
            if self.reader.read_line(&mut resp)? == 0 {
                anyhow::bail!("connection closed mid-stream after {} line(s)", lines.len());
            }
            let v = Json::parse(resp.trim())
                .map_err(|e| anyhow::anyhow!("bad response: {e} ({resp:?})"))?;
            let first = lines.is_empty();
            let streaming_header =
                first && v.get("stream").and_then(Json::as_bool) == Some(true);
            let done = v.get("done").and_then(Json::as_bool) == Some(true);
            lines.push(v);
            if (first && !streaming_header) || done {
                return Ok(lines);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Json;

    #[test]
    fn spawn_ping_shutdown() {
        if std::net::TcpListener::bind("127.0.0.1:0").is_err() {
            eprintln!("skipping: no loopback TCP available in this environment");
            return;
        }
        let server = Server::spawn(ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            ..ServerConfig::default()
        })
        .unwrap();
        let mut client = Client::connect(server.local_addr).unwrap();
        let resp = client.request(&Json::obj(vec![("cmd", Json::str("ping"))])).unwrap();
        assert_eq!(resp.get("pong").and_then(Json::as_bool), Some(true));
        let m = client.request(&Json::obj(vec![("cmd", Json::str("metrics"))])).unwrap();
        // the metrics request itself is counted before rendering
        assert_eq!(m.get_f64("requests_total"), Some(2.0));
        server.shutdown();
    }
}
