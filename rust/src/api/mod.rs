//! The declarative fit API: one `FitSpec` → one [`QuantileModel`].
//!
//! Every consumer — the CLI subcommands, the TCP line-JSON protocol, the
//! Rust library surface and the CV driver — funnels through this layer
//! instead of hand-assembling solvers. A [`FitSpec`] names the data, the
//! kernel, the task and optional solver/strategy overrides; it
//! round-trips through [`crate::util::Json`] (so the exact same document
//! fits identically over the wire, from a file, or in-process); and
//! [`FitEngine::run`] executes it on the engine's GramCache, so *every*
//! task — including `NonCrossing`, which used to construct its solver
//! outside the cache — shares one eigendecomposition per (dataset,
//! kernel) fingerprint per process.
//!
//! ```text
//!   FitSpec { x, y, kernel(+approx), task, opts?, nc_opts?, lockstep?,
//!             backend?, solver?, seed }
//!     task   ∈ Single{τ,λ} | Path{τ,λs} | Grid{τs,λs}
//!            | NonCrossing{τs,λ₁,λ₂} | Cv{τs,λs,folds,seed}
//!     approx ∈ exact | nystrom{m, seed} | rff{d, seed}   (Gram repr)
//!     solver ∈ apgd | ssn | auto        (optimizer backend)
//!        │  FitEngine::run(&spec)
//!        ▼
//!   QuantileModel (predict / taus / diagnostics / save / load)
//! ```
//!
//! The resulting [`QuantileModel`] unifies `KqrFit` / `NckqrFit` /
//! grid-and-CV fit sets behind one `predict`/`taus`/`diagnostics` API
//! and persists to a versioned JSON artifact (see [`artifact`]) that a
//! fresh process reloads to bitwise-identical predictions.

pub mod artifact;
pub mod model;

pub use model::{CvSummary, ModelSet, QuantileModel, SetShape};

use crate::backend::{Backend, NativeBackend};
use crate::cv::cross_validate_on;
use crate::data::{Dataset, Rng};
use crate::engine::{ApproxSpec, FitEngine};
use crate::kernel::{median_heuristic_sigma, Kernel};
use crate::kqr::apgd::ApgdState;
use crate::kqr::SolveOptions;
use crate::linalg::Matrix;
use crate::nckqr::NcOptions;
use crate::solver::{self, SolverBackend, SsnState};
use crate::util::Json;
use anyhow::{anyhow, bail, Result};

/// Highest spec document version this build reads. [`FitSpec::to_json`]
/// writes the **lowest** version that can represent the document — 1 for
/// exact specs (older readers keep working), 2 once the kernel carries a
/// Nyström `approx` block, 3 for a random-feature (`rff`) block, 4 once
/// the document names a solver backend (`"solver"`) — which older
/// readers must reject rather than silently fit with the wrong
/// representation or optimizer.
pub const SPEC_VERSION: u64 = 4;

/// Default master seed of a spec (`"seed"`): drives Nyström landmark
/// sampling and random-feature frequency draws when the `approx` block
/// carries no seed of its own, and is the documented default for CV fold
/// shuffling (`task.seed`). Pinning it in the document makes every
/// randomized choice reproducible from the spec alone.
pub const DEFAULT_SEED: u64 = 2024;

// ---------------------------------------------------------------------------
// Matrix JSON helpers (shared by specs, artifacts and the wire protocol)
// ---------------------------------------------------------------------------

/// Parse an n×p matrix from a JSON array of arrays (strict: non-empty,
/// rectangular, all numbers).
pub fn matrix_from_json(v: &Json) -> Result<Matrix> {
    let rows = v.as_arr().ok_or_else(|| anyhow!("x must be an array of arrays"))?;
    if rows.is_empty() {
        bail!("x must be non-empty");
    }
    let p = rows[0].as_arr().ok_or_else(|| anyhow!("x rows must be arrays"))?.len();
    if p == 0 {
        bail!("x rows must be non-empty");
    }
    let mut m = Matrix::zeros(rows.len(), p);
    for (i, r) in rows.iter().enumerate() {
        let r = r.as_arr().ok_or_else(|| anyhow!("x rows must be arrays"))?;
        if r.len() != p {
            bail!("ragged x: row {i} has {} cols, expected {p}", r.len());
        }
        for (j, cell) in r.iter().enumerate() {
            m[(i, j)] = cell.as_f64().ok_or_else(|| anyhow!("x[{i}][{j}] not a number"))?;
        }
    }
    Ok(m)
}

/// Inverse of [`matrix_from_json`].
pub fn matrix_to_json(m: &Matrix) -> Json {
    Json::Arr((0..m.rows()).map(|i| Json::arr_f64(m.row(i))).collect())
}

// ---------------------------------------------------------------------------
// Kernel spec
// ---------------------------------------------------------------------------

/// A possibly-unresolved kernel: bandwidths may be left to the median
/// heuristic, which is resolved against the actual training inputs by
/// [`KernelSpec::resolve`].
#[derive(Clone, Debug, Default, PartialEq)]
pub enum KernelSpec {
    /// RBF with the median-heuristic bandwidth (the default).
    #[default]
    Auto,
    Rbf { sigma: Option<f64> },
    Linear { c: f64 },
    Polynomial { gamma: f64, c: f64, degree: u32 },
    Laplacian { sigma: Option<f64> },
}

impl KernelSpec {
    /// Pin a fully-specified kernel.
    pub fn exact(kernel: &Kernel) -> KernelSpec {
        match kernel {
            Kernel::Rbf { sigma } => KernelSpec::Rbf { sigma: Some(*sigma) },
            Kernel::Linear { c } => KernelSpec::Linear { c: *c },
            Kernel::Polynomial { gamma, c, degree } => {
                KernelSpec::Polynomial { gamma: *gamma, c: *c, degree: *degree }
            }
            Kernel::Laplacian { sigma } => KernelSpec::Laplacian { sigma: Some(*sigma) },
        }
    }

    /// Resolve against the training inputs (fills median-heuristic σ).
    pub fn resolve(&self, x: &Matrix) -> Kernel {
        match self {
            KernelSpec::Auto => Kernel::Rbf { sigma: median_heuristic_sigma(x) },
            KernelSpec::Rbf { sigma } => {
                Kernel::Rbf { sigma: sigma.unwrap_or_else(|| median_heuristic_sigma(x)) }
            }
            KernelSpec::Linear { c } => Kernel::Linear { c: *c },
            KernelSpec::Polynomial { gamma, c, degree } => {
                Kernel::Polynomial { gamma: *gamma, c: *c, degree: *degree }
            }
            KernelSpec::Laplacian { sigma } => {
                Kernel::Laplacian { sigma: sigma.unwrap_or_else(|| median_heuristic_sigma(x)) }
            }
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            KernelSpec::Auto => Json::obj(vec![("type", Json::str("auto"))]),
            KernelSpec::Rbf { sigma } => {
                let mut pairs = vec![("type", Json::str("rbf"))];
                if let Some(s) = sigma {
                    pairs.push(("sigma", Json::num(*s)));
                }
                Json::obj(pairs)
            }
            KernelSpec::Linear { c } => {
                Json::obj(vec![("type", Json::str("linear")), ("c", Json::num(*c))])
            }
            KernelSpec::Polynomial { gamma, c, degree } => Json::obj(vec![
                ("type", Json::str("polynomial")),
                ("gamma", Json::num(*gamma)),
                ("c", Json::num(*c)),
                ("degree", Json::num(*degree as f64)),
            ]),
            KernelSpec::Laplacian { sigma } => {
                let mut pairs = vec![("type", Json::str("laplacian"))];
                if let Some(s) = sigma {
                    pairs.push(("sigma", Json::num(*s)));
                }
                Json::obj(pairs)
            }
        }
    }

    /// Parse a kernel spec. The type defaults to `"rbf"` (the wire
    /// protocol's historical behavior); an unknown type is an error.
    pub fn from_json(v: &Json) -> Result<KernelSpec> {
        match v.get_str("type").unwrap_or("rbf") {
            "auto" => Ok(KernelSpec::Auto),
            "rbf" => Ok(KernelSpec::Rbf { sigma: v.get_f64("sigma") }),
            "linear" => Ok(KernelSpec::Linear { c: v.get_f64("c").unwrap_or(0.0) }),
            "polynomial" => Ok(KernelSpec::Polynomial {
                gamma: v.get_f64("gamma").unwrap_or(1.0),
                c: v.get_f64("c").unwrap_or(1.0),
                degree: v.get_usize("degree").unwrap_or(2) as u32,
            }),
            "laplacian" => Ok(KernelSpec::Laplacian { sigma: v.get_f64("sigma") }),
            other => bail!("unknown kernel type {other:?}"),
        }
    }
}

/// Serialize a *resolved* kernel (artifacts pin exact parameters).
pub fn kernel_to_json(k: &Kernel) -> Json {
    KernelSpec::exact(k).to_json()
}

/// Parse a resolved kernel from an artifact (σ is required there — an
/// artifact must not re-derive bandwidths from data).
pub fn kernel_from_json(v: &Json) -> Result<Kernel> {
    match KernelSpec::from_json(v)? {
        KernelSpec::Auto | KernelSpec::Rbf { sigma: None } | KernelSpec::Laplacian { sigma: None } => {
            bail!("artifact kernel must carry an explicit sigma")
        }
        KernelSpec::Rbf { sigma: Some(s) } => Ok(Kernel::Rbf { sigma: s }),
        KernelSpec::Laplacian { sigma: Some(s) } => Ok(Kernel::Laplacian { sigma: s }),
        KernelSpec::Linear { c } => Ok(Kernel::Linear { c }),
        KernelSpec::Polynomial { gamma, c, degree } => {
            Ok(Kernel::Polynomial { gamma, c, degree })
        }
    }
}

// ---------------------------------------------------------------------------
// Approximation spec (the kernel object's `approx` block)
// ---------------------------------------------------------------------------

/// Serialize an [`ApproxSpec`] (the kernel object's `approx` block).
/// `Exact` is the implicit default and is not written.
pub fn approx_to_json(a: &ApproxSpec) -> Option<Json> {
    match a {
        ApproxSpec::Exact => None,
        ApproxSpec::Nystrom { m, seed } => Some(Json::obj(vec![
            ("type", Json::str("nystrom")),
            ("m", Json::num(*m as f64)),
            ("seed", Json::num(*seed as f64)),
        ])),
        ApproxSpec::RandomFeatures { d, seed } => Some(Json::obj(vec![
            ("type", Json::str("rff")),
            ("d", Json::num(*d as f64)),
            ("seed", Json::num(*seed as f64)),
        ])),
    }
}

/// Parse the kernel object's `approx` block. Unknown keys are errors —
/// a typo'd `"m"` silently ignored would fit a different model. A
/// `nystrom` block without a seed inherits `default_seed` (the spec's
/// master seed).
pub fn approx_from_json(v: &Json, default_seed: u64) -> Result<ApproxSpec> {
    let Json::Obj(map) = v else { bail!("approx must be an object") };
    let ty = v.get_str("type").ok_or_else(|| anyhow!("approx: missing 'type'"))?;
    match ty {
        "exact" => {
            for key in map.keys() {
                if key != "type" {
                    bail!("approx: unknown key {key:?} for type \"exact\"");
                }
            }
            Ok(ApproxSpec::Exact)
        }
        "nystrom" => {
            for key in map.keys() {
                if !["type", "m", "seed"].contains(&key.as_str()) {
                    bail!("approx: unknown key {key:?} (have: type, m, seed)");
                }
            }
            let m = v
                .get_usize("m")
                .ok_or_else(|| anyhow!("approx: nystrom needs a positive integer 'm'"))?;
            if m == 0 {
                bail!("approx: nystrom needs m >= 1");
            }
            let seed = match v.get("seed") {
                None => default_seed,
                Some(_) => v
                    .get_usize("seed")
                    .ok_or_else(|| anyhow!("approx: seed must be a non-negative integer"))?
                    as u64,
            };
            Ok(ApproxSpec::Nystrom { m, seed })
        }
        "rff" => {
            for key in map.keys() {
                if !["type", "d", "seed"].contains(&key.as_str()) {
                    bail!("approx: unknown key {key:?} (have: type, d, seed)");
                }
            }
            let d = v
                .get_usize("d")
                .ok_or_else(|| anyhow!("approx: rff needs a positive integer 'd'"))?;
            if d == 0 {
                bail!("approx: rff needs d >= 1");
            }
            let seed = match v.get("seed") {
                None => default_seed,
                Some(_) => v
                    .get_usize("seed")
                    .ok_or_else(|| anyhow!("approx: seed must be a non-negative integer"))?
                    as u64,
            };
            Ok(ApproxSpec::RandomFeatures { d, seed })
        }
        other => bail!("unknown approx type {other:?} (exact|nystrom|rff)"),
    }
}

// ---------------------------------------------------------------------------
// Solver option overrides
// ---------------------------------------------------------------------------

macro_rules! opt_fields {
    // internal per-field rules first, so `@one` never reaches the
    // public rule's `expr` fragment parser
    (@one $v:ident, $opts:ident, $key:tt, $field:ident, f64) => {
        if $v.get($key).is_some() {
            $opts.$field = $v
                .get_f64($key)
                .ok_or_else(|| anyhow!(concat!($key, " must be a number")))?;
        }
    };
    (@one $v:ident, $opts:ident, $key:tt, $field:ident, usize) => {
        if $v.get($key).is_some() {
            $opts.$field = $v
                .get_usize($key)
                .ok_or_else(|| anyhow!(concat!($key, " must be a non-negative integer")))?;
        }
    };
    (@one $v:ident, $opts:ident, $key:tt, $field:ident, bool) => {
        if $v.get($key).is_some() {
            $opts.$field = $v
                .get_bool($key)
                .ok_or_else(|| anyhow!(concat!($key, " must be a boolean")))?;
        }
    };
    ($v:ident, $opts:ident, { $($key:tt => $field:ident : $kind:tt),+ $(,)? }) => {{
        if let Json::Obj(map) = $v {
            for key in map.keys() {
                if ![$($key),+].contains(&key.as_str()) {
                    bail!("unknown option {key:?} (have: {})", [$($key),+].join(", "));
                }
            }
        } else {
            bail!("options must be an object");
        }
        $(opt_fields!(@one $v, $opts, $key, $field, $kind);)+
    }};
}

/// Apply a partial JSON override on top of `base` [`SolveOptions`].
/// Unknown keys are errors — a typo'd tolerance silently ignored is a
/// wrong-model bug.
pub fn solve_options_from_json(v: &Json, base: SolveOptions) -> Result<SolveOptions> {
    let mut opts = base;
    opt_fields!(v, opts, {
        "chunk" => chunk: usize,
        "max_iters" => max_iters: usize,
        "apgd_tol" => apgd_tol: f64,
        "kkt_tol" => kkt_tol: f64,
        "kkt_band" => kkt_band: f64,
        "gamma_init" => gamma_init: f64,
        "gamma_shrink" => gamma_shrink: f64,
        "gamma_min" => gamma_min: f64,
        "max_expansions" => max_expansions: usize,
        "max_stall_rungs" => max_stall_rungs: usize,
        "projection" => projection: bool,
        "nesterov" => nesterov: bool,
    });
    Ok(opts)
}

/// Full serialization of [`SolveOptions`] (round-trips exactly).
pub fn solve_options_to_json(o: &SolveOptions) -> Json {
    Json::obj(vec![
        ("chunk", Json::num(o.chunk as f64)),
        ("max_iters", Json::num(o.max_iters as f64)),
        ("apgd_tol", Json::num(o.apgd_tol)),
        ("kkt_tol", Json::num(o.kkt_tol)),
        ("kkt_band", Json::num(o.kkt_band)),
        ("gamma_init", Json::num(o.gamma_init)),
        ("gamma_shrink", Json::num(o.gamma_shrink)),
        ("gamma_min", Json::num(o.gamma_min)),
        ("max_expansions", Json::num(o.max_expansions as f64)),
        ("max_stall_rungs", Json::num(o.max_stall_rungs as f64)),
        ("projection", Json::Bool(o.projection)),
        ("nesterov", Json::Bool(o.nesterov)),
    ])
}

/// Apply a partial JSON override on top of `base` [`NcOptions`].
pub fn nc_options_from_json(v: &Json, base: NcOptions) -> Result<NcOptions> {
    let mut opts = base;
    opt_fields!(v, opts, {
        "max_iters" => max_iters: usize,
        "mm_tol" => mm_tol: f64,
        "kkt_tol" => kkt_tol: f64,
        "kkt_band" => kkt_band: f64,
        "gamma_init" => gamma_init: f64,
        "gamma_shrink" => gamma_shrink: f64,
        "gamma_min" => gamma_min: f64,
        "max_expansions" => max_expansions: usize,
        "projection" => projection: bool,
        "max_stall_rungs" => max_stall_rungs: usize,
    });
    Ok(opts)
}

/// Full serialization of [`NcOptions`] (round-trips exactly).
pub fn nc_options_to_json(o: &NcOptions) -> Json {
    Json::obj(vec![
        ("max_iters", Json::num(o.max_iters as f64)),
        ("mm_tol", Json::num(o.mm_tol)),
        ("kkt_tol", Json::num(o.kkt_tol)),
        ("kkt_band", Json::num(o.kkt_band)),
        ("gamma_init", Json::num(o.gamma_init)),
        ("gamma_shrink", Json::num(o.gamma_shrink)),
        ("gamma_min", Json::num(o.gamma_min)),
        ("max_expansions", Json::num(o.max_expansions as f64)),
        ("projection", Json::Bool(o.projection)),
        ("max_stall_rungs", Json::num(o.max_stall_rungs as f64)),
    ])
}

// ---------------------------------------------------------------------------
// Task
// ---------------------------------------------------------------------------

/// What to compute on the spec's (x, y, kernel).
#[derive(Clone, Debug, PartialEq)]
pub enum Task {
    /// One (τ, λ) KQR fit.
    Single { tau: f64, lambda: f64 },
    /// Warm-started descending-λ path at one τ.
    Path { tau: f64, lambdas: Vec<f64> },
    /// Full τ×λ grid on one cached basis ([`FitEngine::fit_grid`]).
    Grid { taus: Vec<f64>, lambdas: Vec<f64> },
    /// Simultaneous non-crossing fit (NCKQR).
    NonCrossing { taus: Vec<f64>, lam1: f64, lam2: f64 },
    /// k-fold CV over a λ grid, one run per τ, each refit at its best λ.
    Cv { taus: Vec<f64>, lambdas: Vec<f64>, folds: usize, seed: u64 },
}

impl Task {
    pub fn to_json(&self) -> Json {
        match self {
            Task::Single { tau, lambda } => Json::obj(vec![
                ("type", Json::str("single")),
                ("tau", Json::num(*tau)),
                ("lambda", Json::num(*lambda)),
            ]),
            Task::Path { tau, lambdas } => Json::obj(vec![
                ("type", Json::str("path")),
                ("tau", Json::num(*tau)),
                ("lambdas", Json::arr_f64(lambdas)),
            ]),
            Task::Grid { taus, lambdas } => Json::obj(vec![
                ("type", Json::str("grid")),
                ("taus", Json::arr_f64(taus)),
                ("lambdas", Json::arr_f64(lambdas)),
            ]),
            Task::NonCrossing { taus, lam1, lam2 } => Json::obj(vec![
                ("type", Json::str("noncrossing")),
                ("taus", Json::arr_f64(taus)),
                ("lam1", Json::num(*lam1)),
                ("lam2", Json::num(*lam2)),
            ]),
            Task::Cv { taus, lambdas, folds, seed } => Json::obj(vec![
                ("type", Json::str("cv")),
                ("taus", Json::arr_f64(taus)),
                ("lambdas", Json::arr_f64(lambdas)),
                ("folds", Json::num(*folds as f64)),
                ("seed", Json::num(*seed as f64)),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Task> {
        Task::from_json_seeded(v, DEFAULT_SEED)
    }

    /// [`Task::from_json`] with an explicit default for `cv.seed` — the
    /// spec's master seed, so one `"seed"` at the top of the document
    /// pins both landmark sampling and fold shuffling.
    pub fn from_json_seeded(v: &Json, default_seed: u64) -> Result<Task> {
        let ty = v.get_str("type").ok_or_else(|| anyhow!("task: missing 'type'"))?;
        let f = |key: &str| v.get_f64(key).ok_or_else(|| anyhow!("task: missing number {key:?}"));
        let fs = |key: &str| {
            v.get_f64_arr_strict(key)
                .ok_or_else(|| anyhow!("task: missing numeric array {key:?}"))
        };
        match ty {
            "single" => Ok(Task::Single { tau: f("tau")?, lambda: f("lambda")? }),
            "path" => Ok(Task::Path { tau: f("tau")?, lambdas: fs("lambdas")? }),
            "grid" => Ok(Task::Grid { taus: fs("taus")?, lambdas: fs("lambdas")? }),
            "noncrossing" | "non_crossing" | "nckqr" => Ok(Task::NonCrossing {
                taus: fs("taus")?,
                lam1: f("lam1")?,
                lam2: f("lam2")?,
            }),
            "cv" => {
                // Absent → default; present-but-invalid → error, like
                // every other spec field (a "folds":"ten" must not
                // silently run 5-fold CV).
                let folds = match v.get("folds") {
                    None => 5,
                    Some(_) => v
                        .get_usize("folds")
                        .ok_or_else(|| anyhow!("task: folds must be a non-negative integer"))?,
                };
                let seed = match v.get("seed") {
                    None => default_seed,
                    Some(_) => v
                        .get_usize("seed")
                        .ok_or_else(|| anyhow!("task: seed must be a non-negative integer"))?
                        as u64,
                };
                Ok(Task::Cv { taus: fs("taus")?, lambdas: fs("lambdas")?, folds, seed })
            }
            other => bail!("unknown task type {other:?} (single|path|grid|noncrossing|cv)"),
        }
    }
}

// ---------------------------------------------------------------------------
// FitSpec
// ---------------------------------------------------------------------------

/// A complete, declarative, serializable fit request.
#[derive(Clone, Debug)]
pub struct FitSpec {
    pub x: Matrix,
    pub y: Vec<f64>,
    pub kernel: KernelSpec,
    /// Gram representation: exact (default, the bitwise oracle), a
    /// rank-m Nyström thin factor, or a D-dimensional random Fourier
    /// feature basis. Serialized as the kernel object's `approx` block.
    pub approx: ApproxSpec,
    pub task: Task,
    /// KQR solver overrides; `None` → the executing engine's defaults.
    pub opts: Option<SolveOptions>,
    /// NCKQR solver overrides; `None` → [`NcOptions::default`].
    pub nc_opts: Option<NcOptions>,
    /// Grid strategy hint: force the lockstep / sequential driver
    /// (`None` → engine config / `FASTKQR_LOCKSTEP`).
    pub lockstep: Option<bool>,
    /// APGD backend hint for Single/Path tasks: `"native"` (default) or
    /// `"xla"` (requires the `xla` cargo feature at runtime).
    pub backend: Option<String>,
    /// Solver backend: `Apgd` (the default), `Ssn` (pALM semismooth
    /// Newton), or `Auto` (resolved per problem by
    /// [`FitSpec::resolved_solver`]). `None` → `Apgd`.
    pub solver: Option<SolverBackend>,
    /// Master seed (`"seed"`, default [`DEFAULT_SEED`]): the default for
    /// Nyström landmark sampling and CV fold shuffling, so a spec
    /// document alone reproduces every randomized choice.
    pub seed: u64,
}

impl FitSpec {
    pub fn new(x: Matrix, y: Vec<f64>, kernel: KernelSpec, task: Task) -> FitSpec {
        FitSpec {
            x,
            y,
            kernel,
            approx: ApproxSpec::Exact,
            task,
            opts: None,
            nc_opts: None,
            lockstep: None,
            backend: None,
            solver: None,
            seed: DEFAULT_SEED,
        }
    }

    pub fn single(x: Matrix, y: Vec<f64>, kernel: KernelSpec, tau: f64, lambda: f64) -> FitSpec {
        FitSpec::new(x, y, kernel, Task::Single { tau, lambda })
    }

    pub fn path(x: Matrix, y: Vec<f64>, kernel: KernelSpec, tau: f64, lambdas: Vec<f64>) -> FitSpec {
        FitSpec::new(x, y, kernel, Task::Path { tau, lambdas })
    }

    pub fn grid(
        x: Matrix,
        y: Vec<f64>,
        kernel: KernelSpec,
        taus: Vec<f64>,
        lambdas: Vec<f64>,
    ) -> FitSpec {
        FitSpec::new(x, y, kernel, Task::Grid { taus, lambdas })
    }

    pub fn non_crossing(
        x: Matrix,
        y: Vec<f64>,
        kernel: KernelSpec,
        taus: Vec<f64>,
        lam1: f64,
        lam2: f64,
    ) -> FitSpec {
        FitSpec::new(x, y, kernel, Task::NonCrossing { taus, lam1, lam2 })
    }

    pub fn cv(
        x: Matrix,
        y: Vec<f64>,
        kernel: KernelSpec,
        taus: Vec<f64>,
        lambdas: Vec<f64>,
        folds: usize,
        seed: u64,
    ) -> FitSpec {
        FitSpec::new(x, y, kernel, Task::Cv { taus, lambdas, folds, seed })
    }

    pub fn with_opts(mut self, opts: SolveOptions) -> FitSpec {
        self.opts = Some(opts);
        self
    }

    pub fn with_nc_opts(mut self, opts: NcOptions) -> FitSpec {
        self.nc_opts = Some(opts);
        self
    }

    pub fn with_lockstep(mut self, lockstep: bool) -> FitSpec {
        self.lockstep = Some(lockstep);
        self
    }

    pub fn with_backend(mut self, backend: impl Into<String>) -> FitSpec {
        self.backend = Some(backend.into());
        self
    }

    /// Select the solver backend (APGD / SSN / per-problem `Auto`).
    pub fn with_solver(mut self, solver: SolverBackend) -> FitSpec {
        self.solver = Some(solver);
        self
    }

    /// Select the Gram representation (e.g. `ApproxSpec::Nystrom`).
    pub fn with_approx(mut self, approx: ApproxSpec) -> FitSpec {
        self.approx = approx;
        self
    }

    /// Pin the spec's master seed (see [`FitSpec::seed`]).
    pub fn with_seed(mut self, seed: u64) -> FitSpec {
        self.seed = seed;
        self
    }

    /// Structural validation (shape + non-empty axes). Numeric validity
    /// (τ ∈ (0,1), λ > 0, fold counts) is enforced by the solvers, which
    /// already error rather than panic on bad values.
    pub fn validate(&self) -> Result<()> {
        if self.x.rows() == 0 || self.x.cols() == 0 {
            bail!("spec: x must be non-empty");
        }
        if self.y.len() != self.x.rows() {
            bail!("spec: len(y)={} != rows(x)={}", self.y.len(), self.x.rows());
        }
        // Seeds travel through JSON numbers (f64): anything above 2^53
        // would silently round on round-trip, breaking the
        // reproducibility-from-document guarantee the field exists for.
        const SEED_MAX: u64 = 1 << 53;
        if self.seed > SEED_MAX {
            bail!("spec: seed must be <= 2^53 for exact JSON round-trip, got {}", self.seed);
        }
        if let ApproxSpec::Nystrom { m, seed } = self.approx {
            if m == 0 || m > self.x.rows() {
                bail!("spec: nystrom needs 0 < m <= n (m={m}, n={})", self.x.rows());
            }
            if seed > SEED_MAX {
                bail!("spec: nystrom seed must be <= 2^53 for exact JSON round-trip");
            }
            // CV fits each fold on ~n(k-1)/k rows: m must fit the
            // smallest fold-training set, not just the full data, or the
            // task errors confusingly mid-run inside nystrom().
            if let Task::Cv { folds, .. } = &self.task {
                if *folds >= 2 {
                    let n = self.x.rows();
                    let min_train = n - (n + *folds - 1) / *folds;
                    if m > min_train {
                        bail!(
                            "spec: nystrom m={m} exceeds the smallest CV fold \
                             training size {min_train} (n={n}, folds={folds})"
                        );
                    }
                }
            }
        }
        if let ApproxSpec::RandomFeatures { d, seed } = self.approx {
            if d == 0 {
                bail!("spec: rff needs d >= 1 random features");
            }
            if seed > SEED_MAX {
                bail!("spec: rff seed must be <= 2^53 for exact JSON round-trip");
            }
            // Mirror the Nyström fold check: the basis rank is capped at
            // the fold-training size, so a D above it buys nothing and
            // usually signals a misconfigured budget — reject up front
            // with the fold arithmetic spelled out instead of fitting a
            // silently-smaller basis per fold.
            if let Task::Cv { folds, .. } = &self.task {
                if *folds >= 2 {
                    let n = self.x.rows();
                    let min_train = n - (n + *folds - 1) / *folds;
                    if d > min_train {
                        bail!(
                            "spec: rff d={d} exceeds the smallest CV fold \
                             training size {min_train} (n={n}, folds={folds})"
                        );
                    }
                }
            }
        }
        if let Task::Cv { seed, .. } = &self.task {
            if *seed > SEED_MAX {
                bail!("spec: cv seed must be <= 2^53 for exact JSON round-trip");
            }
        }
        if self.solver == Some(SolverBackend::Ssn) {
            if let Task::Cv { .. } = &self.task {
                bail!("spec: solver \"ssn\" does not support the cv task (use apgd or auto)")
            }
            if matches!(self.backend.as_deref(), Some("xla")) {
                bail!(
                    "spec: solver \"ssn\" cannot run on the xla backend \
                     (xla executes APGD iteration chunks)"
                );
            }
        }
        match &self.task {
            Task::Path { lambdas, .. } if lambdas.is_empty() => bail!("spec: empty lambdas"),
            Task::Grid { taus, lambdas } if taus.is_empty() || lambdas.is_empty() => {
                bail!("spec: empty grid axis")
            }
            Task::NonCrossing { taus, .. } if taus.is_empty() => bail!("spec: empty taus"),
            Task::Cv { taus, lambdas, .. } if taus.is_empty() || lambdas.is_empty() => {
                bail!("spec: empty cv axis")
            }
            _ => Ok(()),
        }
    }

    /// The concrete backend this spec fits with — `Auto` resolves here,
    /// as a pure function of the document (n, representation rank, grid
    /// size; see [`solver::auto_select`]), so the same spec picks the
    /// same backend on every machine. The CV task and the xla iteration
    /// backend always resolve to APGD; `NonCrossing` counts one cell per
    /// quantile level (the lifted Newton system couples them).
    pub fn resolved_solver(&self) -> SolverBackend {
        if matches!(self.backend.as_deref(), Some("xla")) {
            return SolverBackend::Apgd;
        }
        match self.solver.unwrap_or_default() {
            SolverBackend::Auto => {
                if matches!(self.task, Task::Cv { .. }) {
                    return SolverBackend::Apgd;
                }
                self.auto_resolution().backend
            }
            concrete => concrete,
        }
    }

    /// The cost-model inputs (n, representation rank, grid cells) this
    /// document presents to [`solver::auto_select`], echoed back with
    /// the backend the model would pick. Informational when the spec
    /// pins a concrete solver — [`Self::resolved_solver`] is the binding
    /// decision (it also handles the CV/xla forced-APGD cases).
    pub fn auto_resolution(&self) -> solver::AutoResolution {
        let cells = match &self.task {
            Task::Single { .. } => 1,
            Task::Path { lambdas, .. } => lambdas.len(),
            Task::Grid { taus, lambdas } | Task::Cv { taus, lambdas, .. } => {
                taus.len() * lambdas.len()
            }
            Task::NonCrossing { taus, .. } => taus.len(),
        };
        let n = self.x.rows();
        let rank = match self.approx {
            ApproxSpec::Exact => n,
            ApproxSpec::Nystrom { m, .. } => m.min(n),
            ApproxSpec::RandomFeatures { d, .. } => d.min(n),
        };
        solver::auto_resolve(n, rank, cells)
    }

    pub fn to_json(&self) -> Json {
        let mut kernel_json = self.kernel.to_json();
        if let Some(a) = approx_to_json(&self.approx) {
            if let Json::Obj(map) = &mut kernel_json {
                map.insert("approx".into(), a);
            }
        }
        // Lowest version that represents the document (see SPEC_VERSION).
        let version: u64 = if self.solver.is_some() {
            4
        } else {
            match self.approx {
                ApproxSpec::RandomFeatures { .. } => 3,
                ApproxSpec::Nystrom { .. } => 2,
                ApproxSpec::Exact => 1,
            }
        };
        let mut pairs = vec![
            ("version", Json::num(version as f64)),
            ("kernel", kernel_json),
            ("task", self.task.to_json()),
            ("x", matrix_to_json(&self.x)),
            ("y", Json::arr_f64(&self.y)),
            ("seed", Json::num(self.seed as f64)),
        ];
        if let Some(o) = &self.opts {
            pairs.push(("opts", solve_options_to_json(o)));
        }
        if let Some(o) = &self.nc_opts {
            pairs.push(("nc_opts", nc_options_to_json(o)));
        }
        if let Some(l) = self.lockstep {
            pairs.push(("lockstep", Json::Bool(l)));
        }
        if let Some(b) = &self.backend {
            pairs.push(("backend", Json::str(b.clone())));
        }
        if let Some(s) = self.solver {
            pairs.push(("solver", Json::str(s.as_str())));
        }
        Json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<FitSpec> {
        let version = v.get_usize("version").unwrap_or(1) as u64;
        if version > SPEC_VERSION {
            bail!("spec version {version} is newer than supported {SPEC_VERSION}");
        }
        let x = matrix_from_json(v.get("x").ok_or_else(|| anyhow!("spec: missing 'x'"))?)?;
        let y = v
            .get_f64_arr_strict("y")
            .ok_or_else(|| anyhow!("spec: 'y' must be a numeric array"))?;
        let seed = match v.get("seed") {
            None => DEFAULT_SEED,
            Some(_) => v
                .get_usize("seed")
                .ok_or_else(|| anyhow!("spec: seed must be a non-negative integer"))?
                as u64,
        };
        let (kernel, approx) = match v.get("kernel") {
            None => (KernelSpec::Auto, ApproxSpec::Exact),
            Some(k) => {
                let approx = match k.get("approx") {
                    None => ApproxSpec::Exact,
                    Some(a) => approx_from_json(a, seed)?,
                };
                (KernelSpec::from_json(k)?, approx)
            }
        };
        let task = Task::from_json_seeded(
            v.get("task").ok_or_else(|| anyhow!("spec: missing 'task'"))?,
            seed,
        )?;
        let opts = match v.get("opts") {
            None => None,
            Some(o) => Some(solve_options_from_json(o, SolveOptions::default())?),
        };
        let nc_opts = match v.get("nc_opts") {
            None => None,
            Some(o) => Some(nc_options_from_json(o, NcOptions::default())?),
        };
        let lockstep = match v.get("lockstep") {
            None => None,
            Some(l) => Some(l.as_bool().ok_or_else(|| anyhow!("spec: lockstep must be a bool"))?),
        };
        let backend = v.get_str("backend").map(String::from);
        let solver = match v.get("solver") {
            None => None,
            Some(s) => {
                let name = s
                    .as_str()
                    .ok_or_else(|| anyhow!("spec: solver must be a string (apgd|ssn|auto)"))?;
                Some(SolverBackend::parse(name)?)
            }
        };
        let spec = FitSpec {
            x,
            y,
            kernel,
            approx,
            task,
            opts,
            nc_opts,
            lockstep,
            backend,
            solver,
            seed,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a spec from JSON text.
    pub fn parse(s: &str) -> Result<FitSpec> {
        let v = Json::parse(s).map_err(|e| anyhow!("spec: {e}"))?;
        FitSpec::from_json(&v)
    }
}

/// APGD backend names this build can actually construct: the `xla`
/// cargo feature gates the PJRT backend, so error messages (and name
/// acceptance) must not advertise it on a default build.
pub const BACKEND_NAMES: &str = if cfg!(feature = "xla") { "native|xla" } else { "native" };

fn backend_for(name: Option<&str>) -> Result<Box<dyn Backend>> {
    match name.unwrap_or("native") {
        "native" => Ok(Box::new(NativeBackend::new())),
        "xla" if cfg!(feature = "xla") => {
            Ok(Box::new(crate::runtime::XlaBackend::from_default_dir()?))
        }
        "xla" => bail!(
            "backend \"xla\" is not compiled into this build \
             (enable the `xla` cargo feature); available: {BACKEND_NAMES}"
        ),
        other => bail!("unknown backend {other:?} ({BACKEND_NAMES})"),
    }
}

// ---------------------------------------------------------------------------
// Execution
// ---------------------------------------------------------------------------

impl FitEngine {
    /// Execute a [`FitSpec`] on this engine. Every task — including
    /// `NonCrossing` — draws its Gram matrix and eigenbasis from the
    /// engine's [`crate::engine::GramCache`], so repeated or concurrent
    /// specs on the same (x, y, kernel) share one O(n³) decomposition.
    pub fn run(&self, spec: &FitSpec) -> Result<QuantileModel> {
        spec.validate()?;
        let kernel = spec.kernel.resolve(&spec.x);
        let approx = spec.approx;
        if approx != ApproxSpec::Exact && matches!(spec.backend.as_deref(), Some("xla")) {
            bail!("the xla backend does not support approximate (Nyström/RFF) bases; use native");
        }
        let opts = spec.opts.clone().unwrap_or_else(|| self.config.opts.clone());
        // Auto resolves from the document alone, before any fitting.
        let solver_backend = spec.resolved_solver();
        match &spec.task {
            Task::Single { tau, lambda } => {
                let solver = self.solver_approx(&spec.x, &spec.y, &kernel, approx, opts)?;
                let fit = if solver_backend == SolverBackend::Ssn {
                    let mut state = SsnState::zeros(solver.n(), solver.basis.dim());
                    solver::fit_warm_from(&solver, *tau, *lambda, &mut state)?
                } else {
                    let mut backend = backend_for(spec.backend.as_deref())?;
                    let mut state = ApgdState::zeros(solver.state_dim());
                    solver.fit_warm(*tau, *lambda, &mut state, backend.as_mut())?
                };
                Ok(QuantileModel::Kqr(fit))
            }
            Task::Path { tau, lambdas } => {
                let solver = self.solver_approx(&spec.x, &spec.y, &kernel, approx, opts)?;
                let (fits, ssn) = if solver_backend == SolverBackend::Ssn {
                    // A path is a one-column grid: run the carry driver
                    // so the factor flows down the λ column and the
                    // reuse counters surface in diagnostics.
                    let (cols, stats) =
                        solver::fit_tau_columns_ssn_carry(&solver, &[*tau], lambdas)?;
                    (cols.into_iter().flatten().collect::<Vec<_>>(), Some(stats))
                } else {
                    let mut backend = backend_for(spec.backend.as_deref())?;
                    (solver.fit_path_with_backend(*tau, lambdas, backend.as_mut())?, None)
                };
                Ok(QuantileModel::Set(ModelSet {
                    fits,
                    shape: SetShape::Path { tau: *tau },
                    cv: Vec::new(),
                    lockstep: None,
                    solver: Some(solver_backend),
                    ssn,
                }))
            }
            Task::Grid { taus, lambdas } => {
                let grid = self.fit_grid_with_solver(
                    &spec.x,
                    &spec.y,
                    &kernel,
                    taus,
                    lambdas,
                    approx,
                    spec.lockstep,
                    spec.opts.clone(),
                    solver_backend,
                )?;
                Ok(QuantileModel::from_grid(grid))
            }
            Task::NonCrossing { taus, lam1, lam2 } => {
                let nc_opts = spec.nc_opts.clone().unwrap_or_default();
                let solver = self.nc_solver_approx_with_options(
                    &spec.x, &spec.y, &kernel, taus, approx, nc_opts,
                )?;
                let fit = if solver_backend == SolverBackend::Ssn {
                    solver.fit_ssn(*lam1, *lam2)?
                } else {
                    solver.fit(*lam1, *lam2)?
                };
                Ok(QuantileModel::Nckqr(fit))
            }
            Task::Cv { taus, lambdas, folds, seed } => {
                let data = Dataset::new("spec", spec.x.clone(), spec.y.clone());
                let mut fits = Vec::with_capacity(taus.len());
                let mut summaries = Vec::with_capacity(taus.len());
                for &tau in taus {
                    // A fresh RNG from the same seed per τ: every level
                    // scores on the identical fold assignment, so CV
                    // losses are comparable across τ.
                    let mut rng = Rng::new(*seed);
                    let res = cross_validate_on(
                        self, &data, &kernel, tau, lambdas, *folds, &opts, approx, &mut rng,
                    )?;
                    let refit = res
                        .refit
                        .clone()
                        .ok_or_else(|| anyhow!("cv produced no refit at tau={tau}"))?;
                    fits.push(refit);
                    summaries.push(CvSummary {
                        tau,
                        lambdas: res.lambdas,
                        cv_loss: res.cv_loss,
                        best_index: res.best_index,
                        best_lambda: res.best_lambda,
                    });
                }
                Ok(QuantileModel::Set(ModelSet {
                    fits,
                    shape: SetShape::Cv { folds: *folds, seed: *seed },
                    cv: summaries,
                    lockstep: None,
                    solver: Some(SolverBackend::Apgd),
                    ssn: None,
                }))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn toy_spec(task: Task) -> FitSpec {
        let mut rng = Rng::new(11);
        let d = synth::sine_hetero(24, &mut rng);
        FitSpec::new(d.x, d.y, KernelSpec::Rbf { sigma: Some(0.5) }, task)
    }

    #[test]
    fn spec_json_roundtrip_is_exact() {
        let spec = toy_spec(Task::Grid { taus: vec![0.25, 0.5], lambdas: vec![0.1, 0.01] })
            .with_lockstep(true)
            .with_opts(SolveOptions::cv_preset());
        let s1 = spec.to_json().to_string();
        let back = FitSpec::parse(&s1).unwrap();
        assert_eq!(back.to_json().to_string(), s1, "to_json∘from_json must be identity");
        assert_eq!(back.task, spec.task);
        assert_eq!(back.kernel, spec.kernel);
        assert_eq!(back.lockstep, Some(true));
        assert_eq!(back.x.as_slice(), spec.x.as_slice());
    }

    #[test]
    fn spec_rejects_malformed_documents() {
        // ragged x
        assert!(FitSpec::parse(
            r#"{"x":[[1,2],[3]],"y":[1,2],"task":{"type":"single","tau":0.5,"lambda":0.1}}"#
        )
        .is_err());
        // unknown task
        assert!(FitSpec::parse(
            r#"{"x":[[1],[2]],"y":[1,2],"task":{"type":"warp","tau":0.5}}"#
        )
        .is_err());
        // bad kernel type
        assert!(FitSpec::parse(
            r#"{"x":[[1],[2]],"y":[1,2],"kernel":{"type":"sinc"},
                "task":{"type":"single","tau":0.5,"lambda":0.1}}"#
        )
        .is_err());
        // y/x length mismatch
        assert!(FitSpec::parse(
            r#"{"x":[[1],[2]],"y":[1],"task":{"type":"single","tau":0.5,"lambda":0.1}}"#
        )
        .is_err());
        // non-numeric y entry
        assert!(FitSpec::parse(
            r#"{"x":[[1],[2]],"y":[1,"a"],"task":{"type":"single","tau":0.5,"lambda":0.1}}"#
        )
        .is_err());
        // unknown solver option key
        assert!(FitSpec::parse(
            r#"{"x":[[1],[2]],"y":[1,2],"opts":{"kkt_tolerance":0.1},
                "task":{"type":"single","tau":0.5,"lambda":0.1}}"#
        )
        .is_err());
    }

    #[test]
    fn nystrom_spec_roundtrips_versions_and_runs() {
        let ny = ApproxSpec::Nystrom { m: 10, seed: 7 };
        let spec = toy_spec(Task::Single { tau: 0.5, lambda: 0.05 }).with_approx(ny).with_seed(7);
        // version bump rules: exact specs stay v1, nystrom specs write v2
        assert_eq!(spec.to_json().get_usize("version"), Some(2));
        assert_eq!(
            toy_spec(Task::Single { tau: 0.5, lambda: 0.05 }).to_json().get_usize("version"),
            Some(1)
        );
        let s1 = spec.to_json().to_string();
        let back = FitSpec::parse(&s1).unwrap();
        assert_eq!(back.approx, ny);
        assert_eq!(back.seed, 7);
        assert_eq!(back.to_json().to_string(), s1, "to_json∘from_json identity");
        // approx seed defaults to the spec's master seed
        let doc = r#"{"x":[[1],[2],[3]],"y":[1,2,3],"seed":99,
            "kernel":{"type":"rbf","sigma":0.5,"approx":{"type":"nystrom","m":2}},
            "task":{"type":"single","tau":0.5,"lambda":0.1}}"#
            .replace('\n', " ");
        let parsed = FitSpec::parse(&doc).unwrap();
        assert_eq!(parsed.approx, ApproxSpec::Nystrom { m: 2, seed: 99 });
        // unknown approx keys / bad m are rejected loudly
        assert!(FitSpec::parse(
            r#"{"x":[[1],[2]],"y":[1,2],
                "kernel":{"approx":{"type":"nystrom","m":1,"mm":3}},
                "task":{"type":"single","tau":0.5,"lambda":0.1}}"#
        )
        .is_err());
        assert!(FitSpec::parse(
            r#"{"x":[[1],[2]],"y":[1,2],
                "kernel":{"approx":{"type":"nystrom","m":9}},
                "task":{"type":"single","tau":0.5,"lambda":0.1}}"#
        )
        .is_err(), "m > n must be rejected");
        // and the spec executes on the thin basis end-to-end
        let engine = FitEngine::new();
        let model = engine.run(&spec).unwrap();
        match &model {
            QuantileModel::Kqr(f) => {
                assert!(f.lowrank.is_some(), "low-rank fit carries the compressed predictor")
            }
            other => panic!("expected Kqr model, got {}", other.kind()),
        }
    }

    #[test]
    fn rff_spec_roundtrips_versions_and_runs() {
        let rf = ApproxSpec::RandomFeatures { d: 16, seed: 9 };
        let spec = toy_spec(Task::Single { tau: 0.5, lambda: 0.05 }).with_approx(rf).with_seed(9);
        // rff specs write v3 (older readers must reject, not fit exact)
        assert_eq!(spec.to_json().get_usize("version"), Some(3));
        let s1 = spec.to_json().to_string();
        let back = FitSpec::parse(&s1).unwrap();
        assert_eq!(back.approx, rf);
        assert_eq!(back.to_json().to_string(), s1, "to_json∘from_json identity");
        // approx seed defaults to the spec's master seed
        let doc = r#"{"x":[[1],[2],[3]],"y":[1,2,3],"seed":88,
            "kernel":{"type":"rbf","sigma":0.5,"approx":{"type":"rff","d":4}},
            "task":{"type":"single","tau":0.5,"lambda":0.1}}"#
            .replace('\n', " ");
        let parsed = FitSpec::parse(&doc).unwrap();
        assert_eq!(parsed.approx, ApproxSpec::RandomFeatures { d: 4, seed: 88 });
        // unknown keys / d = 0 / CV folds too small for d are rejected
        assert!(FitSpec::parse(
            r#"{"x":[[1],[2]],"y":[1,2],
                "kernel":{"approx":{"type":"rff","d":4,"dd":3}},
                "task":{"type":"single","tau":0.5,"lambda":0.1}}"#
        )
        .is_err());
        assert!(FitSpec::parse(
            r#"{"x":[[1],[2]],"y":[1,2],
                "kernel":{"approx":{"type":"rff","d":0}},
                "task":{"type":"single","tau":0.5,"lambda":0.1}}"#
        )
        .is_err(), "d = 0 must be rejected");
        assert!(FitSpec::parse(
            r#"{"x":[[1],[2],[3],[4]],"y":[1,2,3,4],
                "kernel":{"type":"rbf","sigma":0.5,"approx":{"type":"rff","d":3}},
                "task":{"type":"cv","taus":[0.5],"lambdas":[0.1],"folds":2}}"#
        )
        .is_err(), "d above the smallest CV fold-training size must be rejected");
        // and the spec executes on the random-feature basis end-to-end
        let engine = FitEngine::new();
        let model = engine.run(&spec).unwrap();
        match &model {
            QuantileModel::Kqr(f) => {
                assert!(f.rff.is_some(), "rff fit carries the compressed predictor");
                assert!(f.lowrank.is_none());
            }
            other => panic!("expected Kqr model, got {}", other.kind()),
        }
    }

    #[test]
    fn kernel_spec_resolves_median_heuristic() {
        let mut rng = Rng::new(3);
        let d = synth::sine_hetero(20, &mut rng);
        let auto = KernelSpec::Auto.resolve(&d.x);
        let expect = Kernel::Rbf { sigma: median_heuristic_sigma(&d.x) };
        assert_eq!(auto, expect);
        let pinned = KernelSpec::Rbf { sigma: Some(0.3) }.resolve(&d.x);
        assert_eq!(pinned, Kernel::Rbf { sigma: 0.3 });
    }

    #[test]
    fn run_single_matches_direct_solver() {
        let spec = toy_spec(Task::Single { tau: 0.5, lambda: 0.05 });
        let engine = FitEngine::new();
        let model = engine.run(&spec).unwrap();
        let direct = crate::kqr::KqrSolver::new(&spec.x, &spec.y, spec.kernel.resolve(&spec.x))
            .unwrap()
            .fit(0.5, 0.05)
            .unwrap();
        match &model {
            QuantileModel::Kqr(f) => {
                assert_eq!(f.objective, direct.objective, "engine path must be exact");
                assert_eq!(f.alpha, direct.alpha);
            }
            other => panic!("expected Kqr model, got {}", other.kind()),
        }
        assert_eq!(model.taus(), vec![0.5]);
    }

    #[test]
    fn solver_field_versions_roundtrips_and_validates() {
        let base = toy_spec(Task::Single { tau: 0.5, lambda: 0.05 });
        assert_eq!(base.to_json().get_usize("version"), Some(1), "no solver → no version bump");
        let spec =
            toy_spec(Task::Single { tau: 0.5, lambda: 0.05 }).with_solver(SolverBackend::Ssn);
        assert_eq!(spec.to_json().get_usize("version"), Some(4), "solver field writes v4");
        let s1 = spec.to_json().to_string();
        let back = FitSpec::parse(&s1).unwrap();
        assert_eq!(back.solver, Some(SolverBackend::Ssn));
        assert_eq!(back.to_json().to_string(), s1, "to_json∘from_json identity");
        // unknown solver names and non-string values are rejected loudly
        assert!(FitSpec::parse(
            r#"{"x":[[1],[2]],"y":[1,2],"solver":"newton",
                "task":{"type":"single","tau":0.5,"lambda":0.1}}"#
        )
        .is_err());
        assert!(FitSpec::parse(
            r#"{"x":[[1],[2]],"y":[1,2],"solver":3,
                "task":{"type":"single","tau":0.5,"lambda":0.1}}"#
        )
        .is_err());
        // tasks SSN does not cover are validation errors, not silent fallbacks
        let cv = toy_spec(Task::Cv { taus: vec![0.5], lambdas: vec![0.1], folds: 2, seed: 0 })
            .with_solver(SolverBackend::Ssn);
        let err = cv.validate().unwrap_err().to_string();
        assert!(err.contains("ssn"), "{err}");
        // the non-crossing task is covered (lifted Newton system)
        let nc = toy_spec(Task::NonCrossing { taus: vec![0.25, 0.75], lam1: 5.0, lam2: 0.05 })
            .with_solver(SolverBackend::Ssn);
        nc.validate().unwrap();
        let xla = toy_spec(Task::Single { tau: 0.5, lambda: 0.05 })
            .with_solver(SolverBackend::Ssn)
            .with_backend("xla");
        assert!(xla.validate().is_err());
    }

    #[test]
    fn auto_solver_resolves_deterministically_from_the_document() {
        // thin basis (n=24, rank 8, 1 cell) → the cost model picks SSN
        let spec = toy_spec(Task::Single { tau: 0.5, lambda: 0.05 })
            .with_approx(ApproxSpec::Nystrom { m: 8, seed: 3 })
            .with_seed(3)
            .with_solver(SolverBackend::Auto);
        let resolved = spec.resolved_solver();
        assert_ne!(resolved, SolverBackend::Auto, "Auto must resolve concretely");
        let back = FitSpec::parse(&spec.to_json().to_string()).unwrap();
        assert_eq!(
            back.resolved_solver(),
            resolved,
            "resolution is a function of the document alone"
        );
        assert_eq!(resolved, SolverBackend::Ssn);
        // tasks outside SSN's coverage always resolve to APGD
        let cv = toy_spec(Task::Cv { taus: vec![0.5], lambdas: vec![0.1], folds: 2, seed: 0 })
            .with_solver(SolverBackend::Auto);
        assert_eq!(cv.resolved_solver(), SolverBackend::Apgd);
        // non-crossing resolves concretely (one cell per level)
        let nc = toy_spec(Task::NonCrossing { taus: vec![0.25, 0.75], lam1: 5.0, lam2: 0.05 })
            .with_solver(SolverBackend::Auto);
        assert_ne!(nc.resolved_solver(), SolverBackend::Auto);
    }

    #[test]
    fn run_noncrossing_ssn_is_certified_and_counted() {
        let spec = toy_spec(Task::NonCrossing { taus: vec![0.25, 0.75], lam1: 5.0, lam2: 0.05 })
            .with_solver(SolverBackend::Ssn);
        let engine = FitEngine::new();
        let model = engine.run(&spec).unwrap();
        match &model {
            QuantileModel::Nckqr(f) => {
                assert!(f.kkt.pass, "{:?}", f.kkt);
                let stats = f.ssn.expect("ssn counters attached");
                assert!(stats.newton_steps > 0 && stats.refactorizations >= 1);
            }
            other => panic!("expected Nckqr model, got {}", other.kind()),
        }
    }

    #[test]
    fn backend_for_is_feature_aware() {
        assert!(backend_for(None).is_ok());
        assert!(backend_for(Some("native")).is_ok());
        let err = backend_for(Some("bogus")).unwrap_err().to_string();
        assert!(err.contains(BACKEND_NAMES), "{err}");
        #[cfg(not(feature = "xla"))]
        {
            assert!(!BACKEND_NAMES.contains("xla"), "names must match the build");
            let err = backend_for(Some("xla")).unwrap_err().to_string();
            assert!(err.contains("not compiled"), "{err}");
        }
        #[cfg(feature = "xla")]
        assert!(BACKEND_NAMES.contains("xla"));
    }

    #[test]
    fn run_noncrossing_uses_the_gram_cache() {
        let spec = toy_spec(Task::NonCrossing { taus: vec![0.25, 0.75], lam1: 5.0, lam2: 0.05 });
        let engine = FitEngine::new();
        let m1 = engine.run(&spec).unwrap();
        let m2 = engine.run(&spec).unwrap();
        assert_eq!(
            crate::engine::CacheMetrics::get(&engine.cache.metrics.decompositions),
            1,
            "repeated NonCrossing specs must share one decomposition"
        );
        assert_eq!(m1.taus(), m2.taus());
    }
}
