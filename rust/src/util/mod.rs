//! Zero-dependency utility substrates: mini-JSON, CLI parsing, the bench
//! harness and a scoped timer/logging helper.

pub mod bench;
pub mod cli;
pub mod hist;
pub mod json;
pub mod timer;

pub use cli::Args;
pub use hist::Histogram;
pub use json::Json;
pub use timer::Timer;

/// Best-effort extraction of a panic payload's message, for worker pools
/// that surface a poisoned thread as an `Err` on the affected job instead
/// of aborting a process serving other jobs.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}
