//! # fastkqr
//!
//! A production-grade reproduction of *fastkqr: A Fast Algorithm for
//! Kernel Quantile Regression* (Tang, Gu & Wang, 2024) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)**: the exact finite-smoothing solvers for KQR and
//!   non-crossing KQR, the spectral O(n²) update machinery, baselines,
//!   CV, the fit-job coordinator and a TCP fit/predict server.
//! - **L2/L1 (python/, build-time only)**: the APGD iteration chunk as a
//!   JAX program calling Pallas kernels, AOT-lowered to HLO text and
//!   executed from Rust through PJRT (`runtime`, behind the `xla`
//!   feature).
//!
//! Cross-cutting the solvers sits the **fit engine** ([`engine`]):
//!
//! - [`linalg::par`] — a scoped-thread parallel substrate (row-blocked
//!   GEMV/GEMVᵀ/GEMM, parallel Gram construction) that the `linalg::blas`
//!   kernels dispatch into above a size cutoff, with a serial fallback
//!   that keeps small-n results bitwise unchanged. Configure with
//!   `FASTKQR_THREADS` / `FASTKQR_PAR_MIN_DIM`.
//! - [`engine::GramCache`] — content-fingerprinted, `Arc`-shared
//!   memoization of (dataset, kernel) → (Gram, eigenbasis); the O(n³)
//!   eigendecomposition runs exactly once per fingerprint per process,
//!   even under concurrent requests.
//! - [`engine::FitEngine`] — hands out cache-backed solvers, batches
//!   full τ × λ grids on one basis with warm starts in both directions
//!   ([`engine::FitEngine::fit_grid`]), and bounds the concurrency that
//!   [`cv::cross_validate`] (parallel folds + final refit) and the
//!   [`coordinator`] scheduler/server draw on.
//!
//! Quick start (native backend):
//!
//! ```no_run
//! use fastkqr::prelude::*;
//!
//! let mut rng = Rng::new(7);
//! let data = fastkqr::data::synth::sine_hetero(200, &mut rng);
//! let kernel = Kernel::Rbf { sigma: median_heuristic_sigma(&data.x) };
//! let fit = KqrSolver::new(&data.x, &data.y, kernel)
//!     .fit(0.5, 1e-2)
//!     .expect("fit");
//! let preds = fit.predict(&data.x);
//! assert_eq!(preds.len(), 200);
//! ```

pub mod backend;
pub mod baselines;
pub mod coordinator;
pub mod cv;
pub mod data;
pub mod engine;
pub mod experiments;
pub mod kernel;
pub mod kqr;
pub mod linalg;
pub mod nckqr;
pub mod runtime;
pub mod smooth;
pub mod spectral;
pub mod util;

/// Convenience re-exports for the common fitting workflow.
pub mod prelude {
    pub use crate::backend::Backend;
    pub use crate::cv::{cross_validate, CvResult};
    pub use crate::data::{Dataset, Rng};
    pub use crate::engine::{FitEngine, GridFit};
    pub use crate::kernel::{median_heuristic_sigma, Kernel};
    pub use crate::kqr::{KqrFit, KqrSolver, SolveOptions};
    pub use crate::nckqr::{NckqrFit, NckqrSolver};
    pub use crate::smooth::pinball_loss;
}

/// Crate version string (reported by the CLI and the server banner).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
