//! Cholesky factorization and triangular solves.
//!
//! Used by the interior-point baseline (`baselines::ipm`) for its Newton
//! systems, and by tests as an independent linear-solve oracle.

use super::matrix::Matrix;

/// Lower-triangular Cholesky factor of a symmetric positive-definite
/// matrix: `a = L Lᵀ`.
#[derive(Clone, Debug)]
pub struct Cholesky {
    l: Matrix,
}

#[derive(Debug, Clone, PartialEq)]
pub enum CholError {
    NotSquare,
    NotPositiveDefinite { pivot: usize, value: f64 },
}

impl std::fmt::Display for CholError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CholError::NotSquare => write!(f, "cholesky: matrix not square"),
            CholError::NotPositiveDefinite { pivot, value } => {
                write!(f, "cholesky: non-PD pivot {pivot} ({value:.3e})")
            }
        }
    }
}

impl std::error::Error for CholError {}

impl Cholesky {
    /// Factor `a` (symmetric PD). Only the lower triangle of `a` is read.
    pub fn new(a: &Matrix) -> Result<Cholesky, CholError> {
        if a.rows() != a.cols() {
            return Err(CholError::NotSquare);
        }
        let n = a.rows();
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 {
                        return Err(CholError::NotPositiveDefinite { pivot: i, value: s });
                    }
                    l[(i, i)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Ok(Cholesky { l })
    }

    /// Solve `a x = b` using the stored factor.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        // forward: L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l[(i, k)] * y[k];
            }
            y[i] = s / self.l[(i, i)];
        }
        // backward: Lᵀ x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= self.l[(k, i)] * x[k];
            }
            x[i] = s / self.l[(i, i)];
        }
        x
    }

    /// log(det(a)) = 2 Σ log L_ii (useful for diagnostics).
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    pub fn factor(&self) -> &Matrix {
        &self.l
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::linalg::blas::{gemm, gemv};

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let b = Matrix::from_fn(n, n, |_, _| rng.normal());
        let bt = b.transpose();
        let mut a = gemm(&b, &bt);
        for i in 0..n {
            a[(i, i)] += n as f64; // well-conditioned
        }
        a
    }

    #[test]
    fn factor_reconstructs() {
        let a = random_spd(8, 42);
        let ch = Cholesky::new(&a).unwrap();
        let l = ch.factor();
        let lt = l.transpose();
        let rec = gemm(l, &lt);
        assert!(a.max_abs_diff(&rec) < 1e-9);
    }

    #[test]
    fn solve_matches_residual() {
        let a = random_spd(12, 7);
        let mut rng = Rng::new(9);
        let b: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let ch = Cholesky::new(&a).unwrap();
        let x = ch.solve(&b);
        let mut ax = vec![0.0; 12];
        gemv(&a, &x, &mut ax);
        for (l, r) in ax.iter().zip(&b) {
            assert!((l - r).abs() < 1e-9);
        }
    }

    #[test]
    fn non_pd_detected() {
        let mut a = Matrix::eye(3);
        a[(2, 2)] = -1.0;
        match Cholesky::new(&a) {
            Err(CholError::NotPositiveDefinite { pivot: 2, .. }) => {}
            other => panic!("expected NotPositiveDefinite, got {other:?}"),
        }
    }

    #[test]
    fn log_det_identity_is_zero() {
        let ch = Cholesky::new(&Matrix::eye(5)).unwrap();
        assert!(ch.log_det().abs() < 1e-14);
    }
}
