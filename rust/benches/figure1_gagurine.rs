//! Figure 1: GAGurine quantile crossing (individual) vs NCKQR (joint).
use fastkqr::experiments::figure1;
use fastkqr::util::Args;

fn main() {
    let args = Args::from_env();
    let res = figure1::run(
        args.get_usize("seed", 2025) as u64,
        args.get_f64("lambda", 2e-5),
        args.get_f64("lam1", 5.0),
        args.get_usize("grid", 200),
    )
    .expect("figure1");
    figure1::write_csv(&res, args.get_str("out", "out/figure1")).expect("csv");
    println!("Figure 1 — individual crossings: {}", res.crossings_individual);
    println!("Figure 1 — NCKQR crossings:      {}", res.crossings_joint);
    assert_eq!(res.crossings_joint, 0);
}
