//! Low-rank scaling trajectory: wall time and in-sample check loss vs
//! the basis budget (Nyström landmark count m, random-feature count D)
//! at a fixed n, against the exact dense baseline at the same n. Writes
//! the machine-readable baseline to `BENCH_lowrank.json` (override with
//! `--out`) so the scale trajectory of future PRs has a recorded
//! starting point.
//!
//! Expectation (ISSUE 4): setup drops from O(n³) to O(n·m² + m³) and
//! per-iteration cost from O(n²) to O(n·m), so wall time falls steeply
//! with m while the check loss approaches the dense baseline as m grows.
//! The RF column (ISSUE 7) tracks the same trajectory with D random
//! Fourier features — setup O(n·D²) streamed in row blocks, no n×n
//! Gram ever materialized.
//!
//! `--big <n>` (e.g. `--big 1000000`) appends one streaming-fit entry at
//! that n through the RF path with loose accounting-oriented solver
//! options, recording wall time, check loss and the representation's
//! peak float count (which must sit far below n²).

use fastkqr::data::{synth, Rng};
use fastkqr::engine::{ApproxSpec, EngineConfig, FitEngine};
use fastkqr::kernel::{median_heuristic_sigma, Kernel};
use fastkqr::smooth::pinball_loss;
use fastkqr::util::{Args, Json};
use std::time::Instant;

fn fit_once(
    engine: &FitEngine,
    data: &fastkqr::data::Dataset,
    kernel: &Kernel,
    approx: ApproxSpec,
    tau: f64,
    lam: f64,
) -> (f64, f64, usize) {
    let t0 = Instant::now();
    let solver = engine
        .solver_approx(&data.x, &data.y, kernel, approx, engine.config.opts.clone())
        .expect("solver");
    let fit = solver.fit(tau, lam).expect("fit");
    let secs = t0.elapsed().as_secs_f64();
    let loss = pinball_loss(&data.y, &fit.predict(&data.x), tau);
    (secs, loss, fit.apgd_iters)
}

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 768);
    let tau = args.get_f64("tau", 0.5);
    let lam = args.get_f64("lambda", 1e-2);
    let ms: Vec<usize> = {
        let def = [32usize, 64, 128, 256];
        args.get_usize_list("ms", &def).into_iter().filter(|&m| m <= n).collect()
    };
    let seed = args.get_usize("seed", 2024) as u64;
    let out = args.get_str("out", "BENCH_lowrank.json").to_string();

    let mut rng = Rng::new(seed);
    let data = synth::sine_hetero(n, &mut rng);
    let kernel = Kernel::Rbf { sigma: median_heuristic_sigma(&data.x) };
    println!("-- nystrom scaling: n={n}, tau={tau}, lambda={lam:.1e} --");

    // Dense baseline at the same n (fresh engine: cold factorization).
    let dense_engine = FitEngine::with_config(EngineConfig::default());
    let (dense_secs, dense_loss, dense_iters) =
        fit_once(&dense_engine, &data, &kernel, ApproxSpec::Exact, tau, lam);
    println!(
        "   exact     n={n:<5}           {dense_secs:8.3}s   check-loss {dense_loss:.6}  \
         ({dense_iters} iters)"
    );

    let mut rows = Vec::new();
    for &m in &ms {
        let engine = FitEngine::with_config(EngineConfig::default());
        let (secs, loss, iters) =
            fit_once(&engine, &data, &kernel, ApproxSpec::Nystrom { m, seed }, tau, lam);
        let speedup = dense_secs / secs.max(1e-12);
        let loss_gap = loss - dense_loss;
        println!(
            "   nystrom   m={m:<5} ({speedup:5.2}x) {secs:8.3}s   check-loss {loss:.6}  \
             (gap {loss_gap:+.2e}, {iters} iters)"
        );
        rows.push(Json::obj(vec![
            ("m", Json::num(m as f64)),
            ("secs", Json::num(secs)),
            ("check_loss", Json::num(loss)),
            ("loss_gap_vs_dense", Json::num(loss_gap)),
            ("speedup_vs_dense", Json::num(speedup)),
            ("apgd_iters", Json::num(iters as f64)),
        ]));
    }

    // RF column at the same basis budgets: D random features instead of
    // m landmarks, same dense baseline (unlike Nyström, D may exceed n).
    let ds: Vec<usize> = {
        let def = [32usize, 64, 128, 256];
        args.get_usize_list("ds", &def)
    };
    let mut rff_rows = Vec::new();
    for &d in &ds {
        let engine = FitEngine::with_config(EngineConfig::default());
        let (secs, loss, iters) =
            fit_once(&engine, &data, &kernel, ApproxSpec::RandomFeatures { d, seed }, tau, lam);
        let speedup = dense_secs / secs.max(1e-12);
        let loss_gap = loss - dense_loss;
        println!(
            "   rff       D={d:<5} ({speedup:5.2}x) {secs:8.3}s   check-loss {loss:.6}  \
             (gap {loss_gap:+.2e}, {iters} iters)"
        );
        rff_rows.push(Json::obj(vec![
            ("d", Json::num(d as f64)),
            ("secs", Json::num(secs)),
            ("check_loss", Json::num(loss)),
            ("loss_gap_vs_dense", Json::num(loss_gap)),
            ("speedup_vs_dense", Json::num(speedup)),
            ("apgd_iters", Json::num(iters as f64)),
        ]));
    }

    // Opt-in large-n streaming entry: `--big 1000000 [--big-d 256]`
    // fits once through the RF path and records the representation's
    // peak float count — the machine-checkable no-n×n claim at scale.
    let rff_big = match args.get("big") {
        None => Json::Null,
        Some(_) => {
            let big_n = args.get_usize("big", 1_000_000);
            let big_d = args.get_usize("big-d", 256);
            let mut brng = Rng::new(seed ^ 0xb16);
            let bdata = synth::sine_hetero(big_n, &mut brng);
            // Median heuristic over all n² pairs would itself be
            // quadratic; a fixed bandwidth keeps setup linear in n.
            let bkernel = Kernel::Rbf { sigma: 0.5 };
            // Loose accounting-oriented options (the entry bounds memory
            // and wall-clock scaling, not certificate quality).
            let opts = fastkqr::kqr::SolveOptions {
                apgd_tol: 1e-2,
                kkt_tol: 1e-2,
                max_iters: 300,
                max_expansions: 2,
                max_stall_rungs: 1,
                projection: false,
                ..fastkqr::kqr::SolveOptions::default()
            };
            let engine = FitEngine::with_config(EngineConfig {
                opts: opts.clone(),
                ..EngineConfig::default()
            });
            let t0 = Instant::now();
            let solver = engine
                .solver_approx(&bdata.x, &bdata.y, &bkernel, ApproxSpec::RandomFeatures {
                    d: big_d,
                    seed,
                }, opts)
                .expect("big-n rff solver");
            let setup_secs = t0.elapsed().as_secs_f64();
            let floats = solver.repr.memory_floats();
            assert!(
                floats < big_n.saturating_mul(big_n) / 16,
                "rff repr holds {floats} f64s at n={big_n} — streaming build must stay \
                 far below n²"
            );
            let t1 = Instant::now();
            let fit = solver.fit(tau, lam).expect("big-n rff fit");
            let fit_secs = t1.elapsed().as_secs_f64();
            let loss = pinball_loss(&bdata.y, &fit.predict(&bdata.x), tau);
            println!(
                "   rff-big   n={big_n} D={big_d}  setup {setup_secs:.3}s  fit {fit_secs:.3}s  \
                 check-loss {loss:.6}  ({} repr floats, {:.1} MB)",
                floats,
                floats as f64 * 8.0 / 1e6
            );
            Json::obj(vec![
                ("n", Json::num(big_n as f64)),
                ("d", Json::num(big_d as f64)),
                ("setup_secs", Json::num(setup_secs)),
                ("fit_secs", Json::num(fit_secs)),
                ("check_loss", Json::num(loss)),
                ("memory_floats", Json::num(floats as f64)),
                ("apgd_iters", Json::num(fit.apgd_iters as f64)),
            ])
        }
    };

    let mut pairs = vec![
        ("bench", Json::str("nystrom_scaling")),
        ("n", Json::num(n as f64)),
        ("tau", Json::num(tau)),
        ("lambda", Json::num(lam)),
        ("seed", Json::num(seed as f64)),
        (
            "dense",
            Json::obj(vec![
                ("secs", Json::num(dense_secs)),
                ("check_loss", Json::num(dense_loss)),
                ("apgd_iters", Json::num(dense_iters as f64)),
            ]),
        ),
        ("lowrank", Json::Arr(rows)),
        ("rff", Json::Arr(rff_rows)),
    ];
    if !matches!(rff_big, Json::Null) {
        pairs.push(("rff_big", rff_big));
    }
    let doc = Json::obj(pairs);
    std::fs::write(&out, doc.to_string()).expect("write BENCH_lowrank.json");
    println!("wrote {out}");
}
