//! Serving-path throughput: per-request baseline vs the PredictEngine's
//! cross-request micro-batching, measured end-to-end over real TCP.
//!
//! Fits one τ×λ grid model (default 8×8 at n = 256), inserts it into two
//! servers — one with batching disabled (`window_us = 0`, the
//! per-request baseline) and one with a generous coalescing window —
//! then fires `--clients` concurrent connections (default 64) each
//! sending `--reps` sequential single-row predicts, and reports
//! requests/second for both paths plus the batch-occupancy metrics.
//! Writes the machine-readable baseline to `BENCH_serve.json` (override
//! with `--out`).
//!
//! Acceptance tracking (ISSUE 5): ≥ 3× requests/sec at 64 concurrent
//! single-row clients on an 8×8 grid model versus the per-request
//! baseline.

use fastkqr::coordinator::server::Client;
use fastkqr::coordinator::{BatchConfig, Server, ServerConfig};
use fastkqr::data::{synth, Rng};
use fastkqr::engine::FitEngine;
use fastkqr::kernel::Kernel;
use fastkqr::util::{Args, Json};
use std::time::Instant;

/// Fire `clients` concurrent connections × `reps` single-row predicts
/// at `server`; returns (requests/sec, failed request count).
fn storm(server: &Server, model_id: &str, clients: usize, reps: usize) -> (f64, usize) {
    let addr = server.local_addr;
    let req = Json::parse(&format!(
        r#"{{"cmd":"predict","model":"{model_id}","x":[[0.42]]}}"#
    ))
    .expect("request json");
    let t0 = Instant::now();
    let failures: usize = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                let req = &req;
                s.spawn(move || {
                    let mut failed = 0usize;
                    let mut client = match Client::connect(addr) {
                        Ok(c) => c,
                        Err(_) => return reps,
                    };
                    for _ in 0..reps {
                        match client.request(req) {
                            Ok(resp)
                                if resp.get("ok").and_then(Json::as_bool)
                                    == Some(true) => {}
                            _ => failed += 1,
                        }
                    }
                    failed
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap_or(reps)).sum()
    });
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    ((clients * reps) as f64 / wall, failures)
}

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 256);
    let taus = args.get_usize("taus", 8);
    let lams = args.get_usize("lams", 8);
    let clients = args.get_usize("clients", 64);
    let reps = args.get_usize("reps", 50);
    let window_us = args.get_usize("window-us", 500) as u64;
    let out = args.get_str("out", "BENCH_serve.json").to_string();

    if std::net::TcpListener::bind("127.0.0.1:0").is_err() {
        println!("no loopback TCP in this environment; skipping serve bench");
        return;
    }

    // One grid model, shared by both servers (the fit cost is not what
    // this bench measures).
    let mut rng = Rng::new(7);
    let data = synth::sine_hetero(n, &mut rng);
    let kernel = Kernel::Rbf { sigma: 0.5 };
    let tau_grid: Vec<f64> =
        (0..taus).map(|i| 0.1 + 0.8 * i as f64 / (taus.max(2) - 1) as f64).collect();
    let lam_grid = fastkqr::kqr::lambda_grid(lams, 1.0, 1e-3);
    println!("fitting the {taus}x{lams} grid at n={n} ...");
    let grid = FitEngine::global()
        .fit_grid(&data.x, &data.y, &kernel, &tau_grid, &lam_grid)
        .expect("grid fit");
    let model = fastkqr::api::QuantileModel::from_grid(grid);

    let spawn = |window_us: u64| -> (Server, String) {
        let server = Server::spawn(ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            batch: BatchConfig { window_us, max_rows: 4096 },
            ..ServerConfig::default()
        })
        .expect("spawn server");
        let id = server.registry.insert(model.clone());
        (server, id)
    };

    println!(
        "-- serve throughput: {clients} clients x {reps} single-row predicts, \
         {}-level model --",
        model.n_levels()
    );
    let (baseline_srv, id) = spawn(0);
    let (baseline_rps, baseline_failed) = storm(&baseline_srv, &id, clients, reps);
    println!("   per-request baseline: {baseline_rps:>10.0} req/s  ({baseline_failed} failed)");
    baseline_srv.shutdown();

    let (batched_srv, id) = spawn(window_us);
    let (batched_rps, batched_failed) = storm(&batched_srv, &id, clients, reps);
    let m = &batched_srv.metrics;
    let batches = fastkqr::coordinator::Metrics::get(&m.predict_batches);
    let batch_p95 = m.predict_batch_size.p95();
    let batch_max = m.predict_batch_size.max();
    let lat_p99 = m.predict_latency.p99();
    println!(
        "   micro-batched ({window_us}us window): {batched_rps:>10.0} req/s  \
         ({batched_failed} failed)"
    );
    println!(
        "   {batches} batches, occupancy p95 {batch_p95} / max {batch_max}, \
         latency p99 {lat_p99}us"
    );
    let speedup = batched_rps / baseline_rps.max(1e-9);
    println!("   {speedup:.2}x requests/sec vs the per-request baseline (target >= 3x)");
    batched_srv.shutdown();

    let doc = Json::obj(vec![
        ("bench", Json::str("serve_throughput")),
        ("n", Json::num(n as f64)),
        ("taus", Json::num(taus as f64)),
        ("lams", Json::num(lams as f64)),
        ("clients", Json::num(clients as f64)),
        ("reps", Json::num(reps as f64)),
        ("window_us", Json::num(window_us as f64)),
        ("baseline_rps", Json::num(baseline_rps)),
        ("batched_rps", Json::num(batched_rps)),
        ("speedup", Json::num(speedup)),
        ("failed", Json::num((baseline_failed + batched_failed) as f64)),
        ("predict_batches", Json::num(batches as f64)),
        ("batch_p95", Json::num(batch_p95 as f64)),
        ("batch_max", Json::num(batch_max as f64)),
        ("latency_us_p99", Json::num(lat_p99 as f64)),
        ("simd_isa", Json::str(fastkqr::linalg::simd::global().isa.as_str())),
        ("simd_fma", Json::Bool(fastkqr::linalg::simd::global().fma)),
    ]);
    std::fs::write(&out, doc.to_string()).expect("write BENCH_serve.json");
    println!("wrote {out}");
    assert_eq!(baseline_failed + batched_failed, 0, "all storm requests must succeed");
}
