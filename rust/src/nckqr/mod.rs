//! Non-crossing kernel quantile regression (paper §3).
//!
//! Fits T quantile levels τ₁ < … < τ_T **simultaneously** with the soft
//! non-crossing penalty λ₁ Σ_t Σᵢ V(f_t(xᵢ) − f_{t+1}(xᵢ)), V the
//! η-smoothed ReLU. The exact solution of problem (12) is recovered by
//! the same finite-smoothing machinery as single-level KQR:
//!
//! - the smoothed surrogate Q^γ is minimized by the specialized MM
//!   algorithm with **two majorization steps** (§3.3): Lipschitz
//!   calibration (γ ≤ η) and the block-diagonal bound Ψ ⪰ Φ, which makes
//!   every level share one spectral system Σ_{γ,λ₁,λ₂} (see
//!   [`plan::NcPlan`]) — 2 GEMVs per level per iteration;
//! - multi-level set expansion Ŝ_t ← E_t (Theorems 6–7) with the K_SS
//!   equality projection per level (eq. 19);
//! - the γ/η ladder: γ = η = 1, both ÷4 per round; once η reaches 10⁻⁵
//!   it is pinned there (η_exact defines problem (12)) while γ continues;
//! - termination on the exact KKT certificate of problem (12):
//!   g_{t,i} = nλ₂α_{t,i} + nλ₁(q_{t,i} − q_{t−1,i}) ∈ ∂ρ_{τ_t}(r_{t,i})
//!   and Σᵢ nλ₂α_{t,i} = 0 per level.

pub mod plan;
mod ssn;

use crate::kernel::Kernel;
use crate::kqr::apgd::ApgdWorkspace;
use crate::kqr::kkt::KktReport;
use crate::kqr::predict_rows;
use crate::linalg::{amax, Matrix};
use crate::smooth::{h_gamma_prime, rho_subgradient, rho_tau, smooth_relu, smooth_relu_prime};
use crate::spectral::{GramRepr, SpectralBasis};
use anyhow::{bail, Result};
use plan::NcPlan;
use std::sync::Arc;

/// The η at which the exact problem (12) is defined (paper: 10⁻⁵).
pub const ETA_EXACT: f64 = 1e-5;

/// Solver options for NCKQR.
#[derive(Clone, Debug)]
pub struct NcOptions {
    /// MM iteration cap per smoothed solve.
    pub max_iters: usize,
    /// Stationarity tolerance (subgradient units, like `kqr`).
    pub mm_tol: f64,
    pub kkt_tol: f64,
    /// Residual band, relative to max(1, ‖y‖∞).
    pub kkt_band: f64,
    pub gamma_init: f64,
    pub gamma_shrink: f64,
    pub gamma_min: f64,
    pub max_expansions: usize,
    pub projection: bool,
    /// Stop the γ ladder after this many consecutive rungs without an
    /// improvement of the certificate score (the solution is returned as
    /// best-effort with `kkt.pass = false`).
    pub max_stall_rungs: usize,
}

impl Default for NcOptions {
    fn default() -> Self {
        NcOptions {
            max_iters: 60_000,
            mm_tol: 5e-5,
            kkt_tol: 2e-3,
            kkt_band: 1e-5,
            gamma_init: 1.0,
            gamma_shrink: 0.25,
            gamma_min: 1e-9,
            max_expansions: 30,
            projection: true,
            max_stall_rungs: 3,
        }
    }
}

/// Coefficients of one fitted quantile level.
#[derive(Clone, Debug)]
pub struct LevelCoef {
    pub tau: f64,
    pub b: f64,
    pub alpha: Vec<f64>,
}

/// Compressed low-rank predictor for a multi-level fit: one m-dim weight
/// vector per level over the shared landmark set (see
/// [`crate::spectral::LowRankCoef`] for the single-level analogue).
#[derive(Clone, Debug)]
pub struct NcLowRank {
    /// Landmark inputs (m×p), `Arc`-shared with the solver's factor.
    pub z: Arc<Matrix>,
    /// Landmark row indices into the training set (provenance).
    pub landmarks: Vec<usize>,
    /// Per-level kernel weights (aligned with `NckqrFit::levels`).
    pub w: Vec<Vec<f64>>,
}

/// Compressed random-feature predictor for a multi-level fit: one D-dim
/// feature-space weight vector per level over the shared feature map
/// (see [`crate::spectral::RffCoef`] for the single-level analogue).
#[derive(Clone, Debug)]
pub struct NcRff {
    /// The feature map (frequencies + phases), `Arc`-shared with the
    /// solver's factor.
    pub map: Arc<crate::kernel::rff::RffMap>,
    /// Per-level feature weights (aligned with `NckqrFit::levels`).
    pub w: Vec<Vec<f64>>,
}

/// A fitted NCKQR model.
#[derive(Clone, Debug)]
pub struct NckqrFit {
    pub taus: Vec<f64>,
    pub lam1: f64,
    pub lam2: f64,
    pub levels: Vec<LevelCoef>,
    /// Exact objective Q (check loss + RKHS + η_exact crossing penalty).
    pub objective: f64,
    pub kkt: KktReport,
    pub mm_iters: usize,
    pub gamma_final: f64,
    /// Crossing violations on the **training** points (tol 1e-9),
    /// computed by the solver from the fitted values it already holds —
    /// consumers must not rebuild the n×n cross-Gram just to count them.
    pub train_crossings: usize,
    /// Compressed low-rank predictor, present iff the fit was produced on
    /// a Nyström basis; `predict` routes through it (m kernel evaluations
    /// per point per level) and artifacts persist it instead of
    /// (x_train, α).
    pub lowrank: Option<NcLowRank>,
    /// Compressed random-feature predictor, present iff the fit was
    /// produced on an RFF basis; `predict` builds one feature matrix for
    /// the whole level set and artifacts persist (frequencies, phases,
    /// per-level w) — O(T·D), independent of n.
    pub rff: Option<NcRff>,
    /// pALM-SSN work counters, present iff the fit was produced by the
    /// semismooth-Newton backend ([`NckqrSolver::fit_ssn`]); the MM path
    /// leaves it `None`.
    pub ssn: Option<crate::solver::SsnGridStats>,
    /// Training inputs, `Arc`-shared with the solver (and with every fit
    /// from the same solver), like [`crate::kqr::KqrFit`]. Empty (0×p)
    /// for models reloaded from a compressed low-rank artifact.
    x_train: Arc<Matrix>,
    /// Training-set size (kept explicitly so compressed reloads still
    /// report it).
    n_train: usize,
    kernel: Kernel,
}

impl NckqrFit {
    /// Predict all T quantile curves at the rows of `xt`; returns one
    /// vector per level (same order as `taus`).
    ///
    /// One cross-Gram + one multi-RHS GEMM for the whole level set —
    /// never per-row kernel evaluations — on both the dense and low-rank
    /// representations.
    pub fn predict(&self, xt: &Matrix) -> Vec<Vec<f64>> {
        if let Some(rf) = &self.rff {
            // One feature build for the whole level set, then the same
            // multi-RHS GEMM as the kernel paths (Φ plays the cross-Gram
            // role).
            let phi = rf.map.features(xt);
            let coefs: Vec<&[f64]> = rf.w.iter().map(Vec::as_slice).collect();
            let bs: Vec<f64> = self.levels.iter().map(|lv| lv.b).collect();
            return predict_rows(&coefs, &bs, &phi);
        }
        match &self.lowrank {
            Some(lr) => {
                let cg = self.kernel.cross_gram(xt, &lr.z);
                let coefs: Vec<&[f64]> = lr.w.iter().map(Vec::as_slice).collect();
                let bs: Vec<f64> = self.levels.iter().map(|lv| lv.b).collect();
                predict_rows(&coefs, &bs, &cg)
            }
            None => {
                let cg = self.kernel.cross_gram(xt, &self.x_train);
                self.predict_from_cross_gram(&cg)
            }
        }
    }

    /// Predict from a precomputed cross-Gram matrix (rows = evaluation
    /// points, columns = training points). Lets consumers that already
    /// hold the training Gram (the solver, the engine cache) evaluate at
    /// the training points without rebuilding an n×n kernel matrix.
    /// Dense-coefficient path only (the low-rank predictor's support set
    /// is the landmark set, not the training set).
    pub fn predict_from_cross_gram(&self, cg: &Matrix) -> Vec<Vec<f64>> {
        assert_eq!(cg.cols(), self.x_train.rows());
        let coefs: Vec<&[f64]> = self.levels.iter().map(|lv| lv.alpha.as_slice()).collect();
        let bs: Vec<f64> = self.levels.iter().map(|lv| lv.b).collect();
        predict_rows(&coefs, &bs, cg)
    }

    /// Training-set size.
    pub fn n_train(&self) -> usize {
        self.n_train
    }

    /// Count crossing violations on a set of evaluation points: pairs
    /// (point, adjacent level) where the higher quantile dips more than
    /// `tol` below the lower one.
    pub fn count_crossings(&self, xt: &Matrix, tol: f64) -> usize {
        count_crossings_in(&self.predict(xt), tol)
    }

    /// The kernel this fit predicts with (artifact serialization).
    pub fn kernel(&self) -> &Kernel {
        &self.kernel
    }

    /// Training inputs (artifact serialization).
    pub fn x_train(&self) -> &Matrix {
        &self.x_train
    }

    /// The `Arc`-shared training inputs (see `KqrFit::x_train_arc`).
    pub(crate) fn x_train_arc(&self) -> &Arc<Matrix> {
        &self.x_train
    }

    /// Assemble a fit from stored parts (the artifact loader must emit the
    /// same self-contained value as the solver).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn assemble(
        taus: Vec<f64>,
        lam1: f64,
        lam2: f64,
        levels: Vec<LevelCoef>,
        objective: f64,
        kkt: KktReport,
        mm_iters: usize,
        gamma_final: f64,
        train_crossings: usize,
        x_train: Arc<Matrix>,
        kernel: Kernel,
    ) -> NckqrFit {
        let n_train = x_train.rows();
        NckqrFit {
            taus,
            lam1,
            lam2,
            levels,
            objective,
            kkt,
            mm_iters,
            gamma_final,
            train_crossings,
            lowrank: None,
            rff: None,
            ssn: None,
            x_train,
            n_train,
            kernel,
        }
    }

    /// Assemble a fit from a compressed low-rank artifact: no training
    /// inputs, no n-dimensional α per level — prediction goes through the
    /// [`NcLowRank`] weights.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn assemble_compressed(
        taus: Vec<f64>,
        lam1: f64,
        lam2: f64,
        levels: Vec<LevelCoef>,
        objective: f64,
        kkt: KktReport,
        mm_iters: usize,
        gamma_final: f64,
        train_crossings: usize,
        n_train: usize,
        lowrank: NcLowRank,
        kernel: Kernel,
    ) -> NckqrFit {
        let p = lowrank.z.cols();
        NckqrFit {
            taus,
            lam1,
            lam2,
            levels,
            objective,
            kkt,
            mm_iters,
            gamma_final,
            train_crossings,
            lowrank: Some(lowrank),
            rff: None,
            ssn: None,
            x_train: Arc::new(Matrix::zeros(0, p)),
            n_train,
            kernel,
        }
    }

    /// Assemble a fit from a compressed random-feature artifact: no
    /// training inputs, no n-dimensional α per level — prediction goes
    /// through the [`NcRff`] feature-space weights.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn assemble_compressed_rff(
        taus: Vec<f64>,
        lam1: f64,
        lam2: f64,
        levels: Vec<LevelCoef>,
        objective: f64,
        kkt: KktReport,
        mm_iters: usize,
        gamma_final: f64,
        train_crossings: usize,
        n_train: usize,
        rff: NcRff,
        kernel: Kernel,
    ) -> NckqrFit {
        let p = rff.map.p();
        NckqrFit {
            taus,
            lam1,
            lam2,
            levels,
            objective,
            kkt,
            mm_iters,
            gamma_final,
            train_crossings,
            lowrank: None,
            rff: Some(rff),
            ssn: None,
            x_train: Arc::new(Matrix::zeros(0, p)),
            n_train,
            kernel,
        }
    }
}

/// Count adjacent-level crossing violations in per-level prediction rows.
fn count_crossings_in(preds: &[Vec<f64>], tol: f64) -> usize {
    let mut c = 0usize;
    for t in 0..preds.len().saturating_sub(1) {
        for i in 0..preds[t].len() {
            if preds[t + 1][i] < preds[t][i] - tol {
                c += 1;
            }
        }
    }
    c
}

/// Per-level mutable MM state (current + previous iterate for the
/// Nesterov extrapolation).
#[derive(Clone, Debug)]
struct LevelState {
    b: f64,
    beta: Vec<f64>,
    b_prev: f64,
    beta_prev: Vec<f64>,
}

impl LevelState {
    fn restart(&mut self) {
        self.b_prev = self.b;
        self.beta_prev.copy_from_slice(&self.beta);
    }
}

/// Validate and sort a τ grid: all in (0,1), strictly distinct after
/// sorting. These arrive from wire payloads and CLI flags, so bad input
/// is an expected runtime condition (error), not a programmer bug
/// (assert).
pub fn normalize_taus(taus: &[f64]) -> Result<Vec<f64>> {
    if taus.is_empty() {
        bail!("taus must be non-empty");
    }
    if taus.iter().any(|t| !t.is_finite()) {
        bail!("taus must be finite numbers, got {taus:?}");
    }
    let mut ts = taus.to_vec();
    ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if !ts.iter().all(|t| 0.0 < *t && *t < 1.0) {
        bail!("taus must be in (0,1), got {taus:?}");
    }
    if !ts.windows(2).all(|w| w[0] < w[1]) {
        bail!("taus must be distinct, got {taus:?}");
    }
    Ok(ts)
}

/// NCKQR solver: data + kernel + eigenbasis + quantile levels.
///
/// Like [`crate::kqr::KqrSolver`], the training inputs, Gram matrix and
/// eigenbasis are `Arc`-shared so the engine's
/// [`crate::engine::GramCache`] can hand out solvers without copying
/// O(n²) state — prefer [`crate::engine::FitEngine::nc_solver`] when the
/// same (dataset, kernel) may be fitted more than once per process.
pub struct NckqrSolver {
    pub x: Arc<Matrix>,
    pub y: Vec<f64>,
    pub kernel: Kernel,
    /// Gram representation (kept for the eq.-(19) K_SS projection solves).
    pub repr: GramRepr,
    pub basis: Arc<SpectralBasis>,
    pub taus: Vec<f64>,
    pub opts: NcOptions,
}

impl NckqrSolver {
    /// Build the solver: computes the Gram matrix and its
    /// eigendecomposition. Errors on malformed inputs (shape mismatch,
    /// invalid τ grid) or a non-PSD kernel matrix (see
    /// [`SpectralBasis::new`]).
    pub fn new(x: &Matrix, y: &[f64], kernel: Kernel, taus: &[f64]) -> Result<NckqrSolver> {
        if x.rows() != y.len() {
            bail!("rows(x)={} != len(y)={}", x.rows(), y.len());
        }
        let gram = Arc::new(kernel.gram(x));
        let basis = Arc::new(SpectralBasis::new(&gram)?);
        NckqrSolver::with_repr(x, y, kernel, taus, GramRepr::dense(gram, basis))
    }

    /// Reuse an already-computed Gram matrix and basis (engine-cached, or
    /// shared with a [`crate::kqr::KqrSolver`] on the same data).
    pub fn with_basis(
        x: &Matrix,
        y: &[f64],
        kernel: Kernel,
        taus: &[f64],
        gram: Arc<Matrix>,
        basis: Arc<SpectralBasis>,
    ) -> Result<NckqrSolver> {
        NckqrSolver::with_repr(x, y, kernel, taus, GramRepr::dense(gram, basis))
    }

    /// Build on an arbitrary Gram representation — the entry point of the
    /// low-rank (Nyström) compute path.
    pub fn with_repr(
        x: &Matrix,
        y: &[f64],
        kernel: Kernel,
        taus: &[f64],
        repr: GramRepr,
    ) -> Result<NckqrSolver> {
        NckqrSolver::with_repr_arc(Arc::new(x.clone()), y, kernel, taus, repr)
    }

    /// [`NckqrSolver::with_repr`] with `Arc`-shared training inputs (the
    /// engine passes its cache entry's copy — see
    /// [`crate::engine::BasisEntry`]).
    pub fn with_repr_arc(
        x: Arc<Matrix>,
        y: &[f64],
        kernel: Kernel,
        taus: &[f64],
        repr: GramRepr,
    ) -> Result<NckqrSolver> {
        if x.rows() != y.len() {
            bail!("rows(x)={} != len(y)={}", x.rows(), y.len());
        }
        if repr.n() != y.len() {
            bail!("basis dimension {} != len(y)={}", repr.n(), y.len());
        }
        let ts = normalize_taus(taus)?;
        let basis = repr.basis().clone();
        Ok(NckqrSolver {
            x,
            y: y.to_vec(),
            kernel,
            repr,
            basis,
            taus: ts,
            opts: NcOptions::default(),
        })
    }

    /// The materialized dense Gram matrix. Panics on a low-rank solver —
    /// only the exact path keeps one (dense baselines / ablations).
    pub fn gram(&self) -> &Arc<Matrix> {
        self.repr
            .dense_gram()
            .expect("dense Gram matrix is not materialized for a low-rank solver")
    }

    pub fn with_options(mut self, opts: NcOptions) -> NckqrSolver {
        self.opts = opts;
        self
    }

    pub fn n(&self) -> usize {
        self.y.len()
    }

    pub fn t_levels(&self) -> usize {
        self.taus.len()
    }

    /// Fit at a single (λ₁, λ₂).
    pub fn fit(&self, lam1: f64, lam2: f64) -> Result<NckqrFit> {
        let mut state = self.init_state();
        self.fit_warm(lam1, lam2, &mut state)
    }

    /// Warm-started descending-λ₂ path at fixed λ₁ (the Table-2 workload).
    /// Like Algorithm 2, both the iterate and the γ-ladder position carry
    /// over between λ₂ values.
    pub fn fit_path(&self, lam1: f64, lam2s: &[f64]) -> Result<Vec<NckqrFit>> {
        let mut state = self.init_state();
        let mut gamma_start = self.opts.gamma_init;
        let mut fits = Vec::with_capacity(lam2s.len());
        for &l2 in lam2s {
            let fit = self.fit_warm_from(lam1, l2, &mut state, gamma_start)?;
            gamma_start = (fit.gamma_final / self.opts.gamma_shrink)
                .min(self.opts.gamma_init)
                .max(self.opts.gamma_min);
            fits.push(fit);
        }
        Ok(fits)
    }

    fn init_state(&self) -> Vec<LevelState> {
        let dim = self.basis.dim();
        (0..self.t_levels())
            .map(|_| LevelState {
                b: 0.0,
                beta: vec![0.0; dim],
                b_prev: 0.0,
                beta_prev: vec![0.0; dim],
            })
            .collect()
    }

    /// Algorithm 2: the finite smoothing algorithm for NCKQR.
    fn fit_warm(&self, lam1: f64, lam2: f64, state: &mut Vec<LevelState>) -> Result<NckqrFit> {
        self.fit_warm_from(lam1, lam2, state, self.opts.gamma_init)
    }

    fn fit_warm_from(
        &self,
        lam1: f64,
        lam2: f64,
        state: &mut Vec<LevelState>,
        gamma_start: f64,
    ) -> Result<NckqrFit> {
        if lam1 < 0.0 {
            bail!("lambda1 must be >= 0, got {lam1}");
        }
        if lam2 <= 0.0 {
            bail!("lambda2 must be positive, got {lam2}");
        }
        let t_lv = self.t_levels();
        let yscale = amax(&self.y).max(1.0);
        let band = self.opts.kkt_band * yscale;
        let mut ws = ApgdWorkspace::for_basis(&self.basis);

        let mut gamma = gamma_start.clamp(self.opts.gamma_min, self.opts.gamma_init);
        let mut total_iters = 0usize;
        let mut best: Option<(f64, Vec<LevelState>, KktReport, f64)> = None;
        let mut stall = 0usize;

        loop {
            // η is pinned at η_exact once the ladder reaches it (γ ≤ η is
            // the first-majorization requirement).
            let eta = gamma.max(ETA_EXACT);
            let plan = NcPlan::new(&self.basis, gamma, lam1, lam2);
            // loose tolerance at large γ (certificate cannot pass there)
            let tol_gamma = self.opts.mm_tol.max(0.02 * gamma.min(1.0));
            let mut s_hat: Vec<Vec<usize>> = vec![Vec::new(); t_lv];
            total_iters += self.expand_at_gamma(&plan, eta, gamma, tol_gamma, state, &mut ws, &mut s_hat)?;
            // --- KKT certificate of problem (12) ---
            let mut rep = self.kkt_check(lam1, lam2, state, band);
            // re-verify loose passes on a tightly converged iterate
            if rep.pass && tol_gamma > self.opts.mm_tol {
                total_iters += self.expand_at_gamma(
                    &plan,
                    eta,
                    gamma,
                    self.opts.mm_tol,
                    state,
                    &mut ws,
                    &mut s_hat,
                )?;
                rep = self.kkt_check(lam1, lam2, state, band);
            }
            let score = rep.max_stationarity.max(rep.intercept);
            let replace = best.as_ref().map(|(s, ..)| score < *s).unwrap_or(true);
            if replace {
                best = Some((score, state.clone(), rep.clone(), gamma));
                stall = 0;
            } else {
                stall += 1;
            }
            if rep.pass || stall >= self.opts.max_stall_rungs {
                break;
            }
            gamma *= self.opts.gamma_shrink;
            if gamma < self.opts.gamma_min {
                break;
            }
        }

        let (_, best_state, kkt, gamma_final) = best.expect("at least one gamma level");
        *state = best_state.clone();
        let levels: Vec<LevelCoef> = (0..t_lv)
            .map(|t| LevelCoef {
                tau: self.taus[t],
                b: best_state[t].b,
                alpha: self.basis.alpha_from_beta(&best_state[t].beta),
            })
            .collect();
        // One pass of fitted values serves both the exact objective and
        // the training-point crossings count — no cross-Gram rebuild.
        let fs = self.fitted_levels(&best_state, &mut ws);
        let objective = self.exact_objective(lam1, lam2, &best_state, &fs);
        let train_crossings = count_crossings_in(&fs, 1e-9);
        // On a factored basis, compress every level into the O(m)
        // landmark predictor (Nyström: w_t = map·β_t) or the O(D)
        // feature-space predictor (RFF: w_t = coef_map·β_t) alongside α.
        let lowrank = self.repr.low_rank().map(|f| NcLowRank {
            z: f.z.clone(),
            landmarks: f.landmarks.clone(),
            w: (0..t_lv).map(|t| f.coef(&best_state[t].beta).w).collect(),
        });
        let rff = self.repr.rff().map(|f| NcRff {
            map: f.map.clone(),
            w: (0..t_lv).map(|t| f.coef(&best_state[t].beta).w).collect(),
        });
        Ok(NckqrFit {
            taus: self.taus.clone(),
            lam1,
            lam2,
            levels,
            objective,
            kkt,
            mm_iters: total_iters,
            gamma_final,
            train_crossings,
            lowrank,
            rff,
            ssn: None,
            x_train: self.x.clone(),
            n_train: self.x.rows(),
            kernel: self.kernel.clone(),
        })
    }

    /// Fitted values of every level at the training points (f_t = b_t·1 +
    /// UΛβ_t).
    fn fitted_levels(&self, state: &[LevelState], ws: &mut ApgdWorkspace) -> Vec<Vec<f64>> {
        let n = self.n();
        let mut fs = vec![vec![0.0; n]; self.t_levels()];
        for (t, f) in fs.iter_mut().enumerate() {
            self.basis.fitted(state[t].b, &state[t].beta, &mut ws.scratch, f);
        }
        fs
    }

    /// One γ level: MM solve + per-level eq.-(19) projection + multi-level
    /// set expansion to the fixed point (Theorems 6–7). Returns MM iters.
    fn expand_at_gamma(
        &self,
        plan: &NcPlan,
        eta: f64,
        gamma: f64,
        tol: f64,
        state: &mut Vec<LevelState>,
        ws: &mut ApgdWorkspace,
        s_hat: &mut [Vec<usize>],
    ) -> Result<usize> {
        let n = self.n();
        let t_lv = self.t_levels();
        let mut total_iters = 0usize;
        for _round in 0..self.opts.max_expansions {
            // --- MM iterations to stationarity ---
            total_iters += self.mm_solve(plan, eta, tol, state, ws)?;
            // --- per-level projection (eq. 19); skip near-full S ---
            if self.opts.projection {
                for t in 0..t_lv {
                    if !s_hat[t].is_empty() && s_hat[t].len() <= n / 2 {
                        let lv = &mut state[t];
                        let LevelState { b, beta, .. } = lv;
                        crate::kqr::project_equality(
                            &self.repr,
                            &self.y,
                            &s_hat[t],
                            b,
                            beta,
                            ws,
                        );
                        lv.restart();
                    }
                }
            }
            // --- multi-level set expansion ---
            let mut expanded = false;
            for t in 0..t_lv {
                self.basis.fitted(state[t].b, &state[t].beta, &mut ws.scratch, &mut ws.f);
                let e: Vec<usize> =
                    (0..n).filter(|&i| (self.y[i] - ws.f[i]).abs() <= gamma).collect();
                if e != s_hat[t] {
                    expanded = true;
                    s_hat[t] = e;
                }
            }
            if !expanded {
                break;
            }
        }
        Ok(total_iters)
    }

    /// MM iterations (Jacobi across levels) with Nesterov acceleration
    /// until the stationarity residual max_t max(‖t_t‖∞, |Σw_t|/n) falls
    /// below `tol`.
    ///
    /// Implementation note: the paper's Algorithm 2 runs plain MM; because
    /// the two-majorization surrogate is a fixed quadratic upper bound,
    /// FISTA-style extrapolation applies verbatim and converges in far
    /// fewer O(T·n²) sweeps — a strict improvement we document in
    /// DESIGN.md (the `ablations` bench compares both).
    fn mm_solve(
        &self,
        plan: &NcPlan,
        eta: f64,
        tol: f64,
        state: &mut [LevelState],
        ws: &mut ApgdWorkspace,
    ) -> Result<usize> {
        let n = self.n();
        let nf = n as f64;
        let dim = self.basis.dim();
        let t_lv = self.t_levels();
        let gamma = plan.gamma;
        let lam1 = plan.lam1;
        let mut fs = vec![vec![0.0; n]; t_lv];
        let mut qs = vec![vec![0.0; n]; t_lv.saturating_sub(1)];
        let mut w = vec![0.0; n];
        let mut bars: Vec<(f64, Vec<f64>)> =
            (0..t_lv).map(|_| (0.0, vec![0.0; dim])).collect();
        let mut ck = 1.0f64;
        let mut iters = 0usize;
        loop {
            let ck_next = 0.5 * (1.0 + (1.0 + 4.0 * ck * ck).sqrt());
            let mom = (ck - 1.0) / ck_next;
            // extrapolation point per level + fitted values there
            for t in 0..t_lv {
                let lv = &state[t];
                bars[t].0 = lv.b + mom * (lv.b - lv.b_prev);
                for i in 0..dim {
                    bars[t].1[i] = lv.beta[i] + mom * (lv.beta[i] - lv.beta_prev[i]);
                }
                self.basis.fitted(bars[t].0, &bars[t].1, &mut ws.scratch, &mut fs[t]);
            }
            // crossing-penalty derivatives q_t = V'(f_t − f_{t+1})
            for t in 0..t_lv.saturating_sub(1) {
                for i in 0..n {
                    qs[t][i] = smooth_relu_prime(fs[t][i] - fs[t + 1][i], eta);
                }
            }
            // per-level Σ⁻¹ϱ updates (Jacobi at the extrapolation point)
            let mut conv = 0.0f64;
            for t in 0..t_lv {
                for i in 0..n {
                    let z = h_gamma_prime(self.y[i] - fs[t][i], self.taus[t], gamma);
                    let fwd = if t < t_lv - 1 { qs[t][i] } else { 0.0 };
                    let bwd = if t > 0 { qs[t - 1][i] } else { 0.0 };
                    w[i] = z - nf * lam1 * (fwd - bwd);
                }
                let db = plan.step_update(&self.basis, &w, &bars[t].1, &mut ws.t, &mut ws.dbeta);
                let t_sup = amax(&ws.t);
                let sum_w: f64 = w.iter().sum();
                conv = conv.max(t_sup).max(sum_w.abs() / nf);
                let lv = &mut state[t];
                lv.b_prev = lv.b;
                lv.b = bars[t].0 + db;
                for i in 0..dim {
                    lv.beta_prev[i] = lv.beta[i];
                    lv.beta[i] = bars[t].1[i] + ws.dbeta[i];
                }
            }
            ck = ck_next;
            iters += 1;
            if conv < tol || iters >= self.opts.max_iters {
                return Ok(iters);
            }
        }
    }

    /// Exact KKT certificate of problem (12) (η = η_exact in V′).
    fn kkt_check(&self, lam1: f64, lam2: f64, state: &[LevelState], band: f64) -> KktReport {
        let n = self.n();
        let nf = n as f64;
        let t_lv = self.t_levels();
        let mut scratch = vec![0.0; self.basis.dim()];
        let mut fs = vec![vec![0.0; n]; t_lv];
        for t in 0..t_lv {
            self.basis.fitted(state[t].b, &state[t].beta, &mut scratch, &mut fs[t]);
        }
        let mut max_stat = 0.0f64;
        let mut max_intercept = 0.0f64;
        for t in 0..t_lv {
            let alpha = self.basis.alpha_from_beta(&state[t].beta);
            let mut sum_g = 0.0;
            for i in 0..n {
                let r = self.y[i] - fs[t][i];
                let fwd = if t < t_lv - 1 {
                    smooth_relu_prime(fs[t][i] - fs[t + 1][i], ETA_EXACT)
                } else {
                    0.0
                };
                let bwd = if t > 0 {
                    smooth_relu_prime(fs[t - 1][i] - fs[t][i], ETA_EXACT)
                } else {
                    0.0
                };
                let g = nf * lam2 * alpha[i] + nf * lam1 * (fwd - bwd);
                sum_g += nf * lam2 * alpha[i];
                let (lo, hi) = rho_subgradient(r, self.taus[t], band);
                let viol = (lo - g).max(g - hi).max(0.0);
                max_stat = max_stat.max(viol);
            }
            max_intercept = max_intercept.max((sum_g / nf).abs());
        }
        KktReport {
            max_stationarity: max_stat,
            intercept: max_intercept,
            band,
            pass: max_stat <= self.opts.kkt_tol && max_intercept <= self.opts.kkt_tol,
        }
    }

    /// Exact objective Q of problem (12), from precomputed fitted values
    /// (see [`NckqrSolver::fitted_levels`]).
    fn exact_objective(
        &self,
        lam1: f64,
        lam2: f64,
        state: &[LevelState],
        fs: &[Vec<f64>],
    ) -> f64 {
        let n = self.n();
        let nf = n as f64;
        let t_lv = self.t_levels();
        let mut q = 0.0;
        for t in 0..t_lv {
            let loss: f64 =
                (0..n).map(|i| rho_tau(self.y[i] - fs[t][i], self.taus[t])).sum::<f64>() / nf;
            q += loss + 0.5 * lam2 * self.basis.penalty(&state[t].beta);
        }
        for t in 0..t_lv.saturating_sub(1) {
            for i in 0..n {
                q += lam1 * smooth_relu(fs[t][i] - fs[t + 1][i], ETA_EXACT);
            }
        }
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::data::Rng;
    use crate::kqr::KqrSolver;

    fn fixture(n: usize, seed: u64) -> (Matrix, Vec<f64>, Kernel) {
        let mut rng = Rng::new(seed);
        let d = synth::sine_hetero(n, &mut rng);
        let sigma = crate::kernel::median_heuristic_sigma(&d.x);
        (d.x, d.y, Kernel::Rbf { sigma })
    }

    #[test]
    fn single_level_matches_kqr() {
        let (x, y, kernel) = fixture(40, 1);
        let nc = NckqrSolver::new(&x, &y, kernel.clone(), &[0.5]).unwrap();
        let fit_nc = nc.fit(0.3, 0.02).unwrap();
        let kqr = KqrSolver::new(&x, &y, kernel).unwrap();
        let fit_k = kqr.fit(0.5, 0.02).unwrap();
        // with one level the crossing penalty vanishes; objectives agree
        assert!(
            (fit_nc.objective - fit_k.objective).abs() < 1e-4 * (1.0 + fit_k.objective),
            "nc={} kqr={}",
            fit_nc.objective,
            fit_k.objective
        );
    }

    #[test]
    fn lam1_zero_matches_independent_fits() {
        let (x, y, kernel) = fixture(40, 2);
        let taus = [0.25, 0.75];
        let nc = NckqrSolver::new(&x, &y, kernel.clone(), &taus).unwrap();
        let fit_nc = nc.fit(0.0, 0.05).unwrap();
        let kqr = KqrSolver::new(&x, &y, kernel).unwrap();
        let sum_obj: f64 = taus.iter().map(|&t| kqr.fit(t, 0.05).unwrap().objective).sum();
        assert!(
            (fit_nc.objective - sum_obj).abs() < 1e-3 * (1.0 + sum_obj),
            "nc={} sum_kqr={sum_obj}",
            fit_nc.objective
        );
    }

    #[test]
    fn kkt_certificate_passes() {
        let (x, y, kernel) = fixture(50, 3);
        let nc = NckqrSolver::new(&x, &y, kernel, &[0.1, 0.5, 0.9]).unwrap();
        let fit = nc.fit(1.0, 0.02).unwrap();
        assert!(fit.kkt.pass, "{:?}", fit.kkt);
    }

    #[test]
    fn large_lam1_removes_crossings() {
        // Heteroscedastic data with small n is the canonical crossing
        // scenario; with strong λ₁ the curves must be ordered.
        let (x, y, kernel) = fixture(60, 4);
        let taus = [0.1, 0.3, 0.5, 0.7, 0.9];
        let nc = NckqrSolver::new(&x, &y, kernel.clone(), &taus).unwrap();
        // independent fits (λ₁ = 0): typically cross somewhere
        let free = nc.fit(0.0, 1e-3).unwrap();
        let tight = nc.fit(50.0, 1e-3).unwrap();
        let grid = Matrix::from_fn(120, 1, |i, _| i as f64 / 119.0);
        let cross_free = free.count_crossings(&grid, 1e-9);
        let cross_tight = tight.count_crossings(&grid, 1e-6);
        assert_eq!(cross_tight, 0, "crossings remain under strong penalty");
        assert!(cross_free >= cross_tight, "free={cross_free} tight={cross_tight}");
    }

    #[test]
    fn levels_are_ordered_in_probability() {
        let (x, y, kernel) = fixture(60, 5);
        let nc = NckqrSolver::new(&x, &y, kernel, &[0.2, 0.8]).unwrap();
        let fit = nc.fit(10.0, 0.01).unwrap();
        let preds = fit.predict(&x);
        // the 0.8-quantile curve should lie above the 0.2 curve on average
        let mean_gap: f64 =
            preds[1].iter().zip(&preds[0]).map(|(h, l)| h - l).sum::<f64>() / x.rows() as f64;
        assert!(mean_gap > 0.3, "gap={mean_gap}");
    }

    #[test]
    fn warm_lam2_path_consistent_with_cold() {
        let (x, y, kernel) = fixture(35, 6);
        let nc = NckqrSolver::new(&x, &y, kernel, &[0.3, 0.7]).unwrap();
        let lam2s = [0.2, 0.05, 0.01];
        let path = nc.fit_path(1.0, &lam2s).unwrap();
        for (i, f) in path.iter().enumerate() {
            let cold = nc.fit(1.0, lam2s[i]).unwrap();
            assert!(
                (f.objective - cold.objective).abs() < 1e-3 * (1.0 + cold.objective),
                "lam2={}: warm {} vs cold {}",
                lam2s[i],
                f.objective,
                cold.objective
            );
        }
    }

    #[test]
    fn input_validation() {
        let (x, y, kernel) = fixture(10, 7);
        let nc = NckqrSolver::new(&x, &y, kernel, &[0.5]).unwrap();
        assert!(nc.fit(-1.0, 0.1).is_err());
        assert!(nc.fit(1.0, 0.0).is_err());
    }

    #[test]
    fn bad_construction_inputs_are_errors_not_panics() {
        // These arrive from wire payloads: they must surface as Err.
        let (x, y, kernel) = fixture(10, 8);
        assert!(NckqrSolver::new(&x, &y, kernel.clone(), &[0.5, 0.5]).is_err(), "dup taus");
        assert!(NckqrSolver::new(&x, &y, kernel.clone(), &[]).is_err(), "empty taus");
        assert!(NckqrSolver::new(&x, &y, kernel.clone(), &[0.0]).is_err(), "tau=0");
        assert!(NckqrSolver::new(&x, &y[..5], kernel, &[0.5]).is_err(), "len mismatch");
    }

    #[test]
    fn with_basis_matches_fresh_solver() {
        let (x, y, kernel) = fixture(30, 9);
        let fresh = NckqrSolver::new(&x, &y, kernel.clone(), &[0.3, 0.7]).unwrap();
        let shared = NckqrSolver::with_basis(
            &x,
            &y,
            kernel,
            &[0.3, 0.7],
            fresh.gram().clone(),
            fresh.basis.clone(),
        )
        .unwrap();
        let a = fresh.fit(1.0, 0.05).unwrap();
        let b = shared.fit(1.0, 0.05).unwrap();
        assert_eq!(a.objective, b.objective, "same basis ⇒ identical solve");
        assert_eq!(a.train_crossings, b.train_crossings);
        // training crossings agree with the predict-based count
        assert_eq!(a.train_crossings, a.count_crossings(&x, 1e-9));
    }
}
