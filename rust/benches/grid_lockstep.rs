//! Grid-solve trajectory: sequential per-cell `fit_grid` (BLAS-2) vs the
//! lockstep bundle driver (BLAS-3) on a τ×λ grid, packed-GEMM GFLOP/s,
//! the lockstep-vs-oracle parity deviation, and the APGD-vs-SSN solver
//! race (dense and rank-m ≪ n Nyström, wall + objective gap). Writes the
//! machine-readable baseline to `BENCH_grid.json` (override with
//! `--out`), so the perf trajectory of future PRs has a recorded
//! starting point.
//!
//! Acceptance tracking (ISSUE 2): at n ≥ 512 on an 8×8 grid the lockstep
//! path should be ≥ 2× faster end-to-end, with `parity_max_abs ≤ 1e-10`.
use fastkqr::experiments::perf;
use fastkqr::linalg::par;
use fastkqr::util::Args;

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 512);
    let taus = args.get_usize("taus", 8);
    let lams = args.get_usize("lams", 8);
    let reps = args.get_usize("reps", 3);
    let out = args.get_str("out", "BENCH_grid.json").to_string();
    println!(
        "-- grid solve: sequential (BLAS-2) vs lockstep (BLAS-3), {} threads --",
        par::global().threads
    );
    let gb = perf::grid_bench(n, taus, lams, reps).expect("grid bench");
    println!("{}", gb.seq.report_line());
    println!("{}", gb.lockstep.report_line());
    println!("   {:.2}x speedup on the {taus}x{lams} grid at n={n}", gb.speedup);
    println!("{}  ({:.2} GFLOP/s packed gemm)", gb.gemm.report_line(), gb.gemm_gflops);
    println!(
        "   simd: isa={} fma={}  gemm {:.2} -> {:.2} GFLOP/s ({:.2}x scalar -> simd)",
        gb.simd_isa,
        gb.simd_fma,
        gb.gemm_gflops_scalar,
        gb.gemm_gflops,
        gb.gemm_gflops / gb.gemm_gflops_scalar.max(1e-12)
    );
    println!("   lockstep-vs-oracle parity: max |Δ(b,α)| = {:.3e}", gb.parity_max_abs);
    println!("{}", gb.ssn.report_line());
    println!(
        "   ssn race (dense): {:.2}x vs blas2, obj gap {:.3e}",
        gb.seq.median / gb.ssn.median.max(1e-12),
        gb.ssn_obj_gap
    );
    println!("{}", gb.apgd_lowrank.report_line());
    println!("{}", gb.ssn_lowrank.report_line());
    println!(
        "   ssn race (nystrom m={}): {:.2}x vs apgd, obj gap {:.3e}",
        gb.lowrank_m,
        gb.apgd_lowrank.median / gb.ssn_lowrank.median.max(1e-12),
        gb.ssn_lowrank_obj_gap
    );
    println!("{}", gb.ssn_oracle.report_line());
    println!("{}", gb.ssn_bundle.report_line());
    println!(
        "   ssn factor economy: carry {:.2}x / bundle {:.2}x vs per-cell oracle \
         (refactorizations {} -> {}, {} rank-1 updates)",
        gb.ssn_carry_speedup,
        gb.ssn_bundle_speedup,
        gb.ssn_refactors_oracle,
        gb.ssn_refactors_carry,
        gb.ssn_rank1_updates
    );
    std::fs::write(&out, gb.to_json().to_string()).expect("write BENCH_grid.json");
    println!("wrote {out}");
}
