//! Micro/macro benchmark harness (criterion substitute, substrate).
//!
//! `cargo bench` targets use `harness = false` and drive this module. It
//! provides warmup, repeated timed runs, robust summary statistics and
//! the table-formatted reporting the experiment harnesses share.

use std::time::Instant;

/// Summary statistics over repeated timed runs (seconds).
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub reps: usize,
    pub mean: f64,
    pub sd: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

impl BenchStats {
    pub fn from_samples(name: impl Into<String>, mut samples: Vec<f64>) -> BenchStats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
        BenchStats {
            name: name.into(),
            reps: samples.len(),
            mean,
            sd: var.sqrt(),
            min: samples[0],
            max: *samples.last().unwrap(),
            median: samples[samples.len() / 2],
        }
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} reps={:<3} mean={:>10.4}s sd={:>8.4}s min={:>10.4}s median={:>10.4}s",
            self.name, self.reps, self.mean, self.sd, self.min, self.median
        )
    }
}

/// Time `f` once, returning (seconds, result).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let t0 = Instant::now();
    let out = f();
    (t0.elapsed().as_secs_f64(), out)
}

/// Benchmark runner: `warmup` throwaway calls then `reps` timed calls.
/// The closure receives the rep index (harnesses use it to reseed).
pub fn run_bench<T>(
    name: &str,
    warmup: usize,
    reps: usize,
    mut f: impl FnMut(usize) -> T,
) -> BenchStats {
    for w in 0..warmup {
        let out = f(w);
        std::hint::black_box(&out);
    }
    let mut samples = Vec::with_capacity(reps);
    for r in 0..reps {
        let t0 = Instant::now();
        let out = f(warmup + r);
        std::hint::black_box(&out);
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchStats::from_samples(name, samples)
}

/// Mean and standard error of a sample of metric values (used to report
/// the paper's "obj (sd)" cells).
pub fn mean_sd(values: &[f64]) -> (f64, f64) {
    let n = values.len() as f64;
    if values.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let mean = values.iter().sum::<f64>() / n;
    let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n.max(1.0);
    (mean, var.sqrt())
}

/// Paper-style table printer: fixed-width columns, one header row.
pub struct TablePrinter {
    pub widths: Vec<usize>,
}

impl TablePrinter {
    pub fn new(headers: &[&str], widths: Vec<usize>) -> TablePrinter {
        assert_eq!(headers.len(), widths.len());
        let tp = TablePrinter { widths };
        tp.row(headers);
        let total: usize = tp.widths.iter().sum::<usize>() + tp.widths.len() * 2;
        println!("{}", "-".repeat(total));
        tp
    }

    pub fn row(&self, cells: &[&str]) {
        let mut line = String::new();
        for (c, w) in cells.iter().zip(&self.widths) {
            line.push_str(&format!("{:<width$}  ", c, width = w));
        }
        println!("{}", line.trim_end());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_samples() {
        let s = BenchStats::from_samples("t", vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
        assert!((s.mean - 2.0).abs() < 1e-15);
    }

    #[test]
    fn run_bench_counts_reps() {
        let mut calls = 0usize;
        let s = run_bench("x", 2, 5, |_| {
            calls += 1;
            calls
        });
        assert_eq!(calls, 7);
        assert_eq!(s.reps, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn mean_sd_hand_checked() {
        let (m, sd) = mean_sd(&[2.0, 4.0]);
        assert_eq!(m, 3.0);
        assert!((sd - 1.0).abs() < 1e-15);
    }
}
