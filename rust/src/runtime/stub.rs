//! Stub XLA backend for builds without the `xla` feature.
//!
//! Keeps every call site (`--backend xla`, the e2e example, the perf
//! harness, the integration tests' probes) compiling unchanged; the only
//! observable behavior is a construction-time error explaining how to get
//! the real backend.

use crate::backend::Backend;
use crate::kqr::apgd::ApgdState;
use crate::spectral::{SpectralBasis, SpectralPlan};
use anyhow::{bail, Result};
use std::path::Path;

/// Placeholder for the PJRT-backed APGD backend. Cannot be constructed;
/// both constructors return an error describing the missing feature.
pub struct XlaBackend {
    /// Number of artifact executions (kept for API parity with the real
    /// backend; always 0 because the stub cannot be constructed).
    pub executions: usize,
    _unconstructible: (),
}

impl XlaBackend {
    pub fn new(_artifact_dir: impl AsRef<Path>) -> Result<XlaBackend> {
        bail!(
            "fastkqr was built without the `xla` cargo feature; the PJRT \
             runtime is unavailable. Enabling it needs an environment with \
             the xla bindings crate (add it to rust/Cargo.toml — it is not \
             declared because the offline image cannot resolve it) and a \
             PJRT CPU plugin; then build with `--features xla` and run \
             `make artifacts`."
        )
    }

    /// Default artifact location relative to the repo root.
    pub fn from_default_dir() -> Result<XlaBackend> {
        XlaBackend::new("artifacts")
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn apgd_chunk(
        &mut self,
        _basis: &SpectralBasis,
        _plan: &SpectralPlan,
        _y: &[f64],
        _tau: f64,
        _state: &mut ApgdState,
        _iters: usize,
    ) -> f64 {
        unreachable!("stub XlaBackend cannot be constructed")
    }
}
