//! BLAS-3 kernels for the lockstep grid solver (engine L1).
//!
//! The lockstep driver advances a *bundle* of m grid cells per iteration,
//! turning the solver's two per-cell GEMVs against the n×n eigenbasis U
//! into two GEMMs that stream U **once** for the whole bundle instead of
//! once per cell — the bandwidth-to-compute upgrade that makes grid-heavy
//! CV/server traffic run at hardware speed. Three entry points:
//!
//! - [`gemm_nt_into`]: `C = A·Bᵀ` with every element computed by the
//!   *identical* 4-way unrolled serial dot product (`blas::dot`), so each
//!   column of C is **bitwise equal** to `gemv(A, b_row)`. Row-band
//!   parallel; used for the multi-RHS fitted values `F = U·(Λ∘B̄)`.
//! - [`gemm_nn_into`]: `C = A·B` accumulated in the k-ascending axpy
//!   order of `gemv_t_serial` (including its zero-skip), so each row of C
//!   is **bitwise equal** to `gemv_t(B, a_row)`. Column-stripe parallel
//!   with per-thread stripe buffers (each thread streams only its column
//!   slice of B — B is read exactly once in total); used for the
//!   multi-RHS gradient carrier `T = Uᵀ·Z`.
//! - [`gemm_into`]: a cache-blocked, panel-packed Mc/Kc/Nc tiled GEMM
//!   with a 4×4 register microkernel, row-band parallel over Mc blocks.
//!   This one re-associates the k-reduction across Kc panels (it is NOT
//!   bitwise comparable to the GEMV kernels) and is the right tool for
//!   large one-time products (Nyström factors, benchmarking GFLOP/s).
//!   Tile sizes come from `FASTKQR_GEMM_MC` / `_KC` / `_NC`.
//!
//! The bitwise contracts are what let the lockstep solve path reproduce
//! the sequential `fit_grid` oracle exactly (see `engine::lockstep`).
//!
//! All three entry points pull their inner kernels (dot / axpy / the 4×4
//! register tile) from the `linalg::simd` dispatch table, which is
//! bitwise-equal to the scalar oracle by construction — so the contracts
//! above hold at every ISA tier.

use super::matrix::Matrix;
use super::par::block_size;
use super::simd::{self, SimdDispatch};
use std::sync::OnceLock;

/// `C = A·Bᵀ` (A: p×k, B: q×k, C: p×q); `c[i][j] = dot(a.row(i), b.row(j))`.
///
/// Every element is one contiguous-slice `blas::dot`, so column j of C is
/// bitwise equal to `gemv_serial(A, b.row(j))` at any worker count. The
/// loop order (C rows outer, B rows inner) keeps the current A row in L1
/// across all q dot products — A is streamed once per call, not once per
/// RHS column, which is the whole BLAS-3 point.
pub fn gemm_nt_into(a: &Matrix, b: &Matrix, c: &mut Matrix, workers: usize) {
    assert_eq!(a.cols(), b.cols(), "gemm_nt_into: inner dim mismatch");
    assert_eq!(c.rows(), a.rows(), "gemm_nt_into: C rows mismatch");
    assert_eq!(c.cols(), b.rows(), "gemm_nt_into: C cols mismatch");
    let (p, q) = (a.rows(), b.rows());
    if p == 0 || q == 0 {
        return;
    }
    let t = simd::global();
    let w = workers.max(1).min(p);
    if w <= 1 {
        for i in 0..p {
            let arow = a.row(i);
            let crow = c.row_mut(i);
            for (j, cij) in crow.iter_mut().enumerate() {
                *cij = (t.dot)(arow, b.row(j));
            }
        }
        return;
    }
    let block = block_size(p, w);
    std::thread::scope(|s| {
        for (bi, rows) in c.as_mut_slice().chunks_mut(block * q).enumerate() {
            let r0 = bi * block;
            s.spawn(move || {
                for (r, crow) in rows.chunks_mut(q).enumerate() {
                    let arow = a.row(r0 + r);
                    for (j, cij) in crow.iter_mut().enumerate() {
                        *cij = (t.dot)(arow, b.row(j));
                    }
                }
            });
        }
    });
}

/// `C = A·B` (A: m×k, B: k×n, C: m×n) in the k-ascending axpy order of
/// `gemv_t_serial`: row r of C is bitwise equal to `gemv_t(B, a.row(r))`
/// at any worker count (same accumulation order, same zero-skip).
///
/// Serial path streams B exactly once for all m rows (k outer, rows
/// inner; the C rows act as m in-cache accumulators). The parallel path
/// stripes the *columns* of B/C: each thread accumulates its stripe in a
/// private buffer while reading only its contiguous column slice of each
/// B row, so B is still read exactly once in total and per-element
/// accumulation order is unchanged.
pub fn gemm_nn_into(a: &Matrix, b: &Matrix, c: &mut Matrix, workers: usize) {
    assert_eq!(a.cols(), b.rows(), "gemm_nn_into: inner dim mismatch");
    assert_eq!(c.rows(), a.rows(), "gemm_nn_into: C rows mismatch");
    assert_eq!(c.cols(), b.cols(), "gemm_nn_into: C cols mismatch");
    let (m, kdim, nn) = (a.rows(), a.cols(), b.cols());
    c.as_mut_slice().fill(0.0);
    if m == 0 || nn == 0 || kdim == 0 {
        return;
    }
    let t = simd::global();
    let w = workers.max(1).min(nn);
    if w <= 1 {
        for k in 0..kdim {
            let brow = b.row(k);
            for r in 0..m {
                let ark = a[(r, k)];
                if ark != 0.0 {
                    (t.axpy)(ark, brow, c.row_mut(r));
                }
            }
        }
        return;
    }
    let stripe = block_size(nn, w);
    let mut stripes: Vec<(usize, Matrix)> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        let mut j0 = 0usize;
        while j0 < nn {
            let j1 = (j0 + stripe).min(nn);
            handles.push((
                j0,
                s.spawn(move || {
                    let mut buf = Matrix::zeros(m, j1 - j0);
                    for k in 0..kdim {
                        let bslice = &b.row(k)[j0..j1];
                        for r in 0..m {
                            let ark = a[(r, k)];
                            if ark != 0.0 {
                                (t.axpy)(ark, bslice, buf.row_mut(r));
                            }
                        }
                    }
                    buf
                }),
            ));
            j0 = j1;
        }
        for (start, h) in handles {
            stripes.push((start, h.join().expect("gemm_nn_into worker panicked")));
        }
    });
    for (j0, buf) in &stripes {
        let wlen = buf.cols();
        for r in 0..m {
            c.row_mut(r)[*j0..j0 + wlen].copy_from_slice(buf.row(r));
        }
    }
}

/// Cache-tile sizes for the packed GEMM: C is computed Mc rows × Nc
/// columns at a time over Kc-deep packed panels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmTiles {
    /// Row-panel height (A pack is mc×kc, should sit in L2).
    pub mc: usize,
    /// Reduction depth per panel (bounds pack buffer size).
    pub kc: usize,
    /// Column-panel width (B pack is kc×nc, should sit in L1/L2).
    pub nc: usize,
}

impl GemmTiles {
    pub const DEFAULT: GemmTiles = GemmTiles { mc: 64, kc: 256, nc: 128 };

    /// Environment-driven tiles: `FASTKQR_GEMM_MC` / `FASTKQR_GEMM_KC` /
    /// `FASTKQR_GEMM_NC` (each ≥ 4), else [`GemmTiles::DEFAULT`]. Read
    /// once per process.
    pub fn auto() -> GemmTiles {
        static AUTO: OnceLock<GemmTiles> = OnceLock::new();
        *AUTO.get_or_init(|| {
            let read = |key: &str, dflt: usize| {
                std::env::var(key)
                    .ok()
                    .and_then(|v| v.trim().parse::<usize>().ok())
                    .filter(|&t| t >= 4)
                    .unwrap_or(dflt)
            };
            GemmTiles {
                mc: read("FASTKQR_GEMM_MC", Self::DEFAULT.mc),
                kc: read("FASTKQR_GEMM_KC", Self::DEFAULT.kc),
                nc: read("FASTKQR_GEMM_NC", Self::DEFAULT.nc),
            }
        })
    }
}

/// `C = A·B` through the packed tiled kernel, with env-configured tiles
/// and the global parallel budget (row-banded above the serial cutoff).
///
/// The Kc panel split re-associates each k-reduction, so results agree
/// with [`super::blas::gemm`] to rounding, not bitwise — use this for
/// large one-time products, not for anything the lockstep parity
/// contract covers.
pub fn gemm_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let dim = a.rows().min(a.cols()).min(b.cols());
    let workers = super::par::global().workers_for(dim);
    gemm_into_tiled(a, b, c, GemmTiles::auto(), workers);
}

/// [`gemm_into`] with explicit tiles and worker count.
pub fn gemm_into_tiled(a: &Matrix, b: &Matrix, c: &mut Matrix, tiles: GemmTiles, workers: usize) {
    gemm_into_tiled_with(a, b, c, tiles, workers, simd::global())
}

/// [`gemm_into_tiled`] through an explicit dispatch table — benches and
/// parity tests pass `simd::scalar()` here to pin the oracle microkernel.
pub fn gemm_into_tiled_with(
    a: &Matrix,
    b: &Matrix,
    c: &mut Matrix,
    tiles: GemmTiles,
    workers: usize,
    t: &SimdDispatch,
) {
    assert_eq!(a.cols(), b.rows(), "gemm_into: inner dim mismatch");
    assert_eq!(c.rows(), a.rows(), "gemm_into: C rows mismatch");
    assert_eq!(c.cols(), b.cols(), "gemm_into: C cols mismatch");
    let (m, kdim, nn) = (a.rows(), a.cols(), b.cols());
    c.as_mut_slice().fill(0.0);
    if m == 0 || nn == 0 || kdim == 0 {
        return;
    }
    let w = workers.max(1).min(m);
    if w <= 1 {
        packed_band(a, b, c.as_mut_slice(), 0, m, nn, tiles, t);
        return;
    }
    let block = block_size(m, w);
    std::thread::scope(|s| {
        for (bi, rows) in c.as_mut_slice().chunks_mut(block * nn).enumerate() {
            let r0 = bi * block;
            let rows_here = rows.len() / nn;
            s.spawn(move || packed_band(a, b, rows, r0, rows_here, nn, tiles, t));
        }
    });
}

/// Packed tiled GEMM for one contiguous row band of C (`crows` holds
/// `m_band` rows of width `nn`, starting at global row `r0`).
#[allow(clippy::too_many_arguments)]
fn packed_band(
    a: &Matrix,
    b: &Matrix,
    crows: &mut [f64],
    r0: usize,
    m_band: usize,
    nn: usize,
    tiles: GemmTiles,
    t: &SimdDispatch,
) {
    let kdim = a.cols();
    let mut apack = vec![0.0f64; tiles.mc * tiles.kc];
    let mut bpack = vec![0.0f64; tiles.kc * tiles.nc];
    for kb in (0..kdim).step_by(tiles.kc) {
        let k_eff = tiles.kc.min(kdim - kb);
        for jb in (0..nn).step_by(tiles.nc) {
            let n_eff = tiles.nc.min(nn - jb);
            // pack B panel (k_eff × n_eff, row-major)
            for kk in 0..k_eff {
                bpack[kk * n_eff..(kk + 1) * n_eff]
                    .copy_from_slice(&b.row(kb + kk)[jb..jb + n_eff]);
            }
            for ib in (0..m_band).step_by(tiles.mc) {
                let m_eff = tiles.mc.min(m_band - ib);
                // pack A panel (m_eff × k_eff, row-major)
                for ir in 0..m_eff {
                    apack[ir * k_eff..(ir + 1) * k_eff]
                        .copy_from_slice(&a.row(r0 + ib + ir)[kb..kb + k_eff]);
                }
                micro_tile(
                    &apack[..m_eff * k_eff],
                    &bpack[..k_eff * n_eff],
                    m_eff,
                    k_eff,
                    n_eff,
                    crows,
                    ib,
                    jb,
                    nn,
                    t,
                );
            }
        }
    }
}

/// 4×4 register-tile microkernel: `C[ib+i][jb+j] += Σ_k Apack[i][k]·Bpack[k][j]`.
///
/// Full tiles go through the dispatched `tile4x4` kernel (AVX2/NEON on
/// capable hosts, the scalar register tile otherwise — bitwise equal).
/// Edge tiles use the same 4-way unrolled `(s0+s1)+(s2+s3)` accumulation
/// as `blas::dot` over the strided B column, shared by every ISA tier.
#[allow(clippy::too_many_arguments)]
fn micro_tile(
    apack: &[f64],
    bpack: &[f64],
    m_eff: usize,
    k_eff: usize,
    n_eff: usize,
    crows: &mut [f64],
    ib: usize,
    jb: usize,
    nn: usize,
    t: &SimdDispatch,
) {
    const MR: usize = 4;
    const NR: usize = 4;
    for i0 in (0..m_eff).step_by(MR) {
        let irn = MR.min(m_eff - i0);
        for j0 in (0..n_eff).step_by(NR) {
            let jrn = NR.min(n_eff - j0);
            if irn == MR && jrn == NR {
                // Full tile: dispatched 16-accumulator register kernel.
                let acc = (t.tile4x4)(apack, bpack, i0, j0, k_eff, n_eff);
                for (ir, accr) in acc.iter().enumerate() {
                    let base = (ib + i0 + ir) * nn + jb + j0;
                    for (jr, v) in accr.iter().enumerate() {
                        crows[base + jr] += v;
                    }
                }
            } else {
                // Edge tile: 4-way unrolled strided accumulation, same
                // reduction shape as blas::dot (kept scalar — the B
                // column is strided, so vector loads don't apply).
                for ir in 0..irn {
                    let arow = &apack[(i0 + ir) * k_eff..(i0 + ir + 1) * k_eff];
                    let base = (ib + i0 + ir) * nn + jb + j0;
                    for jr in 0..jrn {
                        let chunks = k_eff / 4;
                        let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
                        for c in 0..chunks {
                            let kk = 4 * c;
                            let bofs = kk * n_eff + j0 + jr;
                            s0 += arow[kk] * bpack[bofs];
                            s1 += arow[kk + 1] * bpack[bofs + n_eff];
                            s2 += arow[kk + 2] * bpack[bofs + 2 * n_eff];
                            s3 += arow[kk + 3] * bpack[bofs + 3 * n_eff];
                        }
                        let mut s = (s0 + s1) + (s2 + s3);
                        for kk in 4 * chunks..k_eff {
                            s += arow[kk] * bpack[kk * n_eff + j0 + jr];
                        }
                        crows[base + jr] += s;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::linalg::blas;

    fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(rows, cols, |_, _| rng.normal())
    }

    #[test]
    fn gemm_nt_columns_bitwise_match_gemv() {
        let a = random_matrix(37, 23, 1); // plays U
        let b = random_matrix(5, 23, 2); // bundle rows (cell-major)
        for workers in [1usize, 2, 4] {
            let mut c = Matrix::zeros(37, 5);
            gemm_nt_into(&a, &b, &mut c, workers);
            for cell in 0..5 {
                let mut expect = vec![0.0; 37];
                blas::gemv_serial(&a, b.row(cell), &mut expect);
                for i in 0..37 {
                    assert_eq!(c[(i, cell)], expect[i], "workers={workers} cell={cell} i={i}");
                }
            }
        }
    }

    #[test]
    fn gemm_nn_rows_bitwise_match_gemv_t() {
        let z = random_matrix(4, 41, 3); // bundle rows (cell-major)
        let u = random_matrix(41, 29, 4);
        for workers in [1usize, 2, 5] {
            let mut t = Matrix::zeros(4, 29);
            gemm_nn_into(&z, &u, &mut t, workers);
            for cell in 0..4 {
                let mut expect = vec![0.0; 29];
                blas::gemv_t_serial(&u, z.row(cell), &mut expect);
                assert_eq!(t.row(cell), &expect[..], "workers={workers} cell={cell}");
            }
        }
    }

    #[test]
    fn gemm_nn_handles_exact_zeros_like_serial() {
        // The zero-skip must match gemv_t's; seed exact zeros in A.
        let mut z = random_matrix(3, 20, 5);
        for k in (0..20).step_by(3) {
            z[(1, k)] = 0.0;
        }
        let u = random_matrix(20, 11, 6);
        let mut t1 = Matrix::zeros(3, 11);
        gemm_nn_into(&z, &u, &mut t1, 1);
        let mut t4 = Matrix::zeros(3, 11);
        gemm_nn_into(&z, &u, &mut t4, 4);
        assert_eq!(t1.as_slice(), t4.as_slice());
    }

    #[test]
    fn packed_gemm_matches_reference_across_shapes() {
        // Shapes straddling the tile boundaries, incl. non-multiples.
        let tiles = GemmTiles { mc: 8, kc: 16, nc: 8 };
        for (m, k, n, seed) in
            [(1usize, 1usize, 1usize, 7u64), (9, 17, 9, 8), (8, 16, 8, 9), (33, 50, 21, 10)]
        {
            let a = random_matrix(m, k, seed);
            let b = random_matrix(k, n, seed + 100);
            let reference = blas::gemm_serial(&a, &b);
            for workers in [1usize, 3] {
                let mut c = Matrix::zeros(m, n);
                gemm_into_tiled(&a, &b, &mut c, tiles, workers);
                assert!(
                    reference.max_abs_diff(&c) < 1e-11,
                    "m={m} k={k} n={n} workers={workers}: diff {}",
                    reference.max_abs_diff(&c)
                );
            }
        }
    }

    #[test]
    fn packed_gemm_default_entry_point() {
        let a = random_matrix(30, 40, 11);
        let b = random_matrix(40, 25, 12);
        let mut c = Matrix::zeros(30, 25);
        gemm_into(&a, &b, &mut c);
        let reference = blas::gemm_serial(&a, &b);
        assert!(reference.max_abs_diff(&c) < 1e-11);
    }

    #[test]
    fn empty_dims_are_noops() {
        let a = Matrix::zeros(0, 3);
        let b = Matrix::zeros(4, 3);
        let mut c = Matrix::zeros(0, 4);
        gemm_nt_into(&a, &b, &mut c, 2);
        let a2 = Matrix::zeros(2, 0);
        let b2 = Matrix::zeros(0, 3);
        let mut c2 = Matrix::from_fn(2, 3, |_, _| 9.0);
        gemm_nn_into(&a2, &b2, &mut c2, 2);
        assert!(c2.as_slice().iter().all(|&v| v == 0.0), "C must be cleared");
        let mut c3 = Matrix::from_fn(2, 3, |_, _| 9.0);
        gemm_into_tiled(&a2, &b2, &mut c3, GemmTiles::DEFAULT, 2);
        assert!(c3.as_slice().iter().all(|&v| v == 0.0));
    }
}
