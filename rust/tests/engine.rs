//! Engine-layer integration: cache accounting across subsystems,
//! parallel-vs-serial numerical parity, and concurrency guarantees (two
//! scheduler jobs on the same dataset → exactly one eigendecomposition).

use fastkqr::coordinator::{FitJob, JobSpec, Scheduler};
use fastkqr::cv::{cross_validate_on, fold_assignment};
use fastkqr::data::{synth, Rng};
use fastkqr::engine::{ApproxSpec, CacheMetrics, EngineConfig, FitEngine};
use fastkqr::kernel::Kernel;
use fastkqr::kqr::SolveOptions;
use fastkqr::linalg::{blas, par, Matrix, Parallelism};
use std::sync::Arc;

fn fresh_engine() -> Arc<FitEngine> {
    Arc::new(FitEngine::with_config(EngineConfig {
        par: Parallelism::with_threads(2),
        ..EngineConfig::default()
    }))
}

// ---------- parallel-vs-serial parity (1e-12 tolerance) ----------

#[test]
fn parallel_gemv_parity_across_sizes_and_workers() {
    let mut rng = Rng::new(1);
    for n in [17usize, 64, 301] {
        let a = Matrix::from_fn(n, n, |_, _| rng.normal());
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut serial = vec![0.0; n];
        blas::gemv_serial(&a, &x, &mut serial);
        for workers in [2usize, 3, 8] {
            let mut out = vec![0.0; n];
            par::par_gemv(&a, &x, &mut out, workers);
            for (s, p) in serial.iter().zip(&out) {
                assert!(
                    (s - p).abs() <= 1e-12 * (1.0 + s.abs()),
                    "gemv n={n} workers={workers}: {s} vs {p}"
                );
            }
            let mut tserial = vec![0.0; n];
            blas::gemv_t_serial(&a, &x, &mut tserial);
            let mut tpar = vec![0.0; n];
            par::par_gemv_t(&a, &x, &mut tpar, workers);
            for (s, p) in tserial.iter().zip(&tpar) {
                assert!(
                    (s - p).abs() <= 1e-12 * (1.0 + s.abs()),
                    "gemv_t n={n} workers={workers}: {s} vs {p}"
                );
            }
        }
    }
}

#[test]
fn parallel_gemm_and_gram_parity() {
    let mut rng = Rng::new(2);
    let a = Matrix::from_fn(45, 33, |_, _| rng.normal());
    let b = Matrix::from_fn(33, 27, |_, _| rng.normal());
    let serial = blas::gemm_serial(&a, &b);
    for workers in [2usize, 4] {
        let parallel = par::par_gemm(&a, &b, workers);
        assert!(
            serial.max_abs_diff(&parallel) <= 1e-12,
            "gemm workers={workers}: diff {}",
            serial.max_abs_diff(&parallel)
        );
    }
    let x = Matrix::from_fn(80, 3, |_, _| rng.normal());
    for kernel in [
        Kernel::Rbf { sigma: 0.9 },
        Kernel::Laplacian { sigma: 1.1 },
        Kernel::Linear { c: 0.5 },
    ] {
        let gs = kernel.gram_blocked(&x, 1);
        let gp = kernel.gram_blocked(&x, 4);
        assert!(
            gs.max_abs_diff(&gp) <= 1e-12,
            "gram parity ({kernel:?}): diff {}",
            gs.max_abs_diff(&gp)
        );
    }
}

#[test]
fn small_n_serial_results_unchanged_bitwise() {
    // Below the cutoff the dispatching kernels must take the serial path
    // and reproduce it exactly (the 1e-12 acceptance bound is trivially 0).
    let mut rng = Rng::new(3);
    let n = 40; // << DEFAULT_MIN_DIM
    let a = Matrix::from_fn(n, n, |_, _| rng.normal());
    let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut dispatched = vec![0.0; n];
    fastkqr::linalg::gemv(&a, &x, &mut dispatched);
    let mut serial = vec![0.0; n];
    blas::gemv_serial(&a, &x, &mut serial);
    assert_eq!(dispatched, serial);
}

// ---------- cache accounting ----------

#[test]
fn cv_folds_and_refit_hit_cache_on_rerun() {
    let engine = fresh_engine();
    let mut rng = Rng::new(4);
    let data = synth::sine_hetero(45, &mut rng);
    let kernel = Kernel::Rbf { sigma: 0.5 };
    let opts = SolveOptions::cv_preset();
    let lams = [0.5, 0.05];
    let k = 3;

    let mut rng_cv = Rng::new(9);
    let first = cross_validate_on(
        &engine, &data, &kernel, 0.5, &lams, k, &opts, ApproxSpec::Exact, &mut rng_cv,
    )
    .unwrap();
    // k fold bases + 1 full-data refit basis
    let after_first = CacheMetrics::get(&engine.cache.metrics.decompositions);
    assert_eq!(after_first, (k + 1) as u64, "one basis per fold + refit");

    // identical seed → identical folds → every basis is a cache hit
    let mut rng_cv2 = Rng::new(9);
    let second = cross_validate_on(
        &engine, &data, &kernel, 0.5, &lams, k, &opts, ApproxSpec::Exact, &mut rng_cv2,
    )
    .unwrap();
    assert_eq!(
        CacheMetrics::get(&engine.cache.metrics.decompositions),
        after_first,
        "re-running CV on the same data must not re-decompose"
    );
    assert_eq!(first.best_index, second.best_index);
    for (a, b) in first.cv_loss.iter().zip(&second.cv_loss) {
        assert!((a - b).abs() < 1e-12);
    }
}

#[test]
fn multi_tau_grid_is_one_decomposition() {
    let engine = fresh_engine();
    let mut rng = Rng::new(5);
    let data = synth::sine_hetero(35, &mut rng);
    let kernel = Kernel::Rbf { sigma: 0.6 };
    let grid = engine
        .fit_grid(&data.x, &data.y, &kernel, &[0.1, 0.5, 0.9], &[0.1, 0.01])
        .unwrap();
    assert_eq!(grid.fits.len(), 3);
    assert!(grid.fits.iter().all(|col| col.len() == 2));
    assert_eq!(
        CacheMetrics::get(&engine.cache.metrics.decompositions),
        1,
        "the whole tau-grid must share one basis"
    );
    // and a follow-up solver on the same data is a pure hit
    let _s = engine.solver_for(&data, &kernel).unwrap();
    assert_eq!(CacheMetrics::get(&engine.cache.metrics.decompositions), 1);
    assert!(CacheMetrics::get(&engine.cache.metrics.hits) >= 1);
}

// ---------- scheduler concurrency ----------

#[test]
fn concurrent_scheduler_jobs_share_one_eigendecomposition() {
    let engine = fresh_engine();
    let sched = Scheduler::with_engine(2, SolveOptions::default(), engine.clone());
    // two jobs, same dataset content, different τ — dispatched to two
    // workers that race to set up the same basis
    let mut rng = Rng::new(6);
    let dataset = synth::sine_hetero(30, &mut rng);
    let kernel = Kernel::Rbf { sigma: 0.4 };
    let jobs = vec![
        FitJob {
            id: 1,
            dataset: dataset.clone(),
            kernel: kernel.clone(),
            spec: JobSpec::Kqr { tau: 0.25, lambda: 0.05 },
        },
        FitJob {
            id: 2,
            dataset: dataset.clone(),
            kernel: kernel.clone(),
            spec: JobSpec::Kqr { tau: 0.75, lambda: 0.05 },
        },
    ];
    let rx = sched.submit_batch(jobs);
    for _ in 0..2 {
        let (_, res) = rx.recv().unwrap();
        res.unwrap();
    }
    sched.shutdown();
    assert_eq!(
        CacheMetrics::get(&engine.cache.metrics.decompositions),
        1,
        "two scheduler jobs on one dataset must trigger exactly one eigendecomposition"
    );
    assert_eq!(CacheMetrics::get(&engine.cache.metrics.requests), 2);
}

// ---------- error paths ----------

#[test]
fn fold_assignment_is_fallible_not_panicking() {
    let mut rng = Rng::new(7);
    assert!(fold_assignment(8, 1, &mut rng).is_err());
    assert!(fold_assignment(8, 9, &mut rng).is_err());
    let ok = fold_assignment(8, 4, &mut rng).unwrap();
    assert_eq!(ok.len(), 8);
}
