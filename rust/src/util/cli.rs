//! Tiny CLI argument parser (clap substitute, substrate).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Unknown flags are collected so subcommands can validate their own set.
//!
//! Two families of numeric accessors:
//!
//! - `get_*(name, default)` — lenient: absent **or malformed** values fall
//!   back to the default. Only appropriate where a wrong value cannot
//!   silently change results (e.g. bench repetition counts).
//! - `try_*(name, default)` — strict: absent falls back to the default,
//!   but a present-and-malformed value is a hard error. Use these for
//!   anything statistical (σ, τ, λ, fold counts): `--sigma 0.5x`
//!   silently becoming some default bandwidth is a wrong-model bug, not a
//!   convenience.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Strict f64 option: default when absent, error when malformed.
    pub fn try_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected a number, got {v:?}")),
        }
    }

    /// Strict usize option: default when absent, error when malformed.
    pub fn try_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected a non-negative integer, got {v:?}")),
        }
    }

    /// Strict comma-separated f64 list: default when absent, error when
    /// any entry is malformed (the lenient [`Args::get_f64_list`] silently
    /// drops bad entries — fine for bench sweeps, wrong for τ grids).
    pub fn try_f64_list(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(s) => {
                let mut out = Vec::new();
                for t in s.split(',') {
                    let t = t.trim();
                    match t.parse() {
                        Ok(v) => out.push(v),
                        Err(_) => bail!("--{name}: expected a number, got {t:?} in {s:?}"),
                    }
                }
                if out.is_empty() {
                    bail!("--{name}: empty list");
                }
                Ok(out)
            }
        }
    }

    /// Comma-separated f64 list option.
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Vec<f64> {
        match self.get(name) {
            Some(s) => s.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }

    /// Comma-separated usize list option.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            Some(s) => s.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn mixed_forms() {
        // Convention: a bare `--name` consumes the following token as its
        // value unless that token starts with `--`; boolean flags therefore
        // go last or use `--flag=`-style. Harnesses follow this rule.
        let a = parse(&["fit", "data.csv", "--n", "100", "--tau=0.5", "--verbose"]);
        assert_eq!(a.positional, vec!["fit", "data.csv"]);
        assert_eq!(a.get("n"), Some("100"));
        assert_eq!(a.get_f64("tau", 0.0), 0.5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_str("mode", "native"), "native");
    }

    #[test]
    fn negative_number_values() {
        // "--shift -3" : -3 does not start with --, so it's the value
        let a = parse(&["--shift", "-3"]);
        assert_eq!(a.get_f64("shift", 0.0), -3.0);
    }

    #[test]
    fn lists() {
        let a = parse(&["--taus", "0.1,0.5,0.9", "--sizes", "64, 128"]);
        assert_eq!(a.get_f64_list("taus", &[]), vec![0.1, 0.5, 0.9]);
        assert_eq!(a.get_usize_list("sizes", &[]), vec![64, 128]);
        assert_eq!(a.get_f64_list("missing", &[1.0]), vec![1.0]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--paper"]);
        assert!(a.flag("paper"));
    }

    #[test]
    fn strict_parsers_error_on_malformed_values() {
        let a = parse(&["--sigma", "0.5x", "--tau", "0.3", "--folds", "five"]);
        assert!(a.try_f64("sigma", 1.0).is_err(), "malformed --sigma must not default");
        assert_eq!(a.try_f64("tau", 0.5).unwrap(), 0.3);
        assert_eq!(a.try_f64("missing", 0.7).unwrap(), 0.7);
        assert!(a.try_usize("folds", 5).is_err());
        let b = parse(&["--taus", "0.1,oops,0.9"]);
        assert!(b.try_f64_list("taus", &[0.5]).is_err(), "bad list entry must error");
        assert_eq!(b.try_f64_list("other", &[0.5]).unwrap(), vec![0.5]);
        let c = parse(&["--taus", "0.1, 0.9"]);
        assert_eq!(c.try_f64_list("taus", &[]).unwrap(), vec![0.1, 0.9]);
    }
}
