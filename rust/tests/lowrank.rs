//! End-to-end tests of the low-rank (Nyström) compute path: exactness
//! ladder at m = n, compressed O(m) artifacts, cache coexistence,
//! lockstep-on-thin-basis parity and the no-n×n-allocation accounting.

use fastkqr::api::{FitSpec, KernelSpec, QuantileModel};
use fastkqr::data::{synth, Rng};
use fastkqr::engine::{ApproxSpec, CacheMetrics, EngineConfig, FitEngine};
use fastkqr::kernel::Kernel;
use fastkqr::kqr::SolveOptions;
use fastkqr::linalg::Parallelism;
use fastkqr::nckqr::NcOptions;

fn temp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "fastkqr-lowrank-{tag}-{}-{}.json",
        std::process::id(),
        std::time::SystemTime::now().duration_since(std::time::UNIX_EPOCH).unwrap().as_nanos()
    ))
}

fn fixture(n: usize, seed: u64) -> (fastkqr::data::Dataset, Kernel) {
    let mut rng = Rng::new(seed);
    let data = synth::sine_hetero(n, &mut rng);
    (data, Kernel::Rbf { sigma: 0.5 })
}

/// Tight options so both the exact and the m = n Nyström solve follow
/// the same trajectory to (numerically) the same minimizer: the
/// remaining gap is then the K̃ − K factorization noise, not solver
/// slack, and certificate decisions sit far from their thresholds.
fn tight_opts() -> SolveOptions {
    SolveOptions {
        apgd_tol: 1e-8,
        kkt_tol: 1e-4,
        max_iters: 100_000,
        ..SolveOptions::default()
    }
}

/// Nyström exactness ladder (KQR): the objective gap shrinks with m and
/// at m = n the approximate fit reproduces the exact one to ≤ 1e-8.
#[test]
fn nystrom_ladder_kqr_m_equals_n_matches_exact() {
    let n = 40;
    let (data, kernel) = fixture(n, 41);
    let engine = FitEngine::with_config(EngineConfig {
        par: Parallelism::serial(),
        opts: tight_opts(),
        ..EngineConfig::default()
    });
    let exact = engine
        .solver_with_options(&data.x, &data.y, &kernel, tight_opts())
        .unwrap()
        .fit(0.5, 2e-2)
        .unwrap();
    let mut prev_gap = f64::INFINITY;
    for m in [10usize, 20, 40] {
        let ny = ApproxSpec::Nystrom { m, seed: 7 };
        let solver =
            engine.solver_approx(&data.x, &data.y, &kernel, ny, tight_opts()).unwrap();
        let fit = solver.fit(0.5, 2e-2).unwrap();
        let gap = (fit.objective - exact.objective).abs();
        assert!(gap <= prev_gap + 1e-9, "objective gap must shrink: m={m} {gap} vs {prev_gap}");
        prev_gap = gap;
        if m == n {
            assert!(
                gap <= 1e-8 * (1.0 + exact.objective.abs()),
                "m=n objective gap {gap} (exact {})",
                exact.objective
            );
            let pe = exact.predict(&data.x);
            let pl = fit.predict(&data.x);
            let sup = pe
                .iter()
                .zip(&pl)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            assert!(sup < 1e-6, "m=n prediction sup-gap {sup}");
        }
    }
}

/// Nyström exactness at m = n for the simultaneous non-crossing solver.
#[test]
fn nystrom_m_equals_n_matches_exact_nckqr() {
    let n = 28;
    let (data, kernel) = fixture(n, 43);
    let taus = [0.3, 0.7];
    let opts =
        NcOptions { mm_tol: 1e-8, kkt_tol: 1e-3, max_iters: 200_000, ..NcOptions::default() };
    let engine = FitEngine::with_config(EngineConfig {
        par: Parallelism::serial(),
        ..EngineConfig::default()
    });
    let exact = engine
        .nc_solver_with_options(&data.x, &data.y, &kernel, &taus, opts.clone())
        .unwrap()
        .fit(1.0, 0.05)
        .unwrap();
    let approx = engine
        .nc_solver_approx_with_options(
            &data.x,
            &data.y,
            &kernel,
            &taus,
            ApproxSpec::Nystrom { m: n, seed: 9 },
            opts,
        )
        .unwrap()
        .fit(1.0, 0.05)
        .unwrap();
    let gap = (approx.objective - exact.objective).abs();
    assert!(
        gap <= 1e-8 * (1.0 + exact.objective.abs()),
        "m=n NCKQR objective gap {gap} (exact {})",
        exact.objective
    );
    assert!(approx.lowrank.is_some(), "NCKQR low-rank fit carries the compressed predictor");
    let pe = exact.predict(&data.x);
    let pl = approx.predict(&data.x);
    for (re, rl) in pe.iter().zip(&pl) {
        let sup =
            re.iter().zip(rl).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
        assert!(sup < 1e-6, "m=n NCKQR prediction sup-gap {sup}");
    }
}

/// A low-rank grid model persists as an O(m) compressed artifact (no
/// x_train, no n-dim α), reloads, and predicts bitwise.
#[test]
fn lowrank_artifact_is_compressed_and_roundtrips_bitwise() {
    let (data, kernel) = fixture(36, 45);
    let m = 12;
    let spec = FitSpec::grid(
        data.x.clone(),
        data.y.clone(),
        KernelSpec::exact(&kernel),
        vec![0.25, 0.75],
        vec![0.1, 0.01],
    )
    .with_approx(ApproxSpec::Nystrom { m, seed: 3 });
    let engine = FitEngine::new();
    let model = engine.run(&spec).unwrap();
    let doc = model.to_artifact().unwrap();
    assert_eq!(doc.get_usize("format_version"), Some(2));
    assert_eq!(doc.get_str("repr"), Some("lowrank"));
    assert!(doc.get("x_train").is_none(), "compressed artifact must not carry x_train");
    assert_eq!(doc.get("z").unwrap().as_arr().unwrap().len(), m);
    assert_eq!(doc.get_usize("n_train"), Some(36));
    for fit in doc.get("fits").unwrap().as_arr().unwrap() {
        assert!(fit.get("alpha").is_none(), "compressed fits store w, not alpha");
        assert_eq!(fit.get_f64_arr("w").unwrap().len(), m);
    }
    // it really is smaller than the dense artifact of the same task
    let dense = engine.run(&spec.clone().with_approx(ApproxSpec::Exact)).unwrap();
    let dense_len = dense.to_artifact().unwrap().to_string().len();
    let lowrank_len = doc.to_string().len();
    assert!(
        lowrank_len < dense_len,
        "lowrank artifact ({lowrank_len} bytes) should undercut dense ({dense_len} bytes)"
    );
    // save → load → predict bitwise
    let path = temp_path("grid");
    model.save(&path).unwrap();
    let back = QuantileModel::load(&path).unwrap();
    let mut rng = Rng::new(46);
    let xt = synth::sine_hetero(9, &mut rng).x;
    assert_eq!(back.predict(&xt), model.predict(&xt), "reload must predict bitwise");
    assert_eq!(back.n_train(), 36);
    assert_eq!(back.n_levels(), 4);
    let _ = std::fs::remove_file(&path);
}

/// One dataset, exact + approx entries: both live in the cache at once,
/// rerunning either costs zero further factorizations, and identical
/// seeds reproduce identical low-rank fits bitwise.
#[test]
fn cache_coexistence_and_seed_reproducibility() {
    let (data, kernel) = fixture(30, 47);
    let kspec = KernelSpec::exact(&kernel);
    let exact_spec = FitSpec::single(data.x.clone(), data.y.clone(), kspec.clone(), 0.5, 0.05);
    let ny_spec = exact_spec.clone().with_approx(ApproxSpec::Nystrom { m: 10, seed: 21 });
    let engine = FitEngine::new();
    let a1 = engine.run(&exact_spec).unwrap();
    let b1 = engine.run(&ny_spec).unwrap();
    assert_eq!(CacheMetrics::get(&engine.cache.metrics.decompositions), 2);
    assert_eq!(engine.cache.len(), 2, "exact and approx coexist without eviction thrash");
    let a2 = engine.run(&exact_spec).unwrap();
    let b2 = engine.run(&ny_spec).unwrap();
    assert_eq!(
        CacheMetrics::get(&engine.cache.metrics.decompositions),
        2,
        "reruns are pure cache hits"
    );
    let mut rng = Rng::new(48);
    let xt = synth::sine_hetero(7, &mut rng).x;
    assert_eq!(a1.predict(&xt), a2.predict(&xt));
    assert_eq!(b1.predict(&xt), b2.predict(&xt), "same seed ⇒ bitwise-identical low-rank fit");
    // a fresh engine (fresh landmark sampling from the same seed) agrees
    let engine2 = FitEngine::new();
    let b3 = engine2.run(&ny_spec).unwrap();
    assert_eq!(
        b1.predict(&xt),
        b3.predict(&xt),
        "spec document alone reproduces the low-rank fit"
    );
}

/// The BLAS-3 lockstep grid driver on a thin basis matches the sequential
/// low-rank path to ≤ 1e-10 (same contract as the dense parity suite).
#[test]
fn lockstep_grid_matches_sequential_on_lowrank_basis() {
    let (data, kernel) = fixture(40, 49);
    let taus = [0.25, 0.75];
    let lambdas = [0.1, 0.01];
    let approx = ApproxSpec::Nystrom { m: 16, seed: 5 };
    let seq_e = FitEngine::with_config(EngineConfig {
        par: Parallelism::serial(),
        lockstep: Some(false),
        ..EngineConfig::default()
    });
    let lock_e = FitEngine::with_config(EngineConfig {
        par: Parallelism::serial(),
        lockstep: Some(true),
        ..EngineConfig::default()
    });
    let seq = seq_e
        .fit_grid_with_strategy(&data.x, &data.y, &kernel, &taus, &lambdas, approx, None, None)
        .unwrap();
    let lock = lock_e
        .fit_grid_with_strategy(&data.x, &data.y, &kernel, &taus, &lambdas, approx, None, None)
        .unwrap();
    assert!(lock.lockstep.is_some() && seq.lockstep.is_none());
    for ti in 0..taus.len() {
        for li in 0..lambdas.len() {
            let (a, b) = (seq.at(ti, li), lock.at(ti, li));
            assert_eq!(a.apgd_iters, b.apgd_iters, "({ti},{li}) iteration trajectory");
            assert!((a.b - b.b).abs() <= 1e-10, "({ti},{li}) intercept");
            let sup = a
                .alpha
                .iter()
                .zip(&b.alpha)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f64, f64::max);
            assert!(sup <= 1e-10, "({ti},{li}) alpha sup {sup}");
            let (wa, wb) = (
                a.lowrank.as_ref().expect("seq lowrank").w.clone(),
                b.lowrank.as_ref().expect("lock lowrank").w.clone(),
            );
            let wsup =
                wa.iter().zip(&wb).map(|(x, y)| (x - y).abs()).fold(0.0f64, f64::max);
            assert!(wsup <= 1e-10, "({ti},{li}) landmark-weight sup {wsup}");
        }
    }
}

/// n = 4096-scale accounting: the approx path holds O(n·m) state — no
/// n×n matrix anywhere — and a grid fits end-to-end on it.
#[test]
fn no_dense_allocation_on_approx_path_at_4096() {
    let n = 4096;
    let m = 64;
    let (data, kernel) = fixture(n, 51);
    // Loose accounting-oriented options: this test bounds memory, not
    // certificate quality (projection off ⇒ no large K_SS solves).
    let opts = SolveOptions {
        apgd_tol: 1e-2,
        kkt_tol: 1e-2,
        max_iters: 500,
        max_expansions: 3,
        max_stall_rungs: 1,
        projection: false,
        ..SolveOptions::default()
    };
    let engine = FitEngine::with_config(EngineConfig {
        par: Parallelism::serial(),
        opts: opts.clone(),
        ..EngineConfig::default()
    });
    let solver = engine
        .solver_approx(&data.x, &data.y, &kernel, ApproxSpec::Nystrom { m, seed: 13 }, opts.clone())
        .unwrap();
    assert!(solver.repr.is_low_rank());
    let r = solver.basis.dim();
    assert!(r <= m && r > 0);
    assert_eq!(solver.basis.u.rows(), n);
    assert_eq!(solver.basis.u.cols(), r, "thin factor, no zero-padding to n×n");
    let floats = solver.repr.memory_floats();
    assert!(
        floats < n * n / 16,
        "approx repr holds {floats} f64s — must be far below n² = {}",
        n * n
    );
    assert!(floats >= n * r, "sanity: the thin factor itself is accounted");
    // the full grid machinery runs on the thin basis
    let grid = engine
        .fit_grid_with_strategy(
            &data.x,
            &data.y,
            &kernel,
            &[0.25, 0.75],
            &[0.1, 0.01],
            ApproxSpec::Nystrom { m, seed: 13 },
            Some(false),
            Some(opts),
        )
        .unwrap();
    assert_eq!(grid.fits.len(), 2);
    for col in &grid.fits {
        for fit in col {
            assert!(fit.objective.is_finite());
            let lr = fit.lowrank.as_ref().expect("compressed predictor attached");
            assert_eq!(lr.w.len(), m);
        }
    }
    assert_eq!(
        CacheMetrics::get(&engine.cache.metrics.decompositions),
        1,
        "one thin factorization serves the whole grid"
    );
}

/// A low-rank model predicts the same through the engine task pipeline
/// and through a saved artifact in a "fresh process" (new load).
#[test]
fn lowrank_kqr_artifact_single_fit_roundtrip() {
    let (data, kernel) = fixture(32, 53);
    let spec =
        FitSpec::single(data.x.clone(), data.y.clone(), KernelSpec::exact(&kernel), 0.3, 0.02)
            .with_approx(ApproxSpec::Nystrom { m: 8, seed: 2 });
    let model = FitEngine::new().run(&spec).unwrap();
    let doc = model.to_artifact().unwrap();
    assert_eq!(doc.get_str("kind"), Some("kqr"));
    assert_eq!(doc.get_str("repr"), Some("lowrank"));
    let path = temp_path("kqr");
    model.save(&path).unwrap();
    let back = QuantileModel::load(&path).unwrap();
    assert_eq!(back.predict(&data.x), model.predict(&data.x));
    assert_eq!(back.taus(), vec![0.3]);
    assert_eq!(back.n_train(), 32);
    let _ = std::fs::remove_file(&path);
}
