//! Table 5 (supplement): KQR on the benchmark-data lookalikes.
use fastkqr::experiments::{kqr_tables, print_table, speedups, TableConfig};
use fastkqr::util::Args;

fn main() {
    let args = Args::from_env();
    let cfg = TableConfig::from_args(&args);
    let cap = if args.flag("paper") { None } else { Some(args.get_usize("cap", 120)) };
    let cells = kqr_tables::table5(&cfg, cap).expect("table5");
    print_table("Table 5 — benchmark data (KQR)", &cells, &cfg.solvers);
    for (label, n, solver, factor) in speedups(&cells) {
        println!("speedup {label} n={n}: {factor:.1}x vs {solver}");
    }
}
