//! Loss functions of the paper.
//!
//! - `rho_tau`: the quantile check loss ρ_τ(t) = t(τ − 1{t<0}).
//! - `h_gamma`: the γ-smoothed check loss H_{γ,τ} (paper eq. 3); the key
//!   identities 0 ≤ H − ρ ≤ γ/4 (Lemma 8) and H' Lipschitz with constant
//!   1/(2γ) power the finite smoothing algorithm.
//! - `smooth_relu`: the η-smoothed ReLU V used as the soft non-crossing
//!   penalty (§3.1), with V(0)=η/4 absorbed as in the paper's definition.

/// Quantile check loss ρ_τ(t) = t(τ − I(t < 0)).
#[inline]
pub fn rho_tau(t: f64, tau: f64) -> f64 {
    if t < 0.0 {
        (tau - 1.0) * t
    } else {
        tau * t
    }
}

/// γ-smoothed check loss H_{γ,τ}(t), paper eq. (3).
#[inline]
pub fn h_gamma(t: f64, tau: f64, gamma: f64) -> f64 {
    debug_assert!(gamma > 0.0);
    if t < -gamma {
        (tau - 1.0) * t
    } else if t > gamma {
        tau * t
    } else {
        t * t / (4.0 * gamma) + t * (tau - 0.5) + gamma / 4.0
    }
}

/// Derivative H'_{γ,τ}(t): (τ−1) / (t/(2γ)+τ−1/2) / τ on the three pieces.
#[inline]
pub fn h_gamma_prime(t: f64, tau: f64, gamma: f64) -> f64 {
    if t < -gamma {
        tau - 1.0
    } else if t > gamma {
        tau
    } else {
        t / (2.0 * gamma) + tau - 0.5
    }
}

/// Subgradient interval of ρ_τ at t: [lo, hi] (singleton off zero).
#[inline]
pub fn rho_subgradient(t: f64, tau: f64, tol: f64) -> (f64, f64) {
    if t > tol {
        (tau, tau)
    } else if t < -tol {
        (tau - 1.0, tau - 1.0)
    } else {
        (tau - 1.0, tau)
    }
}

/// η-smoothed ReLU V(t) (§3.1): 0 / quadratic blend / t.
#[inline]
pub fn smooth_relu(t: f64, eta: f64) -> f64 {
    debug_assert!(eta > 0.0);
    if t < -eta {
        0.0
    } else if t > eta {
        t
    } else {
        t * t / (4.0 * eta) + t / 2.0 + eta / 4.0
    }
}

/// V'(t): 0 / t/(2η)+1/2 / 1.
#[inline]
pub fn smooth_relu_prime(t: f64, eta: f64) -> f64 {
    if t < -eta {
        0.0
    } else if t > eta {
        1.0
    } else {
        t / (2.0 * eta) + 0.5
    }
}

/// Mean pinball (check) loss — the CV scoring metric for quantile models.
pub fn pinball_loss(y: &[f64], pred: &[f64], tau: f64) -> f64 {
    assert_eq!(y.len(), pred.len());
    let s: f64 = y.iter().zip(pred).map(|(yi, pi)| rho_tau(yi - pi, tau)).sum();
    s / y.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    const TAUS: [f64; 5] = [0.1, 0.3, 0.5, 0.7, 0.9];
    const GAMMAS: [f64; 4] = [1.0, 0.25, 1e-2, 1e-5];

    #[test]
    fn check_loss_basics() {
        assert_eq!(rho_tau(2.0, 0.3), 0.6);
        assert_eq!(rho_tau(-2.0, 0.3), 1.4);
        assert_eq!(rho_tau(0.0, 0.3), 0.0);
    }

    #[test]
    fn h_is_continuous_and_c1_at_knots() {
        for &tau in &TAUS {
            for &g in &GAMMAS {
                for &knot in &[-g, g] {
                    let eps = g * 1e-9;
                    let left = h_gamma(knot - eps, tau, g);
                    let right = h_gamma(knot + eps, tau, g);
                    assert!((left - right).abs() < 1e-7 * (1.0 + left.abs()));
                    let dl = h_gamma_prime(knot - eps, tau, g);
                    let dr = h_gamma_prime(knot + eps, tau, g);
                    assert!((dl - dr).abs() < 1e-6);
                }
            }
        }
    }

    #[test]
    fn lemma8_sandwich_0_le_h_minus_rho_le_quarter_gamma() {
        for &tau in &TAUS {
            for &g in &GAMMAS {
                for i in -400..=400 {
                    let t = i as f64 * (3.0 * g / 400.0);
                    let diff = h_gamma(t, tau, g) - rho_tau(t, tau);
                    assert!(
                        diff >= -1e-15 && diff <= g / 4.0 + 1e-15,
                        "tau={tau} g={g} t={t} diff={diff}"
                    );
                }
            }
        }
    }

    #[test]
    fn h_prime_lipschitz_half_inv_gamma() {
        for &tau in &TAUS {
            let g = 0.3;
            let pts: Vec<f64> = (-60..=60).map(|i| i as f64 * 0.02).collect();
            for w in pts.windows(2) {
                let d = (h_gamma_prime(w[1], tau, g) - h_gamma_prime(w[0], tau, g)).abs();
                assert!(d <= (w[1] - w[0]).abs() / (2.0 * g) + 1e-12);
            }
        }
    }

    #[test]
    fn h_prime_matches_subgradient_outside_band() {
        for &tau in &TAUS {
            let g = 0.1;
            assert_eq!(h_gamma_prime(-0.2, tau, g), tau - 1.0);
            assert_eq!(h_gamma_prime(0.2, tau, g), tau);
            // midpoint value lies inside the subgradient interval at 0
            let mid = h_gamma_prime(0.0, tau, g);
            assert!((mid - (tau - 0.5)).abs() < 1e-15);
            let (lo, hi) = rho_subgradient(0.0, tau, 1e-12);
            assert!(mid >= lo && mid <= hi);
        }
    }

    #[test]
    fn smooth_relu_properties() {
        let eta = 1e-3;
        assert_eq!(smooth_relu(-1.0, eta), 0.0);
        assert!((smooth_relu(1.0, eta) - 1.0).abs() < 1e-15);
        // value at 0 is eta/4 (the paper's blend), nonneg, nondecreasing
        assert!((smooth_relu(0.0, eta) - eta / 4.0).abs() < 1e-18);
        let mut prev = 0.0;
        for i in -20..=20 {
            let t = i as f64 * eta / 5.0;
            let v = smooth_relu(t, eta);
            assert!(v >= prev - 1e-18);
            prev = v;
        }
        // derivative in [0,1], continuous at knots
        for i in -20..=20 {
            let t = i as f64 * eta / 5.0;
            let d = smooth_relu_prime(t, eta);
            assert!((0.0..=1.0).contains(&d));
        }
    }

    #[test]
    fn pinball_matches_hand_value() {
        let y = [1.0, 2.0];
        let p = [0.0, 3.0];
        // rho_{0.5}: 0.5*1 + 0.5*1 = 1.0 => mean 0.5
        assert!((pinball_loss(&y, &p, 0.5) - 0.5).abs() < 1e-15);
    }
}
