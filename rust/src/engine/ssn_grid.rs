//! The shared-factorization SSN grid driver.
//!
//! The sequential SSN grid path (`solver::fit_tau_columns_ssn_carry`)
//! already reuses Newton machinery *along* the warm-start chain: the
//! converged active set and its Cholesky factor flow down each λ column
//! and across τ column heads. This driver additionally exploits the
//! *width* of the warm-start wavefront, the way [`super::lockstep`]
//! does for APGD:
//!
//! - **Batched BLAS-3 glue.** Every in-flight cell's n×dim products go
//!   through grid-wide GEMMs instead of per-cell GEMVs: the Wη refresh
//!   rows as `F = Q·Uᵀ` ([`gemm_nt_into`]), the gradient contractions as
//!   `UᵀS = S·U` ([`gemm_nn_into`]), and the line-search direction
//!   images as `Δ = D·Uᵀ`. U is streamed once per bundle round, not
//!   once per cell per round.
//! - **Shared factorizations.** Cells that need a fresh Newton factor in
//!   the same round are pooled by exact (λ, σ); one **leader** per pool
//!   refactorizes, members whose active set coincides with the leader's
//!   solve their Newton systems off the leader's factor with per-cell
//!   RHS ([`Cholesky::solve_many`]) and adopt a clone for continuation,
//!   and members within [`ssn::swing_cap`] Hamming distance adopt a
//!   clone reconciled by rank-1 up/downdates. Only members beyond the
//!   cap (or hit by a downdate failure) pay their own refactorization.
//! - **Wavefront scheduling.** Identical admission graph to the lockstep
//!   driver and the sequential carry columns: (t, l+1) seeds from
//!   (t, l)'s final state — multipliers, σ, *and* carried factor — and
//!   each column head seeds the next column's head.
//!
//! Within each cell the pALM state machine is the one in
//! [`ssn::fit_warm_from_stats_carried`], decision for decision: the same
//! σ/tolerance ladders, Armijo search, tiny-step and stall exits, and
//! the same exact KKT certificate. Factor *sharing* can perturb last
//! bits relative to the sequential path (an adopted factor is the same
//! matrix up to rounding), so the parity bar against the per-cell
//! oracle is ≤ 1e-8 on objectives — pinned down in
//! `rust/tests/solver_ssn.rs` — rather than the bitwise bar the APGD
//! lockstep driver clears.

use super::FitEngine;
use crate::kqr::apgd::{self, ApgdWorkspace};
use crate::kqr::kkt::{kkt_check, KktReport};
use crate::kqr::{KqrFit, KqrSolver};
use crate::linalg::{amax, gemm_nn_into, gemm_nt_into, par, Cholesky, Matrix};
use crate::solver::ssn::{
    self, assemble_gradient, jacobian_column, line_search, refactor, refresh_from_f,
    seed_factor, swing_cap, FactorCarry, SsnState, Workspace, INNER_TOL_FLOOR, MAX_NEWTON,
    MAX_OUTER, MAX_STALL, SIGMA_GROWTH, SIGMA_INIT, SIGMA_MAX, TAU_P,
};
use crate::solver::SsnGridStats;
use anyhow::{bail, Result};

/// Driver-wide context shared by every cell.
struct Ctx<'a> {
    solver: &'a KqrSolver,
    n: usize,
    dim: usize,
    /// √λ_j of the spectral basis (the W column scales).
    sqrt_lam: Vec<f64>,
    /// `opts.kkt_band · max(1, ‖y‖∞)`.
    band: f64,
    kkt_tol: f64,
}

/// Where a cell stands inside the current bundle round.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// ws.f needs the Wη row from the next refresh GEMM.
    Refresh,
    /// Refreshed; needs the Uᵀs row, then a Newton direction.
    Gradient,
    /// Direction solved; needs the Δ row, then the Armijo search.
    Direction,
    /// Fit emitted; waiting to retire at the end of the round.
    Done,
}

/// One in-flight grid cell: coordinates, pALM state, scratch, and the
/// flattened inner/outer loop counters of `ssn::fit_impl`.
struct Cell {
    ti: usize,
    li: usize,
    tau: f64,
    lam: f64,
    state: SsnState,
    ws: Workspace,
    /// Prox center (b̄, η̄) of the current inner solve.
    center: (f64, Vec<f64>),
    /// Outer rounds completed.
    outer: usize,
    /// Inner gradient tolerance of the current outer round.
    tol: f64,
    /// Newton-loop bodies entered this inner solve (the MAX_NEWTON cap).
    iters_this_inner: usize,
    /// Step just applied, pending its post-refresh tiny-step check.
    pending_step: Option<(f64, f64)>,
    /// Live Newton factor and the active set it embeds.
    chol: Option<Cholesky>,
    prev_active: Vec<bool>,
    /// ∇ψᵀd of the current direction (Armijo slope).
    gd: f64,
    /// Best outer iterate: (score, b, η, report, objective).
    best: Option<(f64, f64, Vec<f64>, KktReport, f64)>,
    prev_obj: f64,
    stall: usize,
    newton_total: usize,
    phase: Phase,
    finished: Option<KqrFit>,
}

impl Cell {
    /// Mirror of `ssn::fit_impl`'s entry: σ floor, multiplier clamp into
    /// the new τ box, prox center at the seed iterate.
    fn admit(ctx: &Ctx<'_>, tau: f64, lam: f64, ti: usize, li: usize, mut state: SsnState) -> Cell {
        if state.sigma <= 0.0 {
            state.sigma = SIGMA_INIT;
        }
        state.retarget(tau);
        if state.sigma <= 0.0 {
            state.sigma = SIGMA_INIT;
        }
        let center = (state.b, state.eta.clone());
        Cell {
            ti,
            li,
            tau,
            lam,
            state,
            ws: Workspace::new(ctx.n, ctx.dim),
            center,
            outer: 0,
            tol: inner_tol(0),
            iters_this_inner: 0,
            pending_step: None,
            chol: None,
            prev_active: Vec::new(),
            gd: 0.0,
            best: None,
            prev_obj: f64::INFINITY,
            stall: 0,
            newton_total: 0,
            phase: Phase::Refresh,
            finished: None,
        }
    }
}

/// The outer tolerance ladder of `ssn::fit_impl`.
fn inner_tol(outer: usize) -> f64 {
    (1e-2 * 0.1f64.powi(outer as i32)).max(INNER_TOL_FLOOR)
}

/// Fit the whole τ×λ grid with bundled SSN. Returns fits indexed
/// `[tau][lambda]` plus grid-level factor-reuse accounting.
pub(crate) fn fit_grid_ssn_bundled(
    engine: &FitEngine,
    solver: &KqrSolver,
    taus: &[f64],
    lambdas: &[f64],
) -> Result<(Vec<Vec<KqrFit>>, SsnGridStats)> {
    for &tau in taus {
        if !(0.0 < tau && tau < 1.0) {
            bail!("tau must be in (0,1), got {tau}");
        }
    }
    for &lam in lambdas {
        if lam <= 0.0 {
            bail!("lambda must be positive, got {lam}");
        }
    }
    let n = solver.n();
    let ctx = Ctx {
        solver,
        n,
        dim: solver.basis.dim(),
        sqrt_lam: solver.basis.lambda.iter().map(|l| l.max(0.0).sqrt()).collect(),
        band: solver.opts.kkt_band * amax(&solver.y).max(1.0),
        kkt_tol: solver.opts.kkt_tol,
    };
    // Batched GEMMs take an explicit worker count; all per-cell glue runs
    // inside a serial scope, exactly like the APGD lockstep driver.
    let workers = engine.config.par.workers_for(n);
    par::serial_scope(|| drive(&ctx, taus, lambdas, workers))
}

fn drive(
    ctx: &Ctx<'_>,
    taus: &[f64],
    lambdas: &[f64],
    workers: usize,
) -> Result<(Vec<Vec<KqrFit>>, SsnGridStats)> {
    let (t_count, l_count) = (taus.len(), lambdas.len());
    let mut results: Vec<Vec<Option<KqrFit>>> =
        (0..t_count).map(|_| (0..l_count).map(|_| None).collect()).collect();
    let mut stats = SsnGridStats::default();
    let mut apgd_ws = ApgdWorkspace::for_basis(&ctx.solver.basis);
    let mut pending: Vec<(usize, usize, SsnState)> =
        vec![(0, 0, SsnState::zeros(ctx.n, ctx.dim))];
    let mut active: Vec<Cell> = Vec::new();
    while !pending.is_empty() || !active.is_empty() {
        for (ti, li, seed) in pending.drain(..) {
            active.push(Cell::admit(ctx, taus[ti], lambdas[li], ti, li, seed));
        }

        // --- refresh: one GEMM fills every pending cell's Wη rows ---
        let refresh_idx: Vec<usize> =
            (0..active.len()).filter(|&i| active[i].phase == Phase::Refresh).collect();
        if !refresh_idx.is_empty() {
            let mut q = Matrix::zeros(refresh_idx.len(), ctx.dim);
            for (r, &i) in refresh_idx.iter().enumerate() {
                let row = q.row_mut(r);
                for (qv, (sl, e)) in
                    row.iter_mut().zip(ctx.sqrt_lam.iter().zip(&active[i].state.eta))
                {
                    *qv = sl * e;
                }
            }
            let mut fm = Matrix::zeros(refresh_idx.len(), ctx.n);
            gemm_nt_into(&q, &ctx.solver.basis.u, &mut fm, workers);
            for (r, &i) in refresh_idx.iter().enumerate() {
                let cell = &mut active[i];
                cell.ws.f.copy_from_slice(fm.row(r));
                refresh_from_f(
                    ctx.solver,
                    cell.state.b,
                    &cell.state.w,
                    cell.state.sigma,
                    cell.tau,
                    &mut cell.ws,
                );
                if let Some((t, step_inf)) = cell.pending_step.take() {
                    let scale = 1.0
                        + cell
                            .state
                            .eta
                            .iter()
                            .fold(cell.state.b.abs(), |a, e| a.max(e.abs()));
                    if t * step_inf <= 1e-15 * scale || cell.iters_this_inner >= MAX_NEWTON {
                        outer_bookkeeping(cell, ctx, &mut apgd_ws, &mut stats);
                        continue;
                    }
                }
                cell.phase = Phase::Gradient;
            }
        }

        // --- gradient: one GEMM contracts every cell's Uᵀs ---
        let grad_idx: Vec<usize> =
            (0..active.len()).filter(|&i| active[i].phase == Phase::Gradient).collect();
        let mut need_dir: Vec<usize> = Vec::new();
        if !grad_idx.is_empty() {
            let mut sm = Matrix::zeros(grad_idx.len(), ctx.n);
            for (r, &i) in grad_idx.iter().enumerate() {
                sm.row_mut(r).copy_from_slice(&active[i].ws.s);
            }
            let mut uts = Matrix::zeros(grad_idx.len(), ctx.dim);
            gemm_nn_into(&sm, &ctx.solver.basis.u, &mut uts, workers);
            for (r, &i) in grad_idx.iter().enumerate() {
                let cell = &mut active[i];
                cell.ws.uts.copy_from_slice(uts.row(r));
                cell.iters_this_inner += 1;
                let gmax = assemble_gradient(
                    &ctx.sqrt_lam,
                    cell.lam,
                    cell.state.sigma,
                    (cell.center.0, &cell.center.1),
                    cell.state.b,
                    &cell.state.eta,
                    &mut cell.ws,
                );
                if gmax <= cell.tol {
                    outer_bookkeeping(cell, ctx, &mut apgd_ws, &mut stats);
                } else {
                    need_dir.push(i);
                }
            }
        }

        // --- factor maintenance, pooling and Newton solves ---
        resolve_directions(ctx, &mut active, &need_dir, &mut stats)?;

        // --- Armijo: one GEMM builds every direction image Δ ---
        let dir_idx: Vec<usize> =
            (0..active.len()).filter(|&i| active[i].phase == Phase::Direction).collect();
        if !dir_idx.is_empty() {
            let mut dm = Matrix::zeros(dir_idx.len(), ctx.dim);
            for (r, &i) in dir_idx.iter().enumerate() {
                let row = dm.row_mut(r);
                for (dv, (sl, d)) in
                    row.iter_mut().zip(ctx.sqrt_lam.iter().zip(&active[i].ws.dir[1..]))
                {
                    *dv = sl * d;
                }
            }
            let mut delta = Matrix::zeros(dir_idx.len(), ctx.n);
            gemm_nt_into(&dm, &ctx.solver.basis.u, &mut delta, workers);
            for (r, &i) in dir_idx.iter().enumerate() {
                let cell = &mut active[i];
                let d0 = cell.ws.dir[0];
                for (dv, src) in cell.ws.delta.iter_mut().zip(delta.row(r)) {
                    *dv = src + d0;
                }
                let step = line_search(
                    ctx.solver,
                    cell.lam,
                    cell.tau,
                    cell.state.sigma,
                    (cell.center.0, &cell.center.1),
                    cell.state.b,
                    &cell.state.eta,
                    cell.gd,
                    &cell.ws,
                );
                match step {
                    // numerically flat — inner convergence
                    None => outer_bookkeeping(cell, ctx, &mut apgd_ws, &mut stats),
                    Some(t) => {
                        cell.state.b += t * cell.ws.dir[0];
                        for j in 0..ctx.dim {
                            cell.state.eta[j] += t * cell.ws.dir[j + 1];
                        }
                        cell.newton_total += 1;
                        stats.newton_steps += 1;
                        let step_inf =
                            cell.ws.dir.iter().fold(0.0f64, |a, d| a.max(d.abs()));
                        cell.pending_step = Some((t, step_inf));
                        cell.phase = Phase::Refresh;
                    }
                }
            }
        }

        // --- retire finished cells; successors inherit the full state ---
        let mut i = 0;
        while i < active.len() {
            if active[i].phase != Phase::Done {
                i += 1;
                continue;
            }
            let cell = active.swap_remove(i);
            stats.cells += 1;
            if cell.li + 1 < l_count {
                pending.push((cell.ti, cell.li + 1, cell.state.clone()));
            }
            if cell.li == 0 && cell.ti + 1 < t_count {
                pending.push((cell.ti + 1, 0, cell.state.clone()));
            }
            results[cell.ti][cell.li] = Some(cell.finished.expect("Done cell carries its fit"));
        }
    }
    let fits: Vec<Vec<KqrFit>> = results
        .into_iter()
        .map(|col| col.into_iter().map(|f| f.expect("every grid cell fitted")).collect())
        .collect();
    Ok((fits, stats))
}

/// Give every cell in `need_dir` a valid Newton factor and direction.
///
/// Order of preference per cell: rank-1 maintenance of its own live
/// factor (small active-set swings), seeding from its carried
/// [`FactorCarry`], then the shared pool — cells grouped by exact
/// (λ, σ); the pool leader refactorizes once, exact-active-set members
/// solve off the leader's factor in one [`Cholesky::solve_many`] batch
/// and adopt clones, near members adopt rank-1-reconciled clones.
fn resolve_directions(
    ctx: &Ctx<'_>,
    active: &mut [Cell],
    need_dir: &[usize],
    stats: &mut SsnGridStats,
) -> Result<()> {
    let cap = swing_cap(ctx.dim);
    let mut pool: Vec<usize> = Vec::new();
    let mut dir_done = vec![false; active.len()];
    for &i in need_dir {
        let cell = &mut active[i];
        let mut factored = false;
        if let Some(f) = cell.chol.as_mut() {
            let changed: Vec<(usize, bool)> = cell
                .prev_active
                .iter()
                .zip(cell.ws.active.iter())
                .enumerate()
                .filter(|(_, (p, c))| p != c)
                .map(|(idx, (_, c))| (idx, *c))
                .collect();
            if changed.len() <= cap {
                let mut ok = true;
                for &(idx, entered) in &changed {
                    let mut x =
                        jacobian_column(ctx.solver, &ctx.sqrt_lam, cell.state.sigma, idx);
                    if entered {
                        f.update(&mut x);
                    } else if f.downdate(&mut x).is_err() {
                        ok = false;
                        break;
                    }
                    stats.rank1_updates += 1;
                }
                factored = ok;
            }
        }
        if !factored && cell.chol.is_none() {
            if let Some(fc) = cell.state.factor.take() {
                let mut upd = 0usize;
                if let Some(c) = seed_factor(
                    ctx.solver,
                    &ctx.sqrt_lam,
                    cell.lam,
                    cell.state.sigma,
                    fc,
                    &cell.ws.active,
                    &mut upd,
                ) {
                    cell.chol = Some(c);
                    stats.carried_seeds += 1;
                    factored = true;
                }
                stats.rank1_updates += upd;
            }
        }
        if !factored {
            // a partially-downdated or oversized factor is dead weight
            cell.chol = None;
            pool.push(i);
        }
    }

    // Pool cells by exact (λ, σ): their Hessians differ only in active
    // sets, so one leader factor can serve the whole group.
    let mut groups: Vec<(u64, u64, Vec<usize>)> = Vec::new();
    for &i in &pool {
        let key = (active[i].lam.to_bits(), active[i].state.sigma.to_bits());
        match groups.iter_mut().find(|(l, s, _)| (*l, *s) == key) {
            Some((_, _, g)) => g.push(i),
            None => groups.push((key.0, key.1, vec![i])),
        }
    }
    for (_, _, group) in &groups {
        let leader = group[0];
        let lchol = refactor(
            ctx.solver,
            &ctx.sqrt_lam,
            active[leader].lam,
            active[leader].state.sigma,
            TAU_P,
            &active[leader].ws.active,
        )?;
        stats.refactorizations += 1;
        if group.len() > 1 {
            stats.bundles += 1;
        }
        let lactive = active[leader].ws.active.clone();
        let sigma = active[leader].state.sigma;
        let mut exact: Vec<usize> = vec![leader];
        for &m in &group[1..] {
            let diff: Vec<usize> = lactive
                .iter()
                .zip(active[m].ws.active.iter())
                .enumerate()
                .filter(|(_, (l, c))| l != c)
                .map(|(idx, _)| idx)
                .collect();
            if diff.is_empty() {
                exact.push(m);
                continue;
            }
            let mut adopted = false;
            if diff.len() <= cap {
                let mut c = lchol.clone();
                let mut ok = true;
                for &idx in &diff {
                    let entered = active[m].ws.active[idx];
                    let mut x = jacobian_column(ctx.solver, &ctx.sqrt_lam, sigma, idx);
                    if entered {
                        c.update(&mut x);
                    } else if c.downdate(&mut x).is_err() {
                        ok = false;
                        break;
                    }
                    stats.rank1_updates += 1;
                }
                if ok {
                    active[m].chol = Some(c);
                    stats.bundle_adoptions += 1;
                    adopted = true;
                }
            }
            if !adopted {
                active[m].chol = Some(refactor(
                    ctx.solver,
                    &ctx.sqrt_lam,
                    active[m].lam,
                    sigma,
                    TAU_P,
                    &active[m].ws.active,
                )?);
                stats.refactorizations += 1;
            }
        }
        // Exact members: per-cell RHS, one factor, one batched solve.
        if exact.len() > 1 {
            let mut rhs = Matrix::zeros(exact.len(), ctx.dim + 1);
            for (r, &m) in exact.iter().enumerate() {
                for (dst, g) in rhs.row_mut(r).iter_mut().zip(&active[m].ws.grad) {
                    *dst = -g;
                }
            }
            let sols = lchol.solve_many(&rhs);
            for (r, &m) in exact.iter().enumerate() {
                active[m].ws.dir.copy_from_slice(sols.row(r));
                dir_done[m] = true;
            }
            for &m in &exact[1..] {
                active[m].chol = Some(lchol.clone());
                stats.bundle_adoptions += 1;
            }
        }
        active[leader].chol = Some(lchol);
    }

    // Every need_dir cell now has a factor; solve the stragglers and do
    // the common per-direction bookkeeping.
    for &i in need_dir {
        let cell = &mut active[i];
        if !dir_done[i] {
            let neg: Vec<f64> = cell.ws.grad.iter().map(|g| -g).collect();
            let d = cell.chol.as_ref().expect("factor present").solve(&neg);
            cell.ws.dir.copy_from_slice(&d);
        }
        cell.gd = cell.ws.grad.iter().zip(&cell.ws.dir).map(|(g, d)| g * d).sum();
        cell.prev_active.clear();
        cell.prev_active.extend_from_slice(&cell.ws.active);
        cell.phase = Phase::Direction;
    }
    Ok(())
}

/// End-of-inner-solve bookkeeping, mirroring `ssn::fit_impl`'s outer
/// loop body after `inner_solve` returns: park the factor in the carry
/// slot, update multipliers, certify, track the best iterate, then
/// either emit the fit or escalate σ into the next inner solve.
fn outer_bookkeeping(
    cell: &mut Cell,
    ctx: &Ctx<'_>,
    apgd_ws: &mut ApgdWorkspace,
    stats: &mut SsnGridStats,
) {
    if let Some(c) = cell.chol.take() {
        cell.state.factor = Some(FactorCarry {
            chol: c,
            active: std::mem::take(&mut cell.prev_active),
            lam: cell.lam,
            sigma: cell.state.sigma,
        });
    }
    for (wi, si) in cell.state.w.iter_mut().zip(&cell.ws.s) {
        *wi = -cell.state.sigma * si;
    }
    let basis = &ctx.solver.basis;
    let y = &ctx.solver.y;
    let mut beta = vec![0.0; ctx.dim];
    for j in 0..ctx.dim {
        beta[j] = if ctx.sqrt_lam[j] > 0.0 { cell.state.eta[j] / ctx.sqrt_lam[j] } else { 0.0 };
    }
    let report = kkt_check(
        basis,
        y,
        cell.tau,
        cell.lam,
        cell.state.b,
        &beta,
        ctx.kkt_tol,
        ctx.band,
    );
    let obj = apgd::exact_objective(basis, cell.lam, y, cell.tau, cell.state.b, &beta, apgd_ws);
    let score = report.score();
    let improved = cell.best.as_ref().map(|(s, ..)| score < *s).unwrap_or(true);
    if improved {
        cell.best = Some((score, cell.state.b, cell.state.eta.clone(), report.clone(), obj));
    }
    let plateau = (cell.prev_obj - obj).abs() <= 1e-11 * (1.0 + obj.abs());
    cell.prev_obj = obj;
    let mut finish = false;
    if report.pass {
        if cell.tol <= INNER_TOL_FLOOR && plateau {
            finish = true;
        } else {
            cell.stall = if improved { 0 } else { cell.stall + 1 };
            if cell.stall >= MAX_STALL {
                finish = true;
            }
        }
    }
    stats.outer_rounds += 1;
    cell.outer += 1;
    if !finish {
        cell.state.sigma = (cell.state.sigma * SIGMA_GROWTH).min(SIGMA_MAX);
        if cell.outer >= MAX_OUTER {
            finish = true;
        }
    }
    if finish {
        cell.finished = Some(finish_cell(cell, ctx));
        cell.phase = Phase::Done;
    } else {
        cell.tol = inner_tol(cell.outer);
        cell.center = (cell.state.b, cell.state.eta.clone());
        cell.iters_this_inner = 0;
        cell.pending_step = None;
        cell.phase = Phase::Refresh;
    }
}

/// Emit the fit from the best outer iterate (the `ssn::fit_impl` return
/// path). `cell.state` keeps the *last* iterate — including the carried
/// factor — so λ-path and column-head successors warm-start exactly as
/// the sequential carry columns do.
fn finish_cell(cell: &mut Cell, ctx: &Ctx<'_>) -> KqrFit {
    let (_, best_b, best_eta, kkt, objective) =
        cell.best.take().expect("ssn bundle: at least one outer round ran");
    let basis = &ctx.solver.basis;
    let y = &ctx.solver.y;
    let mut beta = vec![0.0; ctx.dim];
    for j in 0..ctx.dim {
        beta[j] = if ctx.sqrt_lam[j] > 0.0 { best_eta[j] / ctx.sqrt_lam[j] } else { 0.0 };
    }
    let mut fitted = vec![0.0; ctx.n];
    basis.fitted(best_b, &beta, &mut cell.ws.scratch, &mut fitted);
    let singular_set: Vec<usize> =
        (0..ctx.n).filter(|&i| (y[i] - fitted[i]).abs() <= ctx.band).collect();
    let alpha = basis.alpha_from_beta(&beta);
    let lowrank = ctx.solver.repr.low_rank().map(|f| f.coef(&beta));
    let rff = ctx.solver.repr.rff().map(|f| f.coef(&beta));
    KqrFit::assemble(
        cell.tau,
        cell.lam,
        best_b,
        alpha,
        objective,
        kkt,
        0.0,
        cell.newton_total,
        cell.outer,
        singular_set,
        lowrank,
        rff,
        ctx.solver.x.clone(),
        ctx.solver.kernel.clone(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, Rng};
    use crate::engine::EngineConfig;
    use crate::kernel::{median_heuristic_sigma, Kernel};
    use crate::linalg::par::Parallelism;
    use crate::solver::fit_tau_columns_ssn_stats;

    fn fixture(n: usize, seed: u64) -> (crate::data::Dataset, Kernel) {
        let mut rng = Rng::new(seed);
        let data = synth::sine_hetero(n, &mut rng);
        let sigma = median_heuristic_sigma(&data.x);
        (data, Kernel::Rbf { sigma })
    }

    #[test]
    fn bundled_grid_matches_per_cell_oracle() {
        let engine = FitEngine::with_config(EngineConfig {
            par: Parallelism::serial(),
            ..EngineConfig::default()
        });
        let (data, kernel) = fixture(30, 11);
        let taus = [0.25, 0.5, 0.75];
        let lambdas = [0.1, 0.05, 0.02, 0.01];
        let solver = engine.solver(&data.x, &data.y, &kernel).unwrap();
        let (oracle, ostats) = fit_tau_columns_ssn_stats(&solver, &taus, &lambdas).unwrap();
        let (bundled, bstats) =
            fit_grid_ssn_bundled(&engine, &solver, &taus, &lambdas).unwrap();
        assert_eq!(bstats.cells, taus.len() * lambdas.len());
        assert_eq!(bstats.cells, ostats.cells);
        for ti in 0..taus.len() {
            for li in 0..lambdas.len() {
                let (o, b) = (&oracle[ti][li], &bundled[ti][li]);
                assert!(b.kkt.pass, "({ti},{li}): {:?}", b.kkt);
                let gap = (o.objective - b.objective).abs();
                assert!(
                    gap <= 1e-8 * (1.0 + o.objective.abs()),
                    "({ti},{li}): oracle {} vs bundled {} (gap {gap:.3e})",
                    o.objective,
                    b.objective
                );
            }
        }
        assert!(
            bstats.refactorizations < ostats.refactorizations,
            "bundle refactors {} not below oracle {}",
            bstats.refactorizations,
            ostats.refactorizations
        );
        assert!(bstats.rank1_updates > 0, "bundle did no rank-1 factor work");
        assert!(bstats.carried_seeds > 0, "bundle never seeded from a carry");
    }

    #[test]
    fn bundled_grid_validates_axes() {
        let engine = FitEngine::new();
        let (data, kernel) = fixture(12, 3);
        let solver = engine.solver(&data.x, &data.y, &kernel).unwrap();
        assert!(fit_grid_ssn_bundled(&engine, &solver, &[0.0], &[0.1]).is_err());
        assert!(fit_grid_ssn_bundled(&engine, &solver, &[0.5], &[-1.0]).is_err());
    }
}
