//! Tables 2 and 6: NCKQR — fastkqr vs cvxr(proximal) vs nlm(L-BFGS on the
//! stacked smoothed objective) vs optim(Nelder–Mead, tiny cap).
//!
//! Protocol (paper §4.2): fit T = 3 levels (0.1, 0.5, 0.9) simultaneously
//! across a descending λ₂ grid at fixed λ₁; report the total wall time
//! and the objective of problem (12) at the smallest λ₂ of the grid.

use super::{CellResult, TableConfig};
use crate::baselines::proximal::solve_nckqr_proximal;
use crate::baselines::{lbfgs::lbfgs_minimize, neldermead::nelder_mead_minimize};
use crate::data::{benchmarks, synth, Dataset, Rng};
use crate::kernel::{median_heuristic_sigma, Kernel};
use crate::linalg::{dot, gemv, Matrix};
use crate::nckqr::{NckqrSolver, ETA_EXACT};
use crate::smooth::{h_gamma, h_gamma_prime, smooth_relu, smooth_relu_prime};
use crate::util::bench::mean_sd;
use crate::util::Timer;
use anyhow::Result;

/// Smoothed NCKQR objective + gradient on the stacked parameter vector
/// [b₁, α₁, b₂, α₂, …] — the structure-blind parametrization `nlm`/`optim`
/// would see.
pub fn nc_stacked_fg(
    gram: &Matrix,
    y: &[f64],
    taus: &[f64],
    lam1: f64,
    lam2: f64,
    x: &[f64],
    grad: &mut [f64],
) -> f64 {
    let n = y.len();
    let nf = n as f64;
    let t_lv = taus.len();
    let stride = n + 1;
    let gamma = ETA_EXACT;
    let eta = ETA_EXACT;
    // fitted values per level
    let mut fs = vec![vec![0.0; n]; t_lv];
    let mut kas = vec![vec![0.0; n]; t_lv];
    for t in 0..t_lv {
        let b = x[t * stride];
        let alpha = &x[t * stride + 1..(t + 1) * stride];
        gemv(gram, alpha, &mut kas[t]);
        for i in 0..n {
            fs[t][i] = b + kas[t][i];
        }
    }
    let mut obj = 0.0;
    grad.fill(0.0);
    for t in 0..t_lv {
        let alpha = &x[t * stride + 1..(t + 1) * stride];
        // loss + ridge
        let mut carrier = vec![0.0; n];
        for i in 0..n {
            let r = y[i] - fs[t][i];
            obj += h_gamma(r, taus[t], gamma) / nf;
            carrier[i] = -h_gamma_prime(r, taus[t], gamma) / nf;
        }
        obj += 0.5 * lam2 * dot(alpha, &kas[t]);
        // crossing penalty (pair t, t+1)
        if t + 1 < t_lv {
            for i in 0..n {
                let d = fs[t][i] - fs[t + 1][i];
                obj += lam1 * smooth_relu(d, eta);
            }
        }
        // gradient carrier including penalty terms
        for i in 0..n {
            let fwd = if t + 1 < t_lv {
                smooth_relu_prime(fs[t][i] - fs[t + 1][i], eta)
            } else {
                0.0
            };
            let bwd = if t > 0 {
                smooth_relu_prime(fs[t - 1][i] - fs[t][i], eta)
            } else {
                0.0
            };
            carrier[i] += lam1 * (fwd - bwd);
        }
        grad[t * stride] = carrier.iter().sum();
        let mut w = carrier;
        for i in 0..n {
            w[i] += lam2 * alpha[i];
        }
        gemv(gram, &w, &mut grad[t * stride + 1..(t + 1) * stride]);
    }
    obj
}

/// Exact objective of problem (12) on the stacked vector.
fn nc_exact_objective(
    gram: &Matrix,
    y: &[f64],
    taus: &[f64],
    lam1: f64,
    lam2: f64,
    x: &[f64],
) -> f64 {
    let n = y.len();
    let nf = n as f64;
    let t_lv = taus.len();
    let stride = n + 1;
    let mut fs = vec![vec![0.0; n]; t_lv];
    let mut obj = 0.0;
    for t in 0..t_lv {
        let b = x[t * stride];
        let alpha = &x[t * stride + 1..(t + 1) * stride];
        let mut ka = vec![0.0; n];
        gemv(gram, alpha, &mut ka);
        obj += 0.5 * lam2 * dot(alpha, &ka);
        for i in 0..n {
            fs[t][i] = b + ka[i];
            obj += crate::smooth::rho_tau(y[i] - fs[t][i], taus[t]) / nf;
        }
    }
    for t in 0..t_lv.saturating_sub(1) {
        for i in 0..n {
            obj += lam1 * smooth_relu(fs[t][i] - fs[t + 1][i], ETA_EXACT);
        }
    }
    obj
}

fn run_nc_solver(
    solver: &str,
    data: &Dataset,
    kernel: &Kernel,
    taus: &[f64],
    lam1: f64,
    lam2s: &[f64],
) -> Result<f64> {
    match solver {
        "fastkqr" => {
            let s = NckqrSolver::new(&data.x, &data.y, kernel.clone(), taus)?;
            let fits = s.fit_path(lam1, lam2s)?;
            Ok(fits.last().unwrap().objective)
        }
        "proximal" => {
            let gram = kernel.gram(&data.x);
            let mut last = f64::NAN;
            for &l2 in lam2s {
                let fit =
                    solve_nckqr_proximal(&gram, &data.y, taus, lam1, l2, 60_000, 1e-6)?;
                last = fit.objective;
            }
            Ok(last)
        }
        "lbfgs" => {
            let gram = kernel.gram(&data.x);
            let n = data.n();
            let dim = taus.len() * (n + 1);
            let mut last = f64::NAN;
            for &l2 in lam2s {
                let (x, _, _) = lbfgs_minimize(
                    vec![0.0; dim],
                    |x, g| nc_stacked_fg(&gram, &data.y, taus, lam1, l2, x, g),
                    1500,
                    1e-7,
                );
                last = nc_exact_objective(&gram, &data.y, taus, lam1, l2, &x);
            }
            Ok(last)
        }
        "neldermead" => {
            let gram = kernel.gram(&data.x);
            let n = data.n();
            let dim = taus.len() * (n + 1);
            let mut gscratch = vec![0.0; dim];
            let mut last = f64::NAN;
            for &l2 in lam2s {
                let (x, _, _) = nelder_mead_minimize(
                    vec![0.0; dim],
                    |x| nc_stacked_fg(&gram, &data.y, taus, lam1, l2, x, &mut gscratch),
                    3000,
                    1e-10,
                );
                last = nc_exact_objective(&gram, &data.y, taus, lam1, l2, &x);
            }
            Ok(last)
        }
        other => anyhow::bail!("unknown NC solver {other:?}"),
    }
}

/// Generic NCKQR table engine.
pub fn nckqr_table(
    cfg: &TableConfig,
    lam1: f64,
    mut generate: impl FnMut(usize, &mut Rng) -> Dataset,
) -> Result<Vec<CellResult>> {
    let taus = [0.1, 0.5, 0.9];
    let mut cells = Vec::new();
    let lam2s: Vec<f64> = (0..cfg.nlam)
        .map(|i| 0.5 * (1e-3f64 / 0.5).powf(i as f64 / (cfg.nlam.max(2) - 1) as f64))
        .collect();
    for &n in &cfg.ns {
        for solver in &cfg.solvers {
            let mut objs = Vec::new();
            let mut total_time = 0.0;
            for rep in 0..cfg.reps {
                let mut rng = Rng::new(cfg.seed + 31 * rep as u64 + n as u64);
                let data = generate(n, &mut rng);
                let sigma = median_heuristic_sigma(&data.x);
                let kernel = Kernel::Rbf { sigma };
                let timer = Timer::start(solver);
                let obj = run_nc_solver(solver, &data, &kernel, &taus, lam1, &lam2s)?;
                total_time += timer.total();
                objs.push(obj);
            }
            let (m, sd) = mean_sd(&objs);
            cells.push(CellResult {
                solver: solver.clone(),
                label: format!("p={}", cfg.p),
                n,
                obj_mean: m,
                obj_sd: sd,
                time_s: total_time,
            });
        }
    }
    Ok(cells)
}

/// Table 2: NCKQR on the Friedman design, p ∈ {100, 1000, 5000}.
pub fn table2(cfg: &TableConfig, lam1: f64) -> Result<Vec<CellResult>> {
    let p = cfg.p;
    nckqr_table(cfg, lam1, move |n, rng| synth::friedman(n, p, 3.0, rng))
}

/// Table 6: NCKQR on the benchmark lookalikes, five τ levels.
pub fn table6(cfg: &TableConfig, lam1: f64, subsample: Option<usize>) -> Result<Vec<CellResult>> {
    let taus = [0.1, 0.3, 0.5, 0.7, 0.9];
    let mut cells = Vec::new();
    let lam2s: Vec<f64> = (0..cfg.nlam)
        .map(|i| 0.5 * (1e-3f64 / 0.5).powf(i as f64 / (cfg.nlam.max(2) - 1) as f64))
        .collect();
    for ds_id in 0..4usize {
        for solver in &cfg.solvers {
            let mut objs = Vec::new();
            let mut total_time = 0.0;
            let mut used_n = 0;
            let mut label = String::new();
            for rep in 0..cfg.reps {
                let seed = cfg.seed + rep as u64;
                let mut data = match ds_id {
                    0 => benchmarks::crabs(seed),
                    1 => benchmarks::gagurine(seed),
                    2 => benchmarks::mcycle(seed),
                    _ => benchmarks::boston_housing(seed),
                };
                let mut rng = Rng::new(seed ^ 0xbe6f);
                if let Some(cap) = subsample {
                    if data.n() > cap {
                        let idx = rng.permutation(data.n());
                        data = data.subset(&idx[..cap]);
                    }
                }
                data.standardize();
                used_n = data.n();
                label = data.name.split('(').next().unwrap_or("data").to_string();
                let sigma = median_heuristic_sigma(&data.x);
                let kernel = Kernel::Rbf { sigma };
                let timer = Timer::start(solver);
                let obj = run_nc_solver(solver, &data, &kernel, &taus, lam1, &lam2s)?;
                total_time += timer.total();
                objs.push(obj);
            }
            let (m, sd) = mean_sd(&objs);
            cells.push(CellResult {
                solver: solver.clone(),
                label: label.clone(),
                n: used_n,
                obj_mean: m,
                obj_sd: sd,
                time_s: total_time,
            });
        }
    }
    Ok(cells)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stacked_fg_gradient_matches_finite_differences() {
        let mut rng = Rng::new(1);
        let d = synth::sine_hetero(10, &mut rng);
        let gram = Kernel::Rbf { sigma: 0.5 }.gram(&d.x);
        let taus = [0.3, 0.7];
        let dim = 2 * 11;
        let x: Vec<f64> = (0..dim).map(|_| 0.1 * rng.normal()).collect();
        let mut g = vec![0.0; dim];
        let f0 = nc_stacked_fg(&gram, &d.y, &taus, 0.5, 0.1, &x, &mut g);
        assert!(f0.is_finite());
        let eps = 1e-7;
        let mut gfd = vec![0.0; dim];
        let mut scratch = vec![0.0; dim];
        for j in 0..dim {
            let mut xp = x.clone();
            xp[j] += eps;
            let fp = nc_stacked_fg(&gram, &d.y, &taus, 0.5, 0.1, &xp, &mut scratch);
            gfd[j] = (fp - f0) / eps;
        }
        for j in 0..dim {
            assert!(
                (g[j] - gfd[j]).abs() < 1e-4 * (1.0 + g[j].abs()),
                "grad[{j}]: {} vs fd {}",
                g[j],
                gfd[j]
            );
        }
    }

    #[test]
    fn tiny_table2_shape() {
        let cfg = TableConfig {
            ns: vec![24],
            p: 4,
            taus: vec![],
            nlam: 2,
            folds: 2,
            reps: 1,
            solvers: vec!["fastkqr".into(), "proximal".into()],
            seed: 5,
        };
        let cells = table2(&cfg, 1.0).unwrap();
        assert_eq!(cells.len(), 2);
        let fast = cells.iter().find(|c| c.solver == "fastkqr").unwrap();
        let prox = cells.iter().find(|c| c.solver == "proximal").unwrap();
        // exact solver attains an objective <= the generic one (small slack)
        assert!(fast.obj_mean <= prox.obj_mean + 0.02 * (1.0 + prox.obj_mean.abs()));
    }
}
