//! Cross-request predict micro-batching.
//!
//! Concurrent `predict` requests targeting the **same model** inside a
//! small window are coalesced: the first arriving connection thread
//! becomes the *leader* of that model's queue, sleeps for the batch
//! window (`FASTKQR_BATCH_WINDOW_US`, default 200 µs) while followers
//! enqueue their query matrices, then drains the queue, stacks every
//! request's rows into one matrix, runs the compiled
//! [`PredictPlan`](crate::engine::PredictPlan) **once** (one cross-Gram
//! + one multi-RHS GEMM per plan group) and scatters the output columns
//! back to the parked connections. Every returned row is bitwise equal
//! to what the request would have computed alone — see
//! [`crate::engine::predict`] for the argument — so batching is purely a
//! throughput lever, never a numerics one.
//!
//! Backpressure: each per-model queue holds at most
//! `FASTKQR_BATCH_MAX_ROWS` query rows (default 4096). A request that
//! would overflow the cap gets a clean error immediately (counted in
//! [`Metrics::predict_rejects`]), never a hang; followers whose leader
//! dies mid-batch get an error too (the result channel hangs up).
//!
//! With `FASTKQR_BATCH_WINDOW_US=0` batching is disabled and every
//! request executes directly on its own thread (the per-request
//! baseline `benches/serve_throughput.rs` measures against).

use super::metrics::Metrics;
use crate::engine::PredictPlan;
use crate::linalg::Matrix;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;
use std::time::Duration;

/// Micro-batching knobs (see module docs). The server reads them from
/// the environment once at spawn; tests and benches construct explicit
/// configs.
#[derive(Clone, Debug)]
pub struct BatchConfig {
    /// Coalescing window in microseconds; 0 disables batching.
    pub window_us: u64,
    /// Per-model queue cap in query **rows** (backpressure bound).
    pub max_rows: usize,
}

impl Default for BatchConfig {
    fn default() -> BatchConfig {
        BatchConfig { window_us: 200, max_rows: 4096 }
    }
}

impl BatchConfig {
    /// Read `FASTKQR_BATCH_WINDOW_US` / `FASTKQR_BATCH_MAX_ROWS`,
    /// falling back to the defaults (200 µs window, 4096-row cap).
    pub fn from_env() -> BatchConfig {
        let d = BatchConfig::default();
        let parse = |key: &str, default: u64| {
            std::env::var(key)
                .ok()
                .and_then(|v| v.trim().parse::<u64>().ok())
                .unwrap_or(default)
        };
        BatchConfig {
            window_us: parse("FASTKQR_BATCH_WINDOW_US", d.window_us),
            max_rows: parse("FASTKQR_BATCH_MAX_ROWS", d.max_rows as u64).max(1) as usize,
        }
    }
}

/// One parked request: its query rows and the channel its result (or the
/// leader's failure) comes back on.
struct Pending {
    x: Matrix,
    tx: Sender<Result<Vec<Vec<f64>>, String>>,
}

#[derive(Default)]
struct ModelQueue {
    pending: Vec<Pending>,
    rows: usize,
    /// A leader thread is currently inside its window for this queue.
    leader: bool,
}

/// The per-model predict queues (see module docs).
pub struct PredictBatcher {
    queues: Mutex<HashMap<String, ModelQueue>>,
    config: BatchConfig,
}

impl PredictBatcher {
    pub fn new(config: BatchConfig) -> PredictBatcher {
        PredictBatcher { queues: Mutex::new(HashMap::new()), config }
    }

    pub fn config(&self) -> &BatchConfig {
        &self.config
    }

    /// Rows currently parked across all per-model queues (a point-in-time
    /// gauge, surfaced as `predict_queue_rows` by the `metrics` command —
    /// nonzero only while a batch window is open somewhere).
    pub fn queued_rows(&self) -> usize {
        self.queues.lock().unwrap().values().map(|q| q.rows).sum()
    }

    /// Predict `x` on `plan`, coalescing with concurrent requests for
    /// the same `model_id`. Blocks the calling thread for at most one
    /// batch window (plus the batched compute); returns this request's
    /// rows, bitwise equal to `plan.predict(&x)`.
    pub fn predict(
        &self,
        model_id: &str,
        plan: &PredictPlan,
        x: Matrix,
        metrics: &Metrics,
    ) -> Result<Vec<Vec<f64>>> {
        if self.config.window_us == 0 {
            Metrics::incr(&metrics.predict_batches);
            metrics.predict_batch_size.record(1);
            return Ok(plan.predict(&x));
        }
        let n_rows = x.rows();
        let (tx, rx) = channel();
        let leader = {
            let mut queues = self.queues.lock().unwrap();
            let q = queues.entry(model_id.to_string()).or_default();
            if q.rows + n_rows > self.config.max_rows {
                let queued = q.rows;
                drop(queues);
                Metrics::incr(&metrics.predict_rejects);
                bail!(
                    "predict queue for model {model_id:?} is full \
                     ({queued} rows queued, cap {}); retry shortly",
                    self.config.max_rows
                );
            }
            q.pending.push(Pending { x, tx });
            q.rows += n_rows;
            if q.leader {
                false
            } else {
                q.leader = true;
                true
            }
        };
        if leader {
            std::thread::sleep(Duration::from_micros(self.config.window_us));
            let batch = {
                let mut queues = self.queues.lock().unwrap();
                let q = queues.get_mut(model_id).expect("leader's queue exists");
                q.leader = false;
                q.rows = 0;
                let batch = std::mem::take(&mut q.pending);
                // don't leak empty queue entries for dropped models
                queues.remove(model_id);
                batch
            };
            Metrics::incr(&metrics.predict_batches);
            metrics.predict_batch_size.record(batch.len() as u64);
            let (parts, senders): (Vec<Matrix>, Vec<Sender<_>>) =
                batch.into_iter().map(|p| (p.x, p.tx)).unzip();
            // A panic inside the batched compute must surface as an error
            // on every coalesced request, not hang the followers.
            let computed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                plan.predict_many(&parts)
            }));
            match computed {
                Ok(results) => {
                    for (res, tx) in results.into_iter().zip(&senders) {
                        let _ = tx.send(Ok(res));
                    }
                }
                Err(payload) => {
                    let msg = crate::util::panic_message(&payload);
                    for tx in &senders {
                        let _ = tx.send(Err(format!("batched predict failed: {msg}")));
                    }
                }
            }
        }
        match rx.recv() {
            Ok(Ok(rows)) => Ok(rows),
            Ok(Err(msg)) => bail!(msg),
            Err(_) => bail!("predict batch leader hung up without a result"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::QuantileModel;
    use crate::data::{synth, Rng};
    use crate::kernel::Kernel;
    use crate::kqr::KqrSolver;
    use std::sync::Arc;

    fn toy_plan() -> (QuantileModel, PredictPlan) {
        let mut rng = Rng::new(5);
        let d = synth::sine_hetero(20, &mut rng);
        let fit = KqrSolver::new(&d.x, &d.y, Kernel::Rbf { sigma: 0.5 })
            .unwrap()
            .fit(0.5, 0.05)
            .unwrap();
        let model = QuantileModel::Kqr(fit);
        let plan = model.compile_plan();
        (model, plan)
    }

    #[test]
    fn disabled_window_is_the_direct_path() {
        let (model, plan) = toy_plan();
        let batcher = PredictBatcher::new(BatchConfig { window_us: 0, max_rows: 16 });
        let metrics = Metrics::new();
        let xt = Matrix::from_fn(3, 1, |i, _| i as f64 * 0.3);
        let got = batcher.predict("m0", &plan, xt.clone(), &metrics).unwrap();
        assert_eq!(got, model.predict(&xt));
        assert_eq!(Metrics::get(&metrics.predict_batches), 1);
    }

    #[test]
    fn concurrent_requests_coalesce_and_match_bitwise() {
        let (model, plan) = toy_plan();
        let plan = Arc::new(plan);
        let batcher =
            Arc::new(PredictBatcher::new(BatchConfig { window_us: 20_000, max_rows: 4096 }));
        let metrics = Arc::new(Metrics::new());
        let queries: Vec<Matrix> =
            (0..8).map(|i| Matrix::from_fn(1, 1, |_, _| 0.1 * i as f64)).collect();
        let results: Vec<Vec<Vec<f64>>> = std::thread::scope(|s| {
            let handles: Vec<_> = queries
                .iter()
                .map(|q| {
                    let batcher = batcher.clone();
                    let plan = plan.clone();
                    let metrics = metrics.clone();
                    let q = q.clone();
                    s.spawn(move || batcher.predict("m0", &plan, q, &metrics).unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (q, got) in queries.iter().zip(&results) {
            assert_eq!(got, &model.predict(q), "batched row must be bitwise equal");
        }
        let batches = Metrics::get(&metrics.predict_batches);
        assert!(batches >= 1 && batches <= 8, "batches = {batches}");
        // every request was served by exactly one batch
        assert_eq!(metrics.predict_batch_size.count(), batches);
    }

    #[test]
    fn backpressure_rejects_cleanly_without_hanging() {
        let (_, plan) = toy_plan();
        let plan = Arc::new(plan);
        let batcher =
            Arc::new(PredictBatcher::new(BatchConfig { window_us: 500_000, max_rows: 2 }));
        let metrics = Arc::new(Metrics::new());
        let barrier = Arc::new(std::sync::Barrier::new(3));
        let outcomes: Vec<Result<Vec<Vec<f64>>>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..3)
                .map(|i| {
                    let batcher = batcher.clone();
                    let plan = plan.clone();
                    let metrics = metrics.clone();
                    let barrier = barrier.clone();
                    s.spawn(move || {
                        let x = Matrix::from_fn(1, 1, |_, _| 0.2 * i as f64);
                        barrier.wait();
                        batcher.predict("m0", &plan, x, &metrics)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let ok = outcomes.iter().filter(|r| r.is_ok()).count();
        let rejected: Vec<String> =
            outcomes.iter().filter_map(|r| r.as_ref().err().map(|e| e.to_string())).collect();
        assert_eq!(ok, 2, "cap of 2 rows admits exactly 2 single-row requests");
        assert_eq!(rejected.len(), 1);
        assert!(rejected[0].contains("full"), "clean backpressure error: {rejected:?}");
        assert_eq!(Metrics::get(&metrics.predict_rejects), 1);
    }
}
