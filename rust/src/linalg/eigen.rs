//! Symmetric eigendecomposition K = U Λ Uᵀ.
//!
//! fastkqr's spectral technique needs *one* full eigendecomposition of the
//! kernel matrix, reused across the whole (γ, λ, τ) grid. There is no
//! LAPACK in this environment and the HLO interchange path cannot carry
//! `eigh` (jax ≥ 0.5 lowers it to an FFI custom-call the image's
//! xla_extension 0.5.1 does not export), so we implement the classic
//! dense path from scratch:
//!
//!   1. Householder reduction to symmetric tridiagonal form (EISPACK
//!      `tred2`), accumulating the orthogonal transform, and
//!   2. implicit-shift QL iteration with eigenvector accumulation
//!      (EISPACK `tql2`).
//!
//! Cost is O(n³) once; everything downstream is O(n²) per iteration,
//! which is the paper's headline complexity claim.
//!
//! The two dominant O(n³) phases of `tred2` — the symmetric matvec that
//! forms `e = A·v/h` and the symmetric rank-2 update — are row-banded
//! onto scoped threads ([`super::par`]) above a size cutoff. Both phases
//! are restructured so every output element is computed in the *identical*
//! serial accumulation order, so the parallel decomposition is bitwise
//! equal to the serial one at any worker count (`tql2` and the
//! eigenvector back-accumulation stay serial; they see identical inputs).
//! Both phases' inner loops run the `linalg::simd` dispatched kernels
//! (the matvec's contiguous prefix via `blas::dot`, the rank-2 rows via
//! the elementwise `rank2` kernel), which are bitwise-equal to the
//! scalar oracle — so the decomposition is also invariant to ISA tier.

use super::matrix::Matrix;

/// Eigendecomposition of a symmetric matrix.
///
/// `vectors` holds eigenvectors in its *columns*: `a ≈ U diag(values) Uᵀ`
/// with `U = vectors`. Eigenvalues are sorted ascending.
#[derive(Clone, Debug)]
pub struct SymEigen {
    pub values: Vec<f64>,
    pub vectors: Matrix,
}

impl SymEigen {
    /// Decompose a symmetric matrix. Panics if `a` is not square; the
    /// strictly-lower triangle is trusted to mirror the upper one.
    /// Dispatches the O(n³) `tred2` phases onto the global parallel
    /// budget; Householder steps whose working dimension falls below the
    /// substrate's serial cutoff (`FASTKQR_PAR_MIN_DIM`, default 512 —
    /// the same spawn-vs-work calibration the GEMV kernels use) run
    /// serially. Results are bitwise identical either way.
    pub fn new(a: &Matrix) -> SymEigen {
        let par = super::par::global();
        SymEigen::decompose(a, par.workers_for(a.rows()), par.min_dim)
    }

    /// [`SymEigen::new`] with an explicit `tred2` worker count and a low
    /// fixed parallel floor — the parity tests drive serial vs parallel
    /// through this at sizes where the production cutoff would stay
    /// serial.
    pub fn with_workers(a: &Matrix, workers: usize) -> SymEigen {
        SymEigen::decompose(a, workers, TRED2_TEST_PAR_FLOOR)
    }

    fn decompose(a: &Matrix, workers: usize, par_floor: usize) -> SymEigen {
        assert_eq!(a.rows(), a.cols(), "SymEigen: matrix must be square");
        let n = a.rows();
        if n == 0 {
            return SymEigen { values: vec![], vectors: Matrix::zeros(0, 0) };
        }
        let mut z = a.clone(); // becomes the accumulated orthogonal matrix
        let mut d = vec![0.0; n]; // diagonal
        let mut e = vec![0.0; n]; // off-diagonal
        tred2(&mut z, &mut d, &mut e, workers, par_floor);
        tql2(&mut z, &mut d, &mut e);
        sort_ascending(&mut z, &mut d);
        SymEigen { values: d, vectors: z }
    }

    /// Reconstruct U diag(values) Uᵀ (test / debugging helper).
    pub fn reconstruct(&self) -> Matrix {
        let n = self.values.len();
        let u = &self.vectors;
        let mut out = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += u[(i, k)] * self.values[k] * u[(j, k)];
                }
                out[(i, j)] = s;
            }
        }
        out
    }

    /// Largest eigenvalue (values are sorted ascending).
    pub fn max_eigenvalue(&self) -> f64 {
        *self.values.last().unwrap_or(&0.0)
    }
}

/// Parallel floor for [`SymEigen::with_workers`]: low enough that parity
/// tests exercise the banded phases on fast small fixtures. Production
/// decompositions ([`SymEigen::new`]) gate on the substrate's serial
/// cutoff instead — at its spawn-vs-work calibration, an O(l²)
/// Householder phase below ~512 is cheaper serial.
const TRED2_TEST_PAR_FLOOR: usize = 64;

/// Householder reduction of a real symmetric matrix to tridiagonal form,
/// accumulating transformations (EISPACK tred2, as in Numerical Recipes).
///
/// The O(l²) symmetric matvec (`householder_e`) and rank-2 update
/// (`rank2_update`) of each Householder step run on `workers` scoped
/// threads while the working dimension is at least `par_floor` (the
/// reduction's tail always shrinks below it and goes serial); every
/// output element keeps the serial accumulation order, so the reduction
/// is bitwise identical at any worker count.
fn tred2(z: &mut Matrix, d: &mut [f64], e: &mut [f64], workers: usize, par_floor: usize) {
    let n = d.len();
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let scale: f64 = (0..=l).map(|k| z[(i, k)].abs()).sum();
            if scale == 0.0 {
                e[i] = z[(i, l)];
            } else {
                for k in 0..=l {
                    z[(i, k)] /= scale;
                    h += z[(i, k)] * z[(i, k)];
                }
                let mut f = z[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[(i, l)] = f - g;
                // Store v/h in column i (reads only row i, which the two
                // O(l²) phases below never touch — hoisting it out lets
                // them see an immutable working block).
                for j in 0..=l {
                    z[(j, i)] = z[(i, j)] / h;
                }
                // e = A·v / h (symmetric matvec on the lower triangle).
                householder_e(z, e, i, l, h, workers, par_floor);
                f = 0.0;
                for j in 0..=l {
                    f += e[j] * z[(i, j)];
                }
                let hh = f / (h + h);
                // e ← e − hh·v (the original loop folded this into the
                // rank-2 pass; all of e must be final before rows can be
                // updated independently).
                for j in 0..=l {
                    e[j] -= hh * z[(i, j)];
                }
                // A ← A − v·eᵀ − e·vᵀ (lower triangle).
                rank2_update(z, e, i, l, workers, par_floor);
            }
        } else {
            e[i] = z[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += z[(i, k)] * z[(k, j)];
                }
                for k in 0..i {
                    z[(k, j)] -= g * z[(k, i)];
                }
            }
        }
        d[i] = z[(i, i)];
        z[(i, i)] = 1.0;
        for j in 0..i {
            z[(j, i)] = 0.0;
            z[(i, j)] = 0.0;
        }
    }
}

/// Phase 1 of a Householder step: `e[j] = (A·v)ⱼ / h` for `j ∈ 0..=l`,
/// reading the symmetric working block through its lower triangle
/// (`A[j][k] = z[(j,k)]` for `k ≤ j`, `z[(k,j)]` for `k > j`) and the
/// scaled Householder vector in row `i` (`v[k] = z[(i,k)]`). Each e[j]
/// is an independent reduction computed in the identical order at any
/// worker count.
#[allow(clippy::too_many_arguments)]
fn householder_e(
    z: &Matrix,
    e: &mut [f64],
    i: usize,
    l: usize,
    h: f64,
    workers: usize,
    par_floor: usize,
) {
    let compute = |j: usize| -> f64 {
        // contiguous prefix: Σ_{k≤j} z[j,k]·z[i,k]
        let mut g = super::blas::dot(&z.row(j)[..=j], &z.row(i)[..=j]);
        // strided column tail: Σ_{j<k≤l} z[k,j]·z[i,k]
        for k in (j + 1)..=l {
            g += z[(k, j)] * z[(i, k)];
        }
        g / h
    };
    let w = if l + 1 < par_floor.max(2) { 1 } else { workers.max(1).min(l + 1) };
    if w <= 1 {
        for (j, ej) in e[..=l].iter_mut().enumerate() {
            *ej = compute(j);
        }
        return;
    }
    let block = (l + w) / w; // ceil((l+1)/w)
    std::thread::scope(|s| {
        for (bi, chunk) in e[..=l].chunks_mut(block).enumerate() {
            let j0 = bi * block;
            let compute = &compute;
            s.spawn(move || {
                for (r, ej) in chunk.iter_mut().enumerate() {
                    *ej = compute(j0 + r);
                }
            });
        }
    });
}

/// Phase 2 of a Householder step: the symmetric rank-2 update
/// `A[j][k] -= v[j]·e[k] + e[j]·v[k]` on the lower triangle (`k ≤ j ≤ l`),
/// with `v` in row `i = l+1` (untouched here) and `e` fully updated.
/// Rows are independent, so they are distributed in area-balanced bands;
/// each row runs the dispatched elementwise `rank2` kernel
/// (`linalg::simd`), whose per-element arithmetic is identical at any
/// worker count and ISA tier.
fn rank2_update(
    z: &mut Matrix,
    e: &[f64],
    i: usize,
    l: usize,
    workers: usize,
    par_floor: usize,
) {
    let t = super::simd::global();
    let ncols = z.cols();
    let (lower, upper) = z.as_mut_slice().split_at_mut(i * ncols);
    let zi = &upper[..ncols]; // row i: the Householder vector v
    let w = if l + 1 < par_floor.max(2) { 1 } else { workers.max(1).min(l + 1) };
    if w <= 1 {
        for (j, row) in lower.chunks_mut(ncols).enumerate() {
            (t.rank2)(zi[j], &e[..=j], e[j], &zi[..=j], &mut row[..=j]);
        }
        return;
    }
    let bounds = triangle_bounds(l + 1, w);
    std::thread::scope(|s| {
        let mut rest: &mut [f64] = lower;
        let mut row0 = 0usize;
        for hi in bounds.into_iter().skip(1) {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut((hi - row0) * ncols);
            rest = tail;
            let j0 = row0;
            s.spawn(move || {
                for (r, row) in head.chunks_mut(ncols).enumerate() {
                    let j = j0 + r;
                    (t.rank2)(zi[j], &e[..=j], e[j], &zi[..=j], &mut row[..=j]);
                }
            });
            row0 = hi;
        }
    });
}

/// Band boundaries `[0, …, rows]` splitting the lower triangle's rows so
/// every band holds roughly the same number of triangle elements
/// (row j costs j+1).
fn triangle_bounds(rows: usize, bands: usize) -> Vec<usize> {
    let total = (rows * (rows + 1)) as f64 / 2.0;
    let per = total / bands.max(1) as f64;
    let mut bounds = vec![0usize];
    let mut acc = 0.0;
    for j in 0..rows {
        acc += (j + 1) as f64;
        if acc >= per * bounds.len() as f64 && bounds.len() < bands {
            bounds.push(j + 1);
        }
    }
    bounds.push(rows);
    bounds.dedup();
    bounds
}

/// Implicit-shift QL with eigenvector accumulation (EISPACK tql2).
fn tql2(z: &mut Matrix, d: &mut [f64], e: &mut [f64]) {
    let n = d.len();
    if n <= 1 {
        return;
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    // Absolute deflation floor: kernel Gram matrices have large clusters
    // of near-zero eigenvalues where the relative test |e| ≤ ε(|d_m|+|d_m+1|)
    // can never fire (dd ≈ 0). Anything below ε·‖T‖ is a converged zero.
    let anorm = d
        .iter()
        .zip(e.iter())
        .map(|(a, b)| a.abs() + b.abs())
        .fold(0.0f64, f64::max)
        .max(f64::MIN_POSITIVE);
    let floor = f64::EPSILON * anorm;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find small off-diagonal element to split the matrix.
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd || e[m].abs() <= floor {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 100 {
                // Accept the current (ε‖T‖-accurate) values rather than
                // aborting: the unresolved off-diagonal mass is below the
                // deflation floor for any conditioning we can exploit.
                e[m.min(n - 1)] = 0.0;
                break;
            }
            // Form implicit shift.
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = hypot(g, 1.0);
            g = d[m] - d[l] + e[l] / (g + sign(r, g));
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = hypot(f, g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // Accumulate the rotation into the eigenvector matrix.
                for k in 0..n {
                    f = z[(k, i + 1)];
                    z[(k, i + 1)] = s * z[(k, i)] + c * f;
                    z[(k, i)] = c * z[(k, i)] - s * f;
                }
            }
            if r == 0.0 && m > l + 1 {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
}

fn sort_ascending(z: &mut Matrix, d: &mut [f64]) {
    let n = d.len();
    // Selection sort with column swaps (n is moderate; O(n²) swaps are
    // dominated by the O(n³) decomposition anyway).
    for i in 0..n {
        let mut kmin = i;
        for j in (i + 1)..n {
            if d[j] < d[kmin] {
                kmin = j;
            }
        }
        if kmin != i {
            d.swap(i, kmin);
            for r in 0..n {
                let tmp = z[(r, i)];
                z[(r, i)] = z[(r, kmin)];
                z[(r, kmin)] = tmp;
            }
        }
    }
}

#[inline]
fn hypot(a: f64, b: f64) -> f64 {
    a.hypot(b)
}

#[inline]
fn sign(a: f64, b: f64) -> f64 {
    if b >= 0.0 {
        a.abs()
    } else {
        -a.abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn random_sym(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal();
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        a
    }

    fn check_decomposition(a: &Matrix, tol: f64) {
        let eig = SymEigen::new(a);
        // 1) reconstruction
        let rec = eig.reconstruct();
        assert!(
            a.max_abs_diff(&rec) < tol,
            "reconstruction error {} (n={})",
            a.max_abs_diff(&rec),
            a.rows()
        );
        // 2) orthogonality of U
        let n = a.rows();
        let u = &eig.vectors;
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += u[(k, i)] * u[(k, j)];
                }
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((s - expect).abs() < tol, "UᵀU[{i},{j}]={s}");
            }
        }
        // 3) sorted ascending
        for w in eig.values.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
    }

    #[test]
    fn diag_matrix_eigen() {
        let mut a = Matrix::zeros(4, 4);
        for (i, v) in [3.0, -1.0, 2.0, 0.5].iter().enumerate() {
            a[(i, i)] = *v;
        }
        let eig = SymEigen::new(&a);
        let expect = [-1.0, 0.5, 2.0, 3.0];
        for (v, e) in eig.values.iter().zip(expect) {
            assert!((v - e).abs() < 1e-12);
        }
    }

    #[test]
    fn two_by_two_known() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let eig = SymEigen::new(&a);
        assert!((eig.values[0] - 1.0).abs() < 1e-12);
        assert!((eig.values[1] - 3.0).abs() < 1e-12);
        check_decomposition(&a, 1e-10);
    }

    #[test]
    fn random_matrices_various_sizes() {
        for (n, seed) in [(1usize, 1u64), (2, 2), (5, 3), (16, 4), (33, 5), (64, 6)] {
            let a = random_sym(n, seed);
            check_decomposition(&a, 1e-8 * (n as f64).max(1.0));
        }
    }

    #[test]
    fn psd_kernel_like_matrix() {
        // Gram-like matrix: A = B Bᵀ is PSD; eigenvalues must be >= -eps.
        let mut rng = Rng::new(7);
        let b = Matrix::from_fn(20, 8, |_, _| rng.normal());
        let bt = b.transpose();
        let a = crate::linalg::blas::gemm(&b, &bt);
        let eig = SymEigen::new(&a);
        assert!(eig.values[0] > -1e-8, "PSD eigenvalue {}", eig.values[0]);
        // rank <= 8: the first 12 eigenvalues must be ~0
        for k in 0..12 {
            assert!(eig.values[k].abs() < 1e-7);
        }
        check_decomposition(&a, 1e-7);
    }

    #[test]
    fn repeated_eigenvalues() {
        // 3*I has a triple eigenvalue; decomposition must still be orthogonal.
        let mut a = Matrix::eye(5);
        for i in 0..5 {
            a[(i, i)] = 3.0;
        }
        check_decomposition(&a, 1e-12);
    }

    #[test]
    fn parallel_tred2_is_bitwise_serial() {
        // n above TRED2_PAR_MIN so the banded phases actually engage; the
        // restructured phases compute every element in the serial order,
        // so the whole decomposition must be bitwise identical.
        let a = random_sym(160, 9);
        let serial = SymEigen::with_workers(&a, 1);
        for workers in [2usize, 5] {
            let par = SymEigen::with_workers(&a, workers);
            assert_eq!(serial.values, par.values, "workers={workers}");
            assert_eq!(
                serial.vectors.as_slice(),
                par.vectors.as_slice(),
                "workers={workers}"
            );
        }
        check_decomposition(&a, 1e-7 * (a.rows() as f64));
    }

    #[test]
    fn triangle_bounds_cover_all_rows() {
        for (rows, bands) in [(1usize, 1usize), (5, 2), (200, 4), (7, 16)] {
            let b = triangle_bounds(rows, bands);
            assert_eq!(*b.first().unwrap(), 0);
            assert_eq!(*b.last().unwrap(), rows);
            for w in b.windows(2) {
                assert!(w[0] < w[1], "bounds must strictly increase: {b:?}");
            }
        }
    }

    #[test]
    fn empty_and_single() {
        let e = SymEigen::new(&Matrix::zeros(0, 0));
        assert!(e.values.is_empty());
        let a = Matrix::from_vec(1, 1, vec![4.2]);
        let e = SymEigen::new(&a);
        assert!((e.values[0] - 4.2).abs() < 1e-15);
        assert!((e.vectors[(0, 0)].abs() - 1.0).abs() < 1e-15);
    }
}
