"""AOT pipeline: lower the L2 chunk to HLO text artifacts for the L3 coordinator.

HLO *text* (not `.serialize()`) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids, which the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out ../artifacts
Writes  apgd_chunk_n{N}.hlo.txt  per problem size plus manifest.json.
`make artifacts` skips the rebuild if outputs are newer than inputs.
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from .model import AOT_TILE_ROWS, CHUNK, apgd_chunk, chunk_example_args

# Problem sizes to pre-compile. The Rust runtime picks the smallest
# artifact with artifact_n >= n and zero-pads (padding is exact: padded
# eigenvalues/vectors are zero, contributing nothing to any update).
DEFAULT_SIZES = [64, 128, 256, 512, 1024]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_chunk(n: int) -> str:
    lowered = jax.jit(apgd_chunk, static_argnames=("n_iters", "tile_rows")).lower(
        *chunk_example_args(n), n_iters=CHUNK, tile_rows=AOT_TILE_ROWS
    )
    return to_hlo_text(lowered)


def build(out_dir: str, sizes) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "chunk": CHUNK, "artifacts": []}
    for n in sizes:
        text = lower_chunk(n)
        name = f"apgd_chunk_n{n}.hlo.txt"
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {"kind": "apgd_chunk", "n": n, "chunk": CHUNK, "path": name}
        )
        print(f"wrote {path} ({len(text)} chars)")
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath}")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--sizes",
        default=",".join(str(s) for s in DEFAULT_SIZES),
        help="comma-separated problem sizes",
    )
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split(",") if s.strip()]
    build(args.out, sizes)


if __name__ == "__main__":
    main()
