//! Multi-backend solver layer: APGD (finite smoothing) and pALM-SSN as
//! production peers behind one selection knob.
//!
//! The [`crate::kqr`] module owns the paper's finite-smoothing APGD;
//! [`ssn`] adds a preconditioned augmented Lagrangian / semismooth-Newton
//! backend (Deng–Li–Zhang, arXiv 2510.07929). Both certify against the
//! same exact check-loss objective and KKT report, so everything above
//! them — grids, artifacts, the serving path — is backend-agnostic.
//!
//! [`SolverBackend`] is the user-facing knob, threaded through
//! `FitSpec` (`"solver"` field), the CLI (`--solver`) and the wire
//! protocol. `Auto` resolves deterministically per problem through
//! [`auto_select`]: a small cost model over (n, representation rank,
//! grid size) that prefers SSN exactly where its r×r Newton systems
//! crush first-order iteration counts (thin bases, r ≪ n) and APGD
//! where the lockstep driver amortizes large grids.

pub mod ssn;

pub use ssn::{fit_warm_from, fit_warm_from_stats, SsnState, SsnStats};

use crate::kqr::{KqrFit, KqrSolver};
use anyhow::{bail, Result};

/// Which optimizer fits each (τ, λ) cell.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SolverBackend {
    /// The paper's finite-smoothing accelerated proximal gradient
    /// descent (γ ladder + set expansion) — the default, and the only
    /// backend with a lockstep BLAS-3 grid driver.
    #[default]
    Apgd,
    /// pALM semismooth Newton ([`ssn`]): active-set Newton systems of
    /// size (rank+1), strongest on thin bases (Nyström / RFF).
    Ssn,
    /// Resolve per problem via [`auto_select`] — deterministic from the
    /// spec alone (no timing, no environment).
    Auto,
}

impl SolverBackend {
    pub fn as_str(&self) -> &'static str {
        match self {
            SolverBackend::Apgd => "apgd",
            SolverBackend::Ssn => "ssn",
            SolverBackend::Auto => "auto",
        }
    }

    /// Strict name parsing (spec/CLI/protocol share it): unknown values
    /// are rejected, never defaulted.
    pub fn parse(name: &str) -> Result<SolverBackend> {
        match name {
            "apgd" => Ok(SolverBackend::Apgd),
            "ssn" => Ok(SolverBackend::Ssn),
            "auto" => Ok(SolverBackend::Auto),
            other => bail!("unknown solver {other:?} (apgd|ssn|auto)"),
        }
    }
}

impl std::fmt::Display for SolverBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Resolve `Auto` for a problem with `n` observations, spectral rank
/// `rank`, and `cells` (τ, λ) grid cells.
///
/// The model charges each backend its dominant per-cell term, in
/// arbitrary but common units:
///
/// - APGD: iterations × O(n·r) GEMV work ≈ `400·n·r`, halved on grids
///   of ≥ 8 cells where the lockstep bundle driver amortizes the GEMMs;
/// - SSN: a few dozen Newton/refresh passes of O(n·r) plus Newton
///   factorizations of O(r³) ≈ `25·n·r + 8·r³`.
///
/// On a dense basis (r = n) the cubic term makes SSN lose for all but
/// tiny n; on thin bases (r ≪ n) SSN wins outright. The constants are
/// calibration, not measurement — what matters is that the decision is
/// a pure function of the spec, so `Auto` is reproducible anywhere.
pub fn auto_select(n: usize, rank: usize, cells: usize) -> SolverBackend {
    let (nf, rf) = (n as f64, rank.max(1) as f64);
    let mut apgd = 400.0 * nf * rf;
    if cells >= 8 {
        apgd *= 0.5;
    }
    let ssn = 25.0 * nf * rf + 8.0 * rf * rf * rf;
    if ssn < apgd {
        SolverBackend::Ssn
    } else {
        SolverBackend::Apgd
    }
}

/// Fit a run of τ columns with pALM-SSN, seeding each column's
/// largest-λ fit from its predecessor's — the SSN mirror of the
/// engine's sequential APGD driver, with the multipliers and penalty
/// carried alongside the primal in both grid directions.
pub fn fit_tau_columns_ssn(
    solver: &KqrSolver,
    taus: &[f64],
    lambdas: &[f64],
) -> Result<Vec<Vec<KqrFit>>> {
    let mut cols = Vec::with_capacity(taus.len());
    let mut seed: Option<SsnState> = None;
    for &tau in taus {
        let (col, head_state) = fit_tau_column_ssn(solver, tau, lambdas, seed.take())?;
        seed = Some(head_state);
        cols.push(col);
    }
    Ok(cols)
}

/// One warm-started descending-λ SSN column, optionally seeded from an
/// adjacent τ's state. Returns the fits plus the state at the **head**
/// (largest-λ) cell, which seeds the next column exactly like the APGD
/// driver's cross-column `ApgdState` carry.
pub fn fit_tau_column_ssn(
    solver: &KqrSolver,
    tau: f64,
    lambdas: &[f64],
    seed: Option<SsnState>,
) -> Result<(Vec<KqrFit>, SsnState)> {
    let mut state =
        seed.unwrap_or_else(|| SsnState::zeros(solver.n(), solver.basis.dim()));
    let mut fits = Vec::with_capacity(lambdas.len());
    let mut head_state: Option<SsnState> = None;
    for &lam in lambdas {
        let fit = ssn::fit_warm_from(solver, tau, lam, &mut state)?;
        if head_state.is_none() {
            head_state = Some(state.clone());
        }
        fits.push(fit);
    }
    Ok((fits, head_state.expect("at least one lambda")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_names_round_trip() {
        for b in [SolverBackend::Apgd, SolverBackend::Ssn, SolverBackend::Auto] {
            assert_eq!(SolverBackend::parse(b.as_str()).unwrap(), b);
        }
        let err = SolverBackend::parse("newton").unwrap_err().to_string();
        assert!(err.contains("unknown solver") && err.contains("apgd|ssn|auto"), "{err}");
    }

    #[test]
    fn auto_prefers_ssn_on_thin_bases_and_apgd_on_dense() {
        // Nyström r=64 at n=4096: Newton systems are tiny, SSN wins.
        assert_eq!(auto_select(4096, 64, 1), SolverBackend::Ssn);
        // Dense basis at the same n: r³ dominates, APGD wins.
        assert_eq!(auto_select(4096, 4096, 1), SolverBackend::Apgd);
        // Large lockstep-amortized grid keeps APGD competitive longer:
        // r where single-cell SSN would win can flip back on big grids.
        assert_eq!(auto_select(512, 512, 64), SolverBackend::Apgd);
        // Decision is a pure function — repeated calls agree.
        for _ in 0..3 {
            assert_eq!(auto_select(4096, 64, 9), auto_select(4096, 64, 9));
        }
    }

    #[test]
    fn auto_never_returns_auto() {
        for &(n, r, c) in &[(10usize, 10usize, 1usize), (1000, 32, 4), (50, 50, 100)] {
            assert_ne!(auto_select(n, r, c), SolverBackend::Auto);
        }
    }
}
