"""L2: the APGD iteration chunk as a JAX program calling the L1 kernels.

`apgd_chunk` runs CHUNK accelerated APGD iterations of the smoothed KQR
problem in spectral coordinates (the exact recurrence of
`fastkqr::kqr::apgd::run_chunk_native`; see kernels/ref.py for the
specification). It is lowered once per problem size by `aot.py` to HLO
text; the Rust coordinator loads the artifact through PJRT and calls it
on the hot path — Python never runs at fit time.

All tuning parameters (τ, γ, λ) are runtime scalars, so ONE artifact per
n serves the entire (γ, λ, τ) ladder / path / CV grid.
"""

import functools

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels.smoothed_loss import pallas_h_prime
from .kernels.spectral_gemv import pallas_gemv, pallas_gemv_t

# Iterations per compiled chunk. Must match SolveOptions::chunk on the
# Rust side; the manifest records it and XlaBackend asserts agreement.
CHUNK = 25

# Row-tile height used when lowering the AOT artifacts. Perf iteration
# (EXPERIMENTS.md §Perf): the interpret-mode Pallas grid becomes an XLA
# while-loop over tiles, so a taller tile (fewer grid steps) cuts the
# loop overhead dramatically; 64 divides every artifact size.
AOT_TILE_ROWS = 64


@functools.partial(jax.jit, static_argnames=("n_iters", "tile_rows"))
def apgd_chunk(u_mat, lam_diag, pil, p, lam_p, g, y, mask, inv_n, tau, gamma,
               nlam, b, beta, b_prev, beta_prev, ck, n_iters: int = CHUNK,
               tile_rows: int = 8):
    """Run `n_iters` accelerated APGD iterations.

    Args (shapes for the *artifact* size n, which may exceed the real
    problem size — zero-padding is exact under the mask):
      u_mat: (n, n) eigenvectors U (columns; zero-padded rows/cols).
      lam_diag, pil, p, lam_p: (n,) spectral plan vectors (Λ, Π⁻¹Λ, p, Λp;
        padded entries of lam_diag/p/lam_p are zero).
      g: () Schur scalar.
      y: (n,) responses (padding arbitrary); mask: (n,) 1.0 real / 0.0 pad;
      inv_n: () = 1/n_real; tau, gamma, nlam (= n_real·λ): () scalars.
      b, beta, b_prev, beta_prev, ck: APGD state (β padding zero).

    Returns (b, beta, b_prev, beta_prev, ck, conv) where conv is the
    stationarity residual max(‖t‖∞, |Σz|/n_real) of the final iteration.

    Padding exactness: padded U rows are zero so f_pad = b̄; the mask
    zeroes z_pad so Σz and Uᵀz see only real entries; padded β stays zero
    because t_pad = 0 (zero U column, zero initial β) and p_pad = 0.
    """

    def body(_, carry):
        b, beta, b_prev, beta_prev, ck, _conv = carry
        ck_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * ck * ck))
        mom = (ck - 1.0) / ck_next
        b_bar = b + mom * (b - b_prev)
        beta_bar = beta + mom * (beta - beta_prev)
        # GEMV #1 (L1 kernel): fitted values f = b̄ + U(Λβ̄)
        f = b_bar + pallas_gemv(u_mat, lam_diag * beta_bar, tile_rows=tile_rows)
        # L1 kernel: z = H'(y − f), masked to the real entries
        z = pallas_h_prime(y - f, tau, gamma) * mask
        # GEMV #2 (L1 kernel): t = Uᵀz − nλβ̄
        t = pallas_gemv_t(u_mat, z, tile_rows=tile_rows) - nlam * beta_bar
        sum_z = jnp.sum(z)
        vkw = jnp.dot(lam_p, t)
        delta = g * (sum_z - vkw)
        two_g = 2.0 * gamma
        conv = jnp.maximum(jnp.max(jnp.abs(t)), jnp.abs(sum_z) * inv_n)
        return (
            b_bar + two_g * delta,
            beta_bar + two_g * (pil * t - delta * p),
            b,
            beta,
            ck_next,
            conv,
        )

    init = (b, beta, b_prev, beta_prev, ck, jnp.asarray(jnp.inf, dtype=y.dtype))
    out = jax.lax.fori_loop(0, n_iters, body, init)
    return out


def chunk_example_args(n: int):
    """ShapeDtypeStructs for lowering `apgd_chunk` at artifact size n."""
    f64 = jnp.float64
    vec = jax.ShapeDtypeStruct((n,), f64)
    scalar = jax.ShapeDtypeStruct((), f64)
    mat = jax.ShapeDtypeStruct((n, n), f64)
    return (mat, vec, vec, vec, vec, scalar, vec, vec, scalar, scalar,
            scalar, scalar, scalar, vec, scalar, vec, scalar)
