//! L3 coordinator: fit-job scheduling, model registry, metrics and the
//! TCP fit/predict service.
//!
//! The paper ships an R package; a production deployment of the same
//! capability needs a long-lived service that accepts fit jobs, exploits
//! the algorithm's warm-start structure when ordering work, keeps fitted
//! models addressable for prediction, and reports operational metrics.
//! That is what this module provides:
//!
//! - [`job`]: job specs (single fit, warm-started λ path, NCKQR, CV);
//! - [`scheduler`]: a worker pool with warm-start-aware batch ordering;
//!   solver setup — including NCKQR — goes through the shared
//!   [`crate::engine::FitEngine`], so jobs on the same dataset —
//!   adjacent *or concurrent* — reuse one cached eigendecomposition, and
//!   per-worker APGD state warm-starts the λ grid;
//! - [`registry`]: a concurrent [`crate::api::QuantileModel`] store for
//!   the predict path, with optional write-through persistence to
//!   versioned JSON artifacts (the server survives restarts);
//! - [`metrics`]: atomic counters + log-bucketed latency/occupancy
//!   histograms surfaced by the server and CLI;
//! - [`batcher`]: the predict micro-batcher — concurrent `predict`
//!   requests for one model coalesce (inside `FASTKQR_BATCH_WINDOW_US`)
//!   into a single execution of the registry's compiled
//!   [`crate::engine::PredictPlan`], with bitwise-identical rows and a
//!   per-model backpressure cap;
//! - [`server`]/[`protocol`]: the TCP line-JSON service. Protocol v2
//!   accepts full [`crate::api::FitSpec`] documents for `fit`, adds
//!   `save`/`load`/`export` for artifacts, and streams large predict
//!   responses (`"stream": true`) in bounded chunks;
//! - [`eventloop`]: the event-driven connection layer — a raw
//!   epoll/kqueue readiness poller (no new crate deps; std::net — the
//!   offline environment has no tokio) feeding a **bounded** worker pool
//!   (`FASTKQR_WORKERS`) through a backpressured MPMC queue, with
//!   per-connection outbound buffers drained on writability so slow
//!   readers never pin a worker. Selected by `ServerConfig::io_model` /
//!   `FASTKQR_IO=epoll|threads|auto`; the thread-per-connection model
//!   remains the portable fallback and the bitwise-parity oracle;
//! - [`router`]: the consistent-hash multi-replica front — one client
//!   port fanning out to N replica servers by hashing the model id, so
//!   each replica's micro-batcher sees all of one model's traffic.
//!   Replicas share a persistence dir and hot-swap peers' writes through
//!   the generation manifest (see
//!   [`registry::ModelRegistry::refresh`]).

pub mod batcher;
pub mod eventloop;
pub mod job;
pub mod metrics;
pub mod protocol;
pub mod registry;
pub mod router;
pub mod scheduler;
pub mod server;

pub use batcher::{BatchConfig, PredictBatcher};
pub use eventloop::IoModel;
pub use job::{FitJob, JobOutcome, JobSpec};
pub use metrics::Metrics;
pub use registry::ModelRegistry;
pub use router::{HashRing, Router, RouterConfig};
pub use scheduler::Scheduler;
pub use server::{Server, ServerConfig};
