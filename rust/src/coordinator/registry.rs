//! Concurrent model registry for the predict path, with optional
//! persistence.
//!
//! The registry stores [`QuantileModel`]s (the unified facade from
//! [`crate::api`]) under generated ids, each beside its compiled
//! [`PredictPlan`] — built exactly once at insert (and at write-through
//! reload), so the serving path fetches an `Arc`'d plan instead of
//! cloning models per request. With a persistence directory configured,
//! every inserted model is written as a versioned JSON artifact
//! (`<dir>/<id>.json`) and reloaded on construction — a server restarted
//! on the same directory serves the same models.

use crate::api::QuantileModel;
use crate::engine::PredictPlan;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Tracking for write-through persistence failures: the total counter is
/// surfaced by the protocol's `metrics` command, and the per-model
/// messages become `warning` fields on a later successful `save`.
#[derive(Debug, Default)]
struct PersistFailures {
    total: AtomicU64,
    by_id: RwLock<HashMap<String, String>>,
}

/// Historical name for the registry's stored value: the registry now
/// stores the unified model facade directly (`StoredModel::Kqr(fit)`
/// still constructs, via the [`QuantileModel`] variants).
pub type StoredModel = QuantileModel;

/// A stored model and its serving representation, compiled exactly once
/// at insert / reload time (see [`PredictPlan`]). The predict path asks
/// for the `Arc`'d plan and never clones the model.
#[derive(Debug)]
struct StoredEntry {
    model: QuantileModel,
    plan: Arc<PredictPlan>,
    /// Manifest generation of this entry's artifact — what
    /// [`ModelRegistry::refresh`] diffs against to detect writes by
    /// *other* replicas sharing the persistence dir. `0` = memory-only
    /// (no persistence, or the write-through failed): never hot-swapped
    /// and never dropped by a manifest diff.
    generation: u64,
}

/// Thread-safe model store with generated ids.
#[derive(Debug, Default)]
pub struct ModelRegistry {
    models: RwLock<HashMap<String, StoredEntry>>,
    next_id: AtomicU64,
    /// When set, inserts are mirrored to `<dir>/<id>.json` artifacts.
    persist_dir: Option<PathBuf>,
    /// Prefix of generated ids (`"{scope}m{seq}"`). Replicas sharing one
    /// persistence dir get distinct scopes (`"r0"`, `"r1"`, …) so their
    /// independently-generated ids never collide.
    scope: String,
    /// Manifest generation this registry last reconciled against.
    seen_generation: AtomicU64,
    /// Refresh passes that found a changed manifest.
    refreshes: AtomicU64,
    /// Models atomically swapped in by refresh passes.
    hot_swaps: AtomicU64,
    /// Write-through failures (see [`ModelRegistry::persist_errors`]).
    failures: PersistFailures,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// A registry backed by an artifact directory: existing `*.json`
    /// artifacts in `dir` are loaded (file stem = model id), and every
    /// future insert is written through to the directory, so the process
    /// can be restarted without losing models. Unreadable files are an
    /// error — silently serving a subset of the persisted models would
    /// be worse than failing loudly at startup.
    pub fn with_persistence(dir: impl Into<PathBuf>) -> anyhow::Result<ModelRegistry> {
        Self::with_persistence_scoped(dir, "")
    }

    /// [`ModelRegistry::with_persistence`] with an id scope: generated
    /// ids become `"{scope}m{seq}"`. Replicas sharing one persistence
    /// directory each get a distinct scope so concurrent inserts on
    /// different replicas never collide on an id. All artifacts in the
    /// directory are loaded regardless of scope — every replica can
    /// serve every model; the scope only namespaces *new* ids.
    pub fn with_persistence_scoped(
        dir: impl Into<PathBuf>,
        scope: &str,
    ) -> anyhow::Result<ModelRegistry> {
        use anyhow::Context;
        if !scope.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '_' | '-')) {
            anyhow::bail!("invalid registry scope {scope:?} (use [A-Za-z0-9_-])");
        }
        let dir = dir.into();
        std::fs::create_dir_all(&dir).with_context(|| format!("create {}", dir.display()))?;
        // Read the manifest first: entries loaded below are stamped with
        // the generation of their last recorded write, and the global
        // counter becomes the refresh baseline ("I have seen this").
        let manifest = crate::api::artifact::read_manifest(&dir)?.unwrap_or_default();
        let mut models = HashMap::new();
        let mut max_seq: Option<u64> = None;
        let mut entries: Vec<PathBuf> = std::fs::read_dir(&dir)
            .with_context(|| format!("read {}", dir.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().and_then(|s| s.to_str()) == Some("json"))
            // the manifest describes artifacts; it isn't one
            .filter(|p| {
                p.file_name().and_then(|s| s.to_str())
                    != Some(crate::api::artifact::MANIFEST_FILE)
            })
            .collect();
        entries.sort();
        for path in entries {
            let id = path
                .file_stem()
                .and_then(|s| s.to_str())
                .map(String::from)
                .ok_or_else(|| anyhow::anyhow!("bad artifact file name {}", path.display()))?;
            // Compile the serving plan at reload time, exactly like a
            // fresh insert: a restarted server answers its first predict
            // without re-deriving any coefficient layout.
            let (model, plan) = crate::api::artifact::load_compiled(&path)?;
            // resume this scope's sequence past its own persisted ids
            if let Some(seq) = id
                .strip_prefix(scope)
                .and_then(|s| s.strip_prefix('m'))
                .and_then(|s| s.parse::<u64>().ok())
            {
                max_seq = Some(max_seq.map_or(seq, |m| m.max(seq)));
            }
            let generation = manifest.models.get(&id).copied().unwrap_or(0);
            models.insert(id, StoredEntry { model, plan, generation });
        }
        Ok(ModelRegistry {
            models: RwLock::new(models),
            next_id: AtomicU64::new(max_seq.map_or(0, |m| m + 1)),
            persist_dir: Some(dir),
            scope: scope.to_string(),
            seen_generation: AtomicU64::new(manifest.generation),
            refreshes: AtomicU64::new(0),
            hot_swaps: AtomicU64::new(0),
            failures: PersistFailures::default(),
        })
    }

    /// The configured persistence directory, if any.
    pub fn persist_dir(&self) -> Option<&PathBuf> {
        self.persist_dir.as_ref()
    }

    /// Insert, returning the generated id (`m<seq>`). With persistence
    /// configured the artifact is written through; a failed write keeps
    /// the model serving in memory, is reported on stderr, **counted**
    /// (`persist_errors`, surfaced by the protocol's `metrics` command)
    /// and **remembered per id** so a later successful `save` of the same
    /// model carries a warning instead of looking like nothing happened.
    pub fn insert(&self, model: StoredModel) -> String {
        let id = format!("{}m{}", self.scope, self.next_id.fetch_add(1, Ordering::Relaxed));
        let mut generation = 0u64;
        if let Some(dir) = &self.persist_dir {
            match model.save(dir.join(format!("{id}.json"))) {
                Ok(()) => generation = self.bump_manifest(&[&id], &[]),
                Err(e) => {
                    eprintln!(
                        "fastkqr registry: persisting model {id} to {} FAILED ({e:#}); \
                         the model is served from memory only and will NOT survive a restart",
                        dir.display()
                    );
                    self.failures.total.fetch_add(1, Ordering::Relaxed);
                    self.failures.by_id.write().unwrap().insert(id.clone(), format!("{e:#}"));
                }
            }
        }
        // Compile the serving plan once, outside any lock: every predict
        // for this id shares the Arc instead of re-packing coefficients.
        let plan = Arc::new(model.compile_plan());
        self.models.write().unwrap().insert(id.clone(), StoredEntry { model, plan, generation });
        id
    }

    /// Record an artifact write/removal in the directory manifest,
    /// returning the new global generation (0 when the bump failed —
    /// counted like a persistence failure: peers would miss the change).
    fn bump_manifest(&self, touched: &[&str], removed: &[&str]) -> u64 {
        let Some(dir) = &self.persist_dir else { return 0 };
        match crate::api::artifact::update_manifest(dir, touched, removed) {
            // `seen_generation` is deliberately NOT advanced here: only
            // a full refresh pass may claim a generation as reconciled,
            // otherwise our own write could mask a concurrent peer write
            // with a lower generation we haven't loaded yet. The cost is
            // one cheap no-op refresh after each local write.
            Ok(m) => m.generation,
            Err(e) => {
                eprintln!(
                    "fastkqr registry: manifest update in {} FAILED ({e:#}); \
                     peer replicas will not observe this change",
                    dir.display()
                );
                self.failures.total.fetch_add(1, Ordering::Relaxed);
                0
            }
        }
    }

    /// Total write-through persistence failures since construction.
    pub fn persist_errors(&self) -> u64 {
        self.failures.total.load(Ordering::Relaxed)
    }

    /// Take (and clear) the recorded write-through failure for `id`, if
    /// any — called after a successful checked persist of that model.
    pub fn take_persist_failure(&self, id: &str) -> Option<String> {
        self.failures.by_id.write().unwrap().remove(id)
    }

    /// Validate an artifact name from an untrusted source (the wire
    /// protocol) and resolve it inside the persistence directory. Names
    /// are single path components: no separators, no leading dot, only
    /// `[A-Za-z0-9._-]` — a remote client must never address paths
    /// outside the configured directory.
    fn artifact_path(&self, name: &str) -> anyhow::Result<PathBuf> {
        let dir = self
            .persist_dir
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no persistence directory configured"))?;
        if name.is_empty()
            || name.len() > 128
            || name.starts_with('.')
            || !name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-'))
        {
            anyhow::bail!(
                "invalid artifact name {name:?} (one path component, [A-Za-z0-9._-], \
                 no leading dot)"
            );
        }
        Ok(dir.join(format!("{name}.json")))
    }

    /// Write the artifact for `id` to the persistence directory (checked;
    /// errors when no directory is configured or the write fails).
    /// Returns the artifact path.
    pub fn persist(&self, id: &str) -> anyhow::Result<PathBuf> {
        self.persist_as(id, id)
    }

    /// [`ModelRegistry::persist`] under an explicit artifact name (still
    /// confined to the persistence directory).
    pub fn persist_as(&self, id: &str, name: &str) -> anyhow::Result<PathBuf> {
        let path = self.artifact_path(name)?;
        let model =
            self.get(id).ok_or_else(|| anyhow::anyhow!("no such model {id:?}"))?;
        model.save(&path)?;
        let generation = self.bump_manifest(&[name], &[]);
        if generation > 0 && name == id {
            // the artifact now matches the in-memory entry at this
            // generation; stamp it so refresh doesn't reload our own save
            if let Some(e) = self.models.write().unwrap().get_mut(id) {
                e.generation = generation;
            }
        }
        Ok(path)
    }

    /// Load a named artifact from the persistence directory into the
    /// registry, returning its new id.
    pub fn load_named(&self, name: &str) -> anyhow::Result<String> {
        let path = self.artifact_path(name)?;
        let model = QuantileModel::load(&path)?;
        Ok(self.insert(model))
    }

    /// Load an artifact file into the registry, returning its new id.
    /// Takes an arbitrary path — for *trusted* callers (library users,
    /// the CLI); the wire protocol goes through [`ModelRegistry::load_named`].
    pub fn load_artifact(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<String> {
        let model = QuantileModel::load(path.as_ref())?;
        Ok(self.insert(model))
    }

    pub fn get(&self, id: &str) -> Option<StoredModel> {
        self.models.read().unwrap().get(id).map(|e| e.model.clone())
    }

    /// The compiled serving plan for `id` — an `Arc` clone, no model
    /// copy. This is what the protocol's `predict` (and the micro-
    /// batcher behind it) runs on.
    pub fn plan(&self, id: &str) -> Option<Arc<PredictPlan>> {
        self.models.read().unwrap().get(id).map(|e| e.plan.clone())
    }

    pub fn remove(&self, id: &str) -> bool {
        let removed = self.models.write().unwrap().remove(id).is_some();
        if removed {
            self.failures.by_id.write().unwrap().remove(id);
            if let Some(dir) = &self.persist_dir {
                let _ = std::fs::remove_file(dir.join(format!("{id}.json")));
                self.bump_manifest(&[], &[id]);
            }
        }
        removed
    }

    /// Reconcile against the shared directory's manifest: reload models
    /// whose recorded generation differs from the loaded entry's, drop
    /// persisted models removed elsewhere, and remember the manifest
    /// generation. Each reload swaps the `Arc<PredictPlan>` atomically
    /// under the write lock — an in-flight predict keeps its old plan, a
    /// later predict gets the new one, never a torn model.
    ///
    /// Cheap when nothing changed (one small file read + one compare);
    /// replicas poll this on a short interval. Returns the number of
    /// models swapped in or dropped. Individual artifact load failures
    /// are reported and skipped — a half-visible directory state (a peer
    /// mid-write) must not take down serving of the current model.
    pub fn refresh(&self) -> anyhow::Result<usize> {
        let Some(dir) = &self.persist_dir else { return Ok(0) };
        let Some(manifest) = crate::api::artifact::read_manifest(dir)? else {
            return Ok(0);
        };
        if manifest.generation == self.seen_generation.load(Ordering::Relaxed) {
            return Ok(0);
        }
        self.refreshes.fetch_add(1, Ordering::Relaxed);
        // Diff outside the write lock: stale = new id, or recorded
        // generation moved past the one we loaded.
        let stale: Vec<(String, u64)> = {
            let models = self.models.read().unwrap();
            manifest
                .models
                .iter()
                .filter(|(id, &gen)| {
                    !models.get(*id).is_some_and(|e| e.generation == gen)
                })
                .map(|(id, &gen)| (id.clone(), gen))
                .collect()
        };
        let mut changed = 0usize;
        for (id, generation) in stale {
            let path = dir.join(format!("{id}.json"));
            match crate::api::artifact::load_compiled(&path) {
                Ok((model, plan)) => {
                    self.models
                        .write()
                        .unwrap()
                        .insert(id, StoredEntry { model, plan, generation });
                    self.hot_swaps.fetch_add(1, Ordering::Relaxed);
                    changed += 1;
                }
                Err(e) => {
                    eprintln!(
                        "fastkqr registry: refresh reload of {id} FAILED ({e:#}); \
                         keeping the currently served model"
                    );
                }
            }
        }
        // Persisted entries absent from the manifest were dropped by a
        // peer; memory-only entries (generation 0) are never touched.
        let dropped: Vec<String> = {
            let models = self.models.read().unwrap();
            models
                .iter()
                .filter(|(id, e)| e.generation > 0 && !manifest.models.contains_key(*id))
                .map(|(id, _)| id.clone())
                .collect()
        };
        for id in &dropped {
            self.models.write().unwrap().remove(id);
            self.failures.by_id.write().unwrap().remove(id);
            changed += 1;
        }
        self.seen_generation.store(manifest.generation, Ordering::Relaxed);
        Ok(changed)
    }

    /// The manifest generation this registry last reconciled against.
    pub fn generation(&self) -> u64 {
        self.seen_generation.load(Ordering::Relaxed)
    }

    /// Refresh passes that observed a changed manifest.
    pub fn refreshes(&self) -> u64 {
        self.refreshes.load(Ordering::Relaxed)
    }

    /// Models atomically hot-swapped in by refresh passes.
    pub fn hot_swaps(&self) -> u64 {
        self.hot_swaps.load(Ordering::Relaxed)
    }

    pub fn list(&self) -> Vec<String> {
        let mut ids: Vec<String> = self.models.read().unwrap().keys().cloned().collect();
        ids.sort();
        ids
    }

    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, Rng};
    use crate::kernel::Kernel;
    use crate::kqr::KqrSolver;

    fn toy_fit(n: usize, seed: u64) -> crate::kqr::KqrFit {
        let mut rng = Rng::new(seed);
        let d = synth::sine_hetero(n, &mut rng);
        KqrSolver::new(&d.x, &d.y, Kernel::Rbf { sigma: 0.5 })
            .unwrap()
            .fit(0.5, 0.1)
            .unwrap()
    }

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut rng = Rng::new(1);
        let d = synth::sine_hetero(20, &mut rng);
        let fit = KqrSolver::new(&d.x, &d.y, Kernel::Rbf { sigma: 0.5 })
            .unwrap()
            .fit(0.5, 0.1)
            .unwrap();
        let reg = ModelRegistry::new();
        let id = reg.insert(StoredModel::Kqr(fit));
        assert_eq!(reg.len(), 1);
        let m = reg.get(&id).unwrap();
        assert_eq!(m.taus(), vec![0.5]);
        let preds = m.predict(&d.x);
        assert_eq!(preds.len(), 1);
        assert_eq!(preds[0].len(), 20);
        assert!(reg.remove(&id));
        assert!(reg.is_empty());
        assert!(reg.get(&id).is_none());
    }

    #[test]
    fn plans_are_compiled_on_insert_and_shared() {
        let fit = toy_fit(14, 8);
        let reg = ModelRegistry::new();
        let id = reg.insert(StoredModel::Kqr(fit.clone()));
        let plan = reg.plan(&id).unwrap();
        let again = reg.plan(&id).unwrap();
        assert!(Arc::ptr_eq(&plan, &again), "plan is compiled once and Arc-shared");
        let xt = {
            let mut rng = Rng::new(31);
            synth::sine_hetero(6, &mut rng).x
        };
        assert_eq!(plan.predict(&xt), vec![fit.predict(&xt)]);
        assert!(reg.remove(&id));
        assert!(reg.plan(&id).is_none());
    }

    #[test]
    fn ids_are_unique_and_listed() {
        let fit = toy_fit(15, 2);
        let reg = ModelRegistry::new();
        let a = reg.insert(StoredModel::Kqr(fit.clone()));
        let b = reg.insert(StoredModel::Kqr(fit));
        assert_ne!(a, b);
        assert_eq!(reg.list().len(), 2);
    }

    #[test]
    fn write_through_failures_are_counted_and_remembered() {
        let dir = std::env::temp_dir().join(format!(
            "fastkqr-registry-failtest-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let reg = ModelRegistry::with_persistence(&dir).unwrap();
        // Sabotage the write: the atomic-save temp path of the next id
        // (m0) is occupied by a DIRECTORY, so fs::write fails even when
        // the test runs as root (permission tricks would not).
        std::fs::create_dir_all(dir.join("m0.json.tmp")).unwrap();
        let fit = toy_fit(12, 5);
        let id = reg.insert(StoredModel::Kqr(fit));
        assert_eq!(id, "m0");
        assert_eq!(reg.persist_errors(), 1, "failed write-through must be counted");
        // the model still serves from memory
        assert!(reg.get(&id).is_some());
        // a later checked persist succeeds (temp dir removed) and the
        // recorded failure is taken exactly once
        std::fs::remove_dir_all(dir.join("m0.json.tmp")).unwrap();
        reg.persist(&id).unwrap();
        let msg = reg.take_persist_failure(&id);
        assert!(msg.is_some(), "failure message recorded for the id");
        assert!(reg.take_persist_failure(&id).is_none(), "taken = cleared");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scoped_replicas_share_a_dir_and_hot_swap_via_manifest() {
        let dir = std::env::temp_dir().join(format!(
            "fastkqr-registry-scope-test-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let reg_a = ModelRegistry::with_persistence_scoped(&dir, "r0").unwrap();
        let reg_b = ModelRegistry::with_persistence_scoped(&dir, "r1").unwrap();
        assert!(ModelRegistry::with_persistence_scoped(&dir, "bad scope").is_err());
        let xt = {
            let mut rng = Rng::new(17);
            synth::sine_hetero(5, &mut rng).x
        };
        // A writes; B observes it through the manifest without restart
        let id_a = reg_a.insert(StoredModel::Kqr(toy_fit(16, 4)));
        assert_eq!(id_a, "r0m0", "ids carry the replica scope");
        assert!(reg_b.plan(&id_a).is_none(), "B has not refreshed yet");
        assert_eq!(reg_b.refresh().unwrap(), 1);
        assert_eq!(reg_b.hot_swaps(), 1);
        let via_a = reg_a.get(&id_a).unwrap().predict(&xt);
        let via_b = reg_b.get(&id_a).unwrap().predict(&xt);
        assert_eq!(via_a, via_b, "cross-replica predictions are bitwise equal");
        // a second refresh with no changes is a no-op
        assert_eq!(reg_b.refresh().unwrap(), 0);
        assert_eq!(reg_b.refreshes(), 1, "unchanged manifests short-circuit");
        // B writes under its own scope; no collision, A picks it up
        let id_b = reg_b.insert(StoredModel::Kqr(toy_fit(14, 9)));
        assert_eq!(id_b, "r1m0");
        assert_eq!(reg_a.refresh().unwrap(), 1);
        assert!(reg_a.plan(&id_b).is_some());
        // A re-persists its model (same id): B hot-swaps the new write
        reg_a.persist(&id_a).unwrap();
        assert_eq!(reg_b.refresh().unwrap(), 1, "re-write moves the id's generation");
        // A drops its model: B's refresh retires it
        assert!(reg_a.remove(&id_a));
        assert_eq!(reg_b.refresh().unwrap(), 1);
        assert!(reg_b.plan(&id_a).is_none(), "dropped on the peer too");
        assert!(reg_b.plan(&id_b).is_some(), "unrelated models survive");
        // a restarted scoped registry resumes its own sequence only
        let reg_b2 = ModelRegistry::with_persistence_scoped(&dir, "r1").unwrap();
        let id_b2 = reg_b2.insert(StoredModel::Kqr(toy_fit(12, 6)));
        assert_eq!(id_b2, "r1m1", "sequence resumes past r1m0, ignoring r0 ids");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn persistence_survives_reconstruction() {
        let dir = std::env::temp_dir().join(format!(
            "fastkqr-registry-test-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let fit = toy_fit(16, 3);
        let xt = {
            let mut rng = Rng::new(9);
            synth::sine_hetero(5, &mut rng).x
        };
        let (id, preds_before) = {
            let reg = ModelRegistry::with_persistence(&dir).unwrap();
            let id = reg.insert(StoredModel::Kqr(fit));
            let preds = reg.get(&id).unwrap().predict(&xt);
            (id, preds)
        };
        // a fresh registry on the same dir serves the same model, bitwise
        let reg2 = ModelRegistry::with_persistence(&dir).unwrap();
        assert_eq!(reg2.list(), vec![id.clone()]);
        let preds_after = reg2.get(&id).unwrap().predict(&xt);
        assert_eq!(preds_before, preds_after, "reloaded predictions must be identical");
        // new inserts do not collide with reloaded ids
        let id2 = reg2.insert(reg2.get(&id).unwrap());
        assert_ne!(id, id2);
        // drop removes the artifact too
        assert!(reg2.remove(&id));
        let reg3 = ModelRegistry::with_persistence(&dir).unwrap();
        assert_eq!(reg3.list(), vec![id2]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
