//! Integration tests for the declarative fit API: spec round-trips, the
//! one-spec/many-consumers parity guarantee, artifact persistence and the
//! NonCrossing-through-the-cache invariant.

use fastkqr::api::{FitSpec, KernelSpec, QuantileModel, Task};
use fastkqr::coordinator::protocol::{handle_line, ProtocolState};
use fastkqr::coordinator::{BatchConfig, Metrics, ModelRegistry};
use fastkqr::data::{synth, Rng};
use fastkqr::engine::{CacheMetrics, FitEngine};
use fastkqr::kqr::SolveOptions;
use fastkqr::linalg::Matrix;
use fastkqr::util::Json;
use std::sync::Arc;

fn temp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "fastkqr-api-{tag}-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ))
}

fn toy_spec(n: usize, seed: u64, task: Task) -> FitSpec {
    let mut rng = Rng::new(seed);
    let d = synth::sine_hetero(n, &mut rng);
    FitSpec::new(d.x, d.y, KernelSpec::Rbf { sigma: Some(0.5) }, task)
}

fn eval_grid(m: usize) -> Matrix {
    Matrix::from_fn(m, 1, |i, _| i as f64 / (m - 1) as f64)
}

#[test]
fn kqr_artifact_roundtrip_predicts_identically() {
    let spec = toy_spec(40, 1, Task::Single { tau: 0.3, lambda: 0.02 });
    let model = FitEngine::global().run(&spec).unwrap();
    let xt = eval_grid(23);
    let before = model.predict(&xt);

    let path = temp_path("kqr").with_extension("json");
    model.save(&path).unwrap();
    let back = QuantileModel::load(&path).unwrap();
    let after = back.predict(&xt);
    assert_eq!(before, after, "save→load must reproduce predictions exactly");
    assert_eq!(back.taus(), model.taus());
    assert_eq!(back.kind(), "kqr");
    // double round-trip is byte-stable
    let doc1 = model.to_artifact().unwrap().to_string();
    let doc2 = back.to_artifact().unwrap().to_string();
    assert_eq!(doc1, doc2);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn nckqr_artifact_roundtrip_predicts_identically() {
    let spec = toy_spec(35, 2, Task::NonCrossing { taus: vec![0.2, 0.5, 0.8], lam1: 5.0, lam2: 0.05 });
    let model = FitEngine::global().run(&spec).unwrap();
    let xt = eval_grid(17);
    let before = model.predict(&xt);
    assert_eq!(before.len(), 3, "one row per level");

    let path = temp_path("nckqr").with_extension("json");
    model.save(&path).unwrap();
    let back = QuantileModel::load(&path).unwrap();
    assert_eq!(back.kind(), "nckqr");
    assert_eq!(back.taus(), vec![0.2, 0.5, 0.8]);
    let after = back.predict(&xt);
    assert_eq!(before, after, "NCKQR reload must predict identically");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn grid_artifact_roundtrip_keeps_all_cells() {
    let spec = toy_spec(30, 3, Task::Grid { taus: vec![0.25, 0.75], lambdas: vec![0.1, 0.01] });
    let model = FitEngine::global().run(&spec).unwrap();
    assert_eq!(model.n_levels(), 4);
    let xt = eval_grid(9);
    let before = model.predict(&xt);
    let path = temp_path("grid").with_extension("json");
    model.save(&path).unwrap();
    let back = QuantileModel::load(&path).unwrap();
    assert_eq!(back.n_levels(), 4);
    assert_eq!(back.taus(), model.taus());
    assert_eq!(back.lambdas(), model.lambdas());
    assert_eq!(back.predict(&xt), before);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn one_spec_fits_identically_via_api_and_protocol() {
    // The SAME FitSpec JSON document, executed (a) in-process through
    // FitEngine::run and (b) over the protocol's spec-based `fit`, must
    // produce the same model (≤1e-12; same engine code path ⇒ equal).
    let spec = toy_spec(32, 4, Task::Single { tau: 0.5, lambda: 0.05 })
        .with_opts(SolveOptions::default());
    let doc = spec.to_json().to_string();

    // (a) direct API on a fresh engine
    let engine_a = FitEngine::new();
    let model_a = engine_a.run(&FitSpec::parse(&doc).unwrap()).unwrap();

    // (b) protocol on its own fresh engine
    let st = ProtocolState::new(
        Arc::new(ModelRegistry::new()),
        Arc::new(Metrics::new()),
        SolveOptions::default(),
        Arc::new(FitEngine::new()),
        BatchConfig { window_us: 0, max_rows: 4096 },
    );
    let resp = handle_line(&st, &format!(r#"{{"cmd":"fit","spec":{doc}}}"#));
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{}", resp.to_string());
    let id = resp.get_str("model").unwrap();
    let model_b = st.registry.get(id).unwrap();

    let xt = eval_grid(21);
    let pa = model_a.predict(&xt);
    let pb = model_b.predict(&xt);
    assert_eq!(pa.len(), pb.len());
    for (ra, rb) in pa.iter().zip(&pb) {
        for (a, b) in ra.iter().zip(rb) {
            assert!((a - b).abs() <= 1e-12, "api {a} vs protocol {b}");
        }
    }
    assert_eq!(model_a.objective(), model_b.objective());
}

#[test]
fn noncrossing_specs_share_one_decomposition_with_everything_else() {
    // One engine, three consumers' worth of tasks on the same (x, y,
    // kernel): Single, Grid and repeated NonCrossing — exactly one
    // eigendecomposition in total.
    let engine = FitEngine::new();
    let base = toy_spec(28, 5, Task::Single { tau: 0.5, lambda: 0.05 });
    engine.run(&base).unwrap();
    let nc = FitSpec::new(
        base.x.clone(),
        base.y.clone(),
        base.kernel.clone(),
        Task::NonCrossing { taus: vec![0.25, 0.75], lam1: 2.0, lam2: 0.05 },
    );
    engine.run(&nc).unwrap();
    engine.run(&nc).unwrap();
    let grid = FitSpec::new(
        base.x.clone(),
        base.y.clone(),
        base.kernel.clone(),
        Task::Grid { taus: vec![0.3, 0.7], lambdas: vec![0.1] },
    );
    engine.run(&grid).unwrap();
    assert_eq!(
        CacheMetrics::get(&engine.cache.metrics.decompositions),
        1,
        "all tasks on one dataset must share one decomposition"
    );
}

#[test]
fn cv_task_returns_per_tau_winners_with_summaries() {
    let mut rng = Rng::new(6);
    let d = synth::sine_hetero(45, &mut rng);
    let spec = FitSpec::new(
        d.x,
        d.y,
        KernelSpec::Rbf { sigma: Some(0.5) },
        Task::Cv { taus: vec![0.25, 0.75], lambdas: vec![0.5, 0.05, 0.005], folds: 3, seed: 9 },
    )
    .with_opts(SolveOptions::cv_preset());
    let model = FitEngine::new().run(&spec).unwrap();
    let QuantileModel::Set(set) = &model else { panic!("cv must produce a set") };
    assert_eq!(set.fits.len(), 2, "one refit per tau");
    assert_eq!(set.cv.len(), 2);
    for (fit, cv) in set.fits.iter().zip(&set.cv) {
        assert_eq!(fit.tau, cv.tau);
        assert_eq!(fit.lam, cv.best_lambda, "refit must be at the CV winner");
        assert_eq!(cv.cv_loss.len(), 3);
        assert!(cv.cv_loss.iter().all(|v| v.is_finite()));
    }
    // artifact round-trip keeps the CV diagnostics
    let back = QuantileModel::from_artifact(&model.to_artifact().unwrap()).unwrap();
    let QuantileModel::Set(set2) = &back else { panic!() };
    assert_eq!(set2.cv, set.cv);
}

#[test]
fn spec_fuzz_documents_fail_loudly() {
    // Integration-level fuzz: every malformed document must error (never
    // panic), both at parse time and through the protocol dispatcher.
    let st = ProtocolState::new(
        Arc::new(ModelRegistry::new()),
        Arc::new(Metrics::new()),
        SolveOptions::default(),
        Arc::new(FitEngine::new()),
        BatchConfig { window_us: 0, max_rows: 4096 },
    );
    let bad_specs = [
        r#"{"x":[[1,2],[3]],"y":[1,2],"task":{"type":"single","tau":0.5,"lambda":0.1}}"#,
        r#"{"x":[],"y":[],"task":{"type":"single","tau":0.5,"lambda":0.1}}"#,
        r#"{"x":[[1],[2]],"y":[1,2],"task":{"type":"teleport"}}"#,
        r#"{"x":[[1],[2]],"y":[1,2],"task":{"type":"grid","taus":[],"lambdas":[0.1]}}"#,
        r#"{"x":[[1],[2]],"y":[1,2],"kernel":{"type":"fourier"},"task":{"type":"single","tau":0.5,"lambda":0.1}}"#,
        r#"{"x":[[1],[2]],"y":[1,2],"version":99,"task":{"type":"single","tau":0.5,"lambda":0.1}}"#,
        r#"{"x":[[1],[2]],"y":["a",2],"task":{"type":"single","tau":0.5,"lambda":0.1}}"#,
        r#"{"x":[[1],[2]],"y":[1,2],"task":{"type":"cv","taus":[0.5],"lambdas":[]}}"#,
    ];
    for bad in bad_specs {
        assert!(FitSpec::parse(bad).is_err(), "must reject: {bad}");
        let resp = handle_line(&st, &format!(r#"{{"cmd":"fit","spec":{bad}}}"#));
        assert_eq!(
            resp.get("ok").and_then(Json::as_bool),
            Some(false),
            "protocol must reject: {bad}"
        );
    }
    // runtime-invalid values error through run(), too
    let engine = FitEngine::new();
    for task in [
        Task::Single { tau: 1.5, lambda: 0.1 },
        Task::Single { tau: 0.5, lambda: -1.0 },
        Task::NonCrossing { taus: vec![0.5, 0.5], lam1: 1.0, lam2: 0.1 },
        Task::Cv { taus: vec![0.5], lambdas: vec![0.1], folds: 1, seed: 0 },
    ] {
        let spec = toy_spec(12, 7, task.clone());
        assert!(engine.run(&spec).is_err(), "must reject at run time: {task:?}");
    }
}

#[test]
fn save_load_through_protocol_matches_export() {
    let dir = temp_path("proto-registry");
    let st = ProtocolState::new(
        Arc::new(ModelRegistry::with_persistence(&dir).unwrap()),
        Arc::new(Metrics::new()),
        SolveOptions::default(),
        Arc::new(FitEngine::new()),
        BatchConfig { window_us: 0, max_rows: 4096 },
    );
    let spec = toy_spec(20, 8, Task::Single { tau: 0.5, lambda: 0.05 });
    let doc = spec.to_json().to_string();
    let fit = handle_line(&st, &format!(r#"{{"cmd":"fit","spec":{doc}}}"#));
    assert_eq!(fit.get("ok").and_then(Json::as_bool), Some(true), "{}", fit.to_string());
    let id = fit.get_str("model").unwrap().to_string();

    // save under an explicit name (confined to the persistence dir),
    // then load it back as a new model
    let save = handle_line(&st, &format!(r#"{{"cmd":"save","model":"{id}","name":"snapshot"}}"#));
    assert_eq!(save.get("ok").and_then(Json::as_bool), Some(true), "{}", save.to_string());
    let load = handle_line(&st, r#"{"cmd":"load","name":"snapshot"}"#);
    assert_eq!(load.get("ok").and_then(Json::as_bool), Some(true), "{}", load.to_string());
    let id2 = load.get_str("model").unwrap().to_string();
    assert_ne!(id, id2);

    // the loaded model predicts identically to the original
    let xt = eval_grid(7);
    let a = st.registry.get(&id).unwrap().predict(&xt);
    let b = st.registry.get(&id2).unwrap().predict(&xt);
    assert_eq!(a, b);

    // export of the original equals the saved file's contents
    let export = handle_line(&st, &format!(r#"{{"cmd":"export","model":"{id}"}}"#));
    let inline = export.get("artifact").unwrap().to_string();
    let on_disk = std::fs::read_to_string(dir.join("snapshot.json")).unwrap();
    assert_eq!(inline, on_disk.trim());

    // path traversal and absolute names are rejected outright
    for bad in ["../evil", "a/b", "/etc/x", ".hidden", ""] {
        let r = handle_line(
            &st,
            &format!(r#"{{"cmd":"save","model":"{id}","name":"{bad}"}}"#),
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false), "name {bad:?}");
        let r = handle_line(&st, &format!(r#"{{"cmd":"load","name":"{bad}"}}"#));
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false), "name {bad:?}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
