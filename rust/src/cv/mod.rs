//! k-fold cross validation and λ-grid search on the fit engine.
//!
//! The paper's timing protocol (Tables 1–6) fits a 50-value λ path with
//! 5-fold CV and reports the whole wall time plus the objective at the
//! CV-selected λ. This module implements that loop on top of
//! [`FitEngine`]: each fold's (Gram, eigenbasis) comes from the engine's
//! content-addressed cache (so re-running CV on the same data and fold
//! assignment is free of eigendecompositions), folds run in parallel on
//! scoped threads bounded by the engine's concurrency budget (with
//! intra-op GEMV parallelism disabled inside each fold to avoid
//! oversubscription), and the winning λ gets a final warm-started refit
//! on the full data.
//!
//! The declarative entry point is the [`crate::api::Task::Cv`] variant of
//! a [`crate::api::FitSpec`]: `FitEngine::run` drives this module once
//! per requested τ (same seed → same fold assignment across levels, so
//! losses are comparable) and packages the per-τ winners as one
//! [`crate::api::QuantileModel`] with the CV curves kept as diagnostics.
//! The CLI `cv` subcommand and the protocol's `{"task":{"type":"cv",…}}`
//! are thin shells over that path.

use crate::data::{Dataset, Rng};
use crate::engine::{ApproxSpec, FitEngine};
use crate::kernel::Kernel;
use crate::kqr::{KqrFit, SolveOptions};
use crate::linalg::par;
use crate::smooth::pinball_loss;
use anyhow::{bail, ensure, Result};

/// Outcome of a cross-validated path fit.
#[derive(Clone, Debug)]
pub struct CvResult {
    /// λ grid (descending, as fitted).
    pub lambdas: Vec<f64>,
    /// Mean held-out pinball loss per λ.
    pub cv_loss: Vec<f64>,
    /// Index of the winning λ.
    pub best_index: usize,
    pub best_lambda: f64,
    /// Final fit at the selected λ on the **full** data, warm-started
    /// down the path (and sharing the engine-cached full-data basis).
    pub refit: Option<KqrFit>,
}

/// Assign each of `n` indices to one of `k` folds (balanced, shuffled).
///
/// Errors (rather than panicking) on `k < 2` or `k > n`: fold counts
/// arrive from coordinator job specs and server payloads, so bad input is
/// an expected runtime condition, not a programmer bug.
pub fn fold_assignment(n: usize, k: usize, rng: &mut Rng) -> Result<Vec<usize>> {
    if k < 2 || k > n {
        bail!("fold_assignment: need 2 <= k <= n, got k={k}, n={n}");
    }
    let perm = rng.permutation(n);
    let mut folds = vec![0usize; n];
    for (pos, &idx) in perm.iter().enumerate() {
        folds[idx] = pos % k;
    }
    Ok(folds)
}

/// k-fold CV over a descending λ grid at quantile level τ, on the
/// process-global [`FitEngine`].
pub fn cross_validate(
    data: &Dataset,
    kernel: &Kernel,
    tau: f64,
    lambdas: &[f64],
    k: usize,
    opts: &SolveOptions,
    rng: &mut Rng,
) -> Result<CvResult> {
    cross_validate_on(
        FitEngine::global(),
        data,
        kernel,
        tau,
        lambdas,
        k,
        opts,
        ApproxSpec::Exact,
        rng,
    )
}

/// k-fold CV on an explicit engine (fold bases and the full-data refit
/// basis are served from — and deposited into — its cache; folds run on
/// its thread budget). `approx` selects the Gram representation per fold
/// (and for the refit): with `ApproxSpec::Nystrom` each fold's training
/// subset gets its own seeded thin factor, so CV at n ≫ 10⁴ never
/// materializes an n×n matrix.
#[allow(clippy::too_many_arguments)]
pub fn cross_validate_on(
    engine: &FitEngine,
    data: &Dataset,
    kernel: &Kernel,
    tau: f64,
    lambdas: &[f64],
    k: usize,
    opts: &SolveOptions,
    approx: ApproxSpec,
    rng: &mut Rng,
) -> Result<CvResult> {
    ensure!(!lambdas.is_empty(), "cross_validate: empty lambda grid");
    let n = data.n();
    let assignment = fold_assignment(n, k, rng)?;
    let splits: Vec<(Dataset, Dataset)> = (0..k)
        .map(|fold| {
            let train_idx: Vec<usize> =
                (0..n).filter(|i| assignment[*i] != fold).collect();
            let test_idx: Vec<usize> = (0..n).filter(|i| assignment[*i] == fold).collect();
            (data.subset(&train_idx), data.subset(&test_idx))
        })
        .collect();

    // When already inside a serial scope (e.g. a multi-worker scheduler
    // job), don't fan out further — the outer level owns the parallelism.
    let workers = if par::in_serial_scope() {
        1
    } else {
        engine.config.par.threads.min(k).max(1)
    };
    // Fold solves ALWAYS run with intra-op parallelism disabled — in the
    // parallel branch to avoid oversubscription, and in the serial branch
    // so fold numerics are identical whatever the engine's thread budget
    // (gemv_t re-associates its reduction when parallel, so letting it
    // dispatch would break serial-vs-parallel CV parity at large n).
    let fold_results: Vec<Result<Vec<f64>>> = if workers > 1 {
        // Chunk the folds onto scoped threads: at most `workers` run at a
        // time.
        let chunk = (k + workers - 1) / workers;
        std::thread::scope(|s| {
            let handles: Vec<_> = splits
                .chunks(chunk)
                .map(|split_chunk| {
                    s.spawn(move || {
                        split_chunk
                            .iter()
                            .map(|(tr, te)| {
                                par::serial_scope(|| {
                                    fold_losses(engine, tr, te, kernel, tau, lambdas, opts, approx)
                                })
                            })
                            .collect::<Vec<Result<Vec<f64>>>>()
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| {
                    // A poisoned fold worker becomes an error on this CV
                    // run, not a process abort.
                    h.join().unwrap_or_else(|p| {
                        vec![Err(anyhow::anyhow!(
                            "cv fold worker panicked: {}",
                            crate::util::panic_message(&p)
                        ))]
                    })
                })
                .collect()
        })
    } else {
        splits
            .iter()
            .map(|(tr, te)| {
                par::serial_scope(|| {
                    fold_losses(engine, tr, te, kernel, tau, lambdas, opts, approx)
                })
            })
            .collect()
    };

    // Deterministic reduction: folds are summed in fold order regardless
    // of completion order, so parallel CV reproduces serial CV exactly.
    let mut loss_sum = vec![0.0f64; lambdas.len()];
    for r in fold_results {
        let losses = r?;
        for (li, v) in losses.iter().enumerate() {
            loss_sum[li] += v;
        }
    }
    let cv_loss: Vec<f64> = loss_sum.iter().map(|s| s / k as f64).collect();
    let best_index = cv_loss
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();

    // Final refit at the selected λ on the full data, warm-started down
    // the (truncated) path; the full-data basis lands in the cache so a
    // follow-up predict/fit job on the same dataset is free of setup.
    let refit = {
        let solver = engine.solver_approx(&data.x, &data.y, kernel, approx, opts.clone())?;
        let path: Vec<f64> = lambdas[..=best_index].to_vec();
        let mut fits = solver.fit_path(tau, &path)?;
        fits.pop()
    };

    Ok(CvResult {
        lambdas: lambdas.to_vec(),
        cv_loss,
        best_index,
        best_lambda: lambdas[best_index],
        refit,
    })
}

/// Held-out pinball losses of one fold's warm-started λ path.
#[allow(clippy::too_many_arguments)]
fn fold_losses(
    engine: &FitEngine,
    train: &Dataset,
    test: &Dataset,
    kernel: &Kernel,
    tau: f64,
    lambdas: &[f64],
    opts: &SolveOptions,
    approx: ApproxSpec,
) -> Result<Vec<f64>> {
    let solver = engine.solver_approx(&train.x, &train.y, kernel, approx, opts.clone())?;
    let path = solver.fit_path(tau, lambdas)?;
    Ok(path
        .iter()
        .map(|fit| pinball_loss(&test.y, &fit.predict(&test.x), tau))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kqr::KqrSolver;

    #[test]
    fn folds_are_balanced_partition() {
        let mut rng = Rng::new(1);
        let folds = fold_assignment(23, 5, &mut rng).unwrap();
        assert_eq!(folds.len(), 23);
        let mut counts = vec![0usize; 5];
        for &f in &folds {
            assert!(f < 5);
            counts[f] += 1;
        }
        assert!(counts.iter().all(|&c| c == 4 || c == 5));
    }

    #[test]
    fn fold_assignment_rejects_bad_k() {
        let mut rng = Rng::new(2);
        assert!(fold_assignment(10, 0, &mut rng).is_err());
        assert!(fold_assignment(10, 1, &mut rng).is_err());
        assert!(fold_assignment(10, 11, &mut rng).is_err());
        assert!(fold_assignment(10, 10, &mut rng).is_ok());
    }

    #[test]
    fn cv_rejects_bad_inputs_without_panicking() {
        let mut rng = Rng::new(3);
        let data = synth::sine_hetero(20, &mut rng);
        let kernel = Kernel::Rbf { sigma: 1.0 };
        let opts = SolveOptions::default();
        assert!(
            cross_validate(&data, &kernel, 0.5, &[0.1], 1, &opts, &mut rng).is_err(),
            "k=1 must be an Err"
        );
        assert!(
            cross_validate(&data, &kernel, 0.5, &[], 3, &opts, &mut rng).is_err(),
            "empty grid must be an Err"
        );
    }

    #[test]
    fn cv_selects_interior_lambda_on_smooth_signal() {
        let mut rng = Rng::new(2);
        let data = synth::sine_hetero(90, &mut rng);
        let sigma = crate::kernel::median_heuristic_sigma(&data.x);
        let kernel = Kernel::Rbf { sigma };
        let solver = KqrSolver::new(&data.x, &data.y, kernel.clone()).unwrap();
        let lams = solver.lambda_grid(8, 10.0, 1e-6);
        let res =
            cross_validate(&data, &kernel, 0.5, &lams, 4, &SolveOptions::default(), &mut rng)
                .unwrap();
        assert_eq!(res.cv_loss.len(), 8);
        assert!(res.cv_loss.iter().all(|v| v.is_finite()));
        // neither the most extreme over- nor under-smoothed end should win
        assert!(res.best_index > 0, "picked λ_max");
        assert_eq!(res.best_lambda, lams[res.best_index]);
        // the refit is at the winning λ, on the full data
        let refit = res.refit.expect("refit present");
        assert_eq!(refit.lam, res.best_lambda);
        assert_eq!(refit.n_train(), 90);
    }

    #[test]
    fn parallel_and_serial_cv_agree_exactly() {
        use crate::engine::{EngineConfig, FitEngine};
        use crate::linalg::Parallelism;
        let mut rng = Rng::new(7);
        let data = synth::sine_hetero(60, &mut rng);
        let kernel = Kernel::Rbf { sigma: 0.5 };
        let lams = [0.5, 0.05, 0.005];
        let opts = SolveOptions::cv_preset();

        let serial_engine = FitEngine::with_config(EngineConfig {
            par: Parallelism::serial(),
            ..EngineConfig::default()
        });
        let mut rng_a = Rng::new(11);
        let a = cross_validate_on(
            &serial_engine, &data, &kernel, 0.3, &lams, 3, &opts, ApproxSpec::Exact, &mut rng_a,
        )
        .unwrap();

        let par_engine = FitEngine::with_config(EngineConfig {
            par: Parallelism::with_threads(3),
            ..EngineConfig::default()
        });
        let mut rng_b = Rng::new(11);
        let b = cross_validate_on(
            &par_engine, &data, &kernel, 0.3, &lams, 3, &opts, ApproxSpec::Exact, &mut rng_b,
        )
        .unwrap();

        assert_eq!(a.best_index, b.best_index);
        for (va, vb) in a.cv_loss.iter().zip(&b.cv_loss) {
            assert!(
                (va - vb).abs() < 1e-12,
                "parallel CV diverged from serial: {va} vs {vb}"
            );
        }
    }
}
