//! Compute-backend abstraction.
//!
//! The finite smoothing solver is backend-agnostic: between convergence
//! checks it asks a [`Backend`] to advance the APGD recurrence by a fixed
//! chunk of iterations. Two implementations exist:
//!
//! - [`NativeBackend`]: the pure-Rust hot loop (`kqr::apgd`), always
//!   available; the perf pass tunes this path.
//! - [`runtime::XlaBackend`](crate::runtime::XlaBackend): executes the
//!   same recurrence compiled AOT from the L2 JAX program (which calls
//!   the L1 Pallas kernels) through PJRT. Loaded from
//!   `artifacts/*.hlo.txt`; Python is never on this path.
//!
//! Both must implement the *identical* recurrence; `rust/tests/` enforces
//! elementwise parity.

use crate::kqr::apgd::{run_chunk_native, ApgdState, ApgdWorkspace};
use crate::spectral::{SpectralBasis, SpectralPlan};

/// A provider of APGD chunk execution.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Advance `state` by `iters` accelerated APGD iterations for the
    /// smoothed problem (basis, plan, y, τ). Returns the sup-norm of the
    /// final update (the convergence signal).
    fn apgd_chunk(
        &mut self,
        basis: &SpectralBasis,
        plan: &SpectralPlan,
        y: &[f64],
        tau: f64,
        state: &mut ApgdState,
        iters: usize,
    ) -> f64;
}

/// Pure-Rust backend (no artifacts needed).
pub struct NativeBackend {
    ws: Option<ApgdWorkspace>,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend { ws: None }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn apgd_chunk(
        &mut self,
        basis: &SpectralBasis,
        plan: &SpectralPlan,
        y: &[f64],
        tau: f64,
        state: &mut ApgdState,
        iters: usize,
    ) -> f64 {
        let (n, dim) = (basis.n, basis.dim());
        if self.ws.as_ref().map(|w| (w.f.len(), w.t.len())) != Some((n, dim)) {
            self.ws = Some(ApgdWorkspace::with_dims(n, dim));
        }
        run_chunk_native(basis, plan, y, tau, state, self.ws.as_mut().unwrap(), iters)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::kernel::Kernel;
    use crate::linalg::Matrix;

    #[test]
    fn native_backend_matches_direct_call() {
        let mut rng = Rng::new(1);
        let x = Matrix::from_fn(20, 1, |_, _| rng.uniform());
        let k = Kernel::Rbf { sigma: 0.5 }.gram(&x);
        let basis = SpectralBasis::new(&k).unwrap();
        let y: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
        let plan = SpectralPlan::new(&basis, 0.25, 0.01);

        let mut s1 = ApgdState::zeros(20);
        let mut be = NativeBackend::new();
        let d1 = be.apgd_chunk(&basis, &plan, &y, 0.5, &mut s1, 25);

        let mut s2 = ApgdState::zeros(20);
        let mut ws = ApgdWorkspace::new(20);
        let d2 = run_chunk_native(&basis, &plan, &y, 0.5, &mut s2, &mut ws, 25);

        assert_eq!(d1, d2);
        assert_eq!(s1.b, s2.b);
        assert_eq!(s1.beta, s2.beta);
        assert_eq!(be.name(), "native");
    }
}
