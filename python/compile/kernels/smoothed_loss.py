"""L1 Pallas kernels: elementwise smoothed-loss derivatives.

H'_{γ,τ} (paper eq. 3) and the smooth-ReLU derivative V' (paper §3.1) as
tiled elementwise Pallas kernels. Scalars (τ, γ, η) are passed as (1,)
operands so one compiled kernel serves the whole (γ, τ) ladder.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

TILE = 8


def _h_prime_kernel(r_ref, tau_ref, gamma_ref, o_ref):
    r = r_ref[...]
    tau = tau_ref[0]
    gamma = gamma_ref[0]
    o_ref[...] = jnp.where(
        r < -gamma,
        tau - 1.0,
        jnp.where(r > gamma, tau, r / (2.0 * gamma) + tau - 0.5),
    )


@functools.partial(jax.jit, static_argnames=("tile",))
def pallas_h_prime(r, tau, gamma, tile: int = TILE):
    """z = H'_{γ,τ}(r) elementwise; r length must be a multiple of `tile`."""
    (n,) = r.shape
    assert n % tile == 0, f"length {n} not a multiple of tile {tile}"
    tau = jnp.asarray(tau, dtype=r.dtype).reshape((1,))
    gamma = jnp.asarray(gamma, dtype=r.dtype).reshape((1,))
    return pl.pallas_call(
        _h_prime_kernel,
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), r.dtype),
        interpret=True,
    )(r, tau, gamma)


def _relu_prime_kernel(t_ref, eta_ref, o_ref):
    t = t_ref[...]
    eta = eta_ref[0]
    o_ref[...] = jnp.where(t < -eta, 0.0, jnp.where(t > eta, 1.0, t / (2.0 * eta) + 0.5))


@functools.partial(jax.jit, static_argnames=("tile",))
def pallas_smooth_relu_prime(t, eta, tile: int = TILE):
    """q = V'(t) elementwise (η-smoothed ReLU derivative)."""
    (n,) = t.shape
    assert n % tile == 0, f"length {n} not a multiple of tile {tile}"
    eta = jnp.asarray(eta, dtype=t.dtype).reshape((1,))
    return pl.pallas_call(
        _relu_prime_kernel,
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), t.dtype),
        interpret=True,
    )(t, eta)
