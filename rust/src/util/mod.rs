//! Zero-dependency utility substrates: mini-JSON, CLI parsing, the bench
//! harness and a scoped timer/logging helper.

pub mod bench;
pub mod cli;
pub mod json;
pub mod timer;

pub use cli::Args;
pub use json::Json;
pub use timer::Timer;
