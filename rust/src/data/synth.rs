//! Simulation models from the paper's evaluation section.
//!
//! - [`friedman`]: the linear model of Friedman, Hastie & Tibshirani
//!   (2010), eq. (20) of the paper — used by Tables 1 (p=5000) and 3
//!   (p=100).
//! - [`yuan`]: the two-dimensional nonlinear surface of Yuan (2006),
//!   eq. (24) — used by Table 4 and the paper's headline "70s vs 700s"
//!   anecdote.

use super::dataset::Dataset;
use super::rng::Rng;
use crate::linalg::Matrix;

/// Friedman et al. (2010) simulation, paper eq. (20):
///
///   Y = Σ_j X_j β_j + c·Z,   β_j = (−1)^j exp(−(j−1)/10),  Z ~ N(0,1),
///
/// predictors N(0,1) with pairwise correlation ρ = 0.1, and `c` chosen so
/// the signal-to-noise ratio  Var(Xβ)/c² equals `snr` (3.0 in the paper).
pub fn friedman(n: usize, p: usize, snr: f64, rng: &mut Rng) -> Dataset {
    assert!(n > 0 && p > 0);
    // Equi-correlated Gaussians: X_j = sqrt(rho)*W + sqrt(1-rho)*Z_j gives
    // corr(X_i, X_j) = rho for i != j and Var(X_j) = 1.
    let rho: f64 = 0.1;
    let a = rho.sqrt();
    let b = (1.0 - rho).sqrt();
    let beta: Vec<f64> = (0..p)
        .map(|j| {
            let j1 = (j + 1) as f64; // paper indexes from 1
            let sign = if (j + 1) % 2 == 0 { 1.0 } else { -1.0 };
            sign * (-(j1 - 1.0) / 10.0).exp()
        })
        .collect();
    // Var(Xβ) under the equi-correlated design:
    //   Var = (1-ρ) Σ β_j² + ρ (Σ β_j)².
    let sum_b: f64 = beta.iter().sum();
    let sum_b2: f64 = beta.iter().map(|v| v * v).sum();
    let signal_var = (1.0 - rho) * sum_b2 + rho * sum_b * sum_b;
    let c = (signal_var / snr).sqrt();

    let mut x = Matrix::zeros(n, p);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let w = rng.normal();
        let mut xb = 0.0;
        {
            let row = x.row_mut(i);
            for j in 0..p {
                let v = a * w + b * rng.normal();
                row[j] = v;
                xb += v * beta[j];
            }
        }
        y.push(xb + c * rng.normal());
    }
    Dataset::new(format!("friedman(n={n},p={p},snr={snr})"), x, y)
}

/// Yuan (2006) two-dimensional model, paper eq. (24):
///
///   Y = 40·exp{8((x1−.5)² + (x2−.5)²)} /
///       (exp{8((x1−.2)² + (x2−.7)²)} + exp{8((x1−.7)² + (x2−.2)²)}) + ε,
///
/// x1, x2 ~ U(0,1), ε ~ N(0,1).
pub fn yuan(n: usize, rng: &mut Rng) -> Dataset {
    let mut x = Matrix::zeros(n, 2);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let x1 = rng.uniform();
        let x2 = rng.uniform();
        x[(i, 0)] = x1;
        x[(i, 1)] = x2;
        y.push(yuan_mean(x1, x2) + rng.normal());
    }
    Dataset::new(format!("yuan(n={n})"), x, y)
}

/// Noise-free Yuan (2006) regression surface (used to sanity-check fits).
pub fn yuan_mean(x1: f64, x2: f64) -> f64 {
    let num = 40.0 * (8.0 * ((x1 - 0.5).powi(2) + (x2 - 0.5).powi(2))).exp();
    let den = (8.0 * ((x1 - 0.2).powi(2) + (x2 - 0.7).powi(2))).exp()
        + (8.0 * ((x1 - 0.7).powi(2) + (x2 - 0.2).powi(2))).exp();
    num / den
}

/// A 1-D heteroscedastic sine model used by unit tests and the quickstart
/// example (quantiles have closed form: q_τ(x) = sin(2πx)·2 + σ(x)·Φ⁻¹(τ)).
pub fn sine_hetero(n: usize, rng: &mut Rng) -> Dataset {
    let mut x = Matrix::zeros(n, 1);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let xi = rng.uniform();
        x[(i, 0)] = xi;
        let sd = 0.5 + xi; // noise grows with x
        y.push(2.0 * (2.0 * std::f64::consts::PI * xi).sin() + sd * rng.normal());
    }
    Dataset::new(format!("sine_hetero(n={n})"), x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn friedman_shapes_and_snr() {
        let mut rng = Rng::new(11);
        let d = friedman(2000, 10, 3.0, &mut rng);
        assert_eq!(d.n(), 2000);
        assert_eq!(d.p(), 10);
        // empirical correlation of first two predictors ~ 0.1
        let n = d.n() as f64;
        let m0: f64 = (0..d.n()).map(|i| d.x[(i, 0)]).sum::<f64>() / n;
        let m1: f64 = (0..d.n()).map(|i| d.x[(i, 1)]).sum::<f64>() / n;
        let mut cov = 0.0;
        let mut v0 = 0.0;
        let mut v1 = 0.0;
        for i in 0..d.n() {
            let a = d.x[(i, 0)] - m0;
            let b = d.x[(i, 1)] - m1;
            cov += a * b;
            v0 += a * a;
            v1 += b * b;
        }
        let corr = cov / (v0.sqrt() * v1.sqrt());
        assert!((corr - 0.1).abs() < 0.08, "corr={corr}");
    }

    #[test]
    fn friedman_beta_signs_alternate() {
        // The response should correlate positively with X_2 (β_2 > 0) and
        // negatively with X_1 (β_1 < 0); check via large-sample covariances.
        let mut rng = Rng::new(21);
        let d = friedman(4000, 5, 3.0, &mut rng);
        let n = d.n() as f64;
        let my: f64 = d.y.iter().sum::<f64>() / n;
        for (j, expect_neg) in [(0usize, true), (1usize, false)] {
            let mx: f64 = (0..d.n()).map(|i| d.x[(i, j)]).sum::<f64>() / n;
            let cov: f64 = (0..d.n())
                .map(|i| (d.x[(i, j)] - mx) * (d.y[i] - my))
                .sum::<f64>()
                / n;
            assert_eq!(cov < 0.0, expect_neg, "j={j} cov={cov}");
        }
    }

    #[test]
    fn yuan_surface_known_values() {
        // Symmetric point: x1 = x2 = 0.5 → num = 40, den = 2·exp(8·0.13)
        let v = yuan_mean(0.5, 0.5);
        let expect = 40.0 / (2.0 * (8.0f64 * (0.09 + 0.04)).exp());
        assert!((v - expect).abs() < 1e-12);
        let mut rng = Rng::new(3);
        let d = yuan(500, &mut rng);
        assert_eq!(d.p(), 2);
        assert!(d.x.as_slice().iter().all(|&v| (0.0..1.0).contains(&v)));
    }

    #[test]
    fn sine_hetero_spread_grows() {
        let mut rng = Rng::new(5);
        let d = sine_hetero(4000, &mut rng);
        // residual spread on x<0.2 should be smaller than x>0.8
        let mut lo = vec![];
        let mut hi = vec![];
        for i in 0..d.n() {
            let x = d.x[(i, 0)];
            let r = d.y[i] - 2.0 * (2.0 * std::f64::consts::PI * x).sin();
            if x < 0.2 {
                lo.push(r);
            } else if x > 0.8 {
                hi.push(r);
            }
        }
        let sd = |v: &Vec<f64>| {
            let m = v.iter().sum::<f64>() / v.len() as f64;
            (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64).sqrt()
        };
        assert!(sd(&hi) > sd(&lo) + 0.3, "hi={} lo={}", sd(&hi), sd(&lo));
    }
}
