"""Pure-jnp oracles for the Pallas kernels and the L2 APGD chunk.

Everything here is the *specification*: the Pallas kernels
(`spectral_gemv.py`, `smoothed_loss.py`) and the AOT-compiled chunk
(`model.py`) are tested against these functions by pytest/hypothesis.
The Rust native backend implements the same recurrence; parity across all
three is what lets the coordinator swap backends freely.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


def gemv_ref(a, x):
    """o = A @ x."""
    return a @ x


def gemv_t_ref(a, x):
    """o = Aᵀ @ x."""
    return a.T @ x


def h_gamma_ref(t, tau, gamma):
    """γ-smoothed check loss H_{γ,τ} (paper eq. 3)."""
    return jnp.where(
        t < -gamma,
        (tau - 1.0) * t,
        jnp.where(
            t > gamma,
            tau * t,
            t * t / (4.0 * gamma) + t * (tau - 0.5) + gamma / 4.0,
        ),
    )


def h_gamma_prime_ref(t, tau, gamma):
    """H'_{γ,τ}: (τ−1) / t/(2γ)+τ−½ / τ on the three pieces."""
    return jnp.where(
        t < -gamma,
        tau - 1.0,
        jnp.where(t > gamma, tau, t / (2.0 * gamma) + tau - 0.5),
    )


def smooth_relu_prime_ref(t, eta):
    """V' of the η-smoothed ReLU (paper §3.1)."""
    return jnp.where(t < -eta, 0.0, jnp.where(t > eta, 1.0, t / (2.0 * eta) + 0.5))


def apgd_iteration_ref(u_mat, lam_diag, pil, p, lam_p, g, y, tau, gamma, nlam, state):
    """One accelerated APGD iteration in spectral coordinates.

    Mirrors `fastkqr::kqr::apgd::run_chunk_native` exactly (same update
    order, same Nesterov recurrence). state = (b, beta, b_prev, beta_prev,
    ck); returns (new_state, conv).
    """
    b, beta, b_prev, beta_prev, ck = state
    ck_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * ck * ck))
    mom = (ck - 1.0) / ck_next
    b_bar = b + mom * (b - b_prev)
    beta_bar = beta + mom * (beta - beta_prev)
    f = b_bar + u_mat @ (lam_diag * beta_bar)
    z = h_gamma_prime_ref(y - f, tau, gamma)
    t = u_mat.T @ z - nlam * beta_bar
    sum_z = jnp.sum(z)
    vkw = jnp.dot(lam_p, t)
    delta = g * (sum_z - vkw)
    two_g = 2.0 * gamma
    db = two_g * delta
    dbeta = two_g * (pil * t - delta * p)
    n = y.shape[0]
    conv = jnp.maximum(jnp.max(jnp.abs(t)), jnp.abs(sum_z) / n)
    return (b_bar + db, beta_bar + dbeta, b, beta, ck_next), conv


def apgd_chunk_ref(u_mat, lam_diag, pil, p, lam_p, g, y, tau, gamma, nlam,
                   b, beta, b_prev, beta_prev, ck, n_iters):
    """Pure-jnp reference for the whole chunk (python loop, no pallas)."""
    state = (b, beta, b_prev, beta_prev, ck)
    conv = jnp.asarray(jnp.inf, dtype=y.dtype)
    for _ in range(n_iters):
        state, conv = apgd_iteration_ref(
            u_mat, lam_diag, pil, p, lam_p, g, y, tau, gamma, nlam, state
        )
    b, beta, b_prev, beta_prev, ck = state
    return b, beta, b_prev, beta_prev, ck, conv
