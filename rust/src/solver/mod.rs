//! Multi-backend solver layer: APGD (finite smoothing) and pALM-SSN as
//! production peers behind one selection knob.
//!
//! The [`crate::kqr`] module owns the paper's finite-smoothing APGD;
//! [`ssn`] adds a preconditioned augmented Lagrangian / semismooth-Newton
//! backend (Deng–Li–Zhang, arXiv 2510.07929). Both certify against the
//! same exact check-loss objective and KKT report, so everything above
//! them — grids, artifacts, the serving path — is backend-agnostic.
//!
//! [`SolverBackend`] is the user-facing knob, threaded through
//! `FitSpec` (`"solver"` field), the CLI (`--solver`) and the wire
//! protocol. `Auto` resolves deterministically per problem through
//! [`auto_select`]: a small cost model over (n, representation rank,
//! grid size) that prefers SSN exactly where its r×r Newton systems
//! crush first-order iteration counts (thin bases, r ≪ n) and APGD
//! where the lockstep driver amortizes large grids.

pub mod ssn;

pub use ssn::{
    fit_warm_from, fit_warm_from_stats, fit_warm_from_stats_carried, FactorCarry, SsnState,
    SsnStats,
};

use crate::kqr::{KqrFit, KqrSolver};
use anyhow::{bail, Result};

/// Which optimizer fits each (τ, λ) cell.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SolverBackend {
    /// The paper's finite-smoothing accelerated proximal gradient
    /// descent (γ ladder + set expansion) — the default; its grid
    /// driver is the PR 2 lockstep BLAS-3 wavefront.
    #[default]
    Apgd,
    /// pALM semismooth Newton ([`ssn`]): active-set Newton systems of
    /// size (rank+1), strongest on thin bases (Nyström / RFF).
    Ssn,
    /// Resolve per problem via [`auto_select`] — deterministic from the
    /// spec alone (no timing, no environment).
    Auto,
}

impl SolverBackend {
    pub fn as_str(&self) -> &'static str {
        match self {
            SolverBackend::Apgd => "apgd",
            SolverBackend::Ssn => "ssn",
            SolverBackend::Auto => "auto",
        }
    }

    /// Strict name parsing (spec/CLI/protocol share it): unknown values
    /// are rejected, never defaulted.
    pub fn parse(name: &str) -> Result<SolverBackend> {
        match name {
            "apgd" => Ok(SolverBackend::Apgd),
            "ssn" => Ok(SolverBackend::Ssn),
            "auto" => Ok(SolverBackend::Auto),
            other => bail!("unknown solver {other:?} (apgd|ssn|auto)"),
        }
    }
}

impl std::fmt::Display for SolverBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Resolve `Auto` for a problem with `n` observations, spectral rank
/// `rank`, and `cells` (τ, λ) grid cells.
///
/// The model charges each backend its dominant per-cell term, in
/// arbitrary but common units:
///
/// - APGD: iterations × O(n·r) GEMV work ≈ `400·n·r`, halved on grids
///   of ≥ 8 cells where the lockstep bundle driver amortizes the GEMMs;
/// - SSN: a few dozen Newton/refresh passes of O(n·r) plus a Newton
///   factorization budget of O(r³) ≈ `8·r³` — but the grid drivers
///   carry the active-set Cholesky factor cell to cell, so on a grid
///   only the head cell pays the budget in full and every subsequent
///   cell pays roughly a quarter of it in rank-1 seeding (the carry
///   residual measured against the `BENCH_grid.json` snapshots under
///   `benchmarks/`): per cell, `25·n·r + 8·r³·(1 + 0.25(c−1))/c`.
///
/// On a dense basis (r = n) the cubic term makes SSN lose for all but
/// tiny n; on thin bases (r ≪ n) SSN wins outright; in between, large
/// grids now tip toward SSN because the factor budget amortizes. The
/// constants are calibration, not measurement — what matters is that
/// the decision is a pure function of the spec, so `Auto` is
/// reproducible anywhere.
pub fn auto_select(n: usize, rank: usize, cells: usize) -> SolverBackend {
    let (nf, rf, cf) = (n as f64, rank.max(1) as f64, cells.max(1) as f64);
    let mut apgd = 400.0 * nf * rf;
    if cells >= 8 {
        apgd *= 0.5;
    }
    let factor_budget = 8.0 * rf * rf * rf * (1.0 + 0.25 * (cf - 1.0)) / cf;
    let ssn = 25.0 * nf * rf + factor_budget;
    if ssn < apgd {
        SolverBackend::Ssn
    } else {
        SolverBackend::Apgd
    }
}

/// Cost-model inputs and the backend [`auto_select`] resolved from
/// them — kept together so the CLI status line and the server metrics
/// can report *why* `Auto` picked what it picked.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AutoResolution {
    pub n: usize,
    pub rank: usize,
    pub cells: usize,
    pub backend: SolverBackend,
}

/// [`auto_select`] with the inputs echoed back alongside the decision.
pub fn auto_resolve(n: usize, rank: usize, cells: usize) -> AutoResolution {
    AutoResolution { n, rank, cells, backend: auto_select(n, rank, cells) }
}

/// Grid-level SSN factor-reuse accounting, summed over every cell a
/// grid driver fitted (the sequential carry columns or the bundled
/// wavefront). Surfaced through `GridFit`/`ModelSet` diagnostics and
/// the server's `ssn_refactorizations` / `ssn_rank1_updates` counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SsnGridStats {
    /// Grid cells fitted through the SSN backend.
    pub cells: usize,
    /// Total Newton steps across all cells.
    pub newton_steps: usize,
    /// Outer (multiplier) rounds across all cells.
    pub outer_rounds: usize,
    /// Full Newton-system refactorizations.
    pub refactorizations: usize,
    /// Rank-1 Cholesky up/downdates (maintenance + carry seeding).
    pub rank1_updates: usize,
    /// Inner solves seeded from a carried factor instead of refactoring.
    pub carried_seeds: usize,
    /// Shared-factor bundles formed by the bundled driver (0 for the
    /// sequential carry columns).
    pub bundles: usize,
    /// Cells that adopted a bundle leader's factor in some round.
    pub bundle_adoptions: usize,
}

impl SsnGridStats {
    /// Fold one cell's per-fit counters in.
    pub fn absorb(&mut self, s: &SsnStats) {
        self.newton_steps += s.newton_steps;
        self.outer_rounds += s.outer_rounds;
        self.refactorizations += s.refactors;
        self.rank1_updates += s.updates;
        self.carried_seeds += s.carried;
    }

    /// Merge another driver's totals (chunked grid workers).
    pub fn merge(&mut self, o: &SsnGridStats) {
        self.cells += o.cells;
        self.newton_steps += o.newton_steps;
        self.outer_rounds += o.outer_rounds;
        self.refactorizations += o.refactorizations;
        self.rank1_updates += o.rank1_updates;
        self.carried_seeds += o.carried_seeds;
        self.bundles += o.bundles;
        self.bundle_adoptions += o.bundle_adoptions;
    }
}

/// Fit a run of τ columns with pALM-SSN, seeding each column's
/// largest-λ fit from its predecessor's — the SSN mirror of the
/// engine's sequential APGD driver, with the multipliers and penalty
/// carried alongside the primal in both grid directions.
///
/// This is the **per-cell oracle**: no factor carry, decisions
/// identical to the original per-cell path. The production grid path
/// goes through [`fit_tau_columns_ssn_carry`].
pub fn fit_tau_columns_ssn(
    solver: &KqrSolver,
    taus: &[f64],
    lambdas: &[f64],
) -> Result<Vec<Vec<KqrFit>>> {
    Ok(fit_tau_columns_ssn_stats(solver, taus, lambdas)?.0)
}

/// [`fit_tau_columns_ssn`] returning the summed work counters — same
/// fits, same decisions; the stats exist so benches and parity tests
/// can compare oracle refactorization counts against the carry path.
pub fn fit_tau_columns_ssn_stats(
    solver: &KqrSolver,
    taus: &[f64],
    lambdas: &[f64],
) -> Result<(Vec<Vec<KqrFit>>, SsnGridStats)> {
    let mut cols = Vec::with_capacity(taus.len());
    let mut stats = SsnGridStats::default();
    let mut seed: Option<SsnState> = None;
    for &tau in taus {
        let (col, head_state) =
            fit_tau_column_ssn_impl(solver, tau, lambdas, seed.take(), false, &mut stats)?;
        seed = Some(head_state);
        cols.push(col);
    }
    Ok((cols, stats))
}

/// The carry-enabled grid driver: identical warm-start topology to
/// [`fit_tau_columns_ssn`], but every cell runs through
/// [`ssn::fit_warm_from_stats_carried`], so the converged active set
/// and its Cholesky factor flow down each λ column and across τ column
/// heads, seeding each cell's Newton systems by rank-1 up/downdates.
pub fn fit_tau_columns_ssn_carry(
    solver: &KqrSolver,
    taus: &[f64],
    lambdas: &[f64],
) -> Result<(Vec<Vec<KqrFit>>, SsnGridStats)> {
    let mut cols = Vec::with_capacity(taus.len());
    let mut stats = SsnGridStats::default();
    let mut seed: Option<SsnState> = None;
    for &tau in taus {
        let (col, head_state) =
            fit_tau_column_ssn_impl(solver, tau, lambdas, seed.take(), true, &mut stats)?;
        seed = Some(head_state);
        cols.push(col);
    }
    Ok((cols, stats))
}

/// One warm-started descending-λ SSN column, optionally seeded from an
/// adjacent τ's state. Returns the fits plus the state at the **head**
/// (largest-λ) cell, which seeds the next column exactly like the APGD
/// driver's cross-column `ApgdState` carry.
pub fn fit_tau_column_ssn(
    solver: &KqrSolver,
    tau: f64,
    lambdas: &[f64],
    seed: Option<SsnState>,
) -> Result<(Vec<KqrFit>, SsnState)> {
    let mut stats = SsnGridStats::default();
    fit_tau_column_ssn_impl(solver, tau, lambdas, seed, false, &mut stats)
}

fn fit_tau_column_ssn_impl(
    solver: &KqrSolver,
    tau: f64,
    lambdas: &[f64],
    seed: Option<SsnState>,
    carry: bool,
    stats: &mut SsnGridStats,
) -> Result<(Vec<KqrFit>, SsnState)> {
    let mut state =
        seed.unwrap_or_else(|| SsnState::zeros(solver.n(), solver.basis.dim()));
    let mut fits = Vec::with_capacity(lambdas.len());
    let mut head_state: Option<SsnState> = None;
    for &lam in lambdas {
        let (fit, s) = if carry {
            ssn::fit_warm_from_stats_carried(solver, tau, lam, &mut state)?
        } else {
            ssn::fit_warm_from_stats(solver, tau, lam, &mut state)?
        };
        stats.cells += 1;
        stats.absorb(&s);
        if head_state.is_none() {
            // Clone after the head fit so the next column inherits the
            // head cell's iterate — and, under carry, its factor.
            head_state = Some(state.clone());
        }
        fits.push(fit);
    }
    Ok((fits, head_state.expect("at least one lambda")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_names_round_trip() {
        for b in [SolverBackend::Apgd, SolverBackend::Ssn, SolverBackend::Auto] {
            assert_eq!(SolverBackend::parse(b.as_str()).unwrap(), b);
        }
        let err = SolverBackend::parse("newton").unwrap_err().to_string();
        assert!(err.contains("unknown solver") && err.contains("apgd|ssn|auto"), "{err}");
    }

    #[test]
    fn auto_prefers_ssn_on_thin_bases_and_apgd_on_dense() {
        // Nyström r=64 at n=4096: Newton systems are tiny, SSN wins.
        assert_eq!(auto_select(4096, 64, 1), SolverBackend::Ssn);
        // Dense basis at the same n: r³ dominates, APGD wins.
        assert_eq!(auto_select(4096, 4096, 1), SolverBackend::Apgd);
        // Large lockstep-amortized grid keeps APGD competitive longer:
        // r where single-cell SSN would win can flip back on big grids.
        assert_eq!(auto_select(512, 512, 64), SolverBackend::Apgd);
        // Grid awareness: a mid-rank basis where a single cell's r³
        // factorization budget sinks SSN flips once the carry amortizes
        // that budget across a 16-cell grid.
        assert_eq!(auto_select(1024, 256, 1), SolverBackend::Apgd);
        assert_eq!(auto_select(1024, 256, 16), SolverBackend::Ssn);
        // Decision is a pure function — repeated calls agree.
        for _ in 0..3 {
            assert_eq!(auto_select(4096, 64, 9), auto_select(4096, 64, 9));
        }
    }

    #[test]
    fn auto_never_returns_auto() {
        for &(n, r, c) in &[(10usize, 10usize, 1usize), (1000, 32, 4), (50, 50, 100)] {
            assert_ne!(auto_select(n, r, c), SolverBackend::Auto);
        }
    }
}
