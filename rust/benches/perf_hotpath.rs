//! Hot-path microbenchmarks: GEMV bandwidth, the parallel substrate
//! (serial vs row-blocked multi-thread GEMV and Gram construction — the
//! engine-layer lever; target ≥ 2x at n = 2000 on ≥ 4 cores), APGD chunk
//! (native vs XLA), eigendecomposition, end-to-end fit latency. Feeds
//! EXPERIMENTS.md §Perf.
use fastkqr::experiments::perf;
use fastkqr::linalg::{par, simd};
use fastkqr::util::Args;

fn main() {
    let args = Args::from_env();
    let reps = args.get_usize("reps", 20);
    println!("-- GEMV (the 2x-per-iteration hot spot) --");
    for n in args.get_usize_list("ns", &[128, 256, 512, 1024]) {
        let (stats, gbps) = perf::gemv_throughput(n, reps);
        println!("{}  ({gbps:.2} GB/s effective)", stats.report_line());
    }
    println!("-- packed tiled GEMM (tiles via FASTKQR_GEMM_MC/KC/NC) --");
    for n in args.get_usize_list("gemm-ns", &[256, 512]) {
        let (stats, gflops) = perf::gemm_gflops(n, reps.min(5));
        println!("{}  ({gflops:.2} GFLOP/s)", stats.report_line());
    }
    let table = simd::global();
    println!(
        "-- SIMD dispatch: isa={} fma={} (FASTKQR_SIMD/FASTKQR_FMA to override) --",
        table.isa.as_str(),
        table.fma
    );
    for n in args.get_usize_list("simd-ns", &[256, 512, 1024]) {
        let (scalar, dispatched, speedup) = perf::gemv_simd_speedup(n, reps.min(10));
        println!("{}", scalar.report_line());
        println!("{}", dispatched.report_line());
        println!("   gemv n={n}: {speedup:.2}x scalar -> {}", table.isa.as_str());
        let (_, gf_scalar) = perf::gemm_gflops_with(n, reps.min(5), simd::scalar());
        let (_, gf_simd) = perf::gemm_gflops_with(n, reps.min(5), table);
        println!(
            "   gemm n={n}: {gf_scalar:.2} -> {gf_simd:.2} GFLOP/s ({:.2}x)",
            gf_simd / gf_scalar.max(1e-12)
        );
    }
    println!(
        "-- parallel substrate: serial vs {} threads (FASTKQR_THREADS to override) --",
        par::global().threads
    );
    for n in args.get_usize_list("par-ns", &[512, 1024, 2000]) {
        let (serial, parallel, speedup, workers) = perf::gemv_parallel_speedup(n, reps.min(10));
        println!("{}", serial.report_line());
        println!("{}", parallel.report_line());
        println!("   gemv n={n}: {speedup:.2}x speedup on {workers} threads");
    }
    for n in args.get_usize_list("gram-ns", &[1000, 2000]) {
        let (serial, parallel, speedup, workers) = perf::gram_parallel_speedup(n, reps.min(5));
        println!("{}", serial.report_line());
        println!("{}", parallel.report_line());
        println!("   gram n={n}: {speedup:.2}x speedup on {workers} threads");
    }
    println!("-- APGD chunk: native vs AOT/PJRT --");
    for n in args.get_usize_list("chunk-ns", &[64, 256, 512]) {
        for s in perf::chunk_cost(n, reps.min(10)).unwrap() {
            println!("{}", s.report_line());
        }
    }
    println!("-- one-time eigendecomposition --");
    for n in args.get_usize_list("eig-ns", &[128, 256, 512]) {
        println!("{}", perf::eigen_cost(n, 3).report_line());
    }
    println!("-- end-to-end fit latency --");
    println!("{}", perf::fit_latency(args.get_usize("fit-n", 200), 3).report_line());
}
