//! Data substrate: RNG, dataset container, the paper's simulation models
//! and benchmark-data lookalikes (see DESIGN.md §3 for the substitution
//! rationale).

pub mod benchmarks;
pub mod dataset;
pub mod rng;
pub mod synth;

pub use dataset::Dataset;
pub use rng::Rng;
