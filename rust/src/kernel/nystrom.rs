//! Nyström kernel approximation — the paper's §5 extension, implemented.
//!
//! The paper's closing discussion proposes integrating "random features
//! (Rahimi & Recht 2007) or Nyström subsampling (Rudi et al. 2015) …
//! within the exact update formula of kernel quantile regression". The
//! spectral machinery makes this a drop-in: fastkqr only touches K
//! through its eigendecomposition, so replacing the O(n³) `SymEigen` of
//! the full Gram matrix with the rank-m Nyström factorization gives the
//! same APGD/finite-smoothing algorithm on the approximate kernel
//!
//!   K̃ = K_nm K_mm⁻¹ K_mn = U S Uᵀ     (rank ≤ m)
//!
//! at O(n·m² + m³) setup instead of O(n³). The solver then computes the
//! **exact** KQR solution of the K̃-induced RKHS problem — exactness
//! machinery, KKT certificate and all — which is the sense in which the
//! paper's "exact update formula" is preserved.
//!
//! Construction (standard): with landmark set Z (m rows of X),
//! K_mm = VDVᵀ, B = K_nm V D^{-1/2} (n×m, dropping negligible D), then
//! BᵀB = WSWᵀ gives the thin factor U = B W S^{-1/2} with orthonormal
//! columns and K̃ = BBᵀ. U is zero-padded to n×n so every downstream
//! structure (state sizes, the AOT artifacts) is unchanged; the padded
//! eigenvalues are 0 and therefore inert in all spectral formulas.

use super::Kernel;
use crate::data::rng::Rng;
use crate::linalg::{gemm_into, gemv_t, Matrix, SymEigen};
use crate::spectral::SpectralBasis;
use anyhow::{bail, Result};

/// Result of the Nyström construction.
pub struct NystromApprox {
    /// Dense approximate Gram matrix K̃ (needed by the eq.-(8)/(19)
    /// K_SS projection solves).
    pub gram: Matrix,
    /// Spectral basis of K̃ (rank ≤ m, zero-padded to n).
    pub basis: SpectralBasis,
    /// Landmark row indices actually used.
    pub landmarks: Vec<usize>,
    /// Numerical rank retained.
    pub rank: usize,
}

/// Build the rank-`m` Nyström approximation of `kernel` on the rows of
/// `x`, sampling landmarks uniformly with `rng`.
pub fn nystrom(x: &Matrix, kernel: &Kernel, m: usize, rng: &mut Rng) -> Result<NystromApprox> {
    let n = x.rows();
    if m == 0 || m > n {
        bail!("nystrom: need 0 < m <= n (got m={m}, n={n})");
    }
    // landmarks: uniform sample without replacement
    let perm = rng.permutation(n);
    let mut landmarks: Vec<usize> = perm[..m].to_vec();
    landmarks.sort_unstable();
    let z = Matrix::from_fn(m, x.cols(), |i, j| x[(landmarks[i], j)]);

    // K_mm = V D Vᵀ (+ tiny ridge via eigenvalue clamping below)
    let kmm = kernel.gram(&z);
    let eig_mm = SymEigen::new(&kmm);
    let dmax = eig_mm.values.last().copied().unwrap_or(0.0).max(1e-300);
    let keep: Vec<usize> =
        (0..m).filter(|&j| eig_mm.values[j] > 1e-12 * dmax).collect();
    if keep.is_empty() {
        bail!("nystrom: landmark kernel matrix is numerically zero");
    }

    // B = K_nm V D^{-1/2}  (n × r)
    let knm = kernel.cross_gram(x, &z);
    let r0 = keep.len();
    let mut b = Matrix::zeros(n, r0);
    for (col, &j) in keep.iter().enumerate() {
        let inv_sqrt = 1.0 / eig_mm.values[j].sqrt();
        for i in 0..n {
            let mut s = 0.0;
            for k in 0..m {
                s += knm[(i, k)] * eig_mm.vectors[(k, j)];
            }
            b[(i, col)] = s * inv_sqrt;
        }
    }

    // BᵀB = W S Wᵀ  (r0 × r0), through the packed tiled GEMM
    let btb = {
        let bt = b.transpose();
        let mut c = Matrix::zeros(r0, r0);
        gemm_into(&bt, &b, &mut c);
        c
    };
    let eig_c = SymEigen::new(&btb);
    let smax = eig_c.values.last().copied().unwrap_or(0.0).max(1e-300);
    // keep descending-significance components
    let keep_c: Vec<usize> =
        (0..r0).filter(|&j| eig_c.values[j] > 1e-12 * smax).collect();
    let rank = keep_c.len();

    // thin U = B W S^{-1/2}, written into the zero-padded n×n basis with
    // ASCENDING eigenvalue order to match SymEigen conventions: the kept
    // components go in the LAST `rank` columns.
    let mut u = Matrix::zeros(n, n);
    let mut lambda = vec![0.0; n];
    for (slot, &j) in keep_c.iter().enumerate() {
        let col = n - rank + slot; // eig_c.values ascending over keep_c
        let s = eig_c.values[j];
        let inv_sqrt = 1.0 / s.sqrt();
        for i in 0..n {
            let mut acc = 0.0;
            for k in 0..r0 {
                acc += b[(i, k)] * eig_c.vectors[(k, j)];
            }
            u[(i, col)] = acc * inv_sqrt;
        }
        lambda[col] = s;
    }

    // K̃ = B Bᵀ (dense, O(n²·r0), packed tiled GEMM)
    let gram = {
        let bt = b.transpose();
        let mut c = Matrix::zeros(n, n);
        gemm_into(&b, &bt, &mut c);
        c
    };

    let ones = vec![1.0; n];
    let mut u1 = vec![0.0; n];
    gemv_t(&u, &ones, &mut u1);
    let basis = SpectralBasis { n, u, lambda, u1 };
    Ok(NystromApprox { gram, basis, landmarks, rank })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kernel::median_heuristic_sigma;
    use crate::kqr::KqrSolver;

    fn fixture(n: usize, seed: u64) -> (Matrix, Vec<f64>, Kernel) {
        let mut rng = Rng::new(seed);
        let d = synth::sine_hetero(n, &mut rng);
        let sigma = median_heuristic_sigma(&d.x);
        (d.x, d.y, Kernel::Rbf { sigma })
    }

    #[test]
    fn full_landmarks_reproduce_gram() {
        let (x, _, kernel) = fixture(30, 1);
        let mut rng = Rng::new(2);
        let ny = nystrom(&x, &kernel, 30, &mut rng).unwrap();
        let exact = kernel.gram(&x);
        assert!(
            ny.gram.max_abs_diff(&exact) < 1e-8,
            "m=n Nyström must be exact: {}",
            ny.gram.max_abs_diff(&exact)
        );
    }

    #[test]
    fn basis_reconstructs_gram_approx() {
        let (x, _, kernel) = fixture(40, 3);
        let mut rng = Rng::new(4);
        let ny = nystrom(&x, &kernel, 15, &mut rng).unwrap();
        // U Λ Uᵀ == K̃
        let n = 40;
        for probe in 0..8 {
            let i = (probe * 5) % n;
            let j = (probe * 7 + 3) % n;
            let mut s = 0.0;
            for k in 0..n {
                s += ny.basis.u[(i, k)] * ny.basis.lambda[k] * ny.basis.u[(j, k)];
            }
            assert!(
                (s - ny.gram[(i, j)]).abs() < 1e-9,
                "UΛUᵀ[{i},{j}]={s} vs K̃={}",
                ny.gram[(i, j)]
            );
        }
        assert!(ny.rank <= 15);
        assert_eq!(ny.landmarks.len(), 15);
    }

    #[test]
    fn orthonormal_retained_columns() {
        let (x, _, kernel) = fixture(25, 5);
        let mut rng = Rng::new(6);
        let ny = nystrom(&x, &kernel, 10, &mut rng).unwrap();
        let n = 25;
        for a in (n - ny.rank)..n {
            for b in (n - ny.rank)..n {
                let mut s = 0.0;
                for i in 0..n {
                    s += ny.basis.u[(i, a)] * ny.basis.u[(i, b)];
                }
                let expect = if a == b { 1.0 } else { 0.0 };
                assert!((s - expect).abs() < 1e-9, "UᵀU[{a},{b}]={s}");
            }
        }
    }

    #[test]
    fn kqr_on_nystrom_basis_close_to_exact() {
        // The §5 extension end-to-end: solve KQR on K̃ with the unchanged
        // finite smoothing machinery. The objective approaches the
        // exact-kernel one as m grows; at m = n the full certificate
        // passes (K̃ = K). For m < n the rank-deficient certificate is
        // *conservative* (the clamp candidate ĝ is not the projected-norm
        // minimizer over the subgradient box), so we assert convergence
        // of the objective rather than `kkt.pass`.
        let (x, y, kernel) = fixture(60, 7);
        let exact = KqrSolver::new(&x, &y, kernel.clone()).unwrap().fit(0.5, 1e-2).unwrap();
        let mut prev_gap = f64::INFINITY;
        for m in [10usize, 40] {
            let mut rng = Rng::new(8);
            let ny = nystrom(&x, &kernel, m, &mut rng).unwrap();
            let solver = KqrSolver::with_basis(
                &x,
                &y,
                kernel.clone(),
                std::sync::Arc::new(ny.gram),
                std::sync::Arc::new(ny.basis),
            );
            let fit = solver.fit(0.5, 1e-2).unwrap();
            let gap = (fit.objective - exact.objective).abs();
            assert!(gap <= prev_gap + 1e-6, "gap did not shrink: m={m} {gap} vs {prev_gap}");
            prev_gap = gap;
        }
        assert!(prev_gap < 0.05 * (1.0 + exact.objective), "m=40 gap {prev_gap}");
        // m = n: the approximation is exact and the certificate holds
        let mut rng = Rng::new(9);
        let ny = nystrom(&x, &kernel, 60, &mut rng).unwrap();
        let solver = KqrSolver::with_basis(
            &x,
            &y,
            kernel.clone(),
            std::sync::Arc::new(ny.gram),
            std::sync::Arc::new(ny.basis),
        );
        let fit = solver.fit(0.5, 1e-2).unwrap();
        assert!(
            (fit.objective - exact.objective).abs() < 1e-4 * (1.0 + exact.objective),
            "m=n objective {} vs exact {}",
            fit.objective,
            exact.objective
        );
    }

    #[test]
    fn rejects_bad_m() {
        let (x, _, kernel) = fixture(10, 9);
        let mut rng = Rng::new(1);
        assert!(nystrom(&x, &kernel, 0, &mut rng).is_err());
        assert!(nystrom(&x, &kernel, 11, &mut rng).is_err());
    }
}
