//! Versioned JSON model artifacts.
//!
//! An artifact is everything `predict` needs — resolved kernel, training
//! inputs, per-level coefficients — plus the fit provenance (objective,
//! KKT report, iteration counts), in one self-describing document:
//!
//! ```json
//! { "format": "fastkqr.model", "format_version": 1,
//!   "created_by": "fastkqr 0.1.0", "kind": "kqr|set|nckqr",
//!   "kernel": {"type":"rbf","sigma":…}, "x_train": [[…]…], … }
//! ```
//!
//! Numbers are written with Rust's shortest-round-trip float formatting,
//! so every f64 — coefficients, intercepts, training inputs — reloads to
//! the identical bit pattern and a reloaded model's predictions equal the
//! original's bitwise. Readers accept any `format_version` ≤ theirs and
//! reject newer documents loudly instead of misreading them.
//!
//! **Compressed low-rank documents (format_version 2).** A fit produced
//! on a Nyström basis persists `"repr":"lowrank"` with the m landmark
//! inputs `z`, their training-row indices, `n_train`, and per-fit
//! m-dimensional kernel weights `w` — **no** `x_train` and no
//! n-dimensional α, so the artifact is O(m·p) instead of O(n·p + n) per
//! fit. Prediction from a reloaded document goes through the identical
//! landmark path the in-memory model uses, so it stays bitwise. Dense
//! models keep writing format_version 1 (older readers stay compatible);
//! version-1 readers reject low-rank documents loudly instead of
//! misreading them.
//!
//! **Random-feature documents (format_version 3).** A fit produced on a
//! random Fourier feature basis persists `"repr":"rff"` with the D×p
//! frequency matrix, the D phases, the drawing seed and `n_train`, plus
//! one D-dimensional feature weight vector `w` per fit — the artifact is
//! O(D·p) **independent of n**, smaller than any landmark document once
//! n outgrows D. The √(2/D) normalizer is recomputed from D on load
//! (bit-identical), so a reloaded model's predictions equal the
//! original's bitwise. Each version is the lowest that can represent the
//! model; older readers reject newer documents loudly.

use super::model::{shape_from_json, shape_to_json, CvSummary, ModelSet, QuantileModel};
use super::{kernel_from_json, kernel_to_json, matrix_from_json, matrix_to_json};
use crate::kernel::rff::RffMap;
use crate::kernel::Kernel;
use crate::kqr::kkt::KktReport;
use crate::kqr::KqrFit;
use crate::linalg::Matrix;
use crate::nckqr::{LevelCoef, NcLowRank, NcRff, NckqrFit};
use crate::spectral::{LowRankCoef, RffCoef};
use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// Highest artifact document version this build reads. [`to_json`]
/// writes the lowest version that can represent the model: 1 (dense),
/// 2 (compressed low-rank) or 3 (random features).
pub const ARTIFACT_VERSION: u64 = 3;
/// Magic `format` tag distinguishing model artifacts from other JSON.
pub const ARTIFACT_FORMAT: &str = "fastkqr.model";

fn kqr_fit_to_json(f: &KqrFit) -> Json {
    let mut pairs = vec![
        ("tau", Json::num(f.tau)),
        ("lambda", Json::num(f.lam)),
        ("b", Json::num(f.b)),
    ];
    // Compressed fits persist the small weight vector instead of the
    // n-dim α — that single choice is what makes the artifact O(m)
    // (landmark weights) or O(D) (feature weights).
    match (&f.rff, &f.lowrank) {
        (Some(rf), _) => pairs.push(("w", Json::arr_f64(&rf.w))),
        (None, Some(lr)) => pairs.push(("w", Json::arr_f64(&lr.w))),
        (None, None) => pairs.push(("alpha", Json::arr_f64(&f.alpha))),
    }
    pairs.extend(vec![
        ("objective", Json::num(f.objective)),
        ("gamma_final", Json::num(f.gamma_final)),
        ("apgd_iters", Json::num(f.apgd_iters as f64)),
        ("expansions", Json::num(f.expansions as f64)),
        ("singular_set", Json::arr_usize(&f.singular_set)),
        ("kkt", f.kkt.to_json()),
    ]);
    Json::obj(pairs)
}

fn kqr_fit_from_json(v: &Json, x_train: &Arc<Matrix>, kernel: &Kernel) -> Result<KqrFit> {
    let need = |key: &str| v.get_f64(key).ok_or_else(|| anyhow!("fit: missing {key:?}"));
    let alpha = v
        .get_f64_arr_strict("alpha")
        .ok_or_else(|| anyhow!("fit: missing 'alpha'"))?;
    if alpha.len() != x_train.rows() {
        bail!("fit: len(alpha)={} != n_train={}", alpha.len(), x_train.rows());
    }
    let kkt = KktReport::from_json(v.get("kkt").ok_or_else(|| anyhow!("fit: missing 'kkt'"))?)?;
    Ok(KqrFit::assemble(
        need("tau")?,
        need("lambda")?,
        need("b")?,
        alpha,
        need("objective")?,
        kkt,
        need("gamma_final")?,
        v.get_usize("apgd_iters").unwrap_or(0),
        v.get_usize("expansions").unwrap_or(0),
        v.get_usize_arr("singular_set").unwrap_or_default(),
        None,
        None,
        x_train.clone(),
        kernel.clone(),
    ))
}

/// Parse one compressed low-rank fit object (`"w"` instead of `"alpha"`).
fn kqr_fit_from_json_lowrank(
    v: &Json,
    z: &Arc<Matrix>,
    landmarks: &[usize],
    n_train: usize,
    kernel: &Kernel,
) -> Result<KqrFit> {
    let need = |key: &str| v.get_f64(key).ok_or_else(|| anyhow!("fit: missing {key:?}"));
    let w = v.get_f64_arr_strict("w").ok_or_else(|| anyhow!("lowrank fit: missing 'w'"))?;
    if w.len() != z.rows() {
        bail!("lowrank fit: len(w)={} != landmarks m={}", w.len(), z.rows());
    }
    let kkt = KktReport::from_json(v.get("kkt").ok_or_else(|| anyhow!("fit: missing 'kkt'"))?)?;
    Ok(KqrFit::assemble_compressed(
        need("tau")?,
        need("lambda")?,
        need("b")?,
        need("objective")?,
        kkt,
        need("gamma_final")?,
        v.get_usize("apgd_iters").unwrap_or(0),
        v.get_usize("expansions").unwrap_or(0),
        v.get_usize_arr("singular_set").unwrap_or_default(),
        n_train,
        LowRankCoef { z: z.clone(), landmarks: landmarks.to_vec(), w },
        kernel.clone(),
    ))
}

/// Parse one random-feature fit object (`"w"` holds the D-dimensional
/// feature weights).
fn kqr_fit_from_json_rff(
    v: &Json,
    map: &Arc<RffMap>,
    n_train: usize,
    kernel: &Kernel,
) -> Result<KqrFit> {
    let need = |key: &str| v.get_f64(key).ok_or_else(|| anyhow!("fit: missing {key:?}"));
    let w = v.get_f64_arr_strict("w").ok_or_else(|| anyhow!("rff fit: missing 'w'"))?;
    if w.len() != map.d() {
        bail!("rff fit: len(w)={} != d={}", w.len(), map.d());
    }
    let kkt = KktReport::from_json(v.get("kkt").ok_or_else(|| anyhow!("fit: missing 'kkt'"))?)?;
    Ok(KqrFit::assemble_compressed_rff(
        need("tau")?,
        need("lambda")?,
        need("b")?,
        need("objective")?,
        kkt,
        need("gamma_final")?,
        v.get_usize("apgd_iters").unwrap_or(0),
        v.get_usize("expansions").unwrap_or(0),
        v.get_usize_arr("singular_set").unwrap_or_default(),
        n_train,
        RffCoef { map: map.clone(), w },
        kernel.clone(),
    ))
}

/// Shared header of a compressed low-rank document (every kind writes
/// the same four keys): landmark indices, landmark inputs Z, original
/// training size.
fn push_lowrank_header<'a>(
    pairs: &mut Vec<(&'a str, Json)>,
    z: &Matrix,
    landmarks: &[usize],
    n_train: usize,
) {
    pairs.push(("repr", Json::str("lowrank")));
    pairs.push(("landmarks", Json::arr_usize(landmarks)));
    pairs.push(("z", matrix_to_json(z)));
    pairs.push(("n_train", Json::num(n_train as f64)));
}

/// Shared header of a random-feature document: the seed-pinned map
/// (frequencies + phases + seed) and the original training size. The
/// √(2/D) normalizer is a function of D and is recomputed on load.
fn push_rff_header<'a>(pairs: &mut Vec<(&'a str, Json)>, map: &RffMap, n_train: usize) {
    pairs.push(("repr", Json::str("rff")));
    pairs.push(("freqs", matrix_to_json(&map.freqs)));
    pairs.push(("phases", Json::arr_f64(&map.phases)));
    pairs.push(("rff_seed", Json::num(map.seed as f64)));
    pairs.push(("n_train", Json::num(n_train as f64)));
}

/// Serialize a model to the artifact document. Errors on an empty fit
/// set (which [`from_json`] would reject anyway) or a set mixing gram
/// representations (impossible from one solver).
pub fn to_json(model: &QuantileModel) -> Result<Json> {
    // Lowest version that represents the document (see ARTIFACT_VERSION).
    let fit_version = |lowrank: bool, rff: bool| if rff { 3u64 } else if lowrank { 2 } else { 1 };
    let version: u64 = match model {
        QuantileModel::Kqr(f) => fit_version(f.lowrank.is_some(), f.rff.is_some()),
        QuantileModel::Set(s) => s
            .fits
            .first()
            .map(|f| fit_version(f.lowrank.is_some(), f.rff.is_some()))
            .unwrap_or(1),
        QuantileModel::Nckqr(f) => fit_version(f.lowrank.is_some(), f.rff.is_some()),
    };
    let mut pairs = vec![
        ("format", Json::str(ARTIFACT_FORMAT)),
        ("format_version", Json::num(version as f64)),
        ("created_by", Json::str(format!("fastkqr {}", crate::version()))),
        ("kind", Json::str(model.kind())),
    ];
    match model {
        QuantileModel::Kqr(f) => {
            pairs.push(("kernel", kernel_to_json(f.kernel())));
            match (&f.rff, &f.lowrank) {
                (Some(rf), _) => push_rff_header(&mut pairs, &rf.map, f.n_train()),
                (None, Some(lr)) => {
                    push_lowrank_header(&mut pairs, &lr.z, &lr.landmarks, f.n_train())
                }
                (None, None) => pairs.push(("x_train", matrix_to_json(f.x_train()))),
            }
            pairs.push(("fit", kqr_fit_to_json(f)));
        }
        QuantileModel::Set(s) => {
            // All fits of a set share one solver, hence one kernel and
            // one Arc'd design matrix / landmark set — serialize once.
            let head = s
                .fits
                .first()
                .ok_or_else(|| anyhow!("cannot serialize an empty model set"))?;
            if s.fits.iter().any(|f| {
                f.lowrank.is_some() != head.lowrank.is_some()
                    || f.rff.is_some() != head.rff.is_some()
            }) {
                bail!("cannot serialize a set mixing gram representations");
            }
            pairs.push(("kernel", kernel_to_json(head.kernel())));
            match (&head.rff, &head.lowrank) {
                (Some(rf), _) => push_rff_header(&mut pairs, &rf.map, head.n_train()),
                (None, Some(lr)) => {
                    push_lowrank_header(&mut pairs, &lr.z, &lr.landmarks, head.n_train())
                }
                (None, None) => pairs.push(("x_train", matrix_to_json(head.x_train()))),
            }
            pairs.push(("fits", Json::Arr(s.fits.iter().map(kqr_fit_to_json).collect())));
            pairs.push(("shape", shape_to_json(&s.shape)));
            if !s.cv.is_empty() {
                pairs.push(("cv", Json::Arr(s.cv.iter().map(CvSummary::to_json).collect())));
            }
        }
        QuantileModel::Nckqr(f) => {
            pairs.push(("kernel", kernel_to_json(f.kernel())));
            match (&f.rff, &f.lowrank) {
                (Some(rf), _) => {
                    push_rff_header(&mut pairs, &rf.map, f.n_train());
                    pairs.push((
                        "levels",
                        Json::Arr(
                            f.levels
                                .iter()
                                .zip(&rf.w)
                                .map(|(lv, w)| {
                                    Json::obj(vec![
                                        ("tau", Json::num(lv.tau)),
                                        ("b", Json::num(lv.b)),
                                        ("w", Json::arr_f64(w)),
                                    ])
                                })
                                .collect(),
                        ),
                    ));
                }
                (None, Some(lr)) => {
                    push_lowrank_header(&mut pairs, &lr.z, &lr.landmarks, f.n_train());
                    pairs.push((
                        "levels",
                        Json::Arr(
                            f.levels
                                .iter()
                                .zip(&lr.w)
                                .map(|(lv, w)| {
                                    Json::obj(vec![
                                        ("tau", Json::num(lv.tau)),
                                        ("b", Json::num(lv.b)),
                                        ("w", Json::arr_f64(w)),
                                    ])
                                })
                                .collect(),
                        ),
                    ));
                }
                (None, None) => {
                    pairs.push(("x_train", matrix_to_json(f.x_train())));
                    pairs.push((
                        "levels",
                        Json::Arr(
                            f.levels
                                .iter()
                                .map(|lv| {
                                    Json::obj(vec![
                                        ("tau", Json::num(lv.tau)),
                                        ("b", Json::num(lv.b)),
                                        ("alpha", Json::arr_f64(&lv.alpha)),
                                    ])
                                })
                                .collect(),
                        ),
                    ));
                }
            }
            pairs.push(("taus", Json::arr_f64(&f.taus)));
            pairs.push(("lam1", Json::num(f.lam1)));
            pairs.push(("lam2", Json::num(f.lam2)));
            pairs.push(("objective", Json::num(f.objective)));
            pairs.push(("mm_iters", Json::num(f.mm_iters as f64)));
            pairs.push(("gamma_final", Json::num(f.gamma_final)));
            pairs.push(("train_crossings", Json::num(f.train_crossings as f64)));
            pairs.push(("kkt", f.kkt.to_json()));
        }
    }
    Ok(Json::obj(pairs))
}

/// Deserialize an artifact document.
pub fn from_json(v: &Json) -> Result<QuantileModel> {
    match v.get_str("format") {
        Some(ARTIFACT_FORMAT) => {}
        Some(other) => bail!("not a fastkqr model artifact (format {other:?})"),
        None => bail!("not a fastkqr model artifact (missing 'format')"),
    }
    let version = v.get_usize("format_version").unwrap_or(0) as u64;
    if version == 0 || version > ARTIFACT_VERSION {
        bail!(
            "artifact format_version {version} unsupported (this build reads 1..={ARTIFACT_VERSION})"
        );
    }
    let kernel =
        kernel_from_json(v.get("kernel").ok_or_else(|| anyhow!("artifact: missing 'kernel'"))?)?;
    // Compressed documents carry their representation instead of
    // x_train: low-rank brings (z, landmarks, n_train), random features
    // bring (freqs, phases, n_train). Dense documents parse as before.
    let (lowrank_doc, rff_doc_tag) = match v.get_str("repr") {
        None => (false, false),
        Some("lowrank") => (true, false),
        Some("rff") => (false, true),
        Some(other) => bail!("artifact: unknown repr {other:?}"),
    };
    let compressed = if lowrank_doc {
        let z = Arc::new(matrix_from_json(
            v.get("z").ok_or_else(|| anyhow!("lowrank artifact: missing 'z'"))?,
        )?);
        let landmarks = v
            .get_usize_arr("landmarks")
            .ok_or_else(|| anyhow!("lowrank artifact: missing 'landmarks'"))?;
        if landmarks.len() != z.rows() {
            bail!("lowrank artifact: {} landmarks for {} z rows", landmarks.len(), z.rows());
        }
        let n_train = v
            .get_usize("n_train")
            .ok_or_else(|| anyhow!("lowrank artifact: missing 'n_train'"))?;
        Some((z, landmarks, n_train))
    } else {
        None
    };
    let rff_doc = if rff_doc_tag {
        let freqs = matrix_from_json(
            v.get("freqs").ok_or_else(|| anyhow!("rff artifact: missing 'freqs'"))?,
        )?;
        let phases = v
            .get_f64_arr_strict("phases")
            .ok_or_else(|| anyhow!("rff artifact: missing 'phases'"))?;
        if freqs.rows() == 0 {
            bail!("rff artifact: empty frequency matrix");
        }
        if phases.len() != freqs.rows() {
            bail!("rff artifact: {} phases for {} frequencies", phases.len(), freqs.rows());
        }
        let n_train = v
            .get_usize("n_train")
            .ok_or_else(|| anyhow!("rff artifact: missing 'n_train'"))?;
        let seed = v.get_usize("rff_seed").unwrap_or(0) as u64;
        // √(2/D) is a pure function of D — recomputed bit-identically.
        let scale = (2.0 / freqs.rows() as f64).sqrt();
        Some((Arc::new(RffMap { freqs, phases, scale, seed }), n_train))
    } else {
        None
    };
    let dense_x_train = || -> Result<Arc<Matrix>> {
        Ok(Arc::new(matrix_from_json(
            v.get("x_train").ok_or_else(|| anyhow!("artifact: missing 'x_train'"))?,
        )?))
    };
    match v.get_str("kind") {
        Some("kqr") => {
            let fit = v.get("fit").ok_or_else(|| anyhow!("artifact: missing 'fit'"))?;
            match (&rff_doc, &compressed) {
                (Some((map, n_train)), _) => Ok(QuantileModel::Kqr(kqr_fit_from_json_rff(
                    fit, map, *n_train, &kernel,
                )?)),
                (None, Some((z, landmarks, n_train))) => Ok(QuantileModel::Kqr(
                    kqr_fit_from_json_lowrank(fit, z, landmarks, *n_train, &kernel)?,
                )),
                (None, None) => {
                    let x_train = dense_x_train()?;
                    Ok(QuantileModel::Kqr(kqr_fit_from_json(fit, &x_train, &kernel)?))
                }
            }
        }
        Some("set") => {
            let fits_json = v
                .get("fits")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact: missing 'fits'"))?;
            if fits_json.is_empty() {
                bail!("artifact: empty fit set");
            }
            let fits: Vec<KqrFit> = match (&rff_doc, &compressed) {
                (Some((map, n_train)), _) => fits_json
                    .iter()
                    .map(|f| kqr_fit_from_json_rff(f, map, *n_train, &kernel))
                    .collect::<Result<_>>()?,
                (None, Some((z, landmarks, n_train))) => fits_json
                    .iter()
                    .map(|f| kqr_fit_from_json_lowrank(f, z, landmarks, *n_train, &kernel))
                    .collect::<Result<_>>()?,
                (None, None) => {
                    let x_train = dense_x_train()?;
                    fits_json
                        .iter()
                        .map(|f| kqr_fit_from_json(f, &x_train, &kernel))
                        .collect::<Result<_>>()?
                }
            };
            let shape = shape_from_json(
                v.get("shape").ok_or_else(|| anyhow!("artifact: missing 'shape'"))?,
            )?;
            let cv = match v.get("cv").and_then(Json::as_arr) {
                None => Vec::new(),
                Some(arr) => arr.iter().map(CvSummary::from_json).collect::<Result<_>>()?,
            };
            Ok(QuantileModel::Set(ModelSet { fits, shape, cv, lockstep: None, solver: None }))
        }
        Some("nckqr") => {
            let taus = v
                .get_f64_arr_strict("taus")
                .ok_or_else(|| anyhow!("artifact: missing 'taus'"))?;
            let levels_json = v
                .get("levels")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact: missing 'levels'"))?;
            if levels_json.len() != taus.len() {
                bail!("artifact: {} levels for {} taus", levels_json.len(), taus.len());
            }
            let kkt = KktReport::from_json(
                v.get("kkt").ok_or_else(|| anyhow!("artifact: missing 'kkt'"))?,
            )?;
            let lam1 =
                v.get_f64("lam1").ok_or_else(|| anyhow!("artifact: missing 'lam1'"))?;
            let lam2 =
                v.get_f64("lam2").ok_or_else(|| anyhow!("artifact: missing 'lam2'"))?;
            let objective = v
                .get_f64("objective")
                .ok_or_else(|| anyhow!("artifact: missing 'objective'"))?;
            let mm_iters = v.get_usize("mm_iters").unwrap_or(0);
            let gamma_final = v.get_f64("gamma_final").unwrap_or(0.0);
            let train_crossings = v.get_usize("train_crossings").unwrap_or(0);
            match (rff_doc, compressed) {
                (Some((map, n_train)), _) => {
                    let mut levels = Vec::with_capacity(levels_json.len());
                    let mut ws = Vec::with_capacity(levels_json.len());
                    for lv in levels_json {
                        let w = lv
                            .get_f64_arr_strict("w")
                            .ok_or_else(|| anyhow!("rff level: missing 'w'"))?;
                        if w.len() != map.d() {
                            bail!("rff level: len(w)={} != d={}", w.len(), map.d());
                        }
                        levels.push(LevelCoef {
                            tau: lv
                                .get_f64("tau")
                                .ok_or_else(|| anyhow!("level: missing 'tau'"))?,
                            b: lv.get_f64("b").ok_or_else(|| anyhow!("level: missing 'b'"))?,
                            alpha: Vec::new(),
                        });
                        ws.push(w);
                    }
                    Ok(QuantileModel::Nckqr(NckqrFit::assemble_compressed_rff(
                        taus,
                        lam1,
                        lam2,
                        levels,
                        objective,
                        kkt,
                        mm_iters,
                        gamma_final,
                        train_crossings,
                        n_train,
                        NcRff { map, w: ws },
                        kernel,
                    )))
                }
                (None, Some((z, landmarks, n_train))) => {
                    let mut levels = Vec::with_capacity(levels_json.len());
                    let mut ws = Vec::with_capacity(levels_json.len());
                    for lv in levels_json {
                        let w = lv
                            .get_f64_arr_strict("w")
                            .ok_or_else(|| anyhow!("lowrank level: missing 'w'"))?;
                        if w.len() != z.rows() {
                            bail!("lowrank level: len(w)={} != m={}", w.len(), z.rows());
                        }
                        levels.push(LevelCoef {
                            tau: lv
                                .get_f64("tau")
                                .ok_or_else(|| anyhow!("level: missing 'tau'"))?,
                            b: lv.get_f64("b").ok_or_else(|| anyhow!("level: missing 'b'"))?,
                            alpha: Vec::new(),
                        });
                        ws.push(w);
                    }
                    Ok(QuantileModel::Nckqr(NckqrFit::assemble_compressed(
                        taus,
                        lam1,
                        lam2,
                        levels,
                        objective,
                        kkt,
                        mm_iters,
                        gamma_final,
                        train_crossings,
                        n_train,
                        NcLowRank { z, landmarks, w: ws },
                        kernel,
                    )))
                }
                (None, None) => {
                    let x_train = dense_x_train()?;
                    let mut levels = Vec::with_capacity(levels_json.len());
                    for lv in levels_json {
                        let alpha = lv
                            .get_f64_arr_strict("alpha")
                            .ok_or_else(|| anyhow!("level: missing 'alpha'"))?;
                        if alpha.len() != x_train.rows() {
                            bail!(
                                "level: len(alpha)={} != n_train={}",
                                alpha.len(),
                                x_train.rows()
                            );
                        }
                        levels.push(LevelCoef {
                            tau: lv
                                .get_f64("tau")
                                .ok_or_else(|| anyhow!("level: missing 'tau'"))?,
                            b: lv.get_f64("b").ok_or_else(|| anyhow!("level: missing 'b'"))?,
                            alpha,
                        });
                    }
                    Ok(QuantileModel::Nckqr(NckqrFit::assemble(
                        taus,
                        lam1,
                        lam2,
                        levels,
                        objective,
                        kkt,
                        mm_iters,
                        gamma_final,
                        train_crossings,
                        x_train,
                        kernel,
                    )))
                }
            }
        }
        other => bail!("artifact: unknown kind {other:?}"),
    }
}

/// Write `model` to `path` as one compact JSON document.
///
/// The write is atomic (temp file in the same directory + rename): a
/// crash or full disk mid-write never leaves a truncated artifact behind
/// — important for registry persistence directories, which are reloaded
/// wholesale at server startup.
pub fn save(model: &QuantileModel, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("create {}", parent.display()))?;
        }
    }
    let mut doc = to_json(model)?.to_string();
    doc.push('\n');
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, doc).with_context(|| format!("write {}", tmp.display()))?;
    std::fs::rename(&tmp, path).with_context(|| {
        let _ = std::fs::remove_file(&tmp);
        format!("rename {} -> {}", tmp.display(), path.display())
    })?;
    Ok(())
}

/// Read a model artifact from `path`.
pub fn load(path: &Path) -> Result<QuantileModel> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
    let v = Json::parse(text.trim())
        .map_err(|e| anyhow!("{}: not valid JSON: {e}", path.display()))?;
    from_json(&v).with_context(|| format!("load model artifact {}", path.display()))
}

/// [`load`] plus the compiled serving plan: the consumers that load in
/// order to *predict* (the CLI's `predict` subcommand, registry reloads,
/// benches) get the [`PredictPlan`](crate::engine::PredictPlan) compiled
/// exactly once at artifact-load time instead of re-deriving the
/// coefficient layout per request. An artifact parses into one shared
/// `x_train`/landmark `Arc` for all its fits, so the plan always
/// compiles to a single group.
pub fn load_compiled(
    path: &Path,
) -> Result<(QuantileModel, std::sync::Arc<crate::engine::PredictPlan>)> {
    let model = load(path)?;
    let plan = std::sync::Arc::new(model.compile_plan());
    Ok((model, plan))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, Rng};

    fn toy_kqr_model() -> QuantileModel {
        let mut rng = Rng::new(21);
        let d = synth::sine_hetero(18, &mut rng);
        let fit = crate::kqr::KqrSolver::new(&d.x, &d.y, Kernel::Rbf { sigma: 0.4 })
            .unwrap()
            .fit(0.5, 0.05)
            .unwrap();
        QuantileModel::Kqr(fit)
    }

    #[test]
    fn rff_artifact_roundtrips_and_is_version_3() {
        use crate::spectral::GramRepr;
        let mut rng = Rng::new(33);
        let d = synth::sine_hetero(24, &mut rng);
        let kernel = Kernel::Rbf { sigma: 0.5 };
        let factor = crate::kernel::rff::rff(&d.x, &kernel, 16, 7).unwrap();
        let solver = crate::kqr::KqrSolver::with_repr(
            &d.x,
            &d.y,
            kernel,
            GramRepr::RandomFeatures(std::sync::Arc::new(factor)),
        );
        let fit = solver.fit(0.5, 0.05).unwrap();
        let model = QuantileModel::Kqr(fit);
        let doc = to_json(&model).unwrap();
        assert_eq!(doc.get_usize("format_version"), Some(3));
        assert_eq!(doc.get_str("repr"), Some("rff"));
        assert!(doc.get("x_train").is_none(), "rff artifacts are n-free");
        let back = from_json(&doc).unwrap();
        assert_eq!(to_json(&back).unwrap().to_string(), doc.to_string());
        // reloaded predictions are bitwise equal
        let mut rng2 = Rng::new(34);
        let xt = Matrix::from_fn(9, d.x.cols(), |_, _| rng2.normal());
        assert_eq!(model.predict(&xt), back.predict(&xt));
    }

    #[test]
    fn kqr_artifact_roundtrips_in_memory() {
        let model = toy_kqr_model();
        let doc = to_json(&model).unwrap();
        assert_eq!(doc.get_str("format"), Some(ARTIFACT_FORMAT));
        let back = from_json(&doc).unwrap();
        // the serialized form of the reloaded model is identical
        assert_eq!(to_json(&back).unwrap().to_string(), doc.to_string());
    }

    #[test]
    fn rejects_foreign_and_future_documents() {
        assert!(from_json(&Json::parse(r#"{"hello":1}"#).unwrap()).is_err());
        assert!(from_json(
            &Json::parse(r#"{"format":"fastkqr.model","format_version":999,"kind":"kqr"}"#)
                .unwrap()
        )
        .is_err());
        let mut doc = to_json(&toy_kqr_model()).unwrap();
        if let Json::Obj(m) = &mut doc {
            m.insert("kind".into(), Json::str("mystery"));
        }
        assert!(from_json(&doc).is_err());
    }

    #[test]
    fn empty_set_serialization_is_an_error_not_a_panic() {
        use crate::api::{ModelSet, SetShape};
        let empty = QuantileModel::Set(ModelSet {
            fits: Vec::new(),
            shape: SetShape::Path { tau: 0.5 },
            cv: Vec::new(),
            lockstep: None,
            solver: None,
        });
        assert!(to_json(&empty).is_err());
    }
}
