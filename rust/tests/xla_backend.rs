//! Integration tests for the PJRT runtime: the AOT artifact must
//! reproduce the native APGD recurrence and plug into the full solver.
//!
//! Requires the `xla` cargo feature (the whole file is compiled out of
//! the default build, which ships a stub backend) **and** `make
//! artifacts` (skipped gracefully otherwise so plain
//! `cargo test --features xla` works before the first artifact build).

#![cfg(feature = "xla")]

use fastkqr::backend::{Backend, NativeBackend};
use fastkqr::data::{synth, Rng};
use fastkqr::kernel::{median_heuristic_sigma, Kernel};
use fastkqr::kqr::apgd::ApgdState;
use fastkqr::kqr::KqrSolver;
use fastkqr::runtime::XlaBackend;
use fastkqr::spectral::SpectralPlan;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn make_solver(n: usize, seed: u64) -> KqrSolver {
    let mut rng = Rng::new(seed);
    let d = synth::sine_hetero(n, &mut rng);
    let sigma = median_heuristic_sigma(&d.x);
    KqrSolver::new(&d.x, &d.y, Kernel::Rbf { sigma }).unwrap()
}

#[test]
fn xla_chunk_matches_native_elementwise() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let solver = make_solver(50, 1); // padded to the n=64 artifact
    let plan = SpectralPlan::new(&solver.basis, 0.25, 0.02);
    let tau = 0.3;
    let chunk = 25;

    let mut native = NativeBackend::new();
    let mut s_native = ApgdState::zeros(50);
    let mut xb = XlaBackend::from_default_dir().expect("artifacts");
    let mut s_xla = ApgdState::zeros(50);

    for round in 0..8 {
        let c_native =
            native.apgd_chunk(&solver.basis, &plan, &solver.y, tau, &mut s_native, chunk);
        let c_xla = xb.apgd_chunk(&solver.basis, &plan, &solver.y, tau, &mut s_xla, chunk);
        assert!(
            (c_native - c_xla).abs() <= 1e-9 * (1.0 + c_native.abs()),
            "round {round}: conv native {c_native} vs xla {c_xla}"
        );
        assert!(
            (s_native.b - s_xla.b).abs() < 1e-9,
            "round {round}: b {} vs {}",
            s_native.b,
            s_xla.b
        );
        for i in 0..50 {
            assert!(
                (s_native.beta[i] - s_xla.beta[i]).abs() < 1e-9,
                "round {round} beta[{i}]: {} vs {}",
                s_native.beta[i],
                s_xla.beta[i]
            );
        }
        assert!((s_native.ck - s_xla.ck).abs() < 1e-9);
    }
    assert_eq!(xb.executions, 8);
}

#[test]
fn full_fit_through_xla_backend_matches_native() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let solver = make_solver(40, 2);
    let tau = 0.5;
    let lam = 0.02;
    let fit_native = solver.fit(tau, lam).expect("native fit");
    let mut xb = XlaBackend::from_default_dir().expect("artifacts");
    let mut state = ApgdState::zeros(40);
    let fit_xla = solver.fit_warm(tau, lam, &mut state, &mut xb).expect("xla fit");
    assert!(fit_xla.kkt.pass, "{:?}", fit_xla.kkt);
    assert!(
        (fit_native.objective - fit_xla.objective).abs() < 1e-8 * (1.0 + fit_native.objective),
        "native {} vs xla {}",
        fit_native.objective,
        fit_xla.objective
    );
    for i in 0..40 {
        assert!((fit_native.alpha[i] - fit_xla.alpha[i]).abs() < 1e-6);
    }
}

#[test]
fn xla_path_fit_warm_started() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let solver = make_solver(30, 3);
    let lams = solver.lambda_grid(4, 0.5, 1e-2);
    let mut xb = XlaBackend::from_default_dir().expect("artifacts");
    let fits = solver.fit_path_with_backend(0.5, &lams, &mut xb).expect("path");
    assert_eq!(fits.len(), 4);
    for f in &fits {
        assert!(f.kkt.pass, "lam={}: {:?}", f.lam, f.kkt);
    }
    // compile once, execute many
    assert!(xb.executions >= 4);
}

#[test]
fn chunk_mismatch_is_rejected() {
    if !artifacts_available() {
        return;
    }
    let solver = make_solver(20, 4);
    let plan = SpectralPlan::new(&solver.basis, 0.25, 0.02);
    let mut xb = XlaBackend::from_default_dir().expect("artifacts");
    let mut s = ApgdState::zeros(20);
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        xb.apgd_chunk(&solver.basis, &plan, &solver.y, 0.5, &mut s, 7)
    }));
    assert!(res.is_err(), "wrong chunk size must be rejected");
}
