//! Benchmark-data lookalikes (documented substitution).
//!
//! The paper's benchmark studies (Figure 1, Tables 5–6) use five R
//! datasets (MASS / mlbench): GAGurine, mcycle, crabs, BostonHousing and
//! geyser. Those files are not available offline, so we generate
//! *synthetic lookalikes* with the same (n, p), response scale and the
//! qualitative structure that matters to the experiments:
//!
//! - the experiments measure solver speed/objective at fixed (n, p,
//!   kernel); the data only enters through the Gram matrix spectrum,
//!   which depends on n, p and smoothness — matched here;
//! - Figure 1 needs the GAGurine *shape*: a steeply decaying,
//!   heteroscedastic 1-D cloud (concentration vs age) where individually
//!   fitted quantile curves visibly cross — the generator below
//!   reproduces exactly that behaviour.
//!
//! Every generator is deterministic given its seed. See DESIGN.md §3.

use super::dataset::Dataset;
use super::rng::Rng;
use crate::linalg::Matrix;

/// GAGurine lookalike: n=314, p=1. Concentration of urinary GAGs vs age
/// (0–17). Shape: high (~25) and highly variable near age 0, decaying
/// roughly like a + b·exp(-age/s) toward ~5 with shrinking spread —
/// matches the cloud in the paper's Figure 1.
pub fn gagurine(seed: u64) -> Dataset {
    let n = 314;
    let mut rng = Rng::new(seed ^ 0x6a67);
    let mut x = Matrix::zeros(n, 1);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        // ages skew young in the original data
        let age = 17.0 * rng.uniform().powf(1.4);
        let mean = 3.5 + 22.0 * (-age / 3.2).exp();
        let sd = 1.2 + 6.0 * (-age / 3.0).exp();
        // log-normal-ish positive noise: concentrations are positive and
        // right-skewed
        let noise = sd * 0.5 * (rng.normal() + 0.35 * (rng.normal().powi(2) - 1.0));
        x[(i, 0)] = age;
        y.push((mean + noise).max(0.3));
    }
    Dataset::new("gagurine_lookalike(n=314,p=1)", x, y)
}

/// mcycle lookalike: n=133, p=1. Simulated motorcycle-crash head
/// acceleration vs time: flat ≈0 early, deep negative dip (~-120) around
/// 20ms, rebound overshoot, heteroscedastic noise growing after impact.
pub fn mcycle(seed: u64) -> Dataset {
    let n = 133;
    let mut rng = Rng::new(seed ^ 0x6d63);
    let mut x = Matrix::zeros(n, 1);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let t = 2.4 + 55.0 * rng.uniform();
        let mean = if t < 14.0 {
            0.0
        } else {
            // damped oscillation after impact
            let u = (t - 14.0) / 8.0;
            -120.0 * (-0.35 * (u - 1.0).powi(2)).exp() * (1.0 - u * 0.25).max(-0.6)
                + 50.0 * (-0.5 * (u - 2.6).powi(2)).exp()
        };
        let sd = if t < 14.0 { 3.0 } else { 23.0 };
        x[(i, 0)] = t;
        y.push(mean + sd * rng.normal());
    }
    Dataset::new("mcycle_lookalike(n=133,p=1)", x, y)
}

/// crabs lookalike: n=200, p=8. Five strongly collinear morphometric
/// measurements + 2 dummy-coded factors (species, sex) + an interaction;
/// response = carapace width reconstructed from the latent size factor.
pub fn crabs(seed: u64) -> Dataset {
    let n = 200;
    let p = 8;
    let mut rng = Rng::new(seed ^ 0x6372);
    let mut x = Matrix::zeros(n, p);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let species = (i % 2) as f64; // blue / orange
        let sex = ((i / 2) % 2) as f64;
        // latent body size drives all morphometrics (high collinearity)
        let size = 30.0 + 8.0 * rng.normal() + 2.0 * species;
        let m = |scale: f64, rng: &mut Rng| scale * size + 0.8 * rng.normal();
        let fl = m(0.42, &mut rng) + 1.2 * species;
        let rw = m(0.37, &mut rng) + 1.5 * sex;
        let cl = m(0.95, &mut rng);
        let cw = 1.12 * size + 0.9 * rng.normal(); // response source
        let bd = m(0.40, &mut rng);
        let row = x.row_mut(i);
        row[0] = fl;
        row[1] = rw;
        row[2] = cl;
        row[3] = bd;
        row[4] = species;
        row[5] = sex;
        row[6] = species * sex;
        row[7] = m(0.30, &mut rng); // extra morphometric
        y.push(cw);
    }
    Dataset::new("crabs_lookalike(n=200,p=8)", x, y)
}

/// BostonHousing lookalike: n=506, p=14 (13 covariates + 1 dummy like the
/// paper's converted factor). Median home value driven by a nonlinear mix
/// with heavy right tail and a clipped ceiling at 50 (as in the original).
pub fn boston_housing(seed: u64) -> Dataset {
    let n = 506;
    let p = 14;
    let mut rng = Rng::new(seed ^ 0x6268);
    let mut x = Matrix::zeros(n, p);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let rooms = 6.3 + 0.7 * rng.normal(); // RM
        let lstat = (14.0 + 7.0 * rng.normal()).clamp(1.0, 38.0); // % lower status
        let crim = (-3.0 + 2.1 * rng.normal()).exp().min(90.0); // log-normal crime
        let nox = 0.55 + 0.11 * rng.normal();
        let dis = 3.8 + 2.0 * rng.uniform();
        let tax = 300.0 + 170.0 * rng.uniform();
        let age = 100.0 * rng.uniform().powf(0.6);
        let chas = if rng.uniform() < 0.07 { 1.0 } else { 0.0 };
        let row = x.row_mut(i);
        row[0] = crim;
        row[1] = 12.0 * rng.uniform(); // ZN-ish
        row[2] = 11.0 + 7.0 * rng.uniform(); // INDUS-ish
        row[3] = chas;
        row[4] = nox;
        row[5] = rooms;
        row[6] = age;
        row[7] = dis;
        row[8] = (9.0 * rng.uniform()).round(); // RAD-ish
        row[9] = tax;
        row[10] = 18.5 + 2.0 * rng.normal(); // PTRATIO
        row[11] = 356.0 + 90.0 * (rng.uniform() - 0.5); // B-ish
        row[12] = lstat;
        row[13] = rng.normal(); // converted-factor dummy channel
        let mv = 22.5 + 7.5 * (rooms - 6.3) - 0.45 * lstat + 14.0 / dis.max(1.0)
            - 3.5 * crim.ln_1p()
            + 2.0 * chas
            + 2.2 * rng.normal();
        y.push(mv.clamp(5.0, 50.0));
    }
    Dataset::new("boston_lookalike(n=506,p=14)", x, y)
}

/// geyser lookalike: n=299, p=1. "Old Faithful" waiting time vs previous
/// eruption duration — bimodal durations, two waiting-time regimes.
pub fn geyser(seed: u64) -> Dataset {
    let n = 299;
    let mut rng = Rng::new(seed ^ 0x6779);
    let mut x = Matrix::zeros(n, 1);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let short = rng.uniform() < 0.35;
        let duration =
            if short { 2.0 + 0.35 * rng.normal() } else { 4.3 + 0.45 * rng.normal() };
        let wait = 32.0 + 10.5 * duration + 5.5 * rng.normal();
        x[(i, 0)] = duration.clamp(0.8, 5.5);
        y.push(wait.clamp(40.0, 100.0));
    }
    Dataset::new("geyser_lookalike(n=299,p=1)", x, y)
}

/// The four (data, n, p) combinations of Tables 5–6, in paper order.
pub fn table5_suite(seed: u64) -> Vec<Dataset> {
    vec![crabs(seed), gagurine(seed), mcycle(seed), boston_housing(seed)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_paper() {
        assert_eq!((gagurine(1).n(), gagurine(1).p()), (314, 1));
        assert_eq!((mcycle(1).n(), mcycle(1).p()), (133, 1));
        assert_eq!((crabs(1).n(), crabs(1).p()), (200, 8));
        assert_eq!((boston_housing(1).n(), boston_housing(1).p()), (506, 14));
        assert_eq!((geyser(1).n(), geyser(1).p()), (299, 1));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = gagurine(42);
        let b = gagurine(42);
        assert_eq!(a.y, b.y);
        let c = gagurine(43);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn gagurine_decays_with_age() {
        let d = gagurine(7);
        let mut young = vec![];
        let mut old = vec![];
        for i in 0..d.n() {
            if d.x[(i, 0)] < 2.0 {
                young.push(d.y[i]);
            } else if d.x[(i, 0)] > 10.0 {
                old.push(d.y[i]);
            }
        }
        let mean = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        assert!(mean(&young) > mean(&old) + 8.0, "young={} old={}", mean(&young), mean(&old));
        assert!(d.y.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn mcycle_has_deep_dip() {
        let d = mcycle(7);
        let min = d.y.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(min < -80.0, "dip only reaches {min}");
        // early times stay near zero
        for i in 0..d.n() {
            if d.x[(i, 0)] < 10.0 {
                assert!(d.y[i].abs() < 25.0);
            }
        }
    }

    #[test]
    fn boston_values_clipped_like_original() {
        let d = boston_housing(9);
        assert!(d.y.iter().all(|&v| (5.0..=50.0).contains(&v)));
    }

    #[test]
    fn table5_suite_order() {
        let suite = table5_suite(1);
        assert_eq!(suite.len(), 4);
        assert!(suite[0].name.contains("crabs"));
        assert!(suite[3].name.contains("boston"));
    }
}
