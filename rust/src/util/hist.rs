//! Lock-free log-bucketed histogram for operational metrics.
//!
//! Power-of-two buckets over `u64` samples (latency in µs, batch sizes,
//! queue depths): bucket 0 holds the value 0, bucket i ≥ 1 holds
//! [2^(i−1), 2^i − 1]. Recording is a couple of relaxed atomic adds, so
//! hot serving paths can record every request; percentile reads walk the
//! 64 buckets and report the bucket's upper bound (clamped to the true
//! maximum), which bounds the error to one octave — plenty for p50/p95/
//! p99 operational summaries.

use std::sync::atomic::{AtomicU64, Ordering};

const BUCKETS: usize = 64;

/// Concurrent log₂-bucketed histogram (see module docs).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            (64 - v.leading_zeros() as usize).min(BUCKETS - 1)
        }
    }

    /// Record one sample.
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Largest recorded sample (0 when empty).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Mean of recorded samples (0 when empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// The q-quantile (q ∈ [0, 1]) as the covering bucket's upper bound,
    /// clamped to the recorded maximum. 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                let upper = if i == 0 { 0 } else { (1u64 << i).saturating_sub(1) };
                return upper.min(self.max());
            }
        }
        self.max()
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_cover_powers_of_two() {
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 1);
        assert_eq!(Histogram::bucket_index(2), 2);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 3);
        assert_eq!(Histogram::bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn quantiles_bound_to_one_octave() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.max(), 1000);
        // exact p50 is 500 (bucket [256, 511]); the reported upper bound
        // may not exceed the next power of two minus one
        let p50 = h.p50();
        assert!((500..=511).contains(&p50), "p50 = {p50}");
        // p99 = 990 lives in [512, 1023], clamped to the true max
        let p99 = h.p99();
        assert!((990..=1000).contains(&p99), "p99 = {p99}");
        assert!((h.mean() - 500.5).abs() < 1e-9);
    }

    #[test]
    fn empty_and_zero_samples() {
        let h = Histogram::new();
        assert_eq!(h.p50(), 0);
        assert_eq!(h.max(), 0);
        h.record(0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.p50(), 0);
        assert_eq!(h.p99(), 0);
    }

    #[test]
    fn concurrent_records_all_land() {
        let h = std::sync::Arc::new(Histogram::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let h = h.clone();
                s.spawn(move || {
                    for v in 0..250u64 {
                        h.record(v);
                    }
                });
            }
        });
        assert_eq!(h.count(), 1000);
    }
}
