//! Server integration: fit/predict over TCP, concurrent clients, error
//! handling, metrics accounting.

use fastkqr::coordinator::server::Client;
use fastkqr::coordinator::{Server, ServerConfig};
use fastkqr::data::{synth, Rng};
use fastkqr::util::Json;

/// Runtime environment probe: these tests need a bindable loopback TCP
/// port. Sandboxes without network namespaces fail the bind; skip then
/// (hermetic `cargo test -q`) rather than erroring.
fn net_available() -> bool {
    std::net::TcpListener::bind("127.0.0.1:0").is_ok()
}

fn spawn() -> Server {
    Server::spawn(ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() })
        .expect("server")
}

fn matrix_json(x: &fastkqr::linalg::Matrix) -> Json {
    Json::Arr((0..x.rows()).map(|i| Json::arr_f64(x.row(i))).collect())
}

#[test]
fn fit_predict_drop_over_tcp() {
    if !net_available() {
        eprintln!("skipping: no loopback TCP available");
        return;
    }
    let server = spawn();
    let mut rng = Rng::new(1);
    let data = synth::sine_hetero(60, &mut rng);
    let mut client = Client::connect(server.local_addr).unwrap();

    let fit = client
        .request(&Json::obj(vec![
            ("cmd", Json::str("fit")),
            ("x", matrix_json(&data.x)),
            ("y", Json::arr_f64(&data.y)),
            ("tau", Json::num(0.5)),
            ("lambda", Json::num(1e-2)),
        ]))
        .unwrap();
    assert_eq!(fit.get("ok").and_then(Json::as_bool), Some(true), "{}", fit.to_string());
    assert_eq!(fit.get("kkt_pass").and_then(Json::as_bool), Some(true));
    let id = fit.get_str("model").unwrap().to_string();

    // predictions at training points roughly track the median
    let pred = client
        .request(&Json::obj(vec![
            ("cmd", Json::str("predict")),
            ("model", Json::str(id.clone())),
            ("x", matrix_json(&data.x)),
        ]))
        .unwrap();
    assert_eq!(pred.get("ok").and_then(Json::as_bool), Some(true));
    let rows = pred.get("pred").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].as_arr().unwrap().len(), 60);

    // model listed, then dropped
    let models = client.request(&Json::obj(vec![("cmd", Json::str("models"))])).unwrap();
    assert!(models.to_string().contains(&id));
    let drop = client
        .request(&Json::obj(vec![("cmd", Json::str("drop")), ("model", Json::str(id))]))
        .unwrap();
    assert_eq!(drop.get("ok").and_then(Json::as_bool), Some(true));
    server.shutdown();
}

#[test]
fn concurrent_clients_share_registry() {
    if !net_available() {
        eprintln!("skipping: no loopback TCP available");
        return;
    }
    let server = spawn();
    let addr = server.local_addr;
    let mut rng = Rng::new(2);
    let data = synth::sine_hetero(40, &mut rng);

    // client A fits; client B predicts with A's model id
    let mut a = Client::connect(addr).unwrap();
    let fit = a
        .request(&Json::obj(vec![
            ("cmd", Json::str("fit")),
            ("x", matrix_json(&data.x)),
            ("y", Json::arr_f64(&data.y)),
            ("tau", Json::num(0.3)),
            ("lambda", Json::num(1e-2)),
        ]))
        .unwrap();
    let id = fit.get_str("model").unwrap().to_string();

    let handles: Vec<_> = (0..4)
        .map(|_| {
            let id = id.clone();
            let x = matrix_json(&data.x);
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                for _ in 0..5 {
                    let p = c
                        .request(&Json::obj(vec![
                            ("cmd", Json::str("predict")),
                            ("model", Json::str(id.clone())),
                            ("x", x.clone()),
                        ]))
                        .unwrap();
                    assert_eq!(p.get("ok").and_then(Json::as_bool), Some(true));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let m = a.request(&Json::obj(vec![("cmd", Json::str("metrics"))])).unwrap();
    assert_eq!(m.get_f64("predict_requests"), Some(20.0));
    server.shutdown();
}

#[test]
fn server_restart_with_persistence_serves_same_models() {
    if !net_available() {
        eprintln!("skipping: no loopback TCP available");
        return;
    }
    let dir = std::env::temp_dir().join(format!(
        "fastkqr-server-restart-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    let config = || ServerConfig {
        addr: "127.0.0.1:0".into(),
        persist_dir: Some(dir.display().to_string()),
        ..Default::default()
    };
    let mut rng = Rng::new(5);
    let data = synth::sine_hetero(40, &mut rng);
    let grid = fastkqr::linalg::Matrix::from_fn(16, 1, |i, _| i as f64 / 15.0);

    // fit on the first server instance, record predictions
    let server = Server::spawn(config()).unwrap();
    let mut client = Client::connect(server.local_addr).unwrap();
    let fit = client
        .request(&Json::obj(vec![
            ("cmd", Json::str("fit")),
            ("x", matrix_json(&data.x)),
            ("y", Json::arr_f64(&data.y)),
            ("tau", Json::num(0.5)),
            ("lambda", Json::num(1e-2)),
        ]))
        .unwrap();
    assert_eq!(fit.get("ok").and_then(Json::as_bool), Some(true), "{}", fit.to_string());
    let id = fit.get_str("model").unwrap().to_string();
    let before = client
        .request(&Json::obj(vec![
            ("cmd", Json::str("predict")),
            ("model", Json::str(id.clone())),
            ("x", matrix_json(&grid)),
        ]))
        .unwrap();
    assert_eq!(before.get("ok").and_then(Json::as_bool), Some(true));
    server.shutdown();

    // a fresh server on the same persistence dir serves the reloaded
    // model under the same id, with identical predictions
    let server2 = Server::spawn(config()).unwrap();
    assert_eq!(server2.registry.len(), 1, "model must survive the restart");
    let mut client2 = Client::connect(server2.local_addr).unwrap();
    let after = client2
        .request(&Json::obj(vec![
            ("cmd", Json::str("predict")),
            ("model", Json::str(id)),
            ("x", matrix_json(&grid)),
        ]))
        .unwrap();
    assert_eq!(after.get("ok").and_then(Json::as_bool), Some(true), "{}", after.to_string());
    assert_eq!(
        before.get("pred").unwrap().to_string(),
        after.get("pred").unwrap().to_string(),
        "reloaded model must predict identically"
    );
    server2.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn malformed_requests_get_errors_not_disconnects() {
    if !net_available() {
        eprintln!("skipping: no loopback TCP available");
        return;
    }
    let server = spawn();
    let mut client = Client::connect(server.local_addr).unwrap();
    for bad in [
        "garbage",
        r#"{"cmd":"fit"}"#,
        r#"{"cmd":"fit","x":[[1],[2]],"y":[1],"tau":0.5,"lambda":0.1}"#, // length mismatch
        r#"{"cmd":"predict","model":"nope","x":[[1]]}"#,
        r#"{"cmd":"fit","x":[[1],[2]],"y":[1,2],"tau":2.0,"lambda":0.1}"#, // bad tau
    ] {
        let r = client.request(&Json::parse(r#"{"cmd":"ping"}"#).unwrap()).unwrap();
        assert_eq!(r.get("pong").and_then(Json::as_bool), Some(true));
        // send raw bad line through a fresh request
        let resp = {
            use std::io::{BufRead, Write};
            let mut line = bad.to_string();
            line.push('\n');
            // poke at the client internals via a new connection
            let stream = std::net::TcpStream::connect(server.local_addr).unwrap();
            let mut w = stream.try_clone().unwrap();
            w.write_all(line.as_bytes()).unwrap();
            let mut r = std::io::BufReader::new(stream);
            let mut out = String::new();
            r.read_line(&mut out).unwrap();
            Json::parse(out.trim()).unwrap()
        };
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "{bad}");
    }
    server.shutdown();
}
