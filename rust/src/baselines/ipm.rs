//! Primal–dual interior point method for KQR — the `kernlab` comparator.
//!
//! kernlab's `kqr()` solves the KQR dual with the `ipop` interior-point
//! QP solver. We reproduce that algorithm class: the KQR dual is the
//! box-constrained QP
//!
//!   min_u  ½ uᵀQu + cᵀu   s.t. 1ᵀu = 0,  τ−1 ≤ uᵢ ≤ τ,
//!   Q = K/(n²λ),  c = −y/n,
//!
//! recovered by α = u/(nλ) and b from the active-set structure. Each IPM
//! iteration factorizes an n×n system (O(n³)), the cost profile that
//! makes kernlab an order of magnitude slower than fastkqr on λ paths —
//! there is nothing to reuse across (γ, λ, τ).

use crate::linalg::{dot, gemv, Cholesky, Matrix};
use anyhow::{bail, Result};

/// IPM solution and diagnostics.
#[derive(Clone, Debug)]
pub struct IpmFit {
    pub b: f64,
    pub alpha: Vec<f64>,
    /// Exact primal objective of problem (2).
    pub objective: f64,
    pub iters: usize,
    /// Final complementarity gap.
    pub gap: f64,
}

/// Options for the interior point solver.
#[derive(Clone, Debug)]
pub struct IpmOptions {
    pub max_iters: usize,
    pub gap_tol: f64,
    /// Centering parameter σ ∈ (0,1).
    pub sigma: f64,
}

impl Default for IpmOptions {
    fn default() -> Self {
        IpmOptions { max_iters: 100, gap_tol: 1e-9, sigma: 0.15 }
    }
}

/// Solve KQR at (τ, λ) by the dual interior point method.
pub fn solve_kqr_ipm(
    gram: &Matrix,
    y: &[f64],
    tau: f64,
    lam: f64,
    opts: &IpmOptions,
) -> Result<IpmFit> {
    let n = y.len();
    if gram.rows() != n || gram.cols() != n {
        bail!("ipm: gram shape mismatch");
    }
    if !(0.0 < tau && tau < 1.0) || lam <= 0.0 {
        bail!("ipm: invalid tau/lambda");
    }
    let nf = n as f64;
    let lo = tau - 1.0;
    let hi = tau;
    // Q = K/(n²λ) with a tiny ridge so Cholesky of Q+D never fails.
    let qscale = 1.0 / (nf * nf * lam);
    // c = −y/n
    let c: Vec<f64> = y.iter().map(|v| -v / nf).collect();

    // Interior start: u centred in the box (feasible for 1ᵀu=0 since the
    // box is symmetric around τ−1/2... it is not; start at the midpoint
    // shifted to satisfy the equality exactly).
    let mid = 0.5 * (lo + hi);
    let mut u = vec![mid; n];
    let correction: f64 = u.iter().sum::<f64>() / nf;
    for ui in u.iter_mut() {
        *ui -= correction;
        *ui = ui.clamp(lo + 0.1 * (hi - lo), hi - 0.1 * (hi - lo));
    }
    let mut zl = vec![1.0; n]; // multipliers for u − lo ≥ 0
    let mut zu = vec![1.0; n]; // multipliers for hi − u ≥ 0
    let mut nu = 0.0f64; // equality multiplier

    let mut qu = vec![0.0; n]; // Q u
    let mut gap = f64::INFINITY;
    let mut iters = 0usize;
    for it in 0..opts.max_iters {
        iters = it + 1;
        // residuals
        gemv(gram, &u, &mut qu);
        for v in qu.iter_mut() {
            *v *= qscale;
        }
        // dual residual r_d = Qu + c + ν·1 − zl + zu
        let rd: Vec<f64> = (0..n).map(|i| qu[i] + c[i] + nu - zl[i] + zu[i]).collect();
        let rp: f64 = u.iter().sum(); // primal equality residual
        // complementarity
        let sl: Vec<f64> = u.iter().map(|&v| v - lo).collect();
        let su: Vec<f64> = u.iter().map(|&v| hi - v).collect();
        gap = (dot(&sl, &zl) + dot(&su, &zu)) / (2.0 * nf);
        let rd_max = rd.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        if gap < opts.gap_tol && rd_max < opts.gap_tol.sqrt() * 1e-2 && rp.abs() < 1e-10 {
            break;
        }
        let mu = opts.sigma * gap;
        // Newton system on Δu, Δν:
        //   (Q + D) Δu + 1 Δν = −r_d + (μ − sl∘zl)/sl − (μ − su∘zu)/su
        //   1ᵀ Δu = −r_p
        // with D = diag(zl/sl + zu/su).
        let mut m = Matrix::from_fn(n, n, |i, j| gram[(i, j)] * qscale);
        let mut rhs = vec![0.0; n];
        for i in 0..n {
            let d = zl[i] / sl[i] + zu[i] / su[i];
            m[(i, i)] += d + 1e-12;
            rhs[i] = -rd[i] + (mu - sl[i] * zl[i]) / sl[i] - (mu - su[i] * zu[i]) / su[i];
        }
        let ch = match Cholesky::new(&m) {
            Ok(ch) => ch,
            Err(e) => bail!("ipm: inner factorization failed: {e}"),
        };
        // Block-solve with the single equality via Schur complement:
        //   Δu = M⁻¹(rhs − 1Δν),  1ᵀΔu = −r_p
        let m_inv_rhs = ch.solve(&rhs);
        let ones = vec![1.0; n];
        let m_inv_1 = ch.solve(&ones);
        let denom: f64 = m_inv_1.iter().sum();
        let dnu = (m_inv_rhs.iter().sum::<f64>() + rp) / denom.max(1e-300);
        let du: Vec<f64> = (0..n).map(|i| m_inv_rhs[i] - dnu * m_inv_1[i]).collect();
        // Δz from linearized complementarity
        let dzl: Vec<f64> = (0..n).map(|i| (mu - sl[i] * zl[i] - zl[i] * du[i]) / sl[i]).collect();
        let dzu: Vec<f64> = (0..n).map(|i| (mu - su[i] * zu[i] + zu[i] * du[i]) / su[i]).collect();
        // fraction-to-boundary
        let mut step = 1.0f64;
        for i in 0..n {
            if du[i] < 0.0 {
                step = step.min(-0.995 * sl[i] / du[i]);
            }
            if du[i] > 0.0 {
                step = step.min(0.995 * su[i] / du[i]);
            }
            if dzl[i] < 0.0 {
                step = step.min(-0.995 * zl[i] / dzl[i]);
            }
            if dzu[i] < 0.0 {
                step = step.min(-0.995 * zu[i] / dzu[i]);
            }
        }
        step = step.min(1.0);
        for i in 0..n {
            u[i] += step * du[i];
            zl[i] += step * dzl[i];
            zu[i] += step * dzu[i];
        }
        nu += step * dnu;
    }

    // Recover primal variables.
    let alpha: Vec<f64> = u.iter().map(|&v| v / (nf * lam)).collect();
    let mut ka = vec![0.0; n];
    gemv(gram, &alpha, &mut ka);
    // b: exact minimizer of Σ ρ_τ(residual − b) = τ-quantile of (y − Kα).
    let mut res: Vec<f64> = (0..n).map(|i| y[i] - ka[i]).collect();
    res.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let b = weighted_tau_quantile(&res, tau);
    let objective = {
        let loss: f64 = (0..n)
            .map(|i| crate::smooth::rho_tau(y[i] - b - ka[i], tau))
            .sum::<f64>()
            / nf;
        loss + 0.5 * lam * dot(&alpha, &ka)
    };
    Ok(IpmFit { b, alpha, objective, iters, gap })
}

/// Exact minimizer of b ↦ Σ ρ_τ(rᵢ − b): any τ-quantile of the sorted
/// residuals (take the lower one; the subgradient condition allows the
/// whole interval).
fn weighted_tau_quantile(sorted: &[f64], tau: f64) -> f64 {
    let n = sorted.len();
    let k = ((n as f64) * tau).ceil() as usize;
    sorted[k.clamp(1, n) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, Rng};
    use crate::kernel::{median_heuristic_sigma, Kernel};
    use crate::kqr::KqrSolver;

    #[test]
    fn ipm_matches_fastkqr_objective() {
        let mut rng = Rng::new(3);
        let d = synth::sine_hetero(50, &mut rng);
        let sigma = median_heuristic_sigma(&d.x);
        let kernel = Kernel::Rbf { sigma };
        let solver = KqrSolver::new(&d.x, &d.y, kernel.clone()).unwrap();
        for (tau, lam) in [(0.5, 0.05), (0.1, 0.01), (0.9, 0.2)] {
            let fast = solver.fit(tau, lam).unwrap();
            let ipm =
                solve_kqr_ipm(solver.gram(), &d.y, tau, lam, &IpmOptions::default()).unwrap();
            let rel = (fast.objective - ipm.objective).abs() / (1.0 + fast.objective);
            assert!(
                rel < 5e-4,
                "tau={tau} lam={lam}: fastkqr {} vs ipm {} (rel {rel})",
                fast.objective,
                ipm.objective
            );
        }
    }

    #[test]
    fn ipm_dual_feasible_solution() {
        let mut rng = Rng::new(4);
        let d = synth::sine_hetero(30, &mut rng);
        let kernel = Kernel::Rbf { sigma: 0.5 };
        let gram = kernel.gram(&d.x);
        let tau = 0.3;
        let lam = 0.02;
        let fit = solve_kqr_ipm(&gram, &d.y, tau, lam, &IpmOptions::default()).unwrap();
        // dual box: nλα ∈ [τ−1, τ]
        let nf = 30.0;
        for &a in &fit.alpha {
            let g = nf * lam * a;
            assert!(g >= tau - 1.0 - 1e-6 && g <= tau + 1e-6, "g={g}");
        }
        // equality: Σα = 0
        let s: f64 = fit.alpha.iter().sum();
        assert!(s.abs() < 1e-8, "sum alpha {s}");
        assert!(fit.gap < 1e-8);
    }

    #[test]
    fn rejects_bad_inputs() {
        let gram = Matrix::eye(3);
        let y = [1.0, 2.0, 3.0];
        assert!(solve_kqr_ipm(&gram, &y, 0.0, 0.1, &IpmOptions::default()).is_err());
        assert!(solve_kqr_ipm(&gram, &y, 0.5, 0.0, &IpmOptions::default()).is_err());
    }
}
