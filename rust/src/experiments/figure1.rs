//! Figure 1: quantile crossing on the GAGurine data (lookalike).
//!
//! Top panel: five KQR curves fitted individually at
//! τ ∈ {0.1, 0.3, 0.5, 0.7, 0.9} — crossings highlighted. Bottom panel:
//! the same levels fitted jointly by NCKQR — no crossings. This harness
//! fits both models, writes the curve series as CSV (plot-ready), and
//! returns the crossing counts the integration tests assert on.

use crate::data::benchmarks;
use crate::kernel::{median_heuristic_sigma, Kernel};
use crate::kqr::KqrSolver;
use crate::linalg::Matrix;
use crate::nckqr::NckqrSolver;
use anyhow::{Context, Result};

pub const TAUS: [f64; 5] = [0.1, 0.3, 0.5, 0.7, 0.9];

/// Results of the Figure-1 run.
#[derive(Clone, Debug)]
pub struct Figure1Result {
    /// Crossing violations of the individually fitted curves on the grid.
    pub crossings_individual: usize,
    /// Crossing violations of the NCKQR curves.
    pub crossings_joint: usize,
    /// Grid x values.
    pub grid: Vec<f64>,
    /// Individually fitted curves, one per τ.
    pub curves_individual: Vec<Vec<f64>>,
    /// NCKQR curves, one per τ.
    pub curves_joint: Vec<Vec<f64>>,
}

/// Run the Figure-1 experiment. `lam` is the per-level RKHS penalty
/// (paper tunes by CV; the crossing phenomenon is robust across λ).
///
/// The joint fit subsamples to ≤ 160 points: at strong λ₁ the MM
/// majorizer scale (1 + 4nλ₁) makes full-n NCKQR slow on this one-core
/// container, and the crossing behaviour is identical (see
/// `rust/tests/solver_parity.rs` for the exactness checks at full rigor).
pub fn run(seed: u64, lam: f64, lam1: f64, grid_len: usize) -> Result<Figure1Result> {
    let full = benchmarks::gagurine(seed);
    let data = if full.n() > 160 {
        let mut rng = crate::data::Rng::new(seed ^ 0xf16);
        let idx = rng.permutation(full.n());
        full.subset(&idx[..160])
    } else {
        full
    };
    let sigma = median_heuristic_sigma(&data.x);
    let kernel = Kernel::Rbf { sigma };
    let (xmin, xmax) = data
        .x
        .as_slice()
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &v| (lo.min(v), hi.max(v)));
    let grid_m =
        Matrix::from_fn(grid_len, 1, |i, _| xmin + (xmax - xmin) * i as f64 / (grid_len - 1) as f64);
    let grid: Vec<f64> = grid_m.col(0);

    // individually fitted levels (shared eigendecomposition across τ)
    let solver = KqrSolver::new(&data.x, &data.y, kernel.clone())?;
    let mut curves_individual = Vec::new();
    for &tau in &TAUS {
        let fit = solver.fit(tau, lam)?;
        curves_individual.push(fit.predict(&grid_m));
    }
    let crossings_individual = count_crossings(&curves_individual, 1e-9);

    // joint non-crossing fit (budgeted solver options: the certificate
    // tolerance is relaxed — crossing removal, not exactness, is the
    // point of this figure)
    let mut opts = crate::nckqr::NcOptions::default();
    opts.max_iters = 8_000;
    opts.mm_tol = 5e-4;
    opts.kkt_tol = 2e-2;
    opts.max_stall_rungs = 2;
    let nc = NckqrSolver::new(&data.x, &data.y, kernel, &TAUS)?.with_options(opts);
    let fit = nc.fit(lam1, lam)?;
    let curves_joint = fit.predict(&grid_m);
    let crossings_joint = count_crossings(&curves_joint, 1e-6);

    Ok(Figure1Result { crossings_individual, crossings_joint, grid, curves_individual, curves_joint })
}

/// Count grid points where an upper quantile curve dips below a lower one.
pub fn count_crossings(curves: &[Vec<f64>], tol: f64) -> usize {
    let mut c = 0;
    for t in 0..curves.len().saturating_sub(1) {
        for i in 0..curves[t].len() {
            if curves[t + 1][i] < curves[t][i] - tol {
                c += 1;
            }
        }
    }
    c
}

/// Write both panels as CSV files under `dir`.
pub fn write_csv(res: &Figure1Result, dir: &str) -> Result<()> {
    std::fs::create_dir_all(dir).context("mkdir figure1 out")?;
    for (name, curves) in [
        ("figure1_individual.csv", &res.curves_individual),
        ("figure1_nckqr.csv", &res.curves_joint),
    ] {
        let mut out = String::from("x,q10,q30,q50,q70,q90\n");
        for (i, x) in res.grid.iter().enumerate() {
            out.push_str(&format!(
                "{x},{},{},{},{},{}\n",
                curves[0][i], curves[1][i], curves[2][i], curves[3][i], curves[4][i]
            ));
        }
        std::fs::write(format!("{dir}/{name}"), out)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crossing_counter() {
        let lower = vec![0.0, 0.0, 0.0];
        let upper = vec![1.0, -0.5, 1.0];
        assert_eq!(count_crossings(&[lower, upper], 1e-9), 1);
    }
}
