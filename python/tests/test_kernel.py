"""L1 correctness: Pallas kernels vs the pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and dtypes; targeted cases pin the piecewise
knots of the smoothed losses. This is the CORE correctness signal for the
kernels that end up inside the AOT artifact.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.smoothed_loss import pallas_h_prime, pallas_smooth_relu_prime
from compile.kernels.spectral_gemv import (
    pallas_gemv,
    pallas_gemv_t,
    vmem_footprint_bytes,
)

RTOL = {np.float32: 2e-5, np.float64: 1e-12}


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(dtype)


@settings(max_examples=25, deadline=None)
@given(
    rows_t=st.integers(1, 6),
    cols=st.integers(1, 48),
    dtype=st.sampled_from([np.float64, np.float32]),
    seed=st.integers(0, 2**31),
)
def test_gemv_matches_ref(rows_t, cols, dtype, seed):
    m = 8 * rows_t  # tile contract: multiple of TILE_ROWS
    a = _rand((m, cols), dtype, seed)
    x = _rand((cols,), dtype, seed + 1)
    got = pallas_gemv(jnp.asarray(a), jnp.asarray(x))
    want = ref.gemv_ref(jnp.asarray(a), jnp.asarray(x))
    np.testing.assert_allclose(got, want, rtol=RTOL[dtype], atol=RTOL[dtype])


@settings(max_examples=25, deadline=None)
@given(
    rows_t=st.integers(1, 6),
    cols=st.integers(1, 48),
    dtype=st.sampled_from([np.float64, np.float32]),
    seed=st.integers(0, 2**31),
)
def test_gemv_t_matches_ref(rows_t, cols, dtype, seed):
    m = 8 * rows_t
    a = _rand((m, cols), dtype, seed)
    x = _rand((m,), dtype, seed + 1)
    got = pallas_gemv_t(jnp.asarray(a), jnp.asarray(x))
    want = ref.gemv_t_ref(jnp.asarray(a), jnp.asarray(x))
    np.testing.assert_allclose(got, want, rtol=10 * RTOL[dtype], atol=10 * RTOL[dtype])


def test_gemv_identity():
    a = jnp.eye(16, dtype=jnp.float64)
    x = jnp.arange(16.0)
    np.testing.assert_allclose(pallas_gemv(a, x), x)
    np.testing.assert_allclose(pallas_gemv_t(a, x), x)


def test_gemv_rejects_bad_tile():
    a = jnp.zeros((10, 4))  # 10 not a multiple of 8
    x = jnp.zeros((4,))
    with pytest.raises(AssertionError):
        pallas_gemv(a, x)


@settings(max_examples=30, deadline=None)
@given(
    n_t=st.integers(1, 8),
    tau=st.floats(0.01, 0.99),
    gamma=st.floats(1e-6, 2.0),
    seed=st.integers(0, 2**31),
)
def test_h_prime_matches_ref(n_t, tau, gamma, seed):
    n = 8 * n_t
    r = _rand((n,), np.float64, seed) * 3.0 * gamma
    got = pallas_h_prime(jnp.asarray(r), tau, gamma)
    want = ref.h_gamma_prime_ref(jnp.asarray(r), tau, gamma)
    np.testing.assert_allclose(got, want, rtol=1e-14, atol=1e-14)
    # range check: H' ∈ [τ−1, τ]
    assert float(jnp.min(got)) >= tau - 1.0 - 1e-12
    assert float(jnp.max(got)) <= tau + 1e-12


def test_h_prime_knots_exact():
    tau, gamma = 0.3, 0.25
    r = jnp.array([-gamma, 0.0, gamma, -2 * gamma, 2 * gamma, -gamma * (1 + 1e-12)])
    got = np.asarray(pallas_h_prime(jnp.resize(r, (8,)), tau, gamma))[:6]
    assert got[0] == pytest.approx(tau - 0.5 - 0.5)  # -γ: τ−1 boundary value
    assert got[1] == pytest.approx(tau - 0.5)
    assert got[2] == pytest.approx(tau + 0.0 + 0.5 - 0.5)  # γ: τ
    assert got[3] == pytest.approx(tau - 1.0)
    assert got[4] == pytest.approx(tau)


@settings(max_examples=20, deadline=None)
@given(
    n_t=st.integers(1, 6),
    eta=st.floats(1e-6, 1.0),
    seed=st.integers(0, 2**31),
)
def test_relu_prime_matches_ref(n_t, eta, seed):
    n = 8 * n_t
    t = _rand((n,), np.float64, seed) * 3.0 * eta
    got = pallas_smooth_relu_prime(jnp.asarray(t), eta)
    want = ref.smooth_relu_prime_ref(jnp.asarray(t), eta)
    np.testing.assert_allclose(got, want, rtol=1e-14, atol=1e-14)
    assert float(jnp.min(got)) >= 0.0
    assert float(jnp.max(got)) <= 1.0


def test_vmem_footprint_within_budget():
    # DESIGN.md §Perf contract: a (64 × 4096) f64 slab fits VMEM easily.
    assert vmem_footprint_bytes(4096, tile_rows=64) < 16 * 2**20
