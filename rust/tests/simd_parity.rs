//! SIMD-vs-scalar parity: the dispatched microkernels (`linalg::simd`)
//! must be **bitwise** equal to the scalar oracle at every tail size and
//! every MR/NR edge combination of the packed GEMM — that is the design
//! contract that lets the whole crate switch ISA tiers without moving a
//! single bit anywhere (solvers, lockstep parity, KKT certificates).
//!
//! The one sanctioned exception: the opt-in `FASTKQR_FMA=1` tier fuses
//! multiply-add (different rounding), so when the resolved global table
//! has `fma` set these tests relax to ≤1e-12 relative tolerance — the
//! same contract the parallel GEMVᵀ reduction carries.
//!
//! CI runs this suite twice: `FASTKQR_SIMD=off` (oracle vs itself — the
//! pre-PR code path) and `FASTKQR_SIMD=auto` (real vector kernels on
//! capable hosts), plus an FMA tolerance pass.

use fastkqr::data::Rng;
use fastkqr::linalg::gemm::{gemm_into_tiled_with, gemm_nn_into, gemm_nt_into};
use fastkqr::linalg::simd::{self, SimdDispatch};
use fastkqr::linalg::{blas, GemmTiles, Matrix};

fn rvec(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

fn rmat(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(rows, cols, |_, _| rng.normal())
}

/// Bitwise equality, unless the resolved table runs the FMA tier — then
/// ≤1e-12 relative (fused rounding is the sanctioned exception).
fn assert_feq(t: &SimdDispatch, got: f64, want: f64, ctx: &str) {
    if t.fma {
        // Non-finite values carry no rounding: NaN must stay NaN and an
        // infinity must keep its sign even under fused arithmetic.
        if want.is_nan() {
            assert!(got.is_nan(), "{ctx}: got {got}, want NaN");
            return;
        }
        if want.is_infinite() {
            assert_eq!(got, want, "{ctx}: got {got}, want {want}");
            return;
        }
        let scale = want.abs().max(1.0);
        assert!(
            (got - want).abs() <= 1e-12 * scale,
            "{ctx}: got {got}, want {want} (fma tolerance)"
        );
    } else {
        assert_eq!(
            got.to_bits(),
            want.to_bits(),
            "{ctx}: got {got} ({:#x}), want {want} ({:#x})",
            got.to_bits(),
            want.to_bits()
        );
    }
}

fn assert_slices_eq(t: &SimdDispatch, got: &[f64], want: &[f64], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_feq(t, *g, *w, &format!("{ctx}[{i}]"));
    }
}

/// Exhaustive tail sweep: every length 0–17 plus a few vector-width
/// multiples, for each level-1 kernel, dispatched table vs scalar oracle.
#[test]
fn level1_kernels_bitwise_match_oracle_at_all_tail_sizes() {
    let t = simd::global();
    let o = simd::scalar();
    let lengths: Vec<usize> = (0..=17).chain([31, 32, 33, 64, 65]).collect();
    for &n in &lengths {
        let a = rvec(n, 1000 + n as u64);
        let b = rvec(n, 2000 + n as u64);
        assert_feq(t, (t.dot)(&a, &b), (o.dot)(&a, &b), &format!("dot n={n}"));
        assert_feq(t, (t.sqdist)(&a, &b), (o.sqdist)(&a, &b), &format!("sqdist n={n}"));

        let y0 = rvec(n, 3000 + n as u64);
        let mut y_t = y0.clone();
        let mut y_o = y0.clone();
        (t.axpy)(0.731, &a, &mut y_t);
        (o.axpy)(0.731, &a, &mut y_o);
        assert_slices_eq(t, &y_t, &y_o, &format!("axpy n={n}"));

        (t.scal)(-2.5, &mut y_t);
        (o.scal)(-2.5, &mut y_o);
        assert_slices_eq(t, &y_t, &y_o, &format!("scal n={n}"));

        let mut r_t = y0.clone();
        let mut r_o = y0;
        (t.rank2)(0.37, &a, -0.93, &b, &mut r_t);
        (o.rank2)(0.37, &a, -0.93, &b, &mut r_o);
        assert_slices_eq(t, &r_t, &r_o, &format!("rank2 n={n}"));
    }
}

/// GEMV / GEMVᵀ over dims covering every remainder class, through the
/// explicit-table serial kernels.
#[test]
fn gemv_and_gemv_t_bitwise_match_oracle() {
    let t = simd::global();
    let o = simd::scalar();
    let dims: Vec<usize> = (1..=9).chain([16, 17]).collect();
    for &m in &dims {
        for &k in &dims {
            let a = rmat(m, k, (m * 100 + k) as u64);
            let x = rvec(k, (m * 7 + k) as u64);
            let mut out_t = vec![0.0; m];
            let mut out_o = vec![0.0; m];
            blas::gemv_serial_with(t, &a, &x, &mut out_t);
            blas::gemv_serial_with(o, &a, &x, &mut out_o);
            assert_slices_eq(t, &out_t, &out_o, &format!("gemv {m}x{k}"));

            let xt = rvec(m, (m * 11 + k) as u64);
            let mut tt = vec![0.0; k];
            let mut to = vec![0.0; k];
            blas::gemv_t_serial_with(t, &a, &xt, &mut tt);
            blas::gemv_t_serial_with(o, &a, &xt, &mut to);
            assert_slices_eq(t, &tt, &to, &format!("gemv_t {m}x{k}"));
        }
    }
}

/// `gemm_nt_into` columns must stay bitwise equal to the scalar serial
/// GEMV — the lockstep driver's parity contract, now across ISA tiers.
#[test]
fn gemm_nt_columns_match_scalar_gemv() {
    let t = simd::global();
    let o = simd::scalar();
    for (p, q, k) in [(1usize, 1usize, 1usize), (5, 3, 7), (8, 4, 16), (9, 5, 17), (33, 6, 21)] {
        let a = rmat(p, k, (p * 31 + k) as u64);
        let b = rmat(q, k, (q * 37 + k) as u64);
        for workers in [1usize, 3] {
            let mut c = Matrix::zeros(p, q);
            gemm_nt_into(&a, &b, &mut c, workers);
            for cell in 0..q {
                let mut expect = vec![0.0; p];
                blas::gemv_serial_with(o, &a, b.row(cell), &mut expect);
                for i in 0..p {
                    assert_feq(
                        t,
                        c[(i, cell)],
                        expect[i],
                        &format!("nt p={p} q={q} k={k} w={workers} [{i},{cell}]"),
                    );
                }
            }
        }
    }
}

/// `gemm_nn_into` rows must stay bitwise equal to the scalar serial
/// GEMVᵀ (k-ascending axpy order, zero-skip included).
#[test]
fn gemm_nn_rows_match_scalar_gemv_t() {
    let t = simd::global();
    let o = simd::scalar();
    for (m, k, n) in [(1usize, 1usize, 1usize), (3, 7, 5), (4, 16, 8), (5, 17, 9), (6, 21, 33)] {
        let mut a = rmat(m, k, (m * 41 + k) as u64);
        a[(0, 0)] = 0.0; // exercise the zero-skip on both paths
        let b = rmat(k, n, (n * 43 + k) as u64);
        for workers in [1usize, 3] {
            let mut c = Matrix::zeros(m, n);
            gemm_nn_into(&a, &b, &mut c, workers);
            for r in 0..m {
                let mut expect = vec![0.0; n];
                blas::gemv_t_serial_with(o, &b, a.row(r), &mut expect);
                assert_slices_eq(
                    t,
                    c.row(r),
                    &expect,
                    &format!("nn m={m} k={k} n={n} w={workers} row {r}"),
                );
            }
        }
    }
}

/// The packed tiled GEMM: dispatched table vs pinned scalar oracle must
/// be bitwise equal element-for-element, across shapes hitting every
/// MR/NR edge combination (full tiles, row edges, column edges, both).
#[test]
fn packed_gemm_bitwise_matches_scalar_across_edge_shapes() {
    let t = simd::global();
    let o = simd::scalar();
    // Tiny tiles so a 12×17×12 problem crosses many panel boundaries.
    let tiles = GemmTiles { mc: 8, kc: 8, nc: 8 };
    let ms = [1usize, 2, 3, 4, 5, 7, 8, 9, 12];
    let ks = [1usize, 4, 5, 16, 17];
    for &m in &ms {
        for &n in &ms {
            for &k in &ks {
                let a = rmat(m, k, (m * 53 + k) as u64);
                let b = rmat(k, n, (n * 59 + k) as u64);
                let mut c_t = Matrix::zeros(m, n);
                let mut c_o = Matrix::zeros(m, n);
                gemm_into_tiled_with(&a, &b, &mut c_t, tiles, 1, t);
                gemm_into_tiled_with(&a, &b, &mut c_o, tiles, 1, o);
                assert_slices_eq(
                    t,
                    c_t.as_slice(),
                    c_o.as_slice(),
                    &format!("packed m={m} k={k} n={n}"),
                );
            }
        }
    }
}

/// NaN and ∞ must flow through the vector kernels exactly as through the
/// scalar ones — no masking, no lane blending surprises.
#[test]
fn nan_and_inf_propagation() {
    let t = simd::global();
    let o = simd::scalar();
    for idx in [0usize, 3, 4, 7, 15, 16] {
        let n = 17;
        let mut a = rvec(n, 71 + idx as u64);
        let b = rvec(n, 72 + idx as u64);

        a[idx] = f64::NAN;
        assert!((t.dot)(&a, &b).is_nan(), "dot NaN at {idx}");
        assert!((o.dot)(&a, &b).is_nan());
        assert!((t.sqdist)(&a, &b).is_nan(), "sqdist NaN at {idx}");
        let mut y_t = b.clone();
        let mut y_o = b.clone();
        (t.axpy)(1.0, &a, &mut y_t);
        (o.axpy)(1.0, &a, &mut y_o);
        assert!(y_t[idx].is_nan() && y_o[idx].is_nan(), "axpy NaN at {idx}");

        a[idx] = f64::INFINITY;
        let (dt, dok) = ((t.dot)(&a, &b), (o.dot)(&a, &b));
        assert!(!dt.is_finite(), "dot inf at {idx} must not be masked");
        assert_feq(t, dt, dok, &format!("dot inf at {idx}"));
        let mut z_t = b.clone();
        let mut z_o = b;
        (t.scal)(f64::INFINITY, &mut z_t);
        (o.scal)(f64::INFINITY, &mut z_o);
        for (g, w) in z_t.iter().zip(&z_o) {
            assert_feq(t, *g, *w, &format!("scal inf at {idx}"));
        }
    }
}

/// `FASTKQR_SIMD=off` (and friends) must pin the scalar oracle no matter
/// what the host CPU supports — the env-override contract. Drives the
/// pure resolver (the process-global table is read-once by design).
#[test]
fn env_off_pins_the_scalar_oracle() {
    // Resolve the process global first, so the set_var below can never
    // race another test's first global() initialization.
    let _ = simd::global();
    for off in ["off", "0", "false", "scalar"] {
        let t = SimdDispatch::resolve(Some(off), None);
        assert_eq!(t.isa.as_str(), "scalar", "FASTKQR_SIMD={off}");
        assert!(!t.fma);
        // FMA request is ignored when the oracle is pinned.
        let t = SimdDispatch::resolve(Some(off), Some("1"));
        assert_eq!(t.isa.as_str(), "scalar");
        assert!(!t.fma);
    }
    // The pinned table must be the oracle arithmetic, not merely labeled
    // scalar: spot-check one dot against the hand-rolled reduction.
    let t = SimdDispatch::resolve(Some("off"), None);
    let a = rvec(17, 81);
    let b = rvec(17, 82);
    let o = simd::scalar();
    assert_eq!((t.dot)(&a, &b).to_bits(), (o.dot)(&a, &b).to_bits());

    // from_env honors the variable end-to-end.
    std::env::set_var("FASTKQR_SIMD", "off");
    let t = SimdDispatch::from_env();
    std::env::remove_var("FASTKQR_SIMD");
    assert_eq!(t.isa.as_str(), "scalar");
}

/// The RBF Gram row runs the dispatched squared distance; Gram entries
/// must be identical whichever table the process resolved (and the FMA
/// tier stays within its tolerance contract).
#[test]
fn rbf_gram_matches_oracle_sqdist() {
    let t = simd::global();
    let o = simd::scalar();
    let x = rmat(13, 7, 91);
    let k = fastkqr::kernel::Kernel::Rbf { sigma: 1.3 }.gram(&x);
    for i in 0..13 {
        for j in 0..13 {
            let d2 = (o.sqdist)(x.row(i), x.row(j));
            let want = (-d2 / (2.0 * 1.3 * 1.3)).exp();
            // exp() amplifies the fused-rounding delta slightly; bitwise
            // when the table is exact, small tolerance under FMA.
            if t.fma {
                assert!((k[(i, j)] - want).abs() <= 1e-12, "gram[{i},{j}]");
            } else {
                assert_eq!(k[(i, j)].to_bits(), want.to_bits(), "gram[{i},{j}]");
            }
        }
    }
}
