//! Lock-free operational metrics.

use crate::util::Histogram;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// Atomic counters shared between workers, server threads and the CLI.
#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub fits_total: AtomicU64,
    /// Fits executed by the APGD backend (counted per request after the
    /// spec's `auto` choice is resolved, so the pair always sums to the
    /// number of successful fit requests).
    pub solver_apgd_fits: AtomicU64,
    /// Fits executed by the pALM semismooth-Newton backend.
    pub solver_ssn_fits: AtomicU64,
    /// Fit requests whose spec said `auto` and the server resolved it
    /// from the cost model (either way it lands in one of the two
    /// counters above).
    pub solver_auto_resolutions: AtomicU64,
    /// Full Cholesky refactorizations performed by SSN fits (grid
    /// drivers and single cells alike).
    pub ssn_refactorizations: AtomicU64,
    /// Rank-1 factor up/downdates SSN applied instead of refactoring —
    /// the grid carry's whole payoff is this counter growing while
    /// `ssn_refactorizations` stays near the cell count.
    pub ssn_rank1_updates: AtomicU64,
    pub predict_requests: AtomicU64,
    pub apgd_iters_total: AtomicU64,
    /// Microseconds spent inside solvers.
    pub solver_micros: AtomicU64,
    pub requests_total: AtomicU64,
    pub protocol_errors: AtomicU64,
    /// Batches flushed by the predict micro-batcher (each serves ≥ 1
    /// request; `predict_batches <= predict_requests` always holds).
    pub predict_batches: AtomicU64,
    /// Predict requests rejected by the per-model queue's backpressure
    /// cap (the client gets a clean error, never a hang).
    pub predict_rejects: AtomicU64,
    /// Per-worker warm-start states dropped because the engine's
    /// GramCache no longer holds their dataset's factorization.
    pub warm_evictions: AtomicU64,
    /// Connections accepted since spawn (both io models).
    pub connections_accepted: AtomicU64,
    /// Currently-open connections (gauge: incremented at accept,
    /// decremented at close — `shutdown()` drains it back to zero).
    pub active_connections: AtomicU64,
    /// High-water mark of `active_connections`.
    pub connections_peak: AtomicU64,
    /// Accept-side `thread::Builder::spawn` failures (thread-per-
    /// connection model under thread/fd exhaustion): the client gets a
    /// protocol error line instead of a silent close.
    pub accept_spawn_errors: AtomicU64,
    /// Requests rejected because the event loop's bounded worker queue
    /// was full (clean protocol error, never a hang).
    pub queue_full_rejects: AtomicU64,
    /// The resolved io model this server runs (`"threads"` / `"epoll"`),
    /// set once at spawn.
    pub io_model: OnceLock<&'static str>,
    /// Size of the bounded worker pool behind the event loop (0 under
    /// the thread-per-connection model, which has no pool).
    pub worker_threads: AtomicU64,
    /// Workers currently executing a request (gauge; event loop only).
    pub workers_busy: AtomicU64,
    /// High-water mark of `workers_busy` — the whole point of the
    /// bounded pool: this never exceeds `worker_threads` no matter how
    /// many connections are open.
    pub workers_busy_peak: AtomicU64,
    /// End-to-end predict latency (µs, from request dispatch to response
    /// ready — includes batch-window parking).
    pub predict_latency: Histogram,
    /// Requests coalesced per flushed predict batch.
    pub predict_batch_size: Histogram,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn incr(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }

    /// Decrement a gauge (saturating at zero rather than wrapping).
    pub fn dec(gauge: &AtomicU64) {
        let _ =
            gauge.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(1)));
    }

    /// Increment the `active_connections` gauge and fold the new value
    /// into the `connections_peak` high-water mark.
    pub fn conn_opened(&self) {
        Self::incr(&self.connections_accepted);
        let now = self.active_connections.fetch_add(1, Ordering::Relaxed) + 1;
        self.connections_peak.fetch_max(now, Ordering::Relaxed);
    }

    pub fn conn_closed(&self) {
        Self::dec(&self.active_connections);
    }

    /// Render as a JSON object (served by the `metrics` command).
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        Json::obj(vec![
            ("jobs_submitted", Json::num(Self::get(&self.jobs_submitted) as f64)),
            ("jobs_completed", Json::num(Self::get(&self.jobs_completed) as f64)),
            ("jobs_failed", Json::num(Self::get(&self.jobs_failed) as f64)),
            ("fits_total", Json::num(Self::get(&self.fits_total) as f64)),
            ("solver_apgd_fits", Json::num(Self::get(&self.solver_apgd_fits) as f64)),
            ("solver_ssn_fits", Json::num(Self::get(&self.solver_ssn_fits) as f64)),
            (
                "solver_auto_resolutions",
                Json::num(Self::get(&self.solver_auto_resolutions) as f64),
            ),
            ("ssn_refactorizations", Json::num(Self::get(&self.ssn_refactorizations) as f64)),
            ("ssn_rank1_updates", Json::num(Self::get(&self.ssn_rank1_updates) as f64)),
            ("predict_requests", Json::num(Self::get(&self.predict_requests) as f64)),
            ("apgd_iters_total", Json::num(Self::get(&self.apgd_iters_total) as f64)),
            ("solver_micros", Json::num(Self::get(&self.solver_micros) as f64)),
            ("requests_total", Json::num(Self::get(&self.requests_total) as f64)),
            ("protocol_errors", Json::num(Self::get(&self.protocol_errors) as f64)),
            ("predict_batches", Json::num(Self::get(&self.predict_batches) as f64)),
            ("predict_rejects", Json::num(Self::get(&self.predict_rejects) as f64)),
            ("warm_evictions", Json::num(Self::get(&self.warm_evictions) as f64)),
            ("connections_accepted", Json::num(Self::get(&self.connections_accepted) as f64)),
            ("active_connections", Json::num(Self::get(&self.active_connections) as f64)),
            ("connections_peak", Json::num(Self::get(&self.connections_peak) as f64)),
            ("accept_spawn_errors", Json::num(Self::get(&self.accept_spawn_errors) as f64)),
            ("queue_full_rejects", Json::num(Self::get(&self.queue_full_rejects) as f64)),
            ("io_model", Json::str(self.io_model.get().copied().unwrap_or("unset"))),
            ("worker_threads", Json::num(Self::get(&self.worker_threads) as f64)),
            ("workers_busy", Json::num(Self::get(&self.workers_busy) as f64)),
            ("workers_busy_peak", Json::num(Self::get(&self.workers_busy_peak) as f64)),
            ("predict_latency_us_p50", Json::num(self.predict_latency.p50() as f64)),
            ("predict_latency_us_p95", Json::num(self.predict_latency.p95() as f64)),
            ("predict_latency_us_p99", Json::num(self.predict_latency.p99() as f64)),
            ("predict_latency_us_max", Json::num(self.predict_latency.max() as f64)),
            ("predict_batch_p50", Json::num(self.predict_batch_size.p50() as f64)),
            ("predict_batch_p95", Json::num(self.predict_batch_size.p95() as f64)),
            ("predict_batch_p99", Json::num(self.predict_batch_size.p99() as f64)),
            ("predict_batch_max", Json::num(self.predict_batch_size.max() as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        Metrics::incr(&m.jobs_submitted);
        Metrics::add(&m.jobs_submitted, 2);
        assert_eq!(Metrics::get(&m.jobs_submitted), 3);
        let j = m.to_json();
        assert_eq!(j.get_f64("jobs_submitted"), Some(3.0));
    }

    #[test]
    fn connection_gauge_tracks_peak_and_never_underflows() {
        let m = Metrics::new();
        m.conn_opened();
        m.conn_opened();
        assert_eq!(Metrics::get(&m.active_connections), 2);
        m.conn_closed();
        m.conn_closed();
        m.conn_closed(); // extra close: saturates at zero, no wrap
        assert_eq!(Metrics::get(&m.active_connections), 0);
        assert_eq!(Metrics::get(&m.connections_peak), 2);
        assert_eq!(Metrics::get(&m.connections_accepted), 2);
        let j = m.to_json();
        assert_eq!(j.get_f64("connections_peak"), Some(2.0));
        assert_eq!(j.get_str("io_model"), Some("unset"));
    }

    #[test]
    fn histograms_surface_in_json() {
        let m = Metrics::new();
        m.predict_batch_size.record(1);
        m.predict_batch_size.record(4);
        m.predict_latency.record(100);
        let j = m.to_json();
        assert_eq!(j.get_f64("predict_batch_max"), Some(4.0));
        assert!(j.get_f64("predict_latency_us_p50").unwrap() >= 100.0);
        assert_eq!(j.get_f64("predict_batches"), Some(0.0));
    }
}
