"""L2 correctness: the AOT-able APGD chunk vs the pure-jnp reference.

Builds a real spectral plan (eigendecomposition of an RBF Gram matrix —
the same quantities the Rust side computes) and checks:
  - chunk == reference recurrence, elementwise;
  - zero-padding under the mask is exact;
  - the chunk actually optimizes (stationarity residual falls, and at
    convergence the subgradient identity nλα = z holds).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.model import CHUNK, apgd_chunk


def make_problem(n, seed=0, sigma=0.7, gamma=0.1, lam=0.05, tau=0.3):
    rng = np.random.default_rng(seed)
    x = rng.uniform(size=(n, 1))
    y = np.sin(4.0 * x[:, 0]) + 0.3 * rng.standard_normal(n)
    d2 = (x[:, None, 0] - x[None, :, 0]) ** 2
    k = np.exp(-d2 / (2 * sigma**2))
    lam_diag, u = np.linalg.eigh(k)
    lam_diag = np.clip(lam_diag, 0.0, None)
    u1 = u.T @ np.ones(n)
    ridge = 2.0 * n * gamma * lam
    pil = 1.0 / (lam_diag + ridge)
    p = pil * u1
    lam_p = lam_diag * p
    g = 1.0 / (n - np.sum(u1**2 * lam_diag * pil))
    args = dict(
        u_mat=jnp.asarray(u),
        lam_diag=jnp.asarray(lam_diag),
        pil=jnp.asarray(pil),
        p=jnp.asarray(p),
        lam_p=jnp.asarray(lam_p),
        g=jnp.asarray(g),
        y=jnp.asarray(y),
        tau=jnp.asarray(tau),
        gamma=jnp.asarray(gamma),
        nlam=jnp.asarray(n * lam),
    )
    return args, k


def zero_state(n):
    return dict(
        b=jnp.asarray(0.0),
        beta=jnp.zeros(n),
        b_prev=jnp.asarray(0.0),
        beta_prev=jnp.zeros(n),
        ck=jnp.asarray(1.0),
    )


def run_chunk(args, state, n):
    return apgd_chunk(
        args["u_mat"], args["lam_diag"], args["pil"], args["p"], args["lam_p"],
        args["g"], args["y"], jnp.ones(n), jnp.asarray(1.0 / n), args["tau"],
        args["gamma"], args["nlam"], state["b"], state["beta"],
        state["b_prev"], state["beta_prev"], state["ck"],
    )


def test_chunk_matches_reference():
    n = 32
    args, _ = make_problem(n, seed=1)
    state = zero_state(n)
    got = run_chunk(args, state, n)
    want = ref.apgd_chunk_ref(
        args["u_mat"], args["lam_diag"], args["pil"], args["p"], args["lam_p"],
        args["g"], args["y"], args["tau"], args["gamma"], args["nlam"],
        state["b"], state["beta"], state["b_prev"], state["beta_prev"],
        state["ck"], CHUNK,
    )
    for a, b, name in zip(got, want, ["b", "beta", "b_prev", "beta_prev", "ck", "conv"]):
        np.testing.assert_allclose(a, b, rtol=1e-10, atol=1e-12, err_msg=name)


def test_padding_is_exact():
    n, n_pad = 24, 40
    args, _ = make_problem(n, seed=2)
    # padded operands
    u_pad = jnp.zeros((n_pad, n_pad)).at[:n, :n].set(args["u_mat"])
    pad_vec = lambda v, fill=0.0: jnp.full(n_pad, fill).at[:n].set(v)
    # padded pil entries: the n_pad-size plan value at λ=0 (any finite
    # value works since t_pad = 0; use the natural 1/ridge)
    ridge = 2.0 * n * float(args["gamma"]) * (float(args["nlam"]) / n)
    state = zero_state(n_pad)
    got_pad = apgd_chunk(
        u_pad, pad_vec(args["lam_diag"]), pad_vec(args["pil"], 1.0 / ridge),
        pad_vec(args["p"]), pad_vec(args["lam_p"]), args["g"],
        pad_vec(args["y"], 123.0),  # junk y in the padding
        pad_vec(jnp.ones(n), 0.0),  # mask
        jnp.asarray(1.0 / n), args["tau"], args["gamma"], args["nlam"],
        state["b"], state["beta"], state["b_prev"], state["beta_prev"], state["ck"],
    )
    got = run_chunk(args, zero_state(n), n)
    np.testing.assert_allclose(got_pad[0], got[0], rtol=1e-12)  # b
    np.testing.assert_allclose(got_pad[1][:n], got[1], rtol=1e-10, atol=1e-12)  # beta
    np.testing.assert_allclose(got_pad[1][n:], 0.0, atol=1e-14)  # padding inert
    np.testing.assert_allclose(got_pad[5], got[5], rtol=1e-10)  # conv


def test_chunk_converges_to_stationarity():
    n = 40
    args, k = make_problem(n, seed=3, gamma=0.05, lam=0.02, tau=0.5)
    state = zero_state(n)
    conv = np.inf
    for _ in range(200):
        out = run_chunk(args, state, n)
        state = dict(b=out[0], beta=out[1], b_prev=out[2], beta_prev=out[3], ck=out[4])
        conv = float(out[5])
        if conv < 1e-10:
            break
    assert conv < 1e-8, f"conv={conv}"
    # subgradient identity nλα = z at the smoothed optimum
    alpha = np.asarray(args["u_mat"] @ state["beta"])
    f = float(state["b"]) + k @ alpha
    z = np.asarray(ref.h_gamma_prime_ref(args["y"] - f, args["tau"], args["gamma"]))
    np.testing.assert_allclose(n * 0.02 * alpha, z, atol=1e-6)
    # intercept optimality
    assert abs(z.sum()) / n < 1e-8


def test_conv_is_finite_and_positive_scale():
    n = 16
    args, _ = make_problem(n, seed=4)
    out = run_chunk(args, zero_state(n), n)
    assert np.isfinite(float(out[5]))
    assert float(out[4]) > 1.0  # ck advanced


@pytest.mark.parametrize("tau", [0.1, 0.9])
def test_chunk_objective_decreases(tau):
    n = 24
    args, k = make_problem(n, seed=5, tau=tau)

    def smoothed_obj(state):
        alpha = np.asarray(args["u_mat"] @ state["beta"])
        f = float(state["b"]) + k @ alpha
        h = np.asarray(ref.h_gamma_ref(args["y"] - f, args["tau"], args["gamma"]))
        lam = float(args["nlam"]) / n
        return h.mean() + 0.5 * lam * float(
            jnp.dot(state["beta"] * args["lam_diag"], state["beta"])
        )

    state = zero_state(n)
    prev = smoothed_obj(state)
    for _ in range(8):
        out = run_chunk(args, state, n)
        state = dict(b=out[0], beta=out[1], b_prev=out[2], beta_prev=out[3], ck=out[4])
        cur = smoothed_obj(state)
        # Nesterov is not strictly monotone; allow a tiny relative ripple
        assert cur <= prev + 1e-7 * (1.0 + abs(prev))
        prev = cur
