//! PJRT runtime: load AOT-compiled HLO-text artifacts and run them on the
//! request path (the L3 ⇄ L2/L1 bridge; Python is never involved here).
//!
//! - [`ArtifactManifest`]: `artifacts/manifest.json` written by
//!   `python/compile/aot.py` (always available — plain JSON parsing).
//! - `XlaRuntime` / [`XlaBackend`]: a PJRT CPU client plus a cache of
//!   compiled executables (compile once per artifact, execute many).
//!
//! The PJRT pieces need the `xla` bindings crate and a PJRT CPU plugin,
//! which the offline image does not ship. They are therefore gated behind
//! the `xla` cargo feature; the default build exports a stub
//! [`XlaBackend`] whose constructors return an error, so every caller
//! that probes for the backend (`--backend xla`, the e2e example, the
//! perf harness) degrades gracefully at runtime while still compiling.

use crate::util::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::{XlaBackend, XlaRuntime};

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::XlaBackend;

/// One entry of the artifact manifest.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub kind: String,
    pub n: usize,
    pub chunk: usize,
    pub path: PathBuf,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub chunk: usize,
    pub entries: Vec<ArtifactEntry>,
    pub dir: PathBuf,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactManifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let chunk = json
            .get_f64("chunk")
            .ok_or_else(|| anyhow!("manifest missing 'chunk'"))? as usize;
        let mut entries = Vec::new();
        for a in json
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?
        {
            entries.push(ArtifactEntry {
                kind: a.get_str("kind").unwrap_or("unknown").to_string(),
                n: a.get_f64("n").ok_or_else(|| anyhow!("artifact missing n"))? as usize,
                chunk: a.get_f64("chunk").unwrap_or(chunk as f64) as usize,
                path: dir.join(a.get_str("path").ok_or_else(|| anyhow!("missing path"))?),
            });
        }
        entries.sort_by_key(|e| e.n);
        Ok(ArtifactManifest { chunk, entries, dir })
    }

    /// Smallest apgd_chunk artifact with artifact-n ≥ n.
    pub fn best_for(&self, n: usize) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.kind == "apgd_chunk" && e.n >= n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_when_artifacts_built() {
        // Integration-grade checks live in rust/tests/xla_backend.rs; here
        // we only exercise the manifest parser against the real file if it
        // exists (unit tests must not require `make artifacts`).
        if let Ok(m) = ArtifactManifest::load("artifacts") {
            assert!(m.chunk > 0);
            assert!(!m.entries.is_empty());
            assert!(m.best_for(10).is_some());
            let e = m.best_for(100).unwrap();
            assert!(e.n >= 100);
        }
    }

    #[test]
    fn manifest_missing_dir_errors() {
        assert!(ArtifactManifest::load("/nonexistent/dir").is_err());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_backend_reports_unavailable() {
        let err = XlaBackend::from_default_dir().unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
