//! Coordinator integration: mixed job batches through the scheduler, the
//! registry wiring, and warm-start accounting across the λ grid.

use fastkqr::coordinator::registry::StoredModel;
use fastkqr::coordinator::{FitJob, JobOutcome, JobSpec, Metrics, ModelRegistry, Scheduler};
use fastkqr::data::{synth, Rng};
use fastkqr::kernel::Kernel;

fn job(id: u64, seed: u64, n: usize, spec: JobSpec) -> FitJob {
    let mut rng = Rng::new(seed);
    FitJob {
        id,
        dataset: synth::sine_hetero(n, &mut rng),
        kernel: Kernel::Rbf { sigma: 0.4 },
        spec,
    }
}

#[test]
fn mixed_batch_flows_into_registry() {
    let sched = Scheduler::new(2);
    let registry = ModelRegistry::new();
    let jobs = vec![
        job(1, 1, 40, JobSpec::KqrPath { tau: 0.5, lambdas: vec![0.5, 0.05, 0.005] }),
        job(2, 1, 40, JobSpec::Nckqr { taus: vec![0.25, 0.75], lam1: 2.0, lam2: 0.05 }),
        job(3, 1, 40, JobSpec::Kqr { tau: 0.1, lambda: 0.02 }),
    ];
    let rx = sched.submit_batch(jobs);
    let mut seen = 0;
    for _ in 0..3 {
        let (id, res) = rx.recv().unwrap();
        match res.unwrap() {
            JobOutcome::Kqr(fits) => {
                for f in fits {
                    assert!(f.kkt.pass, "job {id}");
                    registry.insert(StoredModel::Kqr(f));
                }
            }
            JobOutcome::Nckqr(f) => {
                assert!(f.kkt.pass);
                registry.insert(StoredModel::Nckqr(f));
            }
            JobOutcome::Cv(_) => panic!("no cv submitted"),
        }
        seen += 1;
    }
    assert_eq!(seen, 3);
    // path (3 fits) + nckqr (1) + single (1)
    assert_eq!(registry.len(), 5);
    assert_eq!(Metrics::get(&sched.metrics.fits_total), 5);
    sched.shutdown();
}

#[test]
fn warm_ordering_reduces_iterations_on_same_dataset() {
    // Two identical batches, one submitted ascending λ (worst case), one
    // through submit_batch (sorted descending). The scheduler's per-worker
    // solver cache + warm state should make the sorted batch cheaper in
    // total APGD iterations.
    let lambda_grid = [0.5, 0.1, 0.02, 0.004];

    // unsorted, forced ascending via individual submits
    let sched_a = Scheduler::new(1);
    for (i, &l) in lambda_grid.iter().rev().enumerate() {
        let rx = sched_a.submit(job(i as u64, 7, 50, JobSpec::Kqr { tau: 0.5, lambda: l }));
        rx.recv().unwrap().1.unwrap();
    }
    let iters_ascending = Metrics::get(&sched_a.metrics.apgd_iters_total);
    sched_a.shutdown();

    // sorted batch
    let sched_b = Scheduler::new(1);
    let jobs: Vec<FitJob> = lambda_grid
        .iter()
        .rev() // submit ascending; scheduler sorts back to descending
        .enumerate()
        .map(|(i, &l)| job(i as u64, 7, 50, JobSpec::Kqr { tau: 0.5, lambda: l }))
        .collect();
    let rx = sched_b.submit_batch(jobs);
    for _ in 0..lambda_grid.len() {
        rx.recv().unwrap().1.unwrap();
    }
    let iters_sorted = Metrics::get(&sched_b.metrics.apgd_iters_total);
    sched_b.shutdown();

    assert!(
        iters_sorted <= iters_ascending,
        "warm-ordered batch used more iterations: {iters_sorted} vs {iters_ascending}"
    );
}

#[test]
fn cv_job_through_scheduler() {
    let sched = Scheduler::new(1);
    let rx = sched.submit(job(
        1,
        3,
        45,
        JobSpec::Cv { tau: 0.5, lambdas: vec![0.5, 0.05, 0.005], folds: 3, seed: 1 },
    ));
    let (_, res) = rx.recv().unwrap();
    match res.unwrap() {
        JobOutcome::Cv(cv) => {
            assert_eq!(cv.cv_loss.len(), 3);
            assert!(cv.best_lambda > 0.0);
        }
        _ => panic!("expected CV outcome"),
    }
    sched.shutdown();
}
