//! Scoped-thread parallel substrate for the dense kernels (engine L1).
//!
//! The offline image has no rayon, so this module implements the minimal
//! data-parallel layer the fit engine needs on plain `std::thread::scope`:
//! row-blocked GEMV/GEMVᵀ/GEMM and a generic row-filler (the Gram
//! construction in `kernel` uses the same scoped-thread pattern with
//! triangle-balanced row bands). Design rules:
//!
//! - **Bit-stable small-n behavior.** Every operation falls back to the
//!   serial kernel below [`Parallelism::min_dim`], and the row-parallel
//!   kernels (`par_gemv`, `par_gemm`, `par_fill_rows`) compute each
//!   output row with the *identical* serial accumulation order, so their
//!   results are bitwise equal to the serial path at any size. Only
//!   `par_gemv_t` re-associates its reduction (per-thread partials summed
//!   block-by-block); its results agree with serial to ~1e-12 relative.
//! - **Bounded, nest-aware concurrency.** [`serial_scope`] lets an outer
//!   parallel loop (CV folds, τ columns, scheduler workers) disable
//!   intra-op parallelism on its worker threads, so the process never
//!   oversubscribes: one level parallelizes, the other runs serial.
//! - **Configurable without code.** `FASTKQR_THREADS` overrides the
//!   worker count (default: available cores); `FASTKQR_PAR_MIN_DIM`
//!   overrides the serial cutoff (default 512).
//! - **Orthogonal to SIMD.** Each band runs the same dispatched serial
//!   kernels (`linalg::simd`), which are bitwise-equal to the scalar
//!   oracle — so the thread axis and the ISA axis compose without any
//!   new parity surface.

use super::matrix::Matrix;
use std::cell::Cell;
use std::sync::OnceLock;

/// Parallel execution configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Parallelism {
    /// Maximum worker threads per parallel operation.
    pub threads: usize,
    /// Operations whose parallel dimension is below this run serially
    /// (thread spawn/join costs more than the work saves, and serial
    /// small-n results stay exactly as before).
    pub min_dim: usize,
}

impl Parallelism {
    /// Default serial cutoff: n = 512 GEMV ≈ 2 Mflop, comfortably above
    /// scoped-thread overhead on commodity cores.
    pub const DEFAULT_MIN_DIM: usize = 512;

    /// Strictly serial configuration.
    pub fn serial() -> Parallelism {
        Parallelism { threads: 1, min_dim: usize::MAX }
    }

    /// Environment-driven default: `FASTKQR_THREADS` (else available
    /// cores) and `FASTKQR_PAR_MIN_DIM` (else 512).
    pub fn auto() -> Parallelism {
        let threads = std::env::var("FASTKQR_THREADS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&t| t >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        let min_dim = std::env::var("FASTKQR_PAR_MIN_DIM")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&d| d >= 1)
            .unwrap_or(Self::DEFAULT_MIN_DIM);
        Parallelism { threads, min_dim }
    }

    /// Fixed thread count with the default cutoff.
    pub fn with_threads(threads: usize) -> Parallelism {
        Parallelism { threads: threads.max(1), min_dim: Self::DEFAULT_MIN_DIM }
    }

    /// Effective worker count for an operation whose parallel dimension
    /// is `dim`: 1 (serial) below the cutoff, inside a [`serial_scope`],
    /// or when only one thread is configured.
    pub fn workers_for(&self, dim: usize) -> usize {
        if self.threads <= 1 || dim < self.min_dim || in_serial_scope() {
            1
        } else {
            self.threads.min(dim)
        }
    }
}

static GLOBAL: OnceLock<Parallelism> = OnceLock::new();

/// The process-wide configuration the dispatching kernels consult.
pub fn global() -> Parallelism {
    *GLOBAL.get_or_init(Parallelism::auto)
}

/// Install a specific global configuration. First initializer (this call
/// or the first [`global`]) wins; returns the effective configuration.
pub fn init_global(par: Parallelism) -> Parallelism {
    *GLOBAL.get_or_init(|| par)
}

thread_local! {
    static SERIAL_DEPTH: Cell<usize> = Cell::new(0);
}

/// Is intra-op parallelism disabled on this thread?
pub fn in_serial_scope() -> bool {
    SERIAL_DEPTH.with(|d| d.get() > 0)
}

struct SerialGuard;

impl SerialGuard {
    fn enter() -> SerialGuard {
        SERIAL_DEPTH.with(|d| d.set(d.get() + 1));
        SerialGuard
    }
}

impl Drop for SerialGuard {
    fn drop(&mut self) {
        SERIAL_DEPTH.with(|d| d.set(d.get() - 1));
    }
}

/// Run `f` with intra-op parallelism disabled on this thread. Outer-level
/// parallel loops (CV folds, grid τ columns, scheduler workers) wrap their
/// per-item work in this so nested GEMVs do not oversubscribe the machine.
pub fn serial_scope<T>(f: impl FnOnce() -> T) -> T {
    let _guard = SerialGuard::enter();
    f()
}

/// Contiguous band size for distributing `items` across `workers`
/// (shared by the row-blocked kernels here and the BLAS-3 layer in
/// [`super::gemm`]).
#[inline]
pub(crate) fn block_size(items: usize, workers: usize) -> usize {
    let w = workers.max(1);
    ((items + w - 1) / w).max(1)
}

/// Row-blocked parallel `out = A x`. Each worker computes a contiguous
/// block of output rows with the identical serial row kernel, so the
/// result is bitwise equal to the serial GEMV.
pub fn par_gemv(a: &Matrix, x: &[f64], out: &mut [f64], workers: usize) {
    assert_eq!(a.cols(), x.len(), "par_gemv: dim mismatch");
    assert_eq!(a.rows(), out.len(), "par_gemv: out dim mismatch");
    if workers <= 1 || a.rows() == 0 {
        super::blas::gemv_serial(a, x, out);
        return;
    }
    let block = block_size(a.rows(), workers);
    std::thread::scope(|s| {
        for (bi, chunk) in out.chunks_mut(block).enumerate() {
            let start = bi * block;
            s.spawn(move || {
                for (r, o) in chunk.iter_mut().enumerate() {
                    *o = super::blas::dot(a.row(start + r), x);
                }
            });
        }
    });
}

/// Row-blocked parallel `out = Aᵀ x`: each worker accumulates a private
/// `out`-sized partial over its row block (streaming A once, like the
/// serial kernel), partials are then summed in block order. The reduction
/// is re-associated across blocks, so results agree with the serial path
/// to rounding (~1e-12 relative), not bitwise.
pub fn par_gemv_t(a: &Matrix, x: &[f64], out: &mut [f64], workers: usize) {
    assert_eq!(a.rows(), x.len(), "par_gemv_t: dim mismatch");
    assert_eq!(a.cols(), out.len(), "par_gemv_t: out dim mismatch");
    if workers <= 1 || a.rows() == 0 {
        super::blas::gemv_t_serial(a, x, out);
        return;
    }
    let rows = a.rows();
    let cols = a.cols();
    let block = block_size(rows, workers);
    let mut partials: Vec<Vec<f64>> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        let mut start = 0usize;
        while start < rows {
            let end = (start + block).min(rows);
            handles.push(s.spawn(move || {
                let mut acc = vec![0.0f64; cols];
                for i in start..end {
                    let xi = x[i];
                    if xi != 0.0 {
                        super::blas::axpy(xi, a.row(i), &mut acc);
                    }
                }
                acc
            }));
            start = end;
        }
        for h in handles {
            partials.push(h.join().expect("par_gemv_t worker panicked"));
        }
    });
    out.fill(0.0);
    for p in &partials {
        super::blas::axpy(1.0, p, out);
    }
}

/// Row-blocked parallel `C = A B`: workers own disjoint row blocks of C
/// and run the same cache-blocked i-k-j kernel as the serial GEMM, so
/// each C row is computed in the identical accumulation order (bitwise
/// equal to serial).
pub fn par_gemm(a: &Matrix, b: &Matrix, workers: usize) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "par_gemm: inner dim mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    if workers <= 1 || m == 0 || n == 0 {
        return super::blas::gemm_serial(a, b);
    }
    let mut c = Matrix::zeros(m, n);
    let block = block_size(m, workers);
    std::thread::scope(|s| {
        for (bi, crows) in c.as_mut_slice().chunks_mut(block * n).enumerate() {
            let row0 = bi * block;
            s.spawn(move || {
                const BK: usize = 64;
                let rows_here = crows.len() / n;
                for kb in (0..k).step_by(BK) {
                    let kend = (kb + BK).min(k);
                    for r in 0..rows_here {
                        let arow = a.row(row0 + r);
                        let crow = &mut crows[r * n..(r + 1) * n];
                        for kk in kb..kend {
                            let aik = arow[kk];
                            if aik != 0.0 {
                                super::blas::axpy(aik, b.row(kk), crow);
                            }
                        }
                    }
                }
            });
        }
    });
    c
}

/// Fill the rows of `out` in parallel: `f(i, row)` writes row `i`.
/// Workers own disjoint contiguous row blocks; `f` runs exactly once per
/// row, so results equal the serial loop whenever `f` is deterministic.
/// Used for parallel Gram construction.
pub fn par_fill_rows<F>(out: &mut Matrix, workers: usize, f: F)
where
    F: Fn(usize, &mut [f64]) + Sync,
{
    let rows = out.rows();
    let cols = out.cols();
    if rows == 0 || cols == 0 {
        return;
    }
    if workers <= 1 {
        for i in 0..rows {
            f(i, out.row_mut(i));
        }
        return;
    }
    let block = block_size(rows, workers);
    let fref = &f;
    std::thread::scope(|s| {
        for (bi, chunk) in out.as_mut_slice().chunks_mut(block * cols).enumerate() {
            let row0 = bi * block;
            s.spawn(move || {
                for (r, row) in chunk.chunks_mut(cols).enumerate() {
                    fref(row0 + r, row);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(rows, cols, |_, _| rng.normal())
    }

    #[test]
    fn par_gemv_bitwise_matches_serial() {
        for workers in [2usize, 3, 7] {
            let a = random_matrix(53, 29, 1);
            let mut rng = Rng::new(2);
            let x: Vec<f64> = (0..29).map(|_| rng.normal()).collect();
            let mut serial = vec![0.0; 53];
            super::super::blas::gemv_serial(&a, &x, &mut serial);
            let mut par = vec![0.0; 53];
            par_gemv(&a, &x, &mut par, workers);
            assert_eq!(serial, par, "workers={workers}");
        }
    }

    #[test]
    fn par_gemv_t_matches_serial_to_rounding() {
        for workers in [2usize, 4] {
            let a = random_matrix(61, 37, 3);
            let mut rng = Rng::new(4);
            let x: Vec<f64> = (0..61).map(|_| rng.normal()).collect();
            let mut serial = vec![0.0; 37];
            super::super::blas::gemv_t_serial(&a, &x, &mut serial);
            let mut par = vec![0.0; 37];
            par_gemv_t(&a, &x, &mut par, workers);
            for (s, p) in serial.iter().zip(&par) {
                assert!((s - p).abs() < 1e-12, "workers={workers}: {s} vs {p}");
            }
        }
    }

    #[test]
    fn par_gemm_bitwise_matches_serial() {
        let a = random_matrix(33, 21, 5);
        let b = random_matrix(21, 17, 6);
        let serial = super::super::blas::gemm_serial(&a, &b);
        for workers in [2usize, 5] {
            let par = par_gemm(&a, &b, workers);
            assert_eq!(serial.as_slice(), par.as_slice(), "workers={workers}");
        }
    }

    #[test]
    fn par_fill_rows_covers_every_row_once() {
        let mut m = Matrix::zeros(41, 7);
        par_fill_rows(&mut m, 4, |i, row| {
            for (j, v) in row.iter_mut().enumerate() {
                *v = (i * 7 + j) as f64;
            }
        });
        for i in 0..41 {
            for j in 0..7 {
                assert_eq!(m[(i, j)], (i * 7 + j) as f64);
            }
        }
    }

    #[test]
    fn serial_scope_disables_workers() {
        let par = Parallelism::with_threads(8);
        assert_eq!(par.workers_for(10_000), 8);
        serial_scope(|| {
            assert_eq!(par.workers_for(10_000), 1);
            // nested scopes stack
            serial_scope(|| assert_eq!(par.workers_for(10_000), 1));
            assert_eq!(par.workers_for(10_000), 1);
        });
        assert_eq!(par.workers_for(10_000), 8);
    }

    #[test]
    fn workers_respect_cutoff_and_dim() {
        let par = Parallelism { threads: 4, min_dim: 100 };
        assert_eq!(par.workers_for(99), 1);
        assert_eq!(par.workers_for(100), 4);
        assert_eq!(par.workers_for(2), 1); // below cutoff
        let wide = Parallelism { threads: 16, min_dim: 1 };
        assert_eq!(wide.workers_for(3), 3); // capped by dim
        assert_eq!(Parallelism::serial().workers_for(1_000_000), 1);
    }
}
