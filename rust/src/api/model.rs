//! The unified model facade over KQR, NCKQR and fit-set results.
//!
//! Everything downstream of a fit — the registry, the predict path, the
//! CLI and the persistence layer — handles a [`QuantileModel`] instead of
//! caring which solver produced it. One `predict` (one output row per
//! quantile level / grid cell), one `taus`, one `diagnostics`, one
//! versioned save/load (see [`super::artifact`]).

use super::artifact;
use crate::engine::{GridFit, LockstepStats, PredictPlan};
use crate::kqr::KqrFit;
use crate::linalg::Matrix;
use crate::nckqr::NckqrFit;
use crate::solver::{SolverBackend, SsnGridStats};
use crate::util::Json;
use anyhow::Result;
use std::path::Path;

/// Provenance of a [`ModelSet`]'s fits.
#[derive(Clone, Debug, PartialEq)]
pub enum SetShape {
    /// A λ path at one τ (fits in grid order).
    Path { tau: f64 },
    /// A full τ×λ grid; fits are flattened τ-major (`fits[ti*|λ|+li]`).
    Grid { taus: Vec<f64>, lambdas: Vec<f64> },
    /// Per-τ CV winners (one refit per τ).
    Cv { folds: usize, seed: u64 },
}

/// One τ level's cross-validation outcome (kept for diagnostics and
/// persisted with the artifact).
#[derive(Clone, Debug, PartialEq)]
pub struct CvSummary {
    pub tau: f64,
    pub lambdas: Vec<f64>,
    pub cv_loss: Vec<f64>,
    pub best_index: usize,
    pub best_lambda: f64,
}

impl CvSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tau", Json::num(self.tau)),
            ("lambdas", Json::arr_f64(&self.lambdas)),
            ("cv_loss", Json::arr_f64(&self.cv_loss)),
            ("best_index", Json::num(self.best_index as f64)),
            ("best_lambda", Json::num(self.best_lambda)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<CvSummary> {
        use anyhow::anyhow;
        Ok(CvSummary {
            tau: v.get_f64("tau").ok_or_else(|| anyhow!("cv summary: missing tau"))?,
            lambdas: v
                .get_f64_arr_strict("lambdas")
                .ok_or_else(|| anyhow!("cv summary: missing lambdas"))?,
            cv_loss: v
                .get_f64_arr_strict("cv_loss")
                .ok_or_else(|| anyhow!("cv summary: missing cv_loss"))?,
            best_index: v
                .get_usize("best_index")
                .ok_or_else(|| anyhow!("cv summary: missing best_index"))?,
            best_lambda: v
                .get_f64("best_lambda")
                .ok_or_else(|| anyhow!("cv summary: missing best_lambda"))?,
        })
    }
}

/// A collection of single-τ fits (path, grid or CV winners) presented as
/// one model: one prediction row per fit.
#[derive(Clone, Debug)]
pub struct ModelSet {
    pub fits: Vec<KqrFit>,
    pub shape: SetShape,
    /// Per-τ CV outcomes (non-empty only for [`SetShape::Cv`]).
    pub cv: Vec<CvSummary>,
    /// Runtime-only bundle accounting from the lockstep grid driver;
    /// not persisted (it does not affect predictions).
    pub lockstep: Option<LockstepStats>,
    /// Which solver backend produced the fits (always concrete, never
    /// `Auto`). Runtime-only diagnostics, like `lockstep`: artifacts do
    /// not persist it, so reloaded models report `None`.
    pub solver: Option<SolverBackend>,
    /// Factor-reuse accounting from the SSN grid drivers (carry /
    /// bundles); runtime-only, like `lockstep`.
    pub ssn: Option<SsnGridStats>,
}

/// The unified fitted-model facade (see module docs).
#[derive(Clone, Debug)]
pub enum QuantileModel {
    Kqr(KqrFit),
    Nckqr(NckqrFit),
    Set(ModelSet),
}

impl QuantileModel {
    /// Flatten an engine [`GridFit`] (τ-major) into a model.
    pub fn from_grid(grid: GridFit) -> QuantileModel {
        let shape = SetShape::Grid { taus: grid.taus, lambdas: grid.lambdas };
        QuantileModel::Set(ModelSet {
            fits: grid.fits.into_iter().flatten().collect(),
            shape,
            cv: Vec::new(),
            lockstep: grid.lockstep,
            solver: Some(grid.solver),
            ssn: grid.ssn,
        })
    }

    /// Artifact/registry kind tag.
    pub fn kind(&self) -> &'static str {
        match self {
            QuantileModel::Kqr(_) => "kqr",
            QuantileModel::Nckqr(_) => "nckqr",
            QuantileModel::Set(_) => "set",
        }
    }

    /// Predict at the rows of `xt`: one output row per quantile level
    /// (KQR: one; NCKQR: one per τ level; sets: one per fit).
    ///
    /// Routed through a freshly compiled [`PredictPlan`]: fits sharing
    /// one predictor basis (the `Arc`'d training inputs, or the landmark
    /// set for low-rank fits) get one cross-Gram + one multi-RHS GEMM
    /// for the whole group instead of per-fit kernel evaluations; each
    /// row stays bitwise equal to the per-fit `KqrFit::predict` path.
    /// Callers that predict repeatedly (the registry, the CLI, benches)
    /// should [`compile_plan`](QuantileModel::compile_plan) once and
    /// reuse it — this convenience re-packs coefficients per call.
    pub fn predict(&self, xt: &Matrix) -> Vec<Vec<f64>> {
        self.compile_plan().predict(xt)
    }

    /// Compile the serving representation of this model (see
    /// [`PredictPlan`]): resolved kernel + `Arc`'d block + packed
    /// coefficient matrix, built once so every subsequent predict is one
    /// cross-Gram + one GEMM with no per-request packing.
    pub fn compile_plan(&self) -> PredictPlan {
        PredictPlan::compile(self)
    }

    /// The τ of each prediction row, in row order.
    pub fn taus(&self) -> Vec<f64> {
        match self {
            QuantileModel::Kqr(f) => vec![f.tau],
            QuantileModel::Nckqr(f) => f.taus.clone(),
            QuantileModel::Set(s) => s.fits.iter().map(|f| f.tau).collect(),
        }
    }

    /// The λ of each prediction row (NCKQR levels all share λ₂).
    pub fn lambdas(&self) -> Vec<f64> {
        match self {
            QuantileModel::Kqr(f) => vec![f.lam],
            QuantileModel::Nckqr(f) => vec![f.lam2; f.taus.len()],
            QuantileModel::Set(s) => s.fits.iter().map(|f| f.lam).collect(),
        }
    }

    /// Number of prediction rows.
    pub fn n_levels(&self) -> usize {
        match self {
            QuantileModel::Kqr(_) => 1,
            QuantileModel::Nckqr(f) => f.taus.len(),
            QuantileModel::Set(s) => s.fits.len(),
        }
    }

    pub fn n_train(&self) -> usize {
        match self {
            QuantileModel::Kqr(f) => f.n_train(),
            QuantileModel::Nckqr(f) => f.n_train(),
            QuantileModel::Set(s) => s.fits.first().map(|f| f.n_train()).unwrap_or(0),
        }
    }

    /// Feature dimension the model was trained on (p of `x_train`).
    pub fn n_features(&self) -> usize {
        match self {
            QuantileModel::Kqr(f) => f.x_train().cols(),
            QuantileModel::Nckqr(f) => f.x_train().cols(),
            QuantileModel::Set(s) => s.fits.first().map(|f| f.x_train().cols()).unwrap_or(0),
        }
    }

    /// Representative objective (first fit's for sets).
    pub fn objective(&self) -> f64 {
        match self {
            QuantileModel::Kqr(f) => f.objective,
            QuantileModel::Nckqr(f) => f.objective,
            QuantileModel::Set(s) => s.fits.first().map(|f| f.objective).unwrap_or(f64::NAN),
        }
    }

    /// Did every constituent fit certify its exact KKT conditions?
    pub fn kkt_pass(&self) -> bool {
        match self {
            QuantileModel::Kqr(f) => f.kkt.pass,
            QuantileModel::Nckqr(f) => f.kkt.pass,
            QuantileModel::Set(s) => s.fits.iter().all(|f| f.kkt.pass),
        }
    }

    /// Structured per-model diagnostics (served by the protocol's fit
    /// response and the CLI).
    pub fn diagnostics(&self) -> Json {
        match self {
            QuantileModel::Kqr(f) => {
                let mut pairs = vec![
                    ("kind", Json::str("kqr")),
                    ("n_train", Json::num(f.n_train() as f64)),
                    ("tau", Json::num(f.tau)),
                    ("lambda", Json::num(f.lam)),
                    ("objective", Json::num(f.objective)),
                    ("apgd_iters", Json::num(f.apgd_iters as f64)),
                    ("expansions", Json::num(f.expansions as f64)),
                    ("gamma_final", Json::num(f.gamma_final)),
                    ("singular_set_size", Json::num(f.singular_set.len() as f64)),
                    ("kkt", f.kkt.to_json()),
                ];
                if let Some(lr) = &f.lowrank {
                    pairs.push(("lowrank_m", Json::num(lr.w.len() as f64)));
                }
                if let Some(rf) = &f.rff {
                    pairs.push(("rff_d", Json::num(rf.map.d() as f64)));
                }
                Json::obj(pairs)
            }
            QuantileModel::Nckqr(f) => {
                let mut pairs = vec![
                    ("kind", Json::str("nckqr")),
                    ("n_train", Json::num(f.n_train() as f64)),
                    ("taus", Json::arr_f64(&f.taus)),
                    ("lam1", Json::num(f.lam1)),
                    ("lam2", Json::num(f.lam2)),
                    ("objective", Json::num(f.objective)),
                    ("mm_iters", Json::num(f.mm_iters as f64)),
                    ("gamma_final", Json::num(f.gamma_final)),
                    ("train_crossings", Json::num(f.train_crossings as f64)),
                    ("kkt", f.kkt.to_json()),
                ];
                if let Some(lr) = &f.lowrank {
                    pairs.push(("lowrank_m", Json::num(lr.landmarks.len() as f64)));
                }
                if let Some(rf) = &f.rff {
                    pairs.push(("rff_d", Json::num(rf.map.d() as f64)));
                }
                if let Some(st) = &f.ssn {
                    pairs.push(("ssn", ssn_to_json(st)));
                }
                Json::obj(pairs)
            }
            QuantileModel::Set(s) => {
                let mut pairs = vec![
                    ("kind", Json::str("set")),
                    ("n_train", Json::num(self.n_train() as f64)),
                    ("count", Json::num(s.fits.len() as f64)),
                    ("taus", Json::arr_f64(&self.taus())),
                    ("lambdas", Json::arr_f64(&self.lambdas())),
                    (
                        "objectives",
                        Json::arr_f64(&s.fits.iter().map(|f| f.objective).collect::<Vec<_>>()),
                    ),
                    ("kkt_pass", Json::Bool(self.kkt_pass())),
                    ("shape", shape_to_json(&s.shape)),
                ];
                if let Some(sb) = s.solver {
                    pairs.push(("solver", Json::str(sb.as_str())));
                }
                if !s.cv.is_empty() {
                    pairs.push(("cv", Json::Arr(s.cv.iter().map(CvSummary::to_json).collect())));
                }
                if let Some(l) = &s.lockstep {
                    pairs.push((
                        "lockstep",
                        Json::obj(vec![
                            ("cells", Json::num(l.cells as f64)),
                            ("chunks", Json::num(l.chunks as f64)),
                            ("retired", Json::num(l.retired as f64)),
                            ("max_active", Json::num(l.max_active as f64)),
                        ]),
                    ));
                }
                if let Some(st) = &s.ssn {
                    pairs.push(("ssn", ssn_to_json(st)));
                }
                Json::obj(pairs)
            }
        }
    }

    /// Serialize to the versioned artifact document (errors on an empty
    /// fit set).
    pub fn to_artifact(&self) -> Result<Json> {
        artifact::to_json(self)
    }

    /// Deserialize from an artifact document.
    pub fn from_artifact(v: &Json) -> Result<QuantileModel> {
        artifact::from_json(v)
    }

    /// Write the artifact to a file (pretty enough: one compact line).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        artifact::save(self, path.as_ref())
    }

    /// Load an artifact file written by [`QuantileModel::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<QuantileModel> {
        artifact::load(path.as_ref())
    }
}

fn ssn_to_json(st: &SsnGridStats) -> Json {
    Json::obj(vec![
        ("cells", Json::num(st.cells as f64)),
        ("newton_steps", Json::num(st.newton_steps as f64)),
        ("outer_rounds", Json::num(st.outer_rounds as f64)),
        ("refactorizations", Json::num(st.refactorizations as f64)),
        ("rank1_updates", Json::num(st.rank1_updates as f64)),
        ("carried_seeds", Json::num(st.carried_seeds as f64)),
        ("bundles", Json::num(st.bundles as f64)),
        ("bundle_adoptions", Json::num(st.bundle_adoptions as f64)),
    ])
}

pub(super) fn shape_to_json(shape: &SetShape) -> Json {
    match shape {
        SetShape::Path { tau } => {
            Json::obj(vec![("type", Json::str("path")), ("tau", Json::num(*tau))])
        }
        SetShape::Grid { taus, lambdas } => Json::obj(vec![
            ("type", Json::str("grid")),
            ("taus", Json::arr_f64(taus)),
            ("lambdas", Json::arr_f64(lambdas)),
        ]),
        SetShape::Cv { folds, seed } => Json::obj(vec![
            ("type", Json::str("cv")),
            ("folds", Json::num(*folds as f64)),
            ("seed", Json::num(*seed as f64)),
        ]),
    }
}

pub(super) fn shape_from_json(v: &Json) -> Result<SetShape> {
    use anyhow::{anyhow, bail};
    match v.get_str("type").ok_or_else(|| anyhow!("shape: missing type"))? {
        "path" => Ok(SetShape::Path {
            tau: v.get_f64("tau").ok_or_else(|| anyhow!("shape: missing tau"))?,
        }),
        "grid" => Ok(SetShape::Grid {
            taus: v.get_f64_arr_strict("taus").ok_or_else(|| anyhow!("shape: missing taus"))?,
            lambdas: v
                .get_f64_arr_strict("lambdas")
                .ok_or_else(|| anyhow!("shape: missing lambdas"))?,
        }),
        "cv" => Ok(SetShape::Cv {
            folds: v.get_usize("folds").ok_or_else(|| anyhow!("shape: missing folds"))?,
            seed: v.get_usize("seed").ok_or_else(|| anyhow!("shape: missing seed"))? as u64,
        }),
        other => bail!("unknown set shape {other:?}"),
    }
}
