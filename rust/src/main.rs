//! fastkqr CLI — the L3 leader entrypoint.
//!
//! Every fitting subcommand builds one declarative [`FitSpec`] and runs
//! it on the process-global [`FitEngine`] (shared GramCache: repeated
//! fits on the same data in one process share one eigendecomposition).
//!
//! Subcommands:
//!   fit        fit one KQR model on a named workload (--save <file>,
//!              --nystrom <m> for the low-rank Gram representation,
//!              --rff <D> for the random-feature representation)
//!   path       warm-started λ path at one τ
//!   grid       full τ×λ grid on one cached basis (--lockstep/--no-lockstep)
//!   cv         k-fold cross-validated path (+ refit at the best λ)
//!   nckqr      simultaneous non-crossing fit
//!   predict    predict from a saved model artifact (--model <file>)
//!   serve      start the TCP fit/predict server (--persist <dir>;
//!              --io epoll|threads|auto picks the connection layer,
//!              --workers N bounds the event loop's worker pool;
//!              --replicas N starts N servers sharing --persist behind a
//!              consistent-hash router on --addr; predict micro-batching
//!              via FASTKQR_BATCH_WINDOW_US)
//!   route      consistent-hash router in front of running replicas
//!              (--replicas host:port,host:port [--vnodes V])
//!   client     send one JSON request line to a running server
//!              (--concurrency N --repeat R opens N connections firing
//!              the request R times each — a predict-batching storm)
//!   table1..6  regenerate the paper's tables (quick scale; --paper full)
//!   figure1    regenerate the crossing figure (writes CSV)
//!   ablations  design-choice ablations
//!   perf       hot-path microbenchmarks
//!   version    version + resolved SIMD dispatch (ISA tier, FMA, threads)
//!
//! Common options: --data yuan|friedman|sine|gagurine|mcycle|crabs|boston
//! --n --p --tau --lambda --backend native|xla --solver apgd|ssn|auto
//! --seed; see DESIGN.md §5. `--solver` picks the optimizer on every
//! fitting subcommand: `apgd` (the paper's finite-smoothing APGD, the
//! default), `ssn` (pALM semismooth Newton — strongest on --nystrom /
//! --rff thin bases), or `auto` (per-problem cost model, deterministic
//! from the spec).
//! `--nystrom <m>` switches every fitting subcommand to the rank-m
//! low-rank (Nyström) Gram representation — no n×n matrix, O(n·m)
//! memory — with landmark sampling seeded by `--seed` (default 2024) so
//! runs are reproducible. `--rff <D>` instead selects the D-dimensional
//! random Fourier feature representation (RBF kernels only): the n×D
//! feature matrix is built streaming in row blocks, the n×n Gram is
//! never formed, and the frequency draw is pinned to `--seed` so the
//! same {D, seed} always yields bitwise-identical features. The two
//! flags are mutually exclusive. Statistical flags (σ, τ, λ, folds, …)
//! are parsed strictly: a malformed value is an error, never a silent
//! default.

use anyhow::{bail, Result};
use fastkqr::api::{FitSpec, KernelSpec, QuantileModel};
use fastkqr::engine::ApproxSpec;
use fastkqr::coordinator::{Server, ServerConfig};
use fastkqr::data::{benchmarks, synth, Dataset, Rng};
use fastkqr::engine::FitEngine;
use fastkqr::experiments::{self, print_table, speedups, TableConfig};
use fastkqr::util::{Args, Json, Timer};

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "fit" => cmd_fit(args),
        "path" => cmd_path(args),
        "grid" => cmd_grid(args),
        "cv" => cmd_cv(args),
        "nckqr" => cmd_nckqr(args),
        "predict" => cmd_predict(args),
        "serve" => cmd_serve(args),
        "route" => cmd_route(args),
        "client" => cmd_client(args),
        "table1" => cmd_table(args, 1),
        "table2" => cmd_table(args, 2),
        "table3" => cmd_table(args, 3),
        "table4" => cmd_table(args, 4),
        "table5" => cmd_table(args, 5),
        "table6" => cmd_table(args, 6),
        "figure1" => cmd_figure1(args),
        "ablations" => cmd_ablations(args),
        "perf" => cmd_perf(args),
        "version" | "--version" => {
            // The dispatch snapshot makes bench JSONs and bug reports
            // interpretable: the same binary runs different microkernels
            // on different hosts (and under FASTKQR_SIMD/FASTKQR_FMA).
            let simd = fastkqr::linalg::simd::global();
            println!("fastkqr {}", fastkqr::version());
            println!("simd_isa       {}", simd.isa.as_str());
            println!("simd_fma       {}", simd.fma);
            println!("threads        {}", fastkqr::linalg::par::global().threads);
            Ok(())
        }
        "help" | "--help" => {
            println!("fastkqr {} — exact kernel quantile regression", fastkqr::version());
            println!(
                "subcommands: fit path grid cv nckqr predict serve route client table1..6 figure1 ablations perf version"
            );
            println!("see README.md for options");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `fastkqr help`)"),
    }
}

/// Build the dataset selected by --data/--n/--p/--seed.
fn dataset_from_args(args: &Args) -> Result<Dataset> {
    let n = args.try_usize("n", 200)?;
    let p = args.try_usize("p", 10)?;
    let seed = args.try_usize("seed", 2024)? as u64;
    let mut rng = Rng::new(seed);
    Ok(match args.get_str("data", "yuan") {
        "yuan" => synth::yuan(n, &mut rng),
        "friedman" => synth::friedman(n, p, 3.0, &mut rng),
        "sine" => synth::sine_hetero(n, &mut rng),
        "gagurine" => benchmarks::gagurine(seed),
        "mcycle" => benchmarks::mcycle(seed),
        "crabs" => benchmarks::crabs(seed),
        "boston" => benchmarks::boston_housing(seed),
        "geyser" => benchmarks::geyser(seed),
        other => bail!("unknown --data {other:?}"),
    })
}

/// Kernel spec from --sigma: strict parse — a malformed bandwidth must
/// not silently become some default, and an absent one resolves to the
/// median heuristic at run time.
fn kernel_from_args(args: &Args) -> Result<KernelSpec> {
    match args.get("sigma") {
        Some(s) => {
            let sigma: f64 = s
                .parse()
                .map_err(|_| anyhow::anyhow!("--sigma: expected a number, got {s:?}"))?;
            if !(sigma.is_finite() && sigma > 0.0) {
                bail!("--sigma must be a positive number, got {sigma}");
            }
            Ok(KernelSpec::Rbf { sigma: Some(sigma) })
        }
        None => Ok(KernelSpec::Auto),
    }
}

/// The shared spec builder: dataset + kernel + approx + backend hint.
/// Every fitting subcommand (fit/path/grid/nckqr/cv) attaches its task to
/// this. `--nystrom <m>` selects the rank-m low-rank representation and
/// `--rff <D>` the D-dimensional random-feature representation (mutually
/// exclusive), both seeded by `--seed` (the spec's master seed, default
/// 2024).
fn spec_from_args(args: &Args, task: fastkqr::api::Task) -> Result<FitSpec> {
    let data = dataset_from_args(args)?;
    let kernel = kernel_from_args(args)?;
    let seed = args.try_usize("seed", 2024)? as u64;
    let name = data.name.clone();
    let mut spec = FitSpec::new(data.x, data.y, kernel, task).with_seed(seed);
    if args.get("nystrom").is_some() && args.get("rff").is_some() {
        bail!("--nystrom and --rff select different Gram representations; pick one");
    }
    if let Some(mstr) = args.get("nystrom") {
        let m: usize = mstr
            .parse()
            .map_err(|_| anyhow::anyhow!("--nystrom: expected a positive integer, got {mstr:?}"))?;
        if m == 0 {
            bail!("--nystrom must be >= 1");
        }
        spec = spec.with_approx(ApproxSpec::Nystrom { m, seed });
    }
    if let Some(dstr) = args.get("rff") {
        let d: usize = dstr
            .parse()
            .map_err(|_| anyhow::anyhow!("--rff: expected a positive integer, got {dstr:?}"))?;
        if d == 0 {
            bail!("--rff must be >= 1");
        }
        spec = spec.with_approx(ApproxSpec::RandomFeatures { d, seed });
    }
    match args.get_str("backend", "native") {
        "native" => {}
        other @ "xla" => spec = spec.with_backend(other),
        other => bail!("unknown --backend {other:?} ({})", fastkqr::api::BACKEND_NAMES),
    }
    // Strict like every other flag: an unknown solver name is an error,
    // never a silent default. Absent → the spec omits the field (and the
    // document keeps its lowest-compatible version).
    if let Some(s) = args.get("solver") {
        spec = spec.with_solver(fastkqr::solver::SolverBackend::parse(s)?);
    }
    println!("dataset        {name}  (n={}, p={})", spec.x.rows(), spec.x.cols());
    if let Some(requested) = spec.solver {
        let res = spec.auto_resolution();
        println!(
            "solver         {} (requested {requested}; cost model n={} rank={} cells={})",
            spec.resolved_solver(),
            res.n,
            res.rank,
            res.cells
        );
    }
    match spec.approx {
        ApproxSpec::Nystrom { m, seed } => {
            println!("gram repr      nystrom (m={m}, seed={seed}; O(n·m) memory)");
        }
        ApproxSpec::RandomFeatures { d, seed } => {
            println!("gram repr      rff (d={d}, seed={seed}; streaming n×D build, no n×n Gram)");
        }
        ApproxSpec::Exact => {}
    }
    Ok(spec)
}

/// Log-spaced descending λ grid for path/grid/cv specs (the solver's
/// `kqr::lambda_grid` spacing, shared so CLI and library never diverge).
fn lambda_grid_from_args(args: &Args, default_count: usize) -> Result<Vec<f64>> {
    let count = args.try_usize("nlam", default_count)?;
    let max = args.try_f64("lambda-max", 1.0)?;
    let min_ratio = args.try_f64("lambda-min-ratio", 1e-4)?;
    if count == 0 || max <= 0.0 || min_ratio <= 0.0 || min_ratio >= 1.0 {
        bail!("need --nlam >= 1, --lambda-max > 0 and 0 < --lambda-min-ratio < 1");
    }
    Ok(fastkqr::kqr::lambda_grid(count, max, min_ratio))
}

fn maybe_save(args: &Args, model: &QuantileModel) -> Result<()> {
    if let Some(path) = args.get("save") {
        model.save(path)?;
        println!("saved          {path}");
    }
    Ok(())
}

fn cmd_fit(args: &Args) -> Result<()> {
    let tau = args.try_f64("tau", 0.5)?;
    let lambda = args.try_f64("lambda", 1e-2)?;
    let spec = spec_from_args(args, fastkqr::api::Task::Single { tau, lambda })?;
    let timer = Timer::start("fit");
    let model = FitEngine::global().run(&spec)?;
    let solve = timer.total();
    println!("backend        {}", spec.backend.as_deref().unwrap_or("native"));
    println!("tau/lambda     {tau} / {lambda}");
    if let QuantileModel::Kqr(fit) = &model {
        println!("objective      {:.6}", fit.objective);
        println!(
            "kkt            pass={} stat={:.2e} intercept={:.2e}",
            fit.kkt.pass, fit.kkt.max_stationarity, fit.kkt.intercept
        );
        println!(
            "gamma_final    {:.2e}   |singular set| {}",
            fit.gamma_final,
            fit.singular_set.len()
        );
        println!("apgd iters     {}", fit.apgd_iters);
    }
    println!("total          {solve:.3}s");
    maybe_save(args, &model)
}

fn cmd_path(args: &Args) -> Result<()> {
    let tau = args.try_f64("tau", 0.5)?;
    let lams = lambda_grid_from_args(args, 50)?;
    let spec = spec_from_args(args, fastkqr::api::Task::Path { tau, lambdas: lams })?;
    let timer = Timer::start("path");
    let model = FitEngine::global().run(&spec)?;
    let total = timer.total();
    let QuantileModel::Set(set) = &model else { bail!("path produced a non-set model") };
    println!("{:<12} {:<14} {:<10} {:<8} {:<6}", "lambda", "objective", "iters", "|S|", "kkt");
    for f in &set.fits {
        println!(
            "{:<12.4e} {:<14.6} {:<10} {:<8} {:<6}",
            f.lam,
            f.objective,
            f.apgd_iters,
            f.singular_set.len(),
            f.kkt.pass
        );
    }
    println!(
        "total {total:.3}s for {} fits ({} backend)",
        set.fits.len(),
        spec.backend.as_deref().unwrap_or("native")
    );
    if let Some(st) = &set.ssn {
        println!(
            "ssn: cells={} refactorizations={} rank1_updates={} carried_seeds={}",
            st.cells, st.refactorizations, st.rank1_updates, st.carried_seeds
        );
    }
    maybe_save(args, &model)
}

/// Fit a whole τ×λ grid on one cached eigenbasis through the engine.
/// `FASTKQR_LOCKSTEP=1` (or --lockstep / --no-lockstep overriding it)
/// selects the BLAS-3 lockstep driver; default is the sequential path.
fn cmd_grid(args: &Args) -> Result<()> {
    let taus = args.try_f64_list("taus", &[0.1, 0.25, 0.5, 0.75, 0.9])?;
    let lams = lambda_grid_from_args(args, 8)?;
    let task = fastkqr::api::Task::Grid { taus: taus.clone(), lambdas: lams.clone() };
    let mut spec = spec_from_args(args, task)?;
    if args.flag("lockstep") {
        spec = spec.with_lockstep(true);
    } else if args.flag("no-lockstep") {
        spec = spec.with_lockstep(false);
    } // else: defer to FASTKQR_LOCKSTEP
    let timer = Timer::start("grid");
    let model = FitEngine::global().run(&spec)?;
    let total = timer.total();
    let QuantileModel::Set(set) = &model else { bail!("grid produced a non-set model") };
    println!("{:<8} {:<12} {:<14} {:<10} {:<6}", "tau", "lambda", "objective", "iters", "kkt");
    for f in &set.fits {
        println!(
            "{:<8} {:<12.4e} {:<14.6} {:<10} {:<6}",
            f.tau, f.lam, f.objective, f.apgd_iters, f.kkt.pass
        );
    }
    let pass = set.fits.iter().filter(|f| f.kkt.pass).count();
    let iters: usize = set.fits.iter().map(|f| f.apgd_iters).sum();
    println!(
        "grid {}x{}: {pass}/{} kkt pass, {iters} total iters, {total:.3}s",
        taus.len(),
        lams.len(),
        set.fits.len()
    );
    if let Some(stats) = &set.lockstep {
        println!(
            "lockstep: bundle peak {} cells, {} chunks, {} retired",
            stats.max_active, stats.chunks, stats.retired
        );
    }
    if let Some(st) = &set.ssn {
        // key=value so the CI smoke (and operators) can grep the factor
        // economy without parsing JSON
        println!(
            "ssn: cells={} refactorizations={} rank1_updates={} carried_seeds={} bundles={} bundle_adoptions={}",
            st.cells,
            st.refactorizations,
            st.rank1_updates,
            st.carried_seeds,
            st.bundles,
            st.bundle_adoptions
        );
    }
    maybe_save(args, &model)
}

fn cmd_cv(args: &Args) -> Result<()> {
    let tau = args.try_f64("tau", 0.5)?;
    let folds = args.try_usize("folds", 5)?;
    let seed = args.try_usize("seed", 2024)? as u64 ^ 0xc5;
    let lams = lambda_grid_from_args(args, 20)?;
    let task =
        fastkqr::api::Task::Cv { taus: vec![tau], lambdas: lams, folds, seed };
    let spec = spec_from_args(args, task)?;
    let timer = Timer::start("cv");
    let model = FitEngine::global().run(&spec)?;
    let total = timer.total();
    let QuantileModel::Set(set) = &model else { bail!("cv produced a non-set model") };
    let cv = set.cv.first().ok_or_else(|| anyhow::anyhow!("cv summary missing"))?;
    println!("{:<12} {}", "lambda", "cv pinball");
    for (l, v) in cv.lambdas.iter().zip(&cv.cv_loss) {
        let mark = if *l == cv.best_lambda { "  <- best" } else { "" };
        println!("{l:<12.4e} {v:.6}{mark}");
    }
    println!("best lambda {:.4e} in {total:.3}s", cv.best_lambda);
    if let Some(refit) = set.fits.first() {
        println!(
            "refit at best lambda: objective {:.6}  kkt pass={}",
            refit.objective, refit.kkt.pass
        );
    }
    maybe_save(args, &model)
}

fn cmd_nckqr(args: &Args) -> Result<()> {
    let taus = args.try_f64_list("taus", &[0.1, 0.3, 0.5, 0.7, 0.9])?;
    let lam1 = args.try_f64("lam1", 10.0)?;
    let lam2 = args.try_f64("lam2", 1e-2)?;
    let task = fastkqr::api::Task::NonCrossing { taus: taus.clone(), lam1, lam2 };
    let spec = spec_from_args(args, task)?;
    let timer = Timer::start("nckqr");
    let model = FitEngine::global().run(&spec)?;
    let total = timer.total();
    let QuantileModel::Nckqr(fit) = &model else { bail!("nckqr produced a non-nckqr model") };
    println!("taus        {taus:?}  lam1={lam1}  lam2={lam2}");
    println!("objective   {:.6}", fit.objective);
    println!("kkt         pass={} stat={:.2e}", fit.kkt.pass, fit.kkt.max_stationarity);
    println!("crossings   {} (training points)", fit.train_crossings);
    println!("mm iters    {}   time {total:.3}s", fit.mm_iters);
    if let Some(st) = &fit.ssn {
        println!(
            "ssn: newton_steps={} outer_rounds={} refactorizations={} rank1_updates={}",
            st.newton_steps, st.outer_rounds, st.refactorizations, st.rank1_updates
        );
    }
    maybe_save(args, &model)
}

/// Predict from a saved model artifact: `fastkqr predict --model m.json
/// [--data … --n …] [--head k]`. Evaluation points come from the same
/// --data selector as the fitting subcommands.
fn cmd_predict(args: &Args) -> Result<()> {
    let path = args
        .get("model")
        .ok_or_else(|| anyhow::anyhow!("predict: --model <artifact.json> is required"))?;
    // Compile the serving plan once at artifact load (resolved kernel +
    // packed coefficient block); every predict below is then one
    // cross-Gram + one multi-RHS GEMM.
    let (model, plan) =
        fastkqr::api::artifact::load_compiled(std::path::Path::new(path))?;
    let data = dataset_from_args(args)?;
    if data.p() != model.n_features() {
        bail!(
            "eval data has {} features but the model was trained on {}",
            data.p(),
            model.n_features()
        );
    }
    let timer = Timer::start("predict");
    let preds = plan.predict(&data.x);
    let total = timer.total();
    let taus = model.taus();
    println!(
        "model          {path}  (kind={}, {} levels, n_train={})",
        model.kind(),
        model.n_levels(),
        model.n_train()
    );
    // v3 (random-feature) artifacts carry a D-dimensional basis instead
    // of train rows; surface D so the O(D) footprint is visible.
    let rff_d = match &model {
        QuantileModel::Kqr(f) => f.rff.as_ref().map(|r| r.map.d()),
        QuantileModel::Set(s) => s.fits.first().and_then(|f| f.rff.as_ref()).map(|r| r.map.d()),
        QuantileModel::Nckqr(f) => f.rff.as_ref().map(|r| r.map.d()),
    };
    if let Some(d) = rff_d {
        println!("gram repr      rff (d={d}; artifact independent of n_train)");
    }
    println!(
        "plan           {} group(s), {} coefficient rows x {} block rows",
        plan.n_groups(),
        plan.n_levels(),
        plan.block_rows()
    );
    println!("eval points    {} ({})", data.n(), data.name);
    let head = args.try_usize("head", 10)?.min(data.n());
    let mut header = format!("{:<6}", "row");
    for t in &taus {
        header.push_str(&format!(" {:>12}", format!("tau={t}")));
    }
    println!("{header}");
    for i in 0..head {
        let mut line = format!("{i:<6}");
        for row in &preds {
            line.push_str(&format!(" {:>12.6}", row[i]));
        }
        println!("{line}");
    }
    if head < data.n() {
        println!("… ({} more rows; --head N to show more)", data.n() - head);
    }
    println!("{} levels x {} points in {total:.3}s", preds.len(), data.n());
    Ok(())
}

/// Derive N replica listen addresses from the client-facing address:
/// same host, ports `base+1 ..= base+n` (explicit `--replica-addrs`
/// overrides).
fn derive_replica_addrs(addr: &str, n: usize) -> Result<Vec<String>> {
    let (host, port) = addr
        .rsplit_once(':')
        .ok_or_else(|| anyhow::anyhow!("--addr must be host:port, got {addr:?}"))?;
    let port: u16 = port.parse().map_err(|_| anyhow::anyhow!("bad port in --addr {addr:?}"))?;
    (1..=n as u16)
        .map(|k| {
            let p = port
                .checked_add(k)
                .ok_or_else(|| anyhow::anyhow!("replica port overflows past {port}"))?;
            Ok(format!("{host}:{p}"))
        })
        .collect()
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7787").to_string();
    let persist_dir = args.get("persist").map(String::from);
    let io_model = match args.get("io") {
        Some(v) => fastkqr::coordinator::IoModel::parse(v)?,
        None => fastkqr::coordinator::IoModel::from_env(),
    };
    let workers = args.try_usize("workers", 0)?;
    let replicas = args.try_usize("replicas", 1)?;
    if replicas == 0 {
        bail!("--replicas must be >= 1");
    }
    let config = |addr: String, scope: Option<String>| ServerConfig {
        addr,
        persist_dir: persist_dir.clone(),
        io_model,
        workers,
        scope,
        ..Default::default()
    };
    if replicas == 1 {
        let server = Server::spawn(config(addr, None))?;
        println!("fastkqr {} serving on {}", fastkqr::version(), server.local_addr);
        println!("io model: {}", server.metrics.io_model.get().copied().unwrap_or("unset"));
        match &persist_dir {
            Some(dir) => {
                println!("persistence: {dir} ({} model(s) reloaded)", server.registry.len())
            }
            None => {
                println!("persistence: off (models are in-memory; --persist <dir> to keep them)")
            }
        }
        println!("protocol: one JSON request per line; try: {{\"cmd\":\"ping\"}}");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    // Scale-out: N replica servers sharing one persistence dir (scoped
    // ids + manifest hot-swap) behind a consistent-hash router on the
    // client-facing address.
    let Some(dir) = &persist_dir else {
        bail!(
            "--replicas {replicas} needs --persist <dir>: replicas share models \
             through the persistence dir's generation manifest"
        );
    };
    let replica_addrs: Vec<String> = match args.get("replica-addrs") {
        Some(list) => {
            let v: Vec<String> =
                list.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect();
            if v.len() != replicas {
                bail!("--replica-addrs lists {} address(es), --replicas says {replicas}", v.len());
            }
            v
        }
        None => derive_replica_addrs(&addr, replicas)?,
    };
    let mut servers = Vec::with_capacity(replicas);
    for (k, raddr) in replica_addrs.iter().enumerate() {
        let server = Server::spawn(config(raddr.clone(), Some(format!("r{k}"))))?;
        println!("replica r{k} on {} ({} model(s) reloaded)", server.local_addr, server.registry.len());
        servers.push(server);
    }
    let router = fastkqr::coordinator::Router::spawn(fastkqr::coordinator::RouterConfig {
        addr,
        replicas: replica_addrs,
        vnodes: args.try_usize("vnodes", 0)?,
    })?;
    println!(
        "fastkqr {} routing on {} ({} replicas, persistence: {dir})",
        fastkqr::version(),
        router.local_addr,
        servers.len()
    );
    println!("protocol: one JSON request per line; try: {{\"cmd\":\"ping\"}}");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Stand-alone consistent-hash router in front of already-running
/// replicas (`serve --replicas N` starts both sides in one process; this
/// subcommand fronts replicas started elsewhere).
fn cmd_route(args: &Args) -> Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7787").to_string();
    let Some(list) = args.get("replicas") else {
        bail!("route needs --replicas host:port[,host:port...]");
    };
    let replicas: Vec<String> =
        list.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect();
    let router = fastkqr::coordinator::Router::spawn(fastkqr::coordinator::RouterConfig {
        addr,
        replicas,
        vnodes: args.try_usize("vnodes", 0)?,
    })?;
    println!(
        "fastkqr {} routing on {} over {} replica(s)",
        fastkqr::version(),
        router.local_addr,
        router.ring.len()
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Send one JSON request line to a running server. `--concurrency N`
/// (with optional `--repeat R`) opens N connections and fires the same
/// request R times from each — the load generator behind the CI serve
/// smoke and a quick way to exercise the predict micro-batcher.
fn cmd_client(args: &Args) -> Result<()> {
    use fastkqr::coordinator::server::Client;
    let addr = args.get_str("addr", "127.0.0.1:7787");
    let req_str = args
        .get("json")
        .map(String::from)
        .unwrap_or_else(|| r#"{"cmd":"ping"}"#.to_string());
    let req = Json::parse(&req_str).map_err(|e| anyhow::anyhow!("{e}"))?;
    let concurrency = args.try_usize("concurrency", 1)?;
    let repeat = args.try_usize("repeat", 1)?;
    if concurrency == 0 || repeat == 0 {
        bail!("--concurrency and --repeat must be >= 1");
    }
    if concurrency == 1 && repeat == 1 {
        let mut client = Client::connect(addr)?;
        // request_stream prints every line of a streamed predict too
        for line in client.request_stream(&req)? {
            println!("{}", line.to_string());
        }
        return Ok(());
    }
    let t0 = std::time::Instant::now();
    let results: Vec<Result<()>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..concurrency)
            .map(|_| {
                let req = &req;
                s.spawn(move || -> Result<()> {
                    let mut client = Client::connect(addr)?;
                    for _ in 0..repeat {
                        // request_stream drains streamed replies fully, so
                        // a "stream":true payload cannot desynchronize the
                        // connection across iterations
                        let lines = client.request_stream(req)?;
                        let first = lines.first().expect("at least one response line");
                        // only an explicit failure counts (the `metrics`
                        // response carries no "ok" field)
                        if first.get("ok").and_then(Json::as_bool) == Some(false) {
                            bail!("request failed: {}", first.to_string());
                        }
                    }
                    Ok(())
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(anyhow::anyhow!("client thread panicked")))
            })
            .collect()
    });
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let failed = results.iter().filter(|r| r.is_err()).count();
    let ok_conns = concurrency - failed;
    println!(
        "{ok_conns}/{concurrency} connections ok x {repeat} request(s) each in {wall:.3}s \
         ({:.0} req/s)",
        (ok_conns * repeat) as f64 / wall
    );
    for e in results.iter().filter_map(|r| r.as_ref().err()).take(3) {
        eprintln!("  error: {e:#}");
    }
    if failed > 0 {
        bail!("{failed} of {concurrency} client connections failed");
    }
    Ok(())
}

fn cmd_table(args: &Args, which: usize) -> Result<()> {
    let mut cfg = TableConfig::from_args(args);
    let cells = match which {
        1 => {
            if args.flag("paper") && args.get("p").is_none() {
                cfg.p = 5000;
            }
            experiments::kqr_tables::table1(&cfg)?
        }
        2 => {
            if args.get("solvers").is_none() {
                cfg.solvers = vec!["fastkqr".into(), "proximal".into(), "lbfgs".into()];
            }
            experiments::nckqr_tables::table2(&cfg, args.try_f64("lam1", 1.0)?)?
        }
        3 => {
            cfg.p = args.try_usize("p", 100)?;
            experiments::kqr_tables::table3(&cfg)?
        }
        4 => experiments::kqr_tables::table4(&cfg)?,
        5 => {
            let cap = if args.flag("paper") { None } else { Some(args.try_usize("cap", 120)?) };
            experiments::kqr_tables::table5(&cfg, cap)?
        }
        6 => {
            if args.get("solvers").is_none() {
                cfg.solvers = vec!["fastkqr".into(), "proximal".into()];
            }
            let cap = if args.flag("paper") { None } else { Some(args.try_usize("cap", 100)?) };
            experiments::nckqr_tables::table6(&cfg, args.try_f64("lam1", 1.0)?, cap)?
        }
        _ => unreachable!(),
    };
    print_table(&format!("Table {which}"), &cells, &cfg.solvers);
    println!("\nspeedups of fastkqr:");
    for (label, n, solver, factor) in speedups(&cells) {
        println!("  {label} n={n}: {factor:.1}x vs {solver}");
    }
    Ok(())
}

fn cmd_figure1(args: &Args) -> Result<()> {
    let seed = args.try_usize("seed", 2025)? as u64;
    let lam = args.try_f64("lambda", 2e-5)?;
    let lam1 = args.try_f64("lam1", 5.0)?;
    let out = args.get_str("out", "out/figure1");
    let res = experiments::figure1::run(seed, lam, lam1, args.try_usize("grid", 200)?)?;
    experiments::figure1::write_csv(&res, out)?;
    println!("Figure 1 (GAGurine lookalike, 5 quantile levels)");
    println!("  individual fits: {} crossing violations on the grid", res.crossings_individual);
    println!("  NCKQR joint fit: {} crossing violations", res.crossings_joint);
    println!("  curves written to {out}/figure1_*.csv");
    Ok(())
}

fn cmd_ablations(args: &Args) -> Result<()> {
    let n = args.try_usize("n", 100)?;
    let seed = args.try_usize("seed", 2024)? as u64;
    let mut rows = Vec::new();
    rows.extend(experiments::ablations::spectral_vs_dense(n, args.try_usize("plans", 8)?, seed)?);
    rows.extend(experiments::ablations::warm_vs_cold(n, args.try_usize("nlam", 20)?, seed)?);
    rows.extend(experiments::ablations::solver_switches(n.min(80), seed)?);
    rows.extend(experiments::ablations::nckqr_ridge(n.min(60), seed)?);
    experiments::ablations::print_rows(&rows);
    Ok(())
}

fn cmd_perf(args: &Args) -> Result<()> {
    let reps = args.try_usize("reps", 20)?;
    for n in args.get_usize_list("ns", &[128, 256, 512, 1024]) {
        let (stats, gbps) = experiments::perf::gemv_throughput(n, reps);
        println!("{}  ({gbps:.2} GB/s effective)", stats.report_line());
    }
    for n in args.get_usize_list("chunk-ns", &[64, 256]) {
        for s in experiments::perf::chunk_cost(n, reps.min(10))? {
            println!("{}", s.report_line());
        }
    }
    for n in args.get_usize_list("eig-ns", &[128, 512]) {
        println!("{}", experiments::perf::eigen_cost(n, 3).report_line());
    }
    println!(
        "{}",
        experiments::perf::fit_latency(args.try_usize("fit-n", 200)?, 3).report_line()
    );
    Ok(())
}
