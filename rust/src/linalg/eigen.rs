//! Symmetric eigendecomposition K = U Λ Uᵀ.
//!
//! fastkqr's spectral technique needs *one* full eigendecomposition of the
//! kernel matrix, reused across the whole (γ, λ, τ) grid. There is no
//! LAPACK in this environment and the HLO interchange path cannot carry
//! `eigh` (jax ≥ 0.5 lowers it to an FFI custom-call the image's
//! xla_extension 0.5.1 does not export), so we implement the classic
//! dense path from scratch:
//!
//!   1. Householder reduction to symmetric tridiagonal form (EISPACK
//!      `tred2`), accumulating the orthogonal transform, and
//!   2. implicit-shift QL iteration with eigenvector accumulation
//!      (EISPACK `tql2`).
//!
//! Cost is O(n³) once; everything downstream is O(n²) per iteration,
//! which is the paper's headline complexity claim.

use super::matrix::Matrix;

/// Eigendecomposition of a symmetric matrix.
///
/// `vectors` holds eigenvectors in its *columns*: `a ≈ U diag(values) Uᵀ`
/// with `U = vectors`. Eigenvalues are sorted ascending.
#[derive(Clone, Debug)]
pub struct SymEigen {
    pub values: Vec<f64>,
    pub vectors: Matrix,
}

impl SymEigen {
    /// Decompose a symmetric matrix. Panics if `a` is not square; the
    /// strictly-lower triangle is trusted to mirror the upper one.
    pub fn new(a: &Matrix) -> SymEigen {
        assert_eq!(a.rows(), a.cols(), "SymEigen: matrix must be square");
        let n = a.rows();
        if n == 0 {
            return SymEigen { values: vec![], vectors: Matrix::zeros(0, 0) };
        }
        let mut z = a.clone(); // becomes the accumulated orthogonal matrix
        let mut d = vec![0.0; n]; // diagonal
        let mut e = vec![0.0; n]; // off-diagonal
        tred2(&mut z, &mut d, &mut e);
        tql2(&mut z, &mut d, &mut e);
        sort_ascending(&mut z, &mut d);
        SymEigen { values: d, vectors: z }
    }

    /// Reconstruct U diag(values) Uᵀ (test / debugging helper).
    pub fn reconstruct(&self) -> Matrix {
        let n = self.values.len();
        let u = &self.vectors;
        let mut out = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += u[(i, k)] * self.values[k] * u[(j, k)];
                }
                out[(i, j)] = s;
            }
        }
        out
    }

    /// Largest eigenvalue (values are sorted ascending).
    pub fn max_eigenvalue(&self) -> f64 {
        *self.values.last().unwrap_or(&0.0)
    }
}

/// Householder reduction of a real symmetric matrix to tridiagonal form,
/// accumulating transformations (EISPACK tred2, as in Numerical Recipes).
fn tred2(z: &mut Matrix, d: &mut [f64], e: &mut [f64]) {
    let n = d.len();
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let scale: f64 = (0..=l).map(|k| z[(i, k)].abs()).sum();
            if scale == 0.0 {
                e[i] = z[(i, l)];
            } else {
                for k in 0..=l {
                    z[(i, k)] /= scale;
                    h += z[(i, k)] * z[(i, k)];
                }
                let mut f = z[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[(i, l)] = f - g;
                f = 0.0;
                for j in 0..=l {
                    z[(j, i)] = z[(i, j)] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z[(j, k)] * z[(i, k)];
                    }
                    for k in (j + 1)..=l {
                        g += z[(k, j)] * z[(i, k)];
                    }
                    e[j] = g / h;
                    f += e[j] * z[(i, j)];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = z[(i, j)];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        let upd = f * e[k] + g * z[(i, k)];
                        z[(j, k)] -= upd;
                    }
                }
            }
        } else {
            e[i] = z[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += z[(i, k)] * z[(k, j)];
                }
                for k in 0..i {
                    z[(k, j)] -= g * z[(k, i)];
                }
            }
        }
        d[i] = z[(i, i)];
        z[(i, i)] = 1.0;
        for j in 0..i {
            z[(j, i)] = 0.0;
            z[(i, j)] = 0.0;
        }
    }
}

/// Implicit-shift QL with eigenvector accumulation (EISPACK tql2).
fn tql2(z: &mut Matrix, d: &mut [f64], e: &mut [f64]) {
    let n = d.len();
    if n <= 1 {
        return;
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    // Absolute deflation floor: kernel Gram matrices have large clusters
    // of near-zero eigenvalues where the relative test |e| ≤ ε(|d_m|+|d_m+1|)
    // can never fire (dd ≈ 0). Anything below ε·‖T‖ is a converged zero.
    let anorm = d
        .iter()
        .zip(e.iter())
        .map(|(a, b)| a.abs() + b.abs())
        .fold(0.0f64, f64::max)
        .max(f64::MIN_POSITIVE);
    let floor = f64::EPSILON * anorm;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find small off-diagonal element to split the matrix.
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd || e[m].abs() <= floor {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 100 {
                // Accept the current (ε‖T‖-accurate) values rather than
                // aborting: the unresolved off-diagonal mass is below the
                // deflation floor for any conditioning we can exploit.
                e[m.min(n - 1)] = 0.0;
                break;
            }
            // Form implicit shift.
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = hypot(g, 1.0);
            g = d[m] - d[l] + e[l] / (g + sign(r, g));
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = hypot(f, g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // Accumulate the rotation into the eigenvector matrix.
                for k in 0..n {
                    f = z[(k, i + 1)];
                    z[(k, i + 1)] = s * z[(k, i)] + c * f;
                    z[(k, i)] = c * z[(k, i)] - s * f;
                }
            }
            if r == 0.0 && m > l + 1 {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
}

fn sort_ascending(z: &mut Matrix, d: &mut [f64]) {
    let n = d.len();
    // Selection sort with column swaps (n is moderate; O(n²) swaps are
    // dominated by the O(n³) decomposition anyway).
    for i in 0..n {
        let mut kmin = i;
        for j in (i + 1)..n {
            if d[j] < d[kmin] {
                kmin = j;
            }
        }
        if kmin != i {
            d.swap(i, kmin);
            for r in 0..n {
                let tmp = z[(r, i)];
                z[(r, i)] = z[(r, kmin)];
                z[(r, kmin)] = tmp;
            }
        }
    }
}

#[inline]
fn hypot(a: f64, b: f64) -> f64 {
    a.hypot(b)
}

#[inline]
fn sign(a: f64, b: f64) -> f64 {
    if b >= 0.0 {
        a.abs()
    } else {
        -a.abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn random_sym(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal();
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        a
    }

    fn check_decomposition(a: &Matrix, tol: f64) {
        let eig = SymEigen::new(a);
        // 1) reconstruction
        let rec = eig.reconstruct();
        assert!(
            a.max_abs_diff(&rec) < tol,
            "reconstruction error {} (n={})",
            a.max_abs_diff(&rec),
            a.rows()
        );
        // 2) orthogonality of U
        let n = a.rows();
        let u = &eig.vectors;
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += u[(k, i)] * u[(k, j)];
                }
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((s - expect).abs() < tol, "UᵀU[{i},{j}]={s}");
            }
        }
        // 3) sorted ascending
        for w in eig.values.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
    }

    #[test]
    fn diag_matrix_eigen() {
        let mut a = Matrix::zeros(4, 4);
        for (i, v) in [3.0, -1.0, 2.0, 0.5].iter().enumerate() {
            a[(i, i)] = *v;
        }
        let eig = SymEigen::new(&a);
        let expect = [-1.0, 0.5, 2.0, 3.0];
        for (v, e) in eig.values.iter().zip(expect) {
            assert!((v - e).abs() < 1e-12);
        }
    }

    #[test]
    fn two_by_two_known() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let eig = SymEigen::new(&a);
        assert!((eig.values[0] - 1.0).abs() < 1e-12);
        assert!((eig.values[1] - 3.0).abs() < 1e-12);
        check_decomposition(&a, 1e-10);
    }

    #[test]
    fn random_matrices_various_sizes() {
        for (n, seed) in [(1usize, 1u64), (2, 2), (5, 3), (16, 4), (33, 5), (64, 6)] {
            let a = random_sym(n, seed);
            check_decomposition(&a, 1e-8 * (n as f64).max(1.0));
        }
    }

    #[test]
    fn psd_kernel_like_matrix() {
        // Gram-like matrix: A = B Bᵀ is PSD; eigenvalues must be >= -eps.
        let mut rng = Rng::new(7);
        let b = Matrix::from_fn(20, 8, |_, _| rng.normal());
        let bt = b.transpose();
        let a = crate::linalg::blas::gemm(&b, &bt);
        let eig = SymEigen::new(&a);
        assert!(eig.values[0] > -1e-8, "PSD eigenvalue {}", eig.values[0]);
        // rank <= 8: the first 12 eigenvalues must be ~0
        for k in 0..12 {
            assert!(eig.values[k].abs() < 1e-7);
        }
        check_decomposition(&a, 1e-7);
    }

    #[test]
    fn repeated_eigenvalues() {
        // 3*I has a triple eigenvalue; decomposition must still be orthogonal.
        let mut a = Matrix::eye(5);
        for i in 0..5 {
            a[(i, i)] = 3.0;
        }
        check_decomposition(&a, 1e-12);
    }

    #[test]
    fn empty_and_single() {
        let e = SymEigen::new(&Matrix::zeros(0, 0));
        assert!(e.values.is_empty());
        let a = Matrix::from_vec(1, 1, vec![4.2]);
        let e = SymEigen::new(&a);
        assert!((e.values[0] - 4.2).abs() < 1e-15);
        assert!((e.vectors[(0, 0)].abs() - 1.0).abs() < 1e-15);
    }
}
