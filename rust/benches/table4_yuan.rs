//! Table 4 (supplement): KQR on the Yuan (2006) 2-D model.
use fastkqr::experiments::{kqr_tables, print_table, speedups, TableConfig};
use fastkqr::util::Args;

fn main() {
    let args = Args::from_env();
    let cfg = TableConfig::from_args(&args);
    let cells = kqr_tables::table4(&cfg).expect("table4");
    print_table("Table 4 — Yuan (2006)", &cells, &cfg.solvers);
    for (label, n, solver, factor) in speedups(&cells) {
        println!("speedup {label} n={n}: {factor:.1}x vs {solver}");
    }
}
