//! Concurrent model registry for the predict path.

use crate::kqr::KqrFit;
use crate::linalg::Matrix;
use crate::nckqr::NckqrFit;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// A stored, predict-ready model.
#[derive(Clone, Debug)]
pub enum StoredModel {
    Kqr(KqrFit),
    Nckqr(NckqrFit),
}

impl StoredModel {
    /// Predict: one output row per quantile level (KQR has one level).
    pub fn predict(&self, xt: &Matrix) -> Vec<Vec<f64>> {
        match self {
            StoredModel::Kqr(f) => vec![f.predict(xt)],
            StoredModel::Nckqr(f) => f.predict(xt),
        }
    }

    pub fn taus(&self) -> Vec<f64> {
        match self {
            StoredModel::Kqr(f) => vec![f.tau],
            StoredModel::Nckqr(f) => f.taus.clone(),
        }
    }

    pub fn objective(&self) -> f64 {
        match self {
            StoredModel::Kqr(f) => f.objective,
            StoredModel::Nckqr(f) => f.objective,
        }
    }
}

/// Thread-safe model store with generated ids.
#[derive(Debug, Default)]
pub struct ModelRegistry {
    models: RwLock<HashMap<String, StoredModel>>,
    next_id: AtomicU64,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Insert, returning the generated id (`m<seq>`).
    pub fn insert(&self, model: StoredModel) -> String {
        let id = format!("m{}", self.next_id.fetch_add(1, Ordering::Relaxed));
        self.models.write().unwrap().insert(id.clone(), model);
        id
    }

    pub fn get(&self, id: &str) -> Option<StoredModel> {
        self.models.read().unwrap().get(id).cloned()
    }

    pub fn remove(&self, id: &str) -> bool {
        self.models.write().unwrap().remove(id).is_some()
    }

    pub fn list(&self) -> Vec<String> {
        let mut ids: Vec<String> = self.models.read().unwrap().keys().cloned().collect();
        ids.sort();
        ids
    }

    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, Rng};
    use crate::kernel::Kernel;
    use crate::kqr::KqrSolver;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut rng = Rng::new(1);
        let d = synth::sine_hetero(20, &mut rng);
        let fit = KqrSolver::new(&d.x, &d.y, Kernel::Rbf { sigma: 0.5 })
            .unwrap()
            .fit(0.5, 0.1)
            .unwrap();
        let reg = ModelRegistry::new();
        let id = reg.insert(StoredModel::Kqr(fit));
        assert_eq!(reg.len(), 1);
        let m = reg.get(&id).unwrap();
        assert_eq!(m.taus(), vec![0.5]);
        let preds = m.predict(&d.x);
        assert_eq!(preds.len(), 1);
        assert_eq!(preds[0].len(), 20);
        assert!(reg.remove(&id));
        assert!(reg.is_empty());
        assert!(reg.get(&id).is_none());
    }

    #[test]
    fn ids_are_unique_and_listed() {
        let mut rng = Rng::new(2);
        let d = synth::sine_hetero(15, &mut rng);
        let fit = KqrSolver::new(&d.x, &d.y, Kernel::Rbf { sigma: 0.5 })
            .unwrap()
            .fit(0.5, 0.1)
            .unwrap();
        let reg = ModelRegistry::new();
        let a = reg.insert(StoredModel::Kqr(fit.clone()));
        let b = reg.insert(StoredModel::Kqr(fit));
        assert_ne!(a, b);
        assert_eq!(reg.list().len(), 2);
    }
}
