//! Spectral plan for the NCKQR majorized update (paper §3.3 + suppl. B).
//!
//! The two-step majorization (Lipschitz calibration γ ≤ η, then the
//! block-diagonal bound Ψ ⪰ Φ) yields, per quantile level t, the linear
//! system Σ_{γ,λ₁,λ₂} Δ = 2γ ϱ_t with
//!
//!   Σ = [ (1+4nλ₁)n + εnλ₁      (1+4nλ₁)·1ᵀK                       ]
//!       [ (1+4nλ₁)·K1           (1+4nλ₁)K² + 2γnλ₂K + εnλ₁·I       ]
//!
//! (re-derived in DESIGN.md; the supplement's Algorithm-2 swaps λ₁ ↔ λ₂
//! in places — the main-text Σ is the consistent version implemented
//! here). Σ is identical for all T levels, so one spectral setup per
//! (γ, λ₁, λ₂) serves every level:
//!
//!   D  = UΠUᵀ,  Π = (1+4nλ₁)Λ² + 2γnλ₂Λ + εnλ₁ (strictly positive)
//!   v  = U p,   p = (1+4nλ₁)Π⁻¹Λu₁
//!   g  = 1/[(1+4nλ₁)n + εnλ₁ − (1+4nλ₁)²·Σᵢ u₁ᵢ² λᵢ²/Πᵢ]
//!
//! and Σ⁻¹ϱ = g(ς − pᵀΛt)(1; −v) + (0; U(Π⁻¹Λ∘t)), t = Uᵀw − ... as in
//! `step_update`.

use crate::spectral::SpectralBasis;

/// ε ridge of the second majorization.
///
/// The paper sets ε = 10⁻³ so the dense Σ is invertible. In the spectral
/// form every quantity only involves Π⁻¹Λ = 1/(scale·λᵢ + 2γnλ₂), which
/// is bounded even at λᵢ = 0 — exactly like the single-level plan — so
/// the ridge is unnecessary. Worse, a positive ε *throttles convergence
/// in the near-null eigendirections* (the update coefficient becomes
/// λᵢ/ε → 0 while the KKT identity nλ₂αᵢ = zᵢ still needs those
/// directions to move), stalling the exactness certificate. We therefore
/// run with ε = 0; `NcPlan::with_ridge` retains the paper's variant for
/// the ablation bench.
pub const EPSILON_RIDGE: f64 = 0.0;

/// Per-(γ, λ₁, λ₂) spectral precomputation for the NCKQR MM update.
#[derive(Clone, Debug)]
pub struct NcPlan {
    pub gamma: f64,
    pub lam1: f64,
    pub lam2: f64,
    /// scale = 1 + 4nλ₁
    pub scale: f64,
    /// (Π⁻¹Λ)ᵢ = λᵢ / Πᵢ
    pub pil: Vec<f64>,
    /// p = (1+4nλ₁) Π⁻¹Λ u₁
    pub p: Vec<f64>,
    /// Λp cached for the δ scalar
    pub lam_p: Vec<f64>,
    pub g: f64,
}

impl NcPlan {
    pub fn new(basis: &SpectralBasis, gamma: f64, lam1: f64, lam2: f64) -> NcPlan {
        Self::with_ridge(basis, gamma, lam1, lam2, EPSILON_RIDGE)
    }

    /// Variant with an explicit ε (the paper's ε = 10⁻³ is exercised by
    /// the ablation bench; see [`EPSILON_RIDGE`]).
    pub fn with_ridge(
        basis: &SpectralBasis,
        gamma: f64,
        lam1: f64,
        lam2: f64,
        eps: f64,
    ) -> NcPlan {
        assert!(gamma > 0.0 && lam1 >= 0.0 && lam2 > 0.0);
        let n = basis.n as f64;
        let scale = 1.0 + 4.0 * n * lam1;
        let ridge = eps * n * lam1;
        let pil: Vec<f64> = basis
            .lambda
            .iter()
            .map(|&l| {
                let pi = scale * l * l + 2.0 * gamma * n * lam2 * l + ridge;
                if pi > 0.0 {
                    l / pi
                } else {
                    // lam1 = 0 and l = 0: the λ₁=0 limit 1/(l + 2nγλ₂)
                    1.0 / (2.0 * gamma * n * lam2)
                }
            })
            .collect();
        let p: Vec<f64> = pil.iter().zip(&basis.u1).map(|(pi, u)| scale * pi * u).collect();
        let lam_p: Vec<f64> = p.iter().zip(&basis.lambda).map(|(pi, l)| pi * l).collect();
        // Σᵢ u₁ᵢ² λᵢ²/Πᵢ = Σ u₁ᵢ² λᵢ (Π⁻¹Λ)ᵢ
        let s: f64 = basis
            .u1
            .iter()
            .zip(basis.lambda.iter().zip(&pil))
            .map(|(u, (l, pi))| u * u * l * pi)
            .sum();
        let g = 1.0 / (scale * n + ridge - scale * scale * s);
        NcPlan { gamma, lam1, lam2, scale, pil, p, lam_p, g }
    }

    /// One Σ⁻¹ϱ update for one level.
    ///
    /// `w` is the value-space carrier w = z − nλ₁(q_t − q_{t−1});
    /// ς = Σᵢ wᵢ; on input `t_scratch` is overwritten with
    /// t = Uᵀw − nλ₂β. Writes the 2γ-scaled Δβ into `dbeta` and returns
    /// the 2γ-scaled Δb.
    pub fn step_update(
        &self,
        basis: &SpectralBasis,
        w: &[f64],
        beta: &[f64],
        t_scratch: &mut [f64],
        dbeta: &mut [f64],
    ) -> f64 {
        let n = basis.n as f64;
        let nlam2 = n * self.lam2;
        crate::linalg::gemv_t(&basis.u, w, t_scratch);
        for (t, b) in t_scratch.iter_mut().zip(beta) {
            *t -= nlam2 * b;
        }
        let sig: f64 = w.iter().sum();
        let vkw: f64 = self.lam_p.iter().zip(t_scratch.iter()).map(|(a, t)| a * t).sum();
        let delta = self.g * (sig - vkw);
        let two_g = 2.0 * self.gamma;
        for i in 0..dbeta.len() {
            dbeta[i] = two_g * (self.pil[i] * t_scratch[i] - delta * self.p[i]);
        }
        two_g * delta
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::kernel::Kernel;
    use crate::linalg::{gemm, gemv, Cholesky, Matrix};
    use crate::spectral::SpectralPlan;

    fn fixture(n: usize, seed: u64) -> (Matrix, SpectralBasis) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(n, 2, |_, _| rng.normal());
        let k = Kernel::Rbf { sigma: 1.0 }.gram(&x);
        let b = SpectralBasis::new(&k).unwrap();
        (k, b)
    }

    #[test]
    fn lam1_zero_reduces_to_single_level_plan() {
        let (_, basis) = fixture(12, 1);
        let nc = NcPlan::new(&basis, 0.3, 0.0, 0.05);
        let single = SpectralPlan::new(&basis, 0.3, 0.05);
        assert!((nc.g - single.g).abs() < 1e-12);
        for i in 0..12 {
            assert!((nc.pil[i] - single.pil[i]).abs() < 1e-10, "pil[{i}]");
            assert!((nc.p[i] - single.p[i]).abs() < 1e-10, "p[{i}]");
        }
        // identical update directions
        let mut rng = Rng::new(2);
        let w: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let beta: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let mut t1 = vec![0.0; 12];
        let mut d1 = vec![0.0; 12];
        let db1 = nc.step_update(&basis, &w, &beta, &mut t1, &mut d1);
        let mut t2 = vec![0.0; 12];
        let mut d2 = vec![0.0; 12];
        let db2 = single.step_update(&basis, &w, &beta, &mut t2, &mut d2);
        assert!((db1 - db2).abs() < 1e-10);
        for i in 0..12 {
            assert!((d1[i] - d2[i]).abs() < 1e-10);
        }
    }

    /// The spectral Σ⁻¹ must match a dense Cholesky solve of the
    /// explicitly assembled Σ matrix.
    #[test]
    fn matches_dense_sigma_inverse() {
        let n = 9usize;
        let (k, basis) = fixture(n, 3);
        let (gamma, lam1, lam2) = (0.2, 0.07, 0.04);
        let eps = 1e-3; // exercise the paper's ridge variant for parity
        let plan = NcPlan::with_ridge(&basis, gamma, lam1, lam2, eps);
        let nf = n as f64;
        let scale = 1.0 + 4.0 * nf * lam1;
        let ridge = eps * nf * lam1;
        // dense Σ
        let k2 = gemm(&k, &k);
        let mut sig = Matrix::zeros(n + 1, n + 1);
        sig[(0, 0)] = scale * nf + ridge;
        let k_colsum: Vec<f64> = (0..n).map(|j| (0..n).map(|i| k[(i, j)]).sum()).collect();
        for j in 0..n {
            sig[(0, j + 1)] = scale * k_colsum[j];
            sig[(j + 1, 0)] = scale * k_colsum[j];
        }
        for i in 0..n {
            for j in 0..n {
                sig[(i + 1, j + 1)] = scale * k2[(i, j)] + 2.0 * gamma * nf * lam2 * k[(i, j)];
            }
            sig[(i + 1, i + 1)] += ridge;
        }
        let mut rng = Rng::new(4);
        let w: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let alpha: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let beta = basis.beta_from_alpha(&alpha);
        // ϱ = (Σw ; K(w − nλ₂α))
        let mut wv = vec![0.0; n];
        for i in 0..n {
            wv[i] = w[i] - nf * lam2 * alpha[i];
        }
        let mut kw = vec![0.0; n];
        gemv(&k, &wv, &mut kw);
        let mut rho = vec![w.iter().sum::<f64>()];
        rho.extend_from_slice(&kw);
        let dense = Cholesky::new(&sig).unwrap().solve(&rho);
        // spectral
        let mut t = vec![0.0; n];
        let mut dbeta = vec![0.0; n];
        let db = plan.step_update(&basis, &w, &beta, &mut t, &mut dbeta);
        let dalpha = basis.alpha_from_beta(&dbeta);
        assert!((db - 2.0 * gamma * dense[0]).abs() < 1e-7, "{db} vs {}", 2.0 * gamma * dense[0]);
        for i in 0..n {
            assert!(
                (dalpha[i] - 2.0 * gamma * dense[i + 1]).abs() < 1e-7,
                "i={i}: {} vs {}",
                dalpha[i],
                2.0 * gamma * dense[i + 1]
            );
        }
    }

    #[test]
    fn plan_strictly_positive_pi_with_lam1() {
        // with λ₁ > 0 the ε-ridge keeps Π positive even at λᵢ = 0
        let mut x = Matrix::zeros(6, 1);
        for i in 0..6 {
            x[(i, 0)] = (i / 2) as f64;
        }
        let k = Kernel::Rbf { sigma: 1.0 }.gram(&x);
        let basis = SpectralBasis::new(&k).unwrap();
        let plan = NcPlan::new(&basis, 1e-5, 0.5, 0.1);
        assert!(plan.pil.iter().all(|v| v.is_finite()));
        assert!(plan.g.is_finite() && plan.g > 0.0);
    }
}
