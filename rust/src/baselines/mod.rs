//! Comparator solvers for the paper's evaluation (DESIGN.md §3).
//!
//! | paper baseline | this module | algorithm class |
//! |---|---|---|
//! | `kernlab` (ipop) | [`ipm`] | dual interior-point QP, O(n³)/iter |
//! | `nlm` | [`lbfgs`] | generic quasi-Newton on G^γ |
//! | `optim` | [`neldermead`] | derivative-free simplex on G^γ |
//! | `cvxr` | [`proximal`] | structure-blind accelerated first-order |
//!
//! All report the **exact** check-loss objective of the paper's problem
//! so the tables compare like with like.

pub mod ipm;
pub mod lbfgs;
pub mod neldermead;
pub mod proximal;

pub use ipm::{solve_kqr_ipm, IpmFit, IpmOptions};
pub use lbfgs::{solve_kqr_lbfgs, GenericFit};
pub use neldermead::solve_kqr_nelder_mead;
pub use proximal::{solve_nckqr_proximal, ProximalFit};
