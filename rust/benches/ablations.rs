//! Ablations: spectral vs dense, warm vs cold, Nesterov/projection,
//! NCKQR ε-ridge. See DESIGN.md §5.
use fastkqr::experiments::ablations;
use fastkqr::util::Args;

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 100);
    let seed = args.get_usize("seed", 2024) as u64;
    let mut rows = Vec::new();
    rows.extend(ablations::spectral_vs_dense(n, args.get_usize("plans", 8), seed).unwrap());
    rows.extend(ablations::warm_vs_cold(n, args.get_usize("nlam", 20), seed).unwrap());
    rows.extend(ablations::solver_switches(n.min(80), seed).unwrap());
    rows.extend(ablations::nckqr_ridge(n.min(60), seed).unwrap());
    ablations::print_rows(&rows);
}
