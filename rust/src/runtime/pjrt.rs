//! The real PJRT runtime (`xla` feature): a PJRT CPU client, a compiled
//! executable cache, and the [`XlaBackend`] that marshals spectral state
//! into literals, zero-padding to the artifact size (exact under the
//! mask — see python/compile/model.py), and executes the `apgd_chunk`
//! artifact.

use super::ArtifactManifest;
use crate::backend::Backend;
use crate::kqr::apgd::ApgdState;
use crate::spectral::{SpectralBasis, SpectralPlan};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::path::Path;

/// PJRT CPU client + compiled-executable cache.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    compiled: HashMap<usize, xla::PjRtLoadedExecutable>,
}

impl XlaRuntime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn new(dir: impl AsRef<Path>) -> Result<XlaRuntime> {
        let manifest = ArtifactManifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(XlaRuntime { client, manifest, compiled: HashMap::new() })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the apgd_chunk executable for
    /// problem size n. Returns (artifact_n, chunk).
    pub fn chunk_executable(&mut self, n: usize) -> Result<(usize, usize)> {
        let entry = self
            .manifest
            .best_for(n)
            .ok_or_else(|| anyhow!("no artifact covers n={n} (max {:?})",
                self.manifest.entries.last().map(|e| e.n)))?
            .clone();
        if !self.compiled.contains_key(&entry.n) {
            let proto = xla::HloModuleProto::from_text_file(
                entry.path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("parse {:?}: {e:?}", entry.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {:?}: {e:?}", entry.path))?;
            self.compiled.insert(entry.n, exe);
        }
        Ok((entry.n, entry.chunk))
    }

    fn exe(&self, artifact_n: usize) -> &xla::PjRtLoadedExecutable {
        &self.compiled[&artifact_n]
    }
}

/// Padded per-problem buffers reused across chunk calls.
struct Prepared {
    /// fingerprint: (basis n, U data address) — a new solver/basis
    /// allocates a fresh matrix, so the address disambiguates.
    key: (usize, usize),
    artifact_n: usize,
    chunk: usize,
    /// Problem-constant operands cached as host literals. (A resident
    /// device-buffer variant via `execute_b` was tried in the perf pass
    /// and reverted: the PJRT C wrapper donates input buffers, so reusing
    /// them across calls is unsound — see EXPERIMENTS.md §Perf.)
    u_lit: xla::Literal,
    lam_lit: xla::Literal,
    y_lit: xla::Literal,
    mask_lit: xla::Literal,
    inv_n_lit: xla::Literal,
    /// plan fingerprint (gamma, lam) for the cached plan literals
    plan_key: (f64, f64),
    pil_lit: xla::Literal,
    p_lit: xla::Literal,
    lam_p_lit: xla::Literal,
    g_lit: xla::Literal,
}

/// APGD backend executing the AOT artifact through PJRT.
pub struct XlaBackend {
    runtime: XlaRuntime,
    prepared: Option<Prepared>,
    /// Number of artifact executions (for perf accounting).
    pub executions: usize,
}

impl XlaBackend {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<XlaBackend> {
        Ok(XlaBackend { runtime: XlaRuntime::new(artifact_dir)?, prepared: None, executions: 0 })
    }

    /// Default artifact location relative to the repo root.
    pub fn from_default_dir() -> Result<XlaBackend> {
        XlaBackend::new("artifacts")
    }

    fn vec_literal(v: &[f64], pad_to: usize, fill: f64) -> xla::Literal {
        let mut data = Vec::with_capacity(pad_to);
        data.extend_from_slice(v);
        data.resize(pad_to, fill);
        xla::Literal::vec1(&data)
    }

    fn scalar_literal(v: f64) -> xla::Literal {
        xla::Literal::vec1(&[v]).reshape(&[]).expect("scalar reshape")
    }

    fn prepare(
        &mut self,
        basis: &SpectralBasis,
        plan: &SpectralPlan,
        y: &[f64],
    ) -> Result<()> {
        let n = basis.n;
        if basis.dim() != n {
            bail!(
                "XlaBackend requires a square (dense) spectral basis: the AOT \
                 artifacts are compiled for n×n U, got a rank-{} thin factor \
                 (use the native backend for low-rank/Nyström bases)",
                basis.dim()
            );
        }
        let key = (n, basis.u.as_slice().as_ptr() as usize);
        let plan_key = (plan.gamma, plan.lam);
        let need_problem =
            self.prepared.as_ref().map(|p| p.key != key).unwrap_or(true);
        let need_plan = need_problem
            || self.prepared.as_ref().map(|p| p.plan_key != plan_key).unwrap_or(true);
        if !need_problem && !need_plan {
            return Ok(());
        }
        let (artifact_n, chunk) = self.runtime.chunk_executable(n)?;
        if need_problem {
            // padded U (artifact_n × artifact_n, row-major)
            let mut u_pad = vec![0.0f64; artifact_n * artifact_n];
            for i in 0..n {
                u_pad[i * artifact_n..i * artifact_n + n].copy_from_slice(basis.u.row(i));
            }
            let u_lit = xla::Literal::vec1(&u_pad)
                .reshape(&[artifact_n as i64, artifact_n as i64])
                .map_err(|e| anyhow!("reshape U: {e:?}"))?;
            let lam_lit = Self::vec_literal(&basis.lambda, artifact_n, 0.0);
            let y_lit = Self::vec_literal(y, artifact_n, 0.0);
            let mask = vec![1.0f64; n];
            let mask_lit = Self::vec_literal(&mask, artifact_n, 0.0);
            let inv_n_lit = Self::scalar_literal(1.0 / n as f64);
            self.prepared = Some(Prepared {
                key,
                artifact_n,
                chunk,
                u_lit,
                lam_lit,
                y_lit,
                mask_lit,
                inv_n_lit,
                plan_key: (f64::NAN, f64::NAN),
                pil_lit: Self::scalar_literal(0.0),
                p_lit: Self::scalar_literal(0.0),
                lam_p_lit: Self::scalar_literal(0.0),
                g_lit: Self::scalar_literal(0.0),
            });
        }
        let prepared = self.prepared.as_mut().expect("prepared set above");
        if need_plan || prepared.plan_key.0.is_nan() {
            // padded plan vectors; pil padding uses the λ=0 limit value
            // (inert because t_pad = 0, but keep it finite)
            let pad_pil = 1.0 / (2.0 * n as f64 * plan.gamma * plan.lam);
            prepared.pil_lit = Self::vec_literal(&plan.pil, prepared.artifact_n, pad_pil);
            prepared.p_lit = Self::vec_literal(&plan.p, prepared.artifact_n, 0.0);
            prepared.lam_p_lit = Self::vec_literal(&plan.lam_p, prepared.artifact_n, 0.0);
            prepared.g_lit = Self::scalar_literal(plan.g);
            prepared.plan_key = plan_key;
        }
        Ok(())
    }

    /// Execute one chunk; fallible inner implementation.
    fn chunk_inner(
        &mut self,
        basis: &SpectralBasis,
        plan: &SpectralPlan,
        y: &[f64],
        tau: f64,
        state: &mut ApgdState,
        iters: usize,
    ) -> Result<f64> {
        self.prepare(basis, plan, y)?;
        let prepared = self.prepared.as_ref().expect("prepared");
        if iters != prepared.chunk {
            bail!(
                "XlaBackend: artifact chunk={} but {iters} iterations requested \
                 (set SolveOptions::chunk to match)",
                prepared.chunk
            );
        }
        let n = basis.n;
        let prepared = self.prepared.as_ref().expect("prepared");
        let an = prepared.artifact_n;
        let nlam = n as f64 * plan.lam;
        let beta_lit = Self::vec_literal(&state.beta, an, 0.0);
        let beta_prev_lit = Self::vec_literal(&state.beta_prev, an, 0.0);
        let tau_lit = Self::scalar_literal(tau);
        let gamma_lit = Self::scalar_literal(plan.gamma);
        let nlam_lit = Self::scalar_literal(nlam);
        let b_lit = Self::scalar_literal(state.b);
        let b_prev_lit = Self::scalar_literal(state.b_prev);
        let ck_lit = Self::scalar_literal(state.ck);
        let all_args: Vec<&xla::Literal> = vec![
            &prepared.u_lit,
            &prepared.lam_lit,
            &prepared.pil_lit,
            &prepared.p_lit,
            &prepared.lam_p_lit,
            &prepared.g_lit,
            &prepared.y_lit,
            &prepared.mask_lit,
            &prepared.inv_n_lit,
            &tau_lit,
            &gamma_lit,
            &nlam_lit,
            &b_lit,
            &beta_lit,
            &b_prev_lit,
            &beta_prev_lit,
            &ck_lit,
        ];
        let exe = self.runtime.exe(an);
        let result = exe
            .execute::<&xla::Literal>(&all_args)
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sync: {e:?}"))?;
        self.executions += 1;
        let parts = result.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))?;
        if parts.len() != 6 {
            bail!("artifact returned {} outputs, expected 6", parts.len());
        }
        let get_scalar = |l: &xla::Literal| -> Result<f64> {
            Ok(l.to_vec::<f64>().map_err(|e| anyhow!("scalar out: {e:?}"))?[0])
        };
        state.b = get_scalar(&parts[0])?;
        let beta = parts[1].to_vec::<f64>().map_err(|e| anyhow!("beta out: {e:?}"))?;
        state.beta.copy_from_slice(&beta[..n]);
        state.b_prev = get_scalar(&parts[2])?;
        let beta_prev = parts[3].to_vec::<f64>().map_err(|e| anyhow!("beta_prev: {e:?}"))?;
        state.beta_prev.copy_from_slice(&beta_prev[..n]);
        state.ck = get_scalar(&parts[4])?;
        get_scalar(&parts[5])
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn apgd_chunk(
        &mut self,
        basis: &SpectralBasis,
        plan: &SpectralPlan,
        y: &[f64],
        tau: f64,
        state: &mut ApgdState,
        iters: usize,
    ) -> f64 {
        self.chunk_inner(basis, plan, y, tau, state, iters)
            .expect("XlaBackend chunk execution failed")
    }
}
