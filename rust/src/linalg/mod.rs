//! Dense linear algebra substrate (no external BLAS/LAPACK available).
//!
//! - [`matrix::Matrix`]: row-major dense matrix
//! - [`simd`]: runtime-resolved vector microkernel dispatch table
//!   (`FASTKQR_SIMD` / `FASTKQR_FMA`) — AVX2 on x86_64, NEON on aarch64,
//!   with the scalar reference kernels as the **bitwise oracle**; every
//!   level-1 primitive below pulls its inner loop from here
//! - [`blas`]: dot/axpy/GEMV/GEMM kernels (the O(n²) hot path), each
//!   dispatching to the parallel substrate above a size cutoff
//! - [`gemm`]: BLAS-3 layer — multi-RHS `gemm_nt_into`/`gemm_nn_into`
//!   (bitwise equal per column/row to the serial GEMV kernels; the
//!   lockstep grid solver's two-GEMMs-per-iteration substrate) and the
//!   packed Mc/Kc/Nc-tiled [`gemm::gemm_into`] microkernel
//!   (`FASTKQR_GEMM_MC`/`_KC`/`_NC`)
//! - [`par`]: scoped-thread row-blocked parallel kernels + the
//!   [`par::Parallelism`] configuration (env-overridable)
//! - [`eigen::SymEigen`]: one-time K = UΛUᵀ decomposition, with the
//!   O(n³) `tred2` phases row-banded onto the parallel substrate
//! - [`chol::Cholesky`]: SPD solves for the interior-point baseline
//!
//! Parallel × SIMD compose cleanly: the row-band workers call the same
//! dispatched serial kernels per band, so turning either axis on or off
//! never changes a result bit (outside the opt-in FMA tier).

pub mod blas;
pub mod chol;
pub mod eigen;
pub mod gemm;
pub mod matrix;
pub mod par;
pub mod simd;

pub use blas::{amax, axpy, dot, gemm, gemv, gemv_t, nrm2, quad_form, scal};
pub use chol::{CholError, Cholesky};
pub use eigen::SymEigen;
pub use gemm::{gemm_into, gemm_nn_into, gemm_nt_into, GemmTiles};
pub use matrix::Matrix;
pub use par::Parallelism;
pub use simd::SimdDispatch;
