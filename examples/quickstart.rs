//! Quickstart: fit an exact kernel quantile regression in a few lines.
//!
//!     cargo run --release --example quickstart
//!
//! Fits the 0.1/0.5/0.9 conditional quantiles of a heteroscedastic 1-D
//! signal, verifies the exactness certificate, and prints a small text
//! rendering of the fitted curves.

use fastkqr::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. data: y = 2·sin(2πx) + (0.5 + x)·ε  — noise grows with x
    let mut rng = Rng::new(7);
    let data = fastkqr::data::synth::sine_hetero(200, &mut rng);

    // 2. kernel: RBF with the median-heuristic bandwidth
    let kernel = Kernel::Rbf { sigma: median_heuristic_sigma(&data.x) };

    // 3. one solver = one eigendecomposition, reused across all fits
    let solver = KqrSolver::new(&data.x, &data.y, kernel)?;

    println!("n = {}, kernel = {:?}\n", data.n(), solver.kernel);
    println!("{:<6} {:>12} {:>10} {:>8} {:>10}", "tau", "objective", "iters", "KKT", "|S|");
    let mut fits = Vec::new();
    for tau in [0.1, 0.5, 0.9] {
        let fit = solver.fit(tau, 1e-3)?;
        println!(
            "{:<6} {:>12.6} {:>10} {:>8} {:>10}",
            tau,
            fit.objective,
            fit.apgd_iters,
            fit.kkt.pass,
            fit.singular_set.len()
        );
        assert!(fit.kkt.pass, "exactness certificate must hold");
        fits.push(fit);
    }

    // 4. predict on a grid and sketch the quantile band
    let grid = fastkqr::linalg::Matrix::from_fn(61, 1, |i, _| i as f64 / 60.0);
    let curves: Vec<Vec<f64>> = fits.iter().map(|f| f.predict(&grid)).collect();
    println!("\nquantile band (q10 | q50 | q90), x in [0,1]:");
    for i in (0..61).step_by(6) {
        let x = i as f64 / 60.0;
        println!(
            "  x={x:.2}  {:>7.2} | {:>7.2} | {:>7.2}",
            curves[0][i], curves[1][i], curves[2][i]
        );
    }

    // 5. the band should widen with x (heteroscedastic data)
    let width_lo = curves[2][6] - curves[0][6];
    let width_hi = curves[2][54] - curves[0][54];
    println!("\nband width at x=0.1: {width_lo:.2}, at x=0.9: {width_hi:.2}");
    assert!(width_hi > width_lo, "band should widen with the noise");

    // 6. the declarative surface: the same fit as a FitSpec on the
    //    engine, persisted to an artifact and reloaded bitwise.
    let spec = FitSpec::grid(
        solver.x.as_ref().clone(),
        solver.y.clone(),
        KernelSpec::exact(&solver.kernel),
        vec![0.1, 0.5, 0.9],
        vec![1e-3],
    );
    let model = FitEngine::global().run(&spec)?;
    assert!(model.kkt_pass(), "every grid cell certifies");
    let path = std::env::temp_dir().join("fastkqr-quickstart-model.json");
    model.save(&path)?;
    let reloaded = QuantileModel::load(&path)?;
    assert_eq!(reloaded.predict(&grid), model.predict(&grid), "reload is exact");
    println!(
        "FitSpec -> QuantileModel: {} levels saved to {} and reloaded bitwise",
        model.n_levels(),
        path.display()
    );
    let _ = std::fs::remove_file(&path);
    println!("quickstart OK");
    Ok(())
}
