//! PredictEngine integration: batched-vs-unbatched bitwise parity (dense
//! grid and Nyström models), backpressure behaviour, streamed predict
//! responses, and plan compilation at registry insert/reload.

use fastkqr::api::QuantileModel;
use fastkqr::coordinator::batcher::{BatchConfig, PredictBatcher};
use fastkqr::coordinator::{Metrics, ModelRegistry};
use fastkqr::data::{synth, Rng};
use fastkqr::engine::{ApproxSpec, FitEngine};
use fastkqr::kernel::Kernel;
use fastkqr::linalg::Matrix;
use std::sync::Arc;

fn dense_grid_model(n: usize, seed: u64) -> QuantileModel {
    let mut rng = Rng::new(seed);
    let data = synth::sine_hetero(n, &mut rng);
    let grid = FitEngine::new()
        .fit_grid(&data.x, &data.y, &Kernel::Rbf { sigma: 0.5 }, &[0.25, 0.75], &[0.1, 0.01])
        .unwrap();
    QuantileModel::from_grid(grid)
}

fn nystrom_model(n: usize, m: usize, seed: u64) -> QuantileModel {
    let mut rng = Rng::new(seed);
    let data = synth::sine_hetero(n, &mut rng);
    let engine = FitEngine::new();
    let solver = engine
        .solver_approx(
            &data.x,
            &data.y,
            &Kernel::Rbf { sigma: 0.5 },
            ApproxSpec::Nystrom { m, seed: 11 },
            Default::default(),
        )
        .unwrap();
    let fit = solver.fit(0.5, 0.05).unwrap();
    assert!(fit.lowrank.is_some(), "nystrom fit carries the landmark predictor");
    QuantileModel::Kqr(fit)
}

/// N threads firing single-row predicts through the batcher must produce
/// rows identical to sequential `model.predict`, whatever batches they
/// landed in.
fn assert_concurrent_parity(model: &QuantileModel, label: &str) {
    let plan = Arc::new(model.compile_plan());
    let batcher =
        Arc::new(PredictBatcher::new(BatchConfig { window_us: 10_000, max_rows: 4096 }));
    let metrics = Arc::new(Metrics::new());
    let queries: Vec<Matrix> =
        (0..12).map(|i| Matrix::from_fn(1, 1, |_, _| -0.5 + 0.09 * i as f64)).collect();
    let results: Vec<Vec<Vec<f64>>> = std::thread::scope(|s| {
        let handles: Vec<_> = queries
            .iter()
            .map(|q| {
                let batcher = batcher.clone();
                let plan = plan.clone();
                let metrics = metrics.clone();
                let q = q.clone();
                s.spawn(move || batcher.predict("m0", &plan, q, &metrics).unwrap())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (q, got) in queries.iter().zip(&results) {
        let want = model.predict(q);
        assert_eq!(got, &want, "{label}: batched row must be bitwise equal");
    }
    let batches = Metrics::get(&metrics.predict_batches);
    assert!(
        (1..=12).contains(&batches),
        "{label}: {batches} batches for 12 requests"
    );
    assert_eq!(
        metrics.predict_batch_size.count(),
        batches,
        "{label}: every batch recorded once"
    );
}

#[test]
fn batched_predicts_match_sequential_dense_grid() {
    assert_concurrent_parity(&dense_grid_model(50, 1), "dense 2x2 grid");
}

#[test]
fn batched_predicts_match_sequential_nystrom() {
    assert_concurrent_parity(&nystrom_model(60, 20, 2), "nystrom m=20");
}

#[test]
fn multi_row_requests_batch_bitwise_too() {
    // Mixed-size requests stacked into one GEMM still scatter exactly.
    let model = dense_grid_model(40, 3);
    let plan = model.compile_plan();
    let mut rng = Rng::new(17);
    let parts: Vec<Matrix> = (1..=5).map(|i| synth::sine_hetero(i, &mut rng).x).collect();
    let batched = plan.predict_many(&parts);
    for (part, got) in parts.iter().zip(&batched) {
        assert_eq!(got, &model.predict(part));
    }
}

#[test]
fn backpressure_rejects_cleanly_instead_of_hanging() {
    let model = dense_grid_model(30, 4);
    let plan = Arc::new(model.compile_plan());
    // 1 s window so every thread (released together by the barrier) lands
    // inside one batch cycle; cap 3 rows.
    let batcher =
        Arc::new(PredictBatcher::new(BatchConfig { window_us: 1_000_000, max_rows: 3 }));
    let metrics = Arc::new(Metrics::new());
    let barrier = Arc::new(std::sync::Barrier::new(5));
    let t0 = std::time::Instant::now();
    let outcomes: Vec<anyhow::Result<Vec<Vec<f64>>>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..5)
            .map(|i| {
                let batcher = batcher.clone();
                let plan = plan.clone();
                let metrics = metrics.clone();
                let barrier = barrier.clone();
                s.spawn(move || {
                    let x = Matrix::from_fn(1, 1, |_, _| 0.1 * i as f64);
                    barrier.wait();
                    batcher.predict("m0", &plan, x, &metrics)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert!(t0.elapsed().as_secs() < 30, "no hang");
    let ok = outcomes.iter().filter(|r| r.is_ok()).count();
    assert_eq!(ok, 3, "cap of 3 rows admits exactly 3 single-row requests");
    for err in outcomes.iter().filter_map(|r| r.as_ref().err()) {
        assert!(err.to_string().contains("full"), "clean error, got: {err:#}");
    }
    assert_eq!(Metrics::get(&metrics.predict_rejects), 2);
}

#[test]
fn server_batches_concurrent_tcp_predicts_and_streams() {
    if std::net::TcpListener::bind("127.0.0.1:0").is_err() {
        eprintln!("skipping: no loopback TCP available in this environment");
        return;
    }
    use fastkqr::coordinator::server::Client;
    use fastkqr::coordinator::{Server, ServerConfig};
    use fastkqr::util::Json;
    let server = Server::spawn(ServerConfig {
        addr: "127.0.0.1:0".into(),
        batch: BatchConfig { window_us: 5_000, max_rows: 4096 },
        ..ServerConfig::default()
    })
    .unwrap();
    let model = dense_grid_model(30, 9);
    let id = server.registry.insert(model.clone());
    let want: Vec<f64> =
        model.predict(&Matrix::from_fn(1, 1, |_, _| 0.5)).iter().map(|r| r[0]).collect();
    let addr = server.local_addr;
    std::thread::scope(|s| {
        for _ in 0..8 {
            let id = &id;
            let want = &want;
            s.spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let req = Json::parse(&format!(
                    r#"{{"cmd":"predict","model":"{id}","x":[[0.5]]}}"#
                ))
                .unwrap();
                let r = c.request(&req).unwrap();
                assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r:?}");
                // shortest-roundtrip floats: the wire row is bitwise equal
                let got: Vec<f64> = r
                    .get("pred")
                    .unwrap()
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|row| row.as_arr().unwrap()[0].as_f64().unwrap())
                    .collect();
                assert_eq!(&got, want);
            });
        }
    });
    // streamed predict over the same wire
    let mut c = Client::connect(addr).unwrap();
    let req = Json::parse(&format!(
        r#"{{"cmd":"predict","model":"{id}","x":[[0.1],[0.5],[0.9]],"stream":true,"chunk_points":2}}"#
    ))
    .unwrap();
    let lines = c.request_stream(&req).unwrap();
    assert_eq!(lines.len(), 4, "header + 2 chunks + done: {lines:?}");
    assert_eq!(lines[0].get("stream").and_then(Json::as_bool), Some(true));
    assert_eq!(lines[3].get("done").and_then(Json::as_bool), Some(true));
    // metrics over the wire: batching accounted, never more batches than
    // requests
    let m = c.request(&Json::parse(r#"{"cmd":"metrics"}"#).unwrap()).unwrap();
    assert_eq!(m.get_f64("predict_requests"), Some(9.0));
    let batches = m.get_f64("predict_batches").unwrap();
    assert!(batches >= 1.0 && batches <= 9.0, "batches = {batches}");
    server.shutdown();
}

#[test]
fn registry_compiles_plans_at_insert_and_reload() {
    let dir = std::env::temp_dir().join(format!(
        "fastkqr-predict-engine-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    let model = dense_grid_model(25, 5);
    let xt = {
        let mut rng = Rng::new(23);
        synth::sine_hetero(6, &mut rng).x
    };
    let want = model.predict(&xt);
    let id = {
        let reg = ModelRegistry::with_persistence(&dir).unwrap();
        let id = reg.insert(model.clone());
        assert_eq!(reg.plan(&id).unwrap().predict(&xt), want);
        id
    };
    // a fresh registry on the same dir compiles the plan from the
    // artifact and serves bitwise-identical rows
    let reg2 = ModelRegistry::with_persistence(&dir).unwrap();
    let plan = reg2.plan(&id).expect("plan recompiled on reload");
    assert_eq!(plan.n_levels(), 4);
    assert_eq!(plan.predict(&xt), want, "reloaded plan predicts bitwise");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn nystrom_plan_reloads_bitwise_through_registry() {
    let dir = std::env::temp_dir().join(format!(
        "fastkqr-predict-engine-ny-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    let model = nystrom_model(48, 16, 6);
    let xt = {
        let mut rng = Rng::new(29);
        synth::sine_hetero(5, &mut rng).x
    };
    let want = model.predict(&xt);
    let id = {
        let reg = ModelRegistry::with_persistence(&dir).unwrap();
        reg.insert(model)
    };
    let reg2 = ModelRegistry::with_persistence(&dir).unwrap();
    let plan = reg2.plan(&id).expect("compressed artifact compiles a plan");
    assert_eq!(plan.predict(&xt), want, "low-rank plan predicts bitwise after reload");
    let _ = std::fs::remove_dir_all(&dir);
}
